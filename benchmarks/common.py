"""Shared benchmark harness: scenario construction, base-model pretraining,
accuracy evaluation, timing.

Scale note: the paper runs LLaMA2-7B on GPU clusters; offline we reproduce
the *algorithmic* claims with a reduced transformer on synthetic versions of
both scenarios (DESIGN.md §6). Every benchmark prints CSV rows
``name,us_per_call,derived`` — `derived` carries the paper-table metric
(accuracy, bytes, ...).
"""
from __future__ import annotations

import os
import time
from typing import Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.lora import lora_scale
from repro.data.partition import dirichlet_partition, train_test_split
from repro.data.pipeline import SFTBatcher
from repro.data.synthetic import (answer_accuracy, gen_log_dataset,
                                  gen_medical_dataset, gen_pretrain_text)
from repro.data.tokenizer import ByteTokenizer
from repro.models.api import get_model
from repro.training.checkpoint import load_checkpoint, save_checkpoint
from repro.training.optimizers import adamw
from repro.training.train_step import make_full_train_step

MAX_LEN = 160
FAST = bool(int(os.environ.get("BENCH_FAST", "0")))

BENCH_CFG = ModelConfig(
    name="bench-llm", family="dense", n_layers=2, d_model=128, n_heads=4,
    n_kv_heads=2, d_ff=256, vocab_size=300, max_seq_len=MAX_LEN,
    lora_rank=8, remat=False, param_dtype="float32", dtype="float32")

_CACHE: Dict[str, object] = {}


def tokenizer() -> ByteTokenizer:
    return ByteTokenizer()


def pretrained_base(cfg: ModelConfig = BENCH_CFG, steps: int = 300):
    """'Basic knowledge': pretrain the tiny backbone on scenario-flavoured
    text once, cache to disk. The paper's frozen LLM analog."""
    key = f"base-{cfg.name}-{steps}"
    if key in _CACHE:
        return _CACHE[key]
    path = os.path.join("experiments", "cache", key + ".npz")
    model = get_model(cfg)
    if os.path.exists(path + ".meta.json"):
        params = load_checkpoint(path)
        _CACHE[key] = params
        return params
    rng = np.random.default_rng(0)
    tok = tokenizer()
    # mixed corpus: generic text + unlabeled samples from both scenarios
    texts = gen_pretrain_text(rng, 300)
    pool = (gen_log_dataset(rng, 300, 0) + gen_log_dataset(rng, 300, 1)
            + gen_log_dataset(rng, 300, 2)
            + sum((gen_medical_dataset(rng, 120, t) for t in range(5)), []))
    texts += [ex.prompt + ex.answer for ex in pool]
    from repro.data.tokenizer import pad_batch
    seqs = [tok.encode(t, add_eos=True) for t in texts]
    params = model.init(jax.random.PRNGKey(0))
    opt = adamw(lr=3e-3)
    st = opt.init(params)
    step = jax.jit(make_full_train_step(model, cfg, opt))
    nb = max(1, steps)
    bs = 16
    for i in range(nb):
        idx = rng.integers(0, len(seqs), size=bs)
        toks, mask = pad_batch([seqs[j] for j in idx], MAX_LEN)
        batch = {"tokens": jnp.asarray(toks), "loss_mask": jnp.asarray(mask)}
        params, st, m = step(params, st, batch)
    os.makedirs(os.path.dirname(path), exist_ok=True)
    save_checkpoint(path, params, {"loss": float(m["loss"])})
    _CACHE[key] = params
    return params


def build_scenario(scenario: int, n_clients: int, alpha: float, seed: int = 0,
                   n_per_source: int = 120):
    """Returns (batchers, test_sets) per client under Dirichlet(α) non-IID."""
    rng = np.random.default_rng(seed)
    tok = tokenizer()
    if scenario == 1:
        data = sum((gen_log_dataset(rng, n_per_source, s) for s in range(3)), [])
    else:
        data = sum((gen_medical_dataset(rng, n_per_source, t) for t in range(5)), [])
    parts = dirichlet_partition(data, n_clients, alpha, rng, min_per_client=10)
    batchers, tests = [], []
    for i, part in enumerate(parts):
        tr, te = train_test_split(part, 0.2, rng)  # paper: 8:2 per client
        batchers.append(SFTBatcher(tr, tok, MAX_LEN, batch_size=8,
                                   seed=seed * 100 + i))
        tests.append(te)
    return batchers, tests


def eval_clients(model, cfg, params, adapters_per_client, tests) -> float:
    """Mean client accuracy (the paper's headline metric)."""
    tok = tokenizer()
    accs = []
    for ad, te in zip(adapters_per_client, tests):
        accs.append(answer_accuracy(model, cfg, params, ad, te, tok, MAX_LEN,
                                    lora_scale(cfg)))
    return float(np.mean(accs))


def timed(fn, *args, repeats: int = 3, **kw):
    """Returns (result, us_per_call)."""
    fn(*args, **kw)  # warmup/compile
    t0 = time.perf_counter()
    for _ in range(repeats):
        out = fn(*args, **kw)
    jax.block_until_ready(jax.tree.leaves(out)[0] if jax.tree.leaves(out) else 0)
    return out, (time.perf_counter() - t0) / repeats * 1e6


def row(name: str, us: float, derived) -> str:
    return f"{name},{us:.1f},{derived}"
