"""Roofline report: renders the §Roofline table from the dry-run JSONs
(experiments/dryrun/*.json). One CSV row per (arch × shape); also writes the
markdown table consumed by EXPERIMENTS.md."""
from __future__ import annotations

import glob
import json
import os

from benchmarks import common as C


def load_results(out_dir="experiments/dryrun", mesh="16x16"):
    rows = []
    for path in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        with open(path) as f:
            r = json.load(f)
        if r.get("skipped") or r.get("mesh") != mesh:
            continue
        if r.get("variant", "baseline") != "baseline":
            continue
        rows.append(r)
    return rows


def markdown_table(rows) -> str:
    hdr = ("| arch | shape | step | compute_s | memory_s | collective_s | "
           "dominant | MODEL_FLOPS | useful | peak_GiB/dev |\n"
           "|---|---|---|---|---|---|---|---|---|---|\n")
    out = [hdr]
    for r in rows:
        roof = r["roofline"]
        peak = r["memory"].get("peak_bytes") or 0
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['step']} "
            f"| {roof['compute_s']:.4f} | {roof['memory_s']:.4f} "
            f"| {roof['collective_s']:.4f} | {roof['dominant']} "
            f"| {roof['model_flops']:.3e} | {roof['useful_ratio']:.2f} "
            f"| {peak / 2**30:.2f} |\n")
    return "".join(out)


def run() -> list:
    rows = []
    results = load_results()
    for r in results:
        roof = r["roofline"]
        rows.append(C.row(
            f"roofline/{r['arch']}/{r['shape']}", r["compile_s"] * 1e6,
            f"dom={roof['dominant']};compute={roof['compute_s']:.4f}"
            f";memory={roof['memory_s']:.4f}"
            f";coll={roof['collective_s']:.4f}"
            f";useful={roof['useful_ratio']:.2f}"))
    os.makedirs("experiments", exist_ok=True)
    with open("experiments/roofline_table.md", "w") as f:
        f.write(markdown_table(results))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
