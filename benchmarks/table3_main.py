"""Paper Table 3: mean client accuracy under Dirichlet non-IID, FDLoRA vs the
six baselines, α ∈ {0.1, 0.5, 1.0}, both scenarios."""
from __future__ import annotations

import time

import numpy as np

from benchmarks import common as C
from repro.core.fdlora import FDLoRAConfig, FDLoRATrainer
from repro.federated.baselines import BASELINES, FedConfig
from repro.models.api import get_model


def _fdlora(model, cfg, params, batchers, tests, rounds, seed):
    fed = FDLoRAConfig(n_clients=len(batchers), rounds=rounds, inner_steps=3,
                       sync_every=max(rounds // 3, 1), stage1_steps=10,
                       inner_lr=3e-3, fusion_steps=4, few_shot_k=8, seed=seed)
    tr = FDLoRATrainer(model, cfg, fed, params)
    clients = tr.fit(batchers)
    ads = [tr.fused_adapters(c) for c in clients]
    return C.eval_clients(model, cfg, params, ads, tests)


def _baseline(name, model, cfg, params, batchers, tests, rounds, seed):
    fed = FedConfig(n_clients=len(batchers), rounds=rounds, local_steps=3,
                    lr=3e-3, seed=seed)
    ads = BASELINES[name](model, cfg, fed, params).fit(batchers)
    return C.eval_clients(model, cfg, params, ads, tests)


def run() -> list:
    cfg = C.BENCH_CFG
    model = get_model(cfg)
    params = C.pretrained_base(cfg)
    rounds = 3 if C.FAST else 6
    methods = (["local", "fedavg"] if C.FAST else
               ["local", "fedavg", "fedprox", "fedamp", "fedrep", "fedrod",
                "fedkd"])
    rows = []
    for scenario in (1, 2):
        for alpha in ((0.5,) if C.FAST else (0.1, 0.5, 1.0)):
            batchers, tests = C.build_scenario(scenario, n_clients=3,
                                               alpha=alpha, seed=7)
            t0 = time.perf_counter()
            acc = _fdlora(model, cfg, params, batchers, tests, rounds, seed=7)
            us = (time.perf_counter() - t0) * 1e6
            rows.append(C.row(f"table3/s{scenario}/a{alpha}/fdlora", us,
                              f"acc={acc:.3f}"))
            for m in methods:
                t0 = time.perf_counter()
                acc = _baseline(m, model, cfg, params, batchers, tests,
                                rounds, seed=7)
                us = (time.perf_counter() - t0) * 1e6
                rows.append(C.row(f"table3/s{scenario}/a{alpha}/{m}", us,
                                  f"acc={acc:.3f}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
