"""Benchmark driver — one module per paper table/figure.

Usage:
    PYTHONPATH=src python -m benchmarks.run            # everything
    PYTHONPATH=src python -m benchmarks.run table3 fig5
    BENCH_FAST=1 PYTHONPATH=src python -m benchmarks.run   # reduced budgets

Prints ``name,us_per_call,derived`` CSV (task spec)."""
from __future__ import annotations

import sys
import traceback

MODULES = [
    ("fig4", "benchmarks.fig4_params"),
    ("kernels", "benchmarks.kernels_bench"),
    ("roofline", "benchmarks.roofline_report"),
    ("table3", "benchmarks.table3_main"),
    ("fig5", "benchmarks.fig5_rounds"),
    ("fig6", "benchmarks.fig6_frequency"),
    ("fig7", "benchmarks.fig7_sync"),
    ("table4", "benchmarks.table4_ablation"),
    ("table5", "benchmarks.table5_cost"),
    ("table6", "benchmarks.table6_fusion"),
]


def main() -> None:
    import importlib
    want = set(sys.argv[1:])
    print("name,us_per_call,derived")
    failed = []
    for tag, modname in MODULES:
        if want and tag not in want:
            continue
        try:
            mod = importlib.import_module(modname)
            for row in mod.run():
                print(row)
                sys.stdout.flush()
        except Exception:
            failed.append(tag)
            traceback.print_exc()
    if failed:
        print(f"# FAILED: {failed}", file=sys.stderr)
        sys.exit(1)


if __name__ == '__main__':
    main()
