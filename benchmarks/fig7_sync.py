"""Paper Fig 7: asynchronous personalized-LoRA sync frequency
H ∈ {1, 3, T, ∞} (H=∞ freezes the personalized LoRA after stage 1)."""
from __future__ import annotations

import time

from benchmarks import common as C
from repro.core.fdlora import FDLoRAConfig, FDLoRATrainer
from repro.models.api import get_model


def run() -> list:
    cfg = C.BENCH_CFG
    model = get_model(cfg)
    params = C.pretrained_base(cfg)
    batchers, tests = C.build_scenario(1, n_clients=3, alpha=0.5, seed=13)
    T = 3 if C.FAST else 6
    rows = []
    hs = {"1": 1, "3": 3, "T": T, "inf": 0}
    if C.FAST:
        hs = {"1": 1, "inf": 0}
    for label, H in hs.items():
        fed = FDLoRAConfig(n_clients=3, rounds=T, inner_steps=3,
                           sync_every=H, stage1_steps=8, inner_lr=3e-3,
                           fusion_steps=3, few_shot_k=8, seed=13)
        tr = FDLoRATrainer(model, cfg, fed, params)
        t0 = time.perf_counter()
        clients = tr.fit(batchers)
        us = (time.perf_counter() - t0) * 1e6
        ads = [tr.fused_adapters(c) for c in clients]
        acc = C.eval_clients(model, cfg, params, ads, tests)
        rows.append(C.row(f"fig7/H{label}", us, f"acc={acc:.3f}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
