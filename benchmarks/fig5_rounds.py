"""Paper Fig 5: accuracy vs communication rounds T, for several client
counts N (K fixed at 3)."""
from __future__ import annotations

import time

from benchmarks import common as C
from repro.core.fdlora import FDLoRAConfig, FDLoRATrainer
from repro.models.api import get_model


def run() -> list:
    cfg = C.BENCH_CFG
    model = get_model(cfg)
    params = C.pretrained_base(cfg)
    rows = []
    Ns = (3,) if C.FAST else (3, 5)
    Ts = (1, 3) if C.FAST else (1, 2, 4, 8)
    for N in Ns:
        batchers, tests = C.build_scenario(1, n_clients=N, alpha=0.5, seed=5)
        for T in Ts:
            fed = FDLoRAConfig(n_clients=N, rounds=T, inner_steps=3,
                               sync_every=max(T // 2, 1), stage1_steps=8,
                               inner_lr=3e-3, fusion_steps=3, few_shot_k=8,
                               seed=5)
            tr = FDLoRATrainer(model, cfg, fed, params)
            t0 = time.perf_counter()
            clients = tr.fit(batchers)
            us = (time.perf_counter() - t0) * 1e6
            ads = [tr.fused_adapters(c) for c in clients]
            acc = C.eval_clients(model, cfg, params, ads, tests)
            rows.append(C.row(f"fig5/N{N}/T{T}", us, f"acc={acc:.3f}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
