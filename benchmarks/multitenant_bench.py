"""Multi-tenant serving benchmark: batching strategies and paged-cache wins.

Sections (CSV rows ``name,us_per_call,derived``; compile excluded by a
warmup call; CPU interpret-mode numbers — the wins are architectural):

  * default: tokens/s vs number of resident adapters, comparing
      - ``per-client``: the seed architecture — N single-tenant ``Engine``s,
        one adapter tree and one compiled program each;
      - ``batched``: one ``MultiTenantEngine`` + ``AdapterRegistry`` bank,
        one fixed-shape mixed-client batch (``generate_fixed``, PR-1).
  * ``--ragged`` (also default): a mixed-length mixed-budget request stream
    served by (a) the fixed-batch engine — requests grouped by prompt
    length, every group decoding its max budget (padding waste) — and (b)
    the continuous slot scheduler over the paged KV cache.  Writes
    ``BENCH_serving.json`` (tok/s, waste, speedup).
  * prefill (also default): a prompt-heavy ragged stream served with
    chunked multi-token prefill (``prefill_chunk=16``) vs the
    one-token-per-dispatch baseline (``prefill_chunk=1``) — same outputs,
    fraction of the prefill dispatches.  Appends a ``prefill`` section to
    ``BENCH_serving.json``.
  * prefix_cache (also default): a shared-prefix stream (per-client system
    prompts) served cold vs through the content-addressed warm pool
    (``ServeConfig.prefix_cache``) — bitwise-equal outputs, prompt tokens
    served from cached blocks instead of re-prefilled.  Appends a
    ``prefix_cache`` section (hit rates, prefill-compute reduction).
  * sla (also default): a contended priority-mix stream under
    ``sched_policy="sla"`` vs ``"fcfs"`` — identical greedy outputs, the
    interactive class finishing earlier under priority admission.  Appends
    an ``sla`` section (latency win, per-class wait stats).
  * spec (also default): a decode-bound repetitive stream with
    ``spec_decode`` on vs off — BITWISE-equal outputs, >=1.5x tok/s from
    prompt-lookup drafts verified through the chunked paged prefill path.
    Appends a ``spec`` section (speedup, acceptance, dispatch counts);
    ``--gate-only`` also times it for the
    ``benchmarks/baselines/serving_spec.json`` CI gate.
  * quant (also default): equal-HBM paged pools, ``kv_dtype="f32"`` vs
    ``"int8"`` — greedy-identical streams, the int8 pool holding 1.78x the
    blocks per byte (>=1.5x concurrent residents at the fixed budget, and
    never more preemptions on a pool-thrashing stream).  Appends a
    ``quant`` section; ``--gate-only`` records the deterministic residency
    number for the ``benchmarks/baselines/serving_quant.json`` CI gate.
  * ragged_rank (also default): the same mixed-client stream served from a
    bucketed mixed-rank adapter bank (clients at ranks 2/4/8,
    ``AdapterRegistry(ranks=[...])``) vs every slot padded to the max rank
    — BITWISE-equal outputs (zero rank columns are arithmetically inert),
    the win is adapter-bank HBM: rank-proportional bytes per slot.
    Appends a ``ragged_rank`` section; ``--gate-only`` records the
    deterministic bank-byte ratio for the
    ``benchmarks/baselines/serving_ragged.json`` CI gate.
  * smoke gate (also default): a fixed small continuous workload's tok/s,
    recorded as the ``smoke`` section — CI's
    ``scripts/check_bench_regression.py`` fails the PR when it regresses
    >25% against ``benchmarks/baselines/serving_smoke.json``.
  * shard (also default): a slot-saturated request stream served at
    ``num_shards=1`` vs ``2`` (sharded pool + sharded adapter bank, twice
    the resident slots riding the same fused dispatches) — bitwise-equal
    outputs, aggregate tok/s scaling recorded as the ``shard`` section;
    ``--gate-only`` also times it for the
    ``benchmarks/baselines/serving_shard.json`` CI gate.
  * trace (also default): an open-loop bursty trace (``serving/trace.py``)
    replayed through the streaming session, overlapped dispatch
    (``ServeConfig.overlap``) vs the synchronous per-round loop —
    bitwise-equal streams on the fixed trace, >=1.3x goodput OR p99 TTFT
    win measured realtime.  Appends a ``trace`` section (per-class
    TTFT/TPOT p50/p99, goodput); ``--gate-only`` records the
    ``trace.tok_per_s`` + ``trace.p99_ttft_ms`` pair for the
    ``benchmarks/baselines/serving_trace.json`` CI gate.
  * ``--trace-sweep``: multi-seed x arrival-regime sweep (poisson, bursty,
    heavy burst) in deterministic logical mode, async-vs-sync parity
    asserted per pair — the weekly deep CI job.
  * ``--block-sweep``: ``kernels/batched_lora.py`` tile-size sweep per
    (n_clients, rank) — groundwork for the ROADMAP autotuning item.
  * ``--smoke``: tiny correctness-only run for CI (serving-path regressions
    fail fast; parity + the smoke-gate throughput row only).

  Every non-sweep run also merges a ``section_walltimes`` key into the
  JSON so the uploaded CI artifact shows where the minutes went.

    PYTHONPATH=src python benchmarks/multitenant_bench.py
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np

sys.path.insert(0, ".")
from benchmarks.common import row, timed  # noqa: E402

from repro.configs.base import ModelConfig  # noqa: E402
from repro.core.lora import init_adapters  # noqa: E402
from repro.kernels.batched_lora import batched_lora_matmul  # noqa: E402
from repro.models.api import get_model  # noqa: E402
from repro.serving.engine import (Engine, MultiTenantEngine, Request,  # noqa: E402
                                  ServeConfig)
from repro.serving.kv_cache import kv_bytes_per_block  # noqa: E402
from repro.serving.registry import AdapterRegistry  # noqa: E402
from repro.serving.sharded import ShardedAdapterRegistry  # noqa: E402
from repro.serving.trace import run_trace, synth_trace  # noqa: E402

CFG = ModelConfig(
    name="mt-bench", family="dense", n_layers=2, d_model=128, n_heads=4,
    n_kv_heads=2, d_ff=256, vocab_size=300, max_seq_len=64, lora_rank=8,
    remat=False, param_dtype="float32", dtype="float32")

PROMPT_LEN = 8
NEW_TOKENS = 16
CACHE_LEN = 64


def _merge_json(json_path: str, updates: dict) -> None:
    """Merge section records into the bench JSON (sections accumulate —
    a smoke run must not clobber the committed full-run sections)."""
    record = {}
    if os.path.exists(json_path):
        with open(json_path) as f:
            record = json.load(f)
    record.update(updates)
    with open(json_path, "w") as f:
        json.dump(record, f, indent=2)
        f.write("\n")


def _adapters(seed: int, cfg=CFG, rank=None):
    kw = {} if rank is None else {"rank": rank}
    ad = init_adapters(jax.random.PRNGKey(seed), cfg, **kw)
    bump = jax.random.PRNGKey(seed + 1000)
    return jax.tree.map(
        lambda l: l + 0.02 * jax.random.normal(bump, l.shape), ad)


def _setup(n_adapters: int, cfg=CFG):
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    ads = {f"c{i}": _adapters(i + 1, cfg) for i in range(n_adapters)}
    registry = AdapterRegistry(cfg, capacity=max(n_adapters, 2))
    for cid, ad in ads.items():
        registry.register(cid, ad)
    return model, params, ads, MultiTenantEngine(model, cfg, params, registry)


# ---------------------------------------------------------------------------
# Fixed-shape sections (PR-1): per-client engines vs one batched engine
# ---------------------------------------------------------------------------

def fixed_shape_sections():
    prompt = (np.arange(PROMPT_LEN, dtype=np.int32) * 7) % CFG.vocab_size
    sc = ServeConfig(batch_size=1, max_new_tokens=NEW_TOKENS,
                     cache_len=CACHE_LEN)
    for n_adapters in (2, 4, 8):
        model, params, ads, mt = _setup(n_adapters)
        total_tokens = n_adapters * NEW_TOKENS

        engines = [Engine(model, CFG, params, ad) for ad in ads.values()]
        p1 = jnp.asarray(prompt)[None]

        def per_client():
            return [eng.generate(p1, sc) for eng in engines]

        _, us_base = timed(per_client)
        tps_base = total_tokens / (us_base / 1e6)
        print(row(f"per_client_engines_n{n_adapters}", us_base,
                  f"{tps_base:.1f}"))

        reqs = [Request(cid, prompt) for cid in ads]

        def batched():
            return mt.generate_fixed(reqs, sc)

        out_mt, us_mt = timed(batched)
        tps_mt = total_tokens / (us_mt / 1e6)
        print(row(f"batched_bank_n{n_adapters}", us_mt, f"{tps_mt:.1f}"))
        print(row(f"speedup_n{n_adapters}", us_base / us_mt * 100,
                  f"{tps_mt / tps_base:.2f}x"))

        # sanity: the batched rows must equal per-client generations
        base_out = per_client()
        ok = all(bool((np.asarray(out_mt)[i] == np.asarray(o)[0]).all())
                 for i, o in enumerate(base_out))
        assert ok, "batched engine diverged from per-client baseline"


# ---------------------------------------------------------------------------
# Ragged workload: fixed-batch grouping vs continuous batching (tentpole)
# ---------------------------------------------------------------------------

def _ragged_workload(n_clients: int = 4):
    """Mixed prompt lengths x mixed budgets x mixed clients: the stream the
    fixed-shape engine can only serve by grouping + over-decoding."""
    reqs = []
    lens = (4, 8, 12)
    budgets = (4, 12, 28)
    i = 0
    for plen in lens:
        for b in budgets:
            prompt = (np.arange(plen, dtype=np.int32) * 5 + i) % CFG.vocab_size
            reqs.append(Request(f"c{i % n_clients}", prompt,
                                max_new_tokens=int(b)))
            i += 1
    return reqs


def ragged_section(json_path: str, smoke: bool = False):
    n_clients = 2 if smoke else 4
    model, params, ads, mt = _setup(n_clients)
    reqs = _ragged_workload(n_clients)
    if smoke:
        reqs = reqs[:4]
    useful = sum(r.max_new_tokens for r in reqs)

    # -- fixed-batch (PR-1): group by prompt length, decode each group to
    #    its max budget — finished rows keep burning decode steps ----------
    groups = {}
    for r in reqs:
        groups.setdefault(len(r.prompt), []).append(r)

    def fixed():
        outs = {}
        for plen, grp in sorted(groups.items()):
            sc = ServeConfig(batch_size=len(grp),
                             max_new_tokens=max(g.max_new_tokens for g in grp),
                             cache_len=CACHE_LEN)
            o = mt.generate_fixed(grp, sc)
            for g, row_ in zip(grp, np.asarray(o)):
                outs[id(g)] = row_
        return outs

    decoded = sum(len(grp) * max(g.max_new_tokens for g in grp)
                  for grp in groups.values())
    waste = 1.0 - useful / decoded

    # -- continuous: one slot-based engine over the paged KV cache ---------
    sc_cont = ServeConfig(batch_size=4, max_new_tokens=NEW_TOKENS,
                          block_size=8)

    def continuous():
        return mt.generate(reqs, sc_cont)

    if smoke:
        fixed_out, cont_out = fixed(), continuous()
        for r, o in zip(reqs, cont_out):    # parity: continuous == fixed-path
            np.testing.assert_array_equal(o, fixed_out[id(r)][:o.size])
        print(row("ragged_smoke_parity", 0.0, "ok"))
        return

    fixed_out, us_fixed = timed(fixed)
    cont_out, us_cont = timed(continuous)
    for r, o in zip(reqs, cont_out):        # parity before trusting timings
        np.testing.assert_array_equal(o, fixed_out[id(r)][:o.size])

    tps_fixed = useful / (us_fixed / 1e6)
    tps_cont = useful / (us_cont / 1e6)
    print(row("ragged_fixed_batch", us_fixed,
              f"{tps_fixed:.1f} tok/s, {waste:.1%} padding waste"))
    print(row("ragged_continuous", us_cont,
              f"{tps_cont:.1f} tok/s, 0.0% padding waste"))
    print(row("ragged_speedup", us_fixed / us_cont * 100,
              f"{tps_cont / tps_fixed:.2f}x"))
    _merge_json(json_path, {
        "workload": {"requests": len(reqs),
                     "useful_tokens": useful,
                     "num_shards": sc_cont.num_shards,
                     "prompt_lens": sorted({len(r.prompt) for r in reqs}),
                     "budgets": sorted({r.max_new_tokens for r in reqs})},
        "fixed_batch": {"us_per_call": us_fixed, "tok_per_s": tps_fixed,
                        "decoded_tokens": decoded, "padding_waste": waste},
        "continuous": {"us_per_call": us_cont, "tok_per_s": tps_cont,
                       "decoded_tokens": useful, "padding_waste": 0.0,
                       "slots": sc_cont.batch_size,
                       "block_size": sc_cont.block_size},
        "speedup": tps_cont / tps_fixed,
        "note": "CPU interpret-mode; win = fewer decode dispatches "
                "(no over-decoding, no per-length grouping)",
    })
    print(f"# wrote {json_path}")


# ---------------------------------------------------------------------------
# Chunked prefill: dispatches per prompt token vs per prompt CHUNK
# ---------------------------------------------------------------------------

def prefill_section(json_path: str, smoke: bool = False):
    """Prompt-heavy ragged stream through the continuous engine, chunked
    prefill (prefill_chunk=16) vs the one-token-per-dispatch baseline
    (prefill_chunk=1 drives the same machinery one prompt token at a time).
    Outputs must be identical; the win is the prefill-phase dispatch count
    (and wall time once prompts dominate)."""
    n_clients = 2
    model, params, ads, mt = _setup(n_clients)
    plens = (24, 40) if smoke else (24, 40, 64, 32, 48, 56)
    reqs = []
    for i, plen in enumerate(plens):
        prompt = (np.arange(plen, dtype=np.int32) * 5 + i) % CFG.vocab_size
        reqs.append(Request(f"c{i % n_clients}", prompt, max_new_tokens=4))
    prompt_tokens = sum(len(r.prompt) for r in reqs)

    sc_chunk = ServeConfig(batch_size=4, max_new_tokens=4, block_size=8,
                           prefill_chunk=16)
    sc_token = dataclasses.replace(sc_chunk, prefill_chunk=1)

    out_c = mt.generate(reqs, sc_chunk)
    st_c = dict(mt.last_stats)
    out_t = mt.generate(reqs, sc_token)
    st_t = dict(mt.last_stats)
    for a, b in zip(out_c, out_t):            # parity before trusting counts
        np.testing.assert_array_equal(a, b)

    reduction = st_t["prefill_dispatches"] / st_c["prefill_dispatches"]
    print(row("prefill_dispatches_per_token", 0.0,
              f"{st_t['prefill_dispatches']}"))
    print(row("prefill_dispatches_chunked", 0.0,
              f"{st_c['prefill_dispatches']}"))
    print(row("prefill_dispatch_reduction", 0.0, f"{reduction:.2f}x"))
    assert reduction >= 2.0, \
        f"chunked prefill must cut dispatches >=2x (got {reduction:.2f}x)"
    if smoke:
        print(row("prefill_smoke_parity", 0.0, "ok"))
        return

    _, us_c = timed(lambda: mt.generate(reqs, sc_chunk))
    _, us_t = timed(lambda: mt.generate(reqs, sc_token))
    print(row("prefill_chunked", us_c, f"chunk=16"))
    print(row("prefill_per_token", us_t, f"chunk=1"))
    print(row("prefill_walltime_speedup", us_t / us_c * 100,
              f"{us_t / us_c:.2f}x"))

    _merge_json(json_path, {"prefill": {
        "workload": {"requests": len(reqs), "prompt_tokens": prompt_tokens,
                     "prompt_lens": sorted(plens), "budget": 4,
                     "slots": sc_chunk.batch_size,
                     "num_shards": sc_chunk.num_shards,
                     "block_size": sc_chunk.block_size},
        "per_token": {"prefill_dispatches": st_t["prefill_dispatches"],
                      "us_per_call": us_t},
        "chunked": {"prefill_chunk": sc_chunk.prefill_chunk,
                    "prefill_dispatches": st_c["prefill_dispatches"],
                    "us_per_call": us_c},
        "dispatch_reduction": reduction,
        "walltime_speedup": us_t / us_c,
        "note": "CPU interpret-mode; chunked paged prefill consumes a whole "
                "prompt chunk per dispatch (kernels/paged_prefill.py)",
    }})
    print(f"# wrote {json_path} (prefill section)")


# ---------------------------------------------------------------------------
# Prefix caching: shared-prefix streams skip re-prefill (cold vs warm)
# ---------------------------------------------------------------------------

def prefix_cache_section(json_path: str, smoke: bool = False):
    """Shared-prefix request stream (per-client system prompts) through the
    continuous engine, cold pool vs content-addressed warm pool
    (``prefix_cache=True``).  Outputs must be bitwise-identical; the win is
    the prefill COMPUTE reduction — prompt tokens actually prefilled vs
    served from cached blocks — plus the dispatch count once the pool is
    warm across calls."""
    n_req = 4 if smoke else 8
    model, params, ads, mt = _setup(2)
    prefixes = {f"c{i}": (np.arange(24, dtype=np.int32) * 7 + i)
                % CFG.vocab_size for i in range(2)}
    reqs = []
    for i in range(n_req):
        cid = f"c{i % 2}"
        suffix = (np.arange(8, dtype=np.int32) * 11 + 3 * i) % CFG.vocab_size
        reqs.append(Request(cid, np.concatenate([prefixes[cid], suffix]),
                            max_new_tokens=4))
    sc_cold = ServeConfig(batch_size=4, max_new_tokens=4, block_size=8,
                          prefill_chunk=8)
    # pinned pool => stable geometry => the warm pool survives any batch
    # shape (the recommended cross-call configuration)
    sc_warm = dataclasses.replace(sc_cold, prefix_cache=True, num_blocks=25)

    out_cold = mt.generate(reqs, sc_cold)
    st_cold = dict(mt.last_stats)
    out_w1 = mt.generate(reqs, sc_warm)            # intra-call sharing
    st_w1 = dict(mt.last_stats)
    out_w2 = mt.generate(reqs, sc_warm)            # cross-call re-match
    st_w2 = dict(mt.last_stats)
    for a, b, c in zip(out_cold, out_w1, out_w2):  # parity before metrics
        np.testing.assert_array_equal(a, b)
        np.testing.assert_array_equal(a, c)

    prefilled_cold = st_cold["prompt_tokens"] - st_cold["prefix_hit_tokens"]
    prefilled_warm = st_w2["prompt_tokens"] - st_w2["prefix_hit_tokens"]
    reduction = prefilled_cold / max(1, prefilled_warm)
    print(row("prefix_hit_rate_intra_call", 0.0,
              f"{st_w1['prefix_hit_rate']:.1%}"))
    print(row("prefix_hit_rate_cross_call", 0.0,
              f"{st_w2['prefix_hit_rate']:.1%}"))
    print(row("prefix_prefill_compute_reduction", 0.0, f"{reduction:.2f}x"))
    assert st_w2["prefix_hit_rate"] > 0.5, \
        f"warm shared-prefix stream must re-match >50% of prompt tokens " \
        f"(got {st_w2['prefix_hit_rate']:.1%})"
    assert reduction >= 2.0, \
        f"prefix cache must cut prefill compute >=2x (got {reduction:.2f}x)"
    if smoke:
        print(row("prefix_smoke_parity", 0.0, "ok"))
        return

    _, us_cold = timed(lambda: mt.generate(reqs, sc_cold))
    _, us_warm = timed(lambda: mt.generate(reqs, sc_warm))
    print(row("prefix_cold", us_cold, "prefix_cache=off"))
    print(row("prefix_warm", us_warm, "prefix_cache=on (pool stays warm)"))
    print(row("prefix_walltime_speedup", us_cold / us_warm * 100,
              f"{us_cold / us_warm:.2f}x"))
    _merge_json(json_path, {"prefix_cache": {
        "workload": {"requests": len(reqs), "prefix_len": 24,
                     "suffix_len": 8, "budget": 4, "clients": 2,
                     "slots": sc_cold.batch_size,
                     "num_shards": sc_cold.num_shards,
                     "block_size": sc_cold.block_size},
        "cold": {"prefilled_tokens": prefilled_cold,
                 "prefill_dispatches": st_cold["prefill_dispatches"],
                 "us_per_call": us_cold},
        "warm": {"prefilled_tokens": prefilled_warm,
                 "prefill_dispatches": st_w2["prefill_dispatches"],
                 "hit_rate_intra_call": st_w1["prefix_hit_rate"],
                 "hit_rate_cross_call": st_w2["prefix_hit_rate"],
                 "us_per_call": us_warm},
        "prefill_compute_reduction": reduction,
        "walltime_speedup": us_cold / us_warm,
        "note": "CPU interpret-mode; bitwise-equal outputs — cached blocks "
                "are re-matched by chained content hash per client scope "
                "(serving/kv_cache.py)",
    }})
    print(f"# wrote {json_path} (prefix_cache section)")


# ---------------------------------------------------------------------------
# SLA scheduling: interactive-class latency under priority admission vs FCFS
# ---------------------------------------------------------------------------

def sla_section(json_path: str, smoke: bool = False):
    """A contended stream (batch requests queued ahead of late-arriving
    interactive ones, fewer slots than requests) served under
    ``sched_policy="sla"`` vs ``"fcfs"``.  Outputs must be identical
    (greedy decoding is schedule-invariant — the parity the serving tests
    pin down); the win is interactive-class completion latency, measured
    in stream events (logical time, immune to CPU jitter) and wall time."""
    model, params, ads, mt = _setup(2)

    def _p(n, s):
        return (np.arange(n, dtype=np.int32) * 5 + s) % CFG.vocab_size

    n_batch = 4 if smoke else 8
    reqs = [Request(f"c{i % 2}", _p(10, i), max_new_tokens=10,
                    priority="batch") for i in range(n_batch)]
    # interactive requests arrive LAST in submission order — under FCFS
    # they wait out the whole batch backlog
    reqs += [Request(f"c{i % 2}", _p(6, 50 + i), max_new_tokens=4,
                     priority="interactive") for i in range(3)]
    inter = [rid for rid, r in enumerate(reqs) if r.priority == "interactive"]
    sc = ServeConfig(batch_size=2, max_new_tokens=10, block_size=8,
                     prefill_chunk=8)

    def run(policy):
        finish, outs, t = {}, {i: [] for i in range(len(reqs))}, 0
        stream = mt.generate_stream(
            reqs, dataclasses.replace(sc, sched_policy=policy))
        for rid, toks, fin in stream:
            t += 1
            outs[rid].extend(toks)
            if fin:
                finish[rid] = t
        return finish, outs, dict(mt.last_stats)

    fin_sla, out_sla, st_sla = run("sla")
    fin_fcfs, out_fcfs, st_fcfs = run("fcfs")
    for i in range(len(reqs)):                 # parity before trusting stats
        np.testing.assert_array_equal(np.asarray(out_sla[i], np.int32),
                                      np.asarray(out_fcfs[i], np.int32))

    lat_sla = float(np.mean([fin_sla[r] for r in inter]))
    lat_fcfs = float(np.mean([fin_fcfs[r] for r in inter]))
    win = lat_fcfs / lat_sla
    print(row("sla_interactive_finish_events", 0.0, f"{lat_sla:.1f}"))
    print(row("fcfs_interactive_finish_events", 0.0, f"{lat_fcfs:.1f}"))
    print(row("sla_interactive_latency_win", 0.0, f"{win:.2f}x"))
    assert win > 1.0, \
        f"priority admission must cut interactive latency (got {win:.2f}x)"
    if smoke:
        print(row("sla_smoke_parity", 0.0, "ok"))
        return

    _, us_sla = timed(lambda: mt.generate(reqs, sc))
    _, us_fcfs = timed(lambda: mt.generate(
        reqs, dataclasses.replace(sc, sched_policy="fcfs")))
    _merge_json(json_path, {"sla": {
        "workload": {"batch_requests": n_batch, "interactive_requests": 3,
                     "slots": sc.batch_size, "budget_batch": 10,
                     "num_shards": sc.num_shards,
                     "budget_interactive": 4},
        "interactive_mean_finish_events": {"sla": lat_sla, "fcfs": lat_fcfs},
        "interactive_latency_win": win,
        "classes_sla": st_sla["classes"],
        "classes_fcfs": st_fcfs["classes"],
        "us_per_call": {"sla": us_sla, "fcfs": us_fcfs},
        "note": "identical greedy outputs; win = priority-queue admission "
                "with aging (serving/scheduler.py) letting interactive "
                "requests jump the batch backlog; latency in stream events "
                "(logical time) to dodge CPU jitter",
    }})
    print(f"# wrote {json_path} (sla section)")


# ---------------------------------------------------------------------------
# Speculative decoding: decode-bound repetitive stream, spec vs plain greedy
# ---------------------------------------------------------------------------

def _spec_workload(mt, sc, n_req: int = 6):
    """Decode-bound repetitive stream: prompt seeds whose greedy
    continuation settles into a cycle on the bench model, each extended by
    its own first 16 greedy tokens — the timed region then starts inside
    the repetitive regime and the prompt already contains the runs the
    prompt-lookup drafter matches against (continuing a repetitive
    document: the workload speculation targets)."""
    seeds = ([5, 6] * 4)[:n_req]
    warm = [Request(f"c{s % 2}",       # cycle quality is adapter-specific
                    np.tile((np.arange(4, dtype=np.int32) * 9 + s)
                            % CFG.vocab_size, 2).astype(np.int32),
                    max_new_tokens=16)
            for s in seeds]
    outs = mt.generate(warm, dataclasses.replace(sc, spec_decode=False))
    return [Request(r.client_id,
                    np.concatenate([r.prompt, np.asarray(o, np.int32)]),
                    max_new_tokens=40)
            for r, o in zip(warm, outs)]


def _best_us(fn, repeats: int = 5) -> float:
    """Best-of-N wall time in us (see smoke_gate_section on why best)."""
    import time as _time
    fn()                                           # warmup/compile
    us = float("inf")
    for _ in range(repeats):
        t0 = _time.perf_counter()
        fn()
        us = min(us, (_time.perf_counter() - t0) * 1e6)
    return us


def spec_section(json_path: str, smoke: bool = False):
    """Draft-then-verify greedy decoding (``ServeConfig.spec_decode``) vs
    plain chunked decode on a decode-bound repetitive workload.  Outputs
    must be BITWISE equal (speculation changes when tokens are computed,
    never which); the win is model evaluations per emitted token — one
    verify dispatch scores up to spec_k+1 positions in a single eval."""
    n_req = 4 if smoke else 8
    model, params, ads, mt = _setup(2)
    sc = ServeConfig(batch_size=8, max_new_tokens=40, block_size=8)
    sc_spec = dataclasses.replace(sc, spec_decode=True, spec_k=8)
    reqs = _spec_workload(mt, sc, n_req)
    useful = sum(r.max_new_tokens for r in reqs)

    out_base = mt.generate(reqs, sc)
    out_spec = mt.generate(reqs, sc_spec)
    st = dict(mt.last_stats)
    for a, b in zip(out_base, out_spec):           # parity before timings
        np.testing.assert_array_equal(a, b)
    print(row("spec_acceptance_rate", 0.0, f"{st['acceptance_rate']:.1%}"))
    print(row("spec_verify_dispatches", 0.0, f"{st['verify_dispatches']}"))
    assert st["acceptance_rate"] > 0.5, \
        f"repetitive stream must accept >50% of drafts " \
        f"(got {st['acceptance_rate']:.1%})"
    if smoke:
        print(row("spec_smoke_parity", 0.0, "ok"))
        return

    us_base = _best_us(lambda: mt.generate(reqs, sc))
    us_spec = _best_us(lambda: mt.generate(reqs, sc_spec))
    tps_base = useful / (us_base / 1e6)
    tps_spec = useful / (us_spec / 1e6)
    speedup = us_base / us_spec
    print(row("spec_decode_off", us_base, f"{tps_base:.1f} tok/s"))
    print(row("spec_decode_on", us_spec, f"{tps_spec:.1f} tok/s"))
    print(row("spec_speedup", 0.0, f"{speedup:.2f}x"))
    assert speedup >= 1.5, \
        f"speculation must win >=1.5x on the decode-bound repetitive " \
        f"workload (got {speedup:.2f}x)"
    _merge_json(json_path, {"spec": {
        "workload": {"requests": n_req, "prompt_len": 24, "budget": 40,
                     "useful_tokens": useful, "slots": sc.batch_size,
                     "num_shards": sc.num_shards,
                     "block_size": sc.block_size},
        "tok_per_s": tps_spec, "base_tok_per_s": tps_base,
        "us_per_call": us_spec, "base_us_per_call": us_base,
        "speedup": speedup, "spec_k": sc_spec.spec_k,
        "acceptance_rate": st["acceptance_rate"],
        "verify_dispatches": st["verify_dispatches"],
        "drafted_tokens": st["drafted_tokens"],
        "accepted_tokens": st["accepted_tokens"],
        "rollback_tokens": st["rollback_tokens"],
        "note": "CPU interpret-mode; bitwise-equal greedy streams — win = "
                "fewer model evaluations per token (prompt-lookup drafts "
                "verified through the chunked paged prefill path)",
    }})
    print(f"# wrote {json_path} (spec section)")


def spec_gate_section(json_path: str):
    """Speculative throughput floor for CI: the spec workload's tok/s,
    gated against ``benchmarks/baselines/serving_spec.json`` (best-of-5,
    same rationale as :func:`smoke_gate_section`; parity runs in
    serving-smoke)."""
    model, params, ads, mt = _setup(2)
    sc_spec = ServeConfig(batch_size=8, max_new_tokens=40, block_size=8,
                          spec_decode=True, spec_k=8)
    reqs = _spec_workload(mt, ServeConfig(batch_size=8, max_new_tokens=40,
                                          block_size=8), 8)
    useful = sum(r.max_new_tokens for r in reqs)
    us = _best_us(lambda: mt.generate(reqs, sc_spec))
    tps = useful / (us / 1e6)
    print(row("spec_gate", us, f"{tps:.1f} tok/s"))
    _merge_json(json_path, {"spec": {
        "tok_per_s": tps, "us_per_call": us, "useful_tokens": useful,
        "requests": len(reqs), "slots": sc_spec.batch_size,
        "spec_k": sc_spec.spec_k, "num_shards": sc_spec.num_shards,
        "note": "speculative-decoding smoke throughput; gated by "
                "scripts/check_bench_regression.py in CI",
    }})
    print(f"# wrote {json_path} (spec gate section)")


# ---------------------------------------------------------------------------
# Smoke throughput floor: the number scripts/check_bench_regression.py gates
# ---------------------------------------------------------------------------

def smoke_gate_section(json_path: str):
    """Small fixed continuous-batching workload; CI fails if tok/s
    regresses >25% against the committed baseline
    (``benchmarks/baselines/serving_smoke.json``).  BEST-of-N timing (min
    wall time over separate calls): shared runners and this container both
    jitter 2x run-to-run, and the fastest call is the least contended —
    the mean would gate on scheduler noise, the best gates on the code."""
    import time as _time
    model, params, ads, mt = _setup(2)
    reqs = _ragged_workload(2)[:6]
    useful = sum(r.max_new_tokens for r in reqs)
    sc = ServeConfig(batch_size=4, max_new_tokens=NEW_TOKENS, block_size=8)
    mt.generate(reqs, sc)                          # warmup/compile
    us = float("inf")
    for _ in range(5):
        t0 = _time.perf_counter()
        mt.generate(reqs, sc)
        us = min(us, (_time.perf_counter() - t0) * 1e6)
    tps = useful / (us / 1e6)
    print(row("smoke_gate", us, f"{tps:.1f} tok/s"))
    _merge_json(json_path, {"smoke": {
        "tok_per_s": tps, "us_per_call": us, "useful_tokens": useful,
        "requests": len(reqs), "slots": sc.batch_size,
        "num_shards": sc.num_shards,
        "note": "continuous-batching smoke throughput; gated by "
                "scripts/check_bench_regression.py in CI",
    }})
    print(f"# wrote {json_path} (smoke section)")


# ---------------------------------------------------------------------------
# Sharded serving: aggregate throughput scaling vs shard count
# ---------------------------------------------------------------------------

# The shard section's own config: the scaling it measures is DISPATCH
# amortization (extra shards ride the same fused rounds), so the model is
# kept small enough that per-dispatch overhead — not per-row FLOPs — is
# the serving bottleneck (the regime of latency-mode online serving).
SHARD_CFG = dataclasses.replace(CFG, name="mt-shard", d_model=64, d_ff=128)


def _shard_setup():
    """Engine over a 2-way ShardedAdapterRegistry (4 tenants resident, 2
    homed per shard) — serves both shard counts: at ``num_shards=1`` the
    engine runs the single-pool path against the same concatenated bank."""
    model = get_model(SHARD_CFG)
    params = model.init(jax.random.PRNGKey(0))
    reg = ShardedAdapterRegistry(SHARD_CFG, capacity=8, num_shards=2)
    for i in range(4):
        reg.register(f"c{i}", _adapters(i + 1, SHARD_CFG))
    return reg, MultiTenantEngine(model, SHARD_CFG, params, reg)


def _shard_workload(n_req: int):
    """Slot-saturated mixed-client stream: many more requests than slots
    at either shard count, uniform spans so admission waves and
    completions stay aligned (rounds halve exactly at 2 shards)."""
    reqs = []
    for i in range(n_req):
        prompt = ((np.arange(8, dtype=np.int32) * 5 + i)
                  % SHARD_CFG.vocab_size)
        reqs.append(Request(f"c{i % 4}", prompt, max_new_tokens=12))
    return reqs


def shard_section(json_path: str, smoke: bool = False):
    """``num_shards=1`` (2 slots) vs ``num_shards=2`` (4 slots, 2 per
    shard) on a slot-saturated stream in latency-mode serving
    (``scan_chunk=1``: admission between every token).  Outputs must be
    bitwise-identical (placement re-orders nothing greedy decoding can
    see); the win is aggregate tok/s — the second shard's slots ride the
    SAME fused dispatches, so the dispatch-bound stream completes in half
    the rounds."""
    reg, mt = _shard_setup()
    reqs = _shard_workload(8 if smoke else 16)
    useful = sum(r.max_new_tokens for r in reqs)
    sc1 = ServeConfig(batch_size=2, max_new_tokens=12, block_size=8,
                      scan_chunk=1, num_shards=1)
    sc2 = dataclasses.replace(sc1, batch_size=4, num_shards=2)

    out1 = mt.generate(reqs, sc1)
    out2 = mt.generate(reqs, sc2)
    st2 = dict(mt.last_stats)
    for a, b in zip(out1, out2):               # parity before trusting times
        np.testing.assert_array_equal(a, b)
    assert st2["num_shards"] == 2
    print(row("shard_placements", 0.0, str(st2["shard_placements"])))
    if smoke:
        print(row("shard_smoke_parity", 0.0, "ok"))
        return

    # Interleave the timed passes so slow machine drift (thermal, noisy
    # neighbours) hits both configs equally instead of biasing the ratio.
    import time as _time
    us1 = us2 = float("inf")
    for _ in range(7):
        t0 = _time.perf_counter()
        mt.generate(reqs, sc1)
        us1 = min(us1, (_time.perf_counter() - t0) * 1e6)
        t0 = _time.perf_counter()
        mt.generate(reqs, sc2)
        us2 = min(us2, (_time.perf_counter() - t0) * 1e6)
    tps1 = useful / (us1 / 1e6)
    tps2 = useful / (us2 / 1e6)
    scaling = tps2 / tps1
    print(row("shard_1", us1, f"{tps1:.1f} tok/s, 2 slots"))
    print(row("shard_2", us2, f"{tps2:.1f} tok/s, 4 slots (2/shard)"))
    print(row("shard_scaling", 0.0, f"{scaling:.2f}x"))
    assert scaling > 1.5, \
        f"2 shards must scale aggregate tok/s >1.5x on a slot-saturated " \
        f"stream (got {scaling:.2f}x)"
    _merge_json(json_path, {"shard": {
        "workload": {"requests": len(reqs), "useful_tokens": useful,
                     "prompt_len": 8, "budget": 12, "clients": 4,
                     "scan_chunk": sc1.scan_chunk,
                     "block_size": sc1.block_size},
        "num_shards": sc2.num_shards,
        "one_shard": {"tok_per_s": tps1, "us_per_call": us1,
                      "slots": sc1.batch_size},
        "two_shards": {"tok_per_s": tps2, "us_per_call": us2,
                       "slots": sc2.batch_size,
                       "placements": st2["shard_placements"]},
        "tok_per_s": tps2, "scaling": scaling,
        "resident_tenants": len(reg),
        "tenants_per_shard": reg.capacity_per_shard,
        "note": "CPU interpret-mode; bitwise-equal outputs — win = the "
                "second shard's slots riding the same fused dispatches "
                "(serving/sharded.py), halving rounds on a dispatch-bound "
                "stream",
    }})
    print(f"# wrote {json_path} (shard section)")


def shard_gate_section(json_path: str):
    """Sharded throughput floor for CI: the 2-shard slot-saturated
    workload's tok/s, gated against
    ``benchmarks/baselines/serving_shard.json`` (best-of-5; parity and
    scaling assertions run in serving-smoke / the full bench)."""
    _, mt = _shard_setup()
    reqs = _shard_workload(16)
    useful = sum(r.max_new_tokens for r in reqs)
    sc2 = ServeConfig(batch_size=4, max_new_tokens=12, block_size=8,
                      scan_chunk=1, num_shards=2)
    us = _best_us(lambda: mt.generate(reqs, sc2))
    tps = useful / (us / 1e6)
    print(row("shard_gate", us, f"{tps:.1f} tok/s"))
    _merge_json(json_path, {"shard": {
        "tok_per_s": tps, "us_per_call": us, "useful_tokens": useful,
        "requests": len(reqs), "slots": sc2.batch_size,
        "num_shards": sc2.num_shards,
        "note": "2-shard smoke throughput; gated by "
                "scripts/check_bench_regression.py in CI",
    }})
    print(f"# wrote {json_path} (shard gate section)")


# ---------------------------------------------------------------------------
# Quantized KV pools: concurrent residency per HBM byte (int8 vs f32)
# ---------------------------------------------------------------------------

def _quant_capacity(block_size: int = 8, span: int = 40):
    """Static capacity math at a fixed HBM budget: how many requests of
    ``span`` tokens can hold their whole KV residently, f32 pool vs int8
    pool of the same byte cost (``kv_bytes_per_block`` prices one block of
    one layer -- the ratio is layer-count invariant)."""
    hd = CFG.d_model // CFG.n_heads
    by_f32 = kv_bytes_per_block(block_size, CFG.n_kv_heads, hd, "f32")
    by_i8 = kv_bytes_per_block(block_size, CFG.n_kv_heads, hd, "int8")
    budget = 12 * by_f32                       # a 12-block f32 pool's HBM
    blocks_f32 = budget // by_f32
    blocks_i8 = budget // by_i8
    per_req = -(-span // block_size)
    return {"block_size": block_size, "span": span,
            "hbm_budget_bytes_per_layer": budget,
            "bytes_per_block": {"f32": by_f32, "int8": by_i8},
            "blocks": {"f32": int(blocks_f32), "int8": int(blocks_i8)},
            "capacity_ratio": by_f32 / by_i8,
            "concurrent_residents": {"f32": int(blocks_f32 // per_req),
                                     "int8": int(blocks_i8 // per_req)}}


def quant_section(json_path: str, smoke: bool = False):
    """``ServeConfig(kv_dtype="int8")``: paged K/V blocks stored int8 with
    per-(block, position, kv-head) scales — 36 vs 64 bytes per token per
    kv-head (1.78x blocks per HBM byte).  The quantized path is ERROR-
    BOUND, not bitwise: tests/test_quant.py pins kernel-level tolerances
    and greedy-stream equality on the smoke model; on this larger bench
    model an occasional argmax flip is expected and greedy compounds, so
    the section asserts structural parity (every request decodes its full
    budget) and reports token agreement informationally.  The win is
    residency — at the SAME pool byte budget the int8 engine preempts
    less (or not at all) on a stream that thrashes the f32 pool."""
    model, params, ads, mt = _setup(4)
    reqs = _ragged_workload(4)
    if smoke:
        reqs = reqs[:4]
    cap = _quant_capacity()
    print(row("quant_bytes_per_block_f32", 0.0,
              str(cap["bytes_per_block"]["f32"])))
    print(row("quant_bytes_per_block_int8", 0.0,
              str(cap["bytes_per_block"]["int8"])))
    print(row("quant_capacity_ratio", 0.0,
              f"{cap['capacity_ratio']:.2f}x"))
    print(row("quant_concurrent_residents", 0.0,
              f"f32={cap['concurrent_residents']['f32']} "
              f"int8={cap['concurrent_residents']['int8']}"))
    assert cap["capacity_ratio"] >= 1.5, \
        f"int8 pool must hold >=1.5x blocks per HBM byte " \
        f"(got {cap['capacity_ratio']:.2f}x)"

    # equal-HBM pools (+1 for the scratch block): the f32 pool is sized to
    # thrash under this stream, the int8 pool gets the blocks the same
    # bytes buy
    sc_f32 = ServeConfig(batch_size=4, max_new_tokens=NEW_TOKENS,
                         block_size=8, num_blocks=cap["blocks"]["f32"] + 1)
    sc_i8 = dataclasses.replace(sc_f32, kv_dtype="int8",
                                num_blocks=cap["blocks"]["int8"] + 1)
    out_f = mt.generate(reqs, sc_f32)
    st_f = dict(mt.last_stats)
    out_q = mt.generate(reqs, sc_i8)
    st_q = dict(mt.last_stats)
    agree = total = 0
    for r, a, b in zip(reqs, out_f, out_q):
        # structural parity: spans (and thus pool pressure) are identical,
        # so the preemption comparison below is apples-to-apples
        assert len(a) == len(b) == r.max_new_tokens
        agree += int((np.asarray(a) == np.asarray(b)).sum())
        total += len(a)
    print(row("quant_token_agreement", 0.0, f"{agree / total:.1%}"))
    print(row("quant_preemptions_f32_pool", 0.0, str(st_f["preemptions"])))
    print(row("quant_preemptions_int8_pool", 0.0, str(st_q["preemptions"])))
    assert st_q["preemptions"] <= st_f["preemptions"], \
        "the int8 pool must not preempt MORE than the f32 pool it " \
        "out-capacitates at the same HBM budget"
    if smoke:
        print(row("quant_smoke_parity", 0.0, "ok"))
        return

    us_f = _best_us(lambda: mt.generate(reqs, sc_f32))
    us_q = _best_us(lambda: mt.generate(reqs, sc_i8))
    useful = sum(r.max_new_tokens for r in reqs)
    print(row("quant_f32_pool", us_f, f"{useful / (us_f / 1e6):.1f} tok/s"))
    print(row("quant_int8_pool", us_q, f"{useful / (us_q / 1e6):.1f} tok/s"))
    _merge_json(json_path, {"quant": {
        **cap,
        "workload": {"requests": len(reqs), "useful_tokens": useful,
                     "slots": sc_f32.batch_size,
                     "num_shards": sc_f32.num_shards},
        "preemptions": {"f32": st_f["preemptions"],
                        "int8": st_q["preemptions"]},
        "token_agreement": agree / total,
        "us_per_call": {"f32": us_f, "int8": us_q},
        "note": "CPU interpret-mode; error-bound (not bitwise) vs f32 — "
                "smoke-model stream equality pinned in tests/test_quant.py "
                "— win = 1.78x paged blocks per HBM byte (kernels/quant.py, "
                "dequant inside the Pallas kernels)",
    }})
    print(f"# wrote {json_path} (quant section)")


def quant_gate_section(json_path: str):
    """Residency floor for CI: concurrent int8 residents at the fixed HBM
    budget, gated against ``benchmarks/baselines/serving_quant.json``.
    Pure capacity math — deterministic, immune to runner jitter; the
    parity + preemption assertions run in serving-smoke."""
    cap = _quant_capacity()
    print(row("quant_gate", 0.0,
              f"{cap['concurrent_residents']['int8']} residents "
              f"({cap['capacity_ratio']:.2f}x blocks/byte)"))
    _merge_json(json_path, {"quant": {
        **cap,
        "note": "int8 KV residency at fixed HBM; gated by "
                "scripts/check_bench_regression.py in CI",
    }})
    print(f"# wrote {json_path} (quant gate section)")


# ---------------------------------------------------------------------------
# Ragged-rank adapter banks: mixed-rank buckets vs pad-to-max (HBM win)
# ---------------------------------------------------------------------------

RAGGED_RANKS = (2, 4, 8)


def _rank_bank_capacity(ranks=RAGGED_RANKS):
    """Static adapter-HBM math: a resident slot's bank bytes are rank-
    proportional (every LoRA pair is ``(d_in, r)`` + ``(r, d_out)``), so a
    bucketed bank holding one client per rank costs ``sum(ranks)`` rank-
    units where the pad-to-max bank costs ``len(ranks) * max(ranks)``.
    Byte counts come from the actual ``init_adapters`` trees so target-set
    changes reprice the gate automatically."""
    unit = {}
    for r in sorted(set(ranks)):
        tree = init_adapters(jax.random.PRNGKey(0), CFG, rank=r)
        unit[r] = sum(int(l.size) * l.dtype.itemsize
                      for l in jax.tree.leaves(tree))
    bucketed = sum(unit[r] for r in ranks)
    padded = len(ranks) * unit[max(ranks)]
    return {"ranks": sorted(ranks),
            "bytes_per_slot": {str(r): unit[r] for r in sorted(set(unit))},
            "bank_bytes": {"bucketed": bucketed, "pad_to_max": padded},
            "bank_bytes_saved": padded - bucketed,
            "capacity_ratio": padded / bucketed,
            "extra_min_rank_slots_at_budget":
                int((padded - bucketed) // unit[min(ranks)])}


def _pad_rank(tree, to_rank: int):
    """Zero-pad every LoRA pair to ``to_rank``: ``a: (P, d_in, r)`` on the
    last axis, ``b: (P, r, d_out)`` on the middle axis — the pad-to-max
    baseline the rank buckets compete against."""
    def walk(node):
        if isinstance(node, dict) and set(node) == {"a", "b"}:
            r = node["a"].shape[-1]
            return {"a": jnp.pad(node["a"],
                                 [(0, 0), (0, 0), (0, to_rank - r)]),
                    "b": jnp.pad(node["b"],
                                 [(0, 0), (0, to_rank - r), (0, 0)])}
        return {k: walk(v) for k, v in node.items()}
    return walk(tree)


def _ragged_rank_setup():
    """One model, two registries over the SAME client weights (native
    ranks 2/4/8): bucketed (``ranks=[2,4,8]``) vs the legacy single
    max-rank bucket with every client zero-padded to rank 8."""
    model = get_model(CFG)
    params = model.init(jax.random.PRNGKey(0))
    trees = {f"c{i}": _adapters(i + 1, rank=r)
             for i, r in enumerate(RAGGED_RANKS)}
    reg_b = AdapterRegistry(CFG, capacity=len(trees),
                            ranks=list(RAGGED_RANKS))
    reg_p = AdapterRegistry(CFG, capacity=len(trees))
    for cid, t in trees.items():
        reg_b.register(cid, t)
        reg_p.register(cid, _pad_rank(t, max(RAGGED_RANKS)))
    return (MultiTenantEngine(model, CFG, params, reg_b),
            MultiTenantEngine(model, CFG, params, reg_p))


def ragged_rank_section(json_path: str, smoke: bool = False):
    """Mixed-rank serving: clients fine-tuned at ranks 2/4/8, served from
    the bucketed bank vs every slot padded to rank 8.  Outputs must be
    BITWISE equal — zero rank columns contribute exact zeros, and the
    kernel's per-slot rank mask enforces it even for junk padding — so the
    win is pure adapter-bank HBM (rank-proportional bytes per slot), plus
    whatever the smaller per-bucket matmuls buy in wall time."""
    mt_b, mt_p = _ragged_rank_setup()
    reqs = _ragged_workload(len(RAGGED_RANKS))
    if smoke:
        reqs = reqs[:4]               # still cycles through all three ranks
    cap = _rank_bank_capacity()
    print(row("ragged_rank_bank_bytes_bucketed", 0.0,
              str(cap["bank_bytes"]["bucketed"])))
    print(row("ragged_rank_bank_bytes_padded", 0.0,
              str(cap["bank_bytes"]["pad_to_max"])))
    print(row("ragged_rank_capacity_ratio", 0.0,
              f"{cap['capacity_ratio']:.2f}x"))
    assert cap["capacity_ratio"] >= 1.5, \
        f"bucketed bank must save >=1.5x bytes vs pad-to-max for ranks " \
        f"{cap['ranks']} (got {cap['capacity_ratio']:.2f}x)"

    sc = ServeConfig(batch_size=4, max_new_tokens=NEW_TOKENS, block_size=8)
    out_b = mt_b.generate(reqs, sc)
    out_p = mt_p.generate(reqs, sc)
    for a, b in zip(out_b, out_p):             # parity before trusting HBM win
        np.testing.assert_array_equal(a, b)
    if smoke:
        print(row("ragged_rank_smoke_parity", 0.0, "ok"))
        return

    useful = sum(r.max_new_tokens for r in reqs)
    us_b = _best_us(lambda: mt_b.generate(reqs, sc))
    us_p = _best_us(lambda: mt_p.generate(reqs, sc))
    tps_b = useful / (us_b / 1e6)
    tps_p = useful / (us_p / 1e6)
    print(row("ragged_rank_bucketed", us_b, f"{tps_b:.1f} tok/s"))
    print(row("ragged_rank_pad_to_max", us_p, f"{tps_p:.1f} tok/s"))
    _merge_json(json_path, {"ragged_rank": {
        **cap,
        "workload": {"requests": len(reqs), "useful_tokens": useful,
                     "clients": len(RAGGED_RANKS), "slots": sc.batch_size,
                     "num_shards": sc.num_shards,
                     "block_size": sc.block_size},
        "tok_per_s": {"bucketed": tps_b, "pad_to_max": tps_p},
        "us_per_call": {"bucketed": us_b, "pad_to_max": us_p},
        "note": "CPU interpret-mode; bitwise-equal outputs (zero rank "
                "columns are inert, kernel masks them) — win = rank-"
                "proportional adapter-bank bytes (serving/registry.py "
                "rank buckets)",
    }})
    print(f"# wrote {json_path} (ragged_rank section)")


def ragged_rank_gate_section(json_path: str):
    """Ragged-rank HBM floor for CI: the bucketed-vs-padded bank byte
    ratio, gated against ``benchmarks/baselines/serving_ragged.json``.
    Pure capacity math — deterministic, immune to runner jitter; the
    bitwise mixed-rank parity runs in serving-smoke and
    tests/test_ragged_rank.py."""
    cap = _rank_bank_capacity()
    print(row("ragged_rank_gate", 0.0,
              f"{cap['capacity_ratio']:.2f}x bank bytes "
              f"(+{cap['extra_min_rank_slots_at_budget']} rank-"
              f"{min(cap['ranks'])} slots at the padded budget)"))
    _merge_json(json_path, {"ragged_rank": {
        **cap,
        "note": "bucketed adapter-bank bytes vs pad-to-max; gated by "
                "scripts/check_bench_regression.py in CI",
    }})
    print(f"# wrote {json_path} (ragged_rank gate section)")


# ---------------------------------------------------------------------------
# Open-loop trace serving: overlapped dispatch vs the synchronous loop
# ---------------------------------------------------------------------------

# Decode-heavy bursty workload for the overlap sections: short prompts,
# near-budget outputs, ON/OFF arrivals that pile a backlog onto the pinned
# pool.  Decode rounds with no block-table churn are exactly where the
# overlapped session skips host marshalling, so this stream is the one the
# tentpole is supposed to win.
# decode-heavy on purpose: the overlap win comes from pipelined decode
# rounds (deferred observation), and prefill/admission rounds are
# synchronous flush points that dilute it for both configs equally
TRACE_KW = dict(arrival="bursty", rate=30.0, prompt_mean=8.0,
                prompt_sigma=0.4, prompt_max=24, out_mean=56.0,
                out_sigma=0.3, out_max=64, vocab_size=CFG.vocab_size)


def _trace_sc(**kw):
    """Latency-mode serving over a pinned pool: ``scan_chunk=1`` admits
    between every token (the regime where per-round host work dominates),
    and open-loop sessions need pinned geometry up front."""
    bp = -(-(TRACE_KW["prompt_max"] + TRACE_KW["out_max"]) // 16)
    base = dict(batch_size=4, max_new_tokens=TRACE_KW["out_max"],
                block_size=16, num_blocks=1 + 4 * bp,
                max_blocks_per_slot=bp, prefill_chunk=8, scan_chunk=1)
    base.update(kw)
    return ServeConfig(**base)


def _trace_parity(mt, trace, rounds_per_s: float = 8.0):
    """Logical-mode replay, overlap on vs off: identical dispatch
    sequences, so the streams must be BITWISE equal before any timing is
    trusted.  Returns the overlapped run's report."""
    rep_on = run_trace(mt, _trace_sc(), trace, rounds_per_s=rounds_per_s)
    rep_off = run_trace(mt, _trace_sc(overlap=False), trace,
                        rounds_per_s=rounds_per_s)
    assert rep_on["completed"] == len(trace) == rep_off["completed"]
    for rid in range(len(trace)):
        np.testing.assert_array_equal(
            np.asarray(rep_on["streams"][rid], np.int32),
            np.asarray(rep_off["streams"][rid], np.int32))
    return rep_on


def trace_section(json_path: str, smoke: bool = False):
    """Open-loop bursty trace through ``StreamSession``, overlapped
    dispatch (``ServeConfig.overlap``) vs the synchronous per-round loop.
    Outputs must be bitwise-identical on the fixed trace (logical replay);
    the win is wall-clock — goodput and p99 TTFT under backlog — measured
    realtime with the two configs interleaved best-of-N so machine drift
    cancels out of the ratio."""
    n = 8 if smoke else 24
    model, params, ads, mt = _setup(2)
    trace = synth_trace(0, n, **TRACE_KW)
    rep = _trace_parity(mt, trace)
    print(row("trace_parity", 0.0, f"{n} streams bitwise equal"))
    if smoke:
        print(row("trace_smoke_parity", 0.0, "ok"))
        return

    sc_on, sc_off = _trace_sc(), _trace_sc(overlap=False)
    run_trace(mt, sc_on, trace, realtime=True)      # warmup/compile
    best_on = best_off = None
    for _ in range(3):
        r_off = run_trace(mt, sc_off, trace, realtime=True)
        r_on = run_trace(mt, sc_on, trace, realtime=True)
        if (best_off is None or r_off["goodput_tok_per_unit"]
                > best_off["goodput_tok_per_unit"]):
            best_off = r_off
        if (best_on is None or r_on["goodput_tok_per_unit"]
                > best_on["goodput_tok_per_unit"]):
            best_on = r_on
    gp_on = best_on["goodput_tok_per_unit"]
    gp_off = best_off["goodput_tok_per_unit"]
    p99_on = best_on["ttft"]["p99"]
    p99_off = best_off["ttft"]["p99"]
    goodput_win = gp_on / gp_off
    ttft_win = p99_off / max(p99_on, 1e-9)
    print(row("trace_sync", 0.0,
              f"{gp_off:.1f} tok/s, p99 TTFT {p99_off:.1f}ms"))
    print(row("trace_overlap", 0.0,
              f"{gp_on:.1f} tok/s, p99 TTFT {p99_on:.1f}ms"))
    print(row("trace_goodput_win", 0.0, f"{goodput_win:.2f}x"))
    print(row("trace_p99_ttft_win", 0.0, f"{ttft_win:.2f}x"))
    assert goodput_win >= 1.3 or ttft_win >= 1.3, \
        f"overlapped dispatch must win >=1.3x goodput OR >=1.3x p99 TTFT " \
        f"on the bursty trace (got {goodput_win:.2f}x / {ttft_win:.2f}x)"

    def _classes(rep_):
        return {cls: {"n": d["n"], "ttft": d["ttft"], "tpot": d["tpot"]}
                for cls, d in rep_["per_class"].items()}

    _merge_json(json_path, {"trace": {
        "workload": {"requests": n, "arrival": TRACE_KW["arrival"],
                     "rate_req_per_s": TRACE_KW["rate"],
                     "prompt_max": TRACE_KW["prompt_max"],
                     "out_max": TRACE_KW["out_max"],
                     "slots": sc_on.batch_size,
                     "scan_chunk": sc_on.scan_chunk,
                     "block_size": sc_on.block_size,
                     "num_shards": sc_on.num_shards,
                     "emitted_tokens": rep["emitted_tokens"]},
        "sync": {"goodput_tok_per_s": gp_off, "ttft_ms": best_off["ttft"],
                 "per_class": _classes(best_off)},
        "overlap": {"goodput_tok_per_s": gp_on, "ttft_ms": best_on["ttft"],
                    "per_class": _classes(best_on)},
        "tok_per_s": gp_on, "p99_ttft_ms": p99_on,
        "goodput_win": goodput_win, "p99_ttft_win": ttft_win,
        "note": "CPU interpret-mode; bitwise-equal streams on the fixed "
                "trace (logical replay) — win = pipelined decode: the "
                "overlapped session dispatches chunk N+1 from device-"
                "chained state (last token, lengths, rng, cached tables) "
                "and only then materialises chunk N (one-round-deferred "
                "observation), so host bookkeeping overlaps device "
                "execution",
    }})
    print(f"# wrote {json_path} (trace section)")


def trace_gate_section(json_path: str):
    """Trace-serving floor for CI: the overlapped engine's realtime
    goodput AND p99 TTFT on the fixed bursty trace, both gated against
    ``benchmarks/baselines/serving_trace.json`` (goodput 'higher', TTFT
    'lower'; best-of-N — parity and the overlap-win assertion run in the
    full bench / serving-smoke)."""
    model, params, ads, mt = _setup(2)
    trace = synth_trace(0, 24, **TRACE_KW)
    sc = _trace_sc()
    run_trace(mt, sc, trace, realtime=True)         # warmup/compile
    gp, p99 = 0.0, float("inf")
    for _ in range(3):
        rep = run_trace(mt, sc, trace, realtime=True)
        gp = max(gp, rep["goodput_tok_per_unit"])
        p99 = min(p99, rep["ttft"]["p99"])
    print(row("trace_gate", 0.0, f"{gp:.1f} tok/s, p99 TTFT {p99:.1f}ms"))
    _merge_json(json_path, {"trace": {
        "tok_per_s": gp, "p99_ttft_ms": p99, "requests": len(trace),
        "slots": sc.batch_size, "num_shards": sc.num_shards,
        "note": "open-loop bursty-trace goodput + p99 TTFT (overlap on); "
                "gated by scripts/check_bench_regression.py in CI",
    }})
    print(f"# wrote {json_path} (trace gate section)")


def trace_sweep_section(json_path: str):
    """Multi-seed arrival-regime sweep for the weekly deep job: three
    regimes (steady poisson, the default bursty mix, heavy ON/OFF bursts)
    x three seeds, each replayed logically with overlap on vs off —
    bitwise parity asserted on every pair — recording per-regime goodput
    and TTFT spreads."""
    model, params, ads, mt = _setup(2)
    regimes = {
        "poisson": dict(TRACE_KW, arrival="poisson"),
        "bursty": dict(TRACE_KW),
        "heavy_burst": dict(TRACE_KW, rate=45.0, burst_on_s=0.25,
                            burst_off_s=2.25),
    }
    sweep = {}
    for name, kw in regimes.items():
        goodputs, p99s = [], []
        for seed in (0, 1, 2):
            rep = _trace_parity(mt, synth_trace(seed, 16, **kw))
            goodputs.append(rep["goodput_tok_per_unit"])
            p99s.append(rep["ttft"]["p99"])
        sweep[name] = {
            "seeds": [0, 1, 2],
            "goodput_tok_per_round": {"min": min(goodputs),
                                      "max": max(goodputs)},
            "p99_ttft_rounds": {"min": min(p99s), "max": max(p99s)},
        }
        print(row(f"trace_sweep_{name}", 0.0,
                  f"goodput {min(goodputs):.2f}-{max(goodputs):.2f} "
                  f"tok/round, parity ok x3"))
    _merge_json(json_path, {"trace_sweep": {
        **sweep,
        "note": "logical-mode (deterministic) multi-seed sweep; every "
                "seed/regime pair asserted bitwise async-vs-sync parity",
    }})
    print(f"# wrote {json_path} (trace sweep section)")


# ---------------------------------------------------------------------------
# Block-size sweep for the batched-LoRA kernel (autotuning groundwork)
# ---------------------------------------------------------------------------

def block_sweep():
    """Tile-size table per (n_clients, rank) for batched_lora_matmul.

    Interpret-mode timings rank tile shapes only relatively; on TPU rerun
    with interpret=False to pick per-(C, r) defaults (ROADMAP autotuning)."""
    rng = np.random.default_rng(3)
    M = K = N = 256
    print("# block-sweep: name,us_per_call,derived (bm=bn=bk)")
    for C, r in ((2, 8), (4, 16), (8, 32)):
        x = jnp.asarray(rng.standard_normal((M, K)), jnp.bfloat16)
        w = jnp.asarray(rng.standard_normal((K, N)) * 0.05, jnp.bfloat16)
        a = jnp.asarray(rng.standard_normal((C, K, r)) * 0.05, jnp.float32)
        b = jnp.asarray(rng.standard_normal((C, r, N)) * 0.05, jnp.float32)
        g = jnp.asarray(rng.integers(0, C, M), jnp.int32)
        best = None
        for blk in (64, 128, 256):
            _, us = timed(batched_lora_matmul, x, w, a, b, g, 2.0,
                          bm=blk, bn=blk, bk=blk)
            print(row(f"batched_lora_C{C}_r{r}_blk{blk}", us, f"{blk}"))
            if best is None or us < best[1]:
                best = (blk, us)
        print(row(f"batched_lora_C{C}_r{r}_best", best[1], f"blk={best[0]}"))


# per-section wall times accumulate here; main() merges them into the
# bench JSON so the uploaded CI artifact shows where the minutes went
_SECTION_WALLS: dict = {}


def _run_section(name: str, fn, *args, **kwargs):
    """Run one bench section, print its wall time, and record it for the
    ``section_walltimes`` key of the bench JSON."""
    import time as _time
    t0 = _time.perf_counter()
    fn(*args, **kwargs)
    wall = _time.perf_counter() - t0
    _SECTION_WALLS[name] = round(wall, 3)
    print(f"# section {name}: {wall:.1f}s wall")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny correctness-only run for CI")
    ap.add_argument("--gate-only", action="store_true",
                    help="run ONLY the smoke-gate throughput section (the "
                         "bench-gate CI job; parity runs in serving-smoke)")
    ap.add_argument("--block-sweep", action="store_true",
                    help="batched-LoRA tile-size sweep per (n_clients, rank)")
    ap.add_argument("--trace-sweep", action="store_true",
                    help="multi-seed arrival-regime trace sweep (the "
                         "weekly deep CI job; logical-mode parity only)")
    ap.add_argument("--json", default="BENCH_serving.json",
                    help="where the ragged-workload record is written")
    args = ap.parse_args(argv)

    print("name,us_per_call,derived")
    if args.block_sweep:
        _run_section("block_sweep", block_sweep)
        return
    if args.trace_sweep:
        _run_section("trace_sweep", trace_sweep_section, args.json)
    elif args.gate_only:
        _run_section("smoke_gate", smoke_gate_section, args.json)
        _run_section("spec_gate", spec_gate_section, args.json)
        _run_section("shard_gate", shard_gate_section, args.json)
        _run_section("quant_gate", quant_gate_section, args.json)
        _run_section("ragged_rank_gate", ragged_rank_gate_section, args.json)
        _run_section("trace_gate", trace_gate_section, args.json)
    elif args.smoke:
        _run_section("ragged", ragged_section, args.json, smoke=True)
        _run_section("prefill", prefill_section, args.json, smoke=True)
        _run_section("prefix_cache", prefix_cache_section, args.json,
                     smoke=True)
        _run_section("sla", sla_section, args.json, smoke=True)
        _run_section("spec", spec_section, args.json, smoke=True)
        _run_section("shard", shard_section, args.json, smoke=True)
        _run_section("quant", quant_section, args.json, smoke=True)
        _run_section("ragged_rank", ragged_rank_section, args.json,
                     smoke=True)
        _run_section("trace", trace_section, args.json, smoke=True)
        _run_section("smoke_gate", smoke_gate_section, args.json)
    else:
        _run_section("fixed_shape", fixed_shape_sections)
        _run_section("ragged", ragged_section, args.json)
        _run_section("prefill", prefill_section, args.json)
        _run_section("prefix_cache", prefix_cache_section, args.json)
        _run_section("sla", sla_section, args.json)
        _run_section("spec", spec_section, args.json)
        _run_section("shard", shard_section, args.json)
        _run_section("quant", quant_section, args.json)
        _run_section("ragged_rank", ragged_rank_section, args.json)
        _run_section("trace", trace_section, args.json)
        _run_section("smoke_gate", smoke_gate_section, args.json)
    _merge_json(args.json, {"section_walltimes": _SECTION_WALLS})
    print(f"# wrote {args.json} (section_walltimes)")


if __name__ == "__main__":
    main()
