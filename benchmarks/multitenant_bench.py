"""Multi-tenant serving benchmark: tokens/s vs number of resident adapters.

Compares the two ways to serve N FDLoRA clients on one host:

  * ``per-client``: the seed architecture — N single-tenant ``Engine``s, one
    adapter tree and one compiled program each; requests run client-by-client
    as N batch-1 generations.
  * ``batched``: one ``MultiTenantEngine`` + ``AdapterRegistry`` bank; the
    same N requests run as ONE mixed-client batch through a single compiled
    program, routed per-row to each client's adapter.

CSV rows: ``name,us_per_call,derived`` where derived is tokens/s (compile
excluded by the warmup call). CPU interpret-mode numbers; the win is
architectural (batching + one program), not kernel micro-perf.

    PYTHONPATH=src python benchmarks/multitenant_bench.py
"""
from __future__ import annotations

import sys

import jax
import jax.numpy as jnp
import numpy as np

sys.path.insert(0, ".")
from benchmarks.common import row, timed  # noqa: E402

from repro.configs.base import ModelConfig  # noqa: E402
from repro.core.lora import init_adapters  # noqa: E402
from repro.models.api import get_model  # noqa: E402
from repro.serving.engine import (Engine, MultiTenantEngine, Request,  # noqa: E402
                                  ServeConfig)
from repro.serving.registry import AdapterRegistry  # noqa: E402

CFG = ModelConfig(
    name="mt-bench", family="dense", n_layers=2, d_model=128, n_heads=4,
    n_kv_heads=2, d_ff=256, vocab_size=300, max_seq_len=64, lora_rank=8,
    remat=False, param_dtype="float32", dtype="float32")

PROMPT_LEN = 8
NEW_TOKENS = 16
CACHE_LEN = 64


def _adapters(seed: int):
    ad = init_adapters(jax.random.PRNGKey(seed), CFG)
    bump = jax.random.PRNGKey(seed + 1000)
    return jax.tree.map(
        lambda l: l + 0.02 * jax.random.normal(bump, l.shape), ad)


def main():
    model = get_model(CFG)
    params = model.init(jax.random.PRNGKey(0))
    prompt = (np.arange(PROMPT_LEN, dtype=np.int32) * 7) % CFG.vocab_size
    sc = ServeConfig(batch_size=1, max_new_tokens=NEW_TOKENS,
                     cache_len=CACHE_LEN)

    print("name,us_per_call,derived")
    for n_adapters in (2, 4, 8):
        ads = {f"c{i}": _adapters(i + 1) for i in range(n_adapters)}
        total_tokens = n_adapters * NEW_TOKENS

        # -- baseline: one engine (and one compiled program) per client ----
        engines = [Engine(model, CFG, params, ad) for ad in ads.values()]
        p1 = jnp.asarray(prompt)[None]

        def per_client():
            return [eng.generate(p1, sc) for eng in engines]

        _, us_base = timed(per_client)
        tps_base = total_tokens / (us_base / 1e6)
        print(row(f"per_client_engines_n{n_adapters}", us_base,
                  f"{tps_base:.1f}"))

        # -- batched: one engine, one mixed-client batch --------------------
        registry = AdapterRegistry(CFG, capacity=n_adapters)
        for cid, ad in ads.items():
            registry.register(cid, ad)
        mt = MultiTenantEngine(model, CFG, params, registry)
        reqs = [Request(cid, prompt) for cid in ads]

        def batched():
            return mt.generate(reqs, sc)

        out_mt, us_mt = timed(batched)
        tps_mt = total_tokens / (us_mt / 1e6)
        print(row(f"batched_bank_n{n_adapters}", us_mt, f"{tps_mt:.1f}"))
        print(row(f"speedup_n{n_adapters}", us_base / us_mt * 100,
                  f"{tps_mt / tps_base:.2f}x"))

        # sanity: the batched rows must equal per-client generations
        base_out = per_client()
        ok = all(bool((np.asarray(out_mt)[i] == np.asarray(o)[0]).all())
                 for i, o in enumerate(base_out))
        assert ok, "batched engine diverged from per-client baseline"


if __name__ == "__main__":
    main()
