"""Pallas-kernel microbenchmarks (interpret mode on CPU — correctness-scale
timings only; the roofline story for TPU lives in EXPERIMENTS.md §Perf) and
the jnp reference for context. ``derived`` reports achieved GFLOP/s of the
reference path and the kernel/ref agreement."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common as C
from repro.kernels.lora_matmul import lora_matmul
from repro.kernels.flash_attention import flash_attention
from repro.kernels.ref import flash_attention_ref, lora_matmul_ref


def run() -> list:
    rng = np.random.default_rng(0)
    rows = []

    M = K = N = 512
    r = 16
    x = jnp.asarray(rng.standard_normal((M, K)), jnp.bfloat16)
    w = jnp.asarray(rng.standard_normal((K, N)) * 0.05, jnp.bfloat16)
    a = jnp.asarray(rng.standard_normal((K, r)) * 0.05, jnp.float32)
    b = jnp.asarray(rng.standard_normal((r, N)) * 0.05, jnp.float32)
    ref_fn = jax.jit(lambda: lora_matmul_ref(x, w, a, b, 2.0))
    out_ref, us_ref = C.timed(lambda: jax.block_until_ready(ref_fn()))
    flops = 2 * M * K * N + 2 * M * K * r + 2 * M * r * N
    rows.append(C.row("kernels/lora_matmul_ref_512", us_ref,
                      f"gflops={flops / us_ref / 1e3:.2f}"))
    out_k, us_k = C.timed(
        lambda: jax.block_until_ready(lora_matmul(x, w, a, b, 2.0)))
    err = float(jnp.max(jnp.abs(out_k.astype(jnp.float32)
                                - out_ref.astype(jnp.float32))))
    rows.append(C.row("kernels/lora_matmul_pallas_interp_512", us_k,
                      f"max_err_vs_ref={err:.4f}"))

    B, H, S, d = 1, 2, 256, 64
    q = jnp.asarray(rng.standard_normal((B, H, S, d)), jnp.bfloat16)
    k = jnp.asarray(rng.standard_normal((B, H, S, d)), jnp.bfloat16)
    v = jnp.asarray(rng.standard_normal((B, H, S, d)), jnp.bfloat16)
    rfn = jax.jit(lambda: flash_attention_ref(q, k, v, causal=True))
    o_ref, us_r = C.timed(lambda: jax.block_until_ready(rfn()))
    rows.append(C.row("kernels/attention_ref_256", us_r, "baseline"))
    o_k, us_f = C.timed(lambda: jax.block_until_ready(
        flash_attention(q, k, v, causal=True)))
    err = float(jnp.max(jnp.abs(o_k.astype(jnp.float32)
                                - o_ref.astype(jnp.float32))))
    rows.append(C.row("kernels/flash_attention_pallas_interp_256", us_f,
                      f"max_err_vs_ref={err:.4f}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
