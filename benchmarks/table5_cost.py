"""Paper Table 5 (RQ6): communication / time / compute trade-off.

Strategies compared at equal token budget (paper's setup adapted):
  baseline            1× batch, per-step gradient sync (data parallel)
  dp_4x               4× batch via 4-way data parallelism (comm every step)
  microbatch_4x       4× batch via gradient accumulation (no extra comm)
  update_4x           4× optimizer updates
  fdlora              K-step inner optimization (comm every K steps, LoRA only)

Communication is *measured* adapter-tree bytes; time is wall clock of the
simulation; accuracy from held-out client test sets.
"""
from __future__ import annotations

import time

from benchmarks import common as C
from repro.core.fdlora import FDLoRAConfig, FDLoRATrainer, tree_bytes
from repro.federated.baselines import BASELINES, FedConfig
from repro.models.api import get_model


def run() -> list:
    cfg = C.BENCH_CFG
    model = get_model(cfg)
    params = C.pretrained_base(cfg)
    batchers, tests = C.build_scenario(1, n_clients=3, alpha=0.5, seed=23)
    rows = []
    T = 3 if C.FAST else 6
    K = 3

    # FedAvg with per-step sync == "baseline DP": rounds=T*K, local_steps=1
    def run_fedavg(rounds, local_steps, tag):
        fed = FedConfig(n_clients=3, rounds=rounds, local_steps=local_steps,
                        lr=3e-3, seed=23)
        b = BASELINES["fedavg"](model, cfg, fed, params)
        t0 = time.perf_counter()
        ads = b.fit(batchers)
        us = (time.perf_counter() - t0) * 1e6
        acc = C.eval_clients(model, cfg, params, ads, tests)
        rows.append(C.row(f"table5/{tag}", us,
                          f"acc={acc:.3f};comm_bytes={b.comm_bytes:.0f}"))

    run_fedavg(T * K, 1, "baseline_dp_sync_every_step")
    run_fedavg(T * K, 4, "update_4x")

    # FDLoRA: same inner-step budget, comm every K steps only
    fed = FDLoRAConfig(n_clients=3, rounds=T, inner_steps=K, sync_every=T,
                       stage1_steps=8, inner_lr=3e-3, fusion_steps=3,
                       few_shot_k=8, seed=23)
    tr = FDLoRATrainer(model, cfg, fed, params)
    t0 = time.perf_counter()
    clients = tr.fit(batchers)
    us = (time.perf_counter() - t0) * 1e6
    acc = C.eval_clients(model, cfg, params,
                         [tr.fused_adapters(c) for c in clients], tests)
    comm = sum(c.comm_bytes_up + c.comm_bytes_down for c in clients)
    rows.append(C.row("table5/fdlora_K3", us,
                      f"acc={acc:.3f};comm_bytes={comm:.0f}"))
    # analytic check: FDLoRA comm should be ~1/K of per-step sync
    ad_bytes = tree_bytes(tr.theta_s)
    rows.append(C.row("table5/analytic", 0.0,
                      f"adapter_bytes={ad_bytes:.0f};ratio_vs_dp=1/{K}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
