"""Paper Table 6 (RQ7): AdaFusion vs Random / Average / Sum fusion on the
same trained dual-LoRA state."""
from __future__ import annotations

import time

import jax.numpy as jnp
import numpy as np

from benchmarks import common as C
from repro.core import fusion as fusion_lib
from repro.core.fdlora import FDLoRAConfig, FDLoRATrainer
from repro.core.dual_lora import merge
from repro.models.api import get_model


def run() -> list:
    cfg = C.BENCH_CFG
    model = get_model(cfg)
    params = C.pretrained_base(cfg)
    rows = []
    for alpha in ((0.5,) if C.FAST else (0.1, 0.5, 1.0)):
        batchers, tests = C.build_scenario(1, n_clients=3, alpha=alpha, seed=19)
        T = 3 if C.FAST else 6
        fed = FDLoRAConfig(n_clients=3, rounds=T, inner_steps=3,
                           sync_every=T, stage1_steps=10, inner_lr=3e-3,
                           fusion_steps=4, few_shot_k=8, seed=19)
        tr = FDLoRATrainer(model, cfg, fed, params)
        clients = tr.stage1(batchers)
        tr.stage2(clients, batchers)

        for method in ("random", "average", "sum", "es"):
            t0 = time.perf_counter()
            ads = []
            for i, c in enumerate(clients):
                q = {k: jnp.asarray(v) for k, v in
                     batchers[i].few_shot(fed.few_shot_k).items()}

                def eval_loss(w):
                    loss, _ = tr._fused_eval(params, c.personalized,
                                             tr.theta_s, jnp.asarray(w), q)
                    return float(loss)

                w, _ = fusion_lib.adafusion(eval_loss, method=method,
                                            steps=fed.fusion_steps,
                                            lam=fed.fusion_l1, seed=19 + i)
                ads.append(merge(c.personalized, tr.theta_s, jnp.asarray(w)))
            us = (time.perf_counter() - t0) * 1e6
            acc = C.eval_clients(model, cfg, params, ads, tests)
            name = "adafusion" if method == "es" else method
            rows.append(C.row(f"table6/a{alpha}/{name}", us, f"acc={acc:.3f}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
