"""Paper Fig 6: communication frequency — inner steps K ∈ {1, 3, 5} at a
fixed inner-step budget (T×K constant), scenario 1."""
from __future__ import annotations

import time

from benchmarks import common as C
from repro.core.fdlora import FDLoRAConfig, FDLoRATrainer
from repro.models.api import get_model

BUDGET = 18  # total inner steps per client in stage 2


def run() -> list:
    cfg = C.BENCH_CFG
    model = get_model(cfg)
    params = C.pretrained_base(cfg)
    batchers, tests = C.build_scenario(1, n_clients=3, alpha=0.5, seed=11)
    rows = []
    for K in ((1, 5) if C.FAST else (1, 3, 5)):
        T = max(BUDGET // K, 1)
        fed = FDLoRAConfig(n_clients=3, rounds=T, inner_steps=K,
                           sync_every=max(T // 2, 1), stage1_steps=8,
                           inner_lr=3e-3, fusion_steps=3, few_shot_k=8,
                           seed=11)
        tr = FDLoRATrainer(model, cfg, fed, params)
        t0 = time.perf_counter()
        clients = tr.fit(batchers)
        us = (time.perf_counter() - t0) * 1e6
        ads = [tr.fused_adapters(c) for c in clients]
        acc = C.eval_clients(model, cfg, params, ads, tests)
        comm = clients[0].comm_bytes_up + clients[0].comm_bytes_down
        rows.append(C.row(f"fig6/K{K}/T{T}", us,
                          f"acc={acc:.3f};comm_bytes={comm:.0f}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
