"""Paper Table 4: 0-shot base vs standalone personalized vs standalone
global vs fused FDLoRA."""
from __future__ import annotations

import time

from benchmarks import common as C
from repro.core.fdlora import FDLoRAConfig, FDLoRATrainer
from repro.models.api import get_model


def run() -> list:
    cfg = C.BENCH_CFG
    model = get_model(cfg)
    params = C.pretrained_base(cfg)
    rows = []
    for scenario in (1,) if C.FAST else (1, 2):
        batchers, tests = C.build_scenario(scenario, n_clients=3, alpha=0.5,
                                           seed=17)
        T = 3 if C.FAST else 6
        fed = FDLoRAConfig(n_clients=3, rounds=T, inner_steps=3,
                           sync_every=T, stage1_steps=10, inner_lr=3e-3,
                           fusion_steps=4, few_shot_k=8, seed=17)
        tr = FDLoRATrainer(model, cfg, fed, params)
        t0 = time.perf_counter()
        clients = tr.fit(batchers)
        us = (time.perf_counter() - t0) * 1e6

        acc0 = C.eval_clients(model, cfg, params, [None] * 3, tests)
        accp = C.eval_clients(model, cfg, params,
                              [c.personalized for c in clients], tests)
        accg = C.eval_clients(model, cfg, params, [tr.theta_s] * 3, tests)
        accf = C.eval_clients(model, cfg, params,
                              [tr.fused_adapters(c) for c in clients], tests)
        rows += [
            C.row(f"table4/s{scenario}/zero_shot", us, f"acc={acc0:.3f}"),
            C.row(f"table4/s{scenario}/personalized", us, f"acc={accp:.3f}"),
            C.row(f"table4/s{scenario}/global", us, f"acc={accg:.3f}"),
            C.row(f"table4/s{scenario}/fdlora_fused", us, f"acc={accf:.3f}"),
        ]
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
