"""Paper Fig 4: trainable (LoRA) vs frozen (base) parameters — computed for
the paper's backbone and every assigned architecture."""
from __future__ import annotations

from benchmarks import common as C
from repro.configs.registry import ALL_ARCHS, get_config


def run() -> list:
    rows = []
    for arch in ALL_ARCHS:
        cfg = get_config(arch)
        total = cfg.count_params()
        lora = cfg.count_lora_params()
        rows.append(C.row(
            f"fig4/{arch}", 0.0,
            f"total={total};lora={lora};pct={100.0 * lora / total:.4f}%"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
