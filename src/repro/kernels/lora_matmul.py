"""Fused LoRA matmul Pallas kernel: y = x·W + α·(x·A)·B in one HBM pass.

Why a kernel (DESIGN.md §2): the naive LoRA path writes z = x·A (M×r) and
α·z·B (M×N) to HBM between matmuls and re-reads x twice. Fusing keeps the
rank-r expansion entirely in VMEM: per (i, j) output tile we stream K-tiles
of x and W once, accumulate both the base product and the x·A product in
VMEM scratch, and apply ·B once on the final K-step.

Tiling: grid (M/bm, N/bn, K/bk), k innermost (sequential reduction — scratch
accumulators persist across the k steps of a fixed (i, j)). Block shapes are
MXU-aligned multiples of 128 on every matmul dim; the LoRA rank rides as a
VMEM-resident (bm, r_pad) fp32 accumulator (r zero-padded to 128 lanes by the
wrapper, so the tile is lane-aligned).

VMEM budget per step (defaults bm=bn=bk=256, r_pad=128, bf16 in / fp32 acc):
x (256·256·2) + w (256·256·2) + a (256·128·2) + b (128·256·2) + acc fp32
(256·256·4) + zacc fp32 (256·128·4) ≈ 0.8 MB — comfortably inside the
~16 MB VMEM of a v5e core, leaving room for double buffering.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, w_ref, a_ref, b_ref, o_ref, acc_ref, zacc_ref, *,
            scale: float, k_steps: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        zacc_ref[...] = jnp.zeros_like(zacc_ref)

    x = x_ref[...]
    # base product: (bm, bk) @ (bk, bn), fp32 accumulation on the MXU
    acc_ref[...] += jnp.dot(x, w_ref[...], preferred_element_type=jnp.float32)
    # rank-r expansion: (bm, bk) @ (bk, r_pad)
    zacc_ref[...] += jnp.dot(x, a_ref[...], preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _finish():
        z = zacc_ref[...].astype(x_ref.dtype)   # (bm, r_pad)
        lora = jnp.dot(z, b_ref[...], preferred_element_type=jnp.float32)
        o_ref[...] = (acc_ref[...] + scale * lora).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("scale", "bm", "bn", "bk",
                                             "interpret"))
def lora_matmul(x, w, a, b, scale: float = 1.0, *, bm: int = 256,
                bn: int = 256, bk: int = 256, interpret: bool = True):
    """x: (M, K), w: (K, N), a: (K, r), b: (r, N) -> (M, N).

    M, K, N must tile by (bm, bk, bn); r is zero-padded to 128 internally.
    ``interpret=True`` executes on CPU for validation; on TPU pass False.
    """
    M, K = x.shape
    N = w.shape[1]
    r = a.shape[1]
    r_pad = -(-r // 128) * 128
    if r_pad != r:
        a = jnp.pad(a, ((0, 0), (0, r_pad - r)))
        b = jnp.pad(b, ((0, r_pad - r), (0, 0)))
    a = a.astype(x.dtype)
    b = b.astype(x.dtype)
    w = w.astype(x.dtype)
    k_steps = K // bk

    return pl.pallas_call(
        functools.partial(_kernel, scale=scale, k_steps=k_steps),
        grid=(M // bm, N // bn, k_steps),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((bk, r_pad), lambda i, j, k: (k, 0)),
            pl.BlockSpec((r_pad, bn), lambda i, j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), x.dtype),
        scratch_shapes=[
            pltpu.VMEM((bm, bn), jnp.float32),
            pltpu.VMEM((bm, r_pad), jnp.float32),
        ],
        interpret=interpret,
    )(x, w, a, b)
