"""Paged-attention decode kernel: block-table K/V gather inside the grid.

Continuous batching stores K/V in fixed-size blocks of a shared pool; each
serving slot holds a *block table* naming the physical blocks that make up
its (ragged) context.  The jnp serving path gathers ``k_pool[table]`` into a
padded ``(B, MB·bs, Kv, hd)`` HBM tensor before attending — exactly the
materialisation this kernel removes: the block table rides as a
scalar-prefetch operand and the BlockSpec ``index_map`` reads it, so each
grid step DMAs one *physical* K/V block straight from the pool into VMEM.
Ragged per-row context lengths therefore never pad out in HBM; they only
show up as a per-row mask against the running online-softmax.

Layout: one query token per row (decode), GQA folded as (B, Kv, G, hd) with
grid (B, Kv, MB) — the block loop innermost carrying flash-style running
max / denominator / accumulator scratch across K/V blocks.  Rows whose
``lengths[b] == 0`` (empty serving slots) produce zeros, not NaNs.

Oracle: ``kernels/ref.py::paged_attention_ref`` (which *does* materialise
the gather).  Model-layout entry point with lane padding:
``kernels/ops.py::paged_gqa_attention``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(bt_ref, len_ref, q_ref, *rest, scale: float, bs: int, mb: int,
            quantized: bool):
    if quantized:
        # int8 pools ride with block-aligned fp32 scale tiles (1, 1, bs, 1)
        # whose index_map reads the same block-table entry as K/V
        k_ref, ks_ref, v_ref, vs_ref, o_ref, m_ref, l_ref, acc_ref = rest
    else:
        k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref = rest
        ks_ref = vs_ref = None
    b = pl.program_id(0)
    i = pl.program_id(2)

    @pl.when(i == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    n_valid = len_ref[b]                              # row's context length
    g = q_ref.shape[2]
    k_pos = i * bs + jax.lax.broadcasted_iota(jnp.int32, (g, bs), 1)
    mask = k_pos < n_valid

    @pl.when(jnp.any(mask))                           # skip past-the-end blocks
    def _compute():
        q = q_ref[0, 0]                               # (G, hd)
        if quantized:                                 # dequant in VMEM, fp32
            k = k_ref[0, 0].astype(jnp.float32) * ks_ref[0, 0]
            v = v_ref[0, 0].astype(jnp.float32) * vs_ref[0, 0]
        else:
            k = k_ref[0, 0]                           # (bs, hd)
            v = v_ref[0, 0]
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[:, :1]                         # (G, 1) row-carried
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)                        # (G, bs)
        l_new = alpha * l_ref[:, :1] + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
            p.astype(v.dtype), v, preferred_element_type=jnp.float32)
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(i == mb - 1)
    def _finish():
        denom = jnp.maximum(l_ref[:, :1], 1e-30)      # empty slots -> zeros
        o_ref[0, 0] = (acc_ref[...] / denom).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("scale", "interpret"))
def paged_attention(q, k_pool, v_pool, block_tables, lengths, *,
                    k_scale=None, v_scale=None,
                    scale: float | None = None, interpret: bool = True):
    """q: (B, H, hd) decode queries; k_pool/v_pool: (NB, bs, Kv, hd) shared
    block pools; block_tables: (B, MB) int32 physical block ids per row;
    lengths: (B,) int32 valid context per row.  Returns (B, H, hd).

    With int8 pools pass ``k_scale``/``v_scale`` ((NB, bs, Kv) fp32,
    written by ``paged_scatter_quant``): each grid step DMAs the block's
    scale tile alongside its values and dequantizes in VMEM — the fp32
    K/V gather still never materialises in HBM.

    ``lengths`` counts positions ALREADY WRITTEN to the pool, exclusive:
    row b attends K/V positions [0, lengths[b]).  The serving decode step
    scatters the new token's K/V at position L *then* attends it, so a
    caller replacing the jnp paged branch of ``layers.multihead_attention``
    (whose per-step ``pos`` is the pre-write count L) must pass ``L + 1``
    here after the scatter — otherwise each step omits the token being
    decoded from its own attention.

    H must be a multiple of Kv (GQA groups fold into the query tile).
    ``interpret=True`` executes on CPU for validation; on TPU pass False.
    """
    B, H, hd = q.shape
    NB, bs, Kv, _ = k_pool.shape
    MB = block_tables.shape[1]
    G = H // Kv
    scale = scale if scale is not None else hd ** -0.5
    quantized = k_scale is not None

    qg = q.reshape(B, Kv, G, hd)
    # head-major pools so one (block, head) tile DMAs contiguously
    kh = k_pool.transpose(0, 2, 1, 3)                 # (NB, Kv, bs, hd)
    vh = v_pool.transpose(0, 2, 1, 3)

    # the paged gather: block i of row b is DMA'd from the physical
    # block its table names — no padded (B, MB*bs) tensor ever exists
    pool_spec = pl.BlockSpec((1, 1, bs, hd),
                             lambda b, h, i, bt, ln: (bt[b, i], h, 0, 0))
    in_specs = [pl.BlockSpec((1, 1, G, hd),
                             lambda b, h, i, bt, ln: (b, h, 0, 0))]
    operands = [qg]
    if quantized:
        scale_spec = pl.BlockSpec((1, 1, bs, 1),
                                  lambda b, h, i, bt, ln: (bt[b, i], h, 0, 0))
        ksh = k_scale.transpose(0, 2, 1)[..., None]   # (NB, Kv, bs, 1)
        vsh = v_scale.transpose(0, 2, 1)[..., None]
        in_specs += [pool_spec, scale_spec, pool_spec, scale_spec]
        operands += [kh, ksh, vh, vsh]
    else:
        in_specs += [pool_spec, pool_spec]
        operands += [kh, vh]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,                        # block_tables, lengths
        grid=(B, Kv, MB),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, G, hd),
                               lambda b, h, i, bt, ln: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, 128), jnp.float32),        # running max
            pltpu.VMEM((G, 128), jnp.float32),        # running denominator
            pltpu.VMEM((G, hd), jnp.float32),         # output accumulator
        ],
    )
    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale, bs=bs, mb=MB,
                          quantized=quantized),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Kv, G, hd), q.dtype),
        interpret=interpret,
    )(block_tables, lengths, *operands)
    return out.reshape(B, H, hd)
