"""Chunked paged-prefill attention kernel: one dispatch per prompt chunk.

The decode kernel (``kernels/paged_attention.py``) consumes ONE query token
per row per dispatch; feeding a 64-token prompt through it costs 64 decode
steps.  This kernel attends a whole chunk of ``T`` new prompt tokens per
serving slot against the slot's paged K/V context in a single grid pass:
the chunk's K/V must already be scattered into the pool at positions
``lengths[b] .. lengths[b] + T - 1`` through the slot's block table (the
jnp model path and ``kernels/ops.py::paged_prefill_gqa_attention`` do the
scatter — O(T) writes — before calling in; the O(context) gather is what
stays inside the kernel).

Query ``t`` of row ``b`` sits at absolute position ``lengths[b] + t`` and
attends positions ``[0, lengths[b] + t]`` — prior context plus a causal
mask *inside* the chunk — which is exactly the per-row mask applied to the
running online-softmax.  Layout mirrors the decode kernel: GQA folds the
chunk and the group axis into one query tile ``(T*G, hd)`` (row ``r``
holds chunk position ``r // G``), grid ``(B, Kv, MB)`` with the block loop
innermost carrying flash-style running max / denominator / accumulator
scratch, and the block table riding as a scalar-prefetch operand so each
grid step DMAs one physical block straight from the pool.

Rows past a slot's valid chunk fill (``t >= n_new[b]``, host-side raggedness)
produce finite garbage the scheduler never reads — they are masked at
scatter time (their K/V lands in scratch block 0) and discarded at
observation time, so the kernel itself needs no ``n_new`` operand.

Oracle: ``kernels/ref.py::paged_prefill_attention_ref``.  Model-layout
entry point with lane padding: ``kernels/ops.py::paged_prefill_gqa_attention``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.quant import quantize_int8

NEG_INF = -1e30


def _scatter_coords(B: int, S: int, bs_blk: int, block_tables: jnp.ndarray,
                    lengths: jnp.ndarray, n_new: jnp.ndarray | None):
    """(block ids, in-block offsets) every scatter variant writes through:
    token t of row b lands at ``(table[b, (lengths[b]+t) // bs],
    (lengths[b]+t) % bs)``; with ``n_new``, ragged-tail tokens
    (``t >= n_new[b]``) are redirected to scratch block 0."""
    rows = jnp.arange(B, dtype=jnp.int32)
    rows_t = jnp.arange(S, dtype=jnp.int32)
    pos = lengths[:, None].astype(jnp.int32) + rows_t[None, :]  # (B, S)
    blk = block_tables[rows[:, None], pos // bs_blk]
    if n_new is not None:
        blk = jnp.where(rows_t[None, :] < n_new[:, None], blk, 0)
    return blk, pos % bs_blk


def paged_scatter(k_pool: jnp.ndarray, v_pool: jnp.ndarray,
                  k: jnp.ndarray, v: jnp.ndarray,
                  block_tables: jnp.ndarray, lengths: jnp.ndarray,
                  n_new: jnp.ndarray | None = None):
    """Scatter S new K/V tokens per row into the shared paged pools.

    k/v: (B, S, Kv, hd); token t of row b lands at
    ``pool[table[b, (lengths[b]+t) // bs], (lengths[b]+t) % bs]``.  With
    ``n_new`` (B,), rows ``t >= n_new[b]`` (ragged chunk tails / inactive
    slots) are redirected to scratch block 0 — this is the ONE place the
    scatter convention lives; the jnp attention oracle
    (``models/layers.py`` paged branch) and the kernel wrapper
    (``kernels/ops.py``) both go through it.  Returns (k_pool, v_pool).

    Shared/private discipline (prefix caching): writes land only at
    positions ``>= lengths[b]``, and the allocator guarantees every block
    past a slot's sealed prefix is PRIVATE (refcount 1) while shared
    (refcounted / content-indexed) blocks are always full and sit below
    ``lengths[b]`` — so this scatter can never touch a block another slot
    (or the cross-call cache) is reading, with no copy-on-write needed.
    Scratch block 0 is never allocated or cached, so ragged-tail redirects
    stay harmless too."""
    B, S = k.shape[0], k.shape[1]
    blk, off = _scatter_coords(B, S, k_pool.shape[1], block_tables,
                               lengths, n_new)
    return (k_pool.at[blk, off].set(k.astype(k_pool.dtype)),
            v_pool.at[blk, off].set(v.astype(v_pool.dtype)))


def paged_scatter_quant(k_pool: jnp.ndarray, v_pool: jnp.ndarray,
                        k_scale: jnp.ndarray, v_scale: jnp.ndarray,
                        k: jnp.ndarray, v: jnp.ndarray,
                        block_tables: jnp.ndarray, lengths: jnp.ndarray,
                        n_new: jnp.ndarray | None = None):
    """:func:`paged_scatter` for int8 pools: quantize each new token's K/V
    per (token, kv-head) — amax over the head dim — and scatter values and
    fp32 scales through the SAME coordinates (scale pools are
    (NB, bs, Kv)), so every written position is self-contained and blocks
    never need requantizing as they fill.  Returns the four updated pools.
    """
    B, S = k.shape[0], k.shape[1]
    blk, off = _scatter_coords(B, S, k_pool.shape[1], block_tables,
                               lengths, n_new)
    qk, sk = quantize_int8(k, axis=-1)                # (B,S,Kv,hd)/(B,S,Kv)
    qv, sv = quantize_int8(v, axis=-1)
    return (k_pool.at[blk, off].set(qk),
            v_pool.at[blk, off].set(qv),
            k_scale.at[blk, off].set(sk),
            v_scale.at[blk, off].set(sv))


def _kernel(bt_ref, len_ref, q_ref, *rest, scale: float, bs: int, mb: int,
            g: int, quantized: bool):
    if quantized:
        # int8 pools ride with block-aligned fp32 scale tiles (1, 1, bs, 1)
        # whose index_map reads the same block-table entry as K/V
        k_ref, ks_ref, v_ref, vs_ref, o_ref, m_ref, l_ref, acc_ref = rest
    else:
        k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref = rest
        ks_ref = vs_ref = None
    b = pl.program_id(0)
    i = pl.program_id(2)

    @pl.when(i == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    base = len_ref[b]                                 # context before chunk
    rows = q_ref.shape[2]                             # T * G folded rows
    # query row r = t*G + g  ->  absolute position base + t
    q_pos = base + jax.lax.broadcasted_iota(jnp.int32, (rows, bs), 0) // g
    k_pos = i * bs + jax.lax.broadcasted_iota(jnp.int32, (rows, bs), 1)
    mask = k_pos <= q_pos                             # context + intra-chunk causal

    @pl.when(jnp.any(mask))                           # skip past-the-end blocks
    def _compute():
        q = q_ref[0, 0]                               # (T*G, hd)
        if quantized:                                 # dequant in VMEM, fp32
            k = k_ref[0, 0].astype(jnp.float32) * ks_ref[0, 0]
            v = v_ref[0, 0].astype(jnp.float32) * vs_ref[0, 0]
        else:
            k = k_ref[0, 0]                           # (bs, hd)
            v = v_ref[0, 0]
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[:, :1]                         # (T*G, 1) row-carried
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)                        # (T*G, bs)
        l_new = alpha * l_ref[:, :1] + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
            p.astype(v.dtype), v, preferred_element_type=jnp.float32)
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(i == mb - 1)
    def _finish():
        denom = jnp.maximum(l_ref[:, :1], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / denom).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("scale", "interpret"))
def paged_prefill_attention(q, k_pool, v_pool, block_tables, lengths, *,
                            k_scale=None, v_scale=None,
                            scale: float | None = None,
                            interpret: bool = True):
    """q: (B, T, H, hd) — T chunk queries per row at absolute positions
    ``lengths[b] + t``; k_pool/v_pool: (NB, bs, Kv, hd) shared pools WITH
    the chunk's K/V already scattered in; block_tables: (B, MB) int32;
    lengths: (B,) int32 context written BEFORE this chunk.
    Returns (B, T, H, hd).

    Each query attends ``[0, lengths[b] + t]`` inclusive — its own position
    included, matching the decode kernel's scatter-then-attend convention.
    With int8 pools pass ``k_scale``/``v_scale`` ((NB, bs, Kv) fp32,
    written by ``paged_scatter_quant``): each grid step DMAs the block's
    scale tile alongside its values and dequantizes in VMEM — the fp32
    K/V gather still never materialises in HBM.
    H must be a multiple of Kv.  ``interpret=True`` runs on CPU.
    """
    B, T, H, hd = q.shape
    NB, bs, Kv, _ = k_pool.shape
    MB = block_tables.shape[1]
    G = H // Kv
    scale = scale if scale is not None else hd ** -0.5
    quantized = k_scale is not None

    # fold (T, G) into one query tile; row r = t*G + g
    qg = (q.reshape(B, T, Kv, G, hd)
           .transpose(0, 2, 1, 3, 4)
           .reshape(B, Kv, T * G, hd))
    kh = k_pool.transpose(0, 2, 1, 3)                 # (NB, Kv, bs, hd)
    vh = v_pool.transpose(0, 2, 1, 3)

    pool_spec = pl.BlockSpec((1, 1, bs, hd),
                             lambda b, h, i, bt, ln: (bt[b, i], h, 0, 0))
    in_specs = [pl.BlockSpec((1, 1, T * G, hd),
                             lambda b, h, i, bt, ln: (b, h, 0, 0))]
    operands = [qg]
    if quantized:
        scale_spec = pl.BlockSpec((1, 1, bs, 1),
                                  lambda b, h, i, bt, ln: (bt[b, i], h, 0, 0))
        ksh = k_scale.transpose(0, 2, 1)[..., None]   # (NB, Kv, bs, 1)
        vsh = v_scale.transpose(0, 2, 1)[..., None]
        in_specs += [pool_spec, scale_spec, pool_spec, scale_spec]
        operands += [kh, ksh, vh, vsh]
    else:
        in_specs += [pool_spec, pool_spec]
        operands += [kh, vh]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,                        # block_tables, lengths
        grid=(B, Kv, MB),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, T * G, hd),
                               lambda b, h, i, bt, ln: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((T * G, 128), jnp.float32),    # running max
            pltpu.VMEM((T * G, 128), jnp.float32),    # running denominator
            pltpu.VMEM((T * G, hd), jnp.float32),     # output accumulator
        ],
    )
    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale, bs=bs, mb=MB, g=G,
                          quantized=quantized),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Kv, T * G, hd), q.dtype),
        interpret=interpret,
    )(block_tables, lengths, *operands)
    return (out.reshape(B, Kv, T, G, hd)
               .transpose(0, 2, 1, 3, 4)
               .reshape(B, T, H, hd))
