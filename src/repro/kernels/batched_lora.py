"""Batched multi-tenant LoRA matmul: per-row adapter routing in one kernel.

Multi-tenant serving (S-LoRA style) keeps *one* base-model program and a
stacked bank of client adapters resident; every row of a batch may belong to
a different client.  The kernel computes

    y[i] = x[i]·W + α · x[i]·A[g[i]]·B[g[i]]

for per-row adapter indices ``g`` over banks ``A: (C, K, r)``,
``B: (C, r, N)`` — the gathered per-row factors ``A[g]]`` (M·K·r) are never
materialised in HBM.  The routing rides as a one-hot matrix (M, C): the bank
is laid out as a single 2-D operand ``(K, C·r_pad)`` so the rank expansion is
one MXU matmul ``x @ A_all`` whose per-row client column-block is selected by
a VPU masked reduction against the one-hot.  The B-side applies the inverse
trick (mask-expand z to (bm, C·r_pad), one matmul with ``(C·r_pad, N)``).

Cost note: the A-side issues C·r_pad rank columns instead of r — the classic
dense-MXU batched-LoRA trade (a gather/sort-free BGMV).  With C ≲ 32 and
r ≤ 128 this stays well under the base O(K·N) term.

The dual variant fuses FDLoRA Eq. 7 *per request*: the personalized bank is
per-client, the global adapter θ_s is — as in the paper — one tree shared by
every client, and each row carries its own fusion weights (w1, w2):

    y[i] = x[i]·W + α · x[i]·(w1[i]A1[g[i]] + w2[i]A2)(w1[i]B1[g[i]] + w2[i]B2)

so switching tenants (or re-tuning fusion weights) costs nothing at serve
time.  Same tiling scheme as lora_matmul: grid (M/bm, N/bn, K/bk), k
innermost, fp32 VMEM accumulators, rank padded to 128 lanes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, w_ref, oh_ref, *rest,
            scale: float, k_steps: int, n_clients: int, quantized: bool,
            ranked: bool):
    rest = list(rest)
    # int8 banks ride with one combined per-client scale vector
    # (s_a[c]·s_b[c], lane-padded): scalar scales commute through the
    # matmul chain, so dequant collapses to one per-row factor at finish
    cs_ref = rest.pop(0) if quantized else None
    # ragged banks ride a per-client effective-rank vector (lane-padded):
    # the finish step masks rank columns >= the row's rank to exact zero,
    # so a slot's padded columns can never contribute
    rk_ref = rest.pop(0) if ranked else None
    a_ref, b_ref, o_ref, acc_ref, zacc_ref = rest

    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        zacc_ref[...] = jnp.zeros_like(zacc_ref)

    x = x_ref[...]                                  # (bm, bk)
    # one-hot arrives lane-padded to 128; only the first C columns are live
    oh = oh_ref[:, :n_clients]                      # (bm, C) fp32 one-hot
    acc_ref[...] += jnp.dot(x, w_ref[...], preferred_element_type=jnp.float32)
    # rank expansion against ALL resident adapters: (bm, bk) @ (bk, C*r_pad)
    a = a_ref[...]
    if quantized:
        a = a.astype(x.dtype)       # int8 in [-127, 127] is exact in bf16
    xa = jnp.dot(x, a, preferred_element_type=jnp.float32)
    m = xa.shape[0]
    # per-row client select (the on-chip gather): (bm, C, r_pad) ⊙ one-hot
    z = jnp.sum(xa.reshape(m, n_clients, -1) * oh[:, :, None], axis=1)
    zacc_ref[...] += z

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _finish():
        z = zacc_ref[...]                           # (bm, r_pad) fp32
        if ranked:
            # per-row effective rank via the same one-hot select; VPU mask
            # zeroes padded rank columns before they can reach the B matmul
            rk = jnp.sum(oh * rk_ref[:1, :n_clients], axis=1,
                         keepdims=True)             # (bm, 1) fp32
            col = jax.lax.broadcasted_iota(jnp.float32, z.shape, 1)
            z = jnp.where(col < rk, z, 0.0)
        # inverse trick: scatter z into the row's client column-block so one
        # matmul against the stacked (C*r_pad, bn) B-bank applies B[g[i]]
        zt = (z[:, None, :] * oh[:, :, None]).reshape(m, -1).astype(x.dtype)
        b = b_ref[...]
        if quantized:
            b = b.astype(x.dtype)
        lora = jnp.dot(zt, b, preferred_element_type=jnp.float32)
        if quantized:
            # per-row combined dequant scale via the same one-hot select
            row_scale = jnp.sum(oh * cs_ref[:1, :n_clients], axis=1,
                                keepdims=True)      # (bm, 1)
            lora = lora * row_scale
        o_ref[...] = (acc_ref[...] + scale * lora).astype(o_ref.dtype)


def _dual_kernel(x_ref, w_ref, oh_ref, fw_ref, a1_ref, b1_ref, a2_ref, b2_ref,
                 o_ref, acc_ref, zacc_ref, *,
                 scale: float, k_steps: int, n_clients: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        zacc_ref[...] = jnp.zeros_like(zacc_ref)

    x = x_ref[...]
    oh = oh_ref[:, :n_clients]                      # (bm, C); lane-padded in
    w1 = fw_ref[:, 0:1]                             # (bm, 1) fp32
    w2 = fw_ref[:, 1:2]                             # (fw lane-padded too)
    acc_ref[...] += jnp.dot(x, w_ref[...], preferred_element_type=jnp.float32)
    xa1 = jnp.dot(x, a1_ref[...], preferred_element_type=jnp.float32)
    m = xa1.shape[0]
    za = jnp.sum(xa1.reshape(m, n_clients, -1) * oh[:, :, None], axis=1)
    zg = jnp.dot(x, a2_ref[...], preferred_element_type=jnp.float32)
    # on-chip Eq. 7 merge of the A factors, per row: x·(w1 A1[g] + w2 A2)
    zacc_ref[...] += w1 * za + w2 * zg

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _finish():
        z = zacc_ref[...]                           # (bm, r_pad) fp32
        zt = (z[:, None, :] * oh[:, :, None]).reshape(m, -1).astype(x.dtype)
        l1 = jnp.dot(zt, b1_ref[...], preferred_element_type=jnp.float32)
        l2 = jnp.dot(z.astype(x_ref.dtype), b2_ref[...],
                     preferred_element_type=jnp.float32)
        lora = w1 * l1 + w2 * l2                    # z·(w1 B1[g] + w2 B2)
        o_ref[...] = (acc_ref[...] + scale * lora).astype(o_ref.dtype)


def _bank_2d(a, b, r_pad: int, dtype):
    """(C, K, r)/(C, r, N) banks -> (K, C*r_pad)/(C*r_pad, N) kernel layout."""
    C, K, r = a.shape
    N = b.shape[2]
    if r_pad != r:
        a = jnp.pad(a, ((0, 0), (0, 0), (0, r_pad - r)))
        b = jnp.pad(b, ((0, 0), (0, r_pad - r), (0, 0)))
    a2 = a.transpose(1, 0, 2).reshape(K, C * r_pad).astype(dtype)
    b2 = b.reshape(C * r_pad, N).astype(dtype)
    return a2, b2


def _lane_pad(x, mult: int = 128):
    """Zero-pad the last dim to a lane-aligned multiple (TPU VMEM windows
    want 128-lane minor dims; zeros are inert for both operands)."""
    pad = (-x.shape[-1]) % mult
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad)))
    return x


@functools.partial(jax.jit, static_argnames=("scale", "bm", "bn", "bk",
                                             "interpret"))
def batched_lora_matmul(x, w, a, b, adapter_ids, scale: float = 1.0, *,
                        a_scale=None, b_scale=None, ranks=None,
                        bm: int = 256, bn: int = 256, bk: int = 256,
                        interpret: bool = True):
    """x: (M, K), w: (K, N), a: (C, K, r), b: (C, r, N),
    adapter_ids: (M,) int32 in [0, C) -> (M, N).

    With int8 banks pass ``a_scale``/``b_scale`` ((C,) fp32 per-client
    quantization scales): the banks stay int8 in HBM/VMEM and the kernel
    applies one combined ``s_a[g[i]]·s_b[g[i]]`` factor per row at its
    finish step — scalar scales commute through the LoRA chain, so no
    dequantized bank is ever materialised.

    With ragged-rank banks pass ``ranks`` ((C,) int32 effective rank per
    slot, <= r): the finish step zeroes each row's rank columns at or
    beyond its slot's effective rank, so padded rank columns contribute
    exact zeros regardless of what lives in them.

    M, K, N must tile by (bm, bn, bk); r is zero-padded to 128 internally.
    ``interpret=True`` executes on CPU for validation; on TPU pass False.
    """
    M, K = x.shape
    N = w.shape[1]
    C, _, r = a.shape
    quantized = a_scale is not None
    ranked = ranks is not None
    r_pad = -(-r // 128) * 128
    a2, b2 = _bank_2d(a, b, r_pad, jnp.int8 if quantized else x.dtype)
    w = w.astype(x.dtype)
    oh = _lane_pad(jax.nn.one_hot(adapter_ids, C, dtype=jnp.float32))
    C_lanes = oh.shape[1]
    k_steps = K // bk

    in_specs = [
        pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
        pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        pl.BlockSpec((bm, C_lanes), lambda i, j, k: (i, 0)),
    ]
    operands = [x, w, oh]
    if quantized:
        cs = (a_scale.astype(jnp.float32) * b_scale.astype(jnp.float32))
        cs2 = _lane_pad(cs[None, :])                # (1, C_lanes)
        in_specs.append(pl.BlockSpec((1, C_lanes), lambda i, j, k: (0, 0)))
        operands.append(cs2)
    if ranked:
        rk2 = _lane_pad(ranks.astype(jnp.float32)[None, :])  # (1, C_lanes)
        in_specs.append(pl.BlockSpec((1, C_lanes), lambda i, j, k: (0, 0)))
        operands.append(rk2)
    in_specs += [
        pl.BlockSpec((bk, C * r_pad), lambda i, j, k: (k, 0)),
        pl.BlockSpec((C * r_pad, bn), lambda i, j, k: (0, j)),
    ]
    operands += [a2, b2]

    return pl.pallas_call(
        functools.partial(_kernel, scale=scale, k_steps=k_steps, n_clients=C,
                          quantized=quantized, ranked=ranked),
        grid=(M // bm, N // bn, k_steps),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), x.dtype),
        scratch_shapes=[
            pltpu.VMEM((bm, bn), jnp.float32),
            pltpu.VMEM((bm, r_pad), jnp.float32),
        ],
        interpret=interpret,
    )(*operands)


@functools.partial(jax.jit, static_argnames=("scale", "bm", "bn", "bk",
                                             "interpret"))
def batched_dual_lora_matmul(x, w, a1, b1, a2, b2, adapter_ids, fusion_w,
                             scale: float = 1.0, *,
                             bm: int = 256, bn: int = 256, bk: int = 256,
                             interpret: bool = True):
    """Per-request Eq. 7: x: (M, K), w: (K, N), a1/b1: (C, K, r)/(C, r, N)
    personalized bank, a2/b2: (K, r)/(r, N) shared global θ_s,
    adapter_ids: (M,) int32, fusion_w: (M, 2) fp32 per-row [w1, w2]."""
    M, K = x.shape
    N = w.shape[1]
    C, _, r = a1.shape
    r_pad = -(-r // 128) * 128
    a1p, b1p = _bank_2d(a1, b1, r_pad, x.dtype)
    if r_pad != r:
        a2 = jnp.pad(a2, ((0, 0), (0, r_pad - r)))
        b2 = jnp.pad(b2, ((0, r_pad - r), (0, 0)))
    a2 = a2.astype(x.dtype)
    b2 = b2.astype(x.dtype)
    w = w.astype(x.dtype)
    oh = _lane_pad(jax.nn.one_hot(adapter_ids, C, dtype=jnp.float32))
    C_lanes = oh.shape[1]
    fusion_w = _lane_pad(fusion_w.astype(jnp.float32))
    F_lanes = fusion_w.shape[1]
    k_steps = K // bk

    return pl.pallas_call(
        functools.partial(_dual_kernel, scale=scale, k_steps=k_steps,
                          n_clients=C),
        grid=(M // bm, N // bn, k_steps),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((bm, C_lanes), lambda i, j, k: (i, 0)),
            pl.BlockSpec((bm, F_lanes), lambda i, j, k: (i, 0)),
            pl.BlockSpec((bk, C * r_pad), lambda i, j, k: (k, 0)),
            pl.BlockSpec((C * r_pad, bn), lambda i, j, k: (0, j)),
            pl.BlockSpec((bk, r_pad), lambda i, j, k: (k, 0)),
            pl.BlockSpec((r_pad, bn), lambda i, j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), x.dtype),
        scratch_shapes=[
            pltpu.VMEM((bm, bn), jnp.float32),
            pltpu.VMEM((bm, r_pad), jnp.float32),
        ],
        interpret=interpret,
    )(x, w, oh, fusion_w, a1p, b1p, a2, b2)
