"""Fused dual-LoRA (AdaFusion, Eq. 7) serving kernel.

Computes  y = x·W + α·x·[(w1·A1 + w2·A2)(w1·B1 + w2·B2)]  without ever
materialising the merged factors (or the merged ΔW ∈ R^{K×N}) in HBM: the
per-tile merge  w1·A1 + w2·A2  happens in VMEM right before the MXU issue.

This is the FDLoRA inference hot path — after stage 3 every client serves
base + fused dual adapters; fusing the merge means switching fusion weights
(e.g. per-client weights in a multi-tenant server) costs nothing.

Same tiling scheme as lora_matmul (grid (M/bm, N/bn, K/bk), k innermost,
fp32 VMEM accumulators, rank padded to 128 lanes).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, w_ref, a1_ref, b1_ref, a2_ref, b2_ref, fw_ref,
            o_ref, acc_ref, zacc_ref, *, scale: float, k_steps: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        zacc_ref[...] = jnp.zeros_like(zacc_ref)

    w1 = fw_ref[0]
    w2 = fw_ref[1]
    x = x_ref[...]
    acc_ref[...] += jnp.dot(x, w_ref[...], preferred_element_type=jnp.float32)
    # on-chip Eq.7 merge of the A factors for this K-tile
    am = (w1 * a1_ref[...].astype(jnp.float32)
          + w2 * a2_ref[...].astype(jnp.float32)).astype(x.dtype)
    zacc_ref[...] += jnp.dot(x, am, preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _finish():
        bm_t = (w1 * b1_ref[...].astype(jnp.float32)
                + w2 * b2_ref[...].astype(jnp.float32)).astype(x_ref.dtype)
        z = zacc_ref[...].astype(x_ref.dtype)
        lora = jnp.dot(z, bm_t, preferred_element_type=jnp.float32)
        o_ref[...] = (acc_ref[...] + scale * lora).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("scale", "bm", "bn", "bk",
                                             "interpret"))
def dual_lora_matmul(x, w, a1, b1, a2, b2, fusion_w, scale: float = 1.0, *,
                     bm: int = 256, bn: int = 256, bk: int = 256,
                     interpret: bool = True):
    """x: (M,K), w: (K,N), a1/a2: (K,r), b1/b2: (r,N), fusion_w: (2,) fp32."""
    M, K = x.shape
    N = w.shape[1]
    r = a1.shape[1]
    r_pad = -(-r // 128) * 128
    pad_a = lambda a: jnp.pad(a, ((0, 0), (0, r_pad - r))) if r_pad != r else a
    pad_b = lambda b: jnp.pad(b, ((0, r_pad - r), (0, 0))) if r_pad != r else b
    a1, a2 = pad_a(a1).astype(x.dtype), pad_a(a2).astype(x.dtype)
    b1, b2 = pad_b(b1).astype(x.dtype), pad_b(b2).astype(x.dtype)
    w = w.astype(x.dtype)
    fusion_w = fusion_w.astype(jnp.float32)
    k_steps = K // bk

    return pl.pallas_call(
        functools.partial(_kernel, scale=scale, k_steps=k_steps),
        grid=(M // bm, N // bn, k_steps),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((bk, r_pad), lambda i, j, k: (k, 0)),
            pl.BlockSpec((r_pad, bn), lambda i, j, k: (0, j)),
            pl.BlockSpec((bk, r_pad), lambda i, j, k: (k, 0)),
            pl.BlockSpec((r_pad, bn), lambda i, j, k: (0, j)),
            pl.BlockSpec(memory_space=pltpu.SMEM),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), x.dtype),
        scratch_shapes=[
            pltpu.VMEM((bm, bn), jnp.float32),
            pltpu.VMEM((bm, r_pad), jnp.float32),
        ],
        interpret=interpret,
    )(x, w, a1, b1, a2, b2, fusion_w)
