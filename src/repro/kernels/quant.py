"""Symmetric int8 quantization helpers for KV pools and adapter banks.

One convention everywhere: values are stored as int8 in [-127, 127] with an
fp32 scale per *group*, where the group is whatever axis set amax runs over:

* paged K/V blocks — one scale per (block, position, kv-head), i.e. amax
  over the head dim.  A scatter write is self-contained (its scale rides
  with it), so blocks quantized at different times never need requantizing
  and LRU-parked prefix-cache blocks stay valid bit-for-bit across owners.
* adapter banks — one scale per (period, client) leaf slice, i.e. amax over
  the whole (d_in, r) factor.  A scalar per-client scale commutes through
  the LoRA matmul chain: ``(x @ (s_a·A)) @ (s_b·B) = s_a·s_b · (x@A)@B``,
  which is what lets the batched kernel apply one per-row combined scale
  at its finish step instead of dequantizing the banks in HBM.

Dequantization always happens at the *read* site (gather oracle or inside
the Pallas kernel), in fp32 — int8 never feeds an MXU dot directly here.
``scale`` is ``amax / 127`` with a tiny floor so all-zero groups (zero-init
pools, unregistered bank slots) round-trip to exact zeros instead of NaNs.
"""
from __future__ import annotations

from typing import Sequence, Tuple, Union

import jax.numpy as jnp

INT8_MAX = 127.0
# groups whose amax is below this are stored with this scale instead of 0
# (q = round(0 / eps) = 0 either way; the floor only avoids 0/0)
_SCALE_FLOOR = 1e-12

Axis = Union[int, Sequence[int]]


def quantize_int8(x: jnp.ndarray, axis: Axis) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Quantize ``x`` to int8 with one fp32 scale per group.

    ``axis`` names the dims amax reduces over (the group extent).  Returns
    ``(q int8, scale fp32)`` where ``scale`` keeps ``x``'s shape with the
    reduced dims REMOVED — callers re-broadcast at dequant time.
    """
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf), axis=axis, keepdims=True)
    scale = jnp.maximum(amax, _SCALE_FLOOR) / INT8_MAX
    q = jnp.clip(jnp.round(xf / scale), -INT8_MAX, INT8_MAX).astype(jnp.int8)
    return q, jnp.squeeze(scale, axis=axis)


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray, axis: Axis
                    ) -> jnp.ndarray:
    """Inverse of :func:`quantize_int8`: fp32 values ``q * scale`` with the
    scale re-broadcast over the reduced ``axis``."""
    return q.astype(jnp.float32) * jnp.expand_dims(scale, axis)
