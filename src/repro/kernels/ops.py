"""jit'd high-level wrappers over the Pallas kernels.

These adapt model-layer calling conventions ((B, S, d) activations, GQA
head layouts, adapter dicts) to the 2-D kernel interfaces. On CPU they run
in ``interpret=True`` (validation); on TPU pass ``interpret=False``.

The model layer keeps pure-jnp math by default (``layers.dense`` /
``multihead_attention``) — the kernels are drop-in replacements for the
serving/training hot paths, exercised by tests and the §Perf iterations.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.batched_lora import batched_lora_matmul
from repro.kernels.dual_lora import dual_lora_matmul
from repro.kernels.flash_attention import flash_attention
from repro.kernels.lora_matmul import lora_matmul
from repro.kernels.paged_attention import paged_attention
from repro.kernels.paged_prefill import (paged_prefill_attention,
                                         paged_scatter, paged_scatter_quant)


def _pad_to(x, axis, mult):
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x, size
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), size


def lora_dense(x: jnp.ndarray, w: jnp.ndarray, adapter: Dict[str, jnp.ndarray],
               scale: float, *, interpret: bool = True,
               block: int = 256) -> jnp.ndarray:
    """(..., K) @ (K, N) + LoRA via the fused kernel. Pads M/K/N to tiles."""
    lead = x.shape[:-1]
    K = x.shape[-1]
    N = w.shape[1]
    x2 = x.reshape(-1, K)
    x2, M = _pad_to(x2, 0, block)
    x2p, _ = _pad_to(x2, 1, block)
    wp, _ = _pad_to(_pad_to(w, 0, block)[0], 1, block)
    ap, _ = _pad_to(adapter["a"], 0, block)
    bp, _ = _pad_to(adapter["b"], 1, block)
    y = lora_matmul(x2p.astype(jnp.bfloat16), wp, ap, bp, scale,
                    bm=block, bn=block, bk=block, interpret=interpret)
    return y[:M, :N].reshape(*lead, N)


def fused_dual_lora_dense(x: jnp.ndarray, w: jnp.ndarray,
                          ad_p: Dict, ad_s: Dict, fusion_w: jnp.ndarray,
                          scale: float, *, interpret: bool = True,
                          block: int = 256) -> jnp.ndarray:
    """FDLoRA serving path: base + Eq.7-merged dual adapters, one kernel."""
    lead = x.shape[:-1]
    K = x.shape[-1]
    N = w.shape[1]
    x2 = x.reshape(-1, K)
    x2, M = _pad_to(x2, 0, block)
    x2p, _ = _pad_to(x2, 1, block)
    wp, _ = _pad_to(_pad_to(w, 0, block)[0], 1, block)
    a1, _ = _pad_to(ad_p["a"], 0, block)
    b1, _ = _pad_to(ad_p["b"], 1, block)
    a2, _ = _pad_to(ad_s["a"], 0, block)
    b2, _ = _pad_to(ad_s["b"], 1, block)
    y = dual_lora_matmul(x2p.astype(jnp.bfloat16), wp, a1, b1, a2, b2,
                         fusion_w, scale, bm=block, bn=block, bk=block,
                         interpret=interpret)
    return y[:M, :N].reshape(*lead, N)


def batched_lora_dense(x: jnp.ndarray, w: jnp.ndarray,
                       bank: Dict[str, jnp.ndarray], adapter_ids: jnp.ndarray,
                       scale: float, *, interpret: bool = True,
                       block: int = 256) -> jnp.ndarray:
    """Multi-tenant dense: (B, ..., K) @ (K, N) with per-*request* adapter
    routing. ``bank`` = {"a": (C, K, r), "b": (C, r, N)}; an int8 bank also
    carries ``a_scale``/``b_scale`` ((C,) fp32) which the kernel applies as
    one per-row combined factor. ``adapter_ids`` is (B,) int32 and
    broadcasts over the trailing (sequence) axes of ``x``.

    Ragged-rank banks arrive with per-bucket LISTS at each leaf (see
    ``AdapterRegistry(ranks=[...])``): the buckets are concatenated along
    the client axis in global-slot order, small buckets rank-padded up to
    the largest bucket, and the kernel gets a per-slot effective-rank
    vector so padded rank columns contribute exact zeros.

    Pads M/K/N to tiles; padded rows route to slot 0 and are sliced away."""
    lead = x.shape[:-1]
    K = x.shape[-1]
    N = w.shape[1]
    rows_per_item = 1
    for s in lead[1:]:
        rows_per_item *= s
    g = jnp.repeat(adapter_ids.astype(jnp.int32), rows_per_item)
    x2 = x.reshape(-1, K)
    x2, M = _pad_to(x2, 0, block)
    g = jnp.pad(g, (0, x2.shape[0] - M))
    x2p, _ = _pad_to(x2, 1, block)
    wp, _ = _pad_to(_pad_to(w, 0, block)[0], 1, block)
    ranks = None
    if isinstance(bank["a"], (list, tuple)):
        # ragged: concat buckets on the client axis at the max bucket rank;
        # the kernel's per-slot rank mask keeps the padding exact
        r_max = max(ab.shape[-1] for ab in bank["a"])
        a_all = jnp.concatenate(
            [jnp.pad(ab, ((0, 0), (0, 0), (0, r_max - ab.shape[-1])))
             for ab in bank["a"]], axis=0)
        b_all = jnp.concatenate(
            [jnp.pad(bb, ((0, 0), (0, r_max - bb.shape[1]), (0, 0)))
             for bb in bank["b"]], axis=0)
        ranks = jnp.concatenate(
            [jnp.full((ab.shape[0],), ab.shape[-1], jnp.int32)
             for ab in bank["a"]])
        a_scale = (jnp.concatenate(bank["a_scale"])
                   if "a_scale" in bank else None)
        b_scale = (jnp.concatenate(bank["b_scale"])
                   if "b_scale" in bank else None)
        bank = {"a": a_all, "b": b_all}
        if a_scale is not None:
            bank["a_scale"], bank["b_scale"] = a_scale, b_scale
    ap, _ = _pad_to(bank["a"], 1, block)
    bp, _ = _pad_to(bank["b"], 2, block)
    y = batched_lora_matmul(x2p.astype(jnp.bfloat16), wp, ap, bp, g, scale,
                            a_scale=bank.get("a_scale"),
                            b_scale=bank.get("b_scale"), ranks=ranks,
                            bm=block, bn=block, bk=block, interpret=interpret)
    return y[:M, :N].reshape(*lead, N)


def paged_gqa_attention(q: jnp.ndarray, k_pool: jnp.ndarray,
                        v_pool: jnp.ndarray, block_tables: jnp.ndarray,
                        lengths: jnp.ndarray, *,
                        k_scale: Optional[jnp.ndarray] = None,
                        v_scale: Optional[jnp.ndarray] = None,
                        interpret: bool = True) -> jnp.ndarray:
    """Model-layout adapter for the paged decode kernel.

    q: (B, 1, H, hd) (or (B, H, hd)) as produced by the serving decode step;
    k_pool/v_pool: (NB, bs, Kv, hd). Pads head_dim to 128 lanes (zero key
    lanes leave q·k unchanged; zero value lanes are sliced away) and keeps
    the block-table gather inside the kernel. Returns q's shape.

    With int8 pools pass ``k_scale``/``v_scale`` ((NB, bs, Kv) fp32) —
    they carry no head-dim axis so the lane padding leaves them alone and
    the kernel dequantizes each DMA'd block tile in VMEM.

    ``lengths`` is exclusive (positions already written): when dropping this
    into the paged branch of ``layers.multihead_attention``, pass the
    per-row step position + 1 — i.e. AFTER scattering the step's K/V — so
    the token being decoded attends itself (see ``paged_attention``)."""
    squeeze = q.ndim == 4
    if squeeze:
        q = q[:, 0]
    hd = q.shape[-1]
    scale = hd ** -0.5                       # scale from the *unpadded* head
    qp, _ = _pad_to(q, 2, 128)
    kp, _ = _pad_to(k_pool, 3, 128)
    vp, _ = _pad_to(v_pool, 3, 128)
    o = paged_attention(qp, kp, vp, block_tables.astype(jnp.int32),
                        lengths.astype(jnp.int32),
                        k_scale=k_scale, v_scale=v_scale, scale=scale,
                        interpret=interpret)[..., :hd]
    return o[:, None] if squeeze else o


def paged_prefill_gqa_attention(q: jnp.ndarray, k_new: jnp.ndarray,
                                v_new: jnp.ndarray, k_pool: jnp.ndarray,
                                v_pool: jnp.ndarray,
                                block_tables: jnp.ndarray,
                                lengths: jnp.ndarray,
                                n_new: jnp.ndarray, *,
                                k_scale: Optional[jnp.ndarray] = None,
                                v_scale: Optional[jnp.ndarray] = None,
                                interpret: bool = True):
    """Model-layout adapter for the chunked paged-prefill kernel.

    q/k_new/v_new: (B, T, H|Kv, hd) — a whole prompt chunk per serving slot,
    as produced by the serving prefill step; k_pool/v_pool: (NB, bs, Kv, hd).
    Scatters the chunk's K/V into each row's block-table slots (positions
    ``lengths[b] .. lengths[b] + n_new[b] - 1``; ragged tails with
    ``t >= n_new[b]`` land in scratch block 0), then runs the Pallas kernel
    over the updated pools — the O(T) scatter is materialised, the
    O(context) gather never is.  Pads head_dim to 128 lanes.

    Returns (out (B, T, H, hd), new_k_pool, new_v_pool).  With int8 pools
    pass ``k_scale``/``v_scale`` ((NB, bs, Kv) fp32): the chunk quantizes
    at scatter time and the return grows to
    (out, new_k_pool, new_v_pool, new_k_scale, new_v_scale)."""
    hd = q.shape[-1]
    quantized = k_scale is not None
    if quantized:
        kp, vp, ks, vs = paged_scatter_quant(
            k_pool, v_pool, k_scale, v_scale, k_new, v_new,
            block_tables.astype(jnp.int32), lengths.astype(jnp.int32),
            n_new.astype(jnp.int32))
    else:
        kp, vp = paged_scatter(k_pool, v_pool, k_new, v_new,
                               block_tables.astype(jnp.int32),
                               lengths.astype(jnp.int32),
                               n_new.astype(jnp.int32))
        ks = vs = None

    scale = hd ** -0.5                       # scale from the *unpadded* head
    qp, _ = _pad_to(q, 3, 128)
    kpp, _ = _pad_to(kp, 3, 128)
    vpp, _ = _pad_to(vp, 3, 128)
    o = paged_prefill_attention(qp, kpp, vpp, block_tables.astype(jnp.int32),
                                lengths.astype(jnp.int32),
                                k_scale=ks, v_scale=vs, scale=scale,
                                interpret=interpret)[..., :hd]
    if quantized:
        return o, kp, vp, ks, vs
    return o, kp, vp


def gqa_flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                        causal: bool = True, sliding_window: int = 0,
                        interpret: bool = True) -> jnp.ndarray:
    """GQA layout adapter: q (B, Sq, H, d), k/v (B, Sk, Kv, d) as produced by
    the model layer -> flash kernel layout, repeating KV heads."""
    B, Sq, H, d = q.shape
    Kv = k.shape[2]
    rep = H // Kv
    qt = q.transpose(0, 2, 1, 3)
    kt = jnp.repeat(k.transpose(0, 2, 1, 3), rep, axis=1)
    vt = jnp.repeat(v.transpose(0, 2, 1, 3), rep, axis=1)
    o = flash_attention(qt, kt, vt, causal=causal,
                        sliding_window=sliding_window, interpret=interpret)
    return o.transpose(0, 2, 1, 3)
