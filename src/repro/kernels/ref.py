"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def lora_matmul_ref(x, w, a, b, scale: float):
    """y = x @ w + scale * (x @ a) @ b, fp32 accumulation."""
    base = jnp.matmul(x, w, preferred_element_type=jnp.float32)
    z = jnp.matmul(x.astype(jnp.float32), a.astype(jnp.float32))
    z = jnp.matmul(z, b.astype(jnp.float32))
    return (base + scale * z).astype(x.dtype)


def dual_lora_matmul_ref(x, w, a1, b1, a2, b2, w1, w2, scale: float):
    """Eq. 7 fused serving path: y = x@w + scale·x@[(w1A1+w2A2)(w1B1+w2B2)]."""
    am = (w1 * a1 + w2 * a2).astype(jnp.float32)
    bm = (w1 * b1 + w2 * b2).astype(jnp.float32)
    base = jnp.matmul(x, w, preferred_element_type=jnp.float32)
    z = jnp.matmul(jnp.matmul(x.astype(jnp.float32), am), bm)
    return (base + scale * z).astype(x.dtype)


def batched_lora_matmul_ref(x, w, a, b, adapter_ids, scale: float, *,
                            a_scale=None, b_scale=None, ranks=None):
    """Multi-tenant: y[i] = x[i]@w + scale*(x[i]@a[g[i]])@b[g[i]].

    a: (C, K, r), b: (C, r, N), adapter_ids: (M,) int32. The reference
    materialises the per-row gather (the thing the kernel avoids).

    With int8 banks pass ``a_scale``/``b_scale`` ((C,) fp32 per-client
    quantization scales): the gathered factors dequantize before the
    matmul chain, exactly as the kernel's per-row combined scale does.

    With ragged-rank banks pass ``ranks`` ((C,) int32 effective rank per
    slot): rank columns at or beyond a row's effective rank are zeroed
    between the two einsums — exactly the kernel's per-row rank mask — so
    whatever lives in a slot's padded columns cannot contribute."""
    base = jnp.matmul(x, w, preferred_element_type=jnp.float32)
    ag = jnp.take(a, adapter_ids, axis=0).astype(jnp.float32)   # (M, K, r)
    bg = jnp.take(b, adapter_ids, axis=0).astype(jnp.float32)   # (M, r, N)
    if a_scale is not None:
        ag = ag * jnp.take(a_scale, adapter_ids, axis=0)[:, None, None]
        bg = bg * jnp.take(b_scale, adapter_ids, axis=0)[:, None, None]
    z = jnp.einsum("mk,mkr->mr", x.astype(jnp.float32), ag)
    if ranks is not None:
        rk = jnp.take(ranks.astype(jnp.int32), adapter_ids)     # (M,)
        col = jnp.arange(z.shape[-1])[None, :]
        z = jnp.where(col < rk[:, None], z, 0.0)
    z = jnp.einsum("mr,mrn->mn", z, bg)
    return (base + scale * z).astype(x.dtype)


def batched_dual_lora_matmul_ref(x, w, a1, b1, a2, b2, adapter_ids, fusion_w,
                                 scale: float):
    """Per-request Eq. 7 over a personalized bank + shared global adapter:
    y[i] = x@w + scale·x@[(w1ᵢA1[gᵢ]+w2ᵢA2)(w1ᵢB1[gᵢ]+w2ᵢB2)].

    a1/b1: (C, K, r)/(C, r, N), a2/b2: (K, r)/(r, N), fusion_w: (M, 2)."""
    w1 = fusion_w[:, 0, None, None].astype(jnp.float32)
    w2 = fusion_w[:, 1, None, None].astype(jnp.float32)
    am = w1 * jnp.take(a1, adapter_ids, 0).astype(jnp.float32) \
        + w2 * a2[None].astype(jnp.float32)                     # (M, K, r)
    bm = w1 * jnp.take(b1, adapter_ids, 0).astype(jnp.float32) \
        + w2 * b2[None].astype(jnp.float32)                     # (M, r, N)
    base = jnp.matmul(x, w, preferred_element_type=jnp.float32)
    z = jnp.einsum("mk,mkr->mr", x.astype(jnp.float32), am)
    z = jnp.einsum("mr,mrn->mn", z, bm)
    return (base + scale * z).astype(x.dtype)


def _gather_pool(pool, pool_scale, block_tables, rep):
    """Materialise the padded per-row block gather (B, MB*bs, Kv, hd) in
    fp32, dequantizing int8 pools with their (NB, bs, Kv) scales."""
    B, MB = block_tables.shape
    bs, Kv, hd = pool.shape[1:]
    g = pool[block_tables].reshape(B, MB * bs, Kv, hd).astype(jnp.float32)
    if pool_scale is not None:
        g = g * pool_scale[block_tables].reshape(B, MB * bs, Kv)[..., None]
    return jnp.repeat(g, rep, axis=2)


def paged_attention_ref(q, k_pool, v_pool, block_tables, lengths, *,
                        k_scale=None, v_scale=None,
                        scale: float | None = None):
    """Paged decode attention: q: (B, H, hd), k_pool/v_pool:
    (NB, bs, Kv, hd), block_tables: (B, MB) int32, lengths: (B,) int32.

    The reference materialises the padded per-row block gather
    (B, MB*bs, Kv, hd) in HBM — the thing the Pallas kernel avoids.
    With int8 pools pass ``k_scale``/``v_scale`` ((NB, bs, Kv) fp32)."""
    B, H, hd = q.shape
    bs, Kv = k_pool.shape[1], k_pool.shape[2]
    MB = block_tables.shape[1]
    scale = scale if scale is not None else hd ** -0.5
    rep = H // Kv
    k = _gather_pool(k_pool, k_scale, block_tables, rep)
    v = _gather_pool(v_pool, v_scale, block_tables, rep)
    logits = jnp.einsum("bhd,bkhd->bhk", q.astype(jnp.float32), k) * scale
    mask = jnp.arange(MB * bs)[None, :] < lengths[:, None]      # (B, L)
    logits = jnp.where(mask[:, None, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    # fully-masked rows (empty slots) -> zeros, matching the kernel
    probs = jnp.where(mask[:, None, :], probs, 0.0)
    out = jnp.einsum("bhk,bkhd->bhd", probs, v)
    return out.astype(q.dtype)


def paged_prefill_attention_ref(q, k_pool, v_pool, block_tables, lengths, *,
                                k_scale=None, v_scale=None,
                                scale: float | None = None):
    """Chunked paged prefill: q: (B, T, H, hd) chunk queries at absolute
    positions ``lengths[b] + t``; k_pool/v_pool: (NB, bs, Kv, hd) pools WITH
    the chunk's K/V already scattered in; block_tables: (B, MB) int32;
    lengths: (B,) int32 context written before the chunk.

    Query t of row b attends positions ``[0, lengths[b] + t]`` — prior
    context plus the causal mask inside the chunk.  The reference
    materialises the padded per-row block gather (B, MB*bs, Kv, hd) in HBM,
    which is what ``kernels/paged_prefill.py`` avoids.  With int8 pools
    pass ``k_scale``/``v_scale`` ((NB, bs, Kv) fp32)."""
    B, T, H, hd = q.shape
    bs, Kv = k_pool.shape[1], k_pool.shape[2]
    MB = block_tables.shape[1]
    scale = scale if scale is not None else hd ** -0.5
    rep = H // Kv
    k = _gather_pool(k_pool, k_scale, block_tables, rep)
    v = _gather_pool(v_pool, v_scale, block_tables, rep)
    logits = jnp.einsum("bthd,bkhd->bhtk", q.astype(jnp.float32), k) * scale
    q_pos = lengths[:, None] + jnp.arange(T)[None, :]           # (B, T)
    mask = jnp.arange(MB * bs)[None, None, :] <= q_pos[:, :, None]  # (B,T,L)
    logits = jnp.where(mask[:, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    probs = jnp.where(mask[:, None], probs, 0.0)
    out = jnp.einsum("bhtk,bkhd->bthd", probs, v)
    return out.astype(q.dtype)


def flash_attention_ref(q, k, v, *, causal: bool = True,
                        sliding_window: int = 0, scale: float | None = None):
    """q: (B, H, Sq, d), k/v: (B, H, Sk, d) -> (B, H, Sq, d).

    Positions are aligned at the end: query i has absolute position
    Sk - Sq + i (the decode/prefill convention)."""
    Bq, H, Sq, d = q.shape
    Sk = k.shape[2]
    scale = scale if scale is not None else d ** -0.5
    logits = jnp.einsum("bhqd,bhkd->bhqk", q, k,
                        preferred_element_type=jnp.float32) * scale
    q_pos = jnp.arange(Sq) + (Sk - Sq)
    k_pos = jnp.arange(Sk)
    mask = jnp.ones((Sq, Sk), dtype=bool)
    if causal:
        mask &= k_pos[None, :] <= q_pos[:, None]
    if sliding_window > 0:
        mask &= k_pos[None, :] > (q_pos[:, None] - sliding_window)
    logits = jnp.where(mask[None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", probs.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.astype(q.dtype)
