"""Flash attention (online softmax) Pallas kernel with causal + sliding
window masking — the sub-quadratic variant that removes the S² HBM traffic
the roofline analysis flags as the dominant memory term for long contexts,
and the qualifier for running dense architectures at ``long_500k``.

Layout: q (B, H, Sq, d), k/v (B, H, Sk, d) — GQA callers repeat KV heads (or
vmap over groups) before the call. Grid (B·H, Sq/bq, Sk/bk); the kv loop is
innermost with running max/denominator scratch carried across kv steps
(standard online-softmax recurrence). Positions align at the end: query i
has absolute position Sk − Sq + i, so the same kernel serves training
(Sq == Sk), chunked prefill, and single-token decode (Sq == 1 is padded to a
block by the wrapper).

Sliding-window + causal masking is applied per tile from absolute positions.
Fully-masked kv tiles still execute (Pallas TPU grids are static) but a
`pl.when` skips their MXU work; on TPU the win over masked XLA attention is
the removed HBM round-trip of the (Sq, Sk) logits, not the mask itself.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale: float, causal: bool, window: int, bq: int, bk: int,
            sq: int, sk: int, kv_steps: int):
    kv_i = pl.program_id(2)

    @pl.when(kv_i == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_idx = pl.program_id(1)
    q_pos = (q_idx * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
             + (sk - sq))
    k_pos = kv_i * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), dtype=bool)
    if causal:
        mask &= k_pos <= q_pos
    if window > 0:
        mask &= k_pos > (q_pos - window)

    # block-level early-out: fully masked tiles skip the MXU work
    any_valid = jnp.any(mask)

    @pl.when(any_valid)
    def _compute():
        q = q_ref[0]                                   # (bq, d)
        k = k_ref[0]                                   # (bk, d)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]                            # (bq, 128) row-carried
        m_cur = jnp.max(s, axis=1, keepdims=True)      # (bq, 1)
        m_new = jnp.maximum(m_prev[:, :1], m_cur)
        alpha = jnp.exp(m_prev[:, :1] - m_new)
        p = jnp.exp(s - m_new)                         # (bq, bk)
        l_new = alpha * l_ref[:, :1] + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
            p.astype(v_ref.dtype), v_ref[0], preferred_element_type=jnp.float32)
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(kv_i == kv_steps - 1)
    def _finish():
        denom = jnp.maximum(l_ref[:, :1], 1e-30)
        o_ref[0] = (acc_ref[...] / denom).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "sliding_window",
                                             "scale", "bq", "bk", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, sliding_window: int = 0,
                    scale: float | None = None, bq: int = 128, bk: int = 128,
                    interpret: bool = True):
    """q: (B, H, Sq, d), k/v: (B, H, Sk, d) -> (B, H, Sq, d)."""
    B, H, Sq, d = q.shape
    Sk = k.shape[2]
    scale = scale if scale is not None else d ** -0.5
    bq = min(bq, Sq)
    bk = min(bk, Sk)
    assert Sq % bq == 0 and Sk % bk == 0, (Sq, bq, Sk, bk)
    kv_steps = Sk // bk

    qf = q.reshape(B * H, Sq, d)
    kf = k.reshape(B * H, Sk, d)
    vf = v.reshape(B * H, Sk, d)

    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale, causal=causal,
                          window=sliding_window, bq=bq, bk=bk, sq=Sq, sk=Sk,
                          kv_steps=kv_steps),
        grid=(B * H, Sq // bq, kv_steps),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, Sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 128), jnp.float32),   # running max (col-bcast)
            pltpu.VMEM((bq, 128), jnp.float32),   # running denom
            pltpu.VMEM((bq, d), jnp.float32),     # output accumulator
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, H, Sq, d)
