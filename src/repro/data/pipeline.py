"""Batching pipeline: deterministic, stateless epoch iterators.

Kept numpy-side (host) with device transfer at the step boundary — the
standard JAX input-pipeline split. Shapes are static (pad to ``max_len``) so
every client shares one compiled step.
"""
from __future__ import annotations

from typing import Dict, Iterator, List, Sequence

import numpy as np

from repro.data.synthetic import Example, encode_sft
from repro.data.tokenizer import ByteTokenizer


class SFTBatcher:
    def __init__(self, examples: Sequence[Example], tok: ByteTokenizer,
                 max_len: int, batch_size: int, seed: int = 0):
        self.data = encode_sft(list(examples), tok, max_len)
        self.n = len(examples)
        self.batch_size = batch_size
        self.rng = np.random.default_rng(seed)

    def sample(self) -> Dict[str, np.ndarray]:
        """Random batch with replacement (paper: 'randomly sample b data')."""
        idx = self.rng.integers(0, self.n, size=self.batch_size)
        return {"tokens": self.data["tokens"][idx],
                "loss_mask": self.data["loss_mask"][idx]}

    def epoch(self) -> Iterator[Dict[str, np.ndarray]]:
        perm = self.rng.permutation(self.n)
        for i in range(0, self.n - self.batch_size + 1, self.batch_size):
            idx = perm[i:i + self.batch_size]
            yield {"tokens": self.data["tokens"][idx],
                   "loss_mask": self.data["loss_mask"][idx]}

    def few_shot(self, k: int) -> Dict[str, np.ndarray]:
        """Fixed few-shot set Q for the AdaFusion objective (Eq. 8)."""
        idx = np.arange(min(k, self.n))
        return {"tokens": self.data["tokens"][idx],
                "loss_mask": self.data["loss_mask"][idx]}
