"""Byte-level tokenizer with a handful of special tokens.

The paper fine-tunes over natural-language prompts; offline we use synthetic
corpora, so a byte-level vocabulary (256 bytes + specials) keeps the pipeline
real (tokenize → pad → mask) without shipping a trained BPE model.
"""
from __future__ import annotations

from typing import List, Sequence

import numpy as np

PAD = 256
BOS = 257
EOS = 258
VOCAB_SIZE = 260  # 256 bytes + pad/bos/eos + 1 spare


class ByteTokenizer:
    vocab_size = VOCAB_SIZE
    pad_id = PAD
    bos_id = BOS
    eos_id = EOS

    def encode(self, text: str, add_bos: bool = True, add_eos: bool = False) -> List[int]:
        ids = list(text.encode("utf-8"))
        if add_bos:
            ids = [BOS] + ids
        if add_eos:
            ids = ids + [EOS]
        return ids

    def decode(self, ids: Sequence[int]) -> str:
        return bytes(i for i in ids if i < 256).decode("utf-8", errors="replace")


def pad_batch(seqs: Sequence[Sequence[int]], max_len: int,
              masks: Sequence[Sequence[int]] = None):
    """Right-pad to (N, max_len); returns (tokens, loss_mask) int32 arrays.

    ``masks`` (same nesting) marks which *input* positions contribute to the
    SFT loss (answer tokens); pad positions are always masked out.
    """
    n = len(seqs)
    toks = np.full((n, max_len), PAD, dtype=np.int32)
    lm = np.zeros((n, max_len), dtype=np.int32)
    for i, s in enumerate(seqs):
        s = list(s)[:max_len]
        toks[i, :len(s)] = s
        if masks is not None:
            m = list(masks[i])[:max_len]
            lm[i, :len(m)] = m
        else:
            lm[i, :len(s)] = 1
    return toks, lm
