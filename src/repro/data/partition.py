"""Dirichlet non-IID partitioning (the paper's federated data setup).

Each client's class mixture is drawn from Dir(α): small α ⇒ heavily skewed
(strong non-IID), large α ⇒ approaches IID. Matches the setup of
Lin et al. 2020 / Ma et al. 2022 cited by the paper; default α = 0.5.
"""
from __future__ import annotations

from typing import Dict, List, Sequence, Tuple

import numpy as np

from repro.data.synthetic import Example


def dirichlet_partition(examples: Sequence[Example], n_clients: int,
                        alpha: float, rng: np.random.Generator,
                        min_per_client: int = 4) -> List[List[Example]]:
    """Split by class with per-class Dirichlet proportions over clients."""
    classes = sorted({ex.cls for ex in examples})
    by_cls: Dict[int, List[Example]] = {c: [] for c in classes}
    for ex in examples:
        by_cls[ex.cls].append(ex)
    clients: List[List[Example]] = [[] for _ in range(n_clients)]
    for c in classes:
        items = by_cls[c]
        rng.shuffle(items)
        props = rng.dirichlet([alpha] * n_clients)
        counts = (props * len(items)).astype(int)
        counts[-1] = len(items) - counts[:-1].sum()
        idx = 0
        for i, k in enumerate(counts):
            clients[i].extend(items[idx:idx + k])
            idx += k
    # guarantee a minimum so every client can form batches
    pool = [ex for cl in clients for ex in cl]
    for cl in clients:
        while len(cl) < min_per_client:
            cl.append(pool[int(rng.integers(len(pool)))])
    for cl in clients:
        rng.shuffle(cl)
    return clients


def train_test_split(examples: Sequence[Example], test_frac: float,
                     rng: np.random.Generator) -> Tuple[List[Example], List[Example]]:
    """The paper's per-client 8:2 split; test stays local (same distribution)."""
    items = list(examples)
    rng.shuffle(items)
    k = max(1, int(len(items) * (1 - test_frac)))
    return items[:k], items[k:] if k < len(items) else items[-1:]
