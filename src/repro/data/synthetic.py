"""Synthetic datasets mirroring the paper's two evaluation scenarios.

Scenario-1 — log-based anomaly detection (BGL / Spirit / Thunderbird style):
samples are sliding windows of parsed log templates; the label is whether the
window contains an anomalous event. Each "source" (≈ a LogHub dataset) has
its own template pool and anomaly signatures, so different sources induce
genuinely different conditional distributions — the non-IID axis.

Scenario-2 — medical multiple-choice QA (ChemProt/MQP/PubMedQA/RCT/USMLE
style): five synthetic sub-tasks with distinct surface forms; the label is
the correct option letter. The class partitioned by Dirichlet(α) is the
sub-task id.

Both follow the paper's SFT format: a prompt, and a short answer span; the
loss mask covers only the answer tokens (appendix A1/A2 templates, reduced to
byte-tokenizer scale).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple

import numpy as np

from repro.data.tokenizer import ByteTokenizer


@dataclasses.dataclass
class Example:
    prompt: str
    answer: str
    cls: int  # class id used for the Dirichlet non-IID partition


# ---------------------------------------------------------------------------
# Scenario 1: log anomaly detection
# ---------------------------------------------------------------------------

_LOG_SOURCES = {
    0: {  # BGL-like
        "normal": ["cache parity ok", "fan speed set", "job start", "net link up",
                   "ciod io ready", "heartbeat ok"],
        "anomaly": ["L3 ecc uncorrectable", "kernel panic cpu0", "ddr failing addr"],
    },
    1: {  # Spirit-like
        "normal": ["sshd session open", "cron job ran", "nfs mount ok", "temp nominal",
                   "disk scrub pass"],
        "anomaly": ["scsi bus reset", "raid degraded", "oom killer invoked"],
    },
    2: {  # Thunderbird-like
        "normal": ["ib port active", "mpi init ok", "lustre ping", "pbs epilogue",
                   "power rail ok"],
        "anomaly": ["machine check fatal", "ib link flap", "ecc threshold exceeded"],
    },
}


def gen_log_dataset(rng: np.random.Generator, n: int, source: int,
                    window: int = 4, anomaly_rate: float = 0.35) -> List[Example]:
    src = _LOG_SOURCES[source % len(_LOG_SOURCES)]
    out = []
    for _ in range(n):
        is_anom = rng.random() < anomaly_rate
        lines = list(rng.choice(src["normal"], size=window))
        if is_anom:
            k = rng.integers(1, 3)
            pos = rng.choice(window, size=k, replace=False)
            for p in pos:
                lines[p] = str(rng.choice(src["anomaly"]))
        prompt = "logs: " + " | ".join(lines) + " anomaly? "
        out.append(Example(prompt, "yes" if is_anom else "no", cls=source))
    return out


# ---------------------------------------------------------------------------
# Scenario 2: medical multiple-choice QA (5 synthetic sub-tasks)
# ---------------------------------------------------------------------------

_MED_TASKS = [
    # (name, [(clue, answer_letter)...], options string)
    ("chemprot", [("x inhibits y", "a"), ("x activates y", "b"),
                  ("x binds y", "c")], "a)inhibitor b)activator c)substrate"),
    ("mqp", [("same meaning", "a"), ("different meaning", "b")],
     "a)similar b)dissimilar"),
    ("pubmedqa", [("evidence supports", "a"), ("evidence refutes", "b"),
                  ("evidence unclear", "c")], "a)yes b)no c)maybe"),
    ("rct", [("background info", "a"), ("methods used", "b"), ("results show", "c"),
             ("we conclude", "d")], "a)background b)methods c)results d)conclusions"),
    ("usmle", [("fever cough", "a"), ("chest pain", "b"), ("headache aura", "c")],
     "a)influenza b)angina c)migraine"),
]


def gen_medical_dataset(rng: np.random.Generator, n: int, task: int) -> List[Example]:
    name, clues, options = _MED_TASKS[task % len(_MED_TASKS)]
    out = []
    for _ in range(n):
        clue, ans = clues[rng.integers(len(clues))]
        noise = "".join(rng.choice(list("abcdefgh "), size=6))
        prompt = f"[{name}] {clue} {noise} {options} ans: "
        out.append(Example(prompt, ans, cls=task))
    return out


# ---------------------------------------------------------------------------
# Generic text for base-model pretraining ("basic knowledge")
# ---------------------------------------------------------------------------

def gen_pretrain_text(rng: np.random.Generator, n: int, length: int = 64) -> List[str]:
    words = ["the", "log", "system", "error", "ok", "yes", "no", "a", "b", "c",
             "patient", "result", "job", "link", "cache", "answer", "is"]
    return [" ".join(rng.choice(words, size=length // 4)) for _ in range(n)]


# ---------------------------------------------------------------------------
# SFT encoding
# ---------------------------------------------------------------------------

def encode_sft(examples: Sequence[Example], tok: ByteTokenizer, max_len: int
               ) -> Dict[str, np.ndarray]:
    """Returns {"tokens": (N, L), "loss_mask": (N, L), "cls": (N,)}."""
    from repro.data.tokenizer import pad_batch
    seqs, masks = [], []
    for ex in examples:
        p = tok.encode(ex.prompt, add_bos=True)
        a = tok.encode(ex.answer, add_bos=False, add_eos=True)
        seqs.append(p + a)
        masks.append([0] * len(p) + [1] * len(a))
    toks, lm = pad_batch(seqs, max_len, masks)
    return {"tokens": toks, "loss_mask": lm,
            "cls": np.array([ex.cls for ex in examples], dtype=np.int32)}


def answer_accuracy(model, cfg, params, adapters, examples: Sequence[Example],
                    tok: ByteTokenizer, max_len: int, lora_scale: float,
                    batch_size: int = 32) -> float:
    """Exact-match on the first answer token (greedy), the paper's
    'accuracy' metric reduced to byte scale: for scenario-1 'yes'/'no' and
    scenario-2 option letters, the first byte determines the answer."""
    import jax.numpy as jnp
    from repro.data.tokenizer import pad_batch

    correct = 0
    for i in range(0, len(examples), batch_size):
        chunk = examples[i:i + batch_size]
        prompts = [tok.encode(ex.prompt) for ex in chunk]
        lens = [min(len(p), max_len) for p in prompts]
        toks, _ = pad_batch(prompts, max_len)
        logits, _ = model.forward(params, {"tokens": jnp.asarray(toks)},
                                  adapters=adapters, lora_scale=lora_scale)
        preds = np.asarray(jnp.argmax(logits, axis=-1))
        for j, ex in enumerate(chunk):
            first_ans = tok.encode(ex.answer, add_bos=False)[0]
            if preds[j, lens[j] - 1] == first_ans:
                correct += 1
    return correct / max(len(examples), 1)
