"""Production mesh factory (TPU v5e target).

Single pod: 16×16 = 256 chips, axes ("data", "model").
Multi-pod:  2×16×16 = 512 chips, axes ("pod", "data", "model") — the "pod"
axis carries FDLoRA clients (client == pod slice; DESIGN.md §4).

Defined as functions so importing this module never touches jax device
state (the dry-run must set XLA_FLAGS before first jax init).
"""
from __future__ import annotations

import jax


def _mesh_kwargs(n):
    """``axis_types`` only exists on newer jax; 0.4.37 meshes are implicitly
    Auto, so omit the kwarg there."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n}


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    devices = jax.devices()[: 512 if multi_pod else 256]
    import numpy as np
    return jax.sharding.Mesh(
        np.asarray(devices).reshape(shape), axes, **_mesh_kwargs(len(axes)))


def make_host_mesh(model: int = 1):
    """Tiny mesh over the real local devices (CPU tests / examples)."""
    import numpy as np
    n = len(jax.devices())
    return jax.sharding.Mesh(
        np.asarray(jax.devices()).reshape(n // model, model),
        ("data", "model"), **_mesh_kwargs(2))
