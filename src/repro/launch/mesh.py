"""Production mesh factory (TPU v5e target).

Single pod: 16×16 = 256 chips, axes ("data", "model").
Multi-pod:  2×16×16 = 512 chips, axes ("pod", "data", "model") — the "pod"
axis carries FDLoRA clients (client == pod slice; DESIGN.md §4).

Defined as functions so importing this module never touches jax device
state (the dry-run must set XLA_FLAGS before first jax init).
"""
from __future__ import annotations

import jax


def _mesh_kwargs(n):
    """``axis_types`` only exists on newer jax; 0.4.37 meshes are implicitly
    Auto, so omit the kwarg there."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n}


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    need = 512 if multi_pod else 256
    devices = jax.devices()
    if len(devices) < need:
        raise ValueError(
            f"make_production_mesh(multi_pod={multi_pod}) needs {need} "
            f"devices, found {len(devices)}; use make_host_mesh() for "
            f"local runs")
    import numpy as np
    return jax.sharding.Mesh(
        np.asarray(devices[:need]).reshape(shape), axes,
        **_mesh_kwargs(len(axes)))


def make_host_mesh(model: int = 1):
    """Tiny mesh over the real local devices (CPU tests / examples).

    ``model`` splits the devices into a ("data", "model") grid; the device
    count must be divisible by it (force extra host devices with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N``).
    """
    import numpy as np
    n = len(jax.devices())
    if model < 1:
        raise ValueError(f"model axis must be >= 1, got {model}")
    if n % model != 0:
        raise ValueError(
            f"make_host_mesh(model={model}): {n} local devices are not "
            f"divisible by the model axis; force a compatible count with "
            f"XLA_FLAGS=--xla_force_host_platform_device_count")
    return jax.sharding.Mesh(
        np.asarray(jax.devices()).reshape(n // model, model),
        ("data", "model"), **_mesh_kwargs(2))
