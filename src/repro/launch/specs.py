"""ShapeDtypeStruct input stand-ins + sharding assignment for the dry-run.

``input_specs(cfg, shape)`` returns abstract inputs for every model input —
weak-type-correct, shardable, zero device allocation. Batch dims are sharded
over ("pod","data") when divisible, "data" when only that divides, else
replicated (long_500k has global_batch=1).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import INPUT_SHAPES, ModelConfig

SDS = jax.ShapeDtypeStruct


def batch_axes(mesh: Mesh, global_batch: int) -> Optional[Tuple[str, ...]]:
    """Largest prefix of (pod, data) whose product divides the batch."""
    names = [n for n in ("pod", "data") if n in mesh.axis_names]
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    chosen = []
    prod = 1
    for n in names:
        if global_batch % (prod * sizes[n]) == 0:
            chosen.append(n)
            prod *= sizes[n]
    return tuple(chosen) or None


def train_inputs(cfg: ModelConfig, shape_name: str) -> Dict[str, SDS]:
    sh = INPUT_SHAPES[shape_name]
    B, S = sh.global_batch, sh.seq_len
    out = {"tokens": SDS((B, S), jnp.int32),
           "loss_mask": SDS((B, S), jnp.int32)}
    if cfg.family == "vlm":
        out["patch_embeds"] = SDS((B, cfg.n_patch_tokens, cfg.d_model), jnp.bfloat16)
    if cfg.is_encdec:
        out["enc_embeds"] = SDS((B, cfg.encoder_seq_len, cfg.d_model), jnp.bfloat16)
    return out


def train_input_specs(cfg: ModelConfig, mesh: Mesh, shape_name: str):
    sh = INPUT_SHAPES[shape_name]
    ba = batch_axes(mesh, sh.global_batch)
    specs = {"tokens": P(ba, None), "loss_mask": P(ba, None)}
    if cfg.family == "vlm":
        specs["patch_embeds"] = P(ba, None, None)
    if cfg.is_encdec:
        specs["enc_embeds"] = P(ba, None, None)
    return specs


def decode_inputs(cfg: ModelConfig, shape_name: str) -> Dict[str, Any]:
    sh = INPUT_SHAPES[shape_name]
    B = sh.global_batch
    return {"tokens": SDS((B, 1), jnp.int32), "pos": SDS((), jnp.int32)}


def decode_input_specs(cfg: ModelConfig, mesh: Mesh, shape_name: str):
    sh = INPUT_SHAPES[shape_name]
    ba = batch_axes(mesh, sh.global_batch)
    return {"tokens": P(ba, None), "pos": P()}


def abstract_tree(fn, *args, **kw):
    """Shapes of fn(*args) without running it."""
    return jax.eval_shape(fn, *args, **kw)


def sharding_tree(mesh: Mesh, spec_tree):
    """PartitionSpec tree -> NamedSharding tree, dropping axes the mesh
    doesn't have and axes that don't divide (replicate instead)."""
    axes = set(mesh.axis_names)

    def fix(spec):
        entries = []
        for e in spec:
            names = e if isinstance(e, tuple) else (e,)
            kept = tuple(n for n in names if n is not None and n in axes)
            entries.append(kept if len(kept) > 1 else (kept[0] if kept else None))
        return NamedSharding(mesh, P(*entries))

    return jax.tree.map(fix, spec_tree, is_leaf=lambda s: isinstance(s, P))


def pad_spec_to(spec_tree, shape_tree):
    """Ensure every spec has exactly the leaf's rank (pad with None)."""
    def fix(spec, sds):
        t = tuple(spec)
        if len(t) < len(sds.shape):
            t = t + (None,) * (len(sds.shape) - len(t))
        return P(*t[:len(sds.shape)])

    return jax.tree.map(fix, spec_tree, shape_tree,
                        is_leaf=lambda s: isinstance(s, P))
