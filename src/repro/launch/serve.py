"""Serving launcher: batched KV-cache decoding with optional fused dual-LoRA
adapters (the FDLoRA inference path).

    PYTHONPATH=src python -m repro.launch.serve --arch gemma-2b --smoke \
        --batch 4 --new-tokens 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import ALL_ARCHS, get_config
from repro.core.dual_lora import merge
from repro.core.lora import init_adapters
from repro.data.tokenizer import ByteTokenizer
from repro.models.api import get_model
from repro.serving.engine import Engine, ServeConfig
from repro.training.checkpoint import load_checkpoint


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b", choices=ALL_ARCHS)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--cache-len", type=int, default=256)
    ap.add_argument("--adapters", default="", help="npz checkpoint to load")
    ap.add_argument("--dual", action="store_true",
                    help="demo: fuse two random adapter sets via Eq.7")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    if cfg.is_encdec:
        raise SystemExit("enc-dec serving needs audio embeds; use tests/"
                         "test_models.py::test_whisper_prefill_cross for the path")
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    adapters = None
    if args.adapters:
        adapters = load_checkpoint(args.adapters)
    elif args.dual:
        ad_p = init_adapters(jax.random.PRNGKey(1), cfg)
        ad_s = init_adapters(jax.random.PRNGKey(2), cfg)
        adapters = merge(ad_p, ad_s, jnp.array([0.6, 0.6]))

    eng = Engine(model, cfg, params, adapters)
    tok = ByteTokenizer()
    prompt = tok.encode("logs: job start | net link up anomaly? ")[:32]
    prompts = jnp.asarray(np.tile(np.array(prompt, np.int32)
                                  % cfg.vocab_size, (args.batch, 1)))
    sc = ServeConfig(batch_size=args.batch, max_new_tokens=args.new_tokens,
                     cache_len=args.cache_len)
    t0 = time.time()
    out = eng.generate(prompts, sc)
    dt = time.time() - t0
    total = args.batch * args.new_tokens
    print(f"generated {total} tokens in {dt:.2f}s "
          f"({total/dt:.1f} tok/s incl. compile)")
    print("sample:", tok.decode(np.asarray(out)[0])[:60])


if __name__ == "__main__":
    main()
