"""Serving launcher: batched KV-cache decoding with optional fused dual-LoRA
adapters (the FDLoRA inference path).

    PYTHONPATH=src python -m repro.launch.serve --arch gemma-2b --smoke \
        --batch 4 --new-tokens 16

Multi-tenant demo (one engine, N resident client adapters, mixed batch):

    PYTHONPATH=src python -m repro.launch.serve --arch gemma-2b --smoke \
        --tenants 4 --batch 8 --new-tokens 16

Continuous batching (slot scheduler + paged KV cache: ragged prompts,
per-request budgets, admission into freed slots):

    PYTHONPATH=src python -m repro.launch.serve --arch gemma-2b --smoke \
        --tenants 4 --batch 4 --requests 12 --continuous
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import ALL_ARCHS, get_config
from repro.core.dual_lora import merge
from repro.core.lora import init_adapters
from repro.data.tokenizer import ByteTokenizer
from repro.models.api import get_model
from repro.serving.engine import (Engine, MultiTenantEngine, Request,
                                  ServeConfig)
from repro.serving.registry import AdapterRegistry
from repro.serving.sharded import ShardedAdapterRegistry
from repro.training.checkpoint import load_checkpoint


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b", choices=ALL_ARCHS)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--cache-len", type=int, default=256)
    ap.add_argument("--adapters", default="", help="npz checkpoint to load")
    ap.add_argument("--dual", action="store_true",
                    help="demo: fuse two random adapter sets via Eq.7")
    ap.add_argument("--tenants", type=int, default=0,
                    help="multi-tenant demo: N resident client adapters, "
                         "one engine, mixed-client batch")
    ap.add_argument("--continuous", action="store_true",
                    help="with --tenants: serve a ragged request stream "
                         "through the slot scheduler + paged KV cache")
    ap.add_argument("--requests", type=int, default=0,
                    help="continuous mode: queued requests (default 3x batch)")
    ap.add_argument("--block-size", type=int, default=16,
                    help="continuous mode: KV block size (tokens)")
    ap.add_argument("--prefill-chunk", type=int, default=16,
                    help="continuous mode: prompt tokens per prefill "
                         "dispatch (1 = legacy one-token-per-step)")
    ap.add_argument("--stream", action="store_true",
                    help="continuous mode: print per-request token "
                         "increments as chunks complete (generate_stream)")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="continuous mode: content-addressed shared KV "
                         "blocks — shared prompt prefixes skip re-prefill "
                         "within and across calls (runs the stream twice "
                         "to show the warm-cache hit rate)")
    ap.add_argument("--sched-policy", default="sla",
                    choices=["sla", "fcfs"],
                    help="continuous mode: 'sla' = priority-class admission "
                         "with aging + prefix-aware preemption victims; "
                         "'fcfs' = legacy arrival order + newest-first")
    ap.add_argument("--priority-mix", default="",
                    help="continuous mode: comma list of classes "
                         "(interactive,batch,background) cycled over the "
                         "request stream, e.g. 'batch,batch,interactive'; "
                         "empty = all batch")
    ap.add_argument("--spec-decode", action="store_true",
                    help="continuous mode: speculative greedy decoding — "
                         "prompt-lookup drafts verified through the paged "
                         "prefill path (bitwise-identical tokens, fewer "
                         "model evaluations on repetitive output)")
    ap.add_argument("--spec-k", type=int, default=4,
                    help="with --spec-decode: max drafted tokens per slot "
                         "per verify round")
    ap.add_argument("--shards", type=int, default=1,
                    help="continuous mode: partition the paged KV pool and "
                         "adapter bank into N shards with placement-aware "
                         "admission (slots and blocks split evenly; outputs "
                         "stay bitwise-identical to --shards 1)")
    ap.add_argument("--kv-dtype", default="f32",
                    choices=["f32", "int8"],
                    help="continuous mode: paged KV block storage — 'f32' "
                         "= the unquantized pools, 'int8' = quantized "
                         "blocks with per-block scales (~1.78x blocks per "
                         "HBM byte; error-bound, not bitwise, vs f32)")
    ap.add_argument("--paged-backend", default="jnp",
                    choices=["jnp", "pallas"],
                    help="continuous mode: paged-attention implementation — "
                         "'jnp' gather oracle (CPU default) or 'pallas' "
                         "kernels (interpret-mode on CPU; identical greedy "
                         "tokens)")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    if cfg.is_encdec:
        raise SystemExit("enc-dec serving needs audio embeds; use tests/"
                         "test_models.py::test_whisper_prefill_cross for the path")
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    tok = ByteTokenizer()
    prompt = tok.encode("logs: job start | net link up anomaly? ")[:32]
    prompt = np.array(prompt, np.int32) % cfg.vocab_size
    sc = ServeConfig(batch_size=args.batch, max_new_tokens=args.new_tokens,
                     cache_len=args.cache_len)

    if args.continuous and args.tenants <= 0:
        raise SystemExit("--continuous needs --tenants N (the continuous "
                         "scheduler serves the multi-tenant engine)")
    if args.tenants > 0:
        if args.adapters or args.dual:
            raise SystemExit("--tenants is a self-contained demo (random "
                             "fused adapters per tenant); it cannot combine "
                             "with --adapters/--dual")
        # FDLoRA end state: every client registered one Eq.7-fused adapter;
        # a single engine serves a batch that mixes all of them.
        if args.shards > 1:
            cap = -(-args.tenants // args.shards) * args.shards
            registry = ShardedAdapterRegistry(cfg, capacity=cap,
                                              num_shards=args.shards)
        else:
            registry = AdapterRegistry(cfg, capacity=args.tenants)
        for i in range(args.tenants):
            ad_p = init_adapters(jax.random.PRNGKey(10 + 2 * i), cfg)
            ad_s = init_adapters(jax.random.PRNGKey(11 + 2 * i), cfg)
            registry.register_dual(f"client{i}", ad_p, ad_s,
                                   jnp.array([0.6, 0.6]))
        eng = MultiTenantEngine(model, cfg, params, registry)
        if args.continuous:
            # ragged stream: varied prompt lengths AND per-request budgets;
            # the scheduler admits queued requests as slots free up.
            n_req = args.requests or 3 * args.batch
            sc.block_size = args.block_size
            sc.prefill_chunk = args.prefill_chunk
            sc.prefix_cache = args.prefix_cache
            sc.sched_policy = args.sched_policy
            sc.paged_backend = args.paged_backend
            sc.kv_dtype = args.kv_dtype
            sc.spec_decode = args.spec_decode
            sc.spec_k = args.spec_k
            sc.num_shards = args.shards
            mix = [c.strip() for c in args.priority_mix.split(",")
                   if c.strip()]
            reqs = [Request(f"client{i % args.tenants}",
                            prompt[: 8 + (5 * i) % (len(prompt) - 7)],
                            max_new_tokens=4 + (7 * i) % args.new_tokens,
                            priority=mix[i % len(mix)] if mix else "batch")
                    for i in range(n_req)]
            t0 = time.time()
            if args.stream:
                outs = [np.zeros((0,), np.int32)] * n_req
                for rid, toks, finished in eng.generate_stream(reqs, sc):
                    outs[rid] = np.concatenate(
                        [outs[rid], np.asarray(toks, np.int32)])
                    tag = " <done>" if finished else ""
                    print(f"  [stream] req{rid} +{len(toks)} "
                          f"({outs[rid].size} total){tag}: "
                          f"{tok.decode(np.asarray(toks))[:24]!r}")
            else:
                outs = eng.generate(reqs, sc)
            dt = time.time() - t0
            total = sum(o.size for o in outs)
            stats = eng.last_stats
            print(f"{args.tenants} tenants, {n_req} ragged requests over "
                  f"{args.batch} slots (block={sc.block_size}, "
                  f"prefill_chunk={sc.prefill_chunk}): {total} tokens in "
                  f"{dt:.2f}s ({total/dt:.1f} tok/s incl. compile); "
                  f"{stats['prefill_dispatches']} prefill + "
                  f"{stats['decode_dispatches']} decode dispatches, "
                  f"{stats['preemptions']} preemptions "
                  f"[{stats['sched_policy']}, backend={sc.paged_backend}, "
                  f"kv={sc.kv_dtype}]")
            if args.shards > 1:
                print(f"  {args.shards} shards: placements "
                      f"{stats['shard_placements']} "
                      f"(prefix-affinity > adapter home > least-loaded)")
            if args.spec_decode:
                print(f"  spec decode (k={sc.spec_k}): "
                      f"{stats['accepted_tokens']}/{stats['drafted_tokens']} "
                      f"drafted tokens accepted "
                      f"({stats['acceptance_rate']:.0%}) over "
                      f"{stats['verify_dispatches']} verify dispatches; "
                      f"{stats['rollback_tokens']} tokens / "
                      f"{stats['rollback_blocks']} blocks rolled back")
            for cname, cs in stats["classes"].items():
                print(f"  class {cname}: {cs['admitted']} admitted, "
                      f"queue wait p50 {cs['wait_p50']:.0f} / "
                      f"p99 {cs['wait_p99']:.0f} rounds, "
                      f"{cs['preemptions']} preemptions")
            if args.prefix_cache:
                print(f"  prefix cache (cold call): "
                      f"{stats['prefix_hit_tokens']}/"
                      f"{stats['prompt_tokens']} prompt tokens cached "
                      f"({stats['prefix_hit_rate']:.0%}); "
                      f"{stats['prefix_cached_blocks']} blocks retained")
                outs2 = eng.generate(reqs, sc)     # warm: prefixes re-match
                warm = eng.last_stats
                if sc.temperature == 0:            # bitwise claim is greedy-only
                    for a, b in zip(outs, outs2):
                        assert (np.asarray(a) == np.asarray(b)).all(), \
                            "warm cache diverged from cold run"
                print(f"  prefix cache (warm call): "
                      f"{warm['prefix_hit_tokens']}/"
                      f"{warm['prompt_tokens']} prompt tokens cached "
                      f"({warm['prefix_hit_rate']:.0%}); bitwise-equal, "
                      f"{warm['prefill_dispatches']} prefill dispatches vs "
                      f"{stats['prefill_dispatches']} cold")
            for r, o in list(zip(reqs, outs))[:args.tenants]:
                print(f"  {r.client_id} (S={len(r.prompt)}, "
                      f"budget={r.max_new_tokens}):", tok.decode(o)[:40])
            return
        reqs = [Request(f"client{b % args.tenants}", prompt)
                for b in range(args.batch)]
        t0 = time.time()
        out = eng.generate_fixed(reqs, sc)
        dt = time.time() - t0
        total = args.batch * args.new_tokens
        print(f"{args.tenants} tenants resident, mixed batch of {args.batch}: "
              f"{total} tokens in {dt:.2f}s ({total/dt:.1f} tok/s incl. compile)")
        for b in range(min(args.batch, args.tenants)):
            print(f"  {reqs[b].client_id}:",
                  tok.decode(np.asarray(out)[b])[:48])
        return

    adapters = None
    if args.adapters:
        adapters = load_checkpoint(args.adapters)
    elif args.dual:
        ad_p = init_adapters(jax.random.PRNGKey(1), cfg)
        ad_s = init_adapters(jax.random.PRNGKey(2), cfg)
        adapters = merge(ad_p, ad_s, jnp.array([0.6, 0.6]))

    eng = Engine(model, cfg, params, adapters)
    prompts = jnp.asarray(np.tile(prompt, (args.batch, 1)))
    t0 = time.time()
    out = eng.generate(prompts, sc)
    dt = time.time() - t0
    total = args.batch * args.new_tokens
    print(f"generated {total} tokens in {dt:.2f}s "
          f"({total/dt:.1f} tok/s incl. compile)")
    print("sample:", tok.decode(np.asarray(out)[0])[:60])


if __name__ == "__main__":
    main()
