"""Serving launcher: batched KV-cache decoding with optional fused dual-LoRA
adapters (the FDLoRA inference path).

    PYTHONPATH=src python -m repro.launch.serve --arch gemma-2b --smoke \
        --batch 4 --new-tokens 16

Multi-tenant demo (one engine, N resident client adapters, mixed batch):

    PYTHONPATH=src python -m repro.launch.serve --arch gemma-2b --smoke \
        --tenants 4 --batch 8 --new-tokens 16

Continuous batching (slot scheduler + paged KV cache: ragged prompts,
per-request budgets, admission into freed slots):

    PYTHONPATH=src python -m repro.launch.serve --arch gemma-2b --smoke \
        --tenants 4 --batch 4 --requests 12 --continuous

Open-loop asyncio serving (requests arrive on a synthetic trace at
arbitrary wall-clock times, tokens stream back per request, graceful
drain; see ``serving/trace.py`` for the workload generator):

    PYTHONPATH=src python -m repro.launch.serve --arch gemma-2b --smoke \
        --tenants 4 --batch 4 --serve --trace-requests 24 \
        --trace-arrival bursty --trace-rate 20
"""
from __future__ import annotations

import argparse
import asyncio
import time
from collections import deque
from typing import AsyncIterator, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import ALL_ARCHS, get_config
from repro.core.dual_lora import merge
from repro.core.lora import init_adapters
from repro.data.tokenizer import ByteTokenizer
from repro.models.api import get_model
from repro.serving.engine import (Engine, MultiTenantEngine, Request,
                                  ServeConfig)
from repro.serving.kv_cache import blocks_needed
from repro.serving.registry import AdapterRegistry
from repro.serving.sharded import ShardedAdapterRegistry
from repro.training.checkpoint import load_checkpoint


class AsyncServer:
    """Asyncio front end over an open-loop :class:`StreamSession`.

    Callers ``await submit(request)`` at ANY time — including while other
    requests are mid-flight — and consume their tokens incrementally via
    ``async for toks in stream(rid)``.  One pump coroutine owns the
    session: it drains staged submissions between engine rounds (so
    scheduler state is only ever touched from the event-loop thread) and
    runs each blocking :meth:`StreamSession.step` in the default executor,
    which keeps the event loop responsive while the device computes —
    with ``ServeConfig.overlap`` the host side of a step is mostly
    planning, so submissions interleave at chunk granularity.

    ``await drain()`` is the graceful shutdown: already-accepted requests
    run to completion, late ``submit`` calls are rejected, and the
    session's ``last_stats`` (wall-clock queue waits per class) come back.
    ``async with AsyncServer(...)`` drains on exit.
    """

    def __init__(self, engine: MultiTenantEngine, sc: ServeConfig):
        self._engine, self._sc = engine, sc
        self._ses = engine.session(sc)          # open loop: starts empty
        self._staged: deque = deque()           # (Request, arrival, Future)
        self._queues: Dict[int, asyncio.Queue] = {}
        self._wake: Optional[asyncio.Event] = None
        self._pump_task: Optional[asyncio.Task] = None
        self._closing = False
        self.stats: Optional[dict] = None

    # -- lifecycle -----------------------------------------------------------
    def start(self) -> "AsyncServer":
        if self._pump_task is None:
            self._wake = asyncio.Event()
            self._pump_task = asyncio.ensure_future(self._pump())
        return self

    async def drain(self) -> dict:
        """Stop accepting; run accepted requests to completion; return the
        session's ``last_stats``."""
        self._closing = True
        if self._pump_task is not None:
            self._wake.set()
            await self._pump_task
        else:
            self.stats = self._ses.finalize()
        return self.stats

    async def __aenter__(self) -> "AsyncServer":
        return self.start()

    async def __aexit__(self, *exc) -> None:
        await self.drain()

    # -- client API ----------------------------------------------------------
    async def submit(self, request: Request) -> int:
        """Stage ``request`` and return its rid once the pump accepts it.
        The submission wall-clock time is recorded as the request's
        arrival, so ``last_stats`` queue waits are end-to-end."""
        if self._closing:
            raise RuntimeError("AsyncServer is draining; submit rejected")
        if self._pump_task is None:
            raise RuntimeError("AsyncServer not started (use 'async with' "
                               "or call start())")
        fut = asyncio.get_running_loop().create_future()
        self._staged.append((request, time.monotonic(), fut))
        self._wake.set()
        return await fut

    async def stream(self, rid: int) -> AsyncIterator[List[int]]:
        """Token increments for one request, ending after its final chunk
        (budget reached or EOS)."""
        q = self._queues[rid]
        while True:
            toks, fin = await q.get()
            if toks:
                yield toks
            if fin:
                return

    # -- engine pump ---------------------------------------------------------
    async def _pump(self) -> None:
        loop = asyncio.get_running_loop()
        ses = self._ses
        while True:
            while self._staged:                 # intake between rounds
                req, arrival, fut = self._staged.popleft()
                rid = ses.submit(req, arrival_time=arrival)
                self._queues[rid] = asyncio.Queue()
                fut.set_result(rid)
            if not ses.has_work:
                if self._closing:
                    break
                self._wake.clear()              # idle: park until a submit
                await self._wake.wait()
                continue
            events = await loop.run_in_executor(None, ses.step)
            for rid, toks, fin in events:
                q = self._queues.get(rid)
                if q is not None:
                    q.put_nowait((list(toks), fin))
                    if fin:
                        self._queues.pop(rid, None)
        self.stats = ses.finalize()


def _print_class_stats(stats: dict) -> None:
    """Per-class queue-wait lines.  Open-loop sessions (driven with
    arrival times) report WALL-CLOCK percentiles; closed-loop batches
    keep the admission-round numbers — rounds are meaningless as a
    latency unit when requests arrive over an open interval."""
    for cname, cs in stats["classes"].items():
        if "wait_wall_ms_p50" in cs:
            print(f"  class {cname}: {cs['admitted']} admitted, "
                  f"queue wait p50 {cs['wait_wall_ms_p50']:.1f} / "
                  f"p99 {cs['wait_wall_ms_p99']:.1f} ms wall, "
                  f"{cs['preemptions']} preemptions")
        else:
            print(f"  class {cname}: {cs['admitted']} admitted, "
                  f"queue wait p50 {cs['wait_p50']:.0f} / "
                  f"p99 {cs['wait_p99']:.0f} rounds, "
                  f"{cs['preemptions']} preemptions")


async def _serve_demo(eng: MultiTenantEngine, sc: ServeConfig, trace,
                      time_scale: float, tok: ByteTokenizer) -> dict:
    """Drive a synthetic open-loop trace through :class:`AsyncServer`:
    one client coroutine per trace entry sleeps until its scheduled
    arrival, submits, and consumes its stream; the server drains
    gracefully once every accepted request finishes."""
    t0 = time.monotonic()
    lat: Dict[int, Tuple[float, int, float]] = {}   # rid -> (ttft, n, span)
    async with AsyncServer(eng, sc) as srv:
        async def client(i, e):
            sched = e.arrival_s * time_scale
            await asyncio.sleep(max(0.0, sched - (time.monotonic() - t0)))
            rid = await srv.submit(e.request())
            first = last = None
            n = 0
            async for toks in srv.stream(rid):
                now = time.monotonic() - t0
                first = now if first is None else first
                last, n = now, n + len(toks)
            lat[i] = (first - sched, n, (last - first) if n > 1 else 0.0)

        await asyncio.gather(*(client(i, e) for i, e in enumerate(trace)))
        elapsed = time.monotonic() - t0
    ttfts = sorted(v[0] for v in lat.values())
    total = sum(v[1] for v in lat.values())
    print(f"open-loop serve: {len(trace)} requests, {total} tokens in "
          f"{elapsed:.2f}s ({total / elapsed:.1f} tok/s goodput incl. "
          f"compile); TTFT p50 "
          f"{1e3 * float(np.percentile(ttfts, 50)):.1f} / p99 "
          f"{1e3 * float(np.percentile(ttfts, 99)):.1f} ms "
          f"[overlap={'on' if sc.overlap else 'off'}]")
    _print_class_stats(srv.stats)
    return srv.stats


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma-2b", choices=ALL_ARCHS)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--cache-len", type=int, default=256)
    ap.add_argument("--adapters", default="", help="npz checkpoint to load")
    ap.add_argument("--dual", action="store_true",
                    help="demo: fuse two random adapter sets via Eq.7")
    ap.add_argument("--tenants", type=int, default=0,
                    help="multi-tenant demo: N resident client adapters, "
                         "one engine, mixed-client batch")
    ap.add_argument("--continuous", action="store_true",
                    help="with --tenants: serve a ragged request stream "
                         "through the slot scheduler + paged KV cache")
    ap.add_argument("--requests", type=int, default=0,
                    help="continuous mode: queued requests (default 3x batch)")
    ap.add_argument("--block-size", type=int, default=16,
                    help="continuous mode: KV block size (tokens)")
    ap.add_argument("--prefill-chunk", type=int, default=16,
                    help="continuous mode: prompt tokens per prefill "
                         "dispatch (1 = legacy one-token-per-step)")
    ap.add_argument("--stream", action="store_true",
                    help="continuous mode: print per-request token "
                         "increments as chunks complete (generate_stream)")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="continuous mode: content-addressed shared KV "
                         "blocks — shared prompt prefixes skip re-prefill "
                         "within and across calls (runs the stream twice "
                         "to show the warm-cache hit rate)")
    ap.add_argument("--sched-policy", default="sla",
                    choices=["sla", "fcfs"],
                    help="continuous mode: 'sla' = priority-class admission "
                         "with aging + prefix-aware preemption victims; "
                         "'fcfs' = legacy arrival order + newest-first")
    ap.add_argument("--priority-mix", default="",
                    help="continuous mode: comma list of classes "
                         "(interactive,batch,background) cycled over the "
                         "request stream, e.g. 'batch,batch,interactive'; "
                         "empty = all batch")
    ap.add_argument("--spec-decode", action="store_true",
                    help="continuous mode: speculative greedy decoding — "
                         "prompt-lookup drafts verified through the paged "
                         "prefill path (bitwise-identical tokens, fewer "
                         "model evaluations on repetitive output)")
    ap.add_argument("--spec-k", type=int, default=4,
                    help="with --spec-decode: max drafted tokens per slot "
                         "per verify round")
    ap.add_argument("--shards", type=int, default=1,
                    help="continuous mode: partition the paged KV pool and "
                         "adapter bank into N shards with placement-aware "
                         "admission (slots and blocks split evenly; outputs "
                         "stay bitwise-identical to --shards 1)")
    ap.add_argument("--kv-dtype", default="f32",
                    choices=["f32", "int8"],
                    help="continuous mode: paged KV block storage — 'f32' "
                         "= the unquantized pools, 'int8' = quantized "
                         "blocks with per-block scales (~1.78x blocks per "
                         "HBM byte; error-bound, not bitwise, vs f32)")
    ap.add_argument("--paged-backend", default="jnp",
                    choices=["jnp", "pallas"],
                    help="continuous mode: paged-attention implementation — "
                         "'jnp' gather oracle (CPU default) or 'pallas' "
                         "kernels (interpret-mode on CPU; identical greedy "
                         "tokens)")
    ap.add_argument("--serve", action="store_true",
                    help="with --tenants: open-loop asyncio serving — "
                         "requests arrive on a synthetic trace at wall-"
                         "clock times, tokens stream back per request, "
                         "graceful drain; reports TTFT percentiles and "
                         "WALL-CLOCK per-class queue waits")
    ap.add_argument("--trace-requests", type=int, default=24,
                    help="--serve: trace length (requests)")
    ap.add_argument("--trace-arrival", default="bursty",
                    choices=["poisson", "bursty"],
                    help="--serve: arrival process (same long-run rate)")
    ap.add_argument("--trace-rate", type=float, default=20.0,
                    help="--serve: mean arrival rate, requests/second")
    ap.add_argument("--trace-seed", type=int, default=0,
                    help="--serve: workload generator seed")
    ap.add_argument("--time-scale", type=float, default=1.0,
                    help="--serve: multiply trace arrival times (<1 "
                         "compresses the trace = higher load)")
    ap.add_argument("--no-overlap", action="store_true",
                    help="disable async overlapped dispatch (run the "
                         "synchronous reference loop; tokens are bitwise "
                         "identical either way)")
    ap.add_argument("--ranks", default="",
                    help="with --tenants: comma list of rank buckets (e.g. "
                         "'2,4,8') — the bank splits into one bucket per "
                         "rank and client i registers at ranks[i %% len], "
                         "padded into its bucket (small-rank clients stop "
                         "paying max-rank HBM; outputs stay bitwise equal "
                         "to each client's native-rank adapter)")
    ap.add_argument("--update-every", type=int, default=0,
                    help="continuous mode: every N stream events, re-"
                         "register one client's fused adapter mid-serve "
                         "(round-robin) — the FDLoRA continual loop; the "
                         "live session hot-swaps the bank at its next "
                         "round boundary and the updated client's prefix-"
                         "cache scope is invalidated by the version bump")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    if cfg.is_encdec:
        raise SystemExit("enc-dec serving needs audio embeds; use tests/"
                         "test_models.py::test_whisper_prefill_cross for the path")
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    tok = ByteTokenizer()
    prompt = tok.encode("logs: job start | net link up anomaly? ")[:32]
    prompt = np.array(prompt, np.int32) % cfg.vocab_size
    sc = ServeConfig(batch_size=args.batch, max_new_tokens=args.new_tokens,
                     cache_len=args.cache_len)

    if (args.continuous or args.serve) and args.tenants <= 0:
        raise SystemExit("--continuous/--serve need --tenants N (the "
                         "continuous scheduler serves the multi-tenant "
                         "engine)")
    sc.overlap = not args.no_overlap
    if args.tenants > 0:
        if args.adapters or args.dual:
            raise SystemExit("--tenants is a self-contained demo (random "
                             "fused adapters per tenant); it cannot combine "
                             "with --adapters/--dual")
        if args.update_every and args.prefix_cache:
            raise SystemExit("--update-every re-registers adapters mid-"
                             "serve, so the --prefix-cache warm-call "
                             "bitwise check cannot hold; pick one")
        # FDLoRA end state: every client registered one Eq.7-fused adapter;
        # a single engine serves a batch that mixes all of them.
        rank_list = [int(r) for r in args.ranks.split(",") if r.strip()]
        # ragged banks split capacity across rank buckets; give the demo 2x
        # slack so round-robin client ranks never churn a full bucket
        cap = args.tenants if not rank_list else 2 * args.tenants
        cap = max(cap, args.shards * max(1, len(set(rank_list))))
        if args.shards > 1:
            cap = -(-cap // args.shards) * args.shards
            registry = ShardedAdapterRegistry(cfg, capacity=cap,
                                              num_shards=args.shards,
                                              ranks=rank_list or None)
        else:
            registry = AdapterRegistry(cfg, capacity=cap,
                                       ranks=rank_list or None)

        def _client_rank(i: int):
            return rank_list[i % len(rank_list)] if rank_list else None

        for i in range(args.tenants):
            rk = _client_rank(i)
            ad_p = init_adapters(jax.random.PRNGKey(10 + 2 * i), cfg, rank=rk)
            ad_s = init_adapters(jax.random.PRNGKey(11 + 2 * i), cfg, rank=rk)
            registry.register_dual(f"client{i}", ad_p, ad_s,
                                   jnp.array([0.6, 0.6]))
        if rank_list:
            print(f"ragged adapter bank: buckets {registry.bucket_ranks}, "
                  f"per-slot effective ranks "
                  f"{registry.slot_ranks().tolist()}")
        eng = MultiTenantEngine(model, cfg, params, registry)
        if args.serve:
            from repro.serving.trace import synth_trace
            sc.block_size = args.block_size
            sc.prefill_chunk = args.prefill_chunk
            sc.sched_policy = args.sched_policy
            sc.paged_backend = args.paged_backend
            sc.kv_dtype = args.kv_dtype
            sc.num_shards = args.shards
            # open-loop sessions need the pool pinned up front: size it for
            # batch_size concurrent worst-case spans (prompt_max + out_max)
            prompt_max, out_max = 32, args.new_tokens
            bp = blocks_needed(prompt_max + out_max, sc.block_size)
            nb = args.batch * bp
            if args.shards > 1:
                nb = -(-nb // args.shards) * args.shards
            sc.num_blocks = 1 + nb
            sc.max_blocks_per_slot = bp
            trace = synth_trace(
                args.trace_seed, args.trace_requests,
                arrival=args.trace_arrival, rate=args.trace_rate,
                prompt_max=prompt_max, out_max=out_max,
                clients=tuple(f"client{i}" for i in range(args.tenants)),
                vocab_size=cfg.vocab_size)
            asyncio.run(_serve_demo(eng, sc, trace, args.time_scale, tok))
            return
        if args.continuous:
            # ragged stream: varied prompt lengths AND per-request budgets;
            # the scheduler admits queued requests as slots free up.
            n_req = args.requests or 3 * args.batch
            sc.block_size = args.block_size
            sc.prefill_chunk = args.prefill_chunk
            sc.prefix_cache = args.prefix_cache
            sc.sched_policy = args.sched_policy
            sc.paged_backend = args.paged_backend
            sc.kv_dtype = args.kv_dtype
            sc.spec_decode = args.spec_decode
            sc.spec_k = args.spec_k
            sc.num_shards = args.shards
            mix = [c.strip() for c in args.priority_mix.split(",")
                   if c.strip()]
            reqs = [Request(f"client{i % args.tenants}",
                            prompt[: 8 + (5 * i) % (len(prompt) - 7)],
                            max_new_tokens=4 + (7 * i) % args.new_tokens,
                            priority=mix[i % len(mix)] if mix else "batch")
                    for i in range(n_req)]
            t0 = time.time()
            updates = 0
            if args.stream or args.update_every > 0:
                outs = [np.zeros((0,), np.int32)] * n_req
                events = 0
                for rid, toks, finished in eng.generate_stream(reqs, sc):
                    outs[rid] = np.concatenate(
                        [outs[rid], np.asarray(toks, np.int32)])
                    if args.stream:
                        tag = " <done>" if finished else ""
                        print(f"  [stream] req{rid} +{len(toks)} "
                              f"({outs[rid].size} total){tag}: "
                              f"{tok.decode(np.asarray(toks))[:24]!r}")
                    events += 1
                    if args.update_every and events % args.update_every == 0:
                        # the FDLoRA continual loop: a finished stage-2
                        # round publishes one client's refreshed fused
                        # adapter into the LIVE registry; the session
                        # hot-swaps the bank at its next round boundary
                        i = updates % args.tenants
                        rk = _client_rank(i)
                        registry.register_dual(
                            f"client{i}",
                            init_adapters(jax.random.PRNGKey(
                                1000 + 2 * updates), cfg, rank=rk),
                            init_adapters(jax.random.PRNGKey(
                                1001 + 2 * updates), cfg, rank=rk),
                            jnp.array([0.6, 0.6]))
                        updates += 1
            else:
                outs = eng.generate(reqs, sc)
            dt = time.time() - t0
            total = sum(o.size for o in outs)
            stats = eng.last_stats
            print(f"{args.tenants} tenants, {n_req} ragged requests over "
                  f"{args.batch} slots (block={sc.block_size}, "
                  f"prefill_chunk={sc.prefill_chunk}): {total} tokens in "
                  f"{dt:.2f}s ({total/dt:.1f} tok/s incl. compile); "
                  f"{stats['prefill_dispatches']} prefill + "
                  f"{stats['decode_dispatches']} decode dispatches, "
                  f"{stats['preemptions']} preemptions "
                  f"[{stats['sched_policy']}, backend={sc.paged_backend}, "
                  f"kv={sc.kv_dtype}]")
            if args.shards > 1:
                print(f"  {args.shards} shards: placements "
                      f"{stats['shard_placements']} "
                      f"(prefix-affinity > adapter home > least-loaded)")
            if args.update_every:
                print(f"  online updates: {updates} mid-serve "
                      f"re-registrations, "
                      f"{stats['adapter_bank_refreshes']} bank hot-swaps")
            if args.spec_decode:
                print(f"  spec decode (k={sc.spec_k}): "
                      f"{stats['accepted_tokens']}/{stats['drafted_tokens']} "
                      f"drafted tokens accepted "
                      f"({stats['acceptance_rate']:.0%}) over "
                      f"{stats['verify_dispatches']} verify dispatches; "
                      f"{stats['rollback_tokens']} tokens / "
                      f"{stats['rollback_blocks']} blocks rolled back")
            _print_class_stats(stats)
            if args.prefix_cache:
                print(f"  prefix cache (cold call): "
                      f"{stats['prefix_hit_tokens']}/"
                      f"{stats['prompt_tokens']} prompt tokens cached "
                      f"({stats['prefix_hit_rate']:.0%}); "
                      f"{stats['prefix_cached_blocks']} blocks retained")
                outs2 = eng.generate(reqs, sc)     # warm: prefixes re-match
                warm = eng.last_stats
                if sc.temperature == 0:            # bitwise claim is greedy-only
                    for a, b in zip(outs, outs2):
                        assert (np.asarray(a) == np.asarray(b)).all(), \
                            "warm cache diverged from cold run"
                print(f"  prefix cache (warm call): "
                      f"{warm['prefix_hit_tokens']}/"
                      f"{warm['prompt_tokens']} prompt tokens cached "
                      f"({warm['prefix_hit_rate']:.0%}); bitwise-equal, "
                      f"{warm['prefill_dispatches']} prefill dispatches vs "
                      f"{stats['prefill_dispatches']} cold")
            for r, o in list(zip(reqs, outs))[:args.tenants]:
                print(f"  {r.client_id} (S={len(r.prompt)}, "
                      f"budget={r.max_new_tokens}):", tok.decode(o)[:40])
            return
        reqs = [Request(f"client{b % args.tenants}", prompt)
                for b in range(args.batch)]
        t0 = time.time()
        out = eng.generate_fixed(reqs, sc)
        dt = time.time() - t0
        total = args.batch * args.new_tokens
        print(f"{args.tenants} tenants resident, mixed batch of {args.batch}: "
              f"{total} tokens in {dt:.2f}s ({total/dt:.1f} tok/s incl. compile)")
        for b in range(min(args.batch, args.tenants)):
            print(f"  {reqs[b].client_id}:",
                  tok.decode(np.asarray(out)[b])[:48])
        return

    adapters = None
    if args.adapters:
        adapters = load_checkpoint(args.adapters)
    elif args.dual:
        ad_p = init_adapters(jax.random.PRNGKey(1), cfg)
        ad_s = init_adapters(jax.random.PRNGKey(2), cfg)
        adapters = merge(ad_p, ad_s, jnp.array([0.6, 0.6]))

    eng = Engine(model, cfg, params, adapters)
    prompts = jnp.asarray(np.tile(prompt, (args.batch, 1)))
    t0 = time.time()
    out = eng.generate(prompts, sc)
    dt = time.time() - t0
    total = args.batch * args.new_tokens
    print(f"generated {total} tokens in {dt:.2f}s "
          f"({total/dt:.1f} tok/s incl. compile)")
    print("sample:", tok.decode(np.asarray(out)[0])[:60])


if __name__ == "__main__":
    main()
