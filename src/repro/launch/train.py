"""Training launcher: LoRA-SFT (paper-faithful inner loop) on a real mesh.

On TPU this runs the production mesh; on CPU it runs the local-device mesh
with the reduced configs — the same code path end to end (config, mesh,
pjit'd step, checkpointing, metrics).

    PYTHONPATH=src python -m repro.launch.train --arch yi-6b --smoke --steps 20
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import ALL_ARCHS, get_config
from repro.core.lora import adapter_specs, init_adapters
from repro.data.pipeline import SFTBatcher
from repro.data.synthetic import gen_log_dataset
from repro.data.tokenizer import ByteTokenizer
from repro.launch.mesh import make_host_mesh
from repro.models.api import get_model
from repro.training.checkpoint import save_checkpoint
from repro.training.optimizers import adamw, cosine_schedule
from repro.training.train_step import make_lora_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama2-7b", choices=ALL_ARCHS)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=160)
    ap.add_argument("--lr", type=float, default=2e-4)
    ap.add_argument("--ckpt", default="")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    if cfg.is_encdec or cfg.family == "vlm":
        print(f"note: {args.arch} needs modality inputs; feeding stub "
              "embeddings alongside synthetic text")
    model = get_model(cfg)
    mesh = make_host_mesh()
    print(f"mesh: {dict(zip(mesh.axis_names, mesh.devices.shape))}, "
          f"arch: {cfg.name} ({cfg.count_params()/1e6:.1f}M params, "
          f"LoRA {cfg.count_lora_params()/1e3:.1f}K)")

    params = model.init(jax.random.PRNGKey(0))
    adapters = init_adapters(jax.random.PRNGKey(1), cfg)
    opt = adamw(lr=args.lr, schedule=cosine_schedule(10, args.steps))
    state = opt.init(adapters)
    step = jax.jit(make_lora_train_step(model, cfg, opt))

    tok = ByteTokenizer()
    rng = np.random.default_rng(0)
    seq = min(args.seq, cfg.max_seq_len)
    batcher = SFTBatcher(gen_log_dataset(rng, 256, 0), tok, seq, args.batch)

    with jax.set_mesh(mesh):
        t0 = time.time()
        for i in range(args.steps):
            raw = batcher.sample()
            batch = {"tokens": jnp.asarray(raw["tokens"] % cfg.vocab_size),
                     "loss_mask": jnp.asarray(raw["loss_mask"])}
            if cfg.family == "vlm":
                batch["patch_embeds"] = jnp.zeros(
                    (args.batch, cfg.n_patch_tokens, cfg.d_model), jnp.float32)
            if cfg.is_encdec:
                batch["enc_embeds"] = jnp.zeros(
                    (args.batch, cfg.encoder_seq_len, cfg.d_model), jnp.float32)
            adapters, state, m = step(params, adapters, state, batch)
            if i % 10 == 0 or i == args.steps - 1:
                print(f"step {i:4d}  loss {float(m['loss']):.4f}  "
                      f"acc {float(m['accuracy']):.3f}  "
                      f"{(time.time()-t0)/(i+1):.2f}s/step")
    if args.ckpt:
        save_checkpoint(args.ckpt, adapters, {"arch": args.arch,
                                              "steps": args.steps})
        print("saved adapters to", args.ckpt)


if __name__ == "__main__":
    main()
