import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))
# ^ MUST run before any jax import: jax locks the device count at first init.
# Only the dry-run sees 512 placeholder devices; tests/benches see 1.
"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) combination
against the production mesh, and emit memory / cost / roofline artifacts.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch yi-6b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --arch yi-6b --shape train_4k --multi-pod
    PYTHONPATH=src python -m repro.launch.dryrun --arch llama2-7b --shape train_4k \
        --step fdlora_round --multi-pod     # the paper-technique lowering

Outputs JSON to experiments/dryrun/<arch>__<shape>__<mesh>__<step>[__<variant>].json
"""
import argparse
import json
import sys
import time
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.analysis import roofline as rl
from repro.configs.base import INPUT_SHAPES
from repro.configs.registry import (ALL_ARCHS, config_for_shape, get_shape,
                                    shape_supported)
from repro.core.lora import adapter_specs, init_adapters, lora_scale
from repro.launch import specs as sp
from repro.launch.mesh import make_production_mesh
from repro.models.api import get_model
from repro.training.optimizers import adamw, sgd
from repro.training.train_step import make_lora_train_step


def _shardings(mesh, spec_tree, shape_tree):
    """NamedShardings; axes that don't divide a dim are dropped (replicated)."""
    axis_size = dict(zip(mesh.axis_names, mesh.devices.shape))

    def fix(spec, sds):
        entries = list(spec) + [None] * (len(sds.shape) - len(tuple(spec)))
        out = []
        for dim, e in zip(sds.shape, entries):
            names = e if isinstance(e, tuple) else ((e,) if e else ())
            kept, prod = [], 1
            for n in names:
                if n in axis_size and dim % (prod * axis_size[n]) == 0:
                    kept.append(n)
                    prod *= axis_size[n]
            out.append(tuple(kept) if len(kept) > 1 else (kept[0] if kept else None))
        return NamedSharding(mesh, P(*out))

    return jax.tree.map(fix, spec_tree, shape_tree,
                        is_leaf=lambda s: isinstance(s, P))


def _opt_state_specs(adapter_spec):
    return {"mu": adapter_spec, "nu": adapter_spec, "count": P()}


def build_train(model, cfg, mesh, shape_name):
    """Paper-faithful train step: LoRA-only SFT (frozen base)."""
    opt = adamw(lr=2e-4)
    step = make_lora_train_step(model, cfg, opt)
    params_s = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    ad_s = jax.eval_shape(partial(init_adapters, cfg=cfg), jax.random.PRNGKey(0))
    opt_s = jax.eval_shape(opt.init, ad_s)
    batch_s = sp.train_inputs(cfg, shape_name)

    pspec = model.param_specs()
    adspec = adapter_specs(cfg)
    in_shardings = (
        _shardings(mesh, pspec, params_s),
        _shardings(mesh, adspec, ad_s),
        _shardings(mesh, _opt_state_specs(adspec), opt_s),
        _shardings(mesh, sp.train_input_specs(cfg, mesh, shape_name), batch_s),
    )
    out_shardings = (in_shardings[1], in_shardings[2], None)
    jitted = jax.jit(step, in_shardings=in_shardings, out_shardings=out_shardings)
    args = (params_s, ad_s, opt_s, batch_s)
    tokens = batch_s["tokens"].shape[0] * batch_s["tokens"].shape[1]
    return jitted, args, rl.model_flops_train(cfg, tokens)


def build_prefill(model, cfg, mesh, shape_name):
    """Inference prefill: full forward, unembed last position only."""
    scale = lora_scale(cfg)

    def step(params, adapters, batch):
        return model.forward(params, batch, adapters=adapters,
                             lora_scale=scale, last_only=not cfg.is_encdec)[0]

    params_s = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    ad_s = jax.eval_shape(partial(init_adapters, cfg=cfg), jax.random.PRNGKey(0))
    batch_s = sp.train_inputs(cfg, shape_name)
    batch_s.pop("loss_mask")
    in_shardings = (
        _shardings(mesh, model.param_specs(), params_s),
        _shardings(mesh, adapter_specs(cfg), ad_s),
        _shardings(mesh, {k: v for k, v in
                          sp.train_input_specs(cfg, mesh, shape_name).items()
                          if k != "loss_mask"}, batch_s),
    )
    jitted = jax.jit(step, in_shardings=in_shardings)
    tokens = batch_s["tokens"].shape[0] * batch_s["tokens"].shape[1]
    return jitted, (params_s, ad_s, batch_s), rl.model_flops_decode(cfg, tokens)


def _serve2d(spec_tree, shape_tree, mesh):
    """§Perf serving iteration: 1-D ("model"-only) weight sharding leaves
    the data axis idle at decode, so big models replicate 16× and blow HBM
    (kimi decode: 187 GiB/dev). Shard the first large unsharded dim of every
    weight over "data" as well (2-D weight sharding, standard for
    inference)."""
    data = dict(zip(mesh.axis_names, mesh.devices.shape)).get("data", 1)

    def fix(spec, sds):
        entries = list(spec) + [None] * (len(sds.shape) - len(tuple(spec)))
        used = {n for e in entries for n in
                (e if isinstance(e, tuple) else (e,)) if n}
        if "data" in used or len(sds.shape) < 2:
            return P(*entries)
        for i, (dim, e) in enumerate(zip(sds.shape, entries)):
            if e is None and dim >= 256 and dim % data == 0:
                entries[i] = "data"
                break
        return P(*entries)

    return jax.tree.map(fix, spec_tree, shape_tree,
                        is_leaf=lambda s: isinstance(s, P))


def build_decode(model, cfg, mesh, shape_name):
    """Serve step: ONE new token against a seq_len cache/state."""
    sh = get_shape(shape_name)
    scale = lora_scale(cfg)

    def step(params, adapters, cache, tokens, pos):
        return model.decode_step(params, cache, tokens, pos, adapters=adapters,
                                 lora_scale=scale)

    params_s = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    ad_s = jax.eval_shape(partial(init_adapters, cfg=cfg), jax.random.PRNGKey(0))
    cache_s = jax.eval_shape(
        partial(model.init_decode_cache, sh.global_batch, sh.seq_len))
    dec_in = sp.decode_inputs(cfg, shape_name)
    cache_spec = model.decode_cache_specs()
    pspec = model.param_specs()
    if globals().get("_SERVE2D"):
        pspec = _serve2d(pspec, params_s, mesh)
    in_shardings = (
        _shardings(mesh, pspec, params_s),
        _shardings(mesh, adapter_specs(cfg), ad_s),
        _shardings(mesh, cache_spec, cache_s),
        _shardings(mesh, sp.decode_input_specs(cfg, mesh, shape_name), dec_in),
    )
    jitted = jax.jit(step, in_shardings=(in_shardings[0], in_shardings[1],
                                         in_shardings[2],
                                         in_shardings[3]["tokens"],
                                         in_shardings[3]["pos"]))
    args = (params_s, ad_s, cache_s, dec_in["tokens"], dec_in["pos"])
    return jitted, args, rl.model_flops_decode(cfg, sh.global_batch)


def build_fdlora_round(model, cfg, mesh, shape_name, n_clients=2, K=3):
    """The paper's technique as one lowered program: K inner steps per client
    (clients on the pod axis) + the single cross-pod outer aggregation."""
    from repro.core.outer_opt import make_outer_optimizer
    from repro.federated.distributed import (client_stacked_specs,
                                             make_fdlora_round_step)
    sh = get_shape(shape_name)
    inner = adamw(lr=2e-4)
    outer = make_outer_optimizer("nesterov", 1e-3, 0.5)
    round_step = make_fdlora_round_step(
        model, cfg, inner, outer, K,
        compress_outer=globals().get("_FDLORA_COMPRESS", "none"))

    params_s = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    theta_s = jax.eval_shape(partial(init_adapters, cfg=cfg), jax.random.PRNGKey(0))
    inner_st = jax.eval_shape(inner.init, theta_s)
    outer_st = jax.eval_shape(outer.init, theta_s)

    def stack(tree):
        return jax.tree.map(
            lambda s: jax.ShapeDtypeStruct((n_clients,) + s.shape, s.dtype), tree)

    state_s = {"inner_opt": stack(inner_st), "outer_opt": outer_st}
    B_local = sh.global_batch // n_clients
    batches_s = {
        "tokens": jax.ShapeDtypeStruct((n_clients, K, B_local, sh.seq_len), jnp.int32),
        "loss_mask": jax.ShapeDtypeStruct((n_clients, K, B_local, sh.seq_len), jnp.int32),
    }

    adspec = adapter_specs(cfg)
    stacked_adspec = client_stacked_specs(adspec)
    state_spec = {"inner_opt": {"mu": stacked_adspec, "nu": stacked_adspec,
                                "count": P("pod")},
                  "outer_opt": {"v": adspec}}
    batch_spec = {"tokens": P("pod", None, "data", None),
                  "loss_mask": P("pod", None, "data", None)}

    in_shardings = (
        _shardings(mesh, model.param_specs(), params_s),
        _shardings(mesh, adspec, theta_s),
        _shardings(mesh, state_spec, state_s),
        _shardings(mesh, batch_spec, batches_s),
    )
    jitted = jax.jit(round_step, in_shardings=in_shardings)
    args = (params_s, theta_s, state_s, batches_s)
    tokens = n_clients * K * B_local * sh.seq_len
    return jitted, args, rl.model_flops_train(cfg, tokens)


BUILDERS = {"train": build_train, "prefill": build_prefill,
            "decode": build_decode, "fdlora_round": build_fdlora_round}


def _reduced_cfg(cfg, n_periods: int):
    """Depth-reduced, fully-unrolled variant for exact cost extraction."""
    period = len(cfg.layer_pattern)
    kw = dict(n_layers=n_periods * period, scan_unroll=n_periods)
    if cfg.is_encdec:
        kw["n_encoder_layers"] = n_periods
        kw["n_layers"] = n_periods
    return cfg.with_overrides(**kw)


def _compile_once(cfg, mesh, shape_name, step):
    model = get_model(cfg)
    jitted, args, model_flops = BUILDERS[step](model, cfg, mesh, shape_name)
    lowered = jitted.lower(*args)
    compiled = lowered.compile()
    return compiled, model_flops


def cost_dict(compiled):
    """compiled.cost_analysis() as a dict: jaxlib <= 0.4.x wraps it in a
    one-element list, newer versions return the dict directly."""
    cost = compiled.cost_analysis()
    return cost[0] if isinstance(cost, (list, tuple)) else cost


def _extrapolated_cost(cfg, mesh, shape_name, step):
    """Exact flops/bytes/collectives via depth extrapolation.

    XLA's cost_analysis counts a while (scan) body ONCE, so the rolled
    production graph under-reports by ~n_layers. Fully unrolling the real
    depth is exact but compiles for minutes. Instead we compile 1-period and
    2-period *unrolled* variants (seconds each; every period is identical)
    and extrapolate: cost(P) = c1 + (P-1)·(c2 - c1). Embedding/unembedding
    and other depth-independent terms live in c1 and are counted once."""
    P = cfg.n_layers if cfg.is_encdec else cfg.n_periods
    if P == 1:
        c, _ = _compile_once(cfg.with_overrides(scan_unroll=1), mesh,
                             shape_name, step)
        cost = cost_dict(c)
        colls = rl.parse_collectives(c.as_text())
        return (float(cost.get("flops", 0)), float(cost.get("bytes accessed", 0)),
                sum(x.per_chip_bytes for x in colls), colls)
    # Two depths: auto-sharding makes the depth-independent part mildly
    # depth-dependent; a lever arm of >= 2 periods keeps that noise small
    # relative to the per-period cost (which dominates for train shapes).
    pa, pb = (1, 3) if P >= 3 else (1, P)
    out = []
    for p in (pa, pb):
        c, _ = _compile_once(_reduced_cfg(cfg, p), mesh, shape_name, step)
        cost = cost_dict(c)
        colls = rl.parse_collectives(c.as_text())
        out.append((float(cost.get("flops", 0)),
                    float(cost.get("bytes accessed", 0)),
                    sum(x.per_chip_bytes for x in colls), colls))
    (fa, ba, cba, colls_a), (fb, bb, cbb, _) = out

    def total(ca, cb):
        per = max((cb - ca) / (pb - pa), 0.0)
        return max(ca - pa * per, 0.0) + P * per

    return total(fa, fb), total(ba, bb), total(cba, cbb), colls_a


# §Perf hillclimb variants: config overrides applied on top of the baseline.
VARIANTS = {
    "baseline": {},
    "gqa_grouped": {"attn_impl": "grouped"},
    "sm_bf16": {"attn_softmax_dtype": "bfloat16"},
    "opt_attn": {"attn_impl": "grouped", "attn_softmax_dtype": "bfloat16"},
    "no_remat": {"remat": False},
    "remat_dots": {"remat_policy": "dots"},
    "moe_cap1": {"moe_capacity_factor": 1.0},
    "opt_moe": {"moe_capacity_factor": 1.0, "remat_policy": "dots"},
    # fdlora_round-only variant (handled in build_fdlora_round):
    "bf16_outer": {},
    "serve2d": {},
}


def run_one(arch: str, shape_name: str, multi_pod: bool, step: str = "auto",
            variant: str = "baseline", out_dir: str = "experiments/dryrun",
            dump_hlo: bool = False, smoke: bool = False,
            with_cost: bool = True):
    if not shape_supported(arch, shape_name):
        return {"arch": arch, "shape": shape_name, "skipped": True,
                "reason": "DESIGN.md §5: whisper decoder context is bounded"}
    cfg = config_for_shape(arch, shape_name, smoke=smoke)
    cfg = cfg.with_overrides(**VARIANTS.get(variant, {}))
    global _FDLORA_COMPRESS, _SERVE2D
    _FDLORA_COMPRESS = "bf16" if variant == "bf16_outer" else "none"
    _SERVE2D = variant == "serve2d"
    if step == "auto":
        step = INPUT_SHAPES[shape_name].kind
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.time()
    with jax.set_mesh(mesh):
        # 1) Full-depth rolled compile: proves the combination lowers on this
        #    mesh and yields the realistic per-device memory analysis.
        jitted_args = BUILDERS[step](get_model(cfg), cfg, mesh, shape_name)
        lowered = jitted_args[0].lower(*jitted_args[1])
        model_flops = jitted_args[2]
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
        hlo = compiled.as_text()
        # 2) Depth-extrapolated exact cost terms (single-pod roofline only;
        #    the multi-pod pass proves lowering + memory, per the task spec).
        if with_cost:
            flops, hbm, coll_bytes, colls = _extrapolated_cost(
                cfg, mesh, shape_name, step)
        else:
            flops = hbm = coll_bytes = 0.0
            colls = rl.parse_collectives(hlo)

    chips = mesh.devices.size
    roof = rl.analyze({"flops": flops, "bytes accessed": hbm}, "", chips,
                      model_flops)
    roof.collective_bytes = coll_bytes
    roof.collective_s = coll_bytes / rl.ICI_BW
    roof.n_collectives = len(colls)
    roof.coll_by_op = {}
    for c in colls:
        roof.coll_by_op[c.op] = roof.coll_by_op.get(c.op, 0.0) + c.per_chip_bytes
    roof.dominant = max((("compute", roof.compute_s), ("memory", roof.memory_s),
                         ("collective", roof.collective_s)),
                        key=lambda kv: kv[1])[0]
    result = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "step": step, "variant": variant, "chips": chips,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "params": cfg.count_params(),
        "active_params": cfg.count_active_params(),
        "lora_params": cfg.count_lora_params(),
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
        },
        "roofline": roof.to_dict(),
    }
    os.makedirs(out_dir, exist_ok=True)
    tag = f"{arch}__{shape_name}__{result['mesh']}__{step}"
    if variant != "baseline":
        tag += f"__{variant}"
    if smoke:
        tag += "__smoke"
    with open(os.path.join(out_dir, tag + ".json"), "w") as f:
        json.dump(result, f, indent=1)
    if dump_hlo:
        with open(os.path.join(out_dir, tag + ".hlo.txt"), "w") as f:
            f.write(hlo)
    return result


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=ALL_ARCHS + ["all"])
    ap.add_argument("--shape", required=True,
                    choices=list(INPUT_SHAPES) + ["all"])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--step", default="auto",
                    choices=["auto", "train", "prefill", "decode", "fdlora_round"])
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--out-dir", default="experiments/dryrun")
    ap.add_argument("--dump-hlo", action="store_true")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--no-cost", action="store_true",
                    help="lower+compile+memory only (multi-pod sweeps)")
    ap.add_argument("--skip-existing", action="store_true",
                    help="skip combos whose JSON artifact already exists")
    args = ap.parse_args(argv)

    archs = ALL_ARCHS if args.arch == "all" else [args.arch]
    shapes = list(INPUT_SHAPES) if args.shape == "all" else [args.shape]
    failures = []
    for arch in archs:
        for shape in shapes:
            mesh_tag = "2x16x16" if args.multi_pod else "16x16"
            step_tag = args.step if args.step != "auto" else INPUT_SHAPES[shape].kind
            tag = f"{arch}__{shape}__{mesh_tag}__{step_tag}"
            if args.variant != "baseline":
                tag += f"__{args.variant}"
            if args.skip_existing and os.path.exists(
                    os.path.join(args.out_dir, tag + ".json")):
                print(f"SKIP-EXISTING {arch} {shape}")
                continue
            try:
                r = run_one(arch, shape, args.multi_pod, args.step,
                            args.variant, args.out_dir, args.dump_hlo,
                            args.smoke, with_cost=not args.no_cost)
            except Exception as e:  # keep sweeping; report at the end
                failures.append((arch, shape, repr(e)[:300]))
                print(f"FAIL {arch} {shape}: {repr(e)[:300]}")
                sys.stdout.flush()
                continue
            if r.get("skipped"):
                print(f"SKIP {arch} {shape}: {r['reason']}")
                continue
            roof = r["roofline"]
            print(f"OK {arch} {shape} {r['mesh']} {r['step']} "
                  f"compile={r['compile_s']}s "
                  f"compute={roof['compute_s']:.4f}s "
                  f"memory={roof['memory_s']:.4f}s "
                  f"coll={roof['collective_s']:.4f}s "
                  f"dom={roof['dominant']} useful={roof['useful_ratio']:.2f}")
            sys.stdout.flush()
    if failures:
        print(f"{len(failures)} FAILURES:")
        for a, s, e in failures:
            print(" ", a, s, e)
        sys.exit(1)


if __name__ == "__main__":
    main()
