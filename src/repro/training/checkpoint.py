"""Checkpointing: nested pytrees <-> flat .npz archives.

No orbax offline; npz round-trips every dtype we use (bf16 stored via
uint16 view). Layout: keys are '/'-joined tree paths; a sidecar JSON holds
dtypes and the tree structure for exact restoration.
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict

import jax
import jax.numpy as jnp
import numpy as np

Params = Any


def _flatten(tree, prefix="") -> Dict[str, Any]:
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            out.update(_flatten(v, f"{prefix}{k}/"))
    elif tree is None:
        pass
    else:
        out[prefix[:-1]] = tree
    return out


def _unflatten(flat: Dict[str, Any]) -> Params:
    tree: Dict[str, Any] = {}
    for key, val in flat.items():
        node = tree
        parts = key.split("/")
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = val
    return tree


def save_checkpoint(path: str, tree: Params, metadata: Dict = None) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = _flatten(jax.device_get(tree))
    arrays, dtypes = {}, {}
    for k, v in flat.items():
        v = np.asarray(v)
        dtypes[k] = str(v.dtype)
        if v.dtype == jnp.bfloat16:
            v = v.view(np.uint16)
        arrays[k.replace("/", "\x1f")] = v
    np.savez(path, **arrays)
    with open(path + ".meta.json", "w") as f:
        json.dump({"dtypes": dtypes, "metadata": metadata or {}}, f)


def load_checkpoint(path: str) -> Params:
    with open(path + ".meta.json") as f:
        meta = json.load(f)
    data = np.load(path if path.endswith(".npz") else path + ".npz")
    flat = {}
    for key in data.files:
        k = key.replace("\x1f", "/")
        v = data[key]
        if meta["dtypes"][k] == "bfloat16":
            v = v.view(jnp.bfloat16)
        flat[k] = jnp.asarray(v)
    return _unflatten(flat)
