"""Train/eval step factories.

Three step flavours:
  * full fine-tuning        (baseline; optimizer over all params)
  * LoRA-only SFT           (the paper's setting: base frozen, adapters train)
  * dual-LoRA fused eval    (AdaFusion objective evaluation)

Steps are pure functions suitable for jit/pjit; the federated layer composes
them (inner steps) with outer optimization at the adapter-tree level.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.lora import lora_scale as _lora_scale
from repro.training.optimizers import Optimizer, apply_updates, clip_by_global_norm

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------

def _shift_for_family(cfg, logits: jnp.ndarray, batch: Dict[str, jnp.ndarray]):
    """Return (logits_t, targets, mask) aligned for next-token prediction."""
    tokens = batch["tokens"]
    if cfg.family == "vlm":
        Pn = cfg.n_patch_tokens
        lg = logits[:, Pn:Pn + tokens.shape[1] - 1]
    else:
        lg = logits[:, :-1]
    tg = tokens[:, 1:]
    mask = batch.get("loss_mask")
    mask = (mask[:, 1:] if mask is not None else jnp.ones_like(tg)).astype(jnp.float32)
    mask = mask * (tg >= 0)
    return lg, jnp.maximum(tg, 0), mask


def cross_entropy(cfg, logits: jnp.ndarray, batch) -> Tuple[jnp.ndarray, Dict]:
    lg, tg, mask = _shift_for_family(cfg, logits, batch)
    logp = jax.nn.log_softmax(lg.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, tg[..., None], axis=-1)[..., 0]
    denom = jnp.maximum(mask.sum(), 1.0)
    loss = (nll * mask).sum() / denom
    acc = ((jnp.argmax(lg, -1) == tg) * mask).sum() / denom
    return loss, {"loss": loss, "accuracy": acc, "tokens": denom}


# ---------------------------------------------------------------------------
# LoRA-only SFT step (paper-faithful inner step)
# ---------------------------------------------------------------------------

def make_lora_loss_fn(model, cfg) -> Callable:
    scale = _lora_scale(cfg)

    def loss_fn(adapters: Params, params: Params, batch) -> Tuple[jnp.ndarray, Dict]:
        logits, aux = model.forward(params, batch, adapters=adapters,
                                    lora_scale=scale)
        loss, metrics = cross_entropy(cfg, logits, batch)
        total = loss + cfg.router_aux_loss_coef * aux
        metrics = dict(metrics, aux_loss=aux)
        return total, metrics

    return loss_fn


def make_lora_train_step(model, cfg, opt: Optimizer,
                         clip_norm: float = 1.0) -> Callable:
    """step(params, adapters, opt_state, batch) -> (adapters, opt_state, metrics).

    ``params`` (the frozen base) receives no gradient — it is a closed-over
    operand, which under pjit means zero optimizer/grad memory for the base.
    """
    loss_fn = make_lora_loss_fn(model, cfg)

    def step(params, adapters, opt_state, batch):
        (_, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            adapters, params, batch)
        if clip_norm:
            grads = clip_by_global_norm(grads, clip_norm)
        updates, opt_state = opt.update(grads, opt_state, adapters)
        adapters = apply_updates(adapters, updates)
        return adapters, opt_state, metrics

    return step


# ---------------------------------------------------------------------------
# Full fine-tuning step (cost/ablation baseline)
# ---------------------------------------------------------------------------

def make_full_train_step(model, cfg, opt: Optimizer,
                         clip_norm: float = 1.0) -> Callable:
    def loss_fn(params, batch):
        logits, aux = model.forward(params, batch)
        loss, metrics = cross_entropy(cfg, logits, batch)
        return loss + cfg.router_aux_loss_coef * aux, metrics

    def step(params, opt_state, batch):
        (_, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
        if clip_norm:
            grads = clip_by_global_norm(grads, clip_norm)
        updates, opt_state = opt.update(grads, opt_state, params)
        params = apply_updates(params, updates)
        return params, opt_state, metrics

    return step


# ---------------------------------------------------------------------------
# Evaluation
# ---------------------------------------------------------------------------

def make_eval_fn(model, cfg) -> Callable:
    """eval(params, adapters, batch) -> metrics (jit-able)."""
    scale = _lora_scale(cfg)

    def evaluate(params, adapters, batch):
        logits, _ = model.forward(params, batch, adapters=adapters,
                                  lora_scale=scale)
        _, metrics = cross_entropy(cfg, logits, batch)
        return metrics

    return evaluate


def make_fused_eval_fn(model, cfg) -> Callable:
    """eval(params, ad_p, ad_s, w, batch) -> CE loss — the AdaFusion objective
    (Eq. 8 without the L1 term, which the black-box wrapper adds)."""
    from repro.core.dual_lora import merge
    scale = _lora_scale(cfg)

    def evaluate(params, ad_p, ad_s, w, batch):
        fused = merge(ad_p, ad_s, w)
        logits, _ = model.forward(params, batch, adapters=fused,
                                  lora_scale=scale)
        loss, metrics = cross_entropy(cfg, logits, batch)
        return loss, metrics

    return evaluate
