"""Optimizers (no optax dependency): AdamW, SGD(+Nesterov), schedules.

All optimizers are pure pytree transforms:
    state = opt.init(params)
    updates, state = opt.update(grads, state, params)
    params = apply_updates(params, updates)
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

Params = Any


class Optimizer(NamedTuple):
    init: Callable[[Params], Any]
    update: Callable[[Params, Any, Optional[Params]], Tuple[Params, Any]]


def apply_updates(params: Params, updates: Params) -> Params:
    return jax.tree.map(lambda p, u: (p + u).astype(p.dtype), params, updates)


# ---------------------------------------------------------------------------
# AdamW — the paper's InnerOpt (PagedAdamW32bit on GPU; paging is a CUDA
# memory workaround, plain fp32-state AdamW is the TPU equivalent).
# ---------------------------------------------------------------------------

def adamw(lr: float = 2e-4, b1: float = 0.9, b2: float = 0.999,
          eps: float = 1e-8, weight_decay: float = 0.01,
          schedule: Optional[Callable[[jnp.ndarray], jnp.ndarray]] = None
          ) -> Optimizer:
    def init(params):
        return {
            "mu": jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params),
            "nu": jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params),
            "count": jnp.zeros((), jnp.int32),
        }

    def update(grads, state, params=None):
        count = state["count"] + 1
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
                          state["mu"], grads)
        nu = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)),
                          state["nu"], grads)
        c1 = 1 - b1 ** count.astype(jnp.float32)
        c2 = 1 - b2 ** count.astype(jnp.float32)
        step_lr = lr * (schedule(count) if schedule is not None else 1.0)

        def upd(m, v, p):
            mhat = m / c1
            vhat = v / c2
            u = -step_lr * (mhat / (jnp.sqrt(vhat) + eps)
                            + weight_decay * p.astype(jnp.float32))
            return u.astype(jnp.float32)

        updates = jax.tree.map(upd, mu, nu, params)
        return updates, {"mu": mu, "nu": nu, "count": count}

    return Optimizer(init, update)


# ---------------------------------------------------------------------------
# SGD with (Nesterov) momentum — the paper's OuterOpt (Sutskever et al.),
# also the inner optimizer of the "large-batch DP" degenerate case.
# ---------------------------------------------------------------------------

def sgd(lr: float = 1e-3, momentum: float = 0.0, nesterov: bool = False
        ) -> Optimizer:
    def init(params):
        if momentum == 0.0:
            return {}
        return {"v": jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)}

    def update(grads, state, params=None):
        if momentum == 0.0:
            return jax.tree.map(lambda g: -lr * g.astype(jnp.float32), grads), state
        v = jax.tree.map(lambda v, g: momentum * v + g.astype(jnp.float32),
                         state["v"], grads)
        if nesterov:
            updates = jax.tree.map(lambda g, vn: -lr * (g.astype(jnp.float32)
                                                        + momentum * vn), grads, v)
        else:
            updates = jax.tree.map(lambda vn: -lr * vn, v)
        return updates, {"v": v}

    return Optimizer(init, update)


# ---------------------------------------------------------------------------
# Gradient utilities
# ---------------------------------------------------------------------------

def clip_by_global_norm(grads: Params, max_norm: float) -> Params:
    norm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(grads)))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale, grads)


def cosine_schedule(warmup: int, total: int, floor: float = 0.1):
    def fn(count):
        c = count.astype(jnp.float32)
        warm = c / jnp.maximum(warmup, 1)
        prog = jnp.clip((c - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
        cos = floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return jnp.where(c < warmup, warm, cos)
    return fn
