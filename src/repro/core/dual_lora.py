"""Dual-LoRA state and the Eq. 7 adaptive merge.

Each FDLoRA client holds two adapter trees over the same frozen base:
  * ``personalized`` (θ_p) — never leaves the client,
  * ``global_`` (θ_s)      — the only federated state.

AdaFusion (paper §3.5) combines them *per low-rank factor*:

    m̂ = (w1·A1 + w2·A2) @ (w1·B1 + w2·B2)                          (Eq. 7)

which requires equal rank (asserted) and yields a single standard adapter —
so the fused model runs through the exact same forward path (and the same
Pallas kernels) as a single-LoRA model.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp

Params = Dict[str, Any]


@dataclasses.dataclass
class DualLoRAState:
    personalized: Params
    global_: Params
    fusion_weights: jnp.ndarray  # (2,) = [w1 (personalized), w2 (global)]

    def replace(self, **kw) -> "DualLoRAState":
        return dataclasses.replace(self, **kw)


def check_same_rank(ad1: Params, ad2: Params) -> None:
    r1 = {p.shape[-1] for p in _a_leaves(ad1)}
    r2 = {p.shape[-1] for p in _a_leaves(ad2)}
    if r1 != r2:
        raise ValueError(f"AdaFusion requires equal LoRA rank, got {r1} vs {r2}")


def check_rank_agreement(personalized: Params, global_: Params) -> None:
    """Per-target rank check for Eq. 7, naming the offending leaf.

    ``merge`` is a plain ``jax.tree.map``: feeding it personalized/global
    trees whose ranks disagree at some target either dies with an opaque
    broadcast error or — worse, when one rank divides the other — silently
    broadcasts into garbage factors.  Walk both trees together and fail
    fast at the first ``{"a", "b"}`` pair whose ranks differ."""
    def walk(p, g, path):
        if isinstance(p, dict) and set(p) == {"a", "b"} \
                and isinstance(g, dict) and set(g) == {"a", "b"}:
            rp, rg = p["a"].shape[-1], g["a"].shape[-1]
            if rp != rg:
                raise ValueError(
                    f"AdaFusion (Eq. 7) requires equal LoRA rank per target; "
                    f"leaf {path or '<root>'} has personalized rank {rp} vs "
                    f"global rank {rg}")
            return
        if isinstance(p, dict) and isinstance(g, dict):
            for k in p:
                if k in g:
                    walk(p[k], g[k], f"{path}[{k!r}]")
    walk(personalized, global_, "")


def _a_leaves(tree):
    out = []

    def walk(t):
        if isinstance(t, dict) and set(t.keys()) == {"a", "b"}:
            out.append(t["a"])
        elif isinstance(t, dict):
            for v in t.values():
                walk(v)
    walk(tree)
    return out


def merge(personalized: Params, global_: Params, w) -> Params:
    """Eq. 7: element-wise weighted merge of the low-rank factors.

    ``w`` is a length-2 array-like [w1, w2]; works under jit/grad (weights
    may be traced).
    """
    w1, w2 = w[0], w[1]
    return jax.tree.map(lambda p, g: w1 * p + w2 * g, personalized, global_)


def fused_forward(model, params: Params, batch, state: DualLoRAState,
                  lora_scale: float):
    """Forward pass through base + AdaFusion-merged dual adapters."""
    fused = merge(state.personalized, state.global_, state.fusion_weights)
    return model.forward(params, batch, adapters=fused, lora_scale=lora_scale)
