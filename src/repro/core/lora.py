"""LoRA adapters (Hu et al. 2021) as parallel parameter trees.

An *adapter tree* mirrors the model's block structure and holds, at each
targeted linear, a dict ``{"a": A, "b": B}`` with ``A: (n_periods, d_in, r)``
and ``B: (n_periods, r, d_out)`` (period-stacked to ride the same ``lax.scan``
as the base parameters). ``B`` is zero-initialised so training starts at the
base model (standard LoRA init); ``A`` is Kaiming-normal.

The effective update is ``ΔW = (alpha/r) · A @ B`` applied additively inside
``layers.dense`` — base weights stay frozen (bf16), adapters train in fp32.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

Params = Dict[str, Any]

# map: mixer/mlp kind -> {target name: (d_in_fn, d_out_fn)}  (fns of cfg)


def _attn_targets(cfg):
    d, H, Kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    return {"wq": (d, H * hd), "wk": (d, Kv * hd), "wv": (d, Kv * hd),
            "wo": (H * hd, d)}


def _mlp_targets(cfg, ff=None):
    d = cfg.d_model
    ff = ff or cfg.d_ff
    t = {"w_up": (d, ff), "w_out": (ff, d)}
    if cfg.mlp_type in ("swiglu", "geglu"):
        t["w_gate"] = (d, ff)
    return t


def _mamba_targets(cfg):
    from repro.models.mamba2 import _dims
    d_in, n_h, d_st, n_g, conv_dim, proj_dim = _dims(cfg)
    return {"in_proj": (cfg.d_model, proj_dim), "out_proj": (d_in, cfg.d_model)}


def _moe_targets(cfg):
    # Only the router gets an adapter (per-expert adapters would defeat PEFT;
    # see DESIGN.md §5). Configurable via lora_targets containing "experts".
    return {"router": (cfg.d_model, cfg.n_experts)}


def block_target_shapes(entry: str, cfg) -> Dict[str, Dict[str, Tuple[int, int]]]:
    """Targets for one pattern entry, filtered by cfg.lora_targets."""
    mixer, _, mlp = entry.partition("+")
    out: Dict[str, Dict[str, Tuple[int, int]]] = {}
    sel = set(cfg.lora_targets)
    if mixer == "attn":
        t = {k: v for k, v in _attn_targets(cfg).items() if k in sel}
    else:
        # SSM blocks: adapt the in/out projections (DESIGN.md §5).
        t = _mamba_targets(cfg)
    if t:
        out["mixer"] = t
    if mlp == "mlp":
        t = {k: v for k, v in _mlp_targets(cfg).items() if k in sel}
        if t:
            out["mlp"] = t
    elif mlp == "moe":
        out["mlp"] = _moe_targets(cfg)
    return out


def lora_target_shapes(cfg) -> List[Tuple[int, int]]:
    """All (d_in, d_out) pairs across the full depth (for param counting)."""
    shapes: List[Tuple[int, int]] = []
    if cfg.is_encdec:
        at = {k: v for k, v in _attn_targets(cfg).items() if k in set(cfg.lora_targets)}
        mt = {k: v for k, v in _mlp_targets(cfg).items() if k in set(cfg.lora_targets)}
        shapes += list(at.values()) * (cfg.n_encoder_layers + 2 * cfg.n_layers)
        shapes += list(mt.values()) * (cfg.n_encoder_layers + cfg.n_layers)
        return shapes
    for i in range(cfg.n_layers):
        entry = cfg.layer_pattern[i % len(cfg.layer_pattern)]
        for sub in block_target_shapes(entry, cfg).values():
            shapes += list(sub.values())
    return shapes


# ---------------------------------------------------------------------------
# Init / specs
# ---------------------------------------------------------------------------

def _init_pair(key, d_in: int, d_out: int, rank: int, stack: int):
    a = jax.random.normal(key, (stack, d_in, rank), dtype=jnp.float32) * (1.0 / rank)
    b = jnp.zeros((stack, rank, d_out), dtype=jnp.float32)
    return {"a": a, "b": b}


def init_adapters(rng, cfg, rank: Optional[int] = None) -> Params:
    """Build a zero-effect adapter tree for the given architecture."""
    r = rank or cfg.lora_rank
    if cfg.is_encdec:
        return _init_encdec_adapters(rng, cfg, r)
    tree: Params = {"blocks": {}}
    keys = jax.random.split(rng, len(cfg.layer_pattern))
    for key, (i, entry) in zip(keys, enumerate(cfg.layer_pattern)):
        name = f"b{i}"
        targets = block_target_shapes(entry, cfg)
        sub: Params = {}
        n_leaf = sum(len(v) for v in targets.values()) or 1
        lkeys = iter(jax.random.split(key, n_leaf))
        for part, tmap in targets.items():
            sub[part] = {t: _init_pair(next(lkeys), din, dout, r, cfg.n_periods)
                         for t, (din, dout) in tmap.items()}
        if sub:
            tree["blocks"][name] = sub
    return tree


def _init_encdec_adapters(rng, cfg, r) -> Params:
    sel = set(cfg.lora_targets)
    at = {k: v for k, v in _attn_targets(cfg).items() if k in sel}
    mt = {k: v for k, v in _mlp_targets(cfg).items() if k in sel}
    k1, k2, k3, k4, k5 = jax.random.split(rng, 5)

    def pairs(key, tmap, stack):
        ks = iter(jax.random.split(key, max(len(tmap), 1)))
        return {t: _init_pair(next(ks), din, dout, r, stack)
                for t, (din, dout) in tmap.items()}

    return {
        "enc_blocks": {"self_attn": pairs(k1, at, cfg.n_encoder_layers),
                       "mlp": pairs(k2, mt, cfg.n_encoder_layers)},
        "dec_blocks": {"self_attn": pairs(k3, at, cfg.n_layers),
                       "cross_attn": pairs(k4, at, cfg.n_layers),
                       "mlp": pairs(k5, mt, cfg.n_layers)},
    }


def adapter_specs(cfg, base_specs: Optional[Params] = None) -> Params:
    """PartitionSpecs for an adapter tree.

    Rule: A inherits the base weight's *input-dim* sharding on dim 1, B
    inherits the *output-dim* sharding on dim 2; the rank dim is never
    sharded (r ≪ 128 tile granularity).  Our base layout keeps d_model
    replicated and shards head/ff output dims on `model`, so: A is fully
    replicated unless the base input dim is sharded (wo / w_out), and B's
    output dim is sharded when the base output dim is (wq/wk/wv/w_up/w_gate).
    """
    sharded_out = {"wq", "wk", "wv", "w_up", "w_gate", "in_proj"}
    sharded_in = {"wo", "w_out", "out_proj"}

    def leaf_spec(name):
        a = P(None, None, None)
        b = P(None, None, None)
        if name in sharded_out:
            b = P(None, None, "model")
        if name in sharded_in:
            a = P(None, "model", None)
        return {"a": a, "b": b}

    def walk(tree):
        out = {}
        for k, v in tree.items():
            if isinstance(v, dict) and set(v.keys()) == {"a", "b"}:
                out[k] = leaf_spec(k)
            else:
                out[k] = walk(v)
        return out

    example = jax.eval_shape(lambda: init_adapters(jax.random.PRNGKey(0), cfg))
    return walk(example)


def lora_scale(cfg, rank: Optional[int] = None) -> float:
    return cfg.lora_alpha / float(rank or cfg.lora_rank)


# ---------------------------------------------------------------------------
# Tree arithmetic (used by the federated optimizers and fusion)
# ---------------------------------------------------------------------------

def tree_add(a, b):
    return jax.tree.map(jnp.add, a, b)


def tree_sub(a, b):
    return jax.tree.map(jnp.subtract, a, b)


def tree_scale(a, s):
    return jax.tree.map(lambda x: x * s, a)


def tree_mean(trees):
    n = len(trees)
    acc = trees[0]
    for t in trees[1:]:
        acc = tree_add(acc, t)
    return tree_scale(acc, 1.0 / n)


def tree_zeros_like(a):
    return jax.tree.map(jnp.zeros_like, a)


def tree_dot(a, b):
    leaves = jax.tree.leaves(jax.tree.map(lambda x, y: jnp.vdot(x, y), a, b))
    return sum(leaves)


def tree_norm(a):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x)) for x in jax.tree.leaves(a)))
