"""AdaFusion: gradient-free optimization of the dual-LoRA fusion weights.

Paper §3.5 / Eq. 8: find w = (w1, w2) minimizing few-shot cross-entropy plus
an L1 penalty, **without** building a hypernetwork or backprop graph — the
search space is 2 scalars per client, so black-box search is cheap (the paper
follows LoRAHub's gradient-free approach; default budget = 5 optimization
steps as in the paper's setup).

Implemented methods:
  * ``es``           — small (μ,λ) evolution strategy with step-size decay
                       (the CMA-ES-style default, matching LoRAHub's choice)
  * ``spsa``         — simultaneous-perturbation stochastic approximation
  * ``nelder_mead``  — deterministic 2-simplex
  * ``random``/``average``/``sum`` — the paper's RQ7 baselines
"""
from __future__ import annotations

from typing import Callable, Dict, List, Tuple

import numpy as np

EvalFn = Callable[[np.ndarray], float]  # w (2,) -> few-shot CE loss


def _penalized(eval_loss: EvalFn, lam: float) -> EvalFn:
    def fn(w):
        return float(eval_loss(np.asarray(w, np.float32))) + lam * float(np.abs(w).sum())
    return fn


def adafusion(eval_loss: EvalFn, *, method: str = "es", steps: int = 5,
              population: int = 8, lam: float = 0.05, seed: int = 0,
              w0=(0.5, 0.5)) -> Tuple[np.ndarray, Dict]:
    """Returns (w_opt (2,), info dict with history)."""
    rng = np.random.default_rng(seed)
    f = _penalized(eval_loss, lam)
    w0 = np.asarray(w0, np.float32)

    if method == "average":
        w = np.array([0.5, 0.5], np.float32)
        return w, {"history": [f(w)], "evals": 1}
    if method == "sum":
        w = np.array([1.0, 1.0], np.float32)
        return w, {"history": [f(w)], "evals": 1}
    if method == "random":
        w = rng.uniform(0.0, 1.0, size=2).astype(np.float32)
        return w, {"history": [f(w)], "evals": 1}
    if method == "es":
        return _es(f, w0, rng, steps, population)
    if method == "spsa":
        return _spsa(f, w0, rng, steps)
    if method == "nelder_mead":
        return _nelder_mead(f, w0, steps)
    raise ValueError(method)


def _es(f, w0, rng, steps, population):
    """(μ,λ)-ES with recombination and exponential step-size decay."""
    mean = w0.copy()
    sigma = 0.35
    mu = max(2, population // 2)
    best_w, best_v = mean.copy(), f(mean)
    history = [best_v]
    evals = 1
    for _ in range(steps):
        cand = mean[None] + sigma * rng.standard_normal((population, 2)).astype(np.float32)
        vals = np.array([f(c) for c in cand])
        evals += population
        elite = cand[np.argsort(vals)[:mu]]
        mean = elite.mean(axis=0)
        sigma *= 0.8
        i = int(np.argmin(vals))
        if vals[i] < best_v:
            best_v, best_w = float(vals[i]), cand[i].copy()
        history.append(best_v)
    return best_w.astype(np.float32), {"history": history, "evals": evals}


def _spsa(f, w0, rng, steps, a0=0.25, c0=0.15):
    w = w0.copy()
    best_w, best_v = w.copy(), f(w)
    history = [best_v]
    evals = 1
    for k in range(steps):
        ak = a0 / (k + 1) ** 0.602
        ck = c0 / (k + 1) ** 0.101
        delta = rng.choice([-1.0, 1.0], size=2).astype(np.float32)
        vp, vm = f(w + ck * delta), f(w - ck * delta)
        evals += 2
        ghat = (vp - vm) / (2 * ck) * delta  # elementwise: delta_i^{-1}=delta_i for ±1
        w = w - ak * ghat
        v = f(w)
        evals += 1
        if v < best_v:
            best_v, best_w = v, w.copy()
        history.append(best_v)
    return best_w.astype(np.float32), {"history": history, "evals": evals}


def _nelder_mead(f, w0, steps, init_step=0.3):
    simplex = [w0.copy(), w0 + np.array([init_step, 0], np.float32),
               w0 + np.array([0, init_step], np.float32)]
    vals = [f(p) for p in simplex]
    evals = 3
    history = [min(vals)]
    for _ in range(steps):
        order = np.argsort(vals)
        simplex = [simplex[i] for i in order]
        vals = [vals[i] for i in order]
        centroid = (simplex[0] + simplex[1]) / 2
        # reflect
        xr = centroid + (centroid - simplex[2])
        fr = f(xr); evals += 1
        if fr < vals[0]:
            xe = centroid + 2 * (centroid - simplex[2])
            fe = f(xe); evals += 1
            simplex[2], vals[2] = (xe, fe) if fe < fr else (xr, fr)
        elif fr < vals[1]:
            simplex[2], vals[2] = xr, fr
        else:
            xc = centroid + 0.5 * (simplex[2] - centroid)
            fc = f(xc); evals += 1
            if fc < vals[2]:
                simplex[2], vals[2] = xc, fc
            else:  # shrink
                for i in (1, 2):
                    simplex[i] = simplex[0] + 0.5 * (simplex[i] - simplex[0])
                    vals[i] = f(simplex[i]); evals += 1
        history.append(min(vals))
    i = int(np.argmin(vals))
    return simplex[i].astype(np.float32), {"history": history, "evals": evals}
