"""Outer (server-side) optimization for the federated stage.

Algorithm 1, lines 17–18: the server averages client pseudo-gradients
``Δ^(t) = (1/N) Σ_i (θ_s^(t-1) − θ_s^(i)(t))`` and applies OuterOpt.

The paper uses Nesterov momentum (best convergence per DiLoCo); OuterOpt=SGD
with lr=1 recovers vanilla FedAvg, and T=1 recovers model souping — both
degenerate cases are exposed here and exercised by tests.
"""
from __future__ import annotations

from typing import Any, List, Sequence

import jax

from repro.core.lora import tree_mean, tree_sub
from repro.training.optimizers import Optimizer, apply_updates, sgd

Params = Any


def pseudo_gradient(theta_prev: Params, client_thetas: Sequence[Params]) -> Params:
    """Δ = mean_i (θ_prev − θ_i). Points *from* the clients' average."""
    avg = tree_mean(list(client_thetas))
    return tree_sub(theta_prev, avg)


def make_outer_optimizer(kind: str = "nesterov", lr: float = 1e-3,
                         momentum: float = 0.5) -> Optimizer:
    if kind == "nesterov":
        return sgd(lr=lr, momentum=momentum, nesterov=True)
    if kind == "sgd":
        return sgd(lr=lr, momentum=0.0)
    if kind == "fedavg":
        # θ ← θ − 1·Δ = mean of client params: exactly FedAvg.
        return sgd(lr=1.0, momentum=0.0)
    raise ValueError(kind)


def outer_step(opt: Optimizer, theta_prev: Params, opt_state,
               client_thetas: Sequence[Params]):
    """One server round. Returns (theta_new, opt_state, delta)."""
    delta = pseudo_gradient(theta_prev, client_thetas)
    updates, opt_state = opt.update(delta, opt_state, theta_prev)
    theta_new = apply_updates(theta_prev, updates)
    return theta_new, opt_state, delta
