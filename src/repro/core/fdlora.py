"""FDLoRA Algorithm 1 — the paper's training procedure, end to end.

Stage 1  Local learning: every client SFTs its *personalized* LoRA on local
         data (Eq. 5); the *global* LoRA is initialised to the client mean
         (Eq. 6) so round 0 starts from pooled knowledge.
Stage 2  Federated learning: T outer rounds; each round every client pulls
         θ_s, runs K inner AdamW steps on it (line 12), optionally re-syncs
         its personalized LoRA every H rounds (lines 13-15); the server
         Nesterov-updates θ_s from the averaged pseudo-gradient (lines 17-18).
Stage 3  AdaFusion: per client, gradient-free search for fusion weights
         (Eq. 7/8) on a few-shot set Q.

The simulation executes clients sequentially on one host but shares a single
jitted inner-update (identical shapes across clients); the *distributed*
expression of the same schedule — clients as mesh "pod" axis entries, outer
aggregation as a pod-axis pmean — lives in ``repro/federated/distributed.py``
and is what the multi-pod dry-run lowers.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import fusion as fusion_lib
from repro.core.dual_lora import DualLoRAState, merge
from repro.core.lora import (init_adapters, lora_scale, tree_mean)
from repro.core.outer_opt import make_outer_optimizer, outer_step
from repro.training.optimizers import adamw
from repro.training.train_step import (make_fused_eval_fn, make_lora_train_step)

Params = Any


@dataclasses.dataclass
class FDLoRAConfig:
    n_clients: int = 5
    rounds: int = 30                 # T
    inner_steps: int = 3             # K
    sync_every: int = 10             # H (0 => never, i.e. H = ∞)
    batch_size: int = 8
    stage1_steps: int = 30           # SFT batches for stage 1
    inner_lr: float = 2e-4
    inner_weight_decay: float = 0.01
    outer_kind: str = "nesterov"     # nesterov | sgd | fedavg
    outer_lr: float = 1e-3
    outer_momentum: float = 0.5
    fusion_method: str = "es"
    fusion_steps: int = 5            # paper: max 5 optimization steps
    fusion_l1: float = 0.05          # λ
    few_shot_k: int = 16             # |Q|
    seed: int = 0


@dataclasses.dataclass
class ClientState:
    personalized: Params
    global_copy: Params              # θ_s^(i), this round's working copy
    inner_opt_state: Any
    fusion_weights: np.ndarray
    comm_bytes_up: float = 0.0
    comm_bytes_down: float = 0.0


def tree_bytes(tree) -> float:
    return float(sum(l.size * l.dtype.itemsize for l in jax.tree.leaves(tree)))


class FDLoRATrainer:
    """Runs Algorithm 1 against a frozen base model + per-client batchers."""

    def __init__(self, model, cfg, fed: FDLoRAConfig, base_params: Params):
        self.model, self.cfg, self.fed = model, cfg, fed
        self.base = base_params
        self.scale = lora_scale(cfg)
        self.inner_opt = adamw(lr=fed.inner_lr, weight_decay=fed.inner_weight_decay)
        self.outer_opt = make_outer_optimizer(fed.outer_kind, fed.outer_lr,
                                              fed.outer_momentum)
        self._step = jax.jit(make_lora_train_step(model, cfg, self.inner_opt))
        self._fused_eval = jax.jit(make_fused_eval_fn(model, cfg))
        self.history: List[Dict] = []

    # ---- Stage 1 ---------------------------------------------------------
    def stage1(self, batchers) -> List[ClientState]:
        fed = self.fed
        clients: List[ClientState] = []
        for i in range(fed.n_clients):
            rng = jax.random.PRNGKey(fed.seed * 1000 + i)
            ad = init_adapters(rng, self.cfg)
            st = self.inner_opt.init(ad)
            for _ in range(fed.stage1_steps):
                batch = _dev(batchers[i].sample())
                ad, st, m = self._step(self.base, ad, st, batch)
            clients.append(ClientState(
                personalized=ad, global_copy=ad, inner_opt_state=st,
                fusion_weights=np.array([0.5, 0.5], np.float32)))
        # Eq. 6: initialise the global LoRA to the client mean.
        self.theta_s = tree_mean([c.personalized for c in clients])
        self.outer_state = self.outer_opt.init(self.theta_s)
        return clients

    # ---- Stage 2 ---------------------------------------------------------
    def stage2_round(self, t: int, clients: Sequence[ClientState], batchers):
        fed = self.fed
        down = tree_bytes(self.theta_s)
        client_thetas = []
        round_losses: List[jnp.ndarray] = []
        for i, c in enumerate(clients):
            theta_i = self.theta_s                      # line 11: re-dispatch
            c.comm_bytes_down += down
            st = c.inner_opt_state
            for _ in range(fed.inner_steps):            # line 12: K inner steps
                batch = _dev(batchers[i].sample())
                theta_i, st, m = self._step(self.base, theta_i, st, batch)
                round_losses.append(m["loss"])  # device scalar; sync once below
            c.inner_opt_state = st
            c.global_copy = theta_i
            if fed.sync_every and t % fed.sync_every == 0:  # lines 13-15
                c.personalized = theta_i
            client_thetas.append(theta_i)
            c.comm_bytes_up += tree_bytes(theta_i)
        # lines 17-18: server outer update
        self.theta_s, self.outer_state, delta = outer_step(
            self.outer_opt, self.theta_s, self.outer_state, client_thetas)
        # per-round mean over every client's every inner step (not just the
        # last client's last step; also well-defined when n_clients == 0)
        mean_loss = (float(np.mean(jax.device_get(round_losses)))
                     if round_losses else float("nan"))
        self.history.append({"round": t, "loss": mean_loss})
        return delta

    def stage2(self, clients, batchers,
               on_round: Optional[Callable[[int, Sequence[ClientState]],
                                           None]] = None):
        """T outer rounds; ``on_round(t, clients)`` fires after each round —
        the continual-serving hook (e.g. :meth:`publish` into a live
        ``AdapterRegistry``, which hot-swaps the serving bank)."""
        for t in range(1, self.fed.rounds + 1):
            self.stage2_round(t, clients, batchers)
            if on_round is not None:
                on_round(t, clients)

    # ---- Stage 3 ---------------------------------------------------------
    def stage3(self, clients: Sequence[ClientState], batchers):
        for i, c in enumerate(clients):
            q = _dev(batchers[i].few_shot(self.fed.few_shot_k))

            def eval_loss(w):
                loss, _ = self._fused_eval(self.base, c.personalized,
                                           self.theta_s, jnp.asarray(w), q)
                return float(loss)

            w, info = fusion_lib.adafusion(
                eval_loss, method=self.fed.fusion_method,
                steps=self.fed.fusion_steps, lam=self.fed.fusion_l1,
                seed=self.fed.seed * 7 + i)
            c.fusion_weights = w

    # ---- full Algorithm 1 --------------------------------------------------
    def fit(self, batchers) -> List[ClientState]:
        clients = self.stage1(batchers)
        self.stage2(clients, batchers)
        self.stage3(clients, batchers)
        return clients

    # ---- inference-side helpers -------------------------------------------
    def fused_adapters(self, c: ClientState) -> Params:
        return merge(c.personalized, self.theta_s, jnp.asarray(c.fusion_weights))

    def publish(self, registry, clients: Sequence[ClientState],
                client_ids: Optional[Sequence[Any]] = None) -> Dict[Any, int]:
        """Push every client's Eq. 7 fused adapter into a serving registry
        (``AdapterRegistry`` or ``ShardedAdapterRegistry``), closing the
        continual-learning loop: re-registration bumps each client's
        ``version()`` (invalidating its prefix-cache scope) and the bank
        epoch (hot-swapping live ``StreamSession``\\ s at their next round
        boundary).  Returns ``{client_id: slot}``."""
        if client_ids is None:
            client_ids = [f"client{i}" for i in range(len(clients))]
        return {cid: registry.register(cid, self.fused_adapters(c))
                for cid, c in zip(client_ids, clients)}


def _dev(batch: Dict[str, np.ndarray]):
    return {k: jnp.asarray(v) for k, v in batch.items()}
