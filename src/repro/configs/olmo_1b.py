"""OLMo-1B [arXiv:2402.00838] — MHA (kv=16), non-parametric LayerNorm (no
affine params), SwiGLU, tied embeddings."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="olmo-1b", family="dense",
    n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16, d_ff=8192,
    vocab_size=50304, head_dim=128,
    norm_type="nonparametric", mlp_type="swiglu", tie_embeddings=True,
    rope_theta=10000.0, max_seq_len=4096,
    citation="arXiv:2402.00838",
)

SMOKE_CONFIG = CONFIG.with_overrides(
    name="olmo-smoke", n_layers=2, d_model=256, n_heads=8, n_kv_heads=8,
    head_dim=32, d_ff=512, vocab_size=512, max_seq_len=64)
