"""Kimi-K2 1T-A32B [arXiv:2501.kimi2] — trillion-parameter fine-grained MoE:
384 experts, top-8, per-expert FFN width 2048, GQA(kv=8), 61 layers.

Adaptations (DESIGN.md §5): head_dim pinned to 128 (7168/64=112 is not
MXU-tile aligned); the real model's first dense layer and shared expert are
uniformised into the attn+moe pattern. This is the dry-run stress test for
expert-parallel sharding and compile-time memory analysis."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b", family="moe",
    n_layers=61, d_model=7168, n_heads=64, n_kv_heads=8, d_ff=2048,
    vocab_size=163840, head_dim=128,
    layer_pattern=("attn+moe",),
    norm_type="rmsnorm", mlp_type="swiglu",
    rope_theta=1000000.0, max_seq_len=131072,
    n_experts=384, n_experts_per_tok=8, d_ff_moe=2048,
    moe_capacity_factor=1.25,
    citation="arXiv:2501.kimi2",
)

SMOKE_CONFIG = CONFIG.with_overrides(
    name="kimi-smoke", n_layers=2, d_model=256, n_heads=8, n_kv_heads=2,
    head_dim=32, d_ff=128, d_ff_moe=128, vocab_size=512,
    n_experts=4, n_experts_per_tok=2, max_seq_len=64)
