"""Whisper-small [arXiv:2212.04356] — encoder-decoder; the mel+conv frontend
is a STUB (input_specs provides 1500 frame embeddings; task-spec carve-out).
LoRA targets q/v + MLP, the usual Whisper-PEFT choice."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small", family="encdec",
    n_layers=12, d_model=768, n_heads=12, n_kv_heads=12, d_ff=3072,
    vocab_size=51865,
    norm_type="layernorm", mlp_type="gelu", use_rope=False,
    tie_embeddings=True,
    n_encoder_layers=12, encoder_seq_len=1500,
    max_seq_len=32768,  # real decoder ctx is 448; widened for decode_32k dry-run
    lora_targets=("wq", "wv", "w_up", "w_out"),
    citation="arXiv:2212.04356",
)

SMOKE_CONFIG = CONFIG.with_overrides(
    name="whisper-smoke", n_layers=2, n_encoder_layers=2, d_model=128,
    n_heads=4, n_kv_heads=4, d_ff=256, vocab_size=512, encoder_seq_len=32,
    max_seq_len=64)
