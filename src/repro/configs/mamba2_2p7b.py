"""Mamba2-2.7B [arXiv:2405.21060] — attention-free SSD (state-space duality):
64 layers of mamba2 blocks, d_state=128, expand=2, head_dim=64. Sub-quadratic
natively -> runs long_500k with O(1) decode state."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-2.7b", family="ssm",
    n_layers=64, d_model=2560, n_heads=1, n_kv_heads=1, d_ff=0,
    vocab_size=50280,
    layer_pattern=("mamba+none",),
    norm_type="rmsnorm", use_rope=False,
    ssm_d_state=128, ssm_d_conv=4, ssm_expand=2, ssm_head_dim=64,
    ssm_n_groups=1, ssm_chunk=128, max_seq_len=1048576,
    lora_targets=("in_proj", "out_proj"),
    citation="arXiv:2405.21060",
)

SMOKE_CONFIG = CONFIG.with_overrides(
    name="mamba2-smoke", n_layers=2, d_model=128, vocab_size=512,
    ssm_d_state=16, ssm_head_dim=16, ssm_chunk=8, max_seq_len=64)
