"""InternVL2-26B [arXiv:2404.16821] — VLM: InternViT-6B vision encoder +
InternLM2-20B language model. Per the task spec the ViT/projector is a STUB;
this config is the LM backbone consuming 256 stubbed patch embeddings."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-26b", family="vlm",
    n_layers=48, d_model=6144, n_heads=48, n_kv_heads=8, d_ff=16384,
    vocab_size=92553, head_dim=128,
    norm_type="rmsnorm", mlp_type="swiglu",
    rope_theta=1000000.0, max_seq_len=32768,
    n_patch_tokens=256,
    citation="arXiv:2404.16821",
)

SMOKE_CONFIG = CONFIG.with_overrides(
    name="internvl2-smoke", n_layers=2, d_model=256, n_heads=8, n_kv_heads=2,
    head_dim=32, d_ff=512, vocab_size=512, n_patch_tokens=8, max_seq_len=64)
