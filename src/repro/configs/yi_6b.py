"""Yi-6B [arXiv:2403.04652] — llama-architecture GQA(kv=4), SwiGLU, RMSNorm."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="yi-6b", family="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=4, d_ff=11008,
    vocab_size=64000, head_dim=128,
    norm_type="rmsnorm", mlp_type="swiglu",
    rope_theta=5000000.0, max_seq_len=4096,
    citation="arXiv:2403.04652",
)

SMOKE_CONFIG = CONFIG.with_overrides(
    name="yi-smoke", n_layers=2, d_model=256, n_heads=8, n_kv_heads=2,
    head_dim=32, d_ff=512, vocab_size=512, max_seq_len=64)
