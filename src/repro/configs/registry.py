"""Architecture registry: ``--arch <id>`` resolution for every launcher."""
from __future__ import annotations

import importlib
from typing import Dict, List, Optional

from repro.configs.base import INPUT_SHAPES, InputShape, ModelConfig

_MODULES = {
    "starcoder2-15b": "repro.configs.starcoder2_15b",
    "whisper-small": "repro.configs.whisper_small",
    "dbrx-132b": "repro.configs.dbrx_132b",
    "internvl2-26b": "repro.configs.internvl2_26b",
    "gemma-2b": "repro.configs.gemma_2b",
    "yi-6b": "repro.configs.yi_6b",
    "mamba2-2.7b": "repro.configs.mamba2_2p7b",
    "olmo-1b": "repro.configs.olmo_1b",
    "kimi-k2-1t-a32b": "repro.configs.kimi_k2_1t_a32b",
    "jamba-v0.1-52b": "repro.configs.jamba_v0p1_52b",
    "llama2-7b": "repro.configs.llama2_7b",  # the paper's own backbone
}

ASSIGNED_ARCHS: List[str] = [k for k in _MODULES if k != "llama2-7b"]
ALL_ARCHS: List[str] = list(_MODULES)


def get_config(arch: str, smoke: bool = False) -> ModelConfig:
    mod = importlib.import_module(_MODULES[arch])
    return mod.SMOKE_CONFIG if smoke else mod.CONFIG


def get_shape(name: str) -> InputShape:
    return INPUT_SHAPES[name]


# ---------------------------------------------------------------------------
# arch × shape applicability + per-shape config adjustment
# ---------------------------------------------------------------------------

def shape_supported(arch: str, shape: str) -> bool:
    """DESIGN.md §5: the only skip is whisper × long_500k (enc-dec with an
    architecturally bounded decoder context)."""
    if arch == "whisper-small" and shape == "long_500k":
        return False
    return True


def config_for_shape(arch: str, shape: str, smoke: bool = False) -> ModelConfig:
    """Per-shape variant: dense archs take a 4k sliding window for long_500k
    (the sub-quadratic variant the task spec requires); everything else runs
    its base config."""
    cfg = get_config(arch, smoke)
    if shape == "long_500k" and cfg.family in ("dense", "moe", "vlm") \
            and cfg.sliding_window == 0:
        cfg = cfg.with_overrides(sliding_window=4096)
    if shape in ("decode_32k", "long_500k", "prefill_32k"):
        need = INPUT_SHAPES[shape].seq_len
        if cfg.max_seq_len < need:
            cfg = cfg.with_overrides(max_seq_len=need)
    return cfg
