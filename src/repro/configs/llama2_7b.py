"""LLaMA2-7B [arXiv:2307.09288] — the paper's own backbone (FDLoRA §4.1).
(The paper calls it "encoder-only"; it is decoder-only — DESIGN.md §8.)"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama2-7b", family="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=32, d_ff=11008,
    vocab_size=32000, head_dim=128,
    norm_type="rmsnorm", mlp_type="swiglu",
    rope_theta=10000.0, max_seq_len=4096,
    citation="arXiv:2307.09288",
)

SMOKE_CONFIG = CONFIG.with_overrides(
    name="llama2-smoke", n_layers=2, d_model=256, n_heads=8, n_kv_heads=8,
    head_dim=32, d_ff=512, vocab_size=512, max_seq_len=64)
