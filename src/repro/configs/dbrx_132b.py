"""DBRX-132B [hf:databricks/dbrx-base] — fine-grained MoE: 16 experts, top-4,
GQA(kv=8). Every layer is attn+moe; per-expert FFN width 10752 (GLU)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="dbrx-132b", family="moe",
    n_layers=40, d_model=6144, n_heads=48, n_kv_heads=8, d_ff=10752,
    vocab_size=100352, head_dim=128,
    layer_pattern=("attn+moe",),
    norm_type="layernorm", mlp_type="swiglu",
    rope_theta=500000.0, max_seq_len=32768,
    n_experts=16, n_experts_per_tok=4, d_ff_moe=10752,
    citation="hf:databricks/dbrx-base",
)

SMOKE_CONFIG = CONFIG.with_overrides(
    name="dbrx-smoke", n_layers=2, d_model=256, n_heads=8, n_kv_heads=2,
    head_dim=32, d_ff=256, d_ff_moe=256, vocab_size=512,
    n_experts=4, n_experts_per_tok=2, max_seq_len=64)
