"""Jamba-v0.1-52B [arXiv:2403.19887] — hybrid Mamba+attention 1:7 interleave
with MoE (16 experts, top-2) on every other layer. Period-8 pattern: one
attention layer per 8, MoE alternating — 4 attn + 28 mamba layers, 16 MoE.

Adaptation: Jamba uses Mamba-1 blocks (d_state=16); we implement the SSD
(Mamba-2) block family throughout — same asymptotics, MXU-friendly (DESIGN.md
§2). Sub-quadratic overall -> runs long_500k (attn layers carry a 4k window
cache, mamba layers O(1) state)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b", family="hybrid",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=14336,
    vocab_size=65536, head_dim=128,
    layer_pattern=("mamba+mlp", "mamba+moe", "mamba+mlp", "mamba+moe",
                   "attn+mlp", "mamba+moe", "mamba+mlp", "mamba+moe"),
    norm_type="rmsnorm", mlp_type="swiglu", use_rope=False,
    sliding_window=4096,  # window on the sparse attn layers for long ctx
    max_seq_len=262144,
    n_experts=16, n_experts_per_tok=2, d_ff_moe=14336,
    ssm_d_state=16, ssm_d_conv=4, ssm_expand=2, ssm_head_dim=64,
    ssm_n_groups=1, ssm_chunk=128,
    citation="arXiv:2403.19887",
)

SMOKE_CONFIG = CONFIG.with_overrides(
    name="jamba-smoke", n_layers=4, d_model=256, n_heads=8, n_kv_heads=2,
    head_dim=32, d_ff=512, d_ff_moe=512, vocab_size=512,
    layer_pattern=("mamba+mlp", "mamba+moe", "attn+mlp", "mamba+moe"),
    n_experts=4, n_experts_per_tok=2, ssm_d_state=16, ssm_head_dim=16,
    ssm_chunk=8, sliding_window=16, max_seq_len=64)
