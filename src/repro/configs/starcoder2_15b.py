"""StarCoder2-15B [arXiv:2402.19173] — dense, GQA(kv=4), RoPE, 4k sliding
window (the real model trains with SW attention, which also qualifies it for
the long_500k shape natively)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-15b", family="dense",
    n_layers=40, d_model=6144, n_heads=48, n_kv_heads=4, d_ff=24576,
    vocab_size=49152, head_dim=128,
    norm_type="layernorm", mlp_type="gelu", use_rope=True,
    rope_theta=100000.0, sliding_window=4096, max_seq_len=16384,
    citation="arXiv:2402.19173",
)

SMOKE_CONFIG = CONFIG.with_overrides(
    name="starcoder2-smoke", n_layers=2, d_model=256, n_heads=8, n_kv_heads=2,
    head_dim=32, d_ff=512, vocab_size=512, sliding_window=16, max_seq_len=64)
