"""Gemma-2B [arXiv:2403.08295] — GeGLU MLP, head_dim=256, MQA (kv=1), tied
embeddings with sqrt(d) input scaling, huge 256k vocab."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma-2b", family="dense",
    n_layers=18, d_model=2048, n_heads=8, n_kv_heads=1, d_ff=16384,
    vocab_size=256000, head_dim=256,
    norm_type="rmsnorm", mlp_type="geglu", tie_embeddings=True,
    rope_theta=10000.0, max_seq_len=8192,
    citation="arXiv:2403.08295",
)

SMOKE_CONFIG = CONFIG.with_overrides(
    name="gemma-smoke", n_layers=2, d_model=256, n_heads=4, n_kv_heads=1,
    head_dim=64, d_ff=512, vocab_size=512, max_seq_len=64)
