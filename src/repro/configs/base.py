"""Model/architecture configuration system.

Every assigned architecture gets one module in ``repro/configs`` exporting a
``CONFIG`` (full-size, exercised only via the dry-run) and a ``SMOKE_CONFIG``
(reduced variant of the same family for CPU tests). Configs are registered by
id in ``repro.configs.registry``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

# ---------------------------------------------------------------------------
# Block descriptors: a model is ``n_layers`` layers arranged as repetitions of
# a ``layer_pattern`` (a period).  Each entry is "<mixer>+<mlp>" where mixer is
# one of {"attn", "mamba"} and mlp one of {"mlp", "moe", "none"}.
# Dense models use a period of 1 (["attn+mlp"]); Jamba uses a period of 8.
# ---------------------------------------------------------------------------

VALID_MIXERS = ("attn", "mamba")
VALID_MLPS = ("mlp", "moe", "none")


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Unified configuration covering all supported families."""

    name: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm

    # Transformer trunk
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // n_heads

    # Layer arrangement (period pattern). Default: dense attn+mlp.
    layer_pattern: Tuple[str, ...] = ("attn+mlp",)

    # Attention flavour
    rope_theta: float = 10000.0
    use_rope: bool = True
    sliding_window: int = 0          # 0 = full attention
    attn_logit_softcap: float = 0.0
    # beyond-paper perf knobs (EXPERIMENTS.md §Perf):
    attn_impl: str = "repeat"        # repeat | grouped (no KV materialization)
    attn_softmax_dtype: str = "float32"  # float32 | bfloat16 logits/probs
    # serving paged-attention backend: "jnp" materialises the block-table
    # gather (CPU oracle, bitwise-stable default); "pallas" routes the paged
    # branch of layers.multihead_attention through kernels/paged_attention.py
    # + kernels/paged_prefill.py (ServeConfig.paged_backend threads this
    # per-stream; full attention only — no sliding window / logit softcap)
    paged_backend: str = "jnp"       # jnp | pallas
    pallas_interpret: bool = True    # False on TPU: compile the kernels

    # Norm / activation flavour
    norm_type: str = "rmsnorm"       # rmsnorm | layernorm | nonparametric
    mlp_type: str = "swiglu"         # swiglu | geglu | gelu
    tie_embeddings: bool = False

    # MoE
    n_experts: int = 0
    n_experts_per_tok: int = 0
    d_ff_moe: int = 0                # 0 -> d_ff
    moe_capacity_factor: float = 1.25
    router_aux_loss_coef: float = 0.01

    # SSM (Mamba2 / SSD)
    ssm_d_state: int = 0
    ssm_d_conv: int = 4
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_n_groups: int = 1
    ssm_chunk: int = 128

    # Encoder-decoder (whisper): encoder is attn+mlp, full attention,
    # learned positions, consumes stubbed frame embeddings.
    n_encoder_layers: int = 0
    encoder_seq_len: int = 0         # e.g. 1500 audio frames
    # VLM: number of stubbed image-patch embeddings prepended to text.
    n_patch_tokens: int = 0

    # Context
    max_seq_len: int = 8192

    # LoRA defaults for this arch (which linears get adapters)
    lora_rank: int = 16
    lora_alpha: float = 32.0
    lora_targets: Tuple[str, ...] = ("wq", "wk", "wv", "wo", "w_up", "w_gate", "w_out")

    # dtype policy
    dtype: str = "bfloat16"          # activations/frozen params
    param_dtype: str = "bfloat16"    # base (frozen) param storage

    # rematerialisation of the per-period body under the layer scan
    # (training memory ~O(sqrt) of depth; standard for big models)
    remat: bool = True
    # remat policy: "full" recomputes everything (min memory, recomputes the
    # TP all-reduces in backward); "dots" saves matmul/collective outputs
    # (§Perf: trades peak memory for ~1/3 of the collective term)
    remat_policy: str = "full"

    # unroll factor for the layer scan. 1 = rolled while-loop (fast compile,
    # production default). The dry-run fully unrolls because XLA's
    # cost_analysis counts a while body ONCE, not × trip count — full unroll
    # makes HLO_FLOPs/bytes exact for the roofline (tests/test_roofline.py).
    scan_unroll: int = 1

    # Source citation for the config values.
    citation: str = ""

    def __post_init__(self):
        assert self.paged_backend in ("jnp", "pallas"), (
            f"{self.name}: unknown paged_backend {self.paged_backend!r}")
        for p in self.layer_pattern:
            mixer, _, mlp = p.partition("+")
            assert mixer in VALID_MIXERS and mlp in VALID_MLPS, p
        assert self.n_layers % len(self.layer_pattern) == 0, (
            f"{self.name}: n_layers={self.n_layers} not a multiple of the "
            f"pattern period {len(self.layer_pattern)}")

    # -- derived ----------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def n_periods(self) -> int:
        return self.n_layers // len(self.layer_pattern)

    @property
    def resolved_d_ff_moe(self) -> int:
        return self.d_ff_moe or self.d_ff

    @property
    def ssm_d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_n_heads(self) -> int:
        return self.ssm_d_inner // self.ssm_head_dim

    @property
    def is_encdec(self) -> bool:
        return self.family == "encdec"

    def has_mixer(self, mixer: str) -> bool:
        return any(p.startswith(mixer + "+") or p == mixer for p in self.layer_pattern)

    def has_moe(self) -> bool:
        return any(p.endswith("+moe") for p in self.layer_pattern)

    def with_overrides(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # -- parameter accounting (for Fig-4 style reporting & rooflines) ------
    def count_params(self) -> int:
        """Total base parameters (approximate, exact for our impl)."""
        d, ff, V = self.d_model, self.d_ff, self.vocab_size
        hd = self.resolved_head_dim
        per = {
            "attn": d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd
                    + self.n_heads * hd * d,
            "mamba": (d * (2 * self.ssm_d_inner + 2 * self.ssm_n_groups * self.ssm_d_state
                           + self.ssm_n_heads)
                      + self.ssm_d_inner * d
                      + self.ssm_d_conv * (self.ssm_d_inner + 2 * self.ssm_n_groups * self.ssm_d_state)
                      + 2 * self.ssm_n_heads),
            "mlp": (3 if self.mlp_type in ("swiglu", "geglu") else 2) * d * ff,
            "moe": self.n_experts * (3 if self.mlp_type in ("swiglu", "geglu") else 2)
                   * d * self.resolved_d_ff_moe + d * self.n_experts,
            "none": 0,
        }
        total = 0
        for i in range(self.n_layers):
            mixer, _, mlp = self.layer_pattern[i % len(self.layer_pattern)].partition("+")
            total += per[mixer] + per[mlp] + 2 * d  # + norms
        total += V * d  # embed
        if not self.tie_embeddings:
            total += V * d
        if self.is_encdec:
            enc_layer = per["attn"] + per["mlp"] + 2 * d
            total += self.n_encoder_layers * (enc_layer + per["attn"] + d)  # + cross-attn
            total += self.encoder_seq_len * d + self.max_seq_len * d  # learned pos
        return total

    def count_active_params(self) -> int:
        """Active parameters per token (MoE: only routed experts)."""
        if not self.has_moe():
            return self.count_params()
        d = self.d_model
        moe_full = self.n_experts * 3 * d * self.resolved_d_ff_moe
        moe_active = self.n_experts_per_tok * 3 * d * self.resolved_d_ff_moe
        n_moe_layers = sum(1 for i in range(self.n_layers)
                           if self.layer_pattern[i % len(self.layer_pattern)].endswith("+moe"))
        return self.count_params() - n_moe_layers * (moe_full - moe_active)

    def count_lora_params(self, rank: Optional[int] = None) -> int:
        """Trainable parameters of one LoRA adapter set."""
        r = rank or self.lora_rank
        from repro.core.lora import lora_target_shapes
        return sum(din * r + r * dout for (din, dout) in lora_target_shapes(self))


# ---------------------------------------------------------------------------
# Input shapes (assigned): name -> (seq_len, global_batch, kind)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}
