"""Roofline-term extraction from the compiled dry-run artifact.

Three terms per (arch × shape × mesh), in seconds (EXPERIMENTS.md §Roofline):

    compute    = HLO_FLOPs / (chips × peak_FLOP/s)
    memory     = HLO_bytes / (chips × HBM_bw)
    collective = collective_bytes / (chips × link_bw)

HLO_FLOPs / HLO_bytes come from ``compiled.cost_analysis()``. Collective
bytes are NOT in cost_analysis: we parse the optimized HLO and sum the bytes
each chip moves per collective, using standard ring-algorithm factors on the
op's *output* shape (g = collective group size):

    all-reduce       2·S·(g-1)/g      (reduce-scatter + all-gather phases)
    all-gather       S_out·(g-1)/g    (each chip receives the other shards)
    reduce-scatter   S_out·(g-1)     (input = g·S_out, each chip sends all but its shard)
    all-to-all       S·(g-1)/g
    collective-permute  S

Hardware model: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLL_RE = re.compile(
    r"=\s*(?:\(([^)]*)\)|(\w+\[[^\]]*\]))\S*\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_SHAPE_RE = re.compile(r"(\w+?)\[([\d,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{?\{([^}]*)\}")
_GROUPS_V2_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class Collective:
    op: str
    out_bytes: int
    group_size: int
    per_chip_bytes: float


def parse_collectives(hlo_text: str) -> List[Collective]:
    out: List[Collective] = []
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        shape_str = m.group(1) or m.group(2)
        op = m.group(3)
        size = _shape_bytes(shape_str)
        g = 1
        gm = _GROUPS_V2_RE.search(line)
        if gm:  # iota format [num_groups,group_size]
            g = int(gm.group(2))
        else:
            gm = _GROUPS_RE.search(line)
            if gm:
                g = len([x for x in gm.group(1).split(",") if x.strip() != ""])
        g = max(g, 1)
        if op == "all-reduce":
            per_chip = 2 * size * (g - 1) / g
        elif op == "all-gather":
            per_chip = size * (g - 1) / g
        elif op == "reduce-scatter":
            per_chip = size * (g - 1)
        elif op == "all-to-all":
            per_chip = size * (g - 1) / g
        else:  # collective-permute
            per_chip = size
        out.append(Collective(op, size, g, per_chip))
    return out


@dataclasses.dataclass
class Roofline:
    flops: float                # global HLO flops
    hbm_bytes: float            # global bytes accessed
    collective_bytes: float     # per-chip bytes moved over ICI
    chips: int
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops: float = 0.0
    useful_ratio: float = 0.0
    n_collectives: int = 0
    coll_by_op: Optional[Dict[str, float]] = None

    def to_dict(self):
        return dataclasses.asdict(self)


def analyze(cost: Dict, hlo_text: str, chips: int,
            model_flops: float = 0.0) -> Roofline:
    """``cost`` comes from ``compiled.cost_analysis()``, which reports the
    SPMD-partitioned (per-device) module — flops/bytes are PER CHIP (verified
    against a hand-computed matmul; tests/test_roofline.py)."""
    flops = float(cost.get("flops", 0.0))          # per chip
    hbm = float(cost.get("bytes accessed", 0.0))   # per chip
    colls = parse_collectives(hlo_text)
    per_chip_coll = sum(c.per_chip_bytes for c in colls)
    by_op: Dict[str, float] = {}
    for c in colls:
        by_op[c.op] = by_op.get(c.op, 0.0) + c.per_chip_bytes

    compute_s = flops / PEAK_FLOPS
    memory_s = hbm / HBM_BW
    coll_s = per_chip_coll / ICI_BW
    dominant = max(
        (("compute", compute_s), ("memory", memory_s), ("collective", coll_s)),
        key=lambda kv: kv[1])[0]
    global_flops = flops * chips
    return Roofline(
        flops=flops, hbm_bytes=hbm, collective_bytes=per_chip_coll,
        chips=chips, compute_s=compute_s, memory_s=memory_s,
        collective_s=coll_s, dominant=dominant, model_flops=model_flops,
        useful_ratio=(model_flops / global_flops if global_flops else 0.0),
        n_collectives=len(colls), coll_by_op=by_op)


def model_flops_train(cfg, tokens: int) -> float:
    """6·N_active·D for a train step (fwd+bwd)."""
    return 6.0 * cfg.count_active_params() * tokens


def model_flops_decode(cfg, tokens: int) -> float:
    """2·N_active·D for forward-only decode."""
    return 2.0 * cfg.count_active_params() * tokens
