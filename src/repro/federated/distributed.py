"""Multi-pod execution of FDLoRA: clients ride the mesh "pod" axis.

The paper allows a client to be "a single device or a cluster"; on a TPU
fleet the natural mapping is client == pod slice. We express one full
federated round (K inner steps + outer aggregation) as a single jitted
function over *client-stacked* state:

    adapters:   (N_clients, ...)  sharded P("pod", ...)
    batches:    (N_clients, K, B_local, L) sharded P("pod", None, "data", None)
    base model: replicated across pods, model-parallel inside each pod

Inside the round, clients are a ``vmap`` axis — so the K inner steps compile
with **zero cross-pod collectives** — and the outer pseudo-gradient mean is a
single reduction over the client axis, which XLA lowers to the only cross-pod
all-reduce, of LoRA-sized tensors. That is the paper's "communication once
every K steps, LoRA parameters only" property, visible in the dry-run HLO
(EXPERIMENTS.md §Dry-run greps the collectives).
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core.lora import lora_scale
from repro.training.optimizers import Optimizer, apply_updates, clip_by_global_norm
from repro.training.train_step import make_lora_loss_fn

Params = Any


def make_fdlora_round_step(model, cfg, inner_opt: Optimizer,
                           outer_opt: Optimizer, inner_steps: int,
                           sync_personalized: bool = False,
                           compress_outer: str = "none") -> Callable:
    """Returns round(base, theta_s, stacked_state, batches) -> (theta_s', state').

    stacked_state = {"adapters": (N,...), "personalized": (N,...),
                     "inner_opt": (N,...), "outer_opt": {...}}
    batches: dict of (N, K, B, ...) arrays.
    """
    loss_fn = make_lora_loss_fn(model, cfg)

    def one_client(base, theta_s, inner_state, batches_k):
        """K inner AdamW steps on this client's copy of the global LoRA."""
        def inner(carry, batch):
            ad, st = carry
            (_, m), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                ad, base, batch)
            grads = clip_by_global_norm(grads, 1.0)
            upd, st = inner_opt.update(grads, st, ad)
            return (apply_updates(ad, upd), st), m["loss"]

        # dry-run cost accounting: unroll the K-step loop alongside the layer
        # scan (XLA counts a while body once; see dryrun._extrapolated_cost)
        (theta_i, inner_state), losses = jax.lax.scan(
            inner, (theta_s, inner_state), batches_k,
            unroll=inner_steps if getattr(cfg, "scan_unroll", 1) > 1 else 1)
        return theta_i, inner_state, losses.mean()

    def round_step(base, theta_s, state, batches):
        # -- inner phase: clients independent (vmap over the pod axis) ----
        theta_i, inner_state, loss = jax.vmap(
            one_client, in_axes=(None, None, 0, 0))(
            base, theta_s, state["inner_opt"], batches)
        # -- outer phase: the ONLY cross-pod communication -----------------
        if compress_outer == "bf16":
            # beyond-paper (§Perf): halve cross-pod bytes by shipping the
            # per-client pseudo-gradient in bf16 — the client-axis mean (the
            # cross-pod all-reduce) runs on bf16 operands; the Nesterov
            # update stays fp32. DiLoCo-style quantised outer gradients.
            delta = jax.tree.map(
                lambda prev, ti: (prev[None] - ti).astype(jnp.bfloat16)
                .mean(axis=0).astype(jnp.float32),
                theta_s, theta_i)
        else:
            delta = jax.tree.map(
                lambda prev, ti: prev - ti.mean(axis=0), theta_s, theta_i)
        upd, outer_state = outer_opt.update(delta, state["outer_opt"], theta_s)
        theta_s_new = apply_updates(theta_s, upd)
        new_state = dict(state, inner_opt=inner_state, outer_opt=outer_state)
        if sync_personalized:  # Algorithm 1 lines 13-15 (H-round sync)
            new_state["personalized"] = theta_i
        return theta_s_new, new_state, loss.mean()

    return round_step


def client_stacked_specs(adapter_spec_tree, n_clients_axis: str = "pod"):
    """Prepend the client axis (sharded on 'pod') to adapter specs."""
    return jax.tree.map(
        lambda s: P(*((n_clients_axis,) + tuple(s))), adapter_spec_tree,
        is_leaf=lambda s: isinstance(s, P))


def batch_specs(kind: str = "train") -> P:
    # (N_clients, K, B, L): clients on pod, batch on data.
    return P("pod", None, "data", None)
