"""The paper's six comparison baselines, reimplemented at the LoRA-adapter
level (the base LLM is frozen everywhere, as in the paper's PEFT setting).

Adaptations (documented per class): methods defined for full models are
expressed over adapter trees; FedRoD's two heads and FedKD's student/teacher
use exact LoRA *rank concatenation* ``(A1|A2)(B1;B2) = A1B1 + A2B2`` to
compose adapters additively without touching the model code.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.lora import (init_adapters, lora_scale, tree_add, tree_mean,
                             tree_scale, tree_sub)
from repro.training.optimizers import adamw, apply_updates, clip_by_global_norm
from repro.training.train_step import cross_entropy, make_lora_train_step

Params = Any


def concat_rank(ad1: Params, ad2: Params) -> Params:
    """Exact additive composition of two LoRAs via rank concatenation."""
    def walk(a, b):
        if isinstance(a, dict) and set(a.keys()) == {"a", "b"}:
            return {"a": jnp.concatenate([a["a"], b["a"]], axis=-1),
                    "b": jnp.concatenate([a["b"], b["b"]], axis=-2)}
        return {k: walk(a[k], b[k]) for k in a}

    return walk(ad1, ad2)


@dataclasses.dataclass
class FedConfig:
    n_clients: int = 5
    rounds: int = 30
    local_steps: int = 3
    lr: float = 2e-4
    seed: int = 0
    # method-specific knobs
    prox_mu: float = 0.01            # FedProx
    amp_lambda: float = 0.1          # FedAMP prox to the attentive aggregate
    amp_tau: float = 5.0             # FedAMP attention temperature
    kd_temp: float = 2.0             # FedKD distillation temperature
    kd_coef: float = 0.5


def _dev(b):
    return {k: jnp.asarray(v) for k, v in b.items()}


class BaselineBase:
    name = "base"

    def __init__(self, model, cfg, fed: FedConfig, base_params):
        self.model, self.cfg, self.fed = model, cfg, fed
        self.base = base_params
        self.scale = lora_scale(cfg)
        self.opt = adamw(lr=fed.lr)
        self.comm_bytes = 0.0

    def _init_all(self):
        return [init_adapters(jax.random.PRNGKey(self.fed.seed * 100 + i), self.cfg)
                for i in range(self.fed.n_clients)]

    def _count(self, tree):
        self.comm_bytes += float(sum(l.size * l.dtype.itemsize
                                     for l in jax.tree.leaves(tree)))

    def fit(self, batchers) -> List[Params]:
        raise NotImplementedError


class Local(BaselineBase):
    """Per-client training only — no communication at all."""
    name = "local"

    def fit(self, batchers):
        step = jax.jit(make_lora_train_step(self.model, self.cfg, self.opt))
        ads = self._init_all()
        states = [self.opt.init(a) for a in ads]
        for _ in range(self.fed.rounds):
            for i in range(self.fed.n_clients):
                for _ in range(self.fed.local_steps):
                    ads[i], states[i], _ = step(self.base, ads[i], states[i],
                                                _dev(batchers[i].sample()))
        return ads


class FedAvg(BaselineBase):
    """McMahan et al. 2017 over LoRA parameters."""
    name = "fedavg"

    def fit(self, batchers):
        step = jax.jit(make_lora_train_step(self.model, self.cfg, self.opt))
        g = init_adapters(jax.random.PRNGKey(self.fed.seed), self.cfg)
        states = [self.opt.init(g) for _ in range(self.fed.n_clients)]
        for _ in range(self.fed.rounds):
            locals_ = []
            for i in range(self.fed.n_clients):
                a = g
                self._count(g)  # broadcast down
                for _ in range(self.fed.local_steps):
                    a, states[i], _ = step(self.base, a, states[i],
                                           _dev(batchers[i].sample()))
                locals_.append(a)
                self._count(a)  # upload
            g = tree_mean(locals_)
        return [g] * self.fed.n_clients


class FedProx(BaselineBase):
    """Li et al. 2020: local loss + (μ/2)·‖θ − θ_global‖²."""
    name = "fedprox"

    def _make_step(self):
        from repro.training.train_step import make_lora_loss_fn
        loss_fn = make_lora_loss_fn(self.model, self.cfg)
        mu = self.fed.prox_mu

        def prox_loss(ad, base, batch, g):
            l, m = loss_fn(ad, base, batch)
            prox = sum(jnp.sum(jnp.square(x - y)) for x, y in
                       zip(jax.tree.leaves(ad), jax.tree.leaves(g)))
            return l + 0.5 * mu * prox, m

        def step(base, ad, st, batch, g):
            (_, m), grads = jax.value_and_grad(prox_loss, has_aux=True)(
                ad, base, batch, g)
            grads = clip_by_global_norm(grads, 1.0)
            upd, st = self.opt.update(grads, st, ad)
            return apply_updates(ad, upd), st, m

        return jax.jit(step)

    def fit(self, batchers):
        step = self._make_step()
        g = init_adapters(jax.random.PRNGKey(self.fed.seed), self.cfg)
        states = [self.opt.init(g) for _ in range(self.fed.n_clients)]
        for _ in range(self.fed.rounds):
            locals_ = []
            for i in range(self.fed.n_clients):
                a = g
                self._count(g)
                for _ in range(self.fed.local_steps):
                    a, states[i], _ = step(self.base, a, states[i],
                                           _dev(batchers[i].sample()), g)
                locals_.append(a)
                self._count(a)
            g = tree_mean(locals_)
        return [g] * self.fed.n_clients


class FedAMP(BaselineBase):
    """Huang et al. 2021: attentive message passing — each client gets a
    personalized aggregate u_i = Σ_j ξ_ij θ_j (ξ from parameter cosine
    similarity) and trains with a prox toward u_i."""
    name = "fedamp"

    def _attention(self, thetas: List[Params]) -> List[Params]:
        n = len(thetas)
        flats = [jnp.concatenate([x.reshape(-1) for x in jax.tree.leaves(t)])
                 for t in thetas]
        normed = [f / (jnp.linalg.norm(f) + 1e-9) for f in flats]
        sims = np.array([[float(jnp.vdot(normed[i], normed[j])) for j in range(n)]
                         for i in range(n)])
        out = []
        for i in range(n):
            logits = self.fed.amp_tau * sims[i]
            w = np.exp(logits - logits.max())
            w = w / w.sum()
            agg = tree_scale(thetas[0], float(w[0]))
            for j in range(1, n):
                agg = tree_add(agg, tree_scale(thetas[j], float(w[j])))
            out.append(agg)
        return out

    def _make_step(self):
        from repro.training.train_step import make_lora_loss_fn
        loss_fn = make_lora_loss_fn(self.model, self.cfg)
        lam = self.fed.amp_lambda

        def amp_loss(ad, base, batch, u):
            l, m = loss_fn(ad, base, batch)
            prox = sum(jnp.sum(jnp.square(x - y)) for x, y in
                       zip(jax.tree.leaves(ad), jax.tree.leaves(u)))
            return l + 0.5 * lam * prox, m

        def step(base, ad, st, batch, u):
            (_, m), grads = jax.value_and_grad(amp_loss, has_aux=True)(
                ad, base, batch, u)
            grads = clip_by_global_norm(grads, 1.0)
            upd, st = self.opt.update(grads, st, ad)
            return apply_updates(ad, upd), st, m

        return jax.jit(step)

    def fit(self, batchers):
        step = self._make_step()
        ads = self._init_all()
        states = [self.opt.init(a) for a in ads]
        for _ in range(self.fed.rounds):
            us = self._attention(ads)          # server message passing
            for u in us:
                self._count(u)
            for i in range(self.fed.n_clients):
                self._count(ads[i])
                for _ in range(self.fed.local_steps):
                    ads[i], states[i], _ = step(self.base, ads[i], states[i],
                                                _dev(batchers[i].sample()), us[i])
        return ads


def _split_rep_head(ad: Params):
    """FedRep split: attention ('representation') adapters are shared,
    MLP/router ('head') adapters stay personal (adapter-level analog of the
    body/head decoupling; see module docstring)."""
    shared = {k: v for k, v in ad.items()} if not isinstance(ad, dict) else None

    def walk(t, keep):
        out = {}
        for k, v in t.items():
            if k in ("mixer", "self_attn", "cross_attn"):
                if keep == "shared":
                    out[k] = v
            elif k == "mlp":
                if keep == "head":
                    out[k] = v
            elif isinstance(v, dict):
                sub = walk(v, keep)
                if sub:
                    out[k] = sub
        return out

    return walk(ad, "shared"), walk(ad, "head")


def _merge_rep_head(shared: Params, head: Params) -> Params:
    def walk(s, h):
        out = dict(s) if s else {}
        for k, v in (h or {}).items():
            if k in out and isinstance(v, dict) and not set(v.keys()) == {"a", "b"}:
                out[k] = walk(out[k], v)
            else:
                out[k] = v
        return out

    return walk(shared, head)


class FedRep(BaselineBase):
    """Collins et al. 2021: shared representation, personal heads."""
    name = "fedrep"

    def fit(self, batchers):
        step = jax.jit(make_lora_train_step(self.model, self.cfg, self.opt))
        ads = self._init_all()
        states = [self.opt.init(a) for a in ads]
        for _ in range(self.fed.rounds):
            for i in range(self.fed.n_clients):
                for _ in range(self.fed.local_steps):
                    ads[i], states[i], _ = step(self.base, ads[i], states[i],
                                                _dev(batchers[i].sample()))
            shared = tree_mean([_split_rep_head(a)[0] for a in ads])
            self._count(shared)
            for i in range(self.fed.n_clients):
                ads[i] = _merge_rep_head(shared, _split_rep_head(ads[i])[1])
                # fresh opt state leaves momenta aligned with the new params
        return ads


class FedRoD(BaselineBase):
    """Chen & Chao 2021: decoupled generic + personalized predictors.
    Generic adapter g is FedAvg'd; personal adapter p_i trains on top via
    exact rank concatenation. Local loss = CE(g) + CE(g ⊕ p_i)."""
    name = "fedrod"

    def _make_step(self):
        scale = self.scale

        def loss_fn(both, base, batch):
            g, p = both
            lg, aux1 = self.model.forward(base, batch, adapters=g, lora_scale=scale)
            l1, m = cross_entropy(self.cfg, lg, batch)
            lp, aux2 = self.model.forward(base, batch, adapters=concat_rank(g, p),
                                          lora_scale=scale)
            l2, m2 = cross_entropy(self.cfg, lp, batch)
            return l1 + l2 + self.cfg.router_aux_loss_coef * (aux1 + aux2), m2

        def step(base, g, p, st, batch):
            (_, m), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                (g, p), base, batch)
            grads = clip_by_global_norm(grads, 1.0)
            upd, st = self.opt.update(grads, st, (g, p))
            g, p = apply_updates((g, p), upd)
            return g, p, st, m

        return jax.jit(step)

    def fit(self, batchers):
        step = self._make_step()
        g = init_adapters(jax.random.PRNGKey(self.fed.seed), self.cfg)
        ps = self._init_all()
        states = [self.opt.init((g, p)) for p in ps]
        for _ in range(self.fed.rounds):
            locals_ = []
            for i in range(self.fed.n_clients):
                gi = g
                self._count(g)
                for _ in range(self.fed.local_steps):
                    gi, ps[i], states[i], _ = step(self.base, gi, ps[i],
                                                   states[i], _dev(batchers[i].sample()))
                locals_.append(gi)
                self._count(gi)
            g = tree_mean(locals_)
        self._final_g = g
        return [concat_rank(g, p) for p in ps]


class FedKD(BaselineBase):
    """Wu et al. 2022: communication-efficient FL via mutual knowledge
    distillation — a small *student* adapter (rank r/2) is the only thing
    communicated; the local *teacher* learns from data + the student and
    vice versa. (The paper's SVD gradient compression is orthogonal to the
    adapter setting and omitted; noted in DESIGN.md.)"""
    name = "fedkd"

    def _make_step(self, student_rank):
        scale = self.scale
        T = self.fed.kd_temp
        coef = self.fed.kd_coef

        def kl(p_logits, q_logits, mask):
            p = jax.nn.log_softmax(p_logits / T, -1)
            q = jax.nn.log_softmax(q_logits / T, -1)
            per = jnp.sum(jnp.exp(p) * (p - q), -1)
            return (per * mask).sum() / jnp.maximum(mask.sum(), 1)

        def loss_fn(both, base, batch):
            t, s = both
            lt, _ = self.model.forward(base, batch, adapters=t, lora_scale=scale)
            ls, _ = self.model.forward(base, batch, adapters=s, lora_scale=scale)
            l1, m = cross_entropy(self.cfg, lt, batch)
            l2, _ = cross_entropy(self.cfg, ls, batch)
            mask = (batch["tokens"][:, 1:] >= 0).astype(jnp.float32)
            mutual = kl(jax.lax.stop_gradient(lt[:, :-1]), ls[:, :-1], mask) + \
                     kl(jax.lax.stop_gradient(ls[:, :-1]), lt[:, :-1], mask)
            return l1 + l2 + coef * mutual, m

        def step(base, t, s, st, batch):
            (_, m), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                (t, s), base, batch)
            grads = clip_by_global_norm(grads, 1.0)
            upd, st = self.opt.update(grads, st, (t, s))
            t, s = apply_updates((t, s), upd)
            return t, s, st, m

        return jax.jit(step)

    def fit(self, batchers):
        r_s = max(2, self.cfg.lora_rank // 2)
        step = self._make_step(r_s)
        teachers = self._init_all()
        s_g = init_adapters(jax.random.PRNGKey(self.fed.seed + 1), self.cfg, rank=r_s)
        states = [self.opt.init((t, s_g)) for t in teachers]
        for _ in range(self.fed.rounds):
            studs = []
            for i in range(self.fed.n_clients):
                s = s_g
                self._count(s_g)
                for _ in range(self.fed.local_steps):
                    teachers[i], s, states[i], _ = step(
                        self.base, teachers[i], s, states[i],
                        _dev(batchers[i].sample()))
                studs.append(s)
                self._count(s)
            s_g = tree_mean(studs)
        return teachers


BASELINES = {b.name: b for b in
             (Local, FedAvg, FedProx, FedAMP, FedRep, FedRoD, FedKD)}
