"""Unified decoder-only model covering dense / MoE / SSM / hybrid / VLM.

A model is ``cfg.n_layers`` layers arranged as ``n_periods`` repetitions of
``cfg.layer_pattern``. Per-period parameters are stacked on a leading axis and
the period loop is a ``jax.lax.scan`` — this keeps the HLO size independent of
depth (essential for the 512-device dry-run compiles) and is the idiomatic
TPU structure for deep stacks.

Public API (all pure functions):
    init_params(rng, cfg)                 -> params
    param_specs(cfg)                      -> PartitionSpec tree
    forward(params, tokens, cfg, ...)     -> (logits, aux_loss)
    init_decode_cache(cfg, batch, length) -> cache
    decode_cache_specs(cfg)               -> PartitionSpec tree
    decode_step(params, cache, tokens, pos, cfg, ...) -> (logits, cache)
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import layers as L
from repro.models import mamba2, moe as moe_lib

Params = Dict[str, Any]


def _block_names(cfg):
    return [f"b{i}" for i in range(len(cfg.layer_pattern))]


def _parse(entry: str) -> Tuple[str, str]:
    mixer, _, mlp = entry.partition("+")
    return mixer, (mlp or "none")


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def _init_block(key, entry: str, cfg, dtype) -> Params:
    mixer, mlp = _parse(entry)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    p: Params = {"norm1": L.init_norm(k3, cfg.d_model, cfg.norm_type, dtype)}
    if mixer == "attn":
        p["mixer"] = L.init_attention(k1, cfg, dtype)
    else:
        p["mixer"] = mamba2.init_mamba(k1, cfg, dtype)
    if mlp == "mlp":
        p["norm2"] = L.init_norm(k4, cfg.d_model, cfg.norm_type, dtype)
        p["mlp"] = L.init_mlp(k2, cfg.d_model, cfg.d_ff, cfg.mlp_type, dtype)
    elif mlp == "moe":
        p["norm2"] = L.init_norm(k4, cfg.d_model, cfg.norm_type, dtype)
        p["mlp"] = moe_lib.init_moe(k2, cfg.d_model, cfg.resolved_d_ff_moe,
                                    cfg.n_experts, cfg.mlp_type, dtype)
    return p


def _block_specs(entry: str, cfg) -> Params:
    mixer, mlp = _parse(entry)
    p: Params = {"norm1": L.norm_specs(cfg.norm_type)}
    p["mixer"] = (L.attention_specs(cfg) if mixer == "attn"
                  else mamba2.mamba_specs(cfg))
    if mlp == "mlp":
        p["norm2"] = L.norm_specs(cfg.norm_type)
        p["mlp"] = L.mlp_specs(cfg.mlp_type)
    elif mlp == "moe":
        p["norm2"] = L.norm_specs(cfg.norm_type)
        p["mlp"] = moe_lib.moe_specs(cfg.mlp_type)
    return p


def init_params(rng, cfg) -> Params:
    dtype = L.dt(cfg.param_dtype)
    n_blocks = len(cfg.layer_pattern)
    keys = jax.random.split(rng, n_blocks + 3)

    def stacked(entry, key):
        ks = jax.random.split(key, cfg.n_periods)
        return jax.vmap(lambda k: _init_block(k, entry, cfg, dtype))(ks)

    params: Params = {
        "embed": L.init_embed(keys[-1], cfg.vocab_size, cfg.d_model, dtype),
        "final_norm": L.init_norm(keys[-2], cfg.d_model, cfg.norm_type, dtype),
        "blocks": {name: stacked(entry, keys[i])
                   for i, (name, entry) in
                   enumerate(zip(_block_names(cfg), cfg.layer_pattern))},
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = (jax.random.normal(
            keys[-3], (cfg.d_model, cfg.vocab_size)) * 0.02).astype(dtype)
    return params


def _add_leading(spec_tree):
    """Prepend a replicated period axis to every PartitionSpec leaf."""
    return jax.tree.map(lambda s: P(*((None,) + tuple(s))), spec_tree,
                        is_leaf=lambda s: isinstance(s, P))


def param_specs(cfg) -> Params:
    specs: Params = {
        "embed": L.embed_specs(),
        "final_norm": L.norm_specs(cfg.norm_type),
        "blocks": {name: _add_leading(_block_specs(entry, cfg))
                   for name, entry in zip(_block_names(cfg), cfg.layer_pattern)},
    }
    if not cfg.tie_embeddings:
        specs["lm_head"] = P(None, L.MODEL)
    return specs


# ---------------------------------------------------------------------------
# Forward (train / prefill)
# ---------------------------------------------------------------------------

def _apply_block(entry: str, bp: Params, x, cfg, positions,
                 adapters=None, lora_scale=1.0, cache=None,
                 adapter_ids=None, paged=None, n_new=None):
    """One layer. Returns (x, new_cache, aux).  ``n_new``: (B,) int32 valid
    leading tokens per row in a ragged prefill chunk (see prefill_step)."""
    mixer, mlp = _parse(entry)
    ad = adapters or {}
    aux = jnp.zeros((), jnp.float32)
    h = L.apply_norm(bp["norm1"], x, cfg.norm_type)
    if mixer == "attn":
        out, new_mix_cache = L.multihead_attention(
            bp["mixer"], h, cfg, positions, ad.get("mixer"), lora_scale,
            kv_cache=cache, adapter_ids=adapter_ids, paged=paged)
    else:
        out, new_mix_cache = mamba2.apply_mamba(
            bp["mixer"], h, cfg, ad.get("mixer"), lora_scale, ssm_cache=cache,
            adapter_ids=adapter_ids, n_new=n_new)
    x = x + out
    if mlp != "none":
        h = L.apply_norm(bp["norm2"], x, cfg.norm_type)
        if mlp == "mlp":
            out = L.apply_mlp(bp["mlp"], h, cfg.mlp_type, ad.get("mlp"),
                              lora_scale, adapter_ids=adapter_ids)
        else:
            out, aux = moe_lib.apply_moe(bp["mlp"], h, cfg, ad.get("mlp"),
                                         lora_scale, adapter_ids=adapter_ids)
        x = x + out
    return x, new_mix_cache, aux


def forward(params: Params, tokens: jnp.ndarray, cfg,
            adapters: Optional[Params] = None, lora_scale: float = 1.0,
            extra_embeds: Optional[jnp.ndarray] = None,
            last_only: bool = False,
            adapter_ids: Optional[jnp.ndarray] = None
            ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """tokens: (B, S_text) int32. extra_embeds: (B, P, d) prepended (VLM).

    ``adapter_ids``: (B,) int32 per-row client slots when ``adapters`` is a
    stacked multi-tenant bank (leaves (n_periods, C, d_in, r)).

    Returns (logits (B, S, V), aux_loss scalar)."""
    dtype = L.dt(cfg.dtype)
    x = params["embed"].astype(dtype)[tokens]
    if cfg.family == "dense" and cfg.tie_embeddings:
        x = x * jnp.asarray(cfg.d_model ** 0.5, dtype)  # gemma-style scaling
    if extra_embeds is not None:
        x = jnp.concatenate([extra_embeds.astype(dtype), x], axis=1)
    B, S, _ = x.shape
    x = L.maybe_shard(x, P(("pod", "data"), None, None))
    # data-dependence defeats XLA constant-folding of the (S, S) causal mask
    # (a 1 GiB bool fold at S=32k that dominates compile time otherwise)
    positions = jnp.arange(S, dtype=jnp.int32) + tokens[0, 0] * 0

    block_names = _block_names(cfg)
    ad_blocks = (adapters or {}).get("blocks", {})

    def period_body(carry, xs):
        x, aux = carry
        for name in block_names:
            entry = cfg.layer_pattern[block_names.index(name)]
            x, _, a = _apply_block(entry, xs[name], x, cfg, positions,
                                   xs.get("__ad_" + name), lora_scale,
                                   adapter_ids=adapter_ids)
            aux = aux + a
        return (x, aux), None

    xs = dict(params["blocks"])
    for name in block_names:
        if name in ad_blocks:
            xs["__ad_" + name] = ad_blocks[name]
    if cfg.remat:
        policy = (jax.checkpoint_policies.dots_with_no_batch_dims_saveable
                  if cfg.remat_policy == "dots" else None)
        body = jax.checkpoint(period_body, policy=policy)
    else:
        body = period_body
    (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)), xs,
                           unroll=min(cfg.scan_unroll, cfg.n_periods))

    if last_only:  # serving prefill: unembed only the final position
        x = x[:, -1:]
    x = L.apply_norm(params["final_norm"], x, cfg.norm_type)
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    logits = L.matmul(x, head.astype(dtype), out_dtype=jnp.float32)
    return logits, aux


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------

def init_decode_cache(cfg, batch: int, cache_len: int) -> Params:
    """cache_len: full context for dense attention, window for SW archs."""
    cache: Params = {"blocks": {}}
    for name, entry in zip(_block_names(cfg), cfg.layer_pattern):
        mixer, _ = _parse(entry)
        if mixer == "attn":
            eff = min(cache_len, cfg.sliding_window) if cfg.sliding_window else cache_len
            one = lambda: L.init_kv_cache(cfg, batch, eff, jnp.bfloat16)
        else:
            one = lambda: mamba2.init_ssm_cache(cfg, batch)
        cache["blocks"][name] = jax.tree.map(
            lambda *ls: jnp.stack(ls), *[one() for _ in range(cfg.n_periods)])
    return cache


def decode_cache_specs(cfg) -> Params:
    specs: Params = {"blocks": {}}
    for name, entry in zip(_block_names(cfg), cfg.layer_pattern):
        mixer, _ = _parse(entry)
        base = L.kv_cache_specs() if mixer == "attn" else mamba2.ssm_cache_specs()
        specs["blocks"][name] = _add_leading(base)
    return specs


def init_paged_decode_cache(cfg, num_slots: int, num_blocks: int,
                            block_size: int, kv_dtype: str = "f32") -> Params:
    """Serving-path cache for continuous batching: attention layers share one
    K/V block pool (slots reference blocks through the scheduler's block
    table); SSM/Mamba rows keep dense per-slot recurrent state.

    ``kv_dtype="int8"`` stores the K/V pools quantized with per-block fp32
    scale leaves (see :func:`layers.init_paged_kv_cache`); SSM state stays
    dense fp32 either way."""
    cache: Params = {"blocks": {}}
    for name, entry in zip(_block_names(cfg), cfg.layer_pattern):
        mixer, _ = _parse(entry)
        if mixer == "attn":
            one = lambda: L.init_paged_kv_cache(cfg, num_blocks, block_size,
                                                jnp.bfloat16,
                                                kv_dtype=kv_dtype)
        else:
            one = lambda: mamba2.init_ssm_cache(cfg, num_slots)
        cache["blocks"][name] = jax.tree.map(
            lambda *ls: jnp.stack(ls), *[one() for _ in range(cfg.n_periods)])
    return cache


def paged_decode_cache_specs(cfg, kv_dtype: str = "f32") -> Params:
    specs: Params = {"blocks": {}}
    for name, entry in zip(_block_names(cfg), cfg.layer_pattern):
        mixer, _ = _parse(entry)
        base = (L.paged_kv_cache_specs(kv_dtype) if mixer == "attn"
                else mamba2.ssm_cache_specs())
        specs["blocks"][name] = _add_leading(base)
    return specs


def _resolve_backend(cfg, paged_backend: Optional[str]):
    """Per-call override of ``cfg.paged_backend`` (the serving engine
    threads ``ServeConfig.paged_backend`` here; ``None`` keeps the config
    default).  Config replacement keeps the flag on ``cfg`` — the one
    object the attention layer already reads."""
    if paged_backend is None or paged_backend == cfg.paged_backend:
        return cfg
    if paged_backend not in ("jnp", "pallas"):
        raise ValueError(f"unknown paged_backend {paged_backend!r}")
    return cfg.with_overrides(paged_backend=paged_backend)


def decode_step(params: Params, cache: Params, tokens: jnp.ndarray,
                pos: jnp.ndarray, cfg,
                adapters: Optional[Params] = None, lora_scale: float = 1.0,
                adapter_ids: Optional[jnp.ndarray] = None,
                block_tables: Optional[jnp.ndarray] = None,
                paged_backend: Optional[str] = None
                ) -> Tuple[jnp.ndarray, Params]:
    """One decode step. tokens: (B, 1) int32; pos: scalar int32 (tokens
    already in the cache). ``adapter_ids``: (B,) int32 client slots for
    multi-tenant banked adapters.

    Continuous batching: pass ``block_tables`` (B, MB) int32 and a *per-row*
    ``pos`` (B,) int32 of ragged context lengths; the cache must come from
    :func:`init_paged_decode_cache`. ``paged_backend`` overrides
    ``cfg.paged_backend`` ("jnp" gather oracle | "pallas" kernels).
    Returns (logits (B, 1, V), new cache)."""
    cfg = _resolve_backend(cfg, paged_backend)
    if block_tables is not None:
        pos = pos.astype(jnp.int32)                  # (B,) ragged lengths
        positions = pos[:, None]                     # (B, S=1) for RoPE
        paged = (block_tables, pos)
    else:
        positions = (pos[None].astype(jnp.int32) if pos.ndim == 0
                     else pos.astype(jnp.int32))
        paged = None
    return _cached_scan(params, cache, tokens, positions, cfg, adapters,
                        lora_scale, adapter_ids, paged=paged, n_new=None)


def prefill_step(params: Params, cache: Params, tokens: jnp.ndarray,
                 pos: jnp.ndarray, n_new: jnp.ndarray, cfg,
                 adapters: Optional[Params] = None, lora_scale: float = 1.0,
                 adapter_ids: Optional[jnp.ndarray] = None,
                 block_tables: Optional[jnp.ndarray] = None,
                 paged_backend: Optional[str] = None
                 ) -> Tuple[jnp.ndarray, Params]:
    """Chunked paged prefill: one dispatch consumes a whole prompt chunk.

    tokens: (B, T) int32 — up to T prompt tokens per serving slot, of which
    ``n_new[b]`` are valid (ragged chunks; tail positions are padding whose
    K/V scatters to scratch block 0 and whose SSM updates are masked out).
    pos: (B,) int32 per-row context lengths already written; the chunk
    occupies positions ``pos[b] .. pos[b] + n_new[b] - 1``.  Requires a
    paged cache (:func:`init_paged_decode_cache`) and ``block_tables``
    whose rows cover ``pos + n_new`` positions (the host scheduler grows
    tables before each chunk).

    Returns (logits (B, T, V), new cache) — the serving engine samples each
    row's logits at its last valid position to seed decoding."""
    cfg = _resolve_backend(cfg, paged_backend)
    if block_tables is None:
        raise ValueError("prefill_step requires block_tables (paged cache)")
    T = tokens.shape[1]
    pos = pos.astype(jnp.int32)
    n_new = n_new.astype(jnp.int32)
    positions = pos[:, None] + jnp.arange(T, dtype=jnp.int32)[None, :]
    return _cached_scan(params, cache, tokens, positions, cfg, adapters,
                        lora_scale, adapter_ids,
                        paged=(block_tables, pos, n_new), n_new=n_new)


def _cached_scan(params: Params, cache: Params, tokens: jnp.ndarray,
                 positions: jnp.ndarray, cfg, adapters, lora_scale,
                 adapter_ids, paged, n_new) -> Tuple[jnp.ndarray, Params]:
    """Shared cache-threading scaffold of decode_step / prefill_step:
    embed, period scan with per-block caches, final norm, unembed."""
    dtype = L.dt(cfg.dtype)
    x = params["embed"].astype(dtype)[tokens]
    if cfg.family == "dense" and cfg.tie_embeddings:
        x = x * jnp.asarray(cfg.d_model ** 0.5, dtype)
    # serving: batch rows (slots) are data-parallel over the mesh; the
    # sharded engine keeps slots shard-contiguous, so partitioning the
    # fused batch axis here lands each KV shard's rows on its devices.
    # No-op when tracing without a mesh (the single-device bitwise path).
    x = L.maybe_shard(x, P("data", None, None))

    block_names = _block_names(cfg)
    ad_blocks = (adapters or {}).get("blocks", {})

    def period_body(x, xs):
        new_caches = {}
        for name in block_names:
            entry = cfg.layer_pattern[block_names.index(name)]
            x, nc, _ = _apply_block(entry, xs[name], x, cfg, positions,
                                    xs.get("__ad_" + name), lora_scale,
                                    cache=xs["__cache_" + name],
                                    adapter_ids=adapter_ids, paged=paged,
                                    n_new=n_new)
            new_caches[name] = nc
        return x, new_caches

    xs = dict(params["blocks"])
    for name in block_names:
        xs["__cache_" + name] = cache["blocks"][name]
        if name in ad_blocks:
            xs["__ad_" + name] = ad_blocks[name]
    x, new_caches = jax.lax.scan(period_body, x, xs,
                             unroll=min(cfg.scan_unroll, cfg.n_periods))

    x = L.apply_norm(params["final_norm"], x, cfg.norm_type)
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    logits = L.matmul(x, head.astype(dtype), out_dtype=jnp.float32)
    return logits, {"blocks": new_caches}
