"""Mamba2 (SSD — state-space duality, arXiv:2405.21060) block.

TPU adaptation (DESIGN.md §2): the SSD "chunked" algorithm is matmul-form —
intra-chunk attention-like matmuls feed the MXU, inter-chunk recurrence is a
short ``lax.scan`` over chunk states. Decode keeps an explicit recurrent state
``h: (B, n_heads, head_dim, d_state)`` so one-token steps are O(1) in seq len
(this is what makes ``long_500k`` native for SSM/hybrid architectures).

Parameterisation follows the Mamba2 reference: a single ``in_proj`` produces
(z, x, B, C, dt); depthwise causal conv over (x, B, C); scalar-per-head decay
A; gated RMSNorm before ``out_proj``.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.layers import MODEL, dense, lora_pair

Params = Dict[str, Any]


def _dims(cfg):
    d_in = cfg.ssm_d_inner
    n_h = cfg.ssm_n_heads
    d_st = cfg.ssm_d_state
    n_g = cfg.ssm_n_groups
    conv_dim = d_in + 2 * n_g * d_st
    proj_dim = 2 * d_in + 2 * n_g * d_st + n_h
    return d_in, n_h, d_st, n_g, conv_dim, proj_dim


def init_mamba(key, cfg, dtype) -> Params:
    d = cfg.d_model
    d_in, n_h, d_st, n_g, conv_dim, proj_dim = _dims(cfg)
    ks = jax.random.split(key, 5)
    s = d ** -0.5
    return {
        "in_proj": (jax.random.normal(ks[0], (d, proj_dim)) * s).astype(dtype),
        "conv_w": (jax.random.normal(ks[1], (cfg.ssm_d_conv, conv_dim)) * 0.1).astype(dtype),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, n_h)).astype(jnp.float32),
        "dt_bias": jnp.zeros((n_h,), dtype=jnp.float32),
        "d_skip": jnp.ones((n_h,), dtype=jnp.float32),
        "norm_scale": jnp.ones((d_in,), dtype=jnp.float32),
        "out_proj": (jax.random.normal(ks[2], (d_in, d)) * d_in ** -0.5).astype(dtype),
    }


def mamba_specs(cfg) -> Params:
    return {
        "in_proj": P(None, MODEL),
        "conv_w": P(None, MODEL),
        "a_log": P(None),
        "dt_bias": P(None),
        "d_skip": P(None),
        "norm_scale": P(MODEL),
        "out_proj": P(MODEL, None),
    }


def _split_proj(cfg, zxbcdt):
    d_in, n_h, d_st, n_g, _, _ = _dims(cfg)
    z, xbc, dt = jnp.split(zxbcdt, [d_in, 2 * d_in + 2 * n_g * d_st], axis=-1)
    return z, xbc, dt


def _causal_conv(xbc: jnp.ndarray, w: jnp.ndarray,
                 state: Optional[jnp.ndarray] = None,
                 n_valid: Optional[jnp.ndarray] = None):
    """Depthwise causal conv1d. xbc: (B, S, C), w: (K, C).

    Training: zero left-pad. Decode: ``state`` is the last K-1 inputs
    (B, K-1, C); returns updated state.  Chunked decode with ragged fill:
    ``n_valid`` (B,) int32 counts the valid leading tokens per row — the new
    state is the last K-1 inputs ENDING at each row's valid fill, so rows
    fed only padding keep their state bit-for-bit.
    """
    K = w.shape[0]
    if state is None:
        pad = jnp.zeros((xbc.shape[0], K - 1, xbc.shape[2]), dtype=xbc.dtype)
        xp = jnp.concatenate([pad, xbc], axis=1)
        new_state = xp[:, -(K - 1):, :]
    else:
        xp = jnp.concatenate([state.astype(xbc.dtype), xbc], axis=1)
        if n_valid is None:
            new_state = xp[:, -(K - 1):, :]
        else:
            idx = n_valid[:, None] + jnp.arange(K - 1, dtype=jnp.int32)[None]
            new_state = jnp.take_along_axis(xp, idx[:, :, None], axis=1)
    # windowed sum: out[t] = sum_k w[k] * xp[t + k]
    out = jnp.zeros_like(xbc, dtype=jnp.float32)
    S = xbc.shape[1]
    for k in range(K):
        out = out + xp[:, k:k + S, :].astype(jnp.float32) * w[k].astype(jnp.float32)
    return jax.nn.silu(out).astype(xbc.dtype), new_state


def _gated_norm(x, z, scale, eps=1e-6):
    xf = x.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale).astype(x.dtype)


def ssd_chunked(x, dt, A, Bm, Cm, chunk: int):
    """Chunked SSD scan (matmul form).

    x:  (B, S, H, P)   inputs per head
    dt: (B, S, H)      softplus'd timestep (>0)
    A:  (H,)           negative decay rate (A < 0)
    Bm: (B, S, G, N)   input->state projection
    Cm: (B, S, G, N)   state->output projection
    Returns y: (B, S, H, P), final_state: (B, H, P, N).
    """
    Bsz, S, H, Pd = x.shape
    G = Bm.shape[2]
    N = Bm.shape[3]
    assert S % chunk == 0, (S, chunk)
    nc = S // chunk
    rep = H // G

    f32 = jnp.float32
    x = x.astype(f32)
    dt = dt.astype(f32)
    Bm = jnp.repeat(Bm.astype(f32), rep, axis=2)   # (B,S,H,N)
    Cm = jnp.repeat(Cm.astype(f32), rep, axis=2)

    def reshape_c(t):
        return t.reshape((Bsz, nc, chunk) + t.shape[2:])

    xc, dtc, Bc, Cc = map(reshape_c, (x, dt, Bm, Cm))

    # per-step log decay  a_t = A * dt_t  (A<0)
    la = dtc * A[None, None, None, :]              # (B,nc,c,H)
    cum = jnp.cumsum(la, axis=2)                   # running within chunk
    # intra-chunk: y_intra[t] = sum_{s<=t} C_t . B_s x_s dt_s * exp(cum_t - cum_s)
    # Mask BEFORE the exp: for s > t the exponent is positive-large; exp would
    # overflow to inf and the masked backward produces 0·inf = NaN.
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # (B,nc,t,s,H)
    idx = jnp.arange(chunk)
    causal = (idx[:, None] >= idx[None, :])[None, None, :, :, None]
    decay = jnp.exp(jnp.where(causal, diff, -1e30))
    cb = jnp.einsum("bzthn,bzshn->bztsh", Cc, Bc)  # (B,nc,t,s,H)
    xdt = xc * dtc[..., None]                      # (B,nc,c,H,P)
    y_intra = jnp.einsum("bztsh,bzshp->bzthp", cb * decay, xdt)

    # chunk-level states: state_z = sum_s exp(cum_end - cum_s) B_s x_s dt_s
    seg = jnp.exp(cum[:, :, -1:, :] - cum)         # (B,nc,c,H)
    states = jnp.einsum("bzsh,bzshn,bzshp->bzhpn", seg, Bc, xdt)
    chunk_decay = jnp.exp(cum[:, :, -1, :])        # (B,nc,H)

    # inter-chunk recurrence over nc chunks
    def step(h, inp):
        st, cd = inp                               # (B,H,P,N), (B,H)
        h_prev = h
        h = h * cd[:, :, None, None] + st
        return h, h_prev

    h0 = jnp.zeros((Bsz, H, Pd, N), dtype=f32)
    states_t = jnp.moveaxis(states, 1, 0)          # (nc,B,H,P,N)
    cd_t = jnp.moveaxis(chunk_decay, 1, 0)         # (nc,B,H)
    h_final, h_prevs = jax.lax.scan(step, h0, (states_t, cd_t))
    h_prevs = jnp.moveaxis(h_prevs, 0, 1)          # (B,nc,H,P,N) state entering chunk

    # contribution of the entering state to each position
    into = jnp.exp(cum)                            # decay from chunk start to t
    y_inter = jnp.einsum("bzth,bzthn,bzhpn->bzthp", into, Cc, h_prevs)

    y = (y_intra + y_inter).reshape(Bsz, S, H, Pd)
    return y, h_final


def apply_mamba(params: Params, x: jnp.ndarray, cfg,
                adapters: Optional[Params] = None, lora_scale: float = 1.0,
                ssm_cache: Optional[Params] = None,
                adapter_ids: Optional[jnp.ndarray] = None,
                n_new: Optional[jnp.ndarray] = None):
    """x: (B, S, d) -> (out, new_cache).

    ``ssm_cache`` = {"h": (B,H,P,N), "conv": (B,K-1,conv_dim)} for decode.
    Decode accepts S >= 1 (chunked prefill): the recurrence steps through
    the chunk with the exact per-token update ops, so a multi-token chunk
    is bitwise-equal to S one-token calls.  ``n_new`` (B,) int32 marks each
    row's valid leading tokens (ragged chunks): rows beyond their fill get
    dt masked to 0 — decay exp(0)=1, update 0 — so their recurrent and conv
    state pass through untouched.
    """
    B, S, d = x.shape
    d_in, n_h, d_st, n_g, conv_dim, _ = _dims(cfg)
    la = partial(lora_pair, adapters)

    zxbcdt = dense(x, params["in_proj"], la("in_proj"), lora_scale,
                   adapter_ids=adapter_ids)
    z, xbc, dt_raw = _split_proj(cfg, zxbcdt)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])

    conv_state = ssm_cache["conv"] if ssm_cache is not None else None
    xbc, new_conv = _causal_conv(xbc, params["conv_w"], conv_state,
                                 n_valid=n_new if ssm_cache is not None
                                 else None)
    xs, Bm, Cm = jnp.split(xbc, [d_in, d_in + n_g * d_st], axis=-1)
    xs = xs.reshape(B, S, n_h, cfg.ssm_head_dim)
    Bm = Bm.reshape(B, S, n_g, d_st)
    Cm = Cm.reshape(B, S, n_g, d_st)
    A = -jnp.exp(params["a_log"])                  # (H,) negative

    if ssm_cache is None:
        chunk = min(cfg.ssm_chunk, S)
        y, h = ssd_chunked(xs, dt, A, Bm, Cm, chunk)
    elif S == 1:
        # single-token recurrent update: h' = h*exp(dt*A) + dt * B x^T
        h = ssm_cache["h"].astype(jnp.float32)
        rep = n_h // n_g
        Bh = jnp.repeat(Bm[:, 0].astype(jnp.float32), rep, axis=1)  # (B,H,N)
        Ch = jnp.repeat(Cm[:, 0].astype(jnp.float32), rep, axis=1)
        dt0 = dt[:, 0]                                               # (B,H)
        if n_new is not None:
            dt0 = jnp.where(n_new[:, None] > 0, dt0, 0.0)
        decay = jnp.exp(dt0 * A[None, :])                            # (B,H)
        upd = jnp.einsum("bh,bhp,bhn->bhpn", dt0, xs[:, 0].astype(jnp.float32), Bh)
        h = h * decay[:, :, None, None] + upd
        y = jnp.einsum("bhpn,bhn->bhp", h, Ch)[:, None]              # (B,1,H,P)
    else:
        # chunked recurrent decode: the SAME per-token update as the S==1
        # branch, stepped over the chunk — invalid tail tokens (t >= n_new)
        # carry dt=0 and pass h through unchanged.
        h = ssm_cache["h"].astype(jnp.float32)
        rep = n_h // n_g
        Bh = jnp.repeat(Bm.astype(jnp.float32), rep, axis=2)  # (B,S,H,N)
        Ch = jnp.repeat(Cm.astype(jnp.float32), rep, axis=2)
        dtm = dt
        if n_new is not None:
            valid = jnp.arange(S, dtype=jnp.int32)[None, :] < n_new[:, None]
            dtm = jnp.where(valid[:, :, None], dt, 0.0)

        def step(h, inp):
            x_t, b_t, c_t, dt_t = inp
            decay = jnp.exp(dt_t * A[None, :])
            upd = jnp.einsum("bh,bhp,bhn->bhpn", dt_t, x_t, b_t)
            h = h * decay[:, :, None, None] + upd
            y_t = jnp.einsum("bhpn,bhn->bhp", h, c_t)
            return h, y_t

        h, ys = jax.lax.scan(
            step, h, (jnp.moveaxis(xs.astype(jnp.float32), 1, 0),
                      jnp.moveaxis(Bh, 1, 0), jnp.moveaxis(Ch, 1, 0),
                      jnp.moveaxis(dtm, 1, 0)))
        y = jnp.moveaxis(ys, 0, 1)                                   # (B,S,H,P)

    y = y + xs.astype(jnp.float32) * params["d_skip"][None, None, :, None]
    y = y.reshape(B, S, d_in).astype(x.dtype)
    y = _gated_norm(y, z, params["norm_scale"])
    out = dense(y, params["out_proj"], la("out_proj"), lora_scale,
                adapter_ids=adapter_ids)
    new_cache = {"h": h.astype(jnp.float32), "conv": new_conv}
    return out, new_cache


def init_ssm_cache(cfg, batch: int) -> Params:
    d_in, n_h, d_st, n_g, conv_dim, _ = _dims(cfg)
    return {
        "h": jnp.zeros((batch, n_h, cfg.ssm_head_dim, d_st), dtype=jnp.float32),
        "conv": jnp.zeros((batch, cfg.ssm_d_conv - 1, conv_dim), dtype=jnp.bfloat16),
    }


def ssm_cache_specs() -> Params:
    from repro.models.layers import DATA
    return {"h": P(DATA, MODEL, None, None), "conv": P(DATA, None, MODEL)}
