"""Shared neural-net primitives (pure-functional, param-dict based).

Conventions
-----------
* Parameters are nested dicts of ``jnp.ndarray``; init functions mirror the
  apply functions. Every init has a matching ``*_specs`` producing a
  :class:`jax.sharding.PartitionSpec` tree with axes named ``data`` / ``model``
  (mesh axis names are bound later by the launcher).
* Linear layers optionally take a LoRA adapter ``(A, B)``; the adapter path is
  ``y = x@W + (alpha/r) * (x@A)@B`` with the base weight frozen.
* All matmuls accumulate in fp32 (``preferred_element_type``) and cast back.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.kernels import ops as kernel_ops
from repro.kernels.paged_prefill import paged_scatter, paged_scatter_quant

Params = Dict[str, Any]

# Mesh-axis aliases used in spec trees. The launcher rewrites "model"/"data"
# to real mesh axes; "None" dims are replicated.
MODEL = "model"
DATA = "data"


# ---------------------------------------------------------------------------
# dtype helpers
# ---------------------------------------------------------------------------

def dt(name: str):
    return jnp.dtype(name)


def _current_mesh():
    """The mesh in scope, or None. jax 0.4.37 has no
    ``jax.sharding.get_abstract_mesh``; fall back to the thread-resources
    physical mesh (set by ``with Mesh(...)``)."""
    get_abstract = getattr(jax.sharding, "get_abstract_mesh", None)
    if get_abstract is not None:
        return get_abstract()
    try:
        return jax.interpreters.pxla.thread_resources.env.physical_mesh
    except AttributeError:
        return None


def maybe_shard(x: jnp.ndarray, spec: P) -> jnp.ndarray:
    """with_sharding_constraint that no-ops when tracing without a mesh
    (CPU smoke tests) or when the spec names axes the mesh lacks."""
    mesh = _current_mesh()
    if mesh is None or mesh.empty:
        return x
    axes = set(mesh.axis_names)
    fixed = []
    for entry in spec:
        names = entry if isinstance(entry, tuple) else (entry,)
        kept = tuple(n for n in names if n is None or n in axes)
        kept = tuple(n for n in kept if n is not None)
        fixed.append(kept if len(kept) > 1 else (kept[0] if kept else None))
    return jax.lax.with_sharding_constraint(x, P(*fixed))


def matmul(x, w, *, out_dtype=None):
    """x @ w with fp32 accumulation."""
    y = jnp.matmul(x, w, preferred_element_type=jnp.float32)
    return y.astype(out_dtype or x.dtype)


def lora_delta(x: jnp.ndarray, a: jnp.ndarray, b: jnp.ndarray,
               adapter_ids: Optional[jnp.ndarray] = None,
               a_scale: Optional[jnp.ndarray] = None,
               b_scale: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """fp32 LoRA update (x·A)·B, single-tenant or banked.

    Single-tenant: ``a: (d_in, r)``, ``b: (r, d_out)``. Multi-tenant serving:
    ``a: (C, d_in, r)``, ``b: (C, r, d_out)`` stacked client banks with
    ``adapter_ids: (B,)`` int32 selecting one adapter per batch row of
    ``x: (B, S, d_in)`` (the pure-jnp oracle of the batched Pallas kernel —
    the kernel path never materialises the per-row gather in HBM).

    int8 banks (``AdapterRegistry(bank_dtype="int8")``) carry one fp32
    quantization scale per client and factor: ``a_scale``/``b_scale`` (C,).
    The gathered per-row factors dequantize before the fp32 matmul chain.

    Ragged-rank banks (``AdapterRegistry(ranks=[...])``) arrive as
    per-bucket LISTS of stacked arrays: rows route to the bucket holding
    their global slot (bucket boundaries are static — read from shapes — so
    the select stays jit/scan-stable).  Each bucket evaluates at its own
    rank; zero rank-padding inside a bucket is arithmetically inert, so the
    result is bitwise the per-client native-rank delta.
    """
    if isinstance(a, (list, tuple)):  # ragged bank: route rows by bucket
        if adapter_ids is None:
            raise ValueError("banked LoRA leaves need adapter_ids")
        out, off = None, 0
        for i, (ab, bb) in enumerate(zip(a, b)):
            cb = ab.shape[0]
            local = jnp.clip(adapter_ids - off, 0, cb - 1)
            d = lora_delta(x, ab, bb, local,
                           a_scale[i] if a_scale is not None else None,
                           b_scale[i] if b_scale is not None else None)
            in_bucket = (adapter_ids >= off) & (adapter_ids < off + cb)
            mask = in_bucket.reshape((-1,) + (1,) * (d.ndim - 1))
            out = d if out is None else jnp.where(mask, d, out)
            off += cb
        return out
    xf = x.astype(jnp.float32)
    if a.ndim == 3:  # banked: per-row client routing
        if adapter_ids is None:
            raise ValueError("banked LoRA leaves need adapter_ids")
        ag = jnp.take(a.astype(jnp.float32), adapter_ids, axis=0)  # (B, d, r)
        bg = jnp.take(b.astype(jnp.float32), adapter_ids, axis=0)  # (B, r, n)
        if a_scale is not None:
            ag = ag * jnp.take(a_scale, adapter_ids, axis=0)[:, None, None]
            bg = bg * jnp.take(b_scale, adapter_ids, axis=0)[:, None, None]
        z = jnp.einsum("b...k,bkr->b...r", xf, ag)
        return jnp.einsum("b...r,brn->b...n", z, bg)
    af = a.astype(jnp.float32)
    bf = b.astype(jnp.float32)
    if a_scale is not None:
        af = af * a_scale
        bf = bf * b_scale
    z = jnp.matmul(xf, af)
    return jnp.matmul(z, bf)


def lora_pair(adapters: Optional[Params], name: str):
    """The LoRA tuple :func:`dense` expects for one adapter target, or
    ``None`` when the target carries no adapter.  fp32 targets yield
    ``(A, B)``; int8 bank targets (which store per-client ``a_scale`` /
    ``b_scale`` leaves next to the factors) yield the 4-tuple
    ``(A, B, a_scale, b_scale)``.  Every layer that routes adapters into
    ``dense`` goes through this helper so the int8 layout has exactly one
    decoding site."""
    if adapters is None or name not in adapters:
        return None
    ad = adapters[name]
    if "a_scale" in ad:
        return (ad["a"], ad["b"], ad["a_scale"], ad["b_scale"])
    return (ad["a"], ad["b"])


def dense(x: jnp.ndarray, w: jnp.ndarray,
          lora: Optional[Tuple[jnp.ndarray, ...]] = None,
          lora_scale: float = 1.0,
          adapter_ids: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Linear layer with optional LoRA adapter.

    ``lora`` is ``(A, B)`` with A: (d_in, r) fp32, B: (r, d_out) fp32 — or
    banked ``(C, d_in, r)`` / ``(C, r, d_out)`` with per-row ``adapter_ids``
    (multi-tenant serving; see :func:`lora_delta`), optionally extended to
    ``(A, B, a_scale, b_scale)`` for int8 banks (see :func:`lora_pair`).
    The adapter path always computes in fp32 (adapters are the trainable,
    numerically sensitive part) and is added to the frozen base output.
    """
    y = matmul(x, w.astype(x.dtype))
    if lora is not None:
        a, b, *scales = lora
        z = lora_delta(x, a, b, adapter_ids, *scales)
        y = (y.astype(jnp.float32) + lora_scale * z).astype(y.dtype)
    return y


# ---------------------------------------------------------------------------
# Normalisation
# ---------------------------------------------------------------------------

def init_norm(key, d: int, norm_type: str, dtype) -> Params:
    if norm_type == "rmsnorm":
        return {"scale": jnp.ones((d,), dtype=jnp.float32)}
    if norm_type == "layernorm":
        return {"scale": jnp.ones((d,), dtype=jnp.float32),
                "bias": jnp.zeros((d,), dtype=jnp.float32)}
    if norm_type == "nonparametric":
        return {}
    raise ValueError(norm_type)


def norm_specs(norm_type: str) -> Params:
    if norm_type == "rmsnorm":
        return {"scale": P(None)}
    if norm_type == "layernorm":
        return {"scale": P(None), "bias": P(None)}
    return {}


def apply_norm(params: Params, x: jnp.ndarray, norm_type: str,
               eps: float = 1e-6) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    if norm_type == "rmsnorm":
        var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + eps) * params["scale"]
    else:
        mean = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mean) * jax.lax.rsqrt(var + eps)
        if norm_type == "layernorm":
            y = y * params["scale"] + params["bias"]
        # nonparametric (OLMo): no affine params
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., S, n_heads, head_dim); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = rope_frequencies(hd, theta)                       # (hd/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos = jnp.cos(angles)[..., :, None, :]                    # (..., S, 1, hd/2)
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention (GQA / MQA, optional sliding window, optional logit softcap)
# ---------------------------------------------------------------------------

def init_attention(key, cfg, dtype) -> Params:
    d, H, Kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s = d ** -0.5
    return {
        "wq": (jax.random.normal(k1, (d, H * hd)) * s).astype(dtype),
        "wk": (jax.random.normal(k2, (d, Kv * hd)) * s).astype(dtype),
        "wv": (jax.random.normal(k3, (d, Kv * hd)) * s).astype(dtype),
        "wo": (jax.random.normal(k4, (H * hd, d)) * s).astype(dtype),
    }


def attention_specs(cfg) -> Params:
    # Head (output) dim of projections sharded on the model axis; wo sharded
    # on its input (head) dim. d_model stays replicated -> activations only
    # need a reduce-scatter/all-reduce at block boundaries.
    return {"wq": P(None, MODEL), "wk": P(None, MODEL),
            "wv": P(None, MODEL), "wo": P(MODEL, None)}


def repeat_kv(x: jnp.ndarray, n_rep: int) -> jnp.ndarray:
    """(B, S, Kv, hd) -> (B, S, Kv*n_rep, hd)"""
    if n_rep == 1:
        return x
    b, s, kv, hd = x.shape
    x = jnp.broadcast_to(x[:, :, :, None, :], (b, s, kv, n_rep, hd))
    return x.reshape(b, s, kv * n_rep, hd)


def _attn_mask(q_pos: jnp.ndarray, k_pos: jnp.ndarray,
               sliding_window: int) -> jnp.ndarray:
    """Boolean mask (..., Sq, Sk): True = attend."""
    causal = k_pos[None, :] <= q_pos[:, None]
    if sliding_window > 0:
        causal &= k_pos[None, :] > (q_pos[:, None] - sliding_window)
    return causal




def _sdpa(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, cfg,
          mask: Optional[jnp.ndarray], out_dtype) -> jnp.ndarray:
    """Masked softmax attention: q (B, Sq, H, hd), k/v (B, Sk, Kv, hd) ->
    (B, Sq, H*hd).  ``mask``: (Sq, Sk) shared, (B, Sq, Sk) per-row, or None.

    Grouped mode folds the q-heads-per-kv-head group into the einsum instead
    of materialising the (B, Sk, H, hd) repeated K/V."""
    B, Sq, H, hd = q.shape
    Kv = k.shape[2]
    scale = hd ** -0.5
    sm_dtype = dt(getattr(cfg, "attn_softmax_dtype", "float32"))
    grouped = getattr(cfg, "attn_impl", "repeat") == "grouped" and H != Kv

    if grouped:
        G = H // Kv
        qg = q.reshape(B, Sq, Kv, G, hd)
        logits = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k,
                            preferred_element_type=sm_dtype) * scale
    else:
        k = repeat_kv(k, H // Kv)
        v = repeat_kv(v, H // Kv)
        logits = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                            preferred_element_type=sm_dtype) * scale
    if cfg.attn_logit_softcap > 0:
        c = cfg.attn_logit_softcap
        logits = c * jnp.tanh(logits / c)
    if mask is not None:
        neg = jnp.asarray(-1e30 if sm_dtype == jnp.float32 else -3e38 / 10,
                          sm_dtype)
        if mask.ndim == 3:                             # per-row (B, Sq, Sk)
            shaped = mask[:, None, None] if grouped else mask[:, None]
        else:                                          # shared (Sq, Sk)
            shaped = mask[None, None, None] if grouped else mask[None, None]
        logits = jnp.where(shaped, logits, neg)
    probs = jax.nn.softmax(logits.astype(sm_dtype), axis=-1).astype(out_dtype)
    if grouped:
        out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, v,
                         preferred_element_type=jnp.float32).astype(out_dtype)
    else:
        out = jnp.einsum("bhqk,bkhd->bqhd", probs, v,
                         preferred_element_type=jnp.float32).astype(out_dtype)
    return out.reshape(B, Sq, H * hd)


def _paged_attention_pallas(params, q, k, v, x, cfg, kv_cache, block_tables,
                            lengths, n_new, dn, la):
    """The paged branch routed through the Pallas kernels
    (``cfg.paged_backend == "pallas"``; ``ServeConfig.paged_backend``
    threads it per stream).  Same scatter convention, same masks, same
    outputs up to online-softmax accumulation order as the jnp gather
    below — greedy token streams are bitwise-equal (tested), logits
    allclose.  ``cfg.pallas_interpret=False`` compiles the kernels on TPU.

    Decode steps (2-tuple ``paged``, S == 1) go through
    ``ops.paged_gqa_attention`` — the kernel's exclusive ``lengths`` is the
    post-scatter count, hence the +1; prefill chunks (3-tuple, ragged
    ``n_new``) through ``ops.paged_prefill_gqa_attention`` which owns the
    scatter."""
    B, S, H, hd = q.shape
    if cfg.sliding_window > 0 or cfg.attn_logit_softcap > 0:
        raise NotImplementedError(
            "paged_backend='pallas' supports full attention only (no "
            "sliding window / logit softcap); use paged_backend='jnp'")
    interp = cfg.pallas_interpret
    quant = "k_scale" in kv_cache             # int8 pools carry scale leaves
    if n_new is None and S == 1:
        if quant:
            kp, vp, ks, vs = paged_scatter_quant(
                kv_cache["k_pool"], kv_cache["v_pool"], kv_cache["k_scale"],
                kv_cache["v_scale"], k, v, block_tables, lengths, None)
            o = kernel_ops.paged_gqa_attention(
                q, kp, vp, block_tables, lengths + 1,
                k_scale=ks, v_scale=vs, interpret=interp)
        else:
            kp, vp = paged_scatter(kv_cache["k_pool"], kv_cache["v_pool"],
                                   k, v, block_tables, lengths, None)
            o = kernel_ops.paged_gqa_attention(
                q, kp, vp, block_tables, lengths + 1, interpret=interp)
    else:
        nn = (n_new if n_new is not None
              else jnp.full((B,), S, dtype=jnp.int32))
        if quant:
            o, kp, vp, ks, vs = kernel_ops.paged_prefill_gqa_attention(
                q, k, v, kv_cache["k_pool"], kv_cache["v_pool"], block_tables,
                lengths, nn, k_scale=kv_cache["k_scale"],
                v_scale=kv_cache["v_scale"], interpret=interp)
        else:
            o, kp, vp = kernel_ops.paged_prefill_gqa_attention(
                q, k, v, kv_cache["k_pool"], kv_cache["v_pool"], block_tables,
                lengths, nn, interpret=interp)
    out = dn(o.astype(x.dtype).reshape(B, S, H * hd), params["wo"], la("wo"))
    new_cache = {"k_pool": kp, "v_pool": vp}
    if quant:
        new_cache.update(k_scale=ks, v_scale=vs)
    return out, new_cache


def multihead_attention(params: Params, x: jnp.ndarray, cfg,
                        positions: jnp.ndarray,
                        adapters: Optional[Params] = None,
                        lora_scale: float = 1.0,
                        kv_cache: Optional[Params] = None,
                        causal: bool = True,
                        kv_override: Optional[Tuple] = None,
                        use_flash: bool = False,
                        adapter_ids: Optional[jnp.ndarray] = None,
                        paged: Optional[Tuple] = None):
    """Attention over x: (B, S, d).

    * training / prefill: ``kv_cache`` is None, causal (+ window) mask.
    * decode: ``kv_cache`` = {"k","v": (B, S_cache, Kv, hd), "pos": scalar
      next write offset}; x has S==1. Returns (out, new_cache).
    * paged decode / chunked paged prefill (continuous batching):
      ``kv_cache`` = {"k_pool","v_pool": (num_blocks, block_size, Kv, hd)}
      shared across slots and ``paged=(block_tables (B, MB) int32,
      lengths (B,) int32[, n_new (B,) int32])`` — row b holds ``lengths[b]``
      context tokens in the blocks named by its table row.  The S incoming
      tokens are scattered to positions ``lengths[b] + t`` through the
      table (with the 3-tuple form, rows ``t >= n_new[b]`` are redirected
      to scratch block 0 — host-side chunk raggedness), and each query
      attends ``[0, lengths[b] + t]``.  Under prefix caching a table row
      may name blocks SHARED with other slots (refcounted, sealed full by
      a previous owner): they are read-only by construction — writes start
      at ``lengths[b]``, which always lies in a private block — and the
      gather treats them identically, so a cache-hit slot is bitwise-equal
      to one that prefilled the same positions itself.  Attention is computed one chunk
      position at a time so a multi-token prefill chunk stays BITWISE equal
      to feeding the same tokens one decode step each (the probs·V matmul
      is not chunk-size-invariant on CPU).  The jnp gather below is the
      oracle; ``kernels/paged_attention.py`` (decode) and
      ``kernels/paged_prefill.py`` (chunk) are the TPU drop-ins that never
      materialise it in HBM.  Speculative-decoding VERIFY dispatches
      (``Model.verify_step``) are this same chunk path fed with drafted
      tokens — no extra kernel, and the per-position bitwise equality
      above is exactly what makes greedy draft-then-verify emit the
      non-speculative token stream (rejected positions are rolled back
      host-side; their scattered K/V is masked off by ``lengths`` and
      overwritten on the next write).
    * cross-attention (whisper): ``kv_override=(k, v)`` precomputed from the
      encoder; causal=False.
    """
    H, Kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    B, S, _ = x.shape
    la = partial(lora_pair, adapters)
    dn = partial(dense, lora_scale=lora_scale, adapter_ids=adapter_ids)

    q = dn(x, params["wq"], la("wq")).reshape(B, S, H, hd)
    if kv_override is None:
        k = dn(x, params["wk"], la("wk")).reshape(B, S, Kv, hd)
        v = dn(x, params["wv"], la("wv")).reshape(B, S, Kv, hd)
        if cfg.use_rope:
            q = apply_rope(q, positions, cfg.rope_theta)
            k = apply_rope(k, positions, cfg.rope_theta)
    else:
        k, v = kv_override

    new_cache = None
    if kv_cache is not None and paged is not None:
        # Paged path: scatter the S new K/V tokens to each row's
        # (block, offset) slots, then attend over the row's gathered blocks
        # with a per-row length mask. Blocks hold contiguous positions, so
        # gathered order == position order and softmax sums match the dense
        # ring buffer.
        if len(paged) == 3:
            block_tables, lengths, n_new = paged
        else:
            block_tables, lengths = paged             # (B, MB) i32, (B,) i32
            n_new = None
        if cfg.paged_backend == "pallas":
            out, new_cache = _paged_attention_pallas(
                params, q, k, v, x, cfg, kv_cache, block_tables, lengths,
                n_new, dn, la)
            return out, new_cache
        bs_blk = kv_cache["k_pool"].shape[1]
        pos = (lengths[:, None].astype(jnp.int32)
               + jnp.arange(S, dtype=jnp.int32)[None, :])  # write positions
        MB = block_tables.shape[1]
        L = MB * bs_blk
        if "k_scale" in kv_cache:             # int8 pools: dequant the gather
            kp, vp, ks, vs = paged_scatter_quant(
                kv_cache["k_pool"], kv_cache["v_pool"], kv_cache["k_scale"],
                kv_cache["v_scale"], k, v, block_tables, lengths, n_new)
            new_cache = {"k_pool": kp, "v_pool": vp,
                         "k_scale": ks, "v_scale": vs}
            # elementwise dequant keeps the per-position bitwise chunk
            # invariance below: values depend only on what was scattered,
            # never on how the chunk was split
            kg = (kp[block_tables].reshape(B, L, Kv, hd).astype(jnp.float32)
                  * ks[block_tables].reshape(B, L, Kv)[..., None]
                  ).astype(x.dtype)
            vg = (vp[block_tables].reshape(B, L, Kv, hd).astype(jnp.float32)
                  * vs[block_tables].reshape(B, L, Kv)[..., None]
                  ).astype(x.dtype)
        else:
            kp, vp = paged_scatter(kv_cache["k_pool"], kv_cache["v_pool"],
                                   k, v, block_tables, lengths, n_new)
            new_cache = {"k_pool": kp, "v_pool": vp}
            kg = kp[block_tables].reshape(B, L, Kv, hd).astype(x.dtype)
            vg = vp[block_tables].reshape(B, L, Kv, hd).astype(x.dtype)
        k_pos = jnp.arange(L, dtype=jnp.int32)        # slot-logical order
        # One attend per chunk position, each with the exact decode-step
        # shapes: q_pos = lengths + t, so the (B, L) causal+window mask
        # falls out of _attn_mask directly.
        outs = [_sdpa(q[:, t:t + 1], kg, vg, cfg,
                      _attn_mask(pos[:, t], k_pos,
                                 cfg.sliding_window)[:, None, :]
                      if causal else None, x.dtype)
                for t in range(S)]
        out = outs[0] if S == 1 else jnp.concatenate(outs, axis=1)
        out = dn(out, params["wo"], la("wo"))
        return out, new_cache
    elif kv_cache is not None:
        # Ring buffer: slot = absolute_position % cache_len. For full
        # attention the cache is allocated at full context length (no wrap);
        # for sliding-window archs it is window-sized and wraps.
        cache_len = kv_cache["k"].shape[1]
        write_idx = kv_cache["pos"] % cache_len
        ck = jax.lax.dynamic_update_slice(kv_cache["k"], k.astype(kv_cache["k"].dtype),
                                          (0, write_idx, 0, 0))
        cv = jax.lax.dynamic_update_slice(kv_cache["v"], v.astype(kv_cache["v"].dtype),
                                          (0, write_idx, 0, 0))
        new_cache = {"k": ck, "v": cv, "pos": kv_cache["pos"] + S}
        k, v = ck.astype(x.dtype), cv.astype(x.dtype)
        # Absolute position held by each slot: largest p <= n-1 with
        # p % cache_len == slot (negative -> slot not written yet).
        n = kv_cache["pos"] + S  # tokens written after this update
        slot = jnp.arange(cache_len, dtype=jnp.int32)
        k_pos = slot + ((n - 1 - slot) // cache_len) * cache_len
        q_pos = positions
    else:
        k_pos = positions
        q_pos = positions

    if causal:
        mask = _attn_mask(q_pos, k_pos, cfg.sliding_window)
        mask &= (k_pos >= 0)[None, :]      # exclude never-written cache slots
    else:
        mask = None
    out = _sdpa(q, k, v, cfg, mask, x.dtype)
    out = dn(out, params["wo"], la("wo"))
    return out, new_cache


def init_kv_cache(cfg, batch: int, cache_len: int, dtype) -> Params:
    Kv, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    return {"k": jnp.zeros((batch, cache_len, Kv, hd), dtype=dtype),
            "v": jnp.zeros((batch, cache_len, Kv, hd), dtype=dtype),
            "pos": jnp.zeros((), dtype=jnp.int32)}


def kv_cache_specs() -> Params:
    return {"k": P(DATA, None, MODEL, None), "v": P(DATA, None, MODEL, None),
            "pos": P()}


def init_paged_kv_cache(cfg, num_blocks: int, block_size: int, dtype,
                        kv_dtype: str = "f32") -> Params:
    """One K/V pool per layer, shared by every serving slot: blocks are
    handed to slots by the host-side block table (serving/kv_cache.py).

    ``kv_dtype="int8"`` stores the pools as int8 with one fp32 scale per
    (block, position, kv-head) riding as ``k_scale``/``v_scale`` leaves —
    36 bytes per token per kv-head instead of 64 (bf16), so the same HBM
    budget holds ~1.78x the blocks.  ``"f32"`` keeps the unquantized pools
    in ``dtype`` exactly as before (bf16 in serving)."""
    Kv, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    if kv_dtype == "int8":
        return {"k_pool": jnp.zeros((num_blocks, block_size, Kv, hd),
                                    dtype=jnp.int8),
                "v_pool": jnp.zeros((num_blocks, block_size, Kv, hd),
                                    dtype=jnp.int8),
                "k_scale": jnp.zeros((num_blocks, block_size, Kv),
                                     dtype=jnp.float32),
                "v_scale": jnp.zeros((num_blocks, block_size, Kv),
                                     dtype=jnp.float32)}
    if kv_dtype != "f32":
        raise ValueError(f"kv_dtype must be 'f32' or 'int8', got {kv_dtype!r}")
    return {"k_pool": jnp.zeros((num_blocks, block_size, Kv, hd), dtype=dtype),
            "v_pool": jnp.zeros((num_blocks, block_size, Kv, hd), dtype=dtype)}


def paged_kv_cache_specs(kv_dtype: str = "f32") -> Params:
    # the block axis is a shared pool (no batch sharding); heads on MODEL
    specs = {"k_pool": P(None, None, MODEL, None),
             "v_pool": P(None, None, MODEL, None)}
    if kv_dtype == "int8":
        specs["k_scale"] = P(None, None, MODEL)
        specs["v_scale"] = P(None, None, MODEL)
    return specs


# ---------------------------------------------------------------------------
# MLP (SwiGLU / GeGLU / GELU)
# ---------------------------------------------------------------------------

def init_mlp(key, d: int, ff: int, mlp_type: str, dtype) -> Params:
    ks = jax.random.split(key, 3)
    s_in, s_out = d ** -0.5, ff ** -0.5
    p = {"w_out": (jax.random.normal(ks[2], (ff, d)) * s_out).astype(dtype)}
    if mlp_type in ("swiglu", "geglu"):
        p["w_gate"] = (jax.random.normal(ks[0], (d, ff)) * s_in).astype(dtype)
        p["w_up"] = (jax.random.normal(ks[1], (d, ff)) * s_in).astype(dtype)
    else:
        p["w_up"] = (jax.random.normal(ks[1], (d, ff)) * s_in).astype(dtype)
    return p


def mlp_specs(mlp_type: str) -> Params:
    p = {"w_up": P(None, MODEL), "w_out": P(MODEL, None)}
    if mlp_type in ("swiglu", "geglu"):
        p["w_gate"] = P(None, MODEL)
    return p


def apply_mlp(params: Params, x: jnp.ndarray, mlp_type: str,
              adapters: Optional[Params] = None, lora_scale: float = 1.0,
              adapter_ids: Optional[jnp.ndarray] = None):
    la = partial(lora_pair, adapters)
    dn = partial(dense, lora_scale=lora_scale, adapter_ids=adapter_ids)
    if mlp_type in ("swiglu", "geglu"):
        act = jax.nn.silu if mlp_type == "swiglu" else partial(jax.nn.gelu, approximate=True)
        g = dn(x, params["w_gate"], la("w_gate"))
        u = dn(x, params["w_up"], la("w_up"))
        h = act(g.astype(jnp.float32)).astype(x.dtype) * u
    else:
        h = dn(x, params["w_up"], la("w_up"))
        h = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
    return dn(h, params["w_out"], la("w_out"))


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------

def init_embed(key, vocab: int, d: int, dtype) -> jnp.ndarray:
    return (jax.random.normal(key, (vocab, d)) * 0.02).astype(dtype)


def embed_specs() -> Any:
    return P(MODEL, None)
