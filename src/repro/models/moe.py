"""Mixture-of-Experts layer: top-k routing with capacity-based dispatch.

TPU adaptation notes (see DESIGN.md §2): instead of the GShard one-hot
dispatch einsum — whose ``(tokens, E, C)`` combine tensor is intractable for
fine-grained MoE (DBRX E=16 is fine, Kimi-K2 E=384 is not) — we use a
sort-based dispatch:

  1. router -> top-k expert ids + weights per token,
  2. flatten the (T*k) token copies, sort by expert id,
  3. compute each copy's slot within its expert via a cumulative count,
  4. scatter copies into a padded ``(E, C, d)`` buffer (overflow drops),
  5. batched expert FFN ``(E, C, d) @ (E, d, ff)`` — expert-parallel on the
     ``model`` mesh axis,
  6. gather outputs back and combine with router weights.

The buffer is the only E-proportional tensor and is sharded on E. Under pjit
this lowers to all-to-all-flavoured collectives between the token (data)
sharding and the expert (model) sharding — exactly the communication pattern
the roofline analysis tracks for MoE architectures.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.layers import (DATA, MODEL, lora_delta, lora_pair, matmul,
                                 maybe_shard)

Params = Dict[str, Any]


def init_moe(key, d: int, ff: int, n_experts: int, mlp_type: str, dtype) -> Params:
    ks = jax.random.split(key, 4)
    s_in, s_out = d ** -0.5, ff ** -0.5
    p = {
        "router": (jax.random.normal(ks[0], (d, n_experts)) * s_in).astype(jnp.float32),
        "w_up": (jax.random.normal(ks[1], (n_experts, d, ff)) * s_in).astype(dtype),
        "w_out": (jax.random.normal(ks[2], (n_experts, ff, d)) * s_out).astype(dtype),
    }
    if mlp_type in ("swiglu", "geglu"):
        p["w_gate"] = (jax.random.normal(ks[3], (n_experts, d, ff)) * s_in).astype(dtype)
    return p


def moe_specs(mlp_type: str) -> Params:
    p = {"router": P(None, None),
         "w_up": P(MODEL, None, None),
         "w_out": P(MODEL, None, None)}
    if mlp_type in ("swiglu", "geglu"):
        p["w_gate"] = P(MODEL, None, None)
    return p


def _top_k_routing(router_logits: jnp.ndarray, k: int
                   ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """(T, E) -> weights (T, k), ids (T, k), aux load-balance loss."""
    probs = jax.nn.softmax(router_logits.astype(jnp.float32), axis=-1)
    weights, ids = jax.lax.top_k(probs, k)
    weights = weights / jnp.clip(weights.sum(-1, keepdims=True), 1e-9)
    # Switch-style load-balance auxiliary loss.
    E = router_logits.shape[-1]
    density = jnp.mean(jax.nn.one_hot(ids[:, 0], E, dtype=jnp.float32), axis=0)
    density_proxy = jnp.mean(probs, axis=0)
    aux = jnp.sum(density * density_proxy) * (E ** 1)
    return weights, ids, aux


def apply_moe(params: Params, x: jnp.ndarray, cfg,
              adapters: Optional[Params] = None, lora_scale: float = 1.0,
              adapter_ids: Optional[jnp.ndarray] = None
              ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, d) -> (out (B, S, d), aux_loss scalar).

    ``adapters`` may contain a "router" LoRA (the only MoE sub-module that
    receives adapters by default; per-expert adapters would defeat PEFT).
    """
    B, S, d = x.shape
    E = cfg.n_experts
    k = cfg.n_experts_per_tok
    ff = cfg.resolved_d_ff_moe
    T = B * S
    # capacity per expert, rounded up to a multiple of 64 so the slot dim
    # shards evenly over the data axis (and tiles the MXU).
    cap = int(max(k, round(T * k / E * cfg.moe_capacity_factor)))
    cap = -(-cap // 64) * 64

    xf = x.reshape(T, d)
    logits = matmul(xf, params["router"].astype(xf.dtype), out_dtype=jnp.float32)
    if adapters is not None and "router" in adapters:
        a, b, *scales = lora_pair(adapters, "router")
        delta = lora_delta(x, a, b, adapter_ids, *scales)    # (B, S, E)
        logits = logits + lora_scale * delta.reshape(T, E)
    weights, ids, aux = _top_k_routing(logits, k)          # (T,k)

    # ---- sort-based dispatch ------------------------------------------
    flat_ids = ids.reshape(-1)                              # (T*k,)
    order = jnp.argsort(flat_ids)                           # stable
    sorted_ids = flat_ids[order]
    # slot of each sorted copy within its expert group
    same = jnp.cumsum(jnp.ones_like(sorted_ids)) - 1
    seg_start = jnp.searchsorted(sorted_ids, jnp.arange(E, dtype=sorted_ids.dtype))
    slot_sorted = same - seg_start[sorted_ids]
    # undo the sort to get (T*k,) slots aligned with flat_ids
    inv = jnp.zeros_like(order).at[order].set(jnp.arange(T * k))
    slot = slot_sorted[inv]

    token_idx = jnp.repeat(jnp.arange(T), k)                # (T*k,)
    keep = slot < cap
    dest = jnp.where(keep, flat_ids * cap + slot, E * cap)  # overflow -> dropped row

    buf = jnp.zeros((E * cap + 1, d), dtype=x.dtype)
    buf = buf.at[dest].set(xf[token_idx], mode="drop")
    buf = maybe_shard(buf[: E * cap].reshape(E, cap, d), _buffer_spec())

    # ---- expert FFN (batched over E; expert-parallel on `model`) -------
    if "w_gate" in params:
        act = jax.nn.silu if cfg.mlp_type == "swiglu" else jax.nn.gelu
        g = jnp.einsum("ecd,edf->ecf", buf, params["w_gate"].astype(x.dtype),
                       preferred_element_type=jnp.float32)
        u = jnp.einsum("ecd,edf->ecf", buf, params["w_up"].astype(x.dtype),
                       preferred_element_type=jnp.float32)
        h = (act(g) * u).astype(x.dtype)
    else:
        u = jnp.einsum("ecd,edf->ecf", buf, params["w_up"].astype(x.dtype),
                       preferred_element_type=jnp.float32)
        h = jax.nn.gelu(u).astype(x.dtype)
    y_buf = jnp.einsum("ecf,efd->ecd", h, params["w_out"].astype(x.dtype),
                       preferred_element_type=jnp.float32).astype(x.dtype)
    y_buf = maybe_shard(y_buf, _buffer_spec())

    # ---- gather back + weighted combine --------------------------------
    y_flat = jnp.concatenate(
        [y_buf.reshape(E * cap, d), jnp.zeros((1, d), dtype=x.dtype)], axis=0)
    y_copies = y_flat[dest]                                 # (T*k, d); dropped -> 0
    w = (weights.reshape(-1) * keep.astype(jnp.float32))[:, None]
    out = jnp.zeros((T, d), dtype=jnp.float32)
    out = out.at[token_idx].add(y_copies.astype(jnp.float32) * w)
    return out.reshape(B, S, d).astype(x.dtype), aux


def _buffer_spec():
    # Experts over `model` (expert parallelism), slots over `data`: without
    # the data-axis constraint the SPMD partitioner replicates the expert
    # GEMMs across every data row — 16x redundant compute on the production
    # mesh (measured during bring-up; EXPERIMENTS.md §Perf, MoE iteration 0).
    return P(MODEL, DATA, None)
