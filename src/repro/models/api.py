"""Family dispatch: one uniform interface over all model families.

``Model.forward(params, batch, adapters)`` where ``batch`` is a dict:
  * decoder families: {"tokens": (B,S)} (+ "patch_embeds": (B,P,d) for vlm)
  * encdec:           {"enc_embeds": (B,T,d), "tokens": (B,S)}
``Model.decode_step(params, cache, tokens, pos, adapters)`` for serving.
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Any, Callable, Dict, Optional

import jax.numpy as jnp

from repro.models import encdec, model as dec

Params = Dict[str, Any]

# reusable no-op context for the mesh=None paths (nullcontext is stateless)
_NULL_CTX = contextlib.nullcontext()


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: Any

    # ---- init ------------------------------------------------------------
    def init(self, rng) -> Params:
        if self.cfg.is_encdec:
            return encdec.init_params(rng, self.cfg)
        return dec.init_params(rng, self.cfg)

    def param_specs(self) -> Params:
        if self.cfg.is_encdec:
            return encdec.param_specs(self.cfg)
        return dec.param_specs(self.cfg)

    # ---- forward ----------------------------------------------------------
    def forward(self, params: Params, batch: Dict[str, jnp.ndarray],
                adapters: Optional[Params] = None, lora_scale: float = 1.0,
                last_only: bool = False,
                adapter_ids: Optional[jnp.ndarray] = None):
        cfg = self.cfg
        if cfg.is_encdec:
            if adapter_ids is not None:
                raise NotImplementedError("multi-tenant banked adapters are "
                                          "decoder-family only")
            return encdec.forward(params, batch["enc_embeds"], batch["tokens"],
                                  cfg, adapters, lora_scale)
        extra = batch.get("patch_embeds") if cfg.family == "vlm" else None
        return dec.forward(params, batch["tokens"], cfg, adapters, lora_scale,
                           extra_embeds=extra, last_only=last_only,
                           adapter_ids=adapter_ids)

    # ---- decode -----------------------------------------------------------
    def init_decode_cache(self, batch: int, cache_len: int) -> Params:
        if self.cfg.is_encdec:
            return encdec.init_decode_cache(self.cfg, batch, cache_len)
        return dec.init_decode_cache(self.cfg, batch, cache_len)

    def decode_cache_specs(self) -> Params:
        if self.cfg.is_encdec:
            return encdec.decode_cache_specs(self.cfg)
        return dec.decode_cache_specs(self.cfg)

    def init_paged_decode_cache(self, num_slots: int, num_blocks: int,
                                block_size: int,
                                kv_dtype: str = "f32") -> Params:
        """Continuous-batching serving cache: shared K/V block pools +
        dense per-slot SSM state (see serving/kv_cache.py).
        ``kv_dtype="int8"`` quantizes the K/V pools with per-block scales."""
        if self.cfg.is_encdec:
            raise NotImplementedError("paged decoding is decoder-family only")
        return dec.init_paged_decode_cache(self.cfg, num_slots, num_blocks,
                                           block_size, kv_dtype=kv_dtype)

    def paged_decode_cache_specs(self, kv_dtype: str = "f32") -> Params:
        if self.cfg.is_encdec:
            raise NotImplementedError("paged decoding is decoder-family only")
        return dec.paged_decode_cache_specs(self.cfg, kv_dtype)

    def prefill_step(self, params: Params, cache: Params, tokens, pos, n_new,
                     adapters: Optional[Params] = None,
                     lora_scale: float = 1.0,
                     adapter_ids: Optional[jnp.ndarray] = None,
                     block_tables: Optional[jnp.ndarray] = None,
                     paged_backend: Optional[str] = None,
                     mesh: Optional[Any] = None):
        """Chunked paged prefill: tokens (B, T) with n_new (B,) valid per
        row, scattered through block_tables at per-row offsets pos (B,).
        ``paged_backend`` overrides ``cfg.paged_backend`` ("jnp" | "pallas").
        ``mesh`` (a ``jax.sharding.Mesh``) traces the step under the mesh so
        the model's "data"-axis constraints bind batch rows to devices —
        the serving engine instead enters the mesh around its jitted
        dispatches (same effect, one context per chunk).  Returns
        (logits (B, T, V), cache)."""
        if self.cfg.is_encdec:
            raise NotImplementedError("paged prefill is decoder-family only")
        with mesh if mesh is not None else _NULL_CTX:
            return dec.prefill_step(params, cache, tokens, pos, n_new,
                                    self.cfg, adapters, lora_scale,
                                    adapter_ids=adapter_ids,
                                    block_tables=block_tables,
                                    paged_backend=paged_backend)

    def verify_step(self, params: Params, cache: Params, tokens, pos, n_new,
                    adapters: Optional[Params] = None,
                    lora_scale: float = 1.0,
                    adapter_ids: Optional[jnp.ndarray] = None,
                    block_tables: Optional[jnp.ndarray] = None,
                    paged_backend: Optional[str] = None,
                    mesh: Optional[Any] = None):
        """Speculative-decoding verification: score a drafted chunk
        (feedback token + proposed continuation per row) causally against
        the paged cache.  This IS :meth:`prefill_step` — same scatter,
        same chunk attention, same kernels on both paged backends — named
        separately because the contract differs: the caller consumes the
        logits at EVERY chunk position (greedy acceptance needs the
        model's choice after each drafted token), and positions past the
        accepted run are rolled back by the scheduler, not kept.  Chunk
        logits are bitwise-equal to feeding the same tokens one decode
        step at a time, which is what makes greedy draft-then-verify
        bitwise-identical to non-speculative decoding."""
        return self.prefill_step(params, cache, tokens, pos, n_new,
                                 adapters=adapters, lora_scale=lora_scale,
                                 adapter_ids=adapter_ids,
                                 block_tables=block_tables,
                                 paged_backend=paged_backend, mesh=mesh)

    def decode_step(self, params: Params, cache: Params, tokens, pos,
                    adapters: Optional[Params] = None, lora_scale: float = 1.0,
                    adapter_ids: Optional[jnp.ndarray] = None,
                    block_tables: Optional[jnp.ndarray] = None,
                    paged_backend: Optional[str] = None,
                    mesh: Optional[Any] = None):
        if self.cfg.is_encdec:
            if adapter_ids is not None or block_tables is not None:
                raise NotImplementedError("multi-tenant banked adapters and "
                                          "paged decoding are decoder-family "
                                          "only")
            return encdec.decode_step(params, cache, tokens, pos, self.cfg,
                                      adapters, lora_scale)
        with mesh if mesh is not None else _NULL_CTX:
            return dec.decode_step(params, cache, tokens, pos, self.cfg,
                                   adapters, lora_scale,
                                   adapter_ids=adapter_ids,
                                   block_tables=block_tables,
                                   paged_backend=paged_backend)


def get_model(cfg) -> Model:
    return Model(cfg)
