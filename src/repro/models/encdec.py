"""Whisper-style encoder-decoder backbone.

Per the task spec, the modality frontend (mel-spectrogram + conv feature
extractor) is a STUB: ``input_specs`` provides precomputed frame embeddings of
shape (B, encoder_seq_len, d_model). Everything downstream — encoder stack,
decoder stack with cross-attention, KV-cache decode — is implemented.

Whisper flavour: learned positional embeddings (no RoPE), pre-LayerNorm,
GELU MLP, tied unembedding.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import layers as L

Params = Dict[str, Any]


def _stack(fn, key, n):
    ks = jax.random.split(key, n)
    return jax.vmap(fn)(ks)


def init_params(rng, cfg) -> Params:
    dtype = L.dt(cfg.param_dtype)
    d = cfg.d_model
    k = jax.random.split(rng, 8)

    def enc_block(key):
        k1, k2, k3, k4 = jax.random.split(key, 4)
        return {"self_attn": L.init_attention(k1, cfg, dtype),
                "mlp": L.init_mlp(k2, d, cfg.d_ff, cfg.mlp_type, dtype),
                "norm1": L.init_norm(k3, d, cfg.norm_type, dtype),
                "norm2": L.init_norm(k4, d, cfg.norm_type, dtype)}

    def dec_block(key):
        k1, k2, k3, k4, k5, k6 = jax.random.split(key, 6)
        return {"self_attn": L.init_attention(k1, cfg, dtype),
                "cross_attn": L.init_attention(k2, cfg, dtype),
                "mlp": L.init_mlp(k3, d, cfg.d_ff, cfg.mlp_type, dtype),
                "norm1": L.init_norm(k4, d, cfg.norm_type, dtype),
                "norm2": L.init_norm(k5, d, cfg.norm_type, dtype),
                "norm3": L.init_norm(k6, d, cfg.norm_type, dtype)}

    return {
        "embed": L.init_embed(k[0], cfg.vocab_size, d, dtype),
        "enc_pos": (jax.random.normal(k[1], (cfg.encoder_seq_len, d)) * 0.02).astype(dtype),
        "dec_pos": (jax.random.normal(k[2], (cfg.max_seq_len, d)) * 0.02).astype(dtype),
        "enc_blocks": _stack(enc_block, k[3], cfg.n_encoder_layers),
        "dec_blocks": _stack(dec_block, k[4], cfg.n_layers),
        "enc_final_norm": L.init_norm(k[5], d, cfg.norm_type, dtype),
        "dec_final_norm": L.init_norm(k[6], d, cfg.norm_type, dtype),
    }


def param_specs(cfg) -> Params:
    from repro.models.model import _add_leading
    enc = {"self_attn": L.attention_specs(cfg), "mlp": L.mlp_specs(cfg.mlp_type),
           "norm1": L.norm_specs(cfg.norm_type), "norm2": L.norm_specs(cfg.norm_type)}
    dec = {"self_attn": L.attention_specs(cfg), "cross_attn": L.attention_specs(cfg),
           "mlp": L.mlp_specs(cfg.mlp_type),
           "norm1": L.norm_specs(cfg.norm_type), "norm2": L.norm_specs(cfg.norm_type),
           "norm3": L.norm_specs(cfg.norm_type)}
    return {
        "embed": L.embed_specs(),
        "enc_pos": P(None, None),
        "dec_pos": P(None, None),
        "enc_blocks": _add_leading(enc),
        "dec_blocks": _add_leading(dec),
        "enc_final_norm": L.norm_specs(cfg.norm_type),
        "dec_final_norm": L.norm_specs(cfg.norm_type),
    }


def _ad(adapters, *path):
    node = adapters
    for p in path:
        if node is None:
            return None
        node = node.get(p)
    return node


def encode(params: Params, enc_embeds: jnp.ndarray, cfg,
           adapters: Optional[Params] = None, lora_scale: float = 1.0):
    """enc_embeds: (B, T_enc, d) stubbed frame embeddings -> (B, T_enc, d)."""
    dtype = L.dt(cfg.dtype)
    T = enc_embeds.shape[1]
    x = enc_embeds.astype(dtype) + params["enc_pos"][None, :T].astype(dtype)
    positions = jnp.arange(T, dtype=jnp.int32)
    ad = _ad(adapters, "enc_blocks")

    def body(x, xs):
        h = L.apply_norm(xs["norm1"], x, cfg.norm_type)
        out, _ = L.multihead_attention(xs["self_attn"], h, cfg, positions,
                                       xs.get("__ad_self_attn"), lora_scale,
                                       causal=False)
        x = x + out
        h = L.apply_norm(xs["norm2"], x, cfg.norm_type)
        x = x + L.apply_mlp(xs["mlp"], h, cfg.mlp_type, xs.get("__ad_mlp"),
                            lora_scale)
        return x, None

    xs = dict(params["enc_blocks"])
    if ad is not None:
        xs["__ad_self_attn"] = ad["self_attn"]
        xs["__ad_mlp"] = ad.get("mlp")
    x, _ = jax.lax.scan(body, x, xs,
                    unroll=min(cfg.scan_unroll, cfg.n_encoder_layers))
    return L.apply_norm(params["enc_final_norm"], x, cfg.norm_type)


def _cross_kv(block, enc_out, cfg):
    B, T, _ = enc_out.shape
    Kv, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    k = L.matmul(enc_out, block["cross_attn"]["wk"].astype(enc_out.dtype)).reshape(B, T, Kv, hd)
    v = L.matmul(enc_out, block["cross_attn"]["wv"].astype(enc_out.dtype)).reshape(B, T, Kv, hd)
    return k, v


def _decoder_stack(params, x, positions, cfg, enc_out=None, cross_kv=None,
                   adapters=None, lora_scale=1.0, cache=None):
    """Shared decoder trunk. Either enc_out (train) or cross_kv (decode)."""
    ad = _ad(adapters, "dec_blocks")
    xs = dict(params["dec_blocks"])
    if ad is not None:
        for n in ("self_attn", "cross_attn", "mlp"):
            if n in ad:
                xs["__ad_" + n] = ad[n]
    if cross_kv is not None:
        xs["__ck"], xs["__cv"] = cross_kv
    if cache is not None:
        xs["__cache"] = cache

    def body(x, xs):
        h = L.apply_norm(xs["norm1"], x, cfg.norm_type)
        out, new_cache = L.multihead_attention(
            xs["self_attn"], h, cfg, positions, xs.get("__ad_self_attn"),
            lora_scale, kv_cache=xs.get("__cache"))
        x = x + out
        h = L.apply_norm(xs["norm2"], x, cfg.norm_type)
        if cross_kv is not None:
            ck, cv = xs["__ck"], xs["__cv"]
        else:
            ck, cv = _cross_kv(xs, enc_out, cfg)
        out, _ = L.multihead_attention(
            xs["cross_attn"], h, cfg, positions, xs.get("__ad_cross_attn"),
            lora_scale, causal=False, kv_override=(ck.astype(h.dtype), cv.astype(h.dtype)))
        x = x + out
        h = L.apply_norm(xs["norm3"], x, cfg.norm_type)
        x = x + L.apply_mlp(xs["mlp"], h, cfg.mlp_type, xs.get("__ad_mlp"), lora_scale)
        return x, new_cache

    return jax.lax.scan(body, x, xs,
                    unroll=min(cfg.scan_unroll, cfg.n_layers))


def forward(params: Params, enc_embeds: jnp.ndarray, dec_tokens: jnp.ndarray,
            cfg, adapters: Optional[Params] = None, lora_scale: float = 1.0
            ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Training forward: (B,T_enc,d) embeds + (B,S) tokens -> (B,S,V) logits."""
    dtype = L.dt(cfg.dtype)
    enc_out = encode(params, enc_embeds, cfg, adapters, lora_scale)
    S = dec_tokens.shape[1]
    x = params["embed"].astype(dtype)[dec_tokens] + params["dec_pos"][None, :S].astype(dtype)
    # + tokens[0,0]*0: defeat constant-folding of the (S, S) causal mask
    positions = jnp.arange(S, dtype=jnp.int32) + dec_tokens[0, 0] * 0
    x, _ = _decoder_stack(params, x, positions, cfg, enc_out=enc_out,
                          adapters=adapters, lora_scale=lora_scale)
    x = L.apply_norm(params["dec_final_norm"], x, cfg.norm_type)
    logits = L.matmul(x, params["embed"].T.astype(dtype), out_dtype=jnp.float32)
    return logits, jnp.zeros((), jnp.float32)


def init_decode_cache(cfg, batch: int, cache_len: int) -> Params:
    """Self-attn KV cache + precomputed cross-attn K/V per decoder layer."""
    Kv, hd = cfg.n_kv_heads, cfg.resolved_head_dim
    nL, T = cfg.n_layers, cfg.encoder_seq_len
    kv = jax.tree.map(lambda *ls: jnp.stack(ls),
                      *[L.init_kv_cache(cfg, batch, cache_len, jnp.bfloat16)
                        for _ in range(nL)])
    return {"self": kv,
            "cross_k": jnp.zeros((nL, batch, T, Kv, hd), jnp.bfloat16),
            "cross_v": jnp.zeros((nL, batch, T, Kv, hd), jnp.bfloat16)}


def decode_cache_specs(cfg) -> Params:
    from repro.models.model import _add_leading
    return {"self": _add_leading(L.kv_cache_specs()),
            "cross_k": P(None, L.DATA, None, L.MODEL, None),
            "cross_v": P(None, L.DATA, None, L.MODEL, None)}


def prefill_cross(params: Params, enc_embeds: jnp.ndarray, cfg,
                  adapters=None, lora_scale=1.0):
    """Run the encoder once and precompute cross K/V for every layer."""
    enc_out = encode(params, enc_embeds, cfg, adapters, lora_scale)

    def per_layer(block):
        return _cross_kv(block, enc_out, cfg)

    ck, cv = jax.vmap(per_layer, in_axes=(0,))(params["dec_blocks"])
    return ck.astype(jnp.bfloat16), cv.astype(jnp.bfloat16)


def decode_step(params: Params, cache: Params, tokens: jnp.ndarray,
                pos: jnp.ndarray, cfg, adapters: Optional[Params] = None,
                lora_scale: float = 1.0) -> Tuple[jnp.ndarray, Params]:
    dtype = L.dt(cfg.dtype)
    x = params["embed"].astype(dtype)[tokens]
    x = x + jax.lax.dynamic_slice_in_dim(params["dec_pos"], pos % cfg.max_seq_len, 1)[None].astype(dtype)
    positions = pos[None].astype(jnp.int32)
    x, new_kv = _decoder_stack(params, x, positions, cfg,
                               cross_kv=(cache["cross_k"], cache["cross_v"]),
                               adapters=adapters, lora_scale=lora_scale,
                               cache=cache["self"])
    x = L.apply_norm(params["dec_final_norm"], x, cfg.norm_type)
    logits = L.matmul(x, params["embed"].T.astype(dtype), out_dtype=jnp.float32)
    return logits, {"self": new_kv, "cross_k": cache["cross_k"],
                    "cross_v": cache["cross_v"]}
