"""Serving engines: batched KV-cache decoding with (fused) LoRA adapters.

FDLoRA's inference story: after stage 3, each client's dual LoRA merges into
one standard adapter (Eq. 7). Two engines share one generation loop:

  * :class:`Engine` — single-tenant: one adapter tree bound at construction
    (the seed behaviour, kept for training-side evals and examples).
  * :class:`MultiTenantEngine` — one base-model program + an
    :class:`~repro.serving.registry.AdapterRegistry` bank; callers submit
    :class:`Request` objects carrying ``client_id`` and the engine serves
    *mixed-client* prefill+decode batches, routing every batch row to its
    client's adapter via per-row ``adapter_ids`` (gathered on-chip, see
    ``kernels/batched_lora.py``).

Both support ``prefill`` (run the full prompt once, fill the cache —
sub-quadratic archs fill SSM state / windowed cache), ``decode`` (steps of
one token for a whole request batch), greedy and temperature sampling.
"""
from __future__ import annotations

import dataclasses
from typing import Any, List, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core.lora import lora_scale
from repro.serving.registry import AdapterRegistry

Params = Any


@dataclasses.dataclass
class ServeConfig:
    batch_size: int
    max_new_tokens: int = 32
    cache_len: int = 4096
    temperature: float = 0.0  # 0 => greedy
    seed: int = 0


@dataclasses.dataclass
class Request:
    """One generation request. ``prompt``: (S,) int32; prompts in a batch
    must share S (continuous batching / paged prefill is a ROADMAP item)."""
    client_id: Any
    prompt: Any


class _EngineBase:
    """The generation loop, parameterised by optional per-row adapter ids."""

    def __init__(self, model, cfg):
        self.model, self.cfg = model, cfg
        self.scale = lora_scale(cfg)
        self._decode = jax.jit(self._decode_impl)
        self._prefill = jax.jit(self._prefill_impl)

    # -- steps ---------------------------------------------------------------
    def _prefill_impl(self, params, adapters, ids, cache, tokens):
        """Sequential prefill through the decode path (cache-filling).

        For production prefill one would run the parallel forward and scatter
        K/V into the cache; the sequential scan keeps one code path across
        attention/SSM/hybrid and is what the ``prefill_32k`` dry-run shape
        lowers via ``forward`` instead."""
        def step(carry, tok):
            cache, pos = carry
            logits, cache = self.model.decode_step(
                params, cache, tok[:, None], pos, adapters=adapters,
                lora_scale=self.scale, adapter_ids=ids)
            return (cache, pos + 1), logits[:, 0]

        (cache, pos), logits = jax.lax.scan(
            step, (cache, jnp.int32(0)), tokens.T)
        return cache, pos, logits[-1]

    def _decode_impl(self, params, adapters, ids, cache, tok, pos, rng,
                     temperature):
        logits, cache = self.model.decode_step(
            params, cache, tok, pos, adapters=adapters, lora_scale=self.scale,
            adapter_ids=ids)
        lg = logits[:, 0]
        greedy = jnp.argmax(lg, axis=-1)
        sampled = jax.random.categorical(rng, lg / jnp.maximum(temperature, 1e-6))
        nxt = jnp.where(temperature > 0, sampled, greedy)
        return nxt.astype(jnp.int32), cache

    # -- loop ----------------------------------------------------------------
    def _run(self, params, adapters, ids, prompts: jnp.ndarray,
             sc: ServeConfig) -> jnp.ndarray:
        """prompts: (B, S_prompt) int32 -> (B, max_new_tokens) int32."""
        B = prompts.shape[0]
        cache = self.model.init_decode_cache(B, sc.cache_len)
        cache, pos, last_logits = self._prefill(params, adapters, ids,
                                                cache, prompts)
        tok = jnp.argmax(last_logits, axis=-1)[:, None].astype(jnp.int32)
        rng = jax.random.PRNGKey(sc.seed)
        out = [tok[:, 0]]
        for _ in range(sc.max_new_tokens - 1):
            rng, sub = jax.random.split(rng)
            nxt, cache = self._decode(params, adapters, ids, cache, tok,
                                      pos, sub, sc.temperature)
            pos = pos + 1
            tok = nxt[:, None]
            out.append(nxt)
        return jnp.stack(out, axis=1)


class Engine(_EngineBase):
    """Single-tenant engine: exactly one adapter tree bound per instance."""

    def __init__(self, model, cfg, params: Params,
                 adapters: Optional[Params] = None):
        super().__init__(model, cfg)
        self.params, self.adapters = params, adapters

    def generate(self, prompts: jnp.ndarray, sc: ServeConfig) -> jnp.ndarray:
        """prompts: (B, S_prompt) int32 -> (B, max_new_tokens) int32."""
        return self._run(self.params, self.adapters, None, prompts, sc)


class MultiTenantEngine(_EngineBase):
    """One compiled program serving every registered client.

    Requests carry ``client_id``; the engine resolves each to its bank slot
    (LRU-touching the registry), stacks the prompts into one mixed-client
    batch and threads the (B,) slot vector through the model as
    ``adapter_ids``. Adapter registration/eviction between calls never
    changes bank shapes, so the jitted prefill/decode programs are reused
    across any tenant mix.
    """

    def __init__(self, model, cfg, params: Params, registry: AdapterRegistry):
        super().__init__(model, cfg)
        self.params, self.registry = params, registry

    def generate(self, requests: Sequence[Request],
                 sc: ServeConfig) -> jnp.ndarray:
        """requests: B same-length prompts (possibly all different clients)
        -> (B, max_new_tokens) int32, row-aligned with ``requests``."""
        if not requests:
            raise ValueError("empty request batch")
        ids = jnp.asarray([self.registry.acquire(r.client_id)
                           for r in requests], jnp.int32)
        prompts = jnp.stack([jnp.asarray(r.prompt, jnp.int32)
                             for r in requests])
        return self._run(self.params, self.registry.bank(), ids, prompts, sc)
