"""Serving engines: batched KV-cache decoding with (fused) LoRA adapters.

FDLoRA's inference story: after stage 3, each client's dual LoRA merges into
one standard adapter (Eq. 7). Two engines share one generation loop:

  * :class:`Engine` — single-tenant: one adapter tree bound at construction
    (the seed behaviour, kept for training-side evals and examples).
  * :class:`MultiTenantEngine` — one base-model program + an
    :class:`~repro.serving.registry.AdapterRegistry` bank; callers submit
    :class:`Request` objects carrying ``client_id`` and the engine serves
    *mixed-client* batches, routing every batch row to its client's adapter
    via per-row ``adapter_ids`` (gathered on-chip, see
    ``kernels/batched_lora.py``).

``MultiTenantEngine.generate_stream`` is a **continuous-batching** loop
over a paged KV cache (``serving/kv_cache.py`` + ``serving/scheduler.py``):
ragged prompts fed through CHUNKED multi-token prefill dispatches, on-demand
block growth with preemption when the pool runs dry, per-request token
budgets, per-row EOS, admission of queued requests into slots freed
mid-flight — and ``(rid, tokens, finished)`` increments yielded the moment
each chunk is observed, before the batch drains.  ``generate`` collects the
stream into per-request arrays; ``generate_fixed`` keeps the fixed-shape
one-batch-per-call path (equal-length prompts, one shared budget) —
equal-shape greedy requests produce bit-identical tokens on both.
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Any, Iterator, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.lora import lora_scale
from repro.serving.kv_cache import PagedKVCache, blocks_needed, reset_slot
from repro.serving.registry import AdapterRegistry
from repro.serving.scheduler import PRIORITY_CLASSES, Scheduler
from repro.serving.sharded import ShardedPagedKVCache, ShardedScheduler

Params = Any


@dataclasses.dataclass
class ServeConfig:
    batch_size: int                  # decode slots (continuous) / batch rows
    max_new_tokens: int = 32         # default per-request budget
    cache_len: int = 4096            # fixed-path cache length
    temperature: float = 0.0         # 0 => greedy
    seed: int = 0
    eos_id: Optional[int] = None     # finished rows emit pad_id afterwards
    pad_id: int = 0
    block_size: int = 16             # paged-cache block size (continuous)
    num_blocks: Optional[int] = None  # pool size; None => full residency
    max_blocks_per_slot: Optional[int] = None  # block-table width; None =>
    #                                  longest span (or the whole pool when
    #                                  prefix caching with a pinned pool)
    scan_chunk: int = 32             # max device steps between admissions
    prefill_chunk: int = 16          # prompt tokens per prefill dispatch
    prefix_cache: bool = False       # content-addressed shared blocks:
    #                                  shared prompt prefixes (and preempted
    #                                  requests' replays) skip re-prefill,
    #                                  within AND across generate calls.
    #                                  Warm-vs-cold BITWISE equality holds
    #                                  for greedy decoding (temperature 0);
    #                                  with temperature > 0 samples stay
    #                                  valid but draw a different rng
    #                                  stream (fewer dispatches = fewer
    #                                  rng splits), so runs don't replay.
    sched_policy: str = "sla"        # "sla": priority-class admission with
    #                                  aging + scored preemption victims
    #                                  (prefix-aware); "fcfs": legacy
    #                                  arrival order + newest-first victims
    sched_aging: int = 16            # admission rounds queued per one-class
    #                                  promotion under "sla" (0 disables)
    paged_backend: str = "jnp"       # paged-attention impl for the
    #                                  continuous path: "jnp" gather oracle
    #                                  (CPU default) | "pallas" kernels
    #                                  (interpret-mode on CPU; on TPU also
    #                                  set cfg.pallas_interpret=False)
    spec_decode: bool = False        # speculative decoding (continuous
    #                                  path): prompt-lookup self-drafts of
    #                                  up to spec_k tokens verified in ONE
    #                                  prefill-shaped dispatch; greedy-only
    #                                  (temperature must be 0) and bitwise-
    #                                  identical to non-speculative greedy
    #                                  decoding.  Rejected drafts roll the
    #                                  paged cache back token-granularly.
    spec_k: int = 4                  # max drafted tokens per slot per round
    spec_ngram: int = 3              # longest history n-gram the drafter
    #                                  matches (see serving/spec_decode.py)
    num_shards: int = 1              # partition the paged block pool +
    #                                  request slots into this many shards
    #                                  (serving/sharded.py): per-shard free
    #                                  lists, seal chains and preemption,
    #                                  placement-aware admission, one fused
    #                                  dispatch per round.  1 (default) is
    #                                  the single-pool path, bit-identical
    #                                  to pre-shard behaviour.
    mesh: Any = None                 # optional jax.sharding.Mesh entered
    #                                  around device dispatches: activates
    #                                  the "data"-axis sharding constraint
    #                                  on the fused batch (slots are shard-
    #                                  contiguous, so shard boundaries land
    #                                  on device boundaries).  None = no
    #                                  mesh (single device, the default).
    overlap: bool = True             # async overlapped dispatch (continuous
    #                                  path): the host plans and enqueues
    #                                  chunk N+1 while the device executes
    #                                  chunk N, materialising a chunk's
    #                                  samples ONLY when the next plan can
    #                                  depend on them — i.e. when the chunk
    #                                  emits tokens (feedback rows, a
    #                                  completing prompt, decode/verify).
    #                                  Prompt-only prefill chunks pipeline
    #                                  with zero host-device round-trips.
    #                                  False = the synchronous reference
    #                                  loop (one host sync per chunk); both
    #                                  run the SAME dispatches with the SAME
    #                                  inputs, so token streams are BITWISE
    #                                  identical either way.
    kv_dtype: str = "f32"            # paged K/V pool storage: "f32" keeps
    #                                  the unquantized (bf16) pools exactly
    #                                  as before; "int8" stores blocks
    #                                  quantized with per-(block, position,
    #                                  kv-head) fp32 scales — ~1.78x the
    #                                  blocks per HBM byte, dequantized at
    #                                  read inside both paged backends.
    #                                  Greedy streams match the f32 path
    #                                  within a documented error bound (see
    #                                  tests/test_quant.py), NOT bitwise.


@dataclasses.dataclass
class Request:
    """One generation request. ``prompt``: (S,) int32 — ragged lengths are
    fine under ``MultiTenantEngine.generate`` (continuous batching); the
    fixed path (``generate_fixed``) still needs every prompt to share S.
    ``max_new_tokens`` overrides ``ServeConfig.max_new_tokens`` per request.
    ``priority`` names a scheduling class (``interactive`` | ``batch`` |
    ``background``); ``None`` (the default) falls back to the client's
    registered default (``AdapterRegistry.register(...,
    default_priority=)``) and then to ``"batch"`` — an explicit request
    priority always wins.  ``deadline`` (any comparable number, e.g. a
    unix timestamp) breaks admission ties earliest-first within a class —
    both only matter under ``ServeConfig.sched_policy="sla"``."""
    client_id: Any
    prompt: Any
    max_new_tokens: Optional[int] = None
    priority: Optional[str] = None
    deadline: Optional[float] = None


class _EngineBase:
    """The generation loop, parameterised by optional per-row adapter ids."""

    def __init__(self, model, cfg):
        self.model, self.cfg = model, cfg
        self.scale = lora_scale(cfg)
        self._decode = jax.jit(self._decode_impl)
        self._prefill = jax.jit(self._prefill_impl)
        self._decode_chunk = jax.jit(self._decode_chunk_impl,
                                     static_argnames=("chunk_cap", "backend"))
        self._prefill_chunk = jax.jit(self._prefill_chunk_impl,
                                      static_argnames=("backend",))
        self._verify_chunk = jax.jit(self._verify_chunk_impl,
                                     static_argnames=("backend",))

    # -- steps ---------------------------------------------------------------
    def _prefill_impl(self, params, adapters, ids, cache, tokens):
        """Sequential prefill through the decode path (cache-filling).

        For production prefill one would run the parallel forward and scatter
        K/V into the cache; the sequential scan keeps one code path across
        attention/SSM/hybrid and is what the ``prefill_32k`` dry-run shape
        lowers via ``forward`` instead."""
        def step(carry, tok):
            cache, pos = carry
            logits, cache = self.model.decode_step(
                params, cache, tok[:, None], pos, adapters=adapters,
                lora_scale=self.scale, adapter_ids=ids)
            return (cache, pos + 1), logits[:, 0]

        (cache, pos), logits = jax.lax.scan(
            step, (cache, jnp.int32(0)), tokens.T)
        return cache, pos, logits[-1]

    def _decode_impl(self, params, adapters, ids, cache, tok, pos, rng,
                     temperature):
        logits, cache = self.model.decode_step(
            params, cache, tok, pos, adapters=adapters, lora_scale=self.scale,
            adapter_ids=ids)
        return self._sample(logits, rng, temperature), cache

    def _decode_chunk_impl(self, params, adapters, ids, cache, last, active,
                           lengths, block_tables, n_steps, rng, temperature,
                           chunk_cap, backend=None):
        """Up to ``n_steps`` (dynamic, <= static ``chunk_cap``) decode steps
        fully on device: each slot feeds its last sampled token — one
        dispatch per chunk instead of per token.  (Prompts are fed by
        ``_prefill_chunk``; every active slot here is past its prompt.)
        ``backend`` (static) selects the paged-attention impl
        (``ServeConfig.paged_backend``).  The per-round rng split lives
        INSIDE the jit (same split math as a host-side
        ``jax.random.split`` — the sampled stream is bitwise unchanged)
        so the serving loop can chain the returned key without a host
        round-trip.  Returns the (chunk_cap, K) sampled block (rows >=
        n_steps are garbage; the scheduler slices), the cache, each
        slot's final context length (``lengths + n_steps * active`` — the
        device-side mirror of the host pool's ``advance`` bookkeeping),
        each slot's final sampled token (the feed for the next chunk,
        letting steady-state decode chain device-to-device without
        materialising this chunk first; garbage for inactive rows, whose
        writes sink into reserved block 0 either way) and the advanced
        rng key."""
        K = ids.shape[0]
        rng, sub = jax.random.split(rng)

        def body(t, carry):
            cache, last, lengths, sub, out = carry
            sub, key = jax.random.split(sub)
            logits, cache = self.model.decode_step(
                params, cache, last[:, None], lengths, adapters=adapters,
                lora_scale=self.scale, adapter_ids=ids,
                block_tables=block_tables, paged_backend=backend)
            nxt = self._sample(logits, key, temperature)
            out = out.at[t].set(nxt)
            return (cache, nxt, lengths + active, sub, out)

        out0 = jnp.zeros((chunk_cap, K), jnp.int32)
        carry = jax.lax.fori_loop(
            0, n_steps, body, (cache, last, lengths, sub, out0))
        cache, new_last, new_lens, _, out = carry
        return out, cache, new_lens, new_last, rng

    def _prefill_chunk_impl(self, params, adapters, ids, cache, tokens,
                            lengths, n_new, block_tables, rng, temperature,
                            backend=None):
        """One chunked-prefill dispatch: scatter+attend ``tokens`` (K, T)
        — ``n_new[k]`` valid per row — through the paged cache, and sample
        each row's logits at its LAST valid position (the first emitted
        token for rows whose prompt just completed; garbage, discarded by
        the scheduler, for the rest).  Like ``_decode_chunk_impl`` the
        per-round rng split happens inside the jit (bitwise-identical
        stream) and the advanced lengths come back as a device array.
        Returns ((K,) sampled, cache, lengths + n_new, rng)."""
        rng, sub = jax.random.split(rng)
        logits, cache = self.model.prefill_step(
            params, cache, tokens, lengths, n_new, adapters=adapters,
            lora_scale=self.scale, adapter_ids=ids,
            block_tables=block_tables, paged_backend=backend)
        K, T, _ = logits.shape
        rows = jnp.arange(K, dtype=jnp.int32)
        lg = logits[rows, jnp.clip(n_new - 1, 0, T - 1)]       # (K, V)
        return (self._sample(lg[:, None], sub, temperature), cache,
                lengths + n_new, rng)

    def _verify_chunk_impl(self, params, adapters, ids, cache, tokens,
                           lengths, n_new, block_tables, backend=None):
        """One draft-verify dispatch: the SAME paged prefill dataflow as
        ``_prefill_chunk_impl`` (``Model.verify_step`` delegates to
        ``prefill_step`` — scatter + causal chunk attention against the
        pool, both backends), but the greedy sample comes back for EVERY
        chunk position, not just the last valid one: position ``t``'s
        argmax is the token non-speculative decoding would have emitted
        after feeding the chunk up to ``t``, which is exactly what the
        scheduler's acceptance rule compares drafts against.  Greedy-only
        (``generate_stream`` rejects spec_decode with temperature > 0),
        so no rng is threaded.  Returns ((K, T) int32 greedy, cache)."""
        logits, cache = self.model.verify_step(
            params, cache, tokens, lengths, n_new, adapters=adapters,
            lora_scale=self.scale, adapter_ids=ids,
            block_tables=block_tables, paged_backend=backend)
        return jnp.argmax(logits, axis=-1).astype(jnp.int32), cache

    @staticmethod
    def _sample(logits, rng, temperature):
        lg = logits[:, 0]
        greedy = jnp.argmax(lg, axis=-1)
        sampled = jax.random.categorical(rng, lg / jnp.maximum(temperature, 1e-6))
        return jnp.where(temperature > 0, sampled, greedy).astype(jnp.int32)

    # -- loop ----------------------------------------------------------------
    def _run(self, params, adapters, ids, prompts: jnp.ndarray,
             sc: ServeConfig) -> jnp.ndarray:
        """prompts: (B, S_prompt) int32 -> (B, max_new_tokens) int32.

        With ``sc.eos_id`` set, a row that samples EOS emits ``sc.pad_id``
        from then on and the loop exits early once every row has finished
        (the output stays (B, max_new_tokens), pad-filled)."""
        B = prompts.shape[0]
        cache = self.model.init_decode_cache(B, sc.cache_len)
        cache, pos, last_logits = self._prefill(params, adapters, ids,
                                                cache, prompts)
        tok = jnp.argmax(last_logits, axis=-1)[:, None].astype(jnp.int32)
        rng = jax.random.PRNGKey(sc.seed)
        out = [tok[:, 0]]
        finished = (tok[:, 0] == sc.eos_id) if sc.eos_id is not None else None
        for _ in range(sc.max_new_tokens - 1):
            if finished is not None and bool(finished.all()):
                break
            rng, sub = jax.random.split(rng)
            nxt, cache = self._decode(params, adapters, ids, cache, tok,
                                      pos, sub, sc.temperature)
            if finished is not None:
                nxt = jnp.where(finished, jnp.int32(sc.pad_id), nxt)
                finished = finished | (nxt == sc.eos_id)
            pos = pos + 1
            tok = nxt[:, None]
            out.append(nxt)
        res = jnp.stack(out, axis=1)
        if res.shape[1] < sc.max_new_tokens:          # early all-EOS exit
            pad = jnp.full((B, sc.max_new_tokens - res.shape[1]),
                           sc.pad_id, jnp.int32)
            res = jnp.concatenate([res, pad], axis=1)
        return res


class Engine(_EngineBase):
    """Single-tenant engine: exactly one adapter tree bound per instance."""

    def __init__(self, model, cfg, params: Params,
                 adapters: Optional[Params] = None):
        super().__init__(model, cfg)
        self.params, self.adapters = params, adapters

    def generate(self, prompts: jnp.ndarray, sc: ServeConfig) -> jnp.ndarray:
        """prompts: (B, S_prompt) int32 -> (B, max_new_tokens) int32."""
        return self._run(self.params, self.adapters, None, prompts, sc)


class MultiTenantEngine(_EngineBase):
    """One compiled program serving every registered client.

    Requests carry ``client_id``; the engine resolves each to its bank slot
    (LRU-touching the registry) and threads the per-row slot vector through
    the model as ``adapter_ids``. Adapter registration/eviction between
    calls never changes bank shapes, so the jitted programs are reused
    across any tenant mix.
    """

    def __init__(self, model, cfg, params: Params, registry: AdapterRegistry):
        super().__init__(model, cfg)
        self.params, self.registry = params, registry
        self.last_stats: Optional[dict] = None   # set when a stream drains
        # cross-call prefix-cache state: (pool key, PagedKVCache, device
        # cache) persisted at stream drain so the NEXT generate call's
        # admission can match blocks sealed by this one.  Retained until a
        # prefix_cache stream with a different pool geometry replaces it or
        # release_prefix_cache() drops it — a deliberate warm cache, which
        # means the device pools stay resident across unrelated calls.
        self._warm: Optional[Tuple[tuple, PagedKVCache, Any]] = None

    def release_prefix_cache(self) -> None:
        """Drop the warm prefix-cache pool (host allocator + device K/V
        blocks).  The next ``prefix_cache=True`` stream starts cold; call
        this when a tenant mix moves on and the retained pool's device
        memory is worth more than future prefix hits."""
        self._warm = None

    def _paged_pool(self, num_slots: int, num_blocks: int, blocks_per: int,
                    sc: ServeConfig) -> Tuple[PagedKVCache, Any, bool]:
        """A (host allocator, device cache, reused) triple for one stream.
        With ``sc.prefix_cache``, reuse the pair persisted by the last
        drained stream when the pool geometry matches — sealed blocks (and
        their device K/V) survive, so shared prompt prefixes across calls
        skip prefill.  A geometry change or a stream abandoned mid-flight
        drops the warm state and starts cold (``last_stats
        ['prefix_pool_reused']`` says which happened)."""
        key = (num_slots, sc.block_size, num_blocks, blocks_per,
               sc.num_shards, sc.kv_dtype)
        if sc.prefix_cache:
            warm, self._warm = self._warm, None   # taken; restored at drain
            if warm is not None and warm[0] == key and warm[1].idle:
                return warm[1], warm[2], True
        if sc.num_shards > 1:
            kv: Any = ShardedPagedKVCache(
                sc.num_shards, num_slots, sc.block_size, num_blocks,
                blocks_per, prefix_cache=sc.prefix_cache)
        else:
            kv = PagedKVCache(num_slots, sc.block_size, num_blocks,
                              blocks_per, prefix_cache=sc.prefix_cache)
        cache = self.model.init_paged_decode_cache(num_slots, num_blocks,
                                                   sc.block_size,
                                                   kv_dtype=sc.kv_dtype)
        if sc.prefix_cache or sc.spec_decode:
            # recurrent SSM state is per-slot and dense — it cannot be
            # reconstructed from cached K/V blocks (a prefix hit would
            # silently skip state updates) nor rolled back token-granularly
            # (a verify dispatch advances it through rejected drafts)
            feature = ("prefix_cache" if sc.prefix_cache else "spec_decode")
            for entry in cache["blocks"].values():
                extra = set(entry) - {"k_pool", "v_pool",
                                      "k_scale", "v_scale"}
                if extra:
                    raise ValueError(
                        f"{feature}=True needs an attention-only model: "
                        f"recurrent per-slot state {sorted(extra)} cannot "
                        "be block-cached or rolled back")
        return kv, cache, False

    # -- continuous batching (the serving path) ------------------------------
    def session(self, sc: ServeConfig,
                requests: Optional[Sequence[Request]] = None
                ) -> "StreamSession":
        """An open-intake continuous-batching session over one paged pool.

        With ``requests`` the session starts closed-loop (the whole batch
        submitted up front — exactly what ``generate_stream`` drives).
        With ``requests=None`` it starts EMPTY and callers
        :meth:`StreamSession.submit` requests at arbitrary times between
        :meth:`StreamSession.step` calls — the open-loop mode behind
        ``launch/serve.py --serve`` and the trace harness
        (``serving/trace.py``).  Open-loop sessions need
        ``sc.num_blocks`` pinned: pool geometry cannot be derived from
        requests that have not arrived yet."""
        return StreamSession(self, sc, requests)

    def generate_stream(self, requests: Sequence[Request], sc: ServeConfig
                        ) -> Iterator[Tuple[int, List[int], bool]]:
        """Continuous batching over ``sc.batch_size`` slots of a paged KV
        cache, streamed: yields ``(rid, new_tokens, finished)`` increments
        as each device chunk is observed — callers see tokens the moment
        they exist, not when the batch drains.

        Prompts are consumed by CHUNKED prefill dispatches
        (``sc.prefill_chunk`` tokens per dispatch through the paged
        scatter+attend path) instead of one decode step per token; blocks
        are allocated on demand at chunk boundaries, and when the pool runs
        dry a victim is preempted (requeued with prompt+emitted as its new
        prompt — no tokens are lost or re-yielded): under
        ``sc.sched_policy="sla"`` the victim comes from the lowest
        priority class present, newest-first unless a candidate's
        cached/co-owned prefix makes preempting it strictly cheaper (see
        ``serving/scheduler.py::sla_victim``); under ``"fcfs"`` the
        newest active request goes, as before.
        ``rid`` is the request's index in ``requests``.  After the stream
        drains, ``self.last_stats`` records dispatch and preemption
        counters plus per-class queue-wait percentiles for the run.

        The loop body lives in :class:`StreamSession` (scheduling split
        from dispatch; ``sc.overlap`` pipelines host planning with device
        execution) — this wrapper is the closed-loop driver."""
        if not requests:
            raise ValueError("empty request batch")
        ses = StreamSession(self, sc, requests)
        while ses.has_work:
            yield from ses.step()
        ses.finalize()

    def generate(self, requests: Sequence[Request],
                 sc: ServeConfig) -> List[np.ndarray]:
        """Continuous batching over ``sc.batch_size`` slots of a paged KV
        cache: ragged prompts, per-request ``max_new_tokens``, per-row EOS.
        Requests beyond the slot count queue and are admitted as slots free
        up (preempted requests resume transparently).

        Returns one 1-D int32 array per request (request order), length <=
        its budget (EOS-terminated rows include the EOS token and stop).
        ``generate_stream`` is the incremental form this collects."""
        outs: List[List[int]] = [[] for _ in requests]
        for rid, toks, _ in self.generate_stream(requests, sc):
            outs[rid].extend(toks)
        return [np.asarray(o, np.int32) for o in outs]

    # -- fixed-shape batch (the PR-1 path, kept for equal-shape workloads) ---
    def generate_fixed(self, requests: Sequence[Request],
                       sc: ServeConfig) -> jnp.ndarray:
        """requests: B same-length prompts (possibly all different clients)
        -> (B, max_new_tokens) int32, row-aligned with ``requests``. Every
        row decodes the full shared ``sc.max_new_tokens`` budget."""
        if not requests:
            raise ValueError("empty request batch")
        ids = jnp.asarray([self.registry.acquire(r.client_id)
                           for r in requests], jnp.int32)
        prompts = jnp.stack([jnp.asarray(r.prompt, jnp.int32)
                             for r in requests])
        return self._run(self.params, self.registry.bank(), ids, prompts, sc)


class StreamSession:
    """One continuous-batching serving session over a paged KV pool.

    ``MultiTenantEngine.generate_stream``'s loop body, split into an object
    so SCHEDULING is separate from DISPATCH:

      * :meth:`submit` — enqueue a request at ANY time (open intake): the
        asyncio front end (``launch/serve.py --serve``) and the open-loop
        trace driver (``serving/trace.py``) call it between steps while
        earlier requests are mid-flight.  Closed-loop callers pass the
        whole batch at construction instead.
      * :meth:`step` — ONE engine round: admission -> chunk planning ->
        device dispatch -> observation, returning the ``(rid, new_tokens,
        finished)`` events the round produced (possibly none).
      * :meth:`finalize` — drain bookkeeping: builds ``engine.last_stats``
        and persists the warm prefix pool.  Idempotent.

    **Overlapped dispatch** (``ServeConfig.overlap``, default True): device
    chunks are enqueued through jax async dispatch and the host only
    BLOCKS on a chunk's samples when the next plan can depend on them.
    Decode and verify chunks always emit tokens, but a prefill chunk that
    feeds only prompt tokens emits nothing (``Scheduler.chunk_emits``) and
    its sampled array is garbage by construction — so it is handed to
    ``observe_prefill`` as the UN-materialised device array (host-side
    bookkeeping never reads it) and the host runs admission, prefix
    matching and chunk planning for chunk N+1 — and enqueues its dispatch
    — while the device is still executing chunk N.  Prompt-heavy phases,
    the open-loop TTFT bottleneck, pipeline with zero host-device
    round-trips.

    Decode rounds pipeline through ONE-ROUND-DEFERRED OBSERVATION: when
    the next plan provably cannot depend on a chunk's token values (no
    slot finishes inside it — ``Scheduler.chunk_defer_safe`` — and no
    EOS / speculation / prefix sealing / sharding is configured), the
    chunk's counts advance immediately (``observe_chunk_counts``) while
    its samples stay on device; the NEXT round dispatches chunk N+1 from
    device-chained state (final sampled token, lengths, rng, cached
    tables/ids) and only then materialises chunk N
    (``observe_chunk_values``), so the host's blocking wait overlaps
    chunk N+1's execution.  Events for a deferred chunk surface one
    round late; the tokens per rid are unchanged.  Both settings run the
    SAME dispatches with the SAME inputs, so token streams are BITWISE
    identical; ``overlap=False`` is the synchronous reference loop (one
    materialisation per chunk).
    """

    def __init__(self, engine: MultiTenantEngine, sc: ServeConfig,
                 requests: Optional[Sequence[Request]] = None):
        if sc.spec_decode:
            if sc.temperature > 0:
                raise ValueError(
                    "spec_decode is greedy-only (temperature must be 0): "
                    "acceptance compares drafts against argmax tokens, "
                    "which is what makes the stream bitwise-identical to "
                    "non-speculative decoding")
            if sc.spec_k < 1:
                raise ValueError(f"spec_decode needs spec_k >= 1, "
                                 f"got {sc.spec_k}")
        if sc.kv_dtype not in ("f32", "int8"):
            raise ValueError(
                f"kv_dtype must be 'f32' or 'int8', got {sc.kv_dtype!r}")
        if sc.num_shards < 1:
            raise ValueError(f"num_shards must be >= 1, got {sc.num_shards}")
        if sc.num_shards > 1 and sc.batch_size % sc.num_shards != 0:
            raise ValueError(
                f"batch_size {sc.batch_size} not divisible by "
                f"{sc.num_shards} shards (slots split evenly)")
        self.engine, self.sc = engine, sc
        self.open_loop = requests is None
        if self.open_loop:
            # pool geometry cannot be derived from requests that have not
            # arrived yet — and must not be, or the compiled programs and
            # any warm prefix pool would churn with traffic
            if sc.num_blocks is None:
                raise ValueError(
                    "an open-loop StreamSession needs ServeConfig."
                    "num_blocks pinned (pool geometry cannot follow "
                    "requests that have not arrived yet)")
            num_slots = max(1, sc.batch_size)
            num_blocks = sc.num_blocks
            blocks_per = sc.max_blocks_per_slot or (num_blocks - 1)
            T = max(1, sc.prefill_chunk)
        else:
            prompts = [np.asarray(r.prompt, np.int32).reshape(-1)
                       for r in requests]
            budgets = [sc.max_new_tokens if r.max_new_tokens is None
                       else r.max_new_tokens for r in requests]
            max_span = max(p.size + b for p, b in zip(prompts, budgets))
            if sc.prefix_cache and sc.num_blocks is not None:
                # STABLE pool geometry: cross-call warm reuse must not
                # depend on this batch's request count or longest span (a
                # batch-derived key would silently drop the cache whenever
                # traffic varies) — slots track batch_size and the table
                # spans the whole pool unless pinned tighter.  Extra masked
                # gather lanes are exact zeros, so the wider table stays
                # bitwise-equal.
                num_slots = max(1, sc.batch_size)
                num_blocks = sc.num_blocks
                blocks_per = sc.max_blocks_per_slot or (num_blocks - 1)
            else:
                num_slots = max(1, min(sc.batch_size, len(requests)))
                if sc.num_shards > 1:      # equal per-shard slot counts
                    num_slots = (-(-num_slots // sc.num_shards)
                                 * sc.num_shards)
                blocks_per = (sc.max_blocks_per_slot
                              or blocks_needed(max_span, sc.block_size))
                num_blocks = sc.num_blocks or (1 + num_slots * blocks_per)
            # Preemption replays prompt+emitted, so prefill chunks must fit
            # the longest possible replayed prompt too — width is fixed per
            # run to keep one compiled prefill program.
            T = max(1, min(sc.prefill_chunk, max_span - 1))
        if sc.num_shards > 1 and (num_blocks - 1) % sc.num_shards != 0:
            raise ValueError(
                f"allocatable blocks {num_blocks - 1} not divisible by "
                f"{sc.num_shards} shards (set num_blocks = 1 + "
                f"{sc.num_shards}*k)")
        self.kv, self.cache, self._reused = engine._paged_pool(
            num_slots, num_blocks, blocks_per, sc)
        self._geom_key = (num_slots, sc.block_size, num_blocks, blocks_per,
                          sc.num_shards, sc.kv_dtype)
        # pool-lifetime counter; stats report the delta for this session
        self._evicted0 = self.kv.evicted_cached
        if sc.num_shards > 1:
            self.sched: Any = ShardedScheduler(
                self.kv, registry=engine.registry, policy=sc.sched_policy,
                aging_ticks=sc.sched_aging,
                spec_k=sc.spec_k if sc.spec_decode else 0,
                spec_ngram=sc.spec_ngram)
        else:
            self.sched = Scheduler(self.kv, policy=sc.sched_policy,
                                   aging_ticks=sc.sched_aging,
                                   spec_k=sc.spec_k if sc.spec_decode else 0,
                                   spec_ngram=sc.spec_ngram)
        self._next_rid = 0
        if not self.open_loop:
            for r in requests:
                self.submit(r)
        self.bank = engine.registry.bank()
        # hot-swap: the registry's bank_epoch moves when an online update
        # (re-)registers a client mid-serve; step() re-snapshots the bank
        # at its next round boundary.  Untouched clients' slots hold
        # bitwise-identical weights across the swap, so their streams are
        # unchanged; the updated client's NEW requests also pick up a
        # bumped version() scope, invalidating its cached prefixes.
        self._bank_epoch = getattr(engine.registry, "bank_epoch", 0)
        self.bank_refreshes = 0
        self.ids = np.zeros((num_slots,), np.int32)
        self.rng = jax.random.PRNGKey(sc.seed)
        engine.last_stats = None     # a partially consumed stream has none
        self.T = T
        # verify chunks have their own fixed width (drafted tokens + the
        # feedback token) so the verify program also compiles once per run
        self.Tv = 1 + sc.spec_k
        # EOS can end a row long before its budget; keep chunks short so
        # its slot frees (and admits the queue head) at the next boundary.
        self.cap = (min(sc.scan_chunk, 8) if sc.eos_id is not None
                    else sc.scan_chunk)
        # with a mesh, dispatches trace under it so the "data"-axis
        # sharding constraints in models/layers.py bind the fused batch to
        # devices; without one the constraints no-op (single-device path)
        self._mesh = (sc.mesh if sc.mesh is not None
                      else contextlib.nullcontext())
        # overlap fast path: device-resident plan state.  Block tables /
        # adapter ids are re-marshalled only when ``kv.table_version``
        # moves (admission, growth, rollback, release); lengths chain
        # through the jit outputs (``advance`` is mirrored on device) and
        # fall back to a host refresh after verify rounds, whose
        # acceptance-dependent advance/rollback is host logic.
        self._tables_ver = -1
        self._bt_dev = None
        self._lens_dev = None
        self._lens_ok = False
        self._ids_dev = None
        # decode pipelining: in the steady decode state the feed token for
        # chunk N+1 is chunk N's final sample, available as a DEVICE array
        # from the decode jit — chaining it (with the active mask, constant
        # while ``table_version`` stands) lets the host dispatch N+1 and
        # only then materialise N ("one-round-deferred observation"),
        # so the host's observe/plan work for N overlaps N+1's execution.
        # Deferral is legal only when the next plan cannot depend on N's
        # token values — see ``Scheduler.chunk_defer_safe`` plus the config
        # gates here: EOS/speculation read values to stop or draft, prefix
        # sealing consumes them in ``advance``, and the sharded scheduler
        # doesn't implement the split.
        self._last_dev = None
        self._act_dev = None
        self._last_ok = False
        self._pending: Optional[Tuple[Any, int, List[int]]] = None
        self._defer_cfg_ok = (sc.overlap and sc.num_shards == 1
                              and sc.eos_id is None and not sc.spec_decode
                              and not sc.prefix_cache)
        self._finalized = False

    # -- intake --------------------------------------------------------------
    def submit(self, request: Request,
               arrival_time: Optional[float] = None) -> int:
        """Enqueue ``request``; returns its rid (submission order — the rid
        tagged on this request's events).  Open-loop drivers pass
        ``arrival_time`` (``time.monotonic()`` seconds) so admission also
        records WALL-CLOCK queue waits
        (``last_stats["classes"][cls]["wait_wall_ms_*"]``)."""
        rid, self._next_rid = self._next_rid, self._next_rid + 1
        reg = self.engine.registry
        p = np.asarray(request.prompt, np.int32).reshape(-1)
        b = (self.sc.max_new_tokens if request.max_new_tokens is None
             else request.max_new_tokens)
        # cached K/V depends on the adapter: scope hits by client AND by
        # the registry's version of its weights (re-registration
        # invalidates without any explicit flush)
        scope = (request.client_id, reg.version(request.client_id))
        # explicit request priority wins; else the client's registered
        # default; else the scheduler's baseline class
        priority = (request.priority
                    or reg.default_priority(request.client_id)
                    or "batch")
        self.sched.submit(rid, request.client_id, p, b, scope=scope,
                          priority=priority, deadline=request.deadline,
                          arrival_time=arrival_time)
        return rid

    @property
    def has_work(self) -> bool:
        """True while any request is queued or holds a slot."""
        return self.sched.has_work

    # -- one engine round ----------------------------------------------------
    def step(self) -> List[Tuple[int, List[int], bool]]:
        """Admission -> chunk planning -> device dispatch -> observation.
        Returns this round's ``(rid, new_tokens, finished)`` events ([] on
        an idle session).  Raises ``RuntimeError`` if queued work cannot
        make progress (a request that can never fit the pool)."""
        eng, sc, sched = self.engine, self.sc, self.sched
        epoch = getattr(eng.registry, "bank_epoch", 0)
        if epoch != self._bank_epoch:
            # online update landed between rounds: swap in the new bank for
            # every dispatch from here on.  A deferred (pipelined) chunk was
            # already dispatched under the old snapshot — its values are
            # unaffected by when we materialise them, so no flush needed.
            self.bank = eng.registry.bank()
            self._bank_epoch = epoch
            self.bank_refreshes += 1
        flushed: List[Tuple[int, List[int], bool]] = []
        if self._pending is not None and (
                sched.queued or sched.prefill_pending
                or self._growth_possible()):
            # leave the pipelined steady state: the deferred chunk's values
            # must land BEFORE admission or planning can preempt a slot
            # (preemption replays prompt+emitted, which must include them)
            flushed = self._flush_pending()
        for slot, cid in sched.admit():
            self.ids[slot] = eng.registry.acquire(cid)
            self.cache = reset_slot(self.cache, slot)
            self._ids_dev = None
        plan = sched.prepare_chunk(self.T, self.cap)
        if plan is None:
            if sched.has_work:           # nothing active: admit failed
                raise RuntimeError("scheduler stalled with queued work")
            return flushed               # idle open-loop session
        # marshal plan state.  Synchronous reference loop: rebuild device
        # tables and ids every round.  Overlap fast path: reuse the cached
        # device arrays while ``table_version`` stands still — on
        # advance-only rounds (the steady decode state) the host ships only
        # the chunk plan, and lengths come chained from the previous jit
        # output instead of a fresh host->device copy.
        ver = self.kv.table_version
        if not sc.overlap or ver != self._tables_ver:
            self._bt_dev, self._lens_dev = self.kv.device_tables()
            self._tables_ver, self._lens_ok = ver, True
            # any table move (admit/growth/rollback/release) can change the
            # active set or a slot's feed token — drop the chained decode
            # state and remarshal it from the scheduler this round
            self._last_ok, self._act_dev = False, None
        elif not self._lens_ok:          # tables stand, verify moved lengths
            self._lens_dev = self.kv.device_tables()[1]
            self._lens_ok = True
        if self._ids_dev is None:
            # .copy(): self.ids is mutated in place on admit while an
            # earlier dispatch holding a (possibly zero-copy aliased)
            # view may still be queued — snapshot, never a live view
            self._ids_dev = jnp.asarray(self.ids.copy())
        bt, lens, ids = self._bt_dev, self._lens_dev, self._ids_dev
        if plan[0] == "prefill":
            arrs = sched.prefill_arrays(self.T)
            with self._mesh:
                sampled, self.cache, self._lens_dev, self.rng = (
                    eng._prefill_chunk(
                        eng.params, self.bank, ids, self.cache,
                        jnp.asarray(arrs["tokens"]), lens,
                        jnp.asarray(arrs["n_new"]), bt, self.rng,
                        sc.temperature, backend=sc.paged_backend))
            # THE overlap point: a chunk that emits no token has a sampled
            # array nothing will read (observe_prefill only indexes it for
            # feedback rows / completing prompts), so skip materialising it
            # — the host returns to planning the next chunk while this one
            # is still executing on device.
            if not sc.overlap or sched.chunk_emits(arrs["n_new"]):
                sampled = np.asarray(sampled)
            self._last_ok = False        # completing prompts seed next_token
            events = sched.observe_prefill(arrs["n_new"], sampled,
                                           eos_id=sc.eos_id)
        elif plan[0] == "verify":
            # keep the per-round rng consumption identical to the other
            # chunk kinds (they split inside the jit) so streams stay
            # bitwise-stable across scheduling mixes
            self.rng, _ = jax.random.split(self.rng)
            arrs = sched.verify_arrays(self.Tv)
            with self._mesh:
                greedy, self.cache = eng._verify_chunk(
                    eng.params, self.bank, ids, self.cache,
                    jnp.asarray(arrs["tokens"]), lens,
                    jnp.asarray(arrs["n_new"]), bt,
                    backend=sc.paged_backend)
            # acceptance decides the advance/rollback amounts on host
            self._lens_ok, self._last_ok = False, False
            events = sched.observe_verify(arrs["n_new"], np.asarray(greedy),
                                          eos_id=sc.eos_id)
        else:
            n = plan[1]
            defer = self._defer_cfg_ok and sched.chunk_defer_safe(n)
            if sc.overlap and self._last_ok:
                last, act = self._last_dev, self._act_dev
            else:
                st = sched.chunk_arrays()
                last, act = jnp.asarray(st["last"]), jnp.asarray(st["active"])
            with self._mesh:
                (out, self.cache, self._lens_dev, self._last_dev,
                 self.rng) = eng._decode_chunk(
                    eng.params, self.bank, ids, self.cache, last, act,
                    lens, bt, jnp.int32(n), self.rng, sc.temperature,
                    chunk_cap=self.cap, backend=sc.paged_backend)
            self._act_dev, self._last_ok = act, sc.overlap
            if self._pending is not None:
                # this chunk is queued behind the deferred one, so
                # materialising the latter's samples here overlaps with
                # this chunk's device execution — the pipelining payoff
                flushed = self._flush_pending()
            if defer:
                self._pending = (out, n, sched.observe_chunk_counts(n))
                return flushed
            events = sched.observe_chunk(np.asarray(out)[:n],
                                         eos_id=sc.eos_id)
        return flushed + events if flushed else events

    # -- deferred-observation plumbing ---------------------------------------
    def _growth_possible(self) -> bool:
        """Whether ANY active slot's next decode chunk (at most ``cap``
        steps) could outgrow its owned blocks.  Growth is the only path to
        preemption on a pure-decode round, so while this is False the next
        ``prepare_chunk`` provably leaves the slot set untouched and a
        deferred chunk may stay unmaterialised through it."""
        kv = self.kv
        for slot in self.sched.active_slots:
            if (int(kv.lengths[slot]) + self.cap
                    > kv.owned_blocks(slot) * kv.block_size):
                return True
        return False

    def _flush_pending(self) -> List[Tuple[int, List[int], bool]]:
        """Materialise the deferred decode chunk (blocking on its dispatch;
        anything queued behind it keeps running) and fold its values into
        the scheduler — its events, one round late."""
        out, n, slots = self._pending
        self._pending = None
        return self.sched.observe_chunk_values(slots, np.asarray(out)[:n])

    # -- drain ---------------------------------------------------------------
    def finalize(self) -> dict:
        """Build ``engine.last_stats`` for this session and (with
        ``prefix_cache``) persist the warm pool for the next one.  Safe to
        call more than once; returns the stats dict."""
        if self._finalized:
            return self.engine.last_stats
        self._finalized = True
        if self._pending is not None:    # stream abandoned mid-pipeline
            self._flush_pending()
        sc, sched, kv = self.sc, self.sched, self.kv
        classes = {}
        for cname in PRIORITY_CLASSES:
            waits = sched.wait_ticks.get(cname, [])
            walls = sched.wait_wall.get(cname, [])
            if not waits and cname not in sched.preemptions_by_class:
                continue                     # class unused this stream
            entry = {
                "admitted": len(waits),
                "wait_p50": float(np.percentile(waits, 50)) if waits else 0.0,
                "wait_p99": float(np.percentile(waits, 99)) if waits else 0.0,
                "preemptions": sched.preemptions_by_class.get(cname, 0)}
            if walls:     # only present when driven with arrival_time
                entry["wait_wall_ms_p50"] = float(
                    np.percentile(walls, 50) * 1e3)
                entry["wait_wall_ms_p99"] = float(
                    np.percentile(walls, 99) * 1e3)
            classes[cname] = entry
        stats = {"prefill_dispatches": sched.prefill_dispatches,
                 "decode_dispatches": sched.decode_dispatches,
                 "decode_steps": sched.steps,
                 "spec_decode": sc.spec_decode,
                 "verify_dispatches": sched.verify_dispatches,
                 "drafted_tokens": sched.drafted_tokens,
                 "accepted_tokens": sched.accepted_tokens,
                 "acceptance_rate": (sched.accepted_tokens
                                     / max(1, sched.drafted_tokens)),
                 "rollback_tokens": sched.rollback_tokens,
                 "rollback_blocks": sched.rollback_blocks,
                 "preemptions": sched.preemptions,
                 "prompt_tokens": sched.prompt_tokens,
                 "prefix_hit_tokens": sched.prefix_hit_tokens,
                 "prefix_hit_rate": (sched.prefix_hit_tokens
                                     / max(1, sched.prompt_tokens)),
                 "prefix_cached_blocks": kv.cached_blocks,
                 "prefix_evictions": kv.evicted_cached - self._evicted0,
                 "prefix_pool_reused": self._reused,
                 "adapter_bank_refreshes": self.bank_refreshes,
                 "sched_policy": sc.sched_policy,
                 "num_shards": sc.num_shards,
                 "kv_dtype": sc.kv_dtype,
                 "overlap": sc.overlap,
                 "open_loop": self.open_loop,
                 # queue waits by class: wait_p50/p99 in admission rounds
                 # (ticks); wait_wall_ms_* in wall-clock milliseconds when
                 # the session was driven open-loop with arrival times
                 "classes": classes,
                 "victim_sealed_fraction_mean": (
                     float(np.mean(sched.victim_sealed_fractions))
                     if sched.victim_sealed_fractions else 0.0)}
        if sc.num_shards > 1:
            stats["shard_placements"] = dict(sched.placed)
        self.engine.last_stats = stats
        if sc.prefix_cache:
            self.engine._warm = (self._geom_key, self.kv, self.cache)
        return stats
