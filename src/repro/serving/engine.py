"""Serving engine: batched KV-cache decoding with (fused) LoRA adapters.

FDLoRA's inference story: after stage 3, each client's dual LoRA merges into
one standard adapter (Eq. 7) — so serving is single-adapter and can also use
the fused Pallas kernels. The engine supports:

  * ``prefill``: run the full prompt once, fill the cache (sub-quadratic
    archs fill SSM state / windowed cache),
  * ``decode``: steps of one token for a whole request batch,
  * greedy and temperature sampling.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp

from repro.core.lora import lora_scale

Params = Any


@dataclasses.dataclass
class ServeConfig:
    batch_size: int
    max_new_tokens: int = 32
    cache_len: int = 4096
    temperature: float = 0.0  # 0 => greedy
    seed: int = 0


class Engine:
    def __init__(self, model, cfg, params: Params,
                 adapters: Optional[Params] = None):
        self.model, self.cfg = model, cfg
        self.params, self.adapters = params, adapters
        self.scale = lora_scale(cfg)
        self._decode = jax.jit(self._decode_impl)
        self._prefill = jax.jit(self._prefill_impl)

    # -- steps ---------------------------------------------------------------
    def _prefill_impl(self, params, adapters, cache, tokens):
        """Sequential prefill through the decode path (cache-filling).

        For production prefill one would run the parallel forward and scatter
        K/V into the cache; the sequential scan keeps one code path across
        attention/SSM/hybrid and is what the ``prefill_32k`` dry-run shape
        lowers via ``forward`` instead."""
        def step(carry, tok):
            cache, pos = carry
            logits, cache = self.model.decode_step(
                params, cache, tok[:, None], pos, adapters=adapters,
                lora_scale=self.scale)
            return (cache, pos + 1), logits[:, 0]

        (cache, pos), logits = jax.lax.scan(
            step, (cache, jnp.int32(0)), tokens.T)
        return cache, pos, logits[-1]

    def _decode_impl(self, params, adapters, cache, tok, pos, rng, temperature):
        logits, cache = self.model.decode_step(
            params, cache, tok, pos, adapters=adapters, lora_scale=self.scale)
        lg = logits[:, 0]
        greedy = jnp.argmax(lg, axis=-1)
        sampled = jax.random.categorical(rng, lg / jnp.maximum(temperature, 1e-6))
        nxt = jnp.where(temperature > 0, sampled, greedy)
        return nxt.astype(jnp.int32), cache

    # -- public API ------------------------------------------------------------
    def generate(self, prompts: jnp.ndarray, sc: ServeConfig) -> jnp.ndarray:
        """prompts: (B, S_prompt) int32 -> (B, max_new_tokens) int32."""
        B = prompts.shape[0]
        cache = self.model.init_decode_cache(B, sc.cache_len)
        cache, pos, last_logits = self._prefill(self.params, self.adapters,
                                                cache, prompts)
        tok = jnp.argmax(last_logits, axis=-1)[:, None].astype(jnp.int32)
        rng = jax.random.PRNGKey(sc.seed)
        out = [tok[:, 0]]
        for _ in range(sc.max_new_tokens - 1):
            rng, sub = jax.random.split(rng)
            nxt, cache = self._decode(self.params, self.adapters, cache, tok,
                                      pos, sub, sc.temperature)
            pos = pos + 1
            tok = nxt[:, None]
            out.append(nxt)
        return jnp.stack(out, axis=1)
