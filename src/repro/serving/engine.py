"""Serving engines: batched KV-cache decoding with (fused) LoRA adapters.

FDLoRA's inference story: after stage 3, each client's dual LoRA merges into
one standard adapter (Eq. 7). Two engines share one generation loop:

  * :class:`Engine` — single-tenant: one adapter tree bound at construction
    (the seed behaviour, kept for training-side evals and examples).
  * :class:`MultiTenantEngine` — one base-model program + an
    :class:`~repro.serving.registry.AdapterRegistry` bank; callers submit
    :class:`Request` objects carrying ``client_id`` and the engine serves
    *mixed-client* batches, routing every batch row to its client's adapter
    via per-row ``adapter_ids`` (gathered on-chip, see
    ``kernels/batched_lora.py``).

``MultiTenantEngine.generate_stream`` is a **continuous-batching** loop
over a paged KV cache (``serving/kv_cache.py`` + ``serving/scheduler.py``):
ragged prompts fed through CHUNKED multi-token prefill dispatches, on-demand
block growth with preemption when the pool runs dry, per-request token
budgets, per-row EOS, admission of queued requests into slots freed
mid-flight — and ``(rid, tokens, finished)`` increments yielded the moment
each chunk is observed, before the batch drains.  ``generate`` collects the
stream into per-request arrays; ``generate_fixed`` keeps the fixed-shape
one-batch-per-call path (equal-length prompts, one shared budget) —
equal-shape greedy requests produce bit-identical tokens on both.
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Any, Iterator, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.lora import lora_scale
from repro.serving.kv_cache import PagedKVCache, blocks_needed, reset_slot
from repro.serving.registry import AdapterRegistry
from repro.serving.scheduler import PRIORITY_CLASSES, Scheduler
from repro.serving.sharded import ShardedPagedKVCache, ShardedScheduler

Params = Any


@dataclasses.dataclass
class ServeConfig:
    batch_size: int                  # decode slots (continuous) / batch rows
    max_new_tokens: int = 32         # default per-request budget
    cache_len: int = 4096            # fixed-path cache length
    temperature: float = 0.0         # 0 => greedy
    seed: int = 0
    eos_id: Optional[int] = None     # finished rows emit pad_id afterwards
    pad_id: int = 0
    block_size: int = 16             # paged-cache block size (continuous)
    num_blocks: Optional[int] = None  # pool size; None => full residency
    max_blocks_per_slot: Optional[int] = None  # block-table width; None =>
    #                                  longest span (or the whole pool when
    #                                  prefix caching with a pinned pool)
    scan_chunk: int = 32             # max device steps between admissions
    prefill_chunk: int = 16          # prompt tokens per prefill dispatch
    prefix_cache: bool = False       # content-addressed shared blocks:
    #                                  shared prompt prefixes (and preempted
    #                                  requests' replays) skip re-prefill,
    #                                  within AND across generate calls.
    #                                  Warm-vs-cold BITWISE equality holds
    #                                  for greedy decoding (temperature 0);
    #                                  with temperature > 0 samples stay
    #                                  valid but draw a different rng
    #                                  stream (fewer dispatches = fewer
    #                                  rng splits), so runs don't replay.
    sched_policy: str = "sla"        # "sla": priority-class admission with
    #                                  aging + scored preemption victims
    #                                  (prefix-aware); "fcfs": legacy
    #                                  arrival order + newest-first victims
    sched_aging: int = 16            # admission rounds queued per one-class
    #                                  promotion under "sla" (0 disables)
    paged_backend: str = "jnp"       # paged-attention impl for the
    #                                  continuous path: "jnp" gather oracle
    #                                  (CPU default) | "pallas" kernels
    #                                  (interpret-mode on CPU; on TPU also
    #                                  set cfg.pallas_interpret=False)
    spec_decode: bool = False        # speculative decoding (continuous
    #                                  path): prompt-lookup self-drafts of
    #                                  up to spec_k tokens verified in ONE
    #                                  prefill-shaped dispatch; greedy-only
    #                                  (temperature must be 0) and bitwise-
    #                                  identical to non-speculative greedy
    #                                  decoding.  Rejected drafts roll the
    #                                  paged cache back token-granularly.
    spec_k: int = 4                  # max drafted tokens per slot per round
    spec_ngram: int = 3              # longest history n-gram the drafter
    #                                  matches (see serving/spec_decode.py)
    num_shards: int = 1              # partition the paged block pool +
    #                                  request slots into this many shards
    #                                  (serving/sharded.py): per-shard free
    #                                  lists, seal chains and preemption,
    #                                  placement-aware admission, one fused
    #                                  dispatch per round.  1 (default) is
    #                                  the single-pool path, bit-identical
    #                                  to pre-shard behaviour.
    mesh: Any = None                 # optional jax.sharding.Mesh entered
    #                                  around device dispatches: activates
    #                                  the "data"-axis sharding constraint
    #                                  on the fused batch (slots are shard-
    #                                  contiguous, so shard boundaries land
    #                                  on device boundaries).  None = no
    #                                  mesh (single device, the default).
    kv_dtype: str = "f32"            # paged K/V pool storage: "f32" keeps
    #                                  the unquantized (bf16) pools exactly
    #                                  as before; "int8" stores blocks
    #                                  quantized with per-(block, position,
    #                                  kv-head) fp32 scales — ~1.78x the
    #                                  blocks per HBM byte, dequantized at
    #                                  read inside both paged backends.
    #                                  Greedy streams match the f32 path
    #                                  within a documented error bound (see
    #                                  tests/test_quant.py), NOT bitwise.


@dataclasses.dataclass
class Request:
    """One generation request. ``prompt``: (S,) int32 — ragged lengths are
    fine under ``MultiTenantEngine.generate`` (continuous batching); the
    fixed path (``generate_fixed``) still needs every prompt to share S.
    ``max_new_tokens`` overrides ``ServeConfig.max_new_tokens`` per request.
    ``priority`` names a scheduling class (``interactive`` | ``batch`` |
    ``background``); ``None`` (the default) falls back to the client's
    registered default (``AdapterRegistry.register(...,
    default_priority=)``) and then to ``"batch"`` — an explicit request
    priority always wins.  ``deadline`` (any comparable number, e.g. a
    unix timestamp) breaks admission ties earliest-first within a class —
    both only matter under ``ServeConfig.sched_policy="sla"``."""
    client_id: Any
    prompt: Any
    max_new_tokens: Optional[int] = None
    priority: Optional[str] = None
    deadline: Optional[float] = None


class _EngineBase:
    """The generation loop, parameterised by optional per-row adapter ids."""

    def __init__(self, model, cfg):
        self.model, self.cfg = model, cfg
        self.scale = lora_scale(cfg)
        self._decode = jax.jit(self._decode_impl)
        self._prefill = jax.jit(self._prefill_impl)
        self._decode_chunk = jax.jit(self._decode_chunk_impl,
                                     static_argnames=("chunk_cap", "backend"))
        self._prefill_chunk = jax.jit(self._prefill_chunk_impl,
                                      static_argnames=("backend",))
        self._verify_chunk = jax.jit(self._verify_chunk_impl,
                                     static_argnames=("backend",))

    # -- steps ---------------------------------------------------------------
    def _prefill_impl(self, params, adapters, ids, cache, tokens):
        """Sequential prefill through the decode path (cache-filling).

        For production prefill one would run the parallel forward and scatter
        K/V into the cache; the sequential scan keeps one code path across
        attention/SSM/hybrid and is what the ``prefill_32k`` dry-run shape
        lowers via ``forward`` instead."""
        def step(carry, tok):
            cache, pos = carry
            logits, cache = self.model.decode_step(
                params, cache, tok[:, None], pos, adapters=adapters,
                lora_scale=self.scale, adapter_ids=ids)
            return (cache, pos + 1), logits[:, 0]

        (cache, pos), logits = jax.lax.scan(
            step, (cache, jnp.int32(0)), tokens.T)
        return cache, pos, logits[-1]

    def _decode_impl(self, params, adapters, ids, cache, tok, pos, rng,
                     temperature):
        logits, cache = self.model.decode_step(
            params, cache, tok, pos, adapters=adapters, lora_scale=self.scale,
            adapter_ids=ids)
        return self._sample(logits, rng, temperature), cache

    def _decode_chunk_impl(self, params, adapters, ids, cache, last, active,
                           lengths, block_tables, n_steps, rng, temperature,
                           chunk_cap, backend=None):
        """Up to ``n_steps`` (dynamic, <= static ``chunk_cap``) decode steps
        fully on device: each slot feeds its last sampled token — one
        dispatch per chunk instead of per token.  (Prompts are fed by
        ``_prefill_chunk``; every active slot here is past its prompt.)
        ``backend`` (static) selects the paged-attention impl
        (``ServeConfig.paged_backend``).  Returns the (chunk_cap, K)
        sampled block (rows >= n_steps are garbage; the scheduler slices)."""
        K = ids.shape[0]

        def body(t, carry):
            cache, last, lengths, rng, out = carry
            rng, sub = jax.random.split(rng)
            logits, cache = self.model.decode_step(
                params, cache, last[:, None], lengths, adapters=adapters,
                lora_scale=self.scale, adapter_ids=ids,
                block_tables=block_tables, paged_backend=backend)
            nxt = self._sample(logits, sub, temperature)
            out = out.at[t].set(nxt)
            return (cache, nxt, lengths + active, rng, out)

        out0 = jnp.zeros((chunk_cap, K), jnp.int32)
        carry = jax.lax.fori_loop(
            0, n_steps, body, (cache, last, lengths, rng, out0))
        cache, _, _, _, out = carry
        return out, cache

    def _prefill_chunk_impl(self, params, adapters, ids, cache, tokens,
                            lengths, n_new, block_tables, rng, temperature,
                            backend=None):
        """One chunked-prefill dispatch: scatter+attend ``tokens`` (K, T)
        — ``n_new[k]`` valid per row — through the paged cache, and sample
        each row's logits at its LAST valid position (the first emitted
        token for rows whose prompt just completed; garbage, discarded by
        the scheduler, for the rest).  Returns ((K,) sampled, cache)."""
        logits, cache = self.model.prefill_step(
            params, cache, tokens, lengths, n_new, adapters=adapters,
            lora_scale=self.scale, adapter_ids=ids,
            block_tables=block_tables, paged_backend=backend)
        K, T, _ = logits.shape
        rows = jnp.arange(K, dtype=jnp.int32)
        lg = logits[rows, jnp.clip(n_new - 1, 0, T - 1)]       # (K, V)
        return self._sample(lg[:, None], rng, temperature), cache

    def _verify_chunk_impl(self, params, adapters, ids, cache, tokens,
                           lengths, n_new, block_tables, backend=None):
        """One draft-verify dispatch: the SAME paged prefill dataflow as
        ``_prefill_chunk_impl`` (``Model.verify_step`` delegates to
        ``prefill_step`` — scatter + causal chunk attention against the
        pool, both backends), but the greedy sample comes back for EVERY
        chunk position, not just the last valid one: position ``t``'s
        argmax is the token non-speculative decoding would have emitted
        after feeding the chunk up to ``t``, which is exactly what the
        scheduler's acceptance rule compares drafts against.  Greedy-only
        (``generate_stream`` rejects spec_decode with temperature > 0),
        so no rng is threaded.  Returns ((K, T) int32 greedy, cache)."""
        logits, cache = self.model.verify_step(
            params, cache, tokens, lengths, n_new, adapters=adapters,
            lora_scale=self.scale, adapter_ids=ids,
            block_tables=block_tables, paged_backend=backend)
        return jnp.argmax(logits, axis=-1).astype(jnp.int32), cache

    @staticmethod
    def _sample(logits, rng, temperature):
        lg = logits[:, 0]
        greedy = jnp.argmax(lg, axis=-1)
        sampled = jax.random.categorical(rng, lg / jnp.maximum(temperature, 1e-6))
        return jnp.where(temperature > 0, sampled, greedy).astype(jnp.int32)

    # -- loop ----------------------------------------------------------------
    def _run(self, params, adapters, ids, prompts: jnp.ndarray,
             sc: ServeConfig) -> jnp.ndarray:
        """prompts: (B, S_prompt) int32 -> (B, max_new_tokens) int32.

        With ``sc.eos_id`` set, a row that samples EOS emits ``sc.pad_id``
        from then on and the loop exits early once every row has finished
        (the output stays (B, max_new_tokens), pad-filled)."""
        B = prompts.shape[0]
        cache = self.model.init_decode_cache(B, sc.cache_len)
        cache, pos, last_logits = self._prefill(params, adapters, ids,
                                                cache, prompts)
        tok = jnp.argmax(last_logits, axis=-1)[:, None].astype(jnp.int32)
        rng = jax.random.PRNGKey(sc.seed)
        out = [tok[:, 0]]
        finished = (tok[:, 0] == sc.eos_id) if sc.eos_id is not None else None
        for _ in range(sc.max_new_tokens - 1):
            if finished is not None and bool(finished.all()):
                break
            rng, sub = jax.random.split(rng)
            nxt, cache = self._decode(params, adapters, ids, cache, tok,
                                      pos, sub, sc.temperature)
            if finished is not None:
                nxt = jnp.where(finished, jnp.int32(sc.pad_id), nxt)
                finished = finished | (nxt == sc.eos_id)
            pos = pos + 1
            tok = nxt[:, None]
            out.append(nxt)
        res = jnp.stack(out, axis=1)
        if res.shape[1] < sc.max_new_tokens:          # early all-EOS exit
            pad = jnp.full((B, sc.max_new_tokens - res.shape[1]),
                           sc.pad_id, jnp.int32)
            res = jnp.concatenate([res, pad], axis=1)
        return res


class Engine(_EngineBase):
    """Single-tenant engine: exactly one adapter tree bound per instance."""

    def __init__(self, model, cfg, params: Params,
                 adapters: Optional[Params] = None):
        super().__init__(model, cfg)
        self.params, self.adapters = params, adapters

    def generate(self, prompts: jnp.ndarray, sc: ServeConfig) -> jnp.ndarray:
        """prompts: (B, S_prompt) int32 -> (B, max_new_tokens) int32."""
        return self._run(self.params, self.adapters, None, prompts, sc)


class MultiTenantEngine(_EngineBase):
    """One compiled program serving every registered client.

    Requests carry ``client_id``; the engine resolves each to its bank slot
    (LRU-touching the registry) and threads the per-row slot vector through
    the model as ``adapter_ids``. Adapter registration/eviction between
    calls never changes bank shapes, so the jitted programs are reused
    across any tenant mix.
    """

    def __init__(self, model, cfg, params: Params, registry: AdapterRegistry):
        super().__init__(model, cfg)
        self.params, self.registry = params, registry
        self.last_stats: Optional[dict] = None   # set when a stream drains
        # cross-call prefix-cache state: (pool key, PagedKVCache, device
        # cache) persisted at stream drain so the NEXT generate call's
        # admission can match blocks sealed by this one.  Retained until a
        # prefix_cache stream with a different pool geometry replaces it or
        # release_prefix_cache() drops it — a deliberate warm cache, which
        # means the device pools stay resident across unrelated calls.
        self._warm: Optional[Tuple[tuple, PagedKVCache, Any]] = None

    def release_prefix_cache(self) -> None:
        """Drop the warm prefix-cache pool (host allocator + device K/V
        blocks).  The next ``prefix_cache=True`` stream starts cold; call
        this when a tenant mix moves on and the retained pool's device
        memory is worth more than future prefix hits."""
        self._warm = None

    def _paged_pool(self, num_slots: int, num_blocks: int, blocks_per: int,
                    sc: ServeConfig) -> Tuple[PagedKVCache, Any, bool]:
        """A (host allocator, device cache, reused) triple for one stream.
        With ``sc.prefix_cache``, reuse the pair persisted by the last
        drained stream when the pool geometry matches — sealed blocks (and
        their device K/V) survive, so shared prompt prefixes across calls
        skip prefill.  A geometry change or a stream abandoned mid-flight
        drops the warm state and starts cold (``last_stats
        ['prefix_pool_reused']`` says which happened)."""
        key = (num_slots, sc.block_size, num_blocks, blocks_per,
               sc.num_shards, sc.kv_dtype)
        if sc.prefix_cache:
            warm, self._warm = self._warm, None   # taken; restored at drain
            if warm is not None and warm[0] == key and warm[1].idle:
                return warm[1], warm[2], True
        if sc.num_shards > 1:
            kv: Any = ShardedPagedKVCache(
                sc.num_shards, num_slots, sc.block_size, num_blocks,
                blocks_per, prefix_cache=sc.prefix_cache)
        else:
            kv = PagedKVCache(num_slots, sc.block_size, num_blocks,
                              blocks_per, prefix_cache=sc.prefix_cache)
        cache = self.model.init_paged_decode_cache(num_slots, num_blocks,
                                                   sc.block_size,
                                                   kv_dtype=sc.kv_dtype)
        if sc.prefix_cache or sc.spec_decode:
            # recurrent SSM state is per-slot and dense — it cannot be
            # reconstructed from cached K/V blocks (a prefix hit would
            # silently skip state updates) nor rolled back token-granularly
            # (a verify dispatch advances it through rejected drafts)
            feature = ("prefix_cache" if sc.prefix_cache else "spec_decode")
            for entry in cache["blocks"].values():
                extra = set(entry) - {"k_pool", "v_pool",
                                      "k_scale", "v_scale"}
                if extra:
                    raise ValueError(
                        f"{feature}=True needs an attention-only model: "
                        f"recurrent per-slot state {sorted(extra)} cannot "
                        "be block-cached or rolled back")
        return kv, cache, False

    # -- continuous batching (the serving path) ------------------------------
    def generate_stream(self, requests: Sequence[Request], sc: ServeConfig
                        ) -> Iterator[Tuple[int, List[int], bool]]:
        """Continuous batching over ``sc.batch_size`` slots of a paged KV
        cache, streamed: yields ``(rid, new_tokens, finished)`` increments
        as each device chunk is observed — callers see tokens the moment
        they exist, not when the batch drains.

        Prompts are consumed by CHUNKED prefill dispatches
        (``sc.prefill_chunk`` tokens per dispatch through the paged
        scatter+attend path) instead of one decode step per token; blocks
        are allocated on demand at chunk boundaries, and when the pool runs
        dry a victim is preempted (requeued with prompt+emitted as its new
        prompt — no tokens are lost or re-yielded): under
        ``sc.sched_policy="sla"`` the victim comes from the lowest
        priority class present, newest-first unless a candidate's
        cached/co-owned prefix makes preempting it strictly cheaper (see
        ``serving/scheduler.py::sla_victim``); under ``"fcfs"`` the
        newest active request goes, as before.
        ``rid`` is the request's index in ``requests``.  After the stream
        drains, ``self.last_stats`` records dispatch and preemption
        counters plus per-class queue-wait percentiles for the run."""
        if not requests:
            raise ValueError("empty request batch")
        if sc.spec_decode:
            if sc.temperature > 0:
                raise ValueError(
                    "spec_decode is greedy-only (temperature must be 0): "
                    "acceptance compares drafts against argmax tokens, "
                    "which is what makes the stream bitwise-identical to "
                    "non-speculative decoding")
            if sc.spec_k < 1:
                raise ValueError(f"spec_decode needs spec_k >= 1, "
                                 f"got {sc.spec_k}")
        if sc.kv_dtype not in ("f32", "int8"):
            raise ValueError(
                f"kv_dtype must be 'f32' or 'int8', got {sc.kv_dtype!r}")
        if sc.num_shards < 1:
            raise ValueError(f"num_shards must be >= 1, got {sc.num_shards}")
        if sc.num_shards > 1 and sc.batch_size % sc.num_shards != 0:
            raise ValueError(
                f"batch_size {sc.batch_size} not divisible by "
                f"{sc.num_shards} shards (slots split evenly)")
        prompts = [np.asarray(r.prompt, np.int32).reshape(-1)
                   for r in requests]
        budgets = [sc.max_new_tokens if r.max_new_tokens is None
                   else r.max_new_tokens for r in requests]
        max_span = max(p.size + b for p, b in zip(prompts, budgets))
        if sc.prefix_cache and sc.num_blocks is not None:
            # STABLE pool geometry: cross-call warm reuse must not depend
            # on this batch's request count or longest span (a batch-derived
            # key would silently drop the cache whenever traffic varies) —
            # slots track batch_size and the table spans the whole pool
            # unless pinned tighter.  Extra masked gather lanes are exact
            # zeros, so the wider table stays bitwise-equal.
            num_slots = max(1, sc.batch_size)
            num_blocks = sc.num_blocks
            blocks_per = sc.max_blocks_per_slot or (num_blocks - 1)
        else:
            num_slots = max(1, min(sc.batch_size, len(requests)))
            if sc.num_shards > 1:      # equal per-shard slot counts
                num_slots = (-(-num_slots // sc.num_shards) * sc.num_shards)
            blocks_per = (sc.max_blocks_per_slot
                          or blocks_needed(max_span, sc.block_size))
            num_blocks = sc.num_blocks or (1 + num_slots * blocks_per)
        if sc.num_shards > 1 and (num_blocks - 1) % sc.num_shards != 0:
            raise ValueError(
                f"allocatable blocks {num_blocks - 1} not divisible by "
                f"{sc.num_shards} shards (set num_blocks = 1 + "
                f"{sc.num_shards}*k)")
        kv, cache, reused = self._paged_pool(num_slots, num_blocks,
                                             blocks_per, sc)
        evicted0 = kv.evicted_cached   # pool-lifetime counter; report delta
        if sc.num_shards > 1:
            sched: Any = ShardedScheduler(
                kv, registry=self.registry, policy=sc.sched_policy,
                aging_ticks=sc.sched_aging,
                spec_k=sc.spec_k if sc.spec_decode else 0,
                spec_ngram=sc.spec_ngram)
        else:
            sched = Scheduler(kv, policy=sc.sched_policy,
                              aging_ticks=sc.sched_aging,
                              spec_k=sc.spec_k if sc.spec_decode else 0,
                              spec_ngram=sc.spec_ngram)
        for rid, (r, p, b) in enumerate(zip(requests, prompts, budgets)):
            # cached K/V depends on the adapter: scope hits by client AND
            # by the registry's version of its weights (re-registration
            # invalidates without any explicit flush)
            scope = (r.client_id, self.registry.version(r.client_id))
            # explicit request priority wins; else the client's registered
            # default; else the scheduler's baseline class
            priority = (r.priority
                        or self.registry.default_priority(r.client_id)
                        or "batch")
            sched.submit(rid, r.client_id, p, b, scope=scope,
                         priority=priority, deadline=r.deadline)

        bank = self.registry.bank()
        ids = np.zeros((num_slots,), np.int32)
        rng = jax.random.PRNGKey(sc.seed)
        self.last_stats = None       # a partially consumed stream has none
        # Preemption replays prompt+emitted, so prefill chunks must fit the
        # longest possible replayed prompt too — width is fixed per run to
        # keep one compiled prefill program.
        T = max(1, min(sc.prefill_chunk, max_span - 1))
        # verify chunks have their own fixed width (drafted tokens + the
        # feedback token) so the verify program also compiles once per run
        Tv = 1 + sc.spec_k
        # EOS can end a row long before its budget; keep chunks short so its
        # slot frees (and admits the queue head) at the next boundary.
        cap = min(sc.scan_chunk, 8) if sc.eos_id is not None else sc.scan_chunk
        # with a mesh, dispatches trace under it so the "data"-axis sharding
        # constraints in models/layers.py bind the fused batch to devices;
        # without one the constraints no-op (single-device bitwise path)
        mesh_scope = (sc.mesh if sc.mesh is not None
                      else contextlib.nullcontext())
        while sched.has_work:
            for slot, cid in sched.admit():
                ids[slot] = self.registry.acquire(cid)
                cache = reset_slot(cache, slot)
            plan = sched.prepare_chunk(T, cap)
            if plan is None:                 # nothing active: admit failed
                raise RuntimeError("scheduler stalled with queued work")
            bt, lens = kv.device_tables()
            rng, sub = jax.random.split(rng)
            if plan[0] == "prefill":
                arrs = sched.prefill_arrays(T)
                with mesh_scope:
                    sampled, cache = self._prefill_chunk(
                        self.params, bank, jnp.asarray(ids), cache,
                        jnp.asarray(arrs["tokens"]), lens,
                        jnp.asarray(arrs["n_new"]), bt, sub, sc.temperature,
                        backend=sc.paged_backend)
                events = sched.observe_prefill(arrs["n_new"],
                                               np.asarray(sampled),
                                               eos_id=sc.eos_id)
            elif plan[0] == "verify":
                arrs = sched.verify_arrays(Tv)
                with mesh_scope:
                    greedy, cache = self._verify_chunk(
                        self.params, bank, jnp.asarray(ids), cache,
                        jnp.asarray(arrs["tokens"]), lens,
                        jnp.asarray(arrs["n_new"]), bt,
                        backend=sc.paged_backend)
                events = sched.observe_verify(arrs["n_new"],
                                              np.asarray(greedy),
                                              eos_id=sc.eos_id)
            else:
                n = plan[1]
                st = sched.chunk_arrays()
                with mesh_scope:
                    out, cache = self._decode_chunk(
                        self.params, bank, jnp.asarray(ids), cache,
                        jnp.asarray(st["last"]), jnp.asarray(st["active"]),
                        lens, bt, jnp.int32(n), sub, sc.temperature,
                        chunk_cap=cap, backend=sc.paged_backend)
                events = sched.observe_chunk(np.asarray(out)[:n],
                                             eos_id=sc.eos_id)
            yield from events
        classes = {}
        for cname in PRIORITY_CLASSES:
            waits = sched.wait_ticks.get(cname, [])
            if not waits and cname not in sched.preemptions_by_class:
                continue                     # class unused this stream
            classes[cname] = {
                "admitted": len(waits),
                "wait_p50": float(np.percentile(waits, 50)) if waits else 0.0,
                "wait_p99": float(np.percentile(waits, 99)) if waits else 0.0,
                "preemptions": sched.preemptions_by_class.get(cname, 0)}
        self.last_stats = {"prefill_dispatches": sched.prefill_dispatches,
                           "decode_dispatches": sched.decode_dispatches,
                           "decode_steps": sched.steps,
                           "spec_decode": sc.spec_decode,
                           "verify_dispatches": sched.verify_dispatches,
                           "drafted_tokens": sched.drafted_tokens,
                           "accepted_tokens": sched.accepted_tokens,
                           "acceptance_rate": (sched.accepted_tokens
                                               / max(1, sched.drafted_tokens)),
                           "rollback_tokens": sched.rollback_tokens,
                           "rollback_blocks": sched.rollback_blocks,
                           "preemptions": sched.preemptions,
                           "prompt_tokens": sched.prompt_tokens,
                           "prefix_hit_tokens": sched.prefix_hit_tokens,
                           "prefix_hit_rate": (sched.prefix_hit_tokens
                                               / max(1, sched.prompt_tokens)),
                           "prefix_cached_blocks": kv.cached_blocks,
                           "prefix_evictions": kv.evicted_cached - evicted0,
                           "prefix_pool_reused": reused,
                           "sched_policy": sc.sched_policy,
                           "num_shards": sc.num_shards,
                           "kv_dtype": sc.kv_dtype,
                           # queue waits in admission rounds (ticks), by class
                           "classes": classes,
                           "victim_sealed_fraction_mean": (
                               float(np.mean(sched.victim_sealed_fractions))
                               if sched.victim_sealed_fractions else 0.0)}
        if sc.num_shards > 1:
            self.last_stats["shard_placements"] = dict(sched.placed)
        if sc.prefix_cache:
            key = (num_slots, sc.block_size, num_blocks, blocks_per,
                   sc.num_shards, sc.kv_dtype)
            self._warm = (key, kv, cache)

    def generate(self, requests: Sequence[Request],
                 sc: ServeConfig) -> List[np.ndarray]:
        """Continuous batching over ``sc.batch_size`` slots of a paged KV
        cache: ragged prompts, per-request ``max_new_tokens``, per-row EOS.
        Requests beyond the slot count queue and are admitted as slots free
        up (preempted requests resume transparently).

        Returns one 1-D int32 array per request (request order), length <=
        its budget (EOS-terminated rows include the EOS token and stop).
        ``generate_stream`` is the incremental form this collects."""
        outs: List[List[int]] = [[] for _ in requests]
        for rid, toks, _ in self.generate_stream(requests, sc):
            outs[rid].extend(toks)
        return [np.asarray(o, np.int32) for o in outs]

    # -- fixed-shape batch (the PR-1 path, kept for equal-shape workloads) ---
    def generate_fixed(self, requests: Sequence[Request],
                       sc: ServeConfig) -> jnp.ndarray:
        """requests: B same-length prompts (possibly all different clients)
        -> (B, max_new_tokens) int32, row-aligned with ``requests``. Every
        row decodes the full shared ``sc.max_new_tokens`` budget."""
        if not requests:
            raise ValueError("empty request batch")
        ids = jnp.asarray([self.registry.acquire(r.client_id)
                           for r in requests], jnp.int32)
        prompts = jnp.stack([jnp.asarray(r.prompt, jnp.int32)
                             for r in requests])
        return self._run(self.params, self.registry.bank(), ids, prompts, sc)
