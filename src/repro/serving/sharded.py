"""Sharded serving: partitioned block pools, adapter banks and placement.

Scaling past one device's HBM means splitting the serving state, not the
engine: this module partitions the three stateful serving structures across
``num_shards`` shards while keeping ONE fused device dispatch per engine
round (request slots are data-parallel across shards — on a mesh the
``"data"`` axis carries them, see ``models/layers.py::maybe_shard``):

* :class:`ShardedPagedKVCache` — ``num_shards`` independent
  :class:`~repro.serving.kv_cache.PagedKVCache` allocators, each with its
  own free list, block tables, seal chains and prefix index over a disjoint
  slice of one global device block pool.  Shard ``s`` owns global blocks
  ``[1 + s*P, 1 + (s+1)*P)`` (``P`` allocatable blocks per shard); block 0
  stays the one global scratch target.  ``device_tables()`` translates each
  shard's local table into global ids and concatenates, so the jitted
  paged-attention steps are untouched.  ``check_invariants`` holds PER
  SHARD — conservation in a starved shard is independent of a roomy one.

* :class:`ShardedAdapterRegistry` — ``num_shards`` fixed-capacity
  :class:`~repro.serving.registry.AdapterRegistry` banks (``capacity /
  num_shards`` clients each).  A client is *homed* on one shard (fewest
  resident clients at first registration); ``bank()`` concatenates the
  per-shard banks along the client axis so global adapter slots
  (``shard * capacity_per_shard + local``) index it directly.

* :class:`ShardedScheduler` — a placement-aware coordinator over
  ``num_shards`` unmodified :class:`~repro.serving.scheduler.Scheduler`
  instances.  ``submit`` routes each request to the shard already holding
  its longest cached prefix, else its client's adapter home shard, else the
  least-loaded shard; preemption stays WITHIN a shard (each per-shard
  scheduler only ever sees its own slots).  Each engine round the
  coordinator negotiates one global round kind — any shard still
  prefilling forces a prefill round, else any shard with drafts forces a
  verify round, else a decode round of the min step count — and forces it
  through every shard's ``prepare_chunk(kind=..., steps=...)``, then
  concatenates the per-shard host arrays into one fused dispatch and
  slices the observations back.  The coordinator duck-types the single
  ``Scheduler`` interface, so the engine loop drives either unchanged.

Everything here is host bookkeeping: one device program, one block pool
tensor, one adapter bank tensor.  On a multi-device mesh the fused batch
axis is laid out over ``"data"`` (slots are shard-contiguous, so shard
boundaries coincide with device boundaries); on one device the fusion
amortises per-dispatch overhead exactly like PR 1's batched engine.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.serving.kv_cache import PagedKVCache
from repro.serving.registry import AdapterRegistry
from repro.serving.scheduler import Scheduler

Params = Any


class ShardedPagedKVCache:
    """``num_shards`` disjoint :class:`PagedKVCache` partitions of one pool.

    ``num_slots`` and ``num_blocks`` are GLOBAL (``num_blocks`` includes
    the shared scratch block 0); both ``num_slots`` and ``num_blocks - 1``
    must divide evenly into ``num_shards``.  Global slot ``s * slots_per_
    shard + i`` is shard ``s``'s local slot ``i``; global block ``b`` (>0)
    of shard ``s`` is local block ``b - s * blocks_per_shard``.
    """

    def __init__(self, num_shards: int, num_slots: int, block_size: int,
                 num_blocks: int, max_blocks_per_slot: int,
                 prefix_cache: bool = False):
        if num_shards < 1:
            raise ValueError(f"num_shards must be >= 1, got {num_shards}")
        if num_slots % num_shards != 0:
            raise ValueError(
                f"num_slots {num_slots} not divisible by {num_shards} shards")
        if (num_blocks - 1) % num_shards != 0:
            raise ValueError(
                f"allocatable blocks {num_blocks - 1} not divisible by "
                f"{num_shards} shards")
        self.num_shards = num_shards
        self.num_slots = num_slots
        self.slots_per_shard = num_slots // num_shards
        self.block_size = block_size
        self.num_blocks = num_blocks
        self.blocks_per_shard = (num_blocks - 1) // num_shards
        self.max_blocks_per_slot = max_blocks_per_slot
        self.prefix_cache = prefix_cache
        self.shards: List[PagedKVCache] = [
            PagedKVCache(self.slots_per_shard, block_size,
                         1 + self.blocks_per_shard, max_blocks_per_slot,
                         prefix_cache=prefix_cache)
            for _ in range(num_shards)]

    # ---- slot/block translation -------------------------------------------
    def shard_of_slot(self, slot: int) -> Tuple[int, int]:
        """Global slot -> (shard, local slot)."""
        return divmod(slot, self.slots_per_shard)

    def global_slot(self, shard: int, local: int) -> int:
        return shard * self.slots_per_shard + local

    # ---- aggregate capacity -----------------------------------------------
    @property
    def free_blocks(self) -> int:
        return sum(sh.free_blocks for sh in self.shards)

    @property
    def cached_blocks(self) -> int:
        return sum(sh.cached_blocks for sh in self.shards)

    @property
    def allocatable_blocks(self) -> int:
        return sum(sh.allocatable_blocks for sh in self.shards)

    @property
    def evicted_cached(self) -> int:
        return sum(sh.evicted_cached for sh in self.shards)

    @property
    def table_version(self) -> int:
        """Aggregate block-table mutation counter: strictly increases when
        any shard's tables change (per-shard counters are monotonic), so
        the engine's overlap fast path can key its cached device tables on
        it exactly as in the single-pool case."""
        return sum(sh.table_version for sh in self.shards)

    @property
    def lengths(self) -> np.ndarray:
        """Global per-slot context lengths (concatenated snapshot)."""
        return np.concatenate([sh.lengths for sh in self.shards])

    @property
    def idle(self) -> bool:
        return all(sh.idle for sh in self.shards)

    def fits(self, n_tokens: int) -> bool:
        """Shards are geometry-identical: fits on one == fits on any."""
        return self.shards[0].fits(n_tokens)

    # ---- placement probe ---------------------------------------------------
    def best_prefix_shard(self, scope: Any, tokens: Sequence[int]
                          ) -> Tuple[Optional[int], int]:
        """The shard holding the longest cached prefix of ``tokens`` under
        ``scope`` as ``(shard, hit tokens)``; ``(None, 0)`` when no shard
        holds any of it (or prefix caching is off)."""
        best, best_hit = None, 0
        for s, sh in enumerate(self.shards):
            hit = len(sh.match_prefix(scope, tokens)[0]) * self.block_size
            if hit > best_hit:
                best, best_hit = s, hit
        return best, best_hit

    # ---- device view -------------------------------------------------------
    def device_tables(self) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """Global ``(block_tables, lengths)`` over the fused slot axis:
        each shard's local block ids shift into its global slice (block 0
        stays 0 — the shared scratch row)."""
        tables, lengths = [], []
        for s, sh in enumerate(self.shards):
            t = sh.block_tables
            off = s * self.blocks_per_shard
            tables.append(np.where(t > 0, t + off, 0).astype(np.int32))
            lengths.append(sh.lengths)
        return (jnp.asarray(np.concatenate(tables, axis=0)),
                jnp.asarray(np.concatenate(lengths, axis=0)))

    # ---- invariants --------------------------------------------------------
    def check_invariants(self) -> None:
        """Per-shard allocator invariants plus global disjointness: every
        global block id referenced by some shard's table falls inside that
        shard's slice (so no shard can ever gather another's content)."""
        for s, sh in enumerate(self.shards):
            sh.check_invariants()
            lo = 1 + s * self.blocks_per_shard
            hi = lo + self.blocks_per_shard
            t = sh.block_tables
            used = np.where(t > 0, t + s * self.blocks_per_shard, 0)
            bad = used[(used != 0) & ((used < lo) | (used >= hi))]
            assert bad.size == 0, \
                f"shard {s} references blocks outside [{lo}, {hi}): {bad}"


class ShardedAdapterRegistry:
    """``num_shards`` fixed-capacity adapter banks behind one interface.

    A client is homed on one shard at first registration (fewest resident
    clients, lowest index on ties) and stays there until evicted — the
    scheduler uses :meth:`shard_of` to co-locate a client's requests with
    its adapter.  Global adapter slots are ``shard * capacity_per_shard +
    local``; :meth:`bank` concatenates the per-shard banks along the
    client axis so the engine's per-row ``adapter_ids`` index it directly
    (the concatenation is cached and invalidated on register/evict).

    With ``ranks=[...]`` every shard carries the same rank-bucket layout
    and :meth:`bank` concatenates shard banks *per bucket* (the list
    leaves zip through ``jax.tree.map``), so global slots order as
    [bucket0: shard0..shardN, bucket1: shard0..shardN, ...] — see
    :meth:`_global_slot`.
    """

    def __init__(self, cfg, capacity: int, num_shards: int,
                 rank: Optional[int] = None, bank_dtype: str = "f32",
                 ranks: Optional[Sequence[int]] = None):
        if num_shards < 1:
            raise ValueError(f"num_shards must be >= 1, got {num_shards}")
        if capacity % num_shards != 0:
            raise ValueError(
                f"capacity {capacity} not divisible by {num_shards} shards")
        self.capacity = capacity
        self.num_shards = num_shards
        self.capacity_per_shard = capacity // num_shards
        self.bank_dtype = bank_dtype
        self.shards: List[AdapterRegistry] = [
            AdapterRegistry(cfg, self.capacity_per_shard, rank,
                            bank_dtype=bank_dtype, ranks=ranks)
            for _ in range(num_shards)]
        self._home: Dict[Any, int] = {}
        self._versions: Dict[Any, int] = {}  # survives cross-shard moves
        self._bank_cache: Optional[Params] = None

    # ---- bookkeeping ------------------------------------------------------
    def __contains__(self, client_id) -> bool:
        return client_id in self._home

    def __len__(self) -> int:
        return len(self._home)

    @property
    def resident(self) -> List[Any]:
        """Client ids grouped by shard (shard-major, LRU order within)."""
        return [cid for sh in self.shards for cid in sh.resident]

    @property
    def evictions(self) -> int:
        return sum(sh.evictions for sh in self.shards)

    def shard_of(self, client_id) -> Optional[int]:
        """The client's home shard, or None when not resident."""
        return self._home.get(client_id)

    @property
    def ragged(self) -> bool:
        return self.shards[0].ragged

    @property
    def bucket_ranks(self) -> List[int]:
        return self.shards[0].bucket_ranks

    @property
    def bank_epoch(self) -> int:
        """Monotone bank-content counter (sum over shards) — the serving
        session's hot-swap signal, same contract as the single registry."""
        return sum(sh.bank_epoch for sh in self.shards)

    def _global_slot(self, s: int, local_slot: int) -> int:
        """Per-shard slot -> global slot under the per-bucket concat order
        of :meth:`bank`: [bucket0: shard0..shardN, bucket1: ...].  With one
        bucket this reduces to the legacy ``s * capacity_per_shard +
        local``."""
        sub = self.shards[s]
        b, loc = sub.bucket_of_slot(local_slot)
        off = self.num_shards * sum(sub.bucket_sizes[:b])
        return off + s * sub.bucket_sizes[b] + loc

    def slot_ranks(self) -> np.ndarray:
        """(capacity,) int32 effective rank per GLOBAL slot (see
        ``AdapterRegistry.slot_ranks``)."""
        out = np.zeros(self.capacity, np.int32)
        for s, sh in enumerate(self.shards):
            sub = sh.slot_ranks()
            for local in range(sh.capacity):
                out[self._global_slot(s, local)] = sub[local]
        return out

    def _place(self, client_id) -> int:
        if client_id in self._home:
            return self._home[client_id]
        return min(range(self.num_shards),
                   key=lambda s: (len(self.shards[s]), s))

    # ---- writes -----------------------------------------------------------
    def register(self, client_id, adapters: Params,
                 default_priority: Optional[str] = None) -> int:
        """Install on the client's home shard (assigned now if new);
        returns the GLOBAL bank slot.  A full shard evicts its own LRU
        client — eviction pressure stays within the shard."""
        s = self._place(client_id)
        sub = self.shards[s]
        before = set(sub.resident)
        local = sub.register(client_id, adapters,
                             default_priority=default_priority)
        for evicted in before - set(sub.resident) - {client_id}:
            self._home.pop(evicted, None)
        self._home[client_id] = s
        # version lives at THIS level: a client evicted from one shard and
        # re-registered on another must keep climbing (per-shard counters
        # restart, which would resurrect stale prefix-cache scopes)
        self._versions[client_id] = self._versions.get(client_id, 0) + 1
        self._bank_cache = None
        return self._global_slot(s, local)

    def register_dual(self, client_id, personalized: Params, global_: Params,
                      fusion_weights,
                      default_priority: Optional[str] = None) -> int:
        from repro.core.dual_lora import merge
        self.shards[self._place(client_id)]._validate_dual(personalized,
                                                           global_)
        fused = merge(personalized, global_, jnp.asarray(fusion_weights))
        return self.register(client_id, fused,
                             default_priority=default_priority)

    def evict(self, client_id) -> None:
        if client_id not in self._home:
            raise KeyError(f"client {client_id!r} is not resident "
                           f"(resident: {self.resident})")
        s = self._home.pop(client_id)
        self.shards[s].evict(client_id)
        self._bank_cache = None

    # ---- reads ------------------------------------------------------------
    def acquire(self, client_id) -> int:
        s = self._home.get(client_id)
        if s is None:
            raise KeyError(f"client {client_id!r} is not resident "
                           f"(resident: {self.resident})")
        return self._global_slot(s, self.shards[s].acquire(client_id))

    def default_priority(self, client_id) -> Optional[str]:
        s = self._home.get(client_id)
        return None if s is None else self.shards[s].default_priority(client_id)

    def version(self, client_id) -> int:
        """Monotone per-client weight version (prefix-cache scope); raises
        ``KeyError`` for a client that was never registered.  Tracked at
        the sharded level so it survives cross-shard re-registration."""
        if client_id not in self._versions:
            raise KeyError(f"client {client_id!r} was never registered "
                           f"(resident: {self.resident})")
        return self._versions[client_id]

    def bank(self) -> Params:
        """The global stacked adapter tree: per-shard banks concatenated
        along the client axis (leaves (n_periods, capacity, d_in, r));
        ragged banks concatenate per bucket (list leaves zip through
        ``jax.tree.map``), matching :meth:`_global_slot`."""
        if self._bank_cache is None:
            banks = [sh.bank() for sh in self.shards]
            self._bank_cache = jax.tree.map(
                lambda *ls: jnp.concatenate(ls, axis=1), *banks)
        return self._bank_cache


class ShardedScheduler:
    """Placement-aware coordinator over per-shard :class:`Scheduler`\\ s.

    Duck-types the single-pool ``Scheduler`` driving interface (submit /
    admit / prepare_chunk / *_arrays / observe_* / stats counters) over the
    GLOBAL slot axis, so ``MultiTenantEngine.generate_stream`` runs either
    unchanged.  ``registry`` (optional) provides ``shard_of`` for
    adapter-affinity placement — any object without it degrades to
    prefix-affinity + least-loaded placement only.
    """

    def __init__(self, kv: ShardedPagedKVCache, registry: Any = None,
                 policy: str = "sla", aging_ticks: int = 16,
                 victim_policy: Optional[Callable] = None,
                 spec_k: int = 0, spec_ngram: int = 3):
        self.kv = kv
        self.registry = registry
        self.shards: List[Scheduler] = [
            Scheduler(pool, policy=policy, aging_ticks=aging_ticks,
                      victim_policy=victim_policy, spec_k=spec_k,
                      spec_ngram=spec_ngram)
            for pool in kv.shards]
        self.policy = policy
        self.spec_k = spec_k
        self.placements: Dict[int, int] = {}        # rid -> shard
        self.placed = {"prefix": 0, "adapter": 0, "load": 0}

    # ---- placement --------------------------------------------------------
    def _load(self, s: int) -> int:
        sh = self.shards[s]
        return len(sh.active_slots) + len(sh._queue)

    def place(self, client_id, scope: Any, prompt) -> Tuple[int, str]:
        """The shard for a new request and why: ``"prefix"`` (a shard holds
        a cached prefix of the prompt — re-prefill saved is worth more than
        balance), ``"adapter"`` (the client's adapter home shard), or
        ``"load"`` (least active+queued requests, most allocatable blocks
        and lowest index breaking ties)."""
        shard, hit = self.kv.best_prefix_shard(scope, prompt)
        if shard is not None and hit > 0:
            return shard, "prefix"
        shard_of = getattr(self.registry, "shard_of", None)
        if shard_of is not None:
            shard = shard_of(client_id)
            if shard is not None:
                return shard, "adapter"
        shard = min(range(len(self.shards)),
                    key=lambda s: (self._load(s),
                                   -self.kv.shards[s].allocatable_blocks, s))
        return shard, "load"

    # ---- intake -----------------------------------------------------------
    def submit(self, rid: int, client_id: Any, prompt, budget: int,
               scope: Any = None, priority: str = "batch",
               deadline: Optional[float] = None,
               arrival_time: Optional[float] = None) -> int:
        """Place and enqueue; returns the chosen shard."""
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        shard, why = self.place(client_id,
                                client_id if scope is None else scope,
                                prompt)
        self.shards[shard].submit(rid, client_id, prompt, budget,
                                  scope=scope, priority=priority,
                                  deadline=deadline,
                                  arrival_time=arrival_time)
        self.placements[rid] = shard
        self.placed[why] += 1
        return shard

    # ---- state ------------------------------------------------------------
    @property
    def has_work(self) -> bool:
        return any(sh.has_work for sh in self.shards)

    @property
    def active_slots(self) -> List[int]:
        return [self.kv.global_slot(s, slot)
                for s, sh in enumerate(self.shards)
                for slot in sh.active_slots]

    @property
    def prefill_pending(self) -> bool:
        return any(sh.prefill_pending for sh in self.shards)

    @property
    def results(self) -> Dict[int, np.ndarray]:
        merged: Dict[int, np.ndarray] = {}
        for sh in self.shards:
            merged.update(sh.results)
        return merged

    # ---- lifecycle --------------------------------------------------------
    def admit(self) -> List[Tuple[int, Any]]:
        """Per-shard admission; returns GLOBAL (slot, client_id) pairs."""
        admitted = []
        for s, sh in enumerate(self.shards):
            for slot, cid in sh.admit():
                admitted.append((self.kv.global_slot(s, slot), cid))
        return admitted

    def negotiate_round(self, decode_cap: int):
        """One global round kind across shards (a fused dispatch has one
        shape): any shard still prefilling -> prefill (others ride as
        1-token feedback rows); else any shard with speculative drafts ->
        verify (draft-less shards ride as 1-token verify rows); else decode
        for the min over shards' planned step counts (so no slot anywhere
        overshoots its budget).  None when no shard has an active slot."""
        prefs = [p for p in (sh.preferred_round(decode_cap)
                             for sh in self.shards) if p is not None]
        if not prefs:
            return None
        if any(p[0] == "prefill" for p in prefs):
            return ("prefill", None)
        if any(p[0] == "verify" for p in prefs):
            return ("verify", None)
        return ("decode", min(p[1] for p in prefs))

    def prepare_chunk(self, prefill_chunk: int, decode_cap: int):
        """Negotiate the global round and force it through every shard's
        planner (growth + within-shard preemption happen there).  Returns
        the global plan, shaped exactly like ``Scheduler.prepare_chunk``."""
        plan = self.negotiate_round(decode_cap)
        if plan is None:
            return None
        kind, steps = plan
        for sh in self.shards:
            sh.prepare_chunk(prefill_chunk, decode_cap, kind=kind,
                             steps=steps)
        return plan

    # ---- fused host arrays -------------------------------------------------
    def _concat(self, parts: List[Dict[str, np.ndarray]]
                ) -> Dict[str, np.ndarray]:
        return {k: np.concatenate([p[k] for p in parts], axis=0)
                for k in parts[0]}

    def prefill_arrays(self, width: int):
        return self._concat([sh.prefill_arrays(width) for sh in self.shards])

    def verify_arrays(self, width: int):
        return self._concat([sh.verify_arrays(width) for sh in self.shards])

    def chunk_arrays(self):
        return self._concat([sh.chunk_arrays() for sh in self.shards])

    def _rows(self, s: int) -> slice:
        K = self.kv.slots_per_shard
        return slice(s * K, (s + 1) * K)

    def chunk_emits(self, n_new) -> bool:
        """Any shard emitting makes the fused chunk an emitting chunk."""
        return any(sh.chunk_emits(n_new[self._rows(s)])
                   for s, sh in enumerate(self.shards))

    def observe_prefill(self, n_new, sampled, eos_id=None):
        events = []
        for s, sh in enumerate(self.shards):
            r = self._rows(s)
            events.extend(sh.observe_prefill(n_new[r], sampled[r],
                                             eos_id=eos_id))
        return events

    def observe_verify(self, n_new, greedy, eos_id=None):
        events = []
        for s, sh in enumerate(self.shards):
            r = self._rows(s)
            events.extend(sh.observe_verify(n_new[r], greedy[r],
                                            eos_id=eos_id))
        return events

    def observe_chunk(self, sampled, eos_id=None):
        events = []
        for s, sh in enumerate(self.shards):
            events.extend(sh.observe_chunk(sampled[:, self._rows(s)],
                                           eos_id=eos_id))
        return events

    # ---- stats (aggregated to match the single Scheduler's counters) ------
    # Dispatch counters: every shard observes every fused dispatch, so the
    # global count is the max (== each shard's count), not the sum.  Token
    # and preemption counters are per-request work, so they sum.
    @property
    def prefill_dispatches(self) -> int:
        return max(sh.prefill_dispatches for sh in self.shards)

    @property
    def decode_dispatches(self) -> int:
        return max(sh.decode_dispatches for sh in self.shards)

    @property
    def verify_dispatches(self) -> int:
        return max(sh.verify_dispatches for sh in self.shards)

    @property
    def steps(self) -> int:
        return max(sh.steps for sh in self.shards)

    @property
    def ticks(self) -> int:
        return max(sh.ticks for sh in self.shards)

    @property
    def drafted_tokens(self) -> int:
        return sum(sh.drafted_tokens for sh in self.shards)

    @property
    def accepted_tokens(self) -> int:
        return sum(sh.accepted_tokens for sh in self.shards)

    @property
    def rollback_tokens(self) -> int:
        return sum(sh.rollback_tokens for sh in self.shards)

    @property
    def rollback_blocks(self) -> int:
        return sum(sh.rollback_blocks for sh in self.shards)

    @property
    def preemptions(self) -> int:
        return sum(sh.preemptions for sh in self.shards)

    @property
    def prompt_tokens(self) -> int:
        return sum(sh.prompt_tokens for sh in self.shards)

    @property
    def prefix_hit_tokens(self) -> int:
        return sum(sh.prefix_hit_tokens for sh in self.shards)

    @property
    def preemptions_by_class(self) -> Dict[str, int]:
        merged: Dict[str, int] = {}
        for sh in self.shards:
            for k, v in sh.preemptions_by_class.items():
                merged[k] = merged.get(k, 0) + v
        return merged

    @property
    def victim_sealed_fractions(self) -> List[float]:
        return [f for sh in self.shards for f in sh.victim_sealed_fractions]

    @property
    def wait_ticks(self) -> Dict[str, List[int]]:
        merged: Dict[str, List[int]] = {}
        for sh in self.shards:
            for k, v in sh.wait_ticks.items():
                merged.setdefault(k, []).extend(v)
        return merged

    @property
    def wait_wall(self) -> Dict[str, List[float]]:
        merged: Dict[str, List[float]] = {}
        for sh in self.shards:
            for k, v in sh.wait_wall.items():
                merged.setdefault(k, []).extend(v)
        return merged
