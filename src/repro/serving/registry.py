"""Multi-tenant adapter registry: a fixed-capacity stacked bank + LRU.

One serving process holds ONE base-model program and a bank of per-client
fused adapters (FDLoRA stage 3 output — ``FDLoRATrainer.fused_adapters`` /
``core.dual_lora.merge``). The bank mirrors a single adapter tree but every
leaf grows a *client* axis right after the period axis:

    single client:  a: (n_periods, d_in, r)   b: (n_periods, r, d_out)
    bank:           a: (n_periods, C, d_in, r) b: (n_periods, C, r, d_out)

so the period ``lax.scan`` in the model still maps the leading axis and each
block sees ``(C, d_in, r)`` leaves — the per-request gather then happens
inside ``layers.lora_delta`` (jnp oracle) or ``kernels.batched_lora``
(Pallas, gather never materialised in HBM).

Capacity is fixed up front (the bank is a VMEM-budgetable, shape-stable
buffer — no recompiles as tenants come and go); registration beyond capacity
evicts the least-recently-*served* client. Slots are updated functionally
(``leaf.at[:, slot].set``) so a jitted engine never sees a shape change.
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.core.dual_lora import merge
from repro.core.lora import init_adapters
from repro.kernels.quant import quantize_int8

Params = Any


def _is_pair(node) -> bool:
    """An adapter target leaf-dict ({"a", "b"}) in the tree walk."""
    return isinstance(node, dict) and set(node) == {"a", "b"}


class AdapterRegistry:
    """Registers/evicts client adapter trees into a stacked serving bank.

    ``bank_dtype="int8"`` stores the stacked factors quantized: each target
    grows fp32 ``a_scale``/``b_scale`` leaves of shape (n_periods, C) — one
    symmetric scale per (period, client) factor, computed at
    :meth:`register` time.  Registered trees stay fp32 at the API; only the
    resident bank is compressed (4x per factor), which is what bounds the
    HBM cost of multi-tenant residency.  The model's jnp path
    (``layers.lora_delta``) and the batched Pallas kernel both dequantize
    at read time, so a zero slot still serves the frozen base model."""

    def __init__(self, cfg, capacity: int, rank: Optional[int] = None,
                 bank_dtype: str = "f32"):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        if bank_dtype not in ("f32", "int8"):
            raise ValueError(
                f"bank_dtype must be 'f32' or 'int8', got {bank_dtype!r}")
        self.capacity = capacity
        self.bank_dtype = bank_dtype
        self.evictions = 0
        template = jax.eval_shape(
            lambda: init_adapters(jax.random.PRNGKey(0), cfg, rank))
        # kept for validating registered trees before any jax.tree.map can
        # die with an opaque broadcast error deep inside the bank update
        self._template: Params = template
        # zero bank: a zero adapter is a no-op, so unregistered slots serve
        # the frozen base model.
        if bank_dtype == "int8":
            self._bank = self._build_int8_bank(template)
        else:
            self._bank = jax.tree.map(
                lambda l: jnp.zeros(l.shape[:1] + (capacity,) + l.shape[1:],
                                    l.dtype), template)
        self._lru: "OrderedDict[Any, int]" = OrderedDict()  # client -> slot
        self._free: List[int] = list(range(capacity))
        self._versions: Dict[Any, int] = {}  # bumped on every register()
        self._default_priority: Dict[Any, str] = {}  # client -> class name

    def _build_int8_bank(self, node) -> Params:
        """Mirror the template with int8 factor banks plus per-(period,
        client) fp32 scale leaves next to each {"a", "b"} pair."""
        if _is_pair(node):
            out = {k: jnp.zeros(l.shape[:1] + (self.capacity,) + l.shape[1:],
                                jnp.int8) for k, l in node.items()}
            periods = node["a"].shape[0]
            out["a_scale"] = jnp.zeros((periods, self.capacity), jnp.float32)
            out["b_scale"] = jnp.zeros((periods, self.capacity), jnp.float32)
            return out
        return {k: self._build_int8_bank(v) for k, v in node.items()}

    def _set_slot_int8(self, bank, adapters, slot: int) -> Params:
        """Quantize one client's fp32 tree into bank slot ``slot``."""
        if "a_scale" in bank:
            qa, sa = quantize_int8(adapters["a"], axis=(1, 2))  # per period
            qb, sb = quantize_int8(adapters["b"], axis=(1, 2))
            return {"a": bank["a"].at[:, slot].set(qa),
                    "b": bank["b"].at[:, slot].set(qb),
                    "a_scale": bank["a_scale"].at[:, slot].set(sa),
                    "b_scale": bank["b_scale"].at[:, slot].set(sb)}
        return {k: self._set_slot_int8(bank[k], adapters[k], slot)
                for k in bank}

    # ---- bookkeeping ------------------------------------------------------
    def __contains__(self, client_id) -> bool:
        return client_id in self._lru

    def __len__(self) -> int:
        return len(self._lru)

    @property
    def resident(self) -> List[Any]:
        """Client ids, least- to most-recently used."""
        return list(self._lru)

    def _grab_slot(self, client_id) -> int:
        if client_id in self._lru:
            return self._lru[client_id]
        if self._free:
            return self._free.pop(0)
        evicted, slot = self._lru.popitem(last=False)   # LRU out
        # a churned-out tenant is gone: its SLA class must not silently
        # resurrect if it re-registers later without one (and the dict must
        # not grow unboundedly under tenant churn).  ``_versions`` stays —
        # monotonicity is what keeps stale prefix-cache entries unreachable
        # if the client ever comes back.
        self._default_priority.pop(evicted, None)
        self.evictions += 1
        return slot

    def _validate_tree(self, adapters: Params, what: str = "adapters") -> None:
        """Check ``adapters`` against the bank template BEFORE any bank
        update, so a mis-shaped or mis-structured tree fails with the bad
        leaf named instead of an opaque broadcast error inside
        ``jax.tree.map``."""
        t_leaves = jax.tree_util.tree_flatten_with_path(self._template)[0]
        t_def = jax.tree.structure(self._template)
        a_def = jax.tree.structure(adapters)
        if t_def != a_def:
            t_keys = {jax.tree_util.keystr(p) for p, _ in t_leaves}
            a_keys = {jax.tree_util.keystr(p) for p, _ in
                      jax.tree_util.tree_flatten_with_path(adapters)[0]}
            missing = sorted(t_keys - a_keys)
            extra = sorted(a_keys - t_keys)
            detail = "".join(
                ([f"; missing leaves: {missing}"] if missing else [])
                + ([f"; unexpected leaves: {extra}"] if extra else []))
            raise ValueError(
                f"{what} tree structure does not match the adapter bank "
                f"template{detail}")
        a_leaves = jax.tree_util.tree_flatten_with_path(adapters)[0]
        for (path, tmpl), (_, leaf) in zip(t_leaves, a_leaves):
            shape = tuple(jnp.shape(leaf))
            if shape != tuple(tmpl.shape):
                raise ValueError(
                    f"{what} leaf {jax.tree_util.keystr(path)} has shape "
                    f"{shape}; the bank template expects {tuple(tmpl.shape)}")

    # ---- writes -----------------------------------------------------------
    def register(self, client_id, adapters: Params,
                 default_priority: Optional[str] = None) -> int:
        """Install (or refresh) a client's fused adapter tree; returns its
        slot. Evicts the least-recently-used client when full.

        ``default_priority`` (an SLA class name — ``interactive`` |
        ``batch`` | ``background``) becomes the scheduling class for this
        client's requests that don't set one themselves; an explicit
        ``Request.priority`` always wins.  ``None`` keeps any previously
        registered default (a weight refresh shouldn't silently demote a
        tenant's SLA)."""
        self._validate_tree(adapters)
        if default_priority is not None:
            from repro.serving.scheduler import PRIORITY_CLASSES
            if default_priority not in PRIORITY_CLASSES:
                raise ValueError(
                    f"unknown default_priority {default_priority!r} "
                    f"(have {sorted(PRIORITY_CLASSES)})")
            self._default_priority[client_id] = default_priority
        slot = self._grab_slot(client_id)
        if self.bank_dtype == "int8":
            self._bank = self._set_slot_int8(self._bank, adapters, slot)
        else:
            self._bank = jax.tree.map(
                lambda bank, leaf: bank.at[:, slot].set(
                    leaf.astype(bank.dtype)),
                self._bank, adapters)
        self._lru[client_id] = slot
        self._lru.move_to_end(client_id)
        self._versions[client_id] = self._versions.get(client_id, 0) + 1
        return slot

    def register_dual(self, client_id, personalized: Params, global_: Params,
                      fusion_weights,
                      default_priority: Optional[str] = None) -> int:
        """Fuse a dual-LoRA state via Eq. 7 and install the result."""
        self._validate_tree(personalized, what="personalized adapters")
        self._validate_tree(global_, what="global adapters")
        fused = merge(personalized, global_, jnp.asarray(fusion_weights))
        return self.register(client_id, fused,
                             default_priority=default_priority)

    def evict(self, client_id) -> None:
        """Drop a client; its slot returns to the free list (stale weights
        stay in the bank but are unreachable until the slot is reused)."""
        if client_id not in self._lru:
            raise KeyError(f"client {client_id!r} is not resident "
                           f"(resident: {self.resident})")
        slot = self._lru.pop(client_id)
        self._default_priority.pop(client_id, None)
        self._free.append(slot)

    # ---- reads ------------------------------------------------------------
    def acquire(self, client_id) -> int:
        """Slot for a request's client (touches LRU recency)."""
        if client_id not in self._lru:
            raise KeyError(f"client {client_id!r} is not resident "
                           f"(resident: {self.resident})")
        self._lru.move_to_end(client_id)
        return self._lru[client_id]

    def default_priority(self, client_id) -> Optional[str]:
        """The client's registered default scheduling class, or ``None``
        when it never set one (the engine then falls back to ``"batch"``).
        Does not touch LRU recency — reading a default is not serving."""
        return self._default_priority.get(client_id)

    def version(self, client_id) -> int:
        """Monotone per-client weight version, bumped on every
        :meth:`register`.  The serving engine folds it into the
        prefix-cache hash scope so cached K/V computed under old adapter
        weights can never be served after a re-registration (0 for clients
        that were never registered)."""
        return self._versions.get(client_id, 0)

    def bank(self) -> Params:
        """The stacked adapter tree (leaves (n_periods, C, d_in, r); int8
        banks also carry (n_periods, C) fp32 ``a_scale``/``b_scale``)."""
        return self._bank
