"""Multi-tenant adapter registry: a fixed-capacity stacked bank + LRU.

One serving process holds ONE base-model program and a bank of per-client
fused adapters (FDLoRA stage 3 output — ``FDLoRATrainer.fused_adapters`` /
``core.dual_lora.merge``). The bank mirrors a single adapter tree but every
leaf grows a *client* axis right after the period axis:

    single client:  a: (n_periods, d_in, r)   b: (n_periods, r, d_out)
    bank:           a: (n_periods, C, d_in, r) b: (n_periods, C, r, d_out)

so the period ``lax.scan`` in the model still maps the leading axis and each
block sees ``(C, d_in, r)`` leaves — the per-request gather then happens
inside ``layers.lora_delta`` (jnp oracle) or ``kernels.batched_lora``
(Pallas, gather never materialised in HBM).

Heterogeneous ranks (``ranks=[r0 < r1 < ...]``) split the capacity into one
*bucket* per rank: a client registering at rank r lands in the smallest
bucket with rank >= r, zero-padded up to the bucket rank.  Zero-padded rank
columns are arithmetically inert (x@0 accumulates exact zeros), so a padded
client serves bitwise the same tokens as its native-rank dense adapter —
while small-rank clients stop paying max-rank HBM.  ``bank()`` then returns
the same tree *structure* but with a per-bucket LIST of stacked arrays at
each factor leaf (lists are pytrees: the period scan and jit tracing are
unchanged), and ``layers.lora_delta`` / ``kernels.ops`` route rows to their
bucket by global slot id.

Capacity is fixed up front (the bank is a VMEM-budgetable, shape-stable
buffer — no recompiles as tenants come and go); registration beyond capacity
evicts the least-recently-*served* client in the same bucket. Slots are
updated functionally (``leaf.at[:, slot].set``) so a jitted engine never
sees a shape change.  ``bank_epoch`` counts bank content changes so a
long-lived serving session can hot-swap re-registered (online-updated)
adapters without re-snapshotting the bank every step.
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.dual_lora import check_rank_agreement, merge
from repro.core.lora import init_adapters
from repro.kernels.quant import quantize_int8

Params = Any


def _is_pair(node) -> bool:
    """An adapter target leaf-dict ({"a", "b"}) in the tree walk."""
    return isinstance(node, dict) and set(node) == {"a", "b"}


def _zip_banks(banks: Sequence[Params]) -> Params:
    """Zip per-bucket bank trees into ONE tree whose factor leaves are
    per-bucket lists (pair dicts — including int8 4-leaf dicts — get
    ``{"a": [a_b0, a_b1, ...], ...}``).  Lists are valid jax pytrees, so
    the result still scans over the period axis and traces under jit."""
    first = banks[0]
    if all(isinstance(v, dict) for v in first.values()):
        return {k: _zip_banks([bk[k] for bk in banks]) for k in first}
    return {k: [bk[k] for bk in banks] for k in first}


class AdapterRegistry:
    """Registers/evicts client adapter trees into a stacked serving bank.

    ``ranks=[r0, r1, ...]`` enables ragged-rank mode: the capacity splits
    into one bucket per rank (larger buckets listed last; sizes as equal as
    integer division allows) and each client lands in the smallest bucket
    whose rank covers its native rank, zero-padded up to the bucket rank.
    Without ``ranks`` the registry is the classic single-bucket bank at
    ``rank or cfg.lora_rank`` and ``bank()`` returns plain stacked arrays.

    ``bank_dtype="int8"`` stores the stacked factors quantized: each target
    grows fp32 ``a_scale``/``b_scale`` leaves of shape (n_periods, C) — one
    symmetric scale per (period, client) factor, computed at
    :meth:`register` time.  Registered trees stay fp32 at the API; only the
    resident bank is compressed (4x per factor), which is what bounds the
    HBM cost of multi-tenant residency.  The model's jnp path
    (``layers.lora_delta``) and the batched Pallas kernel both dequantize
    at read time, so a zero slot still serves the frozen base model."""

    def __init__(self, cfg, capacity: int, rank: Optional[int] = None,
                 bank_dtype: str = "f32",
                 ranks: Optional[Sequence[int]] = None):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        if bank_dtype not in ("f32", "int8"):
            raise ValueError(
                f"bank_dtype must be 'f32' or 'int8', got {bank_dtype!r}")
        if ranks is not None:
            if rank is not None:
                raise ValueError("pass either rank= or ranks=, not both")
            ranks = sorted({int(r) for r in ranks})
            if not ranks or ranks[0] < 1:
                raise ValueError(f"ranks must be positive ints, got {ranks!r}")
            if capacity < len(ranks):
                raise ValueError(
                    f"capacity {capacity} cannot host {len(ranks)} rank "
                    f"buckets (need >= 1 slot per bucket)")
        self.capacity = capacity
        self.bank_dtype = bank_dtype
        self.ragged = ranks is not None
        self.evictions = 0
        self.bank_epoch = 0  # bumped on every bank *content* change
        self._cfg = cfg
        self._rank_templates: Dict[int, Params] = {}
        if self.ragged:
            nb = len(ranks)
            base, rem = divmod(capacity, nb)
            self.bucket_ranks: List[int] = list(ranks)
            self.bucket_sizes: List[int] = [base + (1 if i < rem else 0)
                                            for i in range(nb)]
        else:
            template = jax.eval_shape(
                lambda: init_adapters(jax.random.PRNGKey(0), cfg, rank))
            r0 = self._infer_rank(template, what="bank template")
            self._rank_templates[r0] = template
            self.bucket_ranks = [r0]
            self.bucket_sizes = [capacity]
        offs, acc = [], 0
        for sz in self.bucket_sizes:
            offs.append(acc)
            acc += sz
        self.bucket_offsets: List[int] = offs
        # kept for validating registered trees before any jax.tree.map can
        # die with an opaque broadcast error deep inside the bank update
        self._template: Params = self._rank_template(self.bucket_ranks[-1])
        # zero banks: a zero adapter is a no-op, so unregistered slots serve
        # the frozen base model.
        self._banks: List[Params] = [
            self._zero_bank(self._rank_template(rb), sz)
            for rb, sz in zip(self.bucket_ranks, self.bucket_sizes)]
        self._bank_cache: Optional[Params] = None
        self._lru: "OrderedDict[Any, int]" = OrderedDict()  # client -> slot
        self._free: List[List[int]] = [list(range(sz))
                                       for sz in self.bucket_sizes]
        self._versions: Dict[Any, int] = {}  # bumped on every register()
        self._client_rank: Dict[Any, int] = {}  # native (pre-pad) rank
        self._default_priority: Dict[Any, str] = {}  # client -> class name

    def _rank_template(self, rank: int) -> Params:
        t = self._rank_templates.get(rank)
        if t is None:
            t = jax.eval_shape(
                lambda: init_adapters(jax.random.PRNGKey(0), self._cfg, rank))
            self._rank_templates[rank] = t
        return t

    def _zero_bank(self, template: Params, cap: int) -> Params:
        if self.bank_dtype == "int8":
            return self._build_int8_bank(template, cap)
        return jax.tree.map(
            lambda l: jnp.zeros(l.shape[:1] + (cap,) + l.shape[1:], l.dtype),
            template)

    def _build_int8_bank(self, node, cap: int) -> Params:
        """Mirror the template with int8 factor banks plus per-(period,
        client) fp32 scale leaves next to each {"a", "b"} pair."""
        if _is_pair(node):
            out = {k: jnp.zeros(l.shape[:1] + (cap,) + l.shape[1:],
                                jnp.int8) for k, l in node.items()}
            periods = node["a"].shape[0]
            out["a_scale"] = jnp.zeros((periods, cap), jnp.float32)
            out["b_scale"] = jnp.zeros((periods, cap), jnp.float32)
            return out
        return {k: self._build_int8_bank(v, cap) for k, v in node.items()}

    def _set_slot_int8(self, bank, adapters, slot: int) -> Params:
        """Quantize one client's fp32 tree into bank slot ``slot``."""
        if "a_scale" in bank:
            qa, sa = quantize_int8(adapters["a"], axis=(1, 2))  # per period
            qb, sb = quantize_int8(adapters["b"], axis=(1, 2))
            return {"a": bank["a"].at[:, slot].set(qa),
                    "b": bank["b"].at[:, slot].set(qb),
                    "a_scale": bank["a_scale"].at[:, slot].set(sa),
                    "b_scale": bank["b_scale"].at[:, slot].set(sb)}
        return {k: self._set_slot_int8(bank[k], adapters[k], slot)
                for k in bank}

    # ---- bookkeeping ------------------------------------------------------
    def __contains__(self, client_id) -> bool:
        return client_id in self._lru

    def __len__(self) -> int:
        return len(self._lru)

    @property
    def resident(self) -> List[Any]:
        """Client ids, least- to most-recently used."""
        return list(self._lru)

    def bucket_of_slot(self, slot: int) -> Tuple[int, int]:
        """Global slot id -> (bucket index, local slot within the bucket)."""
        if not 0 <= slot < self.capacity:
            raise ValueError(f"slot {slot} out of range [0, {self.capacity})")
        for b in reversed(range(len(self.bucket_offsets))):
            if slot >= self.bucket_offsets[b]:
                return b, slot - self.bucket_offsets[b]
        raise AssertionError("unreachable")

    def slot_ranks(self) -> np.ndarray:
        """(capacity,) int32: the *native* registered rank per slot
        (bucket rank for free slots) — the effective-rank vector the
        batched kernel masks padded rank columns with."""
        out = np.zeros(self.capacity, np.int32)
        for b, (rb, sz) in enumerate(zip(self.bucket_ranks,
                                         self.bucket_sizes)):
            off = self.bucket_offsets[b]
            out[off:off + sz] = rb
        for cid, slot in self._lru.items():
            out[slot] = self._client_rank.get(cid, out[slot])
        return out

    def _bucket_for(self, rank: int) -> int:
        """Smallest bucket whose rank covers ``rank``."""
        for b, rb in enumerate(self.bucket_ranks):
            if rank <= rb:
                return b
        raise ValueError(
            f"adapter rank {rank} exceeds the largest rank bucket "
            f"(buckets: {self.bucket_ranks})")

    def _infer_rank(self, adapters: Params, what: str = "adapters") -> int:
        """The single LoRA rank of a client tree; rejects mixed ranks
        *within* one tree (per-client rank is one number — heterogeneity
        is across clients) naming the offending leaves."""
        found: Dict[int, str] = {}

        def walk(node, path):
            if _is_pair(node):
                found.setdefault(int(node["a"].shape[-1]), path or "<root>")
            elif isinstance(node, dict):
                for k, v in node.items():
                    walk(v, f"{path}[{k!r}]")
        walk(adapters, "")
        if not found:
            raise ValueError(f"{what} tree has no {{'a', 'b'}} adapter pairs")
        if len(found) > 1:
            detail = ", ".join(f"rank {r} at {p}"
                               for r, p in sorted(found.items()))
            raise ValueError(
                f"{what} tree mixes LoRA ranks within one client: {detail}")
        return next(iter(found))

    def _pad_rank(self, adapters: Params, r_to: int) -> Params:
        """Zero-pad every factor pair's rank axis up to the bucket rank
        (a-last / b-second-to-last); zero columns are arithmetically inert
        so the padded client serves bitwise its native-rank output."""
        def pad(node):
            if _is_pair(node):
                a, b = node["a"], node["b"]
                dr = r_to - a.shape[-1]
                if dr == 0:
                    return {"a": a, "b": b}
                return {"a": jnp.pad(a, [(0, 0)] * (a.ndim - 1) + [(0, dr)]),
                        "b": jnp.pad(b, [(0, 0)] * (b.ndim - 2)
                                     + [(0, dr), (0, 0)])}
            return {k: pad(v) for k, v in node.items()}
        return pad(adapters)

    def _grab_slot(self, client_id, bucket: int) -> int:
        if client_id in self._lru:
            slot = self._lru[client_id]
            b_cur, local = self.bucket_of_slot(slot)
            if b_cur == bucket:
                return slot
            # the client's rank moved buckets: release the old slot back to
            # its bucket's free list (a rank change is not an eviction)
            self._lru.pop(client_id)
            self._free[b_cur].append(local)
        if self._free[bucket]:
            return self.bucket_offsets[bucket] + self._free[bucket].pop(0)
        # evict the least-recently-used client resident in THIS bucket
        for evicted, slot in self._lru.items():      # LRU -> MRU order
            if self.bucket_of_slot(slot)[0] != bucket:
                continue
            self._lru.pop(evicted)
            # a churned-out tenant is gone: its SLA class must not silently
            # resurrect if it re-registers later without one (and the dict
            # must not grow unboundedly under tenant churn).  ``_versions``
            # stays — monotonicity is what keeps stale prefix-cache entries
            # unreachable if the client ever comes back.
            self._default_priority.pop(evicted, None)
            self._client_rank.pop(evicted, None)
            self.evictions += 1
            return slot
        raise AssertionError("bucket has neither free nor resident slots")

    def _validate_tree(self, adapters: Params, what: str = "adapters",
                       template: Optional[Params] = None) -> None:
        """Check ``adapters`` against the bank template BEFORE any bank
        update, so a mis-shaped or mis-structured tree fails with the bad
        leaf named instead of an opaque broadcast error inside
        ``jax.tree.map``."""
        template = self._template if template is None else template
        t_leaves = jax.tree_util.tree_flatten_with_path(template)[0]
        t_def = jax.tree.structure(template)
        a_def = jax.tree.structure(adapters)
        if t_def != a_def:
            t_keys = {jax.tree_util.keystr(p) for p, _ in t_leaves}
            a_keys = {jax.tree_util.keystr(p) for p, _ in
                      jax.tree_util.tree_flatten_with_path(adapters)[0]}
            missing = sorted(t_keys - a_keys)
            extra = sorted(a_keys - t_keys)
            detail = "".join(
                ([f"; missing leaves: {missing}"] if missing else [])
                + ([f"; unexpected leaves: {extra}"] if extra else []))
            raise ValueError(
                f"{what} tree structure does not match the adapter bank "
                f"template{detail}")
        a_leaves = jax.tree_util.tree_flatten_with_path(adapters)[0]
        for (path, tmpl), (_, leaf) in zip(t_leaves, a_leaves):
            shape = tuple(jnp.shape(leaf))
            if shape != tuple(tmpl.shape):
                raise ValueError(
                    f"{what} leaf {jax.tree_util.keystr(path)} has shape "
                    f"{shape}; the bank template expects {tuple(tmpl.shape)}")

    def _check_in(self, adapters: Params,
                  what: str = "adapters") -> Tuple[int, int]:
        """Validate an incoming tree and pick its bucket -> (rank, bucket)."""
        if self.ragged:
            rank = self._infer_rank(adapters, what=what)
            self._validate_tree(adapters, what=what,
                                template=self._rank_template(rank))
            return rank, self._bucket_for(rank)
        self._validate_tree(adapters, what=what)
        return self.bucket_ranks[0], 0

    # ---- writes -----------------------------------------------------------
    def register(self, client_id, adapters: Params,
                 default_priority: Optional[str] = None) -> int:
        """Install (or refresh) a client's fused adapter tree; returns its
        slot. Evicts the least-recently-used client (same rank bucket, in
        ragged mode) when full.

        ``default_priority`` (an SLA class name — ``interactive`` |
        ``batch`` | ``background``) becomes the scheduling class for this
        client's requests that don't set one themselves; an explicit
        ``Request.priority`` always wins.  ``None`` keeps any previously
        registered default (a weight refresh shouldn't silently demote a
        tenant's SLA)."""
        rank, bucket = self._check_in(adapters)
        if default_priority is not None:
            from repro.serving.scheduler import PRIORITY_CLASSES
            if default_priority not in PRIORITY_CLASSES:
                raise ValueError(
                    f"unknown default_priority {default_priority!r} "
                    f"(have {sorted(PRIORITY_CLASSES)})")
            self._default_priority[client_id] = default_priority
        slot = self._grab_slot(client_id, bucket)
        _, local = self.bucket_of_slot(slot)
        if rank != self.bucket_ranks[bucket]:
            adapters = self._pad_rank(adapters, self.bucket_ranks[bucket])
        if self.bank_dtype == "int8":
            self._banks[bucket] = self._set_slot_int8(self._banks[bucket],
                                                      adapters, local)
        else:
            self._banks[bucket] = jax.tree.map(
                lambda bank, leaf: bank.at[:, local].set(
                    leaf.astype(bank.dtype)),
                self._banks[bucket], adapters)
        self._lru[client_id] = slot
        self._lru.move_to_end(client_id)
        self._versions[client_id] = self._versions.get(client_id, 0) + 1
        self._client_rank[client_id] = rank
        self.bank_epoch += 1
        self._bank_cache = None
        return slot

    def _validate_dual(self, personalized: Params, global_: Params) -> None:
        """Pre-merge checks for :meth:`register_dual`: per-target rank
        agreement (naming the offending leaf) plus both trees against the
        bank template — BEFORE ``merge`` can silently broadcast mismatched
        ranks into garbage."""
        check_rank_agreement(personalized, global_)
        rank, _ = self._check_in(personalized, what="personalized adapters")
        if self.ragged:
            self._validate_tree(global_, what="global adapters",
                                template=self._rank_template(rank))
        else:
            self._validate_tree(global_, what="global adapters")

    def register_dual(self, client_id, personalized: Params, global_: Params,
                      fusion_weights,
                      default_priority: Optional[str] = None) -> int:
        """Fuse a dual-LoRA state via Eq. 7 and install the result."""
        self._validate_dual(personalized, global_)
        fused = merge(personalized, global_, jnp.asarray(fusion_weights))
        return self.register(client_id, fused,
                             default_priority=default_priority)

    def evict(self, client_id) -> None:
        """Drop a client; its slot returns to its bucket's free list (stale
        weights stay in the bank but are unreachable until the slot is
        reused)."""
        if client_id not in self._lru:
            raise KeyError(f"client {client_id!r} is not resident "
                           f"(resident: {self.resident})")
        slot = self._lru.pop(client_id)
        bucket, local = self.bucket_of_slot(slot)
        self._default_priority.pop(client_id, None)
        self._client_rank.pop(client_id, None)
        self._free[bucket].append(local)

    # ---- reads ------------------------------------------------------------
    def acquire(self, client_id) -> int:
        """Slot for a request's client (touches LRU recency)."""
        if client_id not in self._lru:
            raise KeyError(f"client {client_id!r} is not resident "
                           f"(resident: {self.resident})")
        self._lru.move_to_end(client_id)
        return self._lru[client_id]

    def default_priority(self, client_id) -> Optional[str]:
        """The client's registered default scheduling class, or ``None``
        when it never set one (the engine then falls back to ``"batch"``).
        Does not touch LRU recency — reading a default is not serving."""
        return self._default_priority.get(client_id)

    def version(self, client_id) -> int:
        """Monotone per-client weight version, bumped on every
        :meth:`register`.  The serving engine folds it into the
        prefix-cache hash scope so cached K/V computed under old adapter
        weights can never be served after a re-registration.  Raises
        ``KeyError`` for a client that was NEVER registered (evicted
        clients keep their last version — monotonicity is what keeps their
        stale cache entries unreachable on return)."""
        if client_id not in self._versions:
            raise KeyError(f"client {client_id!r} was never registered "
                           f"(resident: {self.resident})")
        return self._versions[client_id]

    def bank(self) -> Params:
        """The stacked adapter tree (leaves (n_periods, C, d_in, r); int8
        banks also carry (n_periods, C) fp32 ``a_scale``/``b_scale``).
        With multiple rank buckets each factor leaf becomes a per-bucket
        LIST of stacked arrays, in global-slot order."""
        if len(self._banks) == 1:
            return self._banks[0]
        if self._bank_cache is None:
            self._bank_cache = _zip_banks(self._banks)
        return self._bank_cache
