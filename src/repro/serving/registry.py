"""Multi-tenant adapter registry: a fixed-capacity stacked bank + LRU.

One serving process holds ONE base-model program and a bank of per-client
fused adapters (FDLoRA stage 3 output — ``FDLoRATrainer.fused_adapters`` /
``core.dual_lora.merge``). The bank mirrors a single adapter tree but every
leaf grows a *client* axis right after the period axis:

    single client:  a: (n_periods, d_in, r)   b: (n_periods, r, d_out)
    bank:           a: (n_periods, C, d_in, r) b: (n_periods, C, r, d_out)

so the period ``lax.scan`` in the model still maps the leading axis and each
block sees ``(C, d_in, r)`` leaves — the per-request gather then happens
inside ``layers.lora_delta`` (jnp oracle) or ``kernels.batched_lora``
(Pallas, gather never materialised in HBM).

Capacity is fixed up front (the bank is a VMEM-budgetable, shape-stable
buffer — no recompiles as tenants come and go); registration beyond capacity
evicts the least-recently-*served* client. Slots are updated functionally
(``leaf.at[:, slot].set``) so a jitted engine never sees a shape change.
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.core.dual_lora import merge
from repro.core.lora import init_adapters

Params = Any


class AdapterRegistry:
    """Registers/evicts client adapter trees into a stacked serving bank."""

    def __init__(self, cfg, capacity: int, rank: Optional[int] = None):
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.capacity = capacity
        self.evictions = 0
        template = jax.eval_shape(
            lambda: init_adapters(jax.random.PRNGKey(0), cfg, rank))
        # zero bank: a zero adapter is a no-op, so unregistered slots serve
        # the frozen base model.
        self._bank: Params = jax.tree.map(
            lambda l: jnp.zeros(l.shape[:1] + (capacity,) + l.shape[1:],
                                l.dtype), template)
        self._lru: "OrderedDict[Any, int]" = OrderedDict()  # client -> slot
        self._free: List[int] = list(range(capacity))
        self._versions: Dict[Any, int] = {}  # bumped on every register()
        self._default_priority: Dict[Any, str] = {}  # client -> class name

    # ---- bookkeeping ------------------------------------------------------
    def __contains__(self, client_id) -> bool:
        return client_id in self._lru

    def __len__(self) -> int:
        return len(self._lru)

    @property
    def resident(self) -> List[Any]:
        """Client ids, least- to most-recently used."""
        return list(self._lru)

    def _grab_slot(self, client_id) -> int:
        if client_id in self._lru:
            return self._lru[client_id]
        if self._free:
            return self._free.pop(0)
        evicted, slot = self._lru.popitem(last=False)   # LRU out
        self.evictions += 1
        return slot

    # ---- writes -----------------------------------------------------------
    def register(self, client_id, adapters: Params,
                 default_priority: Optional[str] = None) -> int:
        """Install (or refresh) a client's fused adapter tree; returns its
        slot. Evicts the least-recently-used client when full.

        ``default_priority`` (an SLA class name — ``interactive`` |
        ``batch`` | ``background``) becomes the scheduling class for this
        client's requests that don't set one themselves; an explicit
        ``Request.priority`` always wins.  ``None`` keeps any previously
        registered default (a weight refresh shouldn't silently demote a
        tenant's SLA)."""
        if default_priority is not None:
            from repro.serving.scheduler import PRIORITY_CLASSES
            if default_priority not in PRIORITY_CLASSES:
                raise ValueError(
                    f"unknown default_priority {default_priority!r} "
                    f"(have {sorted(PRIORITY_CLASSES)})")
            self._default_priority[client_id] = default_priority
        slot = self._grab_slot(client_id)
        self._bank = jax.tree.map(
            lambda bank, leaf: bank.at[:, slot].set(leaf.astype(bank.dtype)),
            self._bank, adapters)
        self._lru[client_id] = slot
        self._lru.move_to_end(client_id)
        self._versions[client_id] = self._versions.get(client_id, 0) + 1
        return slot

    def register_dual(self, client_id, personalized: Params, global_: Params,
                      fusion_weights,
                      default_priority: Optional[str] = None) -> int:
        """Fuse a dual-LoRA state via Eq. 7 and install the result."""
        fused = merge(personalized, global_, jnp.asarray(fusion_weights))
        return self.register(client_id, fused,
                             default_priority=default_priority)

    def evict(self, client_id) -> None:
        """Drop a client; its slot returns to the free list (stale weights
        stay in the bank but are unreachable until the slot is reused)."""
        slot = self._lru.pop(client_id)
        self._default_priority.pop(client_id, None)
        self._free.append(slot)

    # ---- reads ------------------------------------------------------------
    def acquire(self, client_id) -> int:
        """Slot for a request's client (touches LRU recency)."""
        if client_id not in self._lru:
            raise KeyError(f"client {client_id!r} is not resident "
                           f"(resident: {self.resident})")
        self._lru.move_to_end(client_id)
        return self._lru[client_id]

    def default_priority(self, client_id) -> Optional[str]:
        """The client's registered default scheduling class, or ``None``
        when it never set one (the engine then falls back to ``"batch"``).
        Does not touch LRU recency — reading a default is not serving."""
        return self._default_priority.get(client_id)

    def version(self, client_id) -> int:
        """Monotone per-client weight version, bumped on every
        :meth:`register`.  The serving engine folds it into the
        prefix-cache hash scope so cached K/V computed under old adapter
        weights can never be served after a re-registration (0 for clients
        that were never registered)."""
        return self._versions.get(client_id, 0)

    def bank(self) -> Params:
        """The stacked adapter tree (leaves (n_periods, C, d_in, r))."""
        return self._bank
