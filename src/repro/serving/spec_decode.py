"""Self-drafting for speculative decoding: prompt-lookup n-gram proposals.

Decode is one token per model evaluation — the serving throughput ceiling
once prefill is chunked and prefix-cached.  Speculative decoding breaks it
by *guessing* the next K tokens cheaply and verifying all of them with ONE
model evaluation: the chunked paged prefill path already scores a (K+1)-
token chunk causally against the pool, and PR 3 established that chunk
logits are bitwise-equal to feeding the same tokens one decode step at a
time.  So greedy acceptance (keep the longest run where every drafted
token equals the model's own greedy choice at the previous position)
yields a token stream bitwise-identical to non-speculative greedy
decoding — the draft only changes *when* tokens are computed, never
*which*.

The drafter here is the cheapest one that works on serving traffic:
**prompt lookup** (as in assisted generation / vLLM's ngram speculator).
No second model — the proposal is copied from the request's own history:
find the most recent earlier occurrence of the history's trailing n-gram
and propose the tokens that followed it.  Repetitive output (templated
logs, code, per-client boilerplate — the FDLoRA serving regime) gives
long matches and high acceptance; adversarial output just degrades to
zero-length drafts, which cost nothing (the slot rides the verify
dispatch as a plain 1-token feedback row).
"""
from __future__ import annotations

from typing import List, Sequence


def propose_draft(history: Sequence[int], k: int, max_ngram: int = 3,
                  min_ngram: int = 1) -> List[int]:
    """Propose up to ``k`` continuation tokens for ``history`` by prompt
    lookup: for the longest ``n`` in ``[min_ngram, max_ngram]`` whose
    trailing n-gram reoccurs earlier in ``history``, copy the tokens that
    followed the MOST RECENT earlier occurrence with a FULL ``k``-token
    continuation (falling back to the most recent occurrence outright when
    none has one).  Returns ``[]`` when no n-gram matches (the caller
    falls back to plain decode) — never a guess, so a non-repetitive
    stream costs nothing extra.

    Recency mirrors the current context best for templated text, but
    recency ALONE is a trap: in a constant or periodic run the most
    recent occurrence sits flush against the tail, leaving a 1-token
    continuation — exactly the stream that should draft ``k`` every
    round.  Requiring a full continuation first makes the drafter step
    back one period and copy a whole window.

    The proposal may still be shorter than ``k`` when every match sits
    near the end of the history (fewer than ``k`` tokens follow it)."""
    h = [int(t) for t in history]
    n_hist = len(h)
    if k <= 0 or n_hist < min_ngram + 1:
        return []
    for n in range(min(max_ngram, n_hist - 1), min_ngram - 1, -1):
        pat = h[n_hist - n:]
        fallback: List[int] = []
        for start in range(n_hist - n - 1, -1, -1):
            if h[start:start + n] == pat:
                cont = h[start + n:start + n + k]
                if len(cont) == k:
                    return cont
                if not fallback:
                    fallback = cont        # most recent partial match
        if fallback:
            return fallback
    return []
