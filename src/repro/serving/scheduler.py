"""Continuous-batching scheduler: admission, growth, preemption, progress.

Sits between a request queue and the paged prefill/decode steps.  Each
serving slot tracks one in-flight request's lifecycle:

    queued -> admitted (slot claimed, zero private blocks, SSM state reset;
              with prefix caching, the prompt's longest cached prefix is
              mapped in refcounted and skipped — ``fed`` starts past it)
           -> prefilling (remaining prompt CHUNKS fed per prefill dispatch)
           -> decoding  (sampled tokens emitted and fed back, chunked)
           -> finished  (budget exhausted or EOS) -> slot + blocks freed
        or -> preempted (blocks released; requeued with prompt+emitted as
              the new prompt, so no work is lost)

Blocks are allocated on demand: :meth:`prepare_chunk` plans the next device
chunk (a prefill chunk while any active slot still has prompt tokens
pending, else a decode chunk) and grows every active slot's block table to
cover exactly the positions that chunk will write — oldest request first.
When the pool runs dry mid-growth a victim is preempted and planning
restarts.

**Scheduling policy** (``policy=``): requests carry a *priority class*
(:data:`PRIORITY_CLASSES`: ``interactive`` < ``batch`` < ``background``)
and an optional deadline.

* ``"sla"`` (default) — admission is a priority queue: candidates order by
  ``(effective class, deadline, arrival)`` where the effective class is
  AGED one level towards ``interactive`` every ``aging_ticks`` admission
  rounds spent queued, so a starved ``background`` request climbs to the
  top class in bounded time and then blocks younger admissions until it
  fits (no starvation).  Preemption victims come from the LOWEST priority
  class among the candidates; inside it the legacy newest-first pick is
  kept unless a candidate is structurally cheaper in the worst case —
  its guaranteed re-prefill cost (context minus the prefix co-owned by
  another live slot, which survives any eviction and re-matches at
  re-admission) undercuts the newest's by at least a block and its
  release covers the pool's shortfall (see :func:`sla_victim`).  The
  progress bound is preserved: the oldest runnable request in the top
  priority class among the active slots is never preempted, so it always
  completes (no livelock) as long as every request's full span fits the
  pool alone (checked at submit).
* ``"fcfs"`` — the legacy behaviour: arrival-order admission (priorities
  ignored) and newest-request-first victims.

A custom victim policy (``victim_policy=``) receives the non-protected
:class:`VictimInfo` candidates and returns the slot to preempt.

The engine drives the loop in chunks:  ``admit()`` between chunks pulls
queued requests into freed slots (the best candidate waits while free
blocks can't cover its prompt — no bypass, which is what makes aging a
starvation bound), ``prepare_chunk()`` plans + grows + preempts,
``prefill_arrays()``/``chunk_arrays()`` snapshot per-slot state for the
device dispatch, and ``observe_prefill()``/``observe_chunk()`` consume the
sampled results, returning ``(rid, new_tokens, finished)`` events the
moment tokens exist — the streaming API yields them before the batch
drains.
"""
from __future__ import annotations

import dataclasses
import math
import time
from collections import deque
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.serving.kv_cache import PagedKVCache, blocks_needed
from repro.serving.spec_decode import propose_draft

# priority classes, most to least urgent (lower level = more urgent)
PRIORITY_CLASSES: Dict[str, int] = {
    "interactive": 0, "batch": 1, "background": 2}
_LEVEL_NAMES = {v: k for k, v in PRIORITY_CLASSES.items()}


@dataclasses.dataclass(frozen=True)
class VictimInfo:
    """One preemption candidate, as seen by a victim policy."""
    slot: int
    rid: int
    seq: int                      # arrival order (stable across preemptions)
    level: int                    # priority class level (0 = interactive)
    emitted: int                  # tokens emitted this incarnation
    context_len: int              # K/V positions written (kv.lengths[slot])
    block_size: int
    sealed_tokens: int            # leading context in SEALED blocks: these
    #                               park content-addressed on release and
    #                               re-match at re-admission (unless pool
    #                               pressure evicts them first)
    sealed_fraction: float        # of owned blocks, sealed/content-indexed
    shared_prefix_tokens: int     # of sealed_tokens, the prefix co-owned by
    #                               another slot — survives release for sure
    releasable_blocks: int        # blocks a release makes allocatable
    #                               (refcount-1; co-owned blocks yield 0)
    prompt_len: int
    fed: int
    deadline: Optional[float] = None  # the request's SLA deadline; None =
    #                               unbounded slack (sorts as +inf: the
    #                               safest victim among deadlined peers)

    @property
    def _cap(self) -> int:
        """Most tokens the replay can possibly re-match: its last full
        block boundary (admission matching leaves at least one token live,
        see ``PagedKVCache.match_prefix``)."""
        replay = self.prompt_len + self.emitted
        return ((replay - 1) // self.block_size) * self.block_size

    @property
    def reprefill_cost(self) -> int:
        """Optimistic re-prefill estimate: context minus the whole sealed
        prefix (assumes parked blocks survive until re-admission — usually
        true under mild pressure).  Always < 2 blocks, so it cannot tell
        victims apart; kept for stats and custom policies."""
        return self.context_len - min(self.sealed_tokens, self._cap)

    @property
    def guaranteed_cost(self) -> int:
        """Pessimistic (worst-case) re-prefill: context minus only the
        prefix CO-OWNED by another active slot — those blocks stay
        referenced through the preemption, immune to eviction, so the
        replay re-matches them no matter how hard the pool thrashes.
        Unlike the optimistic estimate this separates victims structurally:
        ~0 for a request riding a live shared prefix, the full context for
        a unique one."""
        return self.context_len - min(self.shared_prefix_tokens, self._cap)


def sla_victim(cands: List[VictimInfo], short: int = 1) -> int:
    """Default victim policy: prefer the lowest-priority class; inside it,
    keep the legacy newest-first choice (LIFO concentrates preemption
    churn on one young request — empirically hard to beat) UNLESS a
    candidate is structurally cheaper in the WORST case: its guaranteed
    re-prefill cost (counting only blocks co-owned by another live slot,
    which survive any eviction pressure) undercuts the newest's by at
    least a block, and its release alone covers the ``short`` blocks the
    pool is missing (a deviation that still forces a second preemption
    pays twice).  Then take the cheapest such candidate (newest on ties).
    With nothing cached/co-owned no candidate qualifies and this IS
    newest-first.

    Deadlines refine the within-class pick: the LATEST-deadline candidate
    (most slack — a deadline-less request counts as infinite slack) is the
    preferred victim among same-class peers, arrival order breaking exact
    ties as before.  With no deadlines set every candidate has infinite
    slack and the policy reduces to the legacy newest-first behaviour."""
    lvl = max(c.level for c in cands)
    pool = [c for c in cands if c.level == lvl]
    slack = (lambda c: math.inf if c.deadline is None else c.deadline)
    newest = max(pool, key=lambda c: (slack(c), c.seq))
    cheap = [c for c in pool if c.releasable_blocks >= max(1, short)
             and c.guaranteed_cost + c.block_size <= newest.guaranteed_cost]
    if not cheap:
        return newest.slot
    return min(cheap, key=lambda c: (c.guaranteed_cost, -slack(c),
                                     -c.seq)).slot


def newest_victim(cands: List[VictimInfo]) -> int:
    """Legacy victim policy: preempt the newest request."""
    return max(cands, key=lambda c: c.seq).slot


@dataclasses.dataclass
class _ReqMeta:
    level: int
    deadline: Optional[float]     # admission-priority tie-break (EDF); None
    #                               sorts after any deadlined peer in class
    seq: int                      # arrival order, preserved across preempts
    enqueue_tick: int             # (re)entered the queue at this tick
    arrival_time: Optional[float] = None  # open-loop arrival (monotonic
    #                               seconds); set by the session when driven
    #                               by a trace/server — admission then also
    #                               records WALL-CLOCK queue waits


@dataclasses.dataclass
class _SlotState:
    rid: int
    client_id: Any
    prompt: np.ndarray            # (S,) int32 — original prompt + any tokens
    #                               emitted before a preemption (replayed)
    budget: int                   # tokens still to emit this incarnation
    next_token: int               # token the next decode step feeds
    fed: int = 0                  # tokens already fed (prompt + emitted);
    #                               starts PAST a matched cached prefix
    emitted: List[int] = dataclasses.field(default_factory=list)
    prior: List[int] = dataclasses.field(default_factory=list)
    #                               tokens emitted before preemption(s)
    draft: List[int] = dataclasses.field(default_factory=list)
    #                               speculative tokens proposed for the NEXT
    #                               verify dispatch — planning-local state,
    #                               never part of emitted/prompt until a
    #                               verify ACCEPTS them (so a preemption
    #                               between planning and observe can never
    #                               leak drafts into the requeued prompt)


class Scheduler:
    """Priority admission over ``kv.num_slots`` slots; results keyed by rid.

    ``policy``: ``"sla"`` (priority classes + aging + scored victims) or
    ``"fcfs"`` (legacy arrival order + newest-first victims).
    ``aging_ticks``: admission rounds queued per one-class promotion under
    ``"sla"`` (0 disables aging).  ``victim_policy``: optional callable
    ``List[VictimInfo] -> slot`` replacing the default victim scoring
    (candidates already exclude the protected oldest top-class request).
    """

    def __init__(self, kv: PagedKVCache, policy: str = "sla",
                 aging_ticks: int = 16,
                 victim_policy: Optional[
                     Callable[[List[VictimInfo]], int]] = None,
                 spec_k: int = 0, spec_ngram: int = 3):
        if policy not in ("sla", "fcfs"):
            raise ValueError(f"unknown sched policy {policy!r}")
        if spec_k < 0:
            raise ValueError(f"spec_k must be >= 0, got {spec_k}")
        self.kv = kv
        self.policy = policy
        self.aging_ticks = aging_ticks
        self.victim_policy = victim_policy
        # speculative decoding: spec_k > 0 turns decode chunks into
        # draft-then-verify chunks (prompt-lookup drafts of up to spec_k
        # tokens, matched over <= spec_ngram trailing tokens) whenever any
        # decoding slot has a proposal; greedy-only (the engine enforces it)
        self.spec_k = spec_k
        self.spec_ngram = spec_ngram
        # queue entries: (rid, client_id, prompt, budget, prior_emitted)
        self._queue: "deque[Tuple[int, Any, np.ndarray, int, List[int]]]" = \
            deque()
        self._slots: List[Optional[_SlotState]] = [None] * kv.num_slots
        self.results: Dict[int, np.ndarray] = {}
        self._scopes: Dict[int, Any] = {}   # rid -> prefix-cache hash scope
        self._meta: Dict[int, _ReqMeta] = {}  # rid -> priority bookkeeping
        self._seq = 0                       # arrival counter
        self.ticks = 0                      # admission rounds (aging clock)
        self.steps = 0                      # decode steps driven
        self.prefill_dispatches = 0         # prefill chunks dispatched
        self.decode_dispatches = 0          # decode chunks dispatched
        self.verify_dispatches = 0          # draft-verify chunks dispatched
        self.drafted_tokens = 0             # speculative tokens proposed
        self.accepted_tokens = 0            # of those, greedy-accepted
        self.rollback_tokens = 0            # drafted positions rolled back
        self.rollback_blocks = 0            # tail blocks freed by rollback
        self.preemptions = 0
        self.preemptions_by_class: Dict[str, int] = {}
        self.victim_sealed_fractions: List[float] = []
        self.wait_ticks: Dict[str, List[int]] = {}  # class -> per-admission
        #                                     queue waits (incl. re-admits)
        self.wait_wall: Dict[str, List[float]] = {}  # class -> wall-clock
        #                                     queue waits in SECONDS, only
        #                                     for requests submitted with an
        #                                     arrival_time (open-loop); a
        #                                     re-admission after preemption
        #                                     measures from the ORIGINAL
        #                                     arrival (user-visible delay)
        self.prompt_tokens = 0              # prompt tokens admitted (incl.
        #                                     preemption replays)
        self.prefix_hit_tokens = 0          # of those, served from cache

    # ---- intake -----------------------------------------------------------
    def submit(self, rid: int, client_id: Any, prompt, budget: int,
               scope: Any = None, priority: str = "batch",
               deadline: Optional[float] = None,
               arrival_time: Optional[float] = None) -> None:
        """``scope`` isolates the request's prefix-cache hash chain (the
        engine passes ``(client_id, adapter version)`` — cached K/V depends
        on the adapter); ``None`` falls back to ``client_id``.
        ``priority`` names a :data:`PRIORITY_CLASSES` entry; ``deadline``
        (optional, any comparable number — the engine passes it through
        untouched) breaks admission ties earliest-first within a class,
        deadline-less requests sorting last.  ``arrival_time`` (optional,
        ``time.monotonic()`` seconds) marks the request as OPEN-LOOP:
        admission then also records its wall-clock queue wait in
        :attr:`wait_wall` next to the round-based :attr:`wait_ticks`."""
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if prompt.size == 0:
            raise ValueError(f"request {rid}: empty prompt")
        if budget < 1:
            raise ValueError(f"request {rid}: budget must be >= 1")
        if priority not in PRIORITY_CLASSES:
            raise ValueError(f"request {rid}: unknown priority {priority!r} "
                             f"(have {sorted(PRIORITY_CLASSES)})")
        span = int(prompt.size) + budget
        if not self.kv.fits(span):
            raise ValueError(
                f"request {rid}: span {span} exceeds cache capacity "
                f"({self.kv.max_blocks_per_slot} blocks of "
                f"{self.kv.block_size})")
        self._scopes[rid] = client_id if scope is None else scope
        self._meta[rid] = _ReqMeta(PRIORITY_CLASSES[priority], deadline,
                                   self._seq, self.ticks,
                                   arrival_time=arrival_time)
        self._seq += 1
        self._queue.append((rid, client_id, prompt, budget, []))

    # ---- priority ordering -------------------------------------------------
    def effective_level(self, rid: int) -> int:
        """The request's class level after aging: one level more urgent per
        ``aging_ticks`` admission rounds spent queued (clamped at the top
        class).  This is the starvation bound — any request reaches level 0
        within ``level * aging_ticks`` rounds and then admits before every
        younger level-0 request."""
        m = self._meta[rid]
        if self.policy != "sla" or self.aging_ticks <= 0:
            return m.level
        return max(0, m.level - (self.ticks - m.enqueue_tick)
                   // self.aging_ticks)

    def _admit_key(self, rid: int):
        m = self._meta[rid]
        if self.policy == "fcfs":
            return (m.seq,)
        return (self.effective_level(rid),
                m.deadline if m.deadline is not None else math.inf, m.seq)

    # ---- state ------------------------------------------------------------
    @property
    def has_work(self) -> bool:
        return bool(self._queue) or any(s is not None for s in self._slots)

    @property
    def queued(self) -> bool:
        """True while any request waits for admission."""
        return bool(self._queue)

    @property
    def active_slots(self) -> List[int]:
        return [i for i, s in enumerate(self._slots) if s is not None]

    @property
    def prefill_pending(self) -> bool:
        return any(s is not None and s.fed < s.prompt.size
                   for s in self._slots)

    # ---- lifecycle --------------------------------------------------------
    def admit(self) -> List[Tuple[int, Any]]:
        """Fill freed slots from the queue in admission-priority order;
        returns newly admitted ``(slot, client_id)`` pairs (the engine
        resets SSM state and resolves the adapter slot for each).
        Admission claims a slot with zero blocks — the BEST candidate waits
        while the free list can't cover its prompt (no lower-priority
        bypass: combined with aging this is the starvation bound), and
        growth past the prompt relies on preemption.  Each call advances
        the aging clock one tick.

        With prefix caching, admission matches the prompt's longest cached
        prefix under the request's scope and starts ``fed`` past the hit —
        those positions are never re-prefilled (a preempted request
        re-admitted with prompt+emitted re-matches its own sealed blocks)."""
        self.ticks += 1
        admitted = []
        free = [s for s, st in enumerate(self._slots) if st is None]
        while free and self._queue:
            idx = min(range(len(self._queue)),
                      key=lambda i: self._admit_key(self._queue[i][0]))
            rid, cid, prompt, budget, prior = self._queue[idx]
            if not self.kv.can_admit(int(prompt.size)):
                break                        # best candidate waits; no bypass
            del self._queue[idx]
            slot = free.pop(0)
            n_hit = self.kv.admit(slot, scope=self._scopes[rid],
                                  tokens=prompt)
            self._slots[slot] = _SlotState(rid, cid, prompt, budget,
                                           next_token=int(prompt[0]),
                                           fed=n_hit, prior=prior)
            m = self._meta[rid]
            self.wait_ticks.setdefault(_LEVEL_NAMES[m.level], []).append(
                self.ticks - m.enqueue_tick)
            if m.arrival_time is not None:
                self.wait_wall.setdefault(_LEVEL_NAMES[m.level], []).append(
                    time.monotonic() - m.arrival_time)
            self.prompt_tokens += int(prompt.size)
            self.prefix_hit_tokens += n_hit
            admitted.append((slot, cid))
        return admitted

    def preempt(self, slot: int) -> int:
        """Release ``slot``'s blocks and requeue its request at the queue
        head with prompt+emitted as the new prompt (emitted-so-far moves to
        ``prior``), so the resumed incarnation replays its context and
        continues from the exact same state — no work is lost.  The request
        keeps its arrival ``seq`` (it stays ahead of younger peers in its
        class); its aging clock restarts.  Returns the preempted rid."""
        st = self._slots[slot]
        assert st is not None, f"slot {slot} not active"
        m = self._meta[st.rid]
        self.victim_sealed_fractions.append(self.kv.sealed_fraction(slot))
        cname = _LEVEL_NAMES[m.level]
        self.preemptions_by_class[cname] = \
            self.preemptions_by_class.get(cname, 0) + 1
        m.enqueue_tick = self.ticks
        # zero-emitted edge: requeue the original array untouched (an empty
        # concatenand must not copy or silently re-derive the dtype)
        new_prompt = st.prompt if not st.emitted else np.concatenate(
            [st.prompt, np.asarray(st.emitted, np.int32)])
        self._queue.appendleft((st.rid, st.client_id, new_prompt,
                                st.budget - len(st.emitted),
                                st.prior + st.emitted))
        self.kv.release(slot)
        self._slots[slot] = None
        self.preemptions += 1
        return st.rid

    def _finish(self, slot: int) -> None:
        st = self._slots[slot]
        self.results[st.rid] = np.asarray(st.prior + st.emitted, np.int32)
        self.kv.release(slot)
        self._slots[slot] = None

    # ---- chunk planning ----------------------------------------------------
    def plan_steps(self, cap: int) -> int:
        """Decode steps until the EARLIEST active slot completes its budget.
        ``cap`` bounds the chunk (keep small under EOS so early-stopping
        rows don't burn steps until the boundary).  Returns 1 when no slot
        is active (nothing to plan — the engine admits and retries)."""
        remaining = [st.prompt.size - 1 + st.budget - st.fed
                     for st in self._slots if st is not None]
        if not remaining:
            return 1
        return max(1, min(min(remaining), cap))

    def _pick_victim(self, grower: int, short: int = 1) -> int:
        """The slot to preempt when growing ``grower`` found the pool dry
        (``short`` = blocks the pool is missing for the grower's target).

        ``"fcfs"``: the newest active request (legacy).  ``"sla"``: the
        oldest active request of the top priority class present is
        PROTECTED (progress bound — it always completes); the remaining
        candidates go to ``victim_policy`` (default :func:`sla_victim`,
        which also sees ``short``; custom policies get the candidate list
        only).  When the grower is the only candidate it is returned (the
        caller's self-preempt / single-request paths handle it)."""
        active = [(st, s) for s, st in enumerate(self._slots)
                  if st is not None]
        if self.policy == "fcfs":
            return max(active, key=lambda p: self._meta[p[0].rid].seq)[1]
        top = min(self._meta[st.rid].level for st, _ in active)
        protected = min((p for p in active
                         if self._meta[p[0].rid].level == top),
                        key=lambda p: self._meta[p[0].rid].seq)[1]
        cands = [VictimInfo(slot=s, rid=st.rid,
                            seq=self._meta[st.rid].seq,
                            level=self._meta[st.rid].level,
                            emitted=len(st.emitted),
                            context_len=int(self.kv.lengths[s]),
                            block_size=self.kv.block_size,
                            sealed_tokens=self.kv.sealed_tokens(s),
                            sealed_fraction=self.kv.sealed_fraction(s),
                            shared_prefix_tokens=
                            self.kv.shared_prefix_tokens(s),
                            releasable_blocks=self.kv.releasable_blocks(s),
                            prompt_len=int(st.prompt.size), fed=st.fed,
                            deadline=self._meta[st.rid].deadline)
                 for st, s in active if s != protected]
        if not cands:
            return protected             # grower alone; caller raises/replans
        if self.victim_policy is not None:
            return self.victim_policy(cands)
        return sla_victim(cands, short=short)

    def _draft(self, slot: int) -> List[int]:
        """Prompt-lookup proposal for a DECODING slot, capped so the verify
        chunk can neither overshoot the request's budget (at most
        ``remaining - 1`` drafts: the bonus token the verify emits at the
        draft-free position accounts for the rest) nor its table capacity
        (the dispatch transiently writes all drafted positions before
        rollback trims the rejects)."""
        st = self._slots[slot]
        remaining = st.budget - len(st.emitted)
        cap_tokens = self.kv.max_blocks_per_slot * self.kv.block_size
        k = min(self.spec_k, remaining - 1,
                cap_tokens - int(self.kv.lengths[slot]) - 1)
        if k <= 0:
            return []
        history = [int(t) for t in st.prompt] + st.emitted
        return propose_draft(history, k, max_ngram=self.spec_ngram)

    def _decode_cap(self, decode_cap: int) -> int:
        """With spec enabled keep decode chunks short — drafts are
        recomputed only at chunk boundaries, and a full-budget chunk would
        never give the drafter a second look at the (by then repetitive)
        history."""
        return (min(decode_cap, self.spec_k + 1) if self.spec_k > 0
                else decode_cap)

    def preferred_round(self, decode_cap: int):
        """The round this scheduler would plan next, WITHOUT growing any
        block table: ``("prefill", None)``, ``("verify", None)``,
        ``("decode", n_steps)`` or None when no slot is active.  Drafts are
        computed (and stored on the slots) as a side effect, exactly as the
        auto path of :meth:`prepare_chunk` would.

        A multi-shard coordinator calls this on every shard, negotiates one
        global round kind (any prefill wins; else any verify; else decode
        with the min step count), then forces it back through
        :meth:`prepare_chunk(kind=..., steps=...)` so the fused dispatch
        runs one round shape across all shards."""
        if not self.active_slots:
            return None
        if self.prefill_pending:
            return ("prefill", None)
        if self.spec_k > 0:
            verify = False
            for slot in self.active_slots:
                st = self._slots[slot]
                st.draft = self._draft(slot)
                verify = verify or bool(st.draft)
            if verify:
                return ("verify", None)
        return ("decode", self.plan_steps(self._decode_cap(decode_cap)))

    def prepare_chunk(self, prefill_chunk: int, decode_cap: int,
                      kind: Optional[str] = None,
                      steps: Optional[int] = None):
        """Plan the next device chunk under on-demand block growth.

        Grows each active slot (oldest rid first) to cover the positions
        the chunk will write; when the pool runs dry, preempts a victim
        (see :meth:`_pick_victim`) and replans.  Returns
        ``("prefill", None)``, ``("verify", None)`` or
        ``("decode", n_steps)``, or None when no slot is active.

        With ``spec_k > 0`` and no prompt tokens pending, each decoding
        slot gets a prompt-lookup draft; if ANY slot drafted, the chunk is
        a VERIFY chunk — drafting slots feed ``1 + len(draft)`` tokens,
        non-drafting slots ride along as plain 1-token feedback rows (the
        same mixed planning that lets decode ride prefill chunks).  With
        no drafts anywhere the multi-step decode chunk is strictly better
        and is planned as before.  Drafts live only in ``_SlotState.draft``
        until :meth:`observe_verify` accepts them, so a preemption landing
        mid-plan (pool-dry growth below) requeues prompt+emitted ONLY —
        draft tokens never leak into a replayed prompt.

        ``kind`` forces the round shape (multi-shard coordination: every
        shard of a fused dispatch must plan the same kind).  A forced
        ``"prefill"`` on a shard with no prompt pending plans all-feedback
        rows; a forced ``"verify"`` with no local drafts plans 1-token
        rows; a forced ``"decode"`` with ``steps`` runs exactly that many
        steps (the coordinator passes the min over shards, so no slot
        overshoots its budget).  ``kind=None`` (single-pool path) is
        byte-identical to the pre-shard planner."""
        while True:
            active = sorted((st.rid, slot)
                            for slot, st in enumerate(self._slots)
                            if st is not None)
            if not active:
                return None
            prefill = (self.prefill_pending if kind is None
                       else kind == "prefill")
            verify = False
            targets = {}
            if prefill:
                for _, slot in active:
                    st = self._slots[slot]
                    st.draft = []
                    rem = st.prompt.size - st.fed
                    # slots already decoding ride along as 1-token feedback
                    # rows (no decode stall behind another slot's prompt)
                    n = min(prefill_chunk, rem) if rem > 0 else 1
                    targets[slot] = int(self.kv.lengths[slot]) + n
            else:
                if self.spec_k > 0 and kind != "decode":
                    for _, slot in active:
                        st = self._slots[slot]
                        st.draft = self._draft(slot)
                        verify = verify or bool(st.draft)
                verify = verify or kind == "verify"
                if verify:
                    for _, slot in active:
                        st = self._slots[slot]
                        targets[slot] = (int(self.kv.lengths[slot])
                                         + 1 + len(st.draft))
                else:
                    for _, slot in active:
                        self._slots[slot].draft = []
                    n = (steps if steps is not None
                         else self.plan_steps(self._decode_cap(decode_cap)))
                    for _, slot in active:
                        targets[slot] = int(self.kv.lengths[slot]) + n
            preempted = False
            for _, slot in active:           # oldest request claims first
                if self._slots[slot] is None:
                    continue                 # preempted earlier in this pass
                while not self.kv.ensure(slot, targets[slot]):
                    need = (blocks_needed(targets[slot], self.kv.block_size)
                            - self.kv.owned_blocks(slot))
                    victim = self._pick_victim(
                        slot, short=need - self.kv.allocatable_blocks)
                    if victim == slot and len(self.active_slots) == 1:
                        raise RuntimeError(
                            "pool cannot hold a single request's span "
                            "(submit() should have rejected it)")
                    self.preempt(victim)
                    preempted = True
                    if victim == slot:
                        break                # self-preempted; replan
            if not preempted:
                if prefill:
                    return ("prefill", None)
                return ("verify", None) if verify else ("decode", n)

    # ---- prefill chunks ----------------------------------------------------
    def prefill_arrays(self, width: int):
        """Per-slot token chunks for one prefill dispatch: ``tokens``
        (K, width) int32 padded, ``n_new`` (K,) valid counts.  Slots still
        prefilling feed their next prompt chunk; slots already DECODING
        ride along as 1-token feedback rows (``tokens[i, 0] = last
        sample``) so decode never stalls behind another slot's prompt —
        a 1-token prefill row is bitwise-identical to a decode step."""
        K = self.kv.num_slots
        out = {"tokens": np.zeros((K, width), np.int32),
               "n_new": np.zeros((K,), np.int32)}
        for i, st in enumerate(self._slots):
            if st is None:
                continue
            n = min(width, st.prompt.size - st.fed)
            if n > 0:
                out["tokens"][i, :n] = st.prompt[st.fed:st.fed + n]
                out["n_new"][i] = n
            else:                            # decoding: feedback row
                out["tokens"][i, 0] = st.next_token
                out["n_new"][i] = 1
        return out

    def chunk_emits(self, n_new: np.ndarray) -> bool:
        """Whether a prefill chunk planned with these per-slot ``n_new``
        counts will EMIT any token — i.e. whether :meth:`observe_prefill`
        will read the sampled array at all.  True when some slot rides as a
        decoding feedback row or completes its prompt inside the chunk.  A
        pure function of host state, so the engine's overlapped dispatch
        path can decide BEFORE the device finishes whether the next plan
        depends on this chunk's samples (it materialises only when it
        does — the async-overlap sync rule)."""
        for slot, st in enumerate(self._slots):
            if st is None or n_new[slot] == 0:
                continue
            if st.fed >= st.prompt.size:          # decoding feedback row
                return True
            if st.fed + int(n_new[slot]) >= st.prompt.size:
                return True                       # prompt completes: emits
        return False

    def observe_prefill(self, n_new: np.ndarray, sampled: np.ndarray,
                        eos_id: Optional[int] = None
                        ) -> List[Tuple[int, List[int], bool]]:
        """Consume one prefill chunk: ``n_new[slot]`` tokens were written
        for each slot and ``sampled[slot]`` is the sample at the slot's
        last valid position.  A slot whose prompt just completed records
        that sample as its first emission; a slot that rode along as a
        decoding feedback row records it as its next emission.  Returns
        (rid, new_tokens, finished) events."""
        events = []
        for slot, st in enumerate(self._slots):
            if st is None or n_new[slot] == 0:
                continue
            n = int(n_new[slot])
            decoding = st.fed >= st.prompt.size   # feedback row (n == 1)
            written = ([st.next_token] if decoding
                       else [int(t) for t in st.prompt[st.fed:st.fed + n]])
            st.fed += n
            self.kv.advance(slot, n, tokens=written)
            if decoding or st.fed == st.prompt.size:
                tok = int(sampled[slot])
                st.emitted.append(tok)
                st.next_token = tok
                done = (len(st.emitted) >= st.budget
                        or (eos_id is not None and tok == eos_id))
                rid = st.rid
                if done:
                    self._finish(slot)
                events.append((rid, [tok], done))
        self.prefill_dispatches += 1
        return events

    # ---- verify chunks (speculative decoding) ------------------------------
    # A verify chunk is a prefill-shaped dispatch over DECODING slots: each
    # slot feeds its pending feedback token plus its draft, the model scores
    # the whole chunk causally in ONE evaluation (bitwise-equal to feeding
    # the same tokens one decode step at a time — the chunked-prefill
    # property), and the greedy samples at every position come back so
    # observe_verify can accept the longest matching run.

    def verify_arrays(self, width: int):
        """Per-slot token chunks for one verify dispatch: ``tokens``
        (K, width) int32 padded, ``n_new`` (K,) valid counts.  Row ``i``
        feeds ``[next_token, draft...]`` — a draft-less slot is exactly a
        1-token decode feedback row.  ``width`` must cover ``1 + spec_k``
        (fixed per stream so the verify program compiles once)."""
        K = self.kv.num_slots
        out = {"tokens": np.zeros((K, width), np.int32),
               "n_new": np.zeros((K,), np.int32)}
        for i, st in enumerate(self._slots):
            if st is None:
                continue
            assert st.fed >= st.prompt.size, \
                f"slot {i} entered a verify chunk mid-prefill"
            n = 1 + len(st.draft)
            assert n <= width, (n, width)
            out["tokens"][i, 0] = st.next_token
            out["tokens"][i, 1:n] = st.draft
            out["n_new"][i] = n
        return out

    def observe_verify(self, n_new: np.ndarray, greedy: np.ndarray,
                       eos_id: Optional[int] = None
                       ) -> List[Tuple[int, List[int], bool]]:
        """Consume one verify dispatch: ``greedy[slot, t]`` is the model's
        greedy sample after feeding the slot's chunk tokens up to and
        including position ``t``.  Accepts the longest run where each
        drafted token equals the PREVIOUS position's greedy sample (the
        token non-speculative decoding would have fed), emitting one
        greedy token per accepted position plus the bonus sample at the
        last accepted one — bitwise-identical to non-speculative greedy
        decoding.  The K/V written for rejected draft positions is rolled
        back (:meth:`PagedKVCache.rollback`), freeing over-allocated tail
        blocks.  Returns (rid, new_tokens, finished) events."""
        events = []
        for slot, st in enumerate(self._slots):
            if st is None or n_new[slot] == 0:
                continue
            k = int(n_new[slot]) - 1
            draft = st.draft
            assert len(draft) == k, (len(draft), k)
            g = [int(greedy[slot, t]) for t in range(k + 1)]
            a = 0
            while a < k and draft[a] == g[a]:
                a += 1
            # chunk fed [next_token, draft...]: advance the cache through
            # every written position (sealing with the true written ids),
            # then roll back past the first mismatch — rejected positions
            # leave lengths, tables, digests and pending as if never fed
            pre = int(self.kv.lengths[slot])
            self.kv.advance(slot, 1 + k,
                            tokens=[st.next_token] + list(draft))
            self.rollback_blocks += self.kv.rollback(slot, pre + 1 + a)
            st.fed += 1 + a
            st.draft = []
            self.drafted_tokens += k
            self.accepted_tokens += a
            self.rollback_tokens += k - a
            new_toks: List[int] = []
            done = False
            for tok in g[:a + 1]:            # g[i] emits after accepting i
                st.emitted.append(tok)
                new_toks.append(tok)
                if (len(st.emitted) >= st.budget
                        or (eos_id is not None and tok == eos_id)):
                    done = True
                    break
            if done:
                rid = st.rid
                self._finish(slot)
                events.append((rid, new_toks, True))
            else:
                st.next_token = new_toks[-1]
                events.append((st.rid, new_toks, False))
        self.verify_dispatches += 1
        return events

    # ---- decode chunks -----------------------------------------------------
    # One host round-trip per token kills throughput: the engine runs a
    # device-side fori_loop of up to plan_steps() decode steps (each slot
    # feeding its last sampled token) and hands the sampled block back to
    # observe_chunk.  (A per-token driver is just observe_chunk with a
    # (1, num_slots) block.)

    def chunk_arrays(self):
        """Per-slot device state for one decode chunk: last-fed token and
        active mask.  (Prompts are fed by prefill chunks — every active
        slot here resumes from its last sample.)"""
        K = self.kv.num_slots
        out = {"last": np.zeros((K,), np.int32),
               "active": np.zeros((K,), np.int32)}
        for i, st in enumerate(self._slots):
            if st is None:
                continue
            out["last"][i] = st.next_token
            out["active"][i] = 1
        return out

    def observe_chunk(self, sampled: np.ndarray,
                      eos_id: Optional[int] = None
                      ) -> List[Tuple[int, List[int], bool]]:
        """Consume an (n, num_slots) block of decode samples (step-major);
        returns (rid, new_tokens, finished) events.  Decode chunks only run
        once every active slot is past its prompt (prefill chunks fed it
        and recorded the first emission), so step t of slot i fed the
        previous sample and ``sampled[t, i]`` is always an emission."""
        n = sampled.shape[0]
        events = []
        for slot, st in enumerate(self._slots):
            if st is None:
                continue
            assert st.fed >= st.prompt.size, \
                f"slot {slot} entered a decode chunk mid-prefill"
            # step 0 fed (and wrote) next_token; step t>0 fed sampled[t-1]
            written = [st.next_token] + [int(sampled[t, slot])
                                         for t in range(n - 1)]
            new_toks: List[int] = []
            done = False
            for t in range(n):
                tok = int(sampled[t, slot])
                st.emitted.append(tok)
                new_toks.append(tok)
                if (len(st.emitted) >= st.budget
                        or (eos_id is not None and tok == eos_id)):
                    done = True
                    break
            st.fed += n
            self.kv.advance(slot, n, tokens=written)
            if done:
                rid = st.rid
                self._finish(slot)
                events.append((rid, new_toks, True))
            else:
                st.next_token = int(sampled[n - 1, slot])
                events.append((st.rid, new_toks, False))
        self.steps += n
        self.decode_dispatches += 1
        return events

    # ---- deferred observation (overlap pipelining) -------------------------
    def chunk_defer_safe(self, n: int) -> bool:
        """True when the NEXT chunk plan provably does not depend on the
        token VALUES an ``n``-step decode chunk will sample: every active
        slot has strictly more than ``n`` tokens of budget left, so no slot
        finishes inside the chunk (``plan_steps`` stops at the earliest
        boundary, so this is exactly "the chunk was cap-limited") and the
        active set cannot churn.  Only count bookkeeping remains, which
        ``observe_chunk_counts`` advances without the samples — the engine
        combines this with its config gates (no EOS, no speculation, no
        prefix sealing) before deferring materialisation one round."""
        return all(st.prompt.size - 1 + st.budget - st.fed > n
                   for st in self._slots if st is not None)

    def observe_chunk_counts(self, n: int) -> List[int]:
        """Count half of :meth:`observe_chunk`, for a DEFERRED decode
        chunk: advance ``fed``, the pool lengths and the dispatch counters
        — everything the next chunk PLAN reads — while the sampled values
        are still on device.  The caller guarantees ``chunk_defer_safe(n)``
        held at plan time and that prefix sealing is off (``advance`` gets
        no tokens).  Returns the participating slot ids, to be replayed
        through :meth:`observe_chunk_values` once the samples land."""
        slots = []
        for slot, st in enumerate(self._slots):
            if st is None:
                continue
            assert st.fed >= st.prompt.size, \
                f"slot {slot} entered a decode chunk mid-prefill"
            st.fed += n
            self.kv.advance(slot, n)
            slots.append(slot)
        self.steps += n
        self.decode_dispatches += 1
        return slots

    def observe_chunk_values(self, slots: List[int], sampled: np.ndarray
                             ) -> List[Tuple[int, List[int], bool]]:
        """Value half: fold the now-materialised samples of a chunk whose
        counts already advanced into the emitted streams — one engine round
        late.  ``chunk_defer_safe`` ruled out finishes, so every row
        survives and just chains ``next_token`` forward; the token values
        per rid are bitwise what the synchronous path would have emitted,
        only their event round shifts."""
        n = sampled.shape[0]
        events = []
        for slot in slots:
            st = self._slots[slot]
            assert st is not None, \
                f"deferred slot {slot} vanished before its flush"
            toks = [int(sampled[t, slot]) for t in range(n)]
            st.emitted.extend(toks)
            st.next_token = toks[-1]
            events.append((st.rid, toks, False))
        return events
