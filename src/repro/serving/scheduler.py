"""Continuous-batching scheduler: admission, per-slot progress, eviction.

Sits between a request queue and the paged decode step.  Each serving slot
tracks one in-flight request's lifecycle:

    queued -> admitted (blocks reserved, SSM state reset)
           -> prefilling (prompt tokens fed one per engine step; samples
              discarded while ``fed < len(prompt)``)
           -> decoding  (sampled tokens emitted and fed back)
           -> finished  (budget exhausted or EOS) -> slot + blocks freed

The engine drives the loop in chunks:  ``admit()`` between chunks pulls
queued requests into freed slots (FCFS — the head waits if the block pool
can't hold its full span, so admitted requests never deadlock),
``chunk_arrays()`` snapshots per-slot state for up to ``plan_steps()``
device-side decode steps over ALL active slots, and ``observe_chunk()``
consumes the sampled block, returning each request's output the moment it
completes rather than when the batch drains.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.serving.kv_cache import PagedKVCache


@dataclasses.dataclass
class _SlotState:
    rid: int
    client_id: Any
    prompt: np.ndarray            # (S,) int32
    budget: int                   # max tokens to emit
    next_token: int               # token the next step feeds
    fed: int = 0                  # tokens already fed (prompt + emitted)
    emitted: List[int] = dataclasses.field(default_factory=list)


class Scheduler:
    """FCFS admission over ``kv.num_slots`` slots; results keyed by rid."""

    def __init__(self, kv: PagedKVCache):
        self.kv = kv
        self._queue: "deque[Tuple[int, Any, np.ndarray, int]]" = deque()
        self._slots: List[Optional[_SlotState]] = [None] * kv.num_slots
        self.results: Dict[int, np.ndarray] = {}
        self.steps = 0                      # engine steps driven

    # ---- intake -----------------------------------------------------------
    def submit(self, rid: int, client_id: Any, prompt, budget: int) -> None:
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if prompt.size == 0:
            raise ValueError(f"request {rid}: empty prompt")
        if budget < 1:
            raise ValueError(f"request {rid}: budget must be >= 1")
        span = int(prompt.size) + budget
        if not self.kv.fits(span):
            raise ValueError(
                f"request {rid}: span {span} exceeds cache capacity "
                f"({self.kv.max_blocks_per_slot} blocks of "
                f"{self.kv.block_size})")
        self._queue.append((rid, client_id, prompt, budget))

    # ---- state ------------------------------------------------------------
    @property
    def has_work(self) -> bool:
        return bool(self._queue) or any(s is not None for s in self._slots)

    @property
    def active_slots(self) -> List[int]:
        return [i for i, s in enumerate(self._slots) if s is not None]

    # ---- lifecycle --------------------------------------------------------
    def admit(self) -> List[Tuple[int, Any]]:
        """Fill freed slots from the queue head; returns newly admitted
        ``(slot, client_id)`` pairs (the engine resets SSM state and
        resolves the adapter slot for each)."""
        admitted = []
        for slot in range(self.kv.num_slots):
            if self._slots[slot] is not None or not self._queue:
                continue
            rid, cid, prompt, budget = self._queue[0]
            span = int(prompt.size) + budget
            if not self.kv.can_admit(span):
                break                        # FCFS: wait for blocks to free
            self._queue.popleft()
            self.kv.admit(slot, span)
            self._slots[slot] = _SlotState(rid, cid, prompt, budget,
                                           next_token=int(prompt[0]))
            admitted.append((slot, cid))
        return admitted

    # ---- chunked stepping --------------------------------------------------
    # One host round-trip per token kills throughput: the engine instead
    # runs a device-side fori_loop of up to plan_steps() decode steps (each
    # slot feeding prompt-or-sampled tokens from chunk_arrays state) and
    # hands the sampled block back to observe_chunk.  (A per-token driver is
    # just observe_chunk with a (1, num_slots) block.)

    def plan_steps(self, cap: int) -> int:
        """Steps until the EARLIEST active slot completes its budget — no
        slot can overrun its reserved block span inside a chunk this long.
        ``cap`` bounds the chunk (keep small under EOS so early-stopping
        rows don't burn steps until the boundary)."""
        remaining = [st.prompt.size - 1 + st.budget - st.fed
                     for st in self._slots if st is not None]
        return max(1, min(min(remaining), cap))

    def chunk_arrays(self, prompt_width: int):
        """Per-slot device state for one chunk: padded prompts, prompt
        lengths, fed counters, last-fed token, active mask."""
        K = self.kv.num_slots
        out = {"prompt": np.zeros((K, prompt_width), np.int32),
               "plen": np.zeros((K,), np.int32),
               "fed": np.zeros((K,), np.int32),
               "last": np.zeros((K,), np.int32),
               "active": np.zeros((K,), np.int32)}
        for i, st in enumerate(self._slots):
            if st is None:
                continue
            out["prompt"][i, :st.prompt.size] = st.prompt
            out["plen"][i] = st.prompt.size
            out["fed"][i] = st.fed
            out["last"][i] = st.next_token
            out["active"][i] = 1
        return out

    def observe_chunk(self, sampled: np.ndarray,
                      eos_id: Optional[int] = None) -> List[int]:
        """Consume an (n, num_slots) block of sampled tokens (step-major);
        returns rids that finished. Step t of slot i fed token ``fed + t``
        and its sample is an emission once the prompt is consumed
        (``fed + t >= len(prompt) - 1``)."""
        n = sampled.shape[0]
        finished = []
        for slot, st in enumerate(self._slots):
            if st is None:
                continue
            done = False
            for t in range(n):
                fed_t = st.fed + t
                if fed_t < st.prompt.size - 1:
                    continue                 # still prefilling at this step
                tok = int(sampled[t, slot])
                st.emitted.append(tok)
                if (len(st.emitted) >= st.budget
                        or (eos_id is not None and tok == eos_id)):
                    done = True
                    break
            st.fed += n
            for _ in range(n):
                self.kv.advance(slot)
            if done:
                self.results[st.rid] = np.asarray(st.emitted, np.int32)
                self.kv.release(slot)
                self._slots[slot] = None
                finished.append(st.rid)
            else:
                st.next_token = (int(st.prompt[st.fed])
                                 if st.fed < st.prompt.size
                                 else int(sampled[n - 1, slot]))
        self.steps += n
        return finished
