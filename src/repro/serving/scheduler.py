"""Continuous-batching scheduler: admission, growth, preemption, progress.

Sits between a request queue and the paged prefill/decode steps.  Each
serving slot tracks one in-flight request's lifecycle:

    queued -> admitted (slot claimed, zero private blocks, SSM state reset;
              with prefix caching, the prompt's longest cached prefix is
              mapped in refcounted and skipped — ``fed`` starts past it)
           -> prefilling (remaining prompt CHUNKS fed per prefill dispatch)
           -> decoding  (sampled tokens emitted and fed back, chunked)
           -> finished  (budget exhausted or EOS) -> slot + blocks freed
        or -> preempted (blocks released; requeued at the queue head with
              prompt+emitted as the new prompt, so no work is lost)

Blocks are allocated on demand: :meth:`prepare_chunk` plans the next device
chunk (a prefill chunk while any active slot still has prompt tokens
pending, else a decode chunk) and grows every active slot's block table to
cover exactly the positions that chunk will write — oldest request first.
When the pool runs dry mid-growth, the NEWEST active request (highest rid)
is preempted and planning restarts; the oldest active request is therefore
never preempted by a younger one and always completes, which bounds
progress (no livelock) as long as every request's full span fits the pool
alone (checked at submit).

The engine drives the loop in chunks:  ``admit()`` between chunks pulls
queued requests into freed slots (FCFS — the head waits while free blocks
can't cover its prompt), ``prepare_chunk()`` plans + grows + preempts,
``prefill_arrays()``/``chunk_arrays()`` snapshot per-slot state for the
device dispatch, and ``observe_prefill()``/``observe_chunk()`` consume the
sampled results, returning ``(rid, new_tokens, finished)`` events the
moment tokens exist — the streaming API yields them before the batch
drains.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

from repro.serving.kv_cache import PagedKVCache


@dataclasses.dataclass
class _SlotState:
    rid: int
    client_id: Any
    prompt: np.ndarray            # (S,) int32 — original prompt + any tokens
    #                               emitted before a preemption (replayed)
    budget: int                   # tokens still to emit this incarnation
    next_token: int               # token the next decode step feeds
    fed: int = 0                  # tokens already fed (prompt + emitted);
    #                               starts PAST a matched cached prefix
    emitted: List[int] = dataclasses.field(default_factory=list)
    prior: List[int] = dataclasses.field(default_factory=list)
    #                               tokens emitted before preemption(s)


class Scheduler:
    """FCFS admission over ``kv.num_slots`` slots; results keyed by rid."""

    def __init__(self, kv: PagedKVCache):
        self.kv = kv
        # queue entries: (rid, client_id, prompt, budget, prior_emitted)
        self._queue: "deque[Tuple[int, Any, np.ndarray, int, List[int]]]" = \
            deque()
        self._slots: List[Optional[_SlotState]] = [None] * kv.num_slots
        self.results: Dict[int, np.ndarray] = {}
        self._scopes: Dict[int, Any] = {}   # rid -> prefix-cache hash scope
        self.steps = 0                      # decode steps driven
        self.prefill_dispatches = 0         # prefill chunks dispatched
        self.decode_dispatches = 0          # decode chunks dispatched
        self.preemptions = 0
        self.prompt_tokens = 0              # prompt tokens admitted (incl.
        #                                     preemption replays)
        self.prefix_hit_tokens = 0          # of those, served from cache

    # ---- intake -----------------------------------------------------------
    def submit(self, rid: int, client_id: Any, prompt, budget: int,
               scope: Any = None) -> None:
        """``scope`` isolates the request's prefix-cache hash chain (the
        engine passes ``(client_id, adapter version)`` — cached K/V depends
        on the adapter); ``None`` falls back to ``client_id``."""
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        if prompt.size == 0:
            raise ValueError(f"request {rid}: empty prompt")
        if budget < 1:
            raise ValueError(f"request {rid}: budget must be >= 1")
        span = int(prompt.size) + budget
        if not self.kv.fits(span):
            raise ValueError(
                f"request {rid}: span {span} exceeds cache capacity "
                f"({self.kv.max_blocks_per_slot} blocks of "
                f"{self.kv.block_size})")
        self._scopes[rid] = client_id if scope is None else scope
        self._queue.append((rid, client_id, prompt, budget, []))

    # ---- state ------------------------------------------------------------
    @property
    def has_work(self) -> bool:
        return bool(self._queue) or any(s is not None for s in self._slots)

    @property
    def active_slots(self) -> List[int]:
        return [i for i, s in enumerate(self._slots) if s is not None]

    @property
    def prefill_pending(self) -> bool:
        return any(s is not None and s.fed < s.prompt.size
                   for s in self._slots)

    # ---- lifecycle --------------------------------------------------------
    def admit(self) -> List[Tuple[int, Any]]:
        """Fill freed slots from the queue head; returns newly admitted
        ``(slot, client_id)`` pairs (the engine resets SSM state and
        resolves the adapter slot for each).  Admission claims a slot with
        zero blocks — the head waits (FCFS) while the free list can't cover
        its prompt, and growth past the prompt relies on preemption.

        With prefix caching, admission matches the prompt's longest cached
        prefix under the request's scope and starts ``fed`` past the hit —
        those positions are never re-prefilled (a preempted request
        re-admitted with prompt+emitted re-matches its own sealed blocks)."""
        admitted = []
        for slot in range(self.kv.num_slots):
            if self._slots[slot] is not None or not self._queue:
                continue
            rid, cid, prompt, budget, prior = self._queue[0]
            if not self.kv.can_admit(int(prompt.size)):
                break                        # FCFS: wait for blocks to free
            self._queue.popleft()
            n_hit = self.kv.admit(slot, scope=self._scopes[rid],
                                  tokens=prompt)
            self._slots[slot] = _SlotState(rid, cid, prompt, budget,
                                           next_token=int(prompt[0]),
                                           fed=n_hit, prior=prior)
            self.prompt_tokens += int(prompt.size)
            self.prefix_hit_tokens += n_hit
            admitted.append((slot, cid))
        return admitted

    def preempt(self, slot: int) -> int:
        """Release ``slot``'s blocks and requeue its request at the queue
        head with prompt+emitted as the new prompt (emitted-so-far moves to
        ``prior``), so the resumed incarnation replays its context and
        continues from the exact same state — no work is lost.  Returns the
        preempted rid."""
        st = self._slots[slot]
        assert st is not None, f"slot {slot} not active"
        # zero-emitted edge: requeue the original array untouched (an empty
        # concatenand must not copy or silently re-derive the dtype)
        new_prompt = st.prompt if not st.emitted else np.concatenate(
            [st.prompt, np.asarray(st.emitted, np.int32)])
        self._queue.appendleft((st.rid, st.client_id, new_prompt,
                                st.budget - len(st.emitted),
                                st.prior + st.emitted))
        self.kv.release(slot)
        self._slots[slot] = None
        self.preemptions += 1
        return st.rid

    def _finish(self, slot: int) -> None:
        st = self._slots[slot]
        self.results[st.rid] = np.asarray(st.prior + st.emitted, np.int32)
        self.kv.release(slot)
        self._slots[slot] = None

    # ---- chunk planning ----------------------------------------------------
    def plan_steps(self, cap: int) -> int:
        """Decode steps until the EARLIEST active slot completes its budget.
        ``cap`` bounds the chunk (keep small under EOS so early-stopping
        rows don't burn steps until the boundary).  Returns 1 when no slot
        is active (nothing to plan — the engine admits and retries)."""
        remaining = [st.prompt.size - 1 + st.budget - st.fed
                     for st in self._slots if st is not None]
        if not remaining:
            return 1
        return max(1, min(min(remaining), cap))

    def prepare_chunk(self, prefill_chunk: int, decode_cap: int):
        """Plan the next device chunk under on-demand block growth.

        Grows each active slot (oldest rid first) to cover the positions
        the chunk will write; when the pool runs dry, preempts the newest
        active request and replans.  Returns ``("prefill", None)`` or
        ``("decode", n_steps)``, or None when no slot is active."""
        while True:
            active = sorted((st.rid, slot)
                            for slot, st in enumerate(self._slots)
                            if st is not None)
            if not active:
                return None
            prefill = self.prefill_pending
            targets = {}
            if prefill:
                for _, slot in active:
                    st = self._slots[slot]
                    rem = st.prompt.size - st.fed
                    # slots already decoding ride along as 1-token feedback
                    # rows (no decode stall behind another slot's prompt)
                    n = min(prefill_chunk, rem) if rem > 0 else 1
                    targets[slot] = int(self.kv.lengths[slot]) + n
            else:
                n = self.plan_steps(decode_cap)
                for _, slot in active:
                    targets[slot] = int(self.kv.lengths[slot]) + n
            preempted = False
            for _, slot in active:           # oldest request claims first
                if self._slots[slot] is None:
                    continue                 # preempted earlier in this pass
                while not self.kv.ensure(slot, targets[slot]):
                    victim = max((st.rid, s)
                                 for s, st in enumerate(self._slots)
                                 if st is not None)[1]
                    if victim == slot and len(self.active_slots) == 1:
                        raise RuntimeError(
                            "pool cannot hold a single request's span "
                            "(submit() should have rejected it)")
                    self.preempt(victim)
                    preempted = True
                    if victim == slot:
                        break                # self-preempted; replan
            if not preempted:
                return ("prefill", None) if prefill else ("decode", n)

    # ---- prefill chunks ----------------------------------------------------
    def prefill_arrays(self, width: int):
        """Per-slot token chunks for one prefill dispatch: ``tokens``
        (K, width) int32 padded, ``n_new`` (K,) valid counts.  Slots still
        prefilling feed their next prompt chunk; slots already DECODING
        ride along as 1-token feedback rows (``tokens[i, 0] = last
        sample``) so decode never stalls behind another slot's prompt —
        a 1-token prefill row is bitwise-identical to a decode step."""
        K = self.kv.num_slots
        out = {"tokens": np.zeros((K, width), np.int32),
               "n_new": np.zeros((K,), np.int32)}
        for i, st in enumerate(self._slots):
            if st is None:
                continue
            n = min(width, st.prompt.size - st.fed)
            if n > 0:
                out["tokens"][i, :n] = st.prompt[st.fed:st.fed + n]
                out["n_new"][i] = n
            else:                            # decoding: feedback row
                out["tokens"][i, 0] = st.next_token
                out["n_new"][i] = 1
        return out

    def observe_prefill(self, n_new: np.ndarray, sampled: np.ndarray,
                        eos_id: Optional[int] = None
                        ) -> List[Tuple[int, List[int], bool]]:
        """Consume one prefill chunk: ``n_new[slot]`` tokens were written
        for each slot and ``sampled[slot]`` is the sample at the slot's
        last valid position.  A slot whose prompt just completed records
        that sample as its first emission; a slot that rode along as a
        decoding feedback row records it as its next emission.  Returns
        (rid, new_tokens, finished) events."""
        events = []
        for slot, st in enumerate(self._slots):
            if st is None or n_new[slot] == 0:
                continue
            n = int(n_new[slot])
            decoding = st.fed >= st.prompt.size   # feedback row (n == 1)
            written = ([st.next_token] if decoding
                       else [int(t) for t in st.prompt[st.fed:st.fed + n]])
            st.fed += n
            self.kv.advance(slot, n, tokens=written)
            if decoding or st.fed == st.prompt.size:
                tok = int(sampled[slot])
                st.emitted.append(tok)
                st.next_token = tok
                done = (len(st.emitted) >= st.budget
                        or (eos_id is not None and tok == eos_id))
                rid = st.rid
                if done:
                    self._finish(slot)
                events.append((rid, [tok], done))
        self.prefill_dispatches += 1
        return events

    # ---- decode chunks -----------------------------------------------------
    # One host round-trip per token kills throughput: the engine runs a
    # device-side fori_loop of up to plan_steps() decode steps (each slot
    # feeding its last sampled token) and hands the sampled block back to
    # observe_chunk.  (A per-token driver is just observe_chunk with a
    # (1, num_slots) block.)

    def chunk_arrays(self):
        """Per-slot device state for one decode chunk: last-fed token and
        active mask.  (Prompts are fed by prefill chunks — every active
        slot here resumes from its last sample.)"""
        K = self.kv.num_slots
        out = {"last": np.zeros((K,), np.int32),
               "active": np.zeros((K,), np.int32)}
        for i, st in enumerate(self._slots):
            if st is None:
                continue
            out["last"][i] = st.next_token
            out["active"][i] = 1
        return out

    def observe_chunk(self, sampled: np.ndarray,
                      eos_id: Optional[int] = None
                      ) -> List[Tuple[int, List[int], bool]]:
        """Consume an (n, num_slots) block of decode samples (step-major);
        returns (rid, new_tokens, finished) events.  Decode chunks only run
        once every active slot is past its prompt (prefill chunks fed it
        and recorded the first emission), so step t of slot i fed the
        previous sample and ``sampled[t, i]`` is always an emission."""
        n = sampled.shape[0]
        events = []
        for slot, st in enumerate(self._slots):
            if st is None:
                continue
            assert st.fed >= st.prompt.size, \
                f"slot {slot} entered a decode chunk mid-prefill"
            # step 0 fed (and wrote) next_token; step t>0 fed sampled[t-1]
            written = [st.next_token] + [int(sampled[t, slot])
                                         for t in range(n - 1)]
            new_toks: List[int] = []
            done = False
            for t in range(n):
                tok = int(sampled[t, slot])
                st.emitted.append(tok)
                new_toks.append(tok)
                if (len(st.emitted) >= st.budget
                        or (eos_id is not None and tok == eos_id)):
                    done = True
                    break
            st.fed += n
            self.kv.advance(slot, n, tokens=written)
            if done:
                rid = st.rid
                self._finish(slot)
                events.append((rid, new_toks, True))
            else:
                st.next_token = int(sampled[n - 1, slot])
                events.append((st.rid, new_toks, False))
        self.steps += n
        self.decode_dispatches += 1
        return events
