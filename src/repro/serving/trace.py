"""Open-loop trace workloads for the serving engine.

Closed-loop CLI batches (submit everything, wait for the drain) measure
throughput but hide the number users feel: how long a request that arrives
at a BAD moment waits.  This module generates seeded open-loop traces —
requests arrive at scheduled times whether or not the engine is keeping up
— and drives a :class:`~repro.serving.engine.StreamSession` with them,
reporting the SLA metrics serving practice cares about:

  * **TTFT** (time to first token): first emitted token's timestamp minus
    the request's SCHEDULED arrival — queueing delay included, which is
    exactly what closed-loop numbers hide.
  * **TPOT** (time per output token): mean inter-token gap after the
    first, ``(t_last - t_first) / (n - 1)``.
  * **goodput**: total emitted tokens over the serving window.

:func:`synth_trace` builds the workload (Poisson or bursty ON-OFF
arrivals, heavy-tail lognormal prompt/output lengths, priority and client
mixes) from one ``numpy`` Generator seed — same seed, same trace, always.
:func:`run_trace` replays it against an engine in one of two modes:

  * ``realtime=True`` — arrivals at wall-clock times (scaled by
    ``time_scale``); TTFT/TPOT come back in milliseconds.  This is the
    benchmark mode (``benchmarks/multitenant_bench.py --trace``).
  * ``realtime=False`` (logical) — arrival times are mapped to engine
    ROUNDS (``rounds_per_s``), so the submission schedule — and therefore
    every dispatch — is fully deterministic.  This is the parity mode:
    the async overlapped engine (``ServeConfig.overlap=True``) must
    produce bitwise-identical greedy streams to the synchronous loop on
    the same logical trace (``tests/test_trace_serving.py``).
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.serving.engine import MultiTenantEngine, Request, ServeConfig

__all__ = ["TraceEntry", "synth_trace", "run_trace"]

# default class mix: mostly latency-sensitive traffic with a batch tail —
# the shape that makes per-class TTFT percentiles informative
DEFAULT_PRIORITY_MIX = {"interactive": 0.5, "batch": 0.35, "background": 0.15}


@dataclasses.dataclass
class TraceEntry:
    """One scheduled request: WHEN it arrives and WHAT it asks for."""
    arrival_s: float            # scheduled arrival, seconds from trace start
    client_id: Any
    prompt: np.ndarray          # (S,) int32
    max_new_tokens: int
    priority: str

    def request(self) -> Request:
        return Request(client_id=self.client_id, prompt=self.prompt,
                       max_new_tokens=self.max_new_tokens,
                       priority=self.priority)


def _poisson_arrivals(rng: np.random.Generator, n: int,
                      rate: float) -> np.ndarray:
    """n exponential inter-arrival gaps at ``rate`` req/s, cumulated."""
    return np.cumsum(rng.exponential(1.0 / rate, size=n))


def _bursty_arrivals(rng: np.random.Generator, n: int, rate: float,
                     on_s: float, off_s: float) -> np.ndarray:
    """ON-OFF (Markov-modulated Poisson) arrivals: exponential ON windows
    (mean ``on_s`` seconds) of arrivals at ``rate * (on_s + off_s) / on_s``
    req/s separated by silent exponential OFF windows (mean ``off_s``) —
    the within-burst rate is scaled so the LONG-RUN average stays ``rate``,
    which keeps Poisson and bursty traces comparable at equal load while
    the bursty one stresses admission with deep transient queues."""
    burst_rate = rate * (on_s + off_s) / on_s
    out: List[float] = []
    t = 0.0
    while len(out) < n:
        on_end = t + rng.exponential(on_s)
        while len(out) < n:
            t += rng.exponential(1.0 / burst_rate)
            if t >= on_end:
                break                     # overshoot discarded: exponential
            out.append(t)                 # memorylessness keeps rates exact
        t = on_end + rng.exponential(off_s)
    return np.asarray(out)


def _lognormal_len(rng: np.random.Generator, mean: float, sigma: float,
                   lo: int, hi: int) -> int:
    """Heavy-tail length: lognormal with MEDIAN ``mean``, clipped to
    [lo, hi] — most requests are short, a fat tail is not."""
    return int(np.clip(round(rng.lognormal(np.log(mean), sigma)), lo, hi))


def synth_trace(seed: int, n_requests: int, *,
                arrival: str = "poisson",
                rate: float = 8.0,
                burst_on_s: float = 0.5,
                burst_off_s: float = 1.5,
                prompt_mean: float = 12.0, prompt_sigma: float = 0.6,
                prompt_max: int = 48,
                out_mean: float = 8.0, out_sigma: float = 0.6,
                out_max: int = 24,
                clients: Sequence[Any] = ("c0", "c1"),
                client_weights: Optional[Sequence[float]] = None,
                priority_mix: Optional[Dict[str, float]] = None,
                vocab_size: int = 300,
                forbid_tokens: Sequence[int] = (0,),
                ) -> List[TraceEntry]:
    """A seeded open-loop workload: ``n_requests`` entries sorted by
    arrival time.  ``arrival`` is ``"poisson"`` (memoryless at ``rate``
    req/s) or ``"bursty"`` (ON-OFF bursts, same long-run ``rate``).
    Prompt/output lengths are lognormal (median ``prompt_mean`` /
    ``out_mean``, shape ``*_sigma``) clipped to ``[1, *_max]``; prompt
    tokens are uniform over ``[1, vocab_size)`` minus ``forbid_tokens``
    (keep the pad id — and the EOS id, if the engine uses one — out of
    prompts).  Same seed and parameters => the SAME trace, bit for bit."""
    if n_requests < 1:
        raise ValueError(f"n_requests must be >= 1, got {n_requests}")
    if rate <= 0:
        raise ValueError(f"rate must be > 0, got {rate}")
    rng = np.random.default_rng(seed)
    if arrival == "poisson":
        arrivals = _poisson_arrivals(rng, n_requests, rate)
    elif arrival == "bursty":
        arrivals = _bursty_arrivals(rng, n_requests, rate,
                                    burst_on_s, burst_off_s)
    else:
        raise ValueError(f"arrival must be 'poisson' or 'bursty', "
                         f"got {arrival!r}")
    mix = dict(priority_mix or DEFAULT_PRIORITY_MIX)
    pr_names = sorted(mix)                     # fixed draw order
    pr_w = np.asarray([mix[k] for k in pr_names], float)
    pr_w = pr_w / pr_w.sum()
    cl_w = (np.asarray(client_weights, float) / np.sum(client_weights)
            if client_weights is not None
            else np.full(len(clients), 1.0 / len(clients)))
    forbid = set(int(t) for t in forbid_tokens)
    ok = np.asarray([t for t in range(1, vocab_size) if t not in forbid],
                    np.int32)
    if ok.size == 0:
        raise ValueError("forbid_tokens leaves no valid prompt tokens")
    entries = []
    for i in range(n_requests):
        s = _lognormal_len(rng, prompt_mean, prompt_sigma, 1, prompt_max)
        b = _lognormal_len(rng, out_mean, out_sigma, 1, out_max)
        prompt = rng.choice(ok, size=s)
        cid = clients[int(rng.choice(len(clients), p=cl_w))]
        pri = pr_names[int(rng.choice(len(pr_names), p=pr_w))]
        entries.append(TraceEntry(arrival_s=float(arrivals[i]),
                                  client_id=cid,
                                  prompt=prompt.astype(np.int32),
                                  max_new_tokens=b, priority=pri))
    return entries


def _percentiles(xs: List[float]) -> Dict[str, float]:
    if not xs:
        return {"p50": 0.0, "p99": 0.0}
    return {"p50": float(np.percentile(xs, 50)),
            "p99": float(np.percentile(xs, 99))}


def _report(trace: Sequence[TraceEntry], streams: Dict[int, List[int]],
            first: Dict[int, float], last: Dict[int, float],
            arrivals: Dict[int, float], elapsed: float, unit: str,
            mode: str, last_stats: Optional[dict]) -> dict:
    """Fold per-request timestamps into the per-class SLA report.  TTFT =
    first token minus SCHEDULED arrival (queueing included); TPOT = mean
    inter-token gap after the first.  ``unit`` scales seconds -> ms in
    realtime mode; logical mode reports round counts unscaled."""
    scale = 1e3 if unit == "ms" else 1.0
    by_class: Dict[str, Dict[str, List[float]]] = {}
    for rid, e in enumerate(trace):
        if rid not in first:
            continue                      # never produced a token
        d = by_class.setdefault(e.priority, {"ttft": [], "tpot": []})
        d["ttft"].append((first[rid] - arrivals[rid]) * scale)
        n = len(streams.get(rid, []))
        if n > 1:
            d["tpot"].append((last[rid] - first[rid]) / (n - 1) * scale)
    per_class = {}
    all_ttft: List[float] = []
    for cls, d in sorted(by_class.items()):
        per_class[cls] = {"n": len(d["ttft"]),
                          "ttft": _percentiles(d["ttft"]),
                          "tpot": _percentiles(d["tpot"])}
        all_ttft.extend(d["ttft"])
    emitted = sum(len(v) for v in streams.values())
    return {"mode": mode, "unit": unit,
            "n_requests": len(trace),
            "completed": sum(1 for rid in range(len(trace))
                             if len(streams.get(rid, [])) > 0),
            "emitted_tokens": emitted,
            "elapsed": float(elapsed),
            "goodput_tok_per_unit": emitted / max(elapsed, 1e-9),
            "ttft": _percentiles(all_ttft),
            "per_class": per_class,
            "streams": {rid: list(v) for rid, v in streams.items()},
            "last_stats": last_stats}


def run_trace(engine: MultiTenantEngine, sc: ServeConfig,
              trace: Sequence[TraceEntry], *,
              realtime: bool = False, time_scale: float = 1.0,
              rounds_per_s: float = 8.0) -> dict:
    """Replay ``trace`` open-loop against ``engine`` and report SLA stats.

    ``realtime=True``: entry ``i`` is submitted once wall-clock time
    passes ``arrival_s * time_scale`` (``time_scale < 1`` compresses a
    long trace into a short run at proportionally higher load); TTFT and
    TPOT come back in milliseconds, goodput in tokens/second, and
    ``last_stats`` carries wall-clock queue-wait percentiles per class.

    ``realtime=False`` (logical): entry ``i`` is submitted before engine
    round ``ceil(arrival_s * rounds_per_s)`` — no clocks anywhere, so two
    runs over the same trace execute IDENTICAL dispatch sequences (this
    is what makes async-vs-sync bitwise parity testable); TTFT/TPOT are
    reported in rounds, goodput in tokens/round.

    Returns the report dict (see ``_report``): per-class TTFT/TPOT
    p50/p99, goodput, per-request token ``streams`` keyed by rid (rids
    follow trace order), and the session's ``last_stats``."""
    order = sorted(range(len(trace)), key=lambda i: trace[i].arrival_s)
    if list(order) != list(range(len(trace))):
        raise ValueError("trace entries must be sorted by arrival_s")
    ses = engine.session(sc)
    pending = deque(enumerate(trace))
    streams: Dict[int, List[int]] = {}
    first: Dict[int, float] = {}
    last: Dict[int, float] = {}
    arrivals: Dict[int, float] = {}

    def _observe(events, now):
        for rid, toks, _fin in events:
            if toks and rid not in first:
                first[rid] = now
            if toks:
                last[rid] = now
                streams.setdefault(rid, []).extend(toks)

    if realtime:
        t0 = time.monotonic()
        while pending or ses.has_work:
            now = time.monotonic() - t0
            while pending and pending[0][1].arrival_s * time_scale <= now:
                rid, e = pending.popleft()
                sched_t = t0 + e.arrival_s * time_scale
                got = ses.submit(e.request(), arrival_time=sched_t)
                assert got == rid, (got, rid)
                arrivals[rid] = e.arrival_s * time_scale
            if not ses.has_work:
                # idle: sleep toward the next scheduled arrival instead of
                # spinning (open-loop idle gaps are part of the workload)
                gap = (pending[0][1].arrival_s * time_scale
                       - (time.monotonic() - t0))
                if gap > 0:
                    time.sleep(min(gap, 0.005))
                continue
            _observe(ses.step(), time.monotonic() - t0)
        elapsed = time.monotonic() - t0
        unit, mode = "ms", "realtime"
    else:
        rnd = 0
        while pending or ses.has_work:
            while (pending
                   and pending[0][1].arrival_s * rounds_per_s <= rnd):
                rid, e = pending.popleft()
                got = ses.submit(e.request())
                assert got == rid, (got, rid)
                arrivals[rid] = float(rnd)
            if not ses.has_work:
                # jump straight to the next arrival's round — idle rounds
                # run no dispatch and split no rng, so skipping them is
                # invisible to the token streams
                rnd = int(np.ceil(pending[0][1].arrival_s * rounds_per_s))
                continue
            _observe(ses.step(), float(rnd))
            rnd += 1
        elapsed = float(rnd)
        unit, mode = "rounds", "logical"
    stats = ses.finalize()
    return _report(trace, streams, first, last, arrivals, elapsed, unit,
                   mode, stats)
