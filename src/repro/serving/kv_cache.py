"""Paged KV cache: host-side block accounting over shared device pools.

The serving path replaces the monolithic per-batch ``(B, cache_len)`` cache
tree (``models/model.py::init_decode_cache``) with fixed-size K/V *blocks*
drawn from one pool per attention layer
(``models/model.py::init_paged_decode_cache``).  Each serving **slot** (a
row of the decode batch) owns a *block table* — a row of physical block ids
— plus a context length; attention gathers through the table, so slots with
ragged lengths share one pool with zero padding waste in HBM.  SSM/Mamba
layers have O(1) recurrent state and simply keep a dense per-slot row
(reset on admission via :func:`reset_slot`).

Blocks are allocated **on demand** (vLLM style): admission claims a slot
with zero blocks, and the scheduler calls :meth:`PagedKVCache.ensure`
before each device chunk to grow every active slot's table to cover the
positions the chunk will write.  A failed ``ensure`` (empty free list) is
the scheduler's preemption trigger — it releases a victim's blocks and
requeues the victim with its prompt+emitted tokens as the new prompt, so
the pool admits far deeper queues than full-span reservation while no work
is ever lost.  The free list is a ``deque`` (``popleft`` allocation is on
the per-chunk host path); release appends, so block reuse is FIFO.

This class is pure host bookkeeping: the device cache pytree stays
functional and flows through the jitted steps; the tables are uploaded per
chunk (a few hundred int32s).  Physical block 0 is reserved as a scratch
target so *inactive* slots (table rows all-zero, length 0) and ragged
prefill-chunk tails scatter their garbage writes somewhere harmless
instead of corrupting a live request's block.
"""
from __future__ import annotations

from collections import deque
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def blocks_needed(n_tokens: int, block_size: int) -> int:
    return -(-max(n_tokens, 1) // block_size)


class PagedKVCache:
    """Block allocator + block tables for ``num_slots`` serving slots.

    ``num_blocks`` counts physical blocks *including* the reserved scratch
    block 0; ``max_blocks_per_slot`` fixes the block-table width (and so the
    longest admissible context: ``max_blocks_per_slot * block_size``).
    """

    def __init__(self, num_slots: int, block_size: int, num_blocks: int,
                 max_blocks_per_slot: int):
        if num_blocks < 2:
            raise ValueError("need >= 2 blocks (block 0 is scratch)")
        self.num_slots = num_slots
        self.block_size = block_size
        self.num_blocks = num_blocks
        self.max_blocks_per_slot = max_blocks_per_slot
        self.block_tables = np.zeros((num_slots, max_blocks_per_slot),
                                     np.int32)
        self.lengths = np.zeros((num_slots,), np.int32)
        self._free: "deque[int]" = deque(range(1, num_blocks))
        self._owned: List[List[int]] = [[] for _ in range(num_slots)]

    # ---- capacity ---------------------------------------------------------
    @property
    def free_blocks(self) -> int:
        return len(self._free)

    def fits(self, n_tokens: int) -> bool:
        """Can a request spanning ``n_tokens`` EVER be admitted (even with
        every other slot preempted)?"""
        n = blocks_needed(n_tokens, self.block_size)
        return n <= min(self.max_blocks_per_slot, self.num_blocks - 1)

    def can_admit(self, n_tokens: int) -> bool:
        """Are there free blocks to cover ``n_tokens`` positions right now?
        (An admission heuristic — blocks are NOT reserved until
        :meth:`ensure` allocates them chunk by chunk.)"""
        return (self.fits(n_tokens)
                and blocks_needed(n_tokens, self.block_size) <= self.free_blocks)

    # ---- slot lifecycle ---------------------------------------------------
    def admit(self, slot: int) -> None:
        """Claim ``slot`` with zero blocks; :meth:`ensure` grows it."""
        assert not self._owned[slot], f"slot {slot} already occupied"
        self.block_tables[slot] = 0
        self.lengths[slot] = 0

    def ensure(self, slot: int, n_tokens: int) -> bool:
        """Grow ``slot`` to own blocks covering ``n_tokens`` positions.

        Returns False (allocating nothing) when the free list cannot cover
        the growth — the scheduler's cue to preempt a victim and retry."""
        need = blocks_needed(n_tokens, self.block_size)
        assert need <= self.max_blocks_per_slot, (need, n_tokens)
        add = need - len(self._owned[slot])
        if add <= 0:
            return True
        if add > len(self._free):
            return False
        for _ in range(add):
            b = self._free.popleft()
            self.block_tables[slot, len(self._owned[slot])] = b
            self._owned[slot].append(b)
        return True

    def advance(self, slot: int, n: int = 1) -> None:
        """``n`` tokens were written at positions ``lengths[slot]``..."""
        self.lengths[slot] += n
        assert self.lengths[slot] <= len(self._owned[slot]) * self.block_size, \
            f"slot {slot} advanced past its owned blocks"

    def release(self, slot: int) -> None:
        """Return a finished/preempted slot's blocks to the free list."""
        self._free.extend(self._owned[slot])
        self._owned[slot] = []
        self.block_tables[slot] = 0
        self.lengths[slot] = 0

    # ---- invariants -------------------------------------------------------
    def check_invariants(self) -> None:
        """Block accounting must hold after every scheduler transition:
        free list + owned blocks partition {1..num_blocks-1}, no block is
        owned twice, tables name owned blocks in position order, and no
        slot's length exceeds its owned span."""
        owned_all = [b for blocks in self._owned for b in blocks]
        assert len(set(owned_all)) == len(owned_all), "block owned twice"
        both = sorted(owned_all + list(self._free))
        assert both == list(range(1, self.num_blocks)), \
            "free+owned must partition {1..num_blocks-1}"
        for slot, blocks in enumerate(self._owned):
            assert self.lengths[slot] <= len(blocks) * self.block_size
            assert list(self.block_tables[slot, :len(blocks)]) == blocks
            assert (self.block_tables[slot, len(blocks):] == 0).all()

    # ---- device views -----------------------------------------------------
    def device_tables(self) -> Tuple[jnp.ndarray, jnp.ndarray]:
        return (jnp.asarray(self.block_tables), jnp.asarray(self.lengths))


def reset_slot(cache, slot: int):
    """Zero one slot's dense recurrent state (SSM rows) in a paged decode
    cache pytree.  K/V pool blocks need no reset — the per-row length mask
    excludes never-written positions."""
    def _zero(leaf_key, leaf):
        if leaf_key in ("k_pool", "v_pool"):
            return leaf
        # mamba state stacked over periods: (n_periods, num_slots, ...)
        return leaf.at[:, slot].set(jnp.zeros_like(leaf[:, slot]))

    return {"blocks": {
        name: {k: _zero(k, v) for k, v in entry.items()}
        for name, entry in cache["blocks"].items()}}
