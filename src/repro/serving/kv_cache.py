"""Paged KV cache: a content-addressed, refcounted block pool.

The serving path replaces the monolithic per-batch ``(B, cache_len)`` cache
tree (``models/model.py::init_decode_cache``) with fixed-size K/V *blocks*
drawn from one pool per attention layer
(``models/model.py::init_paged_decode_cache``).  Each serving **slot** (a
row of the decode batch) owns a *block table* — a row of physical block ids
— plus a context length; attention gathers through the table, so slots with
ragged lengths share one pool with zero padding waste in HBM.  SSM/Mamba
layers have O(1) recurrent state and simply keep a dense per-slot row
(reset on admission via :func:`reset_slot`).

Blocks are allocated **on demand** (vLLM style): admission claims a slot
with zero blocks, and the scheduler calls :meth:`PagedKVCache.ensure`
before each device chunk to grow every active slot's table to cover the
positions the chunk will write.  A failed ``ensure`` (nothing allocatable)
is the scheduler's preemption trigger — it releases a victim's blocks and
requeues the victim with its prompt+emitted tokens as the new prompt, so
the pool admits far deeper queues than full-span reservation while no work
is ever lost.

**Prefix caching** (``prefix_cache=True``) turns the pool content-addressed
and refcounted: every *sealed* block (a block the owning slot has written
full) gets a chain digest of ``(parent digest, block's token ids)`` rooted
at the slot's *scope* (the engine uses ``(client_id, adapter version)`` —
K/V depends on the adapter, so blocks never leak across clients or across
re-registered weights).  A ``digest -> block`` index lets :meth:`admit`
match the longest cached prefix of a new prompt and map those blocks into
the slot's table with ``refcount += 1`` — their prefill is skipped entirely
(the scheduler starts ``fed`` past the hit).  The match is capped at
``len(prompt) - 1`` tokens so at least one prompt token is always prefilled
(the first sampled logit needs a live forward pass).

Refcount lifecycle: a fresh block is private (``refcount == 1``) and is the
ONLY kind of block ever written — the tail a slot is still filling is
private until sealed, and sealed blocks are full, so sharing needs no
copy-on-write.  :meth:`release` (finish or preemption) decrements; at zero
an *indexed* block parks in an LRU cached-free pool — its device content
intact, ready to be re-matched (a preempted request re-admitted with
``prompt + emitted`` re-matches its own sealed blocks and resumes with
near-zero re-prefill) — while unindexed blocks return to the plain FIFO
free list.  Allocation prefers the free list and only then evicts the
least-recently-released cached block (dropping its index entry), so a warm
cache degrades gracefully under pool pressure and preemption's progress
bound is unchanged: everything cached-free is still allocatable.

This class is pure host bookkeeping: the device cache pytree stays
functional and flows through the jitted steps; the tables are uploaded per
chunk (a few hundred int32s).  Physical block 0 is reserved as a scratch
target so *inactive* slots (table rows all-zero, length 0) and ragged
prefill-chunk tails scatter their garbage writes somewhere harmless
instead of corrupting a live request's block — block 0 is never allocated,
never sealed, never shared.
"""
from __future__ import annotations

import hashlib
from collections import OrderedDict, deque
from typing import Any, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def blocks_needed(n_tokens: int, block_size: int) -> int:
    return -(-max(n_tokens, 1) // block_size)


def kv_bytes_per_block(block_size: int, n_kv_heads: int, head_dim: int,
                       kv_dtype: str = "f32") -> int:
    """HBM bytes one K+V block pair costs per attention layer.

    ``"f32"`` (the unquantized path) stores bf16 pools: 2·2 bytes per
    (position, head, lane).  ``"int8"`` stores 1-byte values plus one fp32
    scale per (position, kv-head) and factor — for head_dim 32 that is
    36 B/token/kv-head against bf16's 64 B, i.e. ~1.78x the blocks at a
    fixed HBM budget.  The serving bench and capacity planning both price
    pools through this one function."""
    positions = block_size * n_kv_heads
    if kv_dtype == "int8":
        return 2 * positions * (head_dim * 1 + 4)     # K+V values + scales
    if kv_dtype != "f32":
        raise ValueError(f"kv_dtype must be 'f32' or 'int8', got {kv_dtype!r}")
    return 2 * positions * head_dim * 2               # bf16 K+V


def _root_digest(scope: Any) -> bytes:
    return hashlib.sha256(b"scope:" + repr(scope).encode()).digest()


def _chain_digest(parent: bytes, tokens: Sequence[int]) -> bytes:
    data = np.asarray(tokens, np.int32).tobytes()
    return hashlib.sha256(parent + data).digest()


class PagedKVCache:
    """Block allocator + block tables for ``num_slots`` serving slots.

    ``num_blocks`` counts physical blocks *including* the reserved scratch
    block 0; ``max_blocks_per_slot`` fixes the block-table width (and so the
    longest admissible context: ``max_blocks_per_slot * block_size``).
    With ``prefix_cache=True`` sealed blocks are content-addressed and
    shared across slots/calls (see module docstring); refcounting is always
    on — without the flag every block simply stays at refcount 1.
    """

    def __init__(self, num_slots: int, block_size: int, num_blocks: int,
                 max_blocks_per_slot: int, prefix_cache: bool = False):
        if num_blocks < 2:
            raise ValueError("need >= 2 blocks (block 0 is scratch)")
        self.num_slots = num_slots
        self.block_size = block_size
        self.num_blocks = num_blocks
        self.max_blocks_per_slot = max_blocks_per_slot
        self.prefix_cache = prefix_cache
        self.block_tables = np.zeros((num_slots, max_blocks_per_slot),
                                     np.int32)
        self.lengths = np.zeros((num_slots,), np.int32)
        # monotonic counter bumped on every block-table mutation (admit,
        # growth, rollback, release).  ``advance`` does NOT bump it: pure
        # length growth is exactly what the engine's overlap fast path
        # chains on device, so callers caching ``device_tables()`` output
        # can key their cache on this and skip re-marshalling tables on
        # advance-only rounds.
        self.table_version = 0
        self._free: "deque[int]" = deque(range(1, num_blocks))
        # refcount-0 blocks whose content is still indexed, least-recently
        # released first (the eviction end) — the AdapterRegistry LRU
        # discipline applied to blocks instead of adapters.
        self._cached: "OrderedDict[int, None]" = OrderedDict()
        self._refcount = np.zeros((num_blocks,), np.int64)
        self._owned: List[List[int]] = [[] for _ in range(num_slots)]
        self._occupied: List[bool] = [False] * num_slots
        # content addressing: digest -> block, plus per-block reverse maps
        # (kept ONLY for indexed blocks; cleared on eviction/reuse)
        self._index: dict = {}
        self._block_hash: dict = {}
        self._block_tokens: dict = {}
        # per-slot hashing state: scope, running chain digest (None = sealing
        # disabled for this slot), sealed-block count, unsealed tail tokens
        self._scope: List[Any] = [None] * num_slots
        self._chain: List[Optional[bytes]] = [None] * num_slots
        self._nseal: List[int] = [0] * num_slots
        self._pending: List[List[int]] = [[] for _ in range(num_slots)]
        # rollback support: the chain digest AFTER each sealed block
        # (element 0 = root, element i = digest after i seals) and the token
        # ids each seal consumed — :meth:`rollback` pops these to rewind the
        # chain and refill ``_pending`` when it unseals a block.  Maintained
        # only while the slot's chain is live (frozen once sealing is
        # disabled; the already-sealed prefix keeps its history).
        self._chain_stack: List[List[bytes]] = [[] for _ in range(num_slots)]
        self._seal_toks: List[List[tuple]] = [[] for _ in range(num_slots)]
        self.evicted_cached = 0    # pool-lifetime cached-block evictions

    # ---- capacity ---------------------------------------------------------
    @property
    def free_blocks(self) -> int:
        return len(self._free)

    @property
    def cached_blocks(self) -> int:
        """Refcount-0 blocks retained for prefix re-matching (allocatable)."""
        return len(self._cached)

    @property
    def allocatable_blocks(self) -> int:
        return len(self._free) + len(self._cached)

    def fits(self, n_tokens: int) -> bool:
        """Can a request spanning ``n_tokens`` EVER be admitted (even with
        every other slot preempted and the whole cache evicted)?"""
        n = blocks_needed(n_tokens, self.block_size)
        return n <= min(self.max_blocks_per_slot, self.num_blocks - 1)

    def can_admit(self, n_tokens: int) -> bool:
        """Are there allocatable blocks to cover ``n_tokens`` positions right
        now?  (An admission heuristic — blocks are NOT reserved until
        :meth:`ensure` allocates them chunk by chunk; cached-free blocks
        count because growth may evict them.)"""
        return (self.fits(n_tokens)
                and blocks_needed(n_tokens, self.block_size)
                <= self.allocatable_blocks)

    # ---- allocation -------------------------------------------------------
    def _drop_index(self, block: int) -> None:
        digest = self._block_hash.pop(block, None)
        if digest is not None:
            self._index.pop(digest, None)
        self._block_tokens.pop(block, None)

    def _alloc(self) -> int:
        """One fresh private block: free list first, else evict the
        least-recently-released cached block (its index entry dies with it)."""
        if self._free:
            return self._free.popleft()
        block, _ = self._cached.popitem(last=False)
        self._drop_index(block)
        self.evicted_cached += 1
        return block

    # ---- prefix matching --------------------------------------------------
    def match_prefix(self, scope: Any, tokens: Sequence[int]
                     ) -> Tuple[List[int], bytes]:
        """Longest cached prefix of ``tokens`` under ``scope``: walks full
        blocks, chaining digests, and stops at the first index miss.  The
        match is capped at ``len(tokens) - 1`` so at least one token is left
        to prefill.  Returns ``(blocks, chain digest after the match)``."""
        chain = _root_digest(scope)
        hits: List[int] = []
        if not self.prefix_cache:
            return hits, chain
        tokens = np.asarray(tokens, np.int32).reshape(-1)
        full = (int(tokens.size) - 1) // self.block_size
        for i in range(min(full, self.max_blocks_per_slot)):
            blk_toks = tuple(int(t) for t in
                             tokens[i * self.block_size:
                                    (i + 1) * self.block_size])
            digest = _chain_digest(chain, blk_toks)
            block = self._index.get(digest)
            if block is None:
                break
            # serving a mismatched block would silently corrupt a request's
            # context — keep this live under ``python -O``
            if self._block_tokens[block] != blk_toks:
                raise RuntimeError(
                    f"prefix index corrupt: block {block}'s digest matches "
                    "different tokens than it stores")
            hits.append(block)
            chain = digest
        return hits, chain

    # ---- slot lifecycle ---------------------------------------------------
    def admit(self, slot: int, scope: Any = None,
              tokens: Optional[Sequence[int]] = None) -> int:
        """Claim ``slot`` with zero private blocks; :meth:`ensure` grows it.

        With prefix caching, ``tokens`` (the request's prompt) is matched
        against the cache under ``scope`` and every hit block is mapped
        into the slot's table with ``refcount += 1`` — the slot starts with
        ``lengths[slot]`` already covering the hit, and the scheduler skips
        prefilling those positions.  Returns the number of cached tokens
        (0 without a hit or with caching disabled)."""
        if self._occupied[slot]:
            raise ValueError(f"slot {slot} already occupied")
        self._occupied[slot] = True
        self.table_version += 1
        self.block_tables[slot] = 0
        self.lengths[slot] = 0
        self._owned[slot] = []
        self._pending[slot] = []
        self._nseal[slot] = 0
        self._scope[slot] = scope
        self._chain[slot] = _root_digest(scope) if self.prefix_cache else None
        self._chain_stack[slot] = (
            [self._chain[slot]] if self.prefix_cache else [])
        self._seal_toks[slot] = []
        if self.prefix_cache and tokens is not None:
            hits, chain = self.match_prefix(scope, tokens)
            for i, block in enumerate(hits):
                self._refcount[block] += 1
                self._cached.pop(block, None)      # 0 -> 1: leaves the pool
                self.block_tables[slot, i] = block
                self._owned[slot].append(block)
                # hit blocks are canonical (the index maps to them), so the
                # reverse maps reconstruct their per-seal digests and tokens
                self._chain_stack[slot].append(self._block_hash[block])
                self._seal_toks[slot].append(self._block_tokens[block])
            self._nseal[slot] = len(hits)
            self._chain[slot] = chain
            self.lengths[slot] = len(hits) * self.block_size
        return int(self.lengths[slot])

    def ensure(self, slot: int, n_tokens: int) -> bool:
        """Grow ``slot`` to own blocks covering ``n_tokens`` positions.

        Growth only ever appends fresh PRIVATE blocks (prefix hits happen at
        admission; every block past the sealed prefix is refcount-1, so the
        scatter path never writes shared content).  Returns False
        (allocating nothing) when free + cached-free blocks cannot cover
        the growth — the scheduler's cue to preempt a victim and retry."""
        if not self._occupied[slot]:
            raise ValueError(f"slot {slot} not occupied")
        need = blocks_needed(n_tokens, self.block_size)
        # a real exception, not an assert: this guards the block-table
        # bounds on the serving hot path and must survive ``python -O``
        if need > self.max_blocks_per_slot:
            raise ValueError(
                f"slot {slot} needs {need} blocks for {n_tokens} tokens but "
                f"tables hold max_blocks_per_slot={self.max_blocks_per_slot} "
                "(admission should have rejected this request: see fits())")
        add = need - len(self._owned[slot])
        if add <= 0:
            return True
        if add > self.allocatable_blocks:
            return False
        self.table_version += 1
        for _ in range(add):
            b = self._alloc()
            self._refcount[b] = 1
            self.block_tables[slot, len(self._owned[slot])] = b
            self._owned[slot].append(b)
        return True

    def _seal(self, slot: int) -> None:
        """The oldest unsealed block of ``slot`` is now full: chain its
        digest and index it (first writer wins; duplicate content keeps the
        original block as the canonical copy)."""
        block = self._owned[slot][self._nseal[slot]]
        toks = tuple(self._pending[slot][:self.block_size])
        del self._pending[slot][:self.block_size]
        digest = _chain_digest(self._chain[slot], toks)
        self._chain[slot] = digest
        self._nseal[slot] += 1
        self._chain_stack[slot].append(digest)
        self._seal_toks[slot].append(toks)
        if digest not in self._index:
            self._index[digest] = block
            self._block_hash[block] = digest
            self._block_tokens[block] = toks

    def advance(self, slot: int, n: int = 1,
                tokens: Optional[Sequence[int]] = None) -> None:
        """``n`` tokens were written at positions ``lengths[slot]``...

        ``tokens`` (the written ids, length ``n``) feeds the sealing chain:
        each block the write fills becomes content-addressed and shareable.
        Passing ``tokens=None`` permanently disables sealing for this slot
        incarnation (unhashable writes must never be served as a prefix)."""
        if not self._occupied[slot]:
            raise ValueError(f"slot {slot} not occupied")
        new_len = int(self.lengths[slot]) + n
        if new_len > len(self._owned[slot]) * self.block_size:
            raise ValueError(
                f"slot {slot} advanced past its owned blocks "
                f"({new_len} > {len(self._owned[slot])} * {self.block_size})")
        self.lengths[slot] = new_len
        if self._chain[slot] is None:
            return
        if tokens is None:
            self._chain[slot] = None
            self._pending[slot] = []
            return
        if len(tokens) != n:
            raise ValueError(f"advance(n={n}) got {len(tokens)} tokens")
        self._pending[slot].extend(int(t) for t in tokens)
        while len(self._pending[slot]) >= self.block_size:
            self._seal(slot)

    def rollback(self, slot: int, n_tokens: int) -> int:
        """Truncate ``slot``'s context to its first ``n_tokens`` tokens —
        the speculative-decoding undo: a verify dispatch writes K/V for the
        whole drafted chunk optimistically, then rolls the slot back past
        the first greedy mismatch.

        Token-granular: reduces ``lengths``, truncates the unsealed pending
        tail, UN-seals any sealed block past the new length (dropping its
        index entry if this slot's block was the canonical copy, popping
        its digest off the chain so future seals re-chain from the right
        parent, and refilling ``_pending`` with the tokens of a partially
        rolled-back block), and frees now-unneeded tail blocks back to the
        pool.  Raises ``ValueError`` — before mutating anything — if a
        sealed block to be rolled back is co-owned (``refcount >= 2``):
        shared prefix content is live in another slot's table and must
        never be invalidated under it.  (The engine's verify path can't hit
        this: it only rolls back tokens advanced within the same observe
        round, before any admission could have matched them.)

        Returns the number of blocks freed back to the pool."""
        if not self._occupied[slot]:
            raise ValueError(f"slot {slot} not occupied")
        cur = int(self.lengths[slot])
        if not 0 <= n_tokens <= cur:
            raise ValueError(
                f"rollback target {n_tokens} outside [0, {cur}]")
        bs = self.block_size
        new_nseal = min(self._nseal[slot], n_tokens // bs)
        for i in range(new_nseal, self._nseal[slot]):
            b = self._owned[slot][i]
            if self._refcount[b] >= 2:
                raise ValueError(
                    f"rollback past sealed block {b} shared by another slot "
                    f"(refcount {int(self._refcount[b])}): co-owned prefix "
                    "content cannot be invalidated")
        while self._nseal[slot] > new_nseal:
            i = self._nseal[slot] - 1
            b = self._owned[slot][i]
            self._drop_index(b)                # no-op for duplicate content
            self._nseal[slot] = i
            if self._chain[slot] is not None:
                toks = self._seal_toks[slot].pop()
                self._chain_stack[slot].pop()
                self._chain[slot] = self._chain_stack[slot][-1]
                self._pending[slot][:0] = list(toks)
        if self._chain[slot] is not None:
            del self._pending[slot][n_tokens - new_nseal * bs:]
        keep = -(-n_tokens // bs)              # ceil; >= new_nseal always
        self.table_version += 1
        freed = 0
        while len(self._owned[slot]) > keep:
            b = self._owned[slot].pop()
            self.block_tables[slot, len(self._owned[slot])] = 0
            # pool-integrity guard (must survive ``python -O``): freeing a
            # co-owned block here would hand shared live content back to the
            # allocator.  The pre-scan above only covers SEALED blocks, so
            # this is the last line of defence for the unsealed tail.
            if self._refcount[b] != 1:
                raise RuntimeError(
                    f"rollback freeing tail block {b} with refcount "
                    f"{int(self._refcount[b])} (expected 1: unsealed tail "
                    "blocks are always private)")
            self._refcount[b] = 0              # unsealed + unindexed by now
            self._free.append(b)
            freed += 1
        self.lengths[slot] = n_tokens
        return freed

    def sealed_fraction(self, slot: int) -> float:
        """Fraction of ``slot``'s owned blocks that are sealed (content-
        addressed — matched at admission or filled and indexed since).
        On release these park re-matchable in the cached-free pool (until
        pool pressure evicts them).  0.0 for empty slots and for pools
        without ``prefix_cache``."""
        if not self._occupied[slot] or not self._owned[slot]:
            return 0.0
        return self._nseal[slot] / len(self._owned[slot])

    def sealed_tokens(self, slot: int) -> int:
        """Leading context tokens of ``slot`` living in SEALED blocks.
        On release these park content-addressed (cached-free LRU) and
        re-match at the request's re-admission — near-free preemption —
        unless pool pressure evicts them in between."""
        return self._nseal[slot] * self.block_size

    def shared_prefix_tokens(self, slot: int) -> int:
        """Tokens in ``slot``'s leading run of sealed blocks that are CO-
        OWNED by another slot (``refcount >= 2``).  These survive this
        slot's release for sure — the co-owner keeps them referenced, out
        of eviction's reach — so a preempted request re-matches at least
        this prefix at re-admission.  (Merely cached-parked blocks don't
        count: the pool pressure that forces a preemption is exactly what
        evicts them.)  The scheduler's SLA victim policy reads
        ``lengths[slot] - shared_prefix_tokens(slot)`` as the re-prefill
        cost of preempting this slot."""
        run = 0
        for i, b in enumerate(self._owned[slot]):
            if i >= self._nseal[slot] or self._refcount[b] < 2:
                break
            run += 1
        return run * self.block_size

    def owned_blocks(self, slot: int) -> int:
        """Blocks currently backing ``slot``'s table (shared hits included)."""
        return len(self._owned[slot])

    def releasable_blocks(self, slot: int) -> int:
        """How many of ``slot``'s blocks become ALLOCATABLE if it releases
        now — its refcount-1 blocks (freed or cached-parked, both
        allocatable).  Co-owned blocks (refcount >= 2) stay referenced and
        yield nothing; a preemption victim is only worth preempting for the
        blocks this counts."""
        return sum(1 for b in self._owned[slot] if self._refcount[b] == 1)

    def release(self, slot: int) -> None:
        """Drop a finished/preempted slot's references.  Blocks reaching
        refcount 0 park in the cached-free LRU if indexed (content retained
        for future prefix hits; deepest blocks are evicted first within one
        release), else return to the FIFO free list."""
        if not self._occupied[slot]:
            raise ValueError(f"slot {slot} not occupied (double release?)")
        owned = self._owned[slot]
        for b in owned:
            self._refcount[b] -= 1
        for b in owned:                       # FIFO free list, table order
            if self._refcount[b] == 0 and b not in self._block_hash:
                self._free.append(b)
        for b in reversed(owned):             # tail blocks evict first
            if self._refcount[b] == 0 and b in self._block_hash:
                self._cached[b] = None
        self._owned[slot] = []
        self._occupied[slot] = False
        self._pending[slot] = []
        self._nseal[slot] = 0
        self._chain[slot] = None
        self._chain_stack[slot] = []
        self._seal_toks[slot] = []
        self._scope[slot] = None
        self.table_version += 1
        self.block_tables[slot] = 0
        self.lengths[slot] = 0

    # ---- invariants -------------------------------------------------------
    def check_invariants(self) -> None:
        """Refcount conservation must hold after every scheduler transition:

        * every block's refcount equals the number of slot-table references
          to it (shared blocks may appear in several tables);
        * each of {1..num_blocks-1} is in exactly one state: referenced
          (refcount > 0, in no free pool), cached-free (refcount 0, indexed,
          content retained), or free (refcount 0, unindexed);
        * no shared or cached block is ever on the free list;
        * the index and per-block reverse maps agree;
        * tables name owned blocks in position order; lengths stay within
          the owned span AND the table's capacity; sealed+pending
          accounting matches lengths;
        * rollback bookkeeping is consistent: no freed block is referenced
          by any table row, and a live chain's per-seal digest/token
          history matches the sealed-block count exactly (so a future
          rollback can always rewind the chain).
        """
        refs = np.zeros((self.num_blocks,), np.int64)
        for blocks in self._owned:
            for b in blocks:
                refs[b] += 1
        assert (refs == self._refcount).all(), \
            "refcount conservation broken (sum of table refs != refcount)"
        free_list = list(self._free)
        free_set = set(free_list)
        assert len(free_set) == len(free_list), "free list duplicates"
        cached = set(self._cached)
        assert not (free_set & cached), "block both free and cached-free"
        for b in range(1, self.num_blocks):
            states = (int(refs[b] > 0) + int(b in cached)
                      + int(b in free_set))
            assert states == 1, \
                f"block {b} in {states} states (refs={refs[b]})"
        for b in free_list:
            assert b not in self._block_hash, \
                f"indexed block {b} on the plain free list"
        # rollback safety: a freed block must have vanished from every
        # table row (a stale reference would gather freed content)
        referenced = set(int(b) for row in self.block_tables
                         for b in row if b != 0)
        assert not (free_set & referenced), \
            f"freed blocks still in a table: {sorted(free_set & referenced)}"
        for b in cached:
            assert b in self._block_hash, f"cached-free block {b} unindexed"
        for digest, b in self._index.items():
            assert self._block_hash.get(b) == digest, \
                f"index/digest mismatch for block {b}"
            assert b in self._block_tokens, f"indexed block {b} lost tokens"
        for slot, blocks in enumerate(self._owned):
            if blocks:
                assert self._occupied[slot], \
                    f"unoccupied slot {slot} owns blocks"
            assert self.lengths[slot] <= len(blocks) * self.block_size
            assert (self.lengths[slot]
                    <= self.max_blocks_per_slot * self.block_size), \
                f"slot {slot} length exceeds table capacity"
            assert list(self.block_tables[slot, :len(blocks)]) == blocks
            assert (self.block_tables[slot, len(blocks):] == 0).all()
            assert self._nseal[slot] <= len(blocks)
            if self._chain[slot] is not None:
                assert (self._nseal[slot] * self.block_size
                        + len(self._pending[slot]) == self.lengths[slot]), \
                    f"slot {slot} sealing accounting broken"
                assert (len(self._chain_stack[slot])
                        == self._nseal[slot] + 1), \
                    f"slot {slot} chain history out of sync with seals"
                assert self._chain_stack[slot][-1] == self._chain[slot], \
                    f"slot {slot} chain digest diverged from its history"
                assert len(self._seal_toks[slot]) == self._nseal[slot], \
                    f"slot {slot} seal-token history out of sync"

    # ---- device views -----------------------------------------------------
    def device_tables(self) -> Tuple[jnp.ndarray, jnp.ndarray]:
        # .copy(): on CPU, jnp.asarray can ALIAS a suitably aligned numpy
        # buffer zero-copy, and these buffers are mutated in place
        # (admit/growth/rollback/release) while a previously dispatched
        # chunk that read them may still be queued under async dispatch —
        # the device must get a snapshot, not a live view.
        return (jnp.asarray(self.block_tables.copy()),
                jnp.asarray(self.lengths.copy()))

    @property
    def idle(self) -> bool:
        """No slot occupied — safe to hand the pool to a new stream."""
        return not any(self._occupied)


def reset_slot(cache, slot: int):
    """Zero one slot's dense recurrent state (SSM rows) in a paged decode
    cache pytree.  K/V pool blocks need no reset — the per-row length mask
    excludes never-written positions, and prefix-cached blocks must keep
    their content across owners."""
    def _zero(leaf_key, leaf):
        if leaf_key in ("k_pool", "v_pool", "k_scale", "v_scale"):
            return leaf
        # mamba state stacked over periods: (n_periods, num_slots, ...)
        return leaf.at[:, slot].set(jnp.zeros_like(leaf[:, slot]))

    return {"blocks": {
        name: {k: _zero(k, v) for k, v in entry.items()}
        for name, entry in cache["blocks"].items()}}
