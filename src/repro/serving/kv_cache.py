"""Paged KV cache: host-side block accounting over shared device pools.

The serving path replaces the monolithic per-batch ``(B, cache_len)`` cache
tree (``models/model.py::init_decode_cache``) with fixed-size K/V *blocks*
drawn from one pool per attention layer
(``models/model.py::init_paged_decode_cache``).  Each serving **slot** (a
row of the decode batch) owns a *block table* — a row of physical block ids
— plus a context length; attention gathers through the table, so slots with
ragged lengths share one pool with zero padding waste in HBM.  SSM/Mamba
layers have O(1) recurrent state and simply keep a dense per-slot row
(reset on admission via :func:`reset_slot`).

This class is pure host bookkeeping (numpy tables, a free list): the device
cache pytree stays functional and flows through the jitted decode step; the
tables are uploaded per step (a few hundred int32s).  Physical block 0 is
reserved as a scratch target so *inactive* slots (table rows all-zero,
length 0) scatter their garbage write somewhere harmless instead of
corrupting a live request's block.
"""
from __future__ import annotations

from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def blocks_needed(n_tokens: int, block_size: int) -> int:
    return -(-max(n_tokens, 1) // block_size)


class PagedKVCache:
    """Block allocator + block tables for ``num_slots`` serving slots.

    ``num_blocks`` counts physical blocks *including* the reserved scratch
    block 0; ``max_blocks_per_slot`` fixes the block-table width (and so the
    longest admissible context: ``max_blocks_per_slot * block_size``).
    """

    def __init__(self, num_slots: int, block_size: int, num_blocks: int,
                 max_blocks_per_slot: int):
        if num_blocks < 2:
            raise ValueError("need >= 2 blocks (block 0 is scratch)")
        self.num_slots = num_slots
        self.block_size = block_size
        self.num_blocks = num_blocks
        self.max_blocks_per_slot = max_blocks_per_slot
        self.block_tables = np.zeros((num_slots, max_blocks_per_slot),
                                     np.int32)
        self.lengths = np.zeros((num_slots,), np.int32)
        self._free: List[int] = list(range(1, num_blocks))
        self._owned: List[List[int]] = [[] for _ in range(num_slots)]

    # ---- capacity ---------------------------------------------------------
    @property
    def free_blocks(self) -> int:
        return len(self._free)

    def fits(self, n_tokens: int) -> bool:
        """Can a request spanning ``n_tokens`` EVER be admitted?"""
        n = blocks_needed(n_tokens, self.block_size)
        return n <= min(self.max_blocks_per_slot, self.num_blocks - 1)

    def can_admit(self, n_tokens: int) -> bool:
        """Are there free blocks for the request's full span right now?"""
        return (self.fits(n_tokens)
                and blocks_needed(n_tokens, self.block_size) <= self.free_blocks)

    # ---- slot lifecycle ---------------------------------------------------
    def admit(self, slot: int, n_tokens: int) -> None:
        """Reserve every block of an ``n_tokens`` context for ``slot``.

        Reserving the full span up front keeps admission deadlock-free (an
        admitted request can always run to its budget); on-demand growth
        with preemption is the vLLM refinement this trades away."""
        assert not self._owned[slot], f"slot {slot} already occupied"
        if not self.can_admit(n_tokens):
            raise RuntimeError("admit() without can_admit()")
        n = blocks_needed(n_tokens, self.block_size)
        blocks = [self._free.pop(0) for _ in range(n)]
        self._owned[slot] = blocks
        self.block_tables[slot] = 0
        self.block_tables[slot, :n] = blocks
        self.lengths[slot] = 0

    def advance(self, slot: int) -> None:
        """One token was written at position ``lengths[slot]``."""
        self.lengths[slot] += 1

    def release(self, slot: int) -> None:
        """Return a finished slot's blocks to the free list."""
        self._free.extend(self._owned[slot])
        self._owned[slot] = []
        self.block_tables[slot] = 0
        self.lengths[slot] = 0

    # ---- device views -----------------------------------------------------
    def device_tables(self) -> Tuple[jnp.ndarray, jnp.ndarray]:
        return (jnp.asarray(self.block_tables), jnp.asarray(self.lengths))


def reset_slot(cache, slot: int):
    """Zero one slot's dense recurrent state (SSM rows) in a paged decode
    cache pytree.  K/V pool blocks need no reset — the per-row length mask
    excludes never-written positions."""
    def _zero(leaf_key, leaf):
        if leaf_key in ("k_pool", "v_pool"):
            return leaf
        # mamba state stacked over periods: (n_periods, num_slots, ...)
        return leaf.at[:, slot].set(jnp.zeros_like(leaf[:, slot]))

    return {"blocks": {
        name: {k: _zero(k, v) for k, v in entry.items()}
        for name, entry in cache["blocks"].items()}}
