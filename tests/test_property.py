"""Property-based tests (hypothesis) on the system's invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.analysis.roofline import _shape_bytes, parse_collectives
from repro.core import fusion as fusion_lib
from repro.core.lora import tree_add, tree_mean, tree_scale, tree_sub
from repro.data.partition import dirichlet_partition, train_test_split
from repro.data.synthetic import Example, gen_log_dataset, gen_medical_dataset
from repro.data.tokenizer import ByteTokenizer, pad_batch

SETTINGS = dict(max_examples=25, deadline=None)


# ---------------------------------------------------------------------------
# Tokenizer
# ---------------------------------------------------------------------------

@given(st.text(alphabet=st.characters(codec="utf-8"), max_size=200))
@settings(**SETTINGS)
def test_tokenizer_roundtrip(text):
    tok = ByteTokenizer()
    ids = tok.encode(text, add_bos=True, add_eos=True)
    assert ids[0] == tok.bos_id and ids[-1] == tok.eos_id
    assert tok.decode(ids) == text


@given(st.lists(st.lists(st.integers(0, 255), min_size=1, max_size=30),
                min_size=1, max_size=8),
       st.integers(8, 40))
@settings(**SETTINGS)
def test_pad_batch_invariants(seqs, max_len):
    toks, mask = pad_batch(seqs, max_len)
    assert toks.shape == (len(seqs), max_len) == mask.shape
    for i, s in enumerate(seqs):
        n = min(len(s), max_len)
        assert (toks[i, :n] == np.asarray(s[:n])).all()
        assert mask[i, :n].all()
        assert not mask[i, n:].any()


# ---------------------------------------------------------------------------
# Dirichlet partition
# ---------------------------------------------------------------------------

@given(st.integers(2, 8), st.floats(0.05, 10.0), st.integers(0, 10_000))
@settings(**SETTINGS)
def test_dirichlet_partition_conserves_and_covers(n_clients, alpha, seed):
    rng = np.random.default_rng(seed)
    data = gen_log_dataset(rng, 60, 0) + gen_log_dataset(rng, 60, 1)
    parts = dirichlet_partition(data, n_clients, alpha, rng, min_per_client=2)
    assert len(parts) == n_clients
    assert all(len(p) >= 2 for p in parts)
    # without the min-fill the counts conserve exactly; with it, >=.
    assert sum(len(p) for p in parts) >= len(data)


@given(st.floats(0.1, 0.5), st.integers(0, 100))
@settings(**SETTINGS)
def test_train_test_split_disjoint_sizes(frac, seed):
    rng = np.random.default_rng(seed)
    data = gen_medical_dataset(rng, 50, 1)
    tr, te = train_test_split(data, frac, rng)
    assert len(tr) + len(te) >= len(data) - 1
    assert len(tr) >= len(te)


# ---------------------------------------------------------------------------
# Tree arithmetic (federated aggregation algebra)
# ---------------------------------------------------------------------------

def _tree(seed, shape=(3, 4)):
    k = jax.random.PRNGKey(seed)
    return {"x": jax.random.normal(k, shape),
            "sub": {"y": jax.random.normal(jax.random.split(k)[0], shape)}}


@given(st.integers(0, 50), st.integers(51, 99))
@settings(**SETTINGS)
def test_tree_mean_is_fixed_point_of_identical(a, b):
    t = _tree(a)
    m = tree_mean([t, t, t])
    for x, y in zip(jax.tree.leaves(m), jax.tree.leaves(t)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), rtol=1e-6)


@given(st.integers(0, 50), st.integers(51, 99),
       st.floats(-2.0, 2.0, allow_nan=False))
@settings(**SETTINGS)
def test_tree_algebra(a, b, s):
    t1, t2 = _tree(a), _tree(b)
    lhs = tree_sub(tree_add(t1, tree_scale(t2, s)), t1)
    rhs = tree_scale(t2, s)
    for x, y in zip(jax.tree.leaves(lhs), jax.tree.leaves(rhs)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), atol=1e-5)


# ---------------------------------------------------------------------------
# AdaFusion black-box optimizers: must never end worse than they started
# ---------------------------------------------------------------------------

@given(st.sampled_from(["es", "spsa", "nelder_mead"]),
       st.floats(-0.5, 1.5), st.floats(-0.5, 1.5), st.integers(0, 99))
@settings(**SETTINGS)
def test_fusion_monotone_best(method, ox, oy, seed):
    opt = np.array([ox, oy], np.float32)

    def loss(w):
        return float(((w - opt) ** 2).sum())

    w, info = fusion_lib.adafusion(loss, method=method, steps=6, lam=0.0,
                                   seed=seed)
    hist = info["history"]
    assert all(hist[i + 1] <= hist[i] + 1e-9 for i in range(len(hist) - 1))
    assert loss(w) <= hist[0] + 1e-9


# ---------------------------------------------------------------------------
# HLO collective parser
# ---------------------------------------------------------------------------

@given(st.sampled_from(["bf16", "f32", "s32"]),
       st.lists(st.integers(1, 64), min_size=1, max_size=3),
       st.sampled_from(["all-reduce", "all-gather", "reduce-scatter",
                        "all-to-all", "collective-permute"]))
@settings(**SETTINGS)
def test_parse_collectives_synthetic(dtype, dims, op):
    shape = ",".join(map(str, dims))
    line = (f"  %x.1 = {dtype}[{shape}]{{0}} {op}(%y), "
            f"replica_groups={{{{0,1,2,3}}}}, channel_id=1\n")
    colls = parse_collectives(line)
    assert len(colls) == 1
    c = colls[0]
    assert c.op == op
    assert c.group_size == 4
    nbytes = int(np.prod(dims)) * {"bf16": 2, "f32": 4, "s32": 4}[dtype]
    assert c.out_bytes == nbytes
    assert c.per_chip_bytes > 0
