"""The docs spine stays healthy: link checker clean on the repo, and the
checker itself catches rot (missing files, missing anchors)."""
import importlib.util
import os

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.fixture(scope="module")
def checker():
    spec = importlib.util.spec_from_file_location(
        "check_docs_links", os.path.join(ROOT, "scripts",
                                         "check_docs_links.py"))
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_repo_docs_links_are_clean(checker):
    assert checker.check(ROOT) == []


def test_required_docs_exist():
    for p in ("README.md", "docs/architecture.md", "docs/kernels.md",
              "docs/serving.md"):
        assert os.path.exists(os.path.join(ROOT, p)), p


def test_checker_flags_broken_link_and_anchor(checker, tmp_path):
    (tmp_path / "docs").mkdir()
    (tmp_path / "README.md").write_text(
        "# Hi\n[ok](docs/a.md) [gone](docs/missing.md) "
        "[bad](docs/a.md#nope) [good](docs/a.md#real-section)\n")
    (tmp_path / "docs" / "a.md").write_text("# Real section\n")
    errors = checker.check(str(tmp_path))
    assert len(errors) == 2
    assert any("missing.md" in e for e in errors)
    assert any("#nope" in e for e in errors)


def test_checker_skips_external_and_code_fences(checker, tmp_path):
    (tmp_path / "docs").mkdir()
    (tmp_path / "README.md").write_text(
        "# Hi\n[x](https://example.com/nope)\n"
        "```\n[not a link](fake.md)\n```\n")
    assert checker.check(str(tmp_path)) == []


def test_github_slug_rules(checker):
    seen = {}
    assert checker.github_slug("Kernel contract — `a/b_c.py`", seen) \
        == "kernel-contract--ab_cpy"
    assert checker.github_slug("Dup", seen) == "dup"
    assert checker.github_slug("Dup", seen) == "dup-1"
