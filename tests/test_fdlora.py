"""FDLoRA Algorithm 1: stages, degenerate-case equivalences, fusion."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import tiny_dense
from repro.core.dual_lora import check_same_rank, merge
from repro.core.fdlora import FDLoRAConfig, FDLoRATrainer
from repro.core.lora import init_adapters, tree_mean, tree_sub
from repro.core.outer_opt import make_outer_optimizer, outer_step, pseudo_gradient
from repro.data.pipeline import SFTBatcher
from repro.data.synthetic import gen_log_dataset
from repro.data.tokenizer import ByteTokenizer
from repro.models.api import get_model


@pytest.fixture(scope="module")
def setup():
    cfg = tiny_dense(vocab_size=300)
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    tok = ByteTokenizer()
    batchers = [SFTBatcher(gen_log_dataset(rng, 24, i), tok, 64, 4, seed=i)
                for i in range(3)]
    return cfg, model, params, batchers


def test_full_algorithm1_runs(setup):
    cfg, model, params, batchers = setup
    fed = FDLoRAConfig(n_clients=3, rounds=2, inner_steps=2, sync_every=1,
                       stage1_steps=2, fusion_steps=2, few_shot_k=4)
    tr = FDLoRATrainer(model, cfg, fed, params)
    clients = tr.fit(batchers)
    assert len(clients) == 3
    for c in clients:
        assert c.fusion_weights.shape == (2,)
        fused = tr.fused_adapters(c)
        assert all(bool(jnp.isfinite(l).all()) for l in jax.tree.leaves(fused))
    # communication accounting: up ≈ down, > 0
    assert clients[0].comm_bytes_up > 0 and clients[0].comm_bytes_down > 0


def test_eq6_global_init_is_client_mean(setup):
    cfg, model, params, batchers = setup
    fed = FDLoRAConfig(n_clients=3, rounds=1, stage1_steps=1, inner_steps=1)
    tr = FDLoRATrainer(model, cfg, fed, params)
    clients = tr.stage1(batchers)
    mean = tree_mean([c.personalized for c in clients])
    for a, b in zip(jax.tree.leaves(tr.theta_s), jax.tree.leaves(mean)):
        assert jnp.allclose(a, b)


def test_fedavg_degenerate_case():
    """OuterOpt=SGD(lr=1, m=0) reduces the outer step to plain averaging."""
    cfg = tiny_dense()
    t0 = init_adapters(jax.random.PRNGKey(0), cfg)
    clients = [jax.tree.map(lambda x: x + i * 0.1, t0) for i in (1, 2, 3)]
    opt = make_outer_optimizer("fedavg")
    new, _, delta = outer_step(opt, t0, opt.init(t0), clients)
    expect = tree_mean(clients)
    for a, b in zip(jax.tree.leaves(new), jax.tree.leaves(expect)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_pseudo_gradient_direction():
    cfg = tiny_dense()
    t0 = init_adapters(jax.random.PRNGKey(0), cfg)
    moved = jax.tree.map(lambda x: x + 1.0, t0)
    delta = pseudo_gradient(t0, [moved, moved])
    for l in jax.tree.leaves(delta):
        np.testing.assert_allclose(np.asarray(l), -1.0, atol=1e-6)


def test_nesterov_outer_momentum_accumulates():
    cfg = tiny_dense()
    t0 = init_adapters(jax.random.PRNGKey(0), cfg)
    opt = make_outer_optimizer("nesterov", lr=0.1, momentum=0.9)
    st = opt.init(t0)
    g = jax.tree.map(jnp.ones_like, t0)
    u1, st = opt.update(g, st, t0)
    u2, st = opt.update(g, st, t0)
    # second step is larger in magnitude (momentum)
    n1 = sum(float(jnp.abs(l).sum()) for l in jax.tree.leaves(u1))
    n2 = sum(float(jnp.abs(l).sum()) for l in jax.tree.leaves(u2))
    assert n2 > n1


def test_merge_eq7_linearity():
    cfg = tiny_dense()
    p = init_adapters(jax.random.PRNGKey(1), cfg)
    s = init_adapters(jax.random.PRNGKey(2), cfg)
    check_same_rank(p, s)
    m = merge(p, s, jnp.array([1.0, 0.0]))
    for a, b in zip(jax.tree.leaves(m), jax.tree.leaves(p)):
        assert jnp.allclose(a, b)
    m2 = merge(p, s, jnp.array([0.5, 0.5]))
    mean = tree_mean([p, s])
    for a, b in zip(jax.tree.leaves(m2), jax.tree.leaves(mean)):
        assert jnp.allclose(a, b)


def test_rank_mismatch_rejected():
    cfg = tiny_dense()
    p = init_adapters(jax.random.PRNGKey(1), cfg, rank=4)
    s = init_adapters(jax.random.PRNGKey(2), cfg, rank=8)
    with pytest.raises(ValueError):
        check_same_rank(p, s)


def test_sync_every_h_rounds(setup):
    """H-sync (lines 13-15): with H=1 personalized tracks the global copy."""
    cfg, model, params, batchers = setup
    fed = FDLoRAConfig(n_clients=3, rounds=1, inner_steps=1, sync_every=1,
                       stage1_steps=1)
    tr = FDLoRATrainer(model, cfg, fed, params)
    clients = tr.stage1(batchers)
    tr.stage2_round(1, clients, batchers)
    for c in clients:
        for a, b in zip(jax.tree.leaves(c.personalized),
                        jax.tree.leaves(c.global_copy)):
            assert jnp.allclose(a, b)
    # and with H=0 (∞) it must NOT track
    fed2 = FDLoRAConfig(n_clients=3, rounds=1, inner_steps=1, sync_every=0,
                        stage1_steps=1)
    tr2 = FDLoRATrainer(model, cfg, fed2, params)
    clients2 = tr2.stage1(batchers)
    before = jax.tree.leaves(clients2[0].personalized)
    tr2.stage2_round(1, clients2, batchers)
    after = jax.tree.leaves(clients2[0].personalized)
    assert all(jnp.allclose(a, b) for a, b in zip(before, after))
