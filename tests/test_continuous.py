"""Continuous batching over the paged KV cache: kernel parity, block
accounting, scheduler lifecycle, and token-for-token parity of the
slot-based engine against fixed-batch and single-tenant decoding."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import tiny_dense, tiny_ssm
from repro.core.lora import init_adapters
from repro.kernels.ops import paged_gqa_attention, paged_prefill_gqa_attention
from repro.kernels.paged_attention import paged_attention
from repro.kernels.paged_prefill import paged_prefill_attention
from repro.kernels.ref import paged_attention_ref, paged_prefill_attention_ref
from repro.models.api import get_model
from repro.serving.engine import (Engine, MultiTenantEngine, Request,
                                  ServeConfig)
from repro.serving.kv_cache import PagedKVCache, blocks_needed
from repro.serving.registry import AdapterRegistry
from repro.serving.scheduler import Scheduler

RNG = np.random.default_rng(11)


# ---------------------------------------------------------------------------
# Paged-attention kernel vs the gather-materialising oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("H,Kv", [(4, 4), (8, 2)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_attention_matches_ref(H, Kv, dtype):
    B, hd, NB, bs, MB = 5, 32, 11, 8, 4
    q = jnp.asarray(RNG.standard_normal((B, H, hd)), dtype)
    kp = jnp.asarray(RNG.standard_normal((NB, bs, Kv, hd)), dtype)
    vp = jnp.asarray(RNG.standard_normal((NB, bs, Kv, hd)), dtype)
    bt = jnp.asarray(np.stack([RNG.permutation(NB)[:MB] for _ in range(B)]),
                     jnp.int32)                      # scattered physical ids
    lens = jnp.asarray([0, 1, 7, 19, 32], jnp.int32)  # ragged, incl. empty
    y = paged_attention(q, kp, vp, bt, lens)
    yr = paged_attention_ref(q, kp, vp, bt, lens)
    atol = 0.03 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32), atol=atol)
    # empty slot -> exact zeros (not NaN) on both
    assert not np.isnan(np.asarray(y, np.float32)).any()
    np.testing.assert_array_equal(np.asarray(y, np.float32)[0], 0.0)


def test_paged_ops_wrapper_pads_head_dim():
    """Model layout (B, 1, H, hd) with a non-lane-aligned head dim."""
    B, H, Kv, hd, NB, bs, MB = 3, 4, 2, 24, 7, 4, 3
    q = jnp.asarray(RNG.standard_normal((B, 1, H, hd)), jnp.float32)
    kp = jnp.asarray(RNG.standard_normal((NB, bs, Kv, hd)), jnp.float32)
    vp = jnp.asarray(RNG.standard_normal((NB, bs, Kv, hd)), jnp.float32)
    bt = jnp.asarray(RNG.integers(0, NB, (B, MB)), jnp.int32)
    lens = jnp.asarray([2, 5, 12], jnp.int32)
    y = paged_gqa_attention(q, kp, vp, bt, lens)
    yr = paged_attention_ref(q[:, 0], kp, vp, bt, lens)
    assert y.shape == q.shape
    np.testing.assert_allclose(np.asarray(y[:, 0]), np.asarray(yr), atol=2e-5)


# ---------------------------------------------------------------------------
# Chunked paged-prefill kernel vs the gather-materialising oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("H,Kv", [(4, 4), (8, 2)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_prefill_matches_ref(H, Kv, dtype):
    B, T, hd, NB, bs, MB = 4, 5, 32, 13, 4, 5
    q = jnp.asarray(RNG.standard_normal((B, T, H, hd)), dtype)
    kp = jnp.asarray(RNG.standard_normal((NB, bs, Kv, hd)), dtype)
    vp = jnp.asarray(RNG.standard_normal((NB, bs, Kv, hd)), dtype)
    bt = jnp.asarray(np.stack([RNG.permutation(np.arange(1, NB))[:MB]
                               for _ in range(B)]), jnp.int32)
    lens = jnp.asarray([0, 3, 7, 11], jnp.int32)   # ragged, incl. fresh slot
    y = paged_prefill_attention(q, kp, vp, bt, lens)
    yr = paged_prefill_attention_ref(q, kp, vp, bt, lens)
    atol = 0.03 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32), atol=atol)
    assert not np.isnan(np.asarray(y, np.float32)).any()


def test_paged_prefill_ops_wrapper_scatters_and_pads():
    """Model layout with a non-lane-aligned head dim: the wrapper scatters
    the chunk's K/V through the block table (ragged n_new tails land in
    scratch block 0) and matches the oracle over the updated pools."""
    B, T, H, Kv, hd, NB, bs, MB = 3, 4, 4, 2, 24, 14, 4, 4
    q = jnp.asarray(RNG.standard_normal((B, T, H, hd)), jnp.float32)
    kn = jnp.asarray(RNG.standard_normal((B, T, Kv, hd)), jnp.float32)
    vn = jnp.asarray(RNG.standard_normal((B, T, Kv, hd)), jnp.float32)
    kp = jnp.asarray(RNG.standard_normal((NB, bs, Kv, hd)), jnp.float32)
    vp = jnp.asarray(RNG.standard_normal((NB, bs, Kv, hd)), jnp.float32)
    # rows own DISJOINT physical blocks (the allocator's invariant) — the
    # scatter would otherwise cross-clobber rows
    perm = RNG.permutation(np.arange(1, NB))[:B * MB].reshape(B, MB)
    bt = jnp.asarray(perm, jnp.int32)
    lens = jnp.asarray([0, 2, 5], jnp.int32)
    n_new = jnp.asarray([4, 2, 0], jnp.int32)      # ragged chunk fill
    o, kp2, vp2 = paged_prefill_gqa_attention(q, kn, vn, kp, vp, bt, lens,
                                              n_new)
    # valid chunk tokens landed at (lengths + t) through the table
    for b, (l, n) in enumerate(zip([0, 2, 5], [4, 2, 0])):
        for t in range(n):
            p = l + t
            np.testing.assert_array_equal(
                np.asarray(kp2)[int(bt[b, p // bs]), p % bs],
                np.asarray(kn)[b, t])
    # row 2 fed nothing: none of its owned blocks changed
    own = [int(b) for b in np.asarray(bt)[2, :2]]
    np.testing.assert_array_equal(np.asarray(kp2)[own], np.asarray(kp)[own])
    yr = paged_prefill_attention_ref(q, kp2, vp2, bt, lens)
    assert o.shape == q.shape
    np.testing.assert_allclose(np.asarray(o), np.asarray(yr), atol=2e-5)


# ---------------------------------------------------------------------------
# PagedKVCache block accounting (on-demand growth)
# ---------------------------------------------------------------------------

def test_kv_cache_block_accounting():
    kv = PagedKVCache(num_slots=2, block_size=4, num_blocks=6,
                      max_blocks_per_slot=3)
    assert kv.free_blocks == 5                     # block 0 is scratch
    assert kv.fits(12) and not kv.fits(13)         # 3 blocks * 4 tokens
    kv.admit(0)                                    # claims slot, ZERO blocks
    assert kv.free_blocks == 5
    assert kv.ensure(0, 9)                         # grow to 3 blocks
    assert kv.free_blocks == 2
    assert (kv.block_tables[0, :3] > 0).all()      # scratch never handed out
    assert kv.ensure(0, 9)                         # idempotent: no growth
    assert kv.free_blocks == 2
    kv.admit(1)
    assert kv.ensure(1, 8)                         # 2 blocks
    assert not kv.ensure(1, 12)                    # pool dry: growth refused
    assert kv.free_blocks == 0                     # ...and nothing allocated
    kv.advance(0, 5)
    assert kv.lengths[0] == 5
    kv.check_invariants()
    kv.release(0)
    assert kv.free_blocks == 3 and kv.lengths[0] == 0
    assert (kv.block_tables[0] == 0).all()
    assert kv.ensure(1, 12)                        # freed blocks reusable
    kv.check_invariants()


def test_kv_cache_free_list_is_fifo():
    """Allocation pops the head (deque.popleft — O(1) on the per-chunk
    path); release appends, so block reuse is FIFO and deterministic."""
    kv = PagedKVCache(num_slots=2, block_size=2, num_blocks=6,
                      max_blocks_per_slot=4)
    kv.admit(0)
    assert kv.ensure(0, 6)                         # pops 1, 2, 3 in order
    assert list(kv.block_tables[0, :3]) == [1, 2, 3]
    kv.admit(1)
    assert kv.ensure(1, 2)
    assert list(kv.block_tables[1, :1]) == [4]
    kv.release(0)                                  # 1,2,3 append after 5
    assert kv.ensure(1, 8)
    assert list(kv.block_tables[1, :4]) == [4, 5, 1, 2]
    kv.check_invariants()


def test_scheduler_fcfs_admission_and_rejection():
    kv = PagedKVCache(num_slots=2, block_size=4, num_blocks=4,
                      max_blocks_per_slot=3)        # 3 free blocks total
    sched = Scheduler(kv)
    sched.submit(0, "a", np.arange(9), 2)           # prompt needs 3 blocks
    assert [s for s, _ in sched.admit()] == [0]
    assert kv.ensure(0, 9)                          # slot 0 grows: pool dry
    sched.submit(1, "b", np.arange(9), 2)
    # head's prompt can't be covered by free blocks -> FCFS wait
    assert sched.admit() == []
    with pytest.raises(ValueError):
        sched.submit(2, "c", np.arange(20), 4)      # span can never fit


def test_scheduler_plan_steps_empty_returns_one():
    """Regression: plan_steps with no active slot used to crash with
    ``min() arg is an empty sequence``."""
    kv = PagedKVCache(num_slots=2, block_size=4, num_blocks=4,
                      max_blocks_per_slot=3)
    sched = Scheduler(kv)
    assert sched.plan_steps(8) == 1
    sched.submit(0, "a", np.arange(4), 4)
    assert sched.plan_steps(8) == 1                 # queued but not admitted


def test_scheduler_preemption_requeues_prompt_plus_emitted():
    """A preempted slot releases its blocks and requeues at the queue head
    with prompt+emitted as the new prompt; nothing is lost."""
    kv = PagedKVCache(num_slots=2, block_size=2, num_blocks=8,
                      max_blocks_per_slot=6)
    sched = Scheduler(kv)
    sched.submit(0, "a", np.asarray([3, 1, 4]), 4)
    sched.submit(1, "b", np.asarray([2, 7]), 4)
    assert [s for s, _ in sched.admit()] == [0, 1]
    assert sched.prepare_chunk(8, 8) == ("prefill", None)
    arrs = sched.prefill_arrays(8)
    np.testing.assert_array_equal(arrs["n_new"], [3, 2])
    sched.observe_prefill(arrs["n_new"], np.asarray([10, 11]))
    # decode one chunk of 2 steps, then preempt slot 1
    assert sched.prepare_chunk(8, 2) == ("decode", 2)
    sched.observe_chunk(np.asarray([[20, 21], [30, 31]], np.int32))
    kv.check_invariants()
    sched.preempt(1)
    kv.check_invariants()
    assert sched.preemptions == 1
    rid, cid, prompt, budget, prior = sched._queue[0]
    assert rid == 1 and cid == "b"
    np.testing.assert_array_equal(prompt, [2, 7, 11, 21, 31])  # prompt+emitted
    assert budget == 1 and prior == [11, 21, 31]
    # resumed: prefill replays, then the final emission completes it
    assert [s for s, _ in sched.admit()] == [1]
    assert sched.prepare_chunk(8, 8) == ("prefill", None)
    arrs = sched.prefill_arrays(8)
    assert arrs["n_new"][1] == 5
    sched.observe_prefill(arrs["n_new"], np.asarray([99, 40]))
    np.testing.assert_array_equal(sched.results[1], [11, 21, 31, 40])


# ---------------------------------------------------------------------------
# Engine parity
# ---------------------------------------------------------------------------

def _client_adapters(cfg, seed):
    ad = init_adapters(jax.random.PRNGKey(seed), cfg)
    bump = jax.random.PRNGKey(seed + 99)
    return jax.tree.map(
        lambda l: l + 0.02 * jax.random.normal(bump, l.shape), ad)


def _mt_setup(cfg, n_clients=2):
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    ads = {f"c{i}": _client_adapters(cfg, i + 1) for i in range(n_clients)}
    reg = AdapterRegistry(cfg, capacity=4)
    for cid, ad in ads.items():
        reg.register(cid, ad)
    return model, params, ads, MultiTenantEngine(model, cfg, params, reg)


def _single_tenant(model, cfg, params, ad, prompt, budget, cache_len=64):
    sc = ServeConfig(batch_size=1, max_new_tokens=budget, cache_len=cache_len)
    return np.asarray(Engine(model, cfg, params, ad).generate(
        jnp.asarray(np.asarray(prompt, np.int32))[None], sc))[0]


def test_continuous_equal_shape_bitmatches_fixed():
    """Acceptance: equal-length, equal-budget greedy requests through the
    slot engine == the PR-1 fixed-batch engine, token for token."""
    cfg = tiny_dense()
    _, _, _, mt = _mt_setup(cfg)
    prompt = np.arange(8, dtype=np.int32) % cfg.vocab_size
    sc = ServeConfig(batch_size=4, max_new_tokens=8, cache_len=32,
                     block_size=8)
    reqs = [Request(c, prompt) for c in ["c1", "c0", "c1", "c0"]]
    fixed = np.asarray(mt.generate_fixed(reqs, sc))
    cont = mt.generate(reqs, sc)
    for i in range(len(reqs)):
        np.testing.assert_array_equal(cont[i], fixed[i])


def test_continuous_ragged_matches_single_tenant():
    """Mixed prompt lengths, budgets and clients — with more requests than
    slots, so completions admit queued requests mid-flight — must equal
    per-request single-tenant greedy decoding."""
    cfg = tiny_dense()
    model, params, ads, mt = _mt_setup(cfg)
    mk = lambda n: (np.arange(n, dtype=np.int32) * 3 + 1) % cfg.vocab_size
    reqs = [Request("c0", mk(5), max_new_tokens=3),
            Request("c1", mk(11), max_new_tokens=9),
            Request("c1", mk(2), max_new_tokens=5),
            Request("c0", mk(8), max_new_tokens=1),
            Request("c0", mk(7), max_new_tokens=6)]
    sc = ServeConfig(batch_size=2, max_new_tokens=8, block_size=4)
    outs = mt.generate(reqs, sc)
    for r, o in zip(reqs, outs):
        assert o.size == r.max_new_tokens
        ref = _single_tenant(model, cfg, params, ads[r.client_id],
                             r.prompt, r.max_new_tokens)
        np.testing.assert_array_equal(o, ref)


def test_continuous_ssm_state_reset_on_slot_reuse():
    """Mamba rows keep dense per-slot state; admitting a new request into a
    freed slot must not leak the previous occupant's recurrent state."""
    cfg = tiny_ssm()
    model, params, ads, mt = _mt_setup(cfg)
    mk = lambda n, o: (np.arange(n, dtype=np.int32) + o) % cfg.vocab_size
    reqs = [Request("c0", mk(4, 0), max_new_tokens=4),
            Request("c1", mk(6, 5), max_new_tokens=6),
            Request("c0", mk(3, 2), max_new_tokens=5)]
    outs = mt.generate(reqs, ServeConfig(batch_size=1, max_new_tokens=8,
                                         block_size=4))
    for r, o in zip(reqs, outs):
        ref = _single_tenant(model, cfg, params, ads[r.client_id],
                             r.prompt, r.max_new_tokens, cache_len=32)
        np.testing.assert_array_equal(o, ref)


def test_continuous_tight_pool_serialises_but_stays_correct():
    """A pool too small for full residency forces queueing; outputs are
    unchanged."""
    cfg = tiny_dense()
    model, params, ads, mt = _mt_setup(cfg)
    prompt = np.arange(6, dtype=np.int32) % cfg.vocab_size
    reqs = [Request("c0", prompt, max_new_tokens=4),
            Request("c1", prompt, max_new_tokens=4),
            Request("c0", prompt, max_new_tokens=4)]
    # span 10 -> 3 blocks of 4; pool of 4 (1 scratch + 3) fits ONE request
    sc = ServeConfig(batch_size=3, max_new_tokens=4, block_size=4,
                     num_blocks=4)
    outs = mt.generate(reqs, sc)
    for r, o in zip(reqs, outs):
        ref = _single_tenant(model, cfg, params, ads[r.client_id],
                             r.prompt, 4)
        np.testing.assert_array_equal(o, ref)


# ---------------------------------------------------------------------------
# EOS handling (ServeConfig.eos_id)
# ---------------------------------------------------------------------------

def test_eos_legacy_engine_pads_after_eos():
    cfg = tiny_dense()
    model, params, ads, mt = _mt_setup(cfg)
    prompt = np.arange(8, dtype=np.int32) % cfg.vocab_size
    base = _single_tenant(model, cfg, params, ads["c0"], prompt, 8)
    eos = int(base[2])                       # third greedy token as "EOS"
    sc = ServeConfig(batch_size=1, max_new_tokens=8, cache_len=64,
                     eos_id=eos, pad_id=0)
    out = np.asarray(Engine(model, cfg, params, ads["c0"]).generate(
        jnp.asarray(prompt)[None], sc))[0]
    cut = np.flatnonzero(base == eos)[0]
    np.testing.assert_array_equal(out[:cut + 1], base[:cut + 1])
    np.testing.assert_array_equal(out[cut + 1:], 0)


def test_eos_continuous_row_stops_early():
    cfg = tiny_dense()
    model, params, ads, mt = _mt_setup(cfg)
    prompt = np.arange(8, dtype=np.int32) % cfg.vocab_size
    base = _single_tenant(model, cfg, params, ads["c0"], prompt, 8)
    eos = int(base[2])
    sc = ServeConfig(batch_size=2, max_new_tokens=8, block_size=4,
                     eos_id=eos)
    outs = mt.generate([Request("c0", prompt), Request("c1", prompt)], sc)
    cut = np.flatnonzero(base == eos)[0]
    np.testing.assert_array_equal(outs[0], base[:cut + 1])  # EOS incl., stops
    assert outs[1].size <= 8
