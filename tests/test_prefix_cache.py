"""Cross-call prefix caching: content-addressed refcounted blocks.

Covers the block pool's hash-chain sealing and longest-prefix matching,
the refcount lifecycle (shared blocks across slots, cached-free LRU with
eviction under pressure), the refcount-aware invariant checker and the
negative paths it must catch — and, at the engine level, the acceptance
bar: shared-prefix workloads through ``MultiTenantEngine.generate`` /
``generate_stream`` with ``prefix_cache=True`` are bitwise-equal to the
cold path, hits show up in ``last_stats``, and the flagship
preemption-requeue path re-matches its own sealed blocks with near-zero
re-prefill.
"""
import dataclasses

import numpy as np
import pytest

from repro.serving.kv_cache import PagedKVCache, blocks_needed
from repro.serving.scheduler import Scheduler


def _drive(kv, sched, prefill_chunk=16, decode_cap=8):
    """The engine loop with a trivial host model (constant samples)."""
    while sched.has_work:
        sched.admit()
        plan = sched.prepare_chunk(prefill_chunk, decode_cap)
        kv.check_invariants()
        assert plan is not None
        if plan[0] == "prefill":
            arrs = sched.prefill_arrays(prefill_chunk)
            sched.observe_prefill(
                arrs["n_new"], np.full((kv.num_slots,), 42, np.int32))
        else:
            sched.observe_chunk(
                np.full((plan[1], kv.num_slots), 7, np.int32))
        kv.check_invariants()


# ---------------------------------------------------------------------------
# Block-pool mechanics: sealing, matching, refcounts, eviction
# ---------------------------------------------------------------------------

def test_seal_and_rematch_same_scope():
    prompt = np.arange(10, dtype=np.int32)
    kv = PagedKVCache(2, 4, 16, 8, prefix_cache=True)
    s = Scheduler(kv)
    s.submit(0, "a", prompt, 3, scope=("a", 1))
    _drive(kv, s)
    assert kv.idle and kv.cached_blocks > 0
    s2 = Scheduler(kv)
    s2.submit(1, "a", prompt, 3, scope=("a", 1))
    s2.admit()
    # 10-token prompt, block 4: two FULL blocks (8 tokens) re-match; the
    # match never covers the whole prompt (>= 1 token must prefill)
    assert s2._slots[0].fed == 8
    assert s2.prefix_hit_tokens == 8
    assert int(kv.lengths[0]) == 8
    kv.check_invariants()
    _drive(kv, s2)
    assert list(s2.results[1]) == list(s.results[0])


def test_scope_isolates_clients_and_versions():
    prompt = np.arange(10, dtype=np.int32)
    kv = PagedKVCache(2, 4, 32, 8, prefix_cache=True)
    s = Scheduler(kv)
    s.submit(0, "a", prompt, 3, scope=("a", 1))
    _drive(kv, s)
    for scope in (("b", 1), ("a", 2)):     # other client / bumped version
        s2 = Scheduler(kv)
        s2.submit(1, "a", prompt, 3, scope=scope)
        s2.admit()
        assert s2._slots[0].fed == 0, f"leak across scope {scope}"
        _drive(kv, s2)
    kv.check_invariants()


def test_match_capped_below_full_prompt():
    """A prompt that is an exact multiple of the block size must still
    leave its last block unmatched — the first sampled logit needs at
    least one live prefill token."""
    prompt = np.arange(8, dtype=np.int32)          # exactly 2 blocks of 4
    kv = PagedKVCache(1, 4, 16, 8, prefix_cache=True)
    s = Scheduler(kv)
    s.submit(0, "a", prompt, 2, scope="s")
    _drive(kv, s)
    s2 = Scheduler(kv)
    s2.submit(1, "a", prompt, 2, scope="s")
    s2.admit()
    assert s2._slots[0].fed == 4                   # only the first block
    _drive(kv, s2)
    assert list(s2.results[1]) == list(s.results[0])


def test_shared_blocks_are_refcounted_across_live_slots():
    prompt = np.arange(10, dtype=np.int32)
    kv = PagedKVCache(2, 4, 32, 8, prefix_cache=True)
    s = Scheduler(kv)
    s.submit(0, "a", prompt, 3, scope="s")
    _drive(kv, s)
    s2 = Scheduler(kv)
    s2.submit(0, "a", prompt, 6, scope="s")
    s2.submit(1, "a", prompt, 6, scope="s")
    s2.admit()
    # both slots matched the SAME two sealed blocks
    assert s2._slots[0].fed == 8 and s2._slots[1].fed == 8
    np.testing.assert_array_equal(kv.block_tables[0, :2],
                                  kv.block_tables[1, :2])
    shared = [int(b) for b in kv.block_tables[0, :2]]
    assert all(kv._refcount[b] == 2 for b in shared)
    kv.check_invariants()
    _drive(kv, s2)
    assert all(kv._refcount[b] == 0 for b in shared)   # released, retained
    assert kv.cached_blocks > 0
    kv.check_invariants()


def test_lru_eviction_under_pool_pressure():
    """A pool too small for two scopes' chains evicts the least-recently
    released cached blocks (index entries die with them) instead of
    refusing to allocate."""
    prompt = np.arange(10, dtype=np.int32)
    kv = PagedKVCache(1, 4, 4, 3, prefix_cache=True)   # 3 usable blocks
    a = Scheduler(kv)
    a.submit(0, "x", prompt, 2, scope="x")
    _drive(kv, a)
    assert kv.cached_blocks == 2                   # 2 sealed, 1 was partial
    b = Scheduler(kv)
    b.submit(0, "y", prompt, 2, scope="y")
    _drive(kv, b)
    assert kv.evicted_cached >= 2                  # x's chain was evicted
    c = Scheduler(kv)
    c.submit(0, "x", prompt, 2, scope="x")
    c.admit()
    assert c._slots[0].fed == 0                    # x's prefix is gone
    _drive(kv, c)
    kv.check_invariants()


def test_free_list_reuse_stays_fifo_without_prefix_cache():
    """prefix_cache=False keeps the PR-3 behaviour exactly: nothing is
    indexed, released blocks go straight to the FIFO free list."""
    prompt = np.arange(10, dtype=np.int32)
    kv = PagedKVCache(1, 4, 8, 4)
    s = Scheduler(kv)
    s.submit(0, "a", prompt, 3, scope="s")
    _drive(kv, s)
    assert kv.cached_blocks == 0
    assert kv.free_blocks == kv.num_blocks - 1
    s2 = Scheduler(kv)
    s2.submit(1, "a", prompt, 3, scope="s")
    s2.admit()
    assert s2._slots[0].fed == 0
    _drive(kv, s2)


def test_unhashable_writes_never_enter_the_index():
    """advance() without tokens permanently disables sealing for the slot
    incarnation — content the pool cannot name must never be matched."""
    kv = PagedKVCache(1, 4, 16, 8, prefix_cache=True)
    kv.admit(0, scope="s", tokens=np.arange(10, dtype=np.int32))
    assert kv.ensure(0, 10)
    kv.advance(0, 4, tokens=list(range(4)))        # sealed: 1 block
    kv.advance(0, 4)                               # tokens unknown: disable
    kv.advance(0, 2, tokens=[8, 9])                # ignored, chain is dead
    assert kv.cached_blocks == 0 and len(kv._index) == 1
    kv.check_invariants()
    kv.release(0)
    # only the one sealed block is retained; the rest went to the free list
    assert kv.cached_blocks == 1
    assert kv.free_blocks == kv.num_blocks - 2
    kv.check_invariants()


# ---------------------------------------------------------------------------
# Negative paths: the pool must refuse, and the checker must catch
# ---------------------------------------------------------------------------

def test_double_release_raises():
    kv = PagedKVCache(2, 4, 8, 4)
    kv.admit(0)
    kv.release(0)
    with pytest.raises(ValueError, match="double release"):
        kv.release(0)


def test_admit_occupied_slot_raises():
    kv = PagedKVCache(2, 4, 8, 4)
    kv.admit(0)
    with pytest.raises(ValueError, match="occupied"):
        kv.admit(0)
    kv.admit(1)                                    # other slots unaffected


def test_advance_past_ensured_blocks_raises():
    kv = PagedKVCache(1, 4, 8, 4)
    kv.admit(0)
    assert kv.ensure(0, 6)                         # 2 blocks = 8 positions
    kv.advance(0, 8)
    with pytest.raises(ValueError, match="advanced past"):
        kv.advance(0, 1)


def test_advance_unoccupied_slot_raises():
    kv = PagedKVCache(1, 4, 8, 4)
    with pytest.raises(ValueError, match="not occupied"):
        kv.advance(0, 1)


def test_invariants_catch_corrupted_free_list():
    kv = PagedKVCache(2, 4, 8, 4)
    kv.admit(0)
    assert kv.ensure(0, 6)
    owned = kv._owned[0][0]
    kv._free.append(owned)                         # hand-corrupt: owned+free
    with pytest.raises(AssertionError):
        kv.check_invariants()


def test_invariants_catch_refcount_drift():
    kv = PagedKVCache(2, 4, 8, 4, prefix_cache=True)
    kv.admit(0, scope="s")
    assert kv.ensure(0, 6)
    kv._refcount[kv._owned[0][0]] += 1             # phantom reference
    with pytest.raises(AssertionError, match="refcount conservation"):
        kv.check_invariants()


def test_invariants_catch_cached_block_on_free_list():
    prompt = np.arange(10, dtype=np.int32)
    kv = PagedKVCache(1, 4, 8, 4, prefix_cache=True)
    s = Scheduler(kv)
    s.submit(0, "a", prompt, 2, scope="s")
    _drive(kv, s)
    assert kv.cached_blocks > 0
    kv._free.append(next(iter(kv._cached)))        # shared/cached leaked
    with pytest.raises(AssertionError):
        kv.check_invariants()


# ---------------------------------------------------------------------------
# Scheduler regressions
# ---------------------------------------------------------------------------

def test_preempt_with_zero_emitted_requeues_original_prompt():
    """Regression (satellite): preempting a slot before its first emission
    must requeue the ORIGINAL prompt array — right dtype, right tokens, no
    empty-concatenation artifacts."""
    kv = PagedKVCache(2, 4, 16, 4)
    sched = Scheduler(kv)
    prompt = np.asarray([3, 1, 4, 1, 5], np.int32)
    sched.submit(0, "a", prompt, 4)
    sched.admit()
    assert sched.prepare_chunk(2, 8) == ("prefill", None)   # mid-prefill
    arrs = sched.prefill_arrays(2)
    sched.observe_prefill(arrs["n_new"], np.asarray([99, 0]))
    assert sched._slots[0].emitted == []
    slot_prompt = sched._slots[0].prompt
    sched.preempt(0)
    rid, cid, requeued, budget, prior = sched._queue[0]
    assert rid == 0 and budget == 4 and prior == []
    assert requeued.dtype == np.int32
    np.testing.assert_array_equal(requeued, prompt)
    assert requeued is slot_prompt                 # untouched, not copied
    kv.check_invariants()
    # resumes cleanly and still completes
    _drive(kv, sched)
    assert len(sched.results[0]) == 4


def test_preempted_request_rematches_its_own_blocks():
    """The flagship path: a preempted request re-admitted with
    prompt+emitted re-matches the blocks it sealed before preemption —
    near-zero re-prefill instead of a full replay."""
    prompt = np.arange(12, dtype=np.int32)
    kv = PagedKVCache(1, 4, 16, 8, prefix_cache=True)
    sched = Scheduler(kv)
    sched.submit(0, "a", prompt, 6, scope="s")
    sched.admit()
    while sched.prefill_pending:
        sched.prepare_chunk(4, 8)
        arrs = sched.prefill_arrays(4)
        sched.observe_prefill(arrs["n_new"],
                              np.full((1,), 21, np.int32))
    sched.prepare_chunk(4, 2)
    sched.observe_chunk(np.asarray([[22], [23]], np.int32))
    kv.check_invariants()
    sched.preempt(0)                               # 14 tokens written
    kv.check_invariants()
    assert kv.cached_blocks == 3                   # 12 of them sealed
    sched.admit()                                  # replays prompt+emitted
    st = sched._slots[0]
    assert st.prompt.size == 15                    # 12 prompt + 3 emitted
    assert st.fed == 12                            # sealed blocks re-matched
    assert sched.prefix_hit_tokens == 12
    _drive(kv, sched)
    assert len(sched.results[0]) == 6              # budget met, nothing lost
    kv.check_invariants()


# ---------------------------------------------------------------------------
# Real engine: warm-vs-cold bitwise parity on shared-prefix workloads
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def engine():
    import jax
    from conftest import tiny_dense
    from repro.core.lora import init_adapters
    from repro.models.api import get_model
    from repro.serving.engine import MultiTenantEngine
    from repro.serving.registry import AdapterRegistry

    cfg = tiny_dense()
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    reg = AdapterRegistry(cfg, capacity=4)
    for i in range(2):
        ad = init_adapters(jax.random.PRNGKey(i + 1), cfg)
        bump = jax.random.PRNGKey(i + 99)
        reg.register(f"c{i}", jax.tree.map(
            lambda l: l + 0.02 * jax.random.normal(bump, l.shape), ad))
    return cfg, model, params, reg, MultiTenantEngine(model, cfg, params, reg)


def _shared_prefix_requests(cfg):
    """Four requests sharing a 12-token prefix (per-client system prompt)."""
    from repro.serving.engine import Request
    pre = (np.arange(12, dtype=np.int32) * 3 + 1) % cfg.vocab_size
    mk = lambda tail: np.concatenate([pre, np.asarray(tail, np.int32)])
    return [Request("c0", mk([5, 9]), max_new_tokens=4),
            Request("c0", mk([2]), max_new_tokens=4),
            Request("c1", mk([7, 7, 7]), max_new_tokens=4),
            Request("c0", pre[:9], max_new_tokens=3)]


def test_engine_warm_bitmatches_cold_and_hits_across_calls(engine):
    """Acceptance: cached vs cold engine on a shared-prefix workload must
    bit-match; the warm call reports a >0 hit rate and fewer prefill
    dispatches than the cold call."""
    from repro.serving.engine import ServeConfig
    cfg, model, params, reg, mt = engine
    reqs = _shared_prefix_requests(cfg)
    sc_cold = ServeConfig(batch_size=2, max_new_tokens=4, block_size=4,
                          num_blocks=24, prefill_chunk=4)
    sc_warm = dataclasses.replace(sc_cold, prefix_cache=True)
    mt.release_prefix_cache()                      # isolate from other tests
    cold = mt.generate(reqs, sc_cold)
    st_cold = dict(mt.last_stats)
    assert st_cold["prefix_hit_tokens"] == 0
    warm1 = mt.generate(reqs, sc_warm)             # intra-call sharing
    st1 = dict(mt.last_stats)
    warm2 = mt.generate(reqs, sc_warm)             # cross-call re-match
    st2 = dict(mt.last_stats)
    for a, b, c in zip(cold, warm1, warm2):
        np.testing.assert_array_equal(a, b)
        np.testing.assert_array_equal(a, c)
    assert st1["prefix_hit_tokens"] > 0            # requests share a prefix
    assert st2["prefix_hit_tokens"] > st1["prefix_hit_tokens"]
    assert st2["prefix_hit_rate"] > 0.5            # whole prompts re-match
    assert st2["prefill_dispatches"] < st_cold["prefill_dispatches"]


def test_engine_warm_pool_survives_varying_batches(engine):
    """Regression: with a pinned pool (``sc.num_blocks``), the warm cache
    must survive calls whose request count and longest span differ — real
    traffic never repeats a batch shape, and a batch-derived pool key would
    silently drop the cache every call."""
    from repro.serving.engine import Request, ServeConfig
    cfg, model, params, reg, mt = engine
    pre = (np.arange(12, dtype=np.int32) * 3 + 1) % cfg.vocab_size
    mk = lambda tail: np.concatenate([pre, np.asarray(tail, np.int32)])
    sc = ServeConfig(batch_size=2, max_new_tokens=4, block_size=4,
                     num_blocks=24, prefill_chunk=4, prefix_cache=True)
    mt.release_prefix_cache()
    mt.generate([Request("c0", mk([5, 9]), max_new_tokens=4),
                 Request("c0", mk([2]), max_new_tokens=4),
                 Request("c1", mk([7]), max_new_tokens=4)], sc)
    assert mt.last_stats["prefix_pool_reused"] is False
    # fewer requests AND a longer span than call 1 — shape changes, pool
    # geometry (and therefore the sealed prefix blocks) must not
    out = mt.generate(
        [Request("c0", mk([8, 8, 8, 8, 8, 8]), max_new_tokens=6)], sc)
    st = mt.last_stats
    assert st["prefix_pool_reused"] is True
    assert st["prefix_hit_tokens"] >= 12           # the shared prefix hit
    from conftest import tiny_dense  # noqa: F401  (fixture already built)
    ref = _shared_prefix_oracle(engine, "c0", mk([8, 8, 8, 8, 8, 8]), 6)
    np.testing.assert_array_equal(out[0], ref)
    mt.release_prefix_cache()


def _shared_prefix_oracle(engine, cid, prompt, budget):
    import jax.numpy as jnp
    from repro.core.lora import init_adapters  # noqa: F401
    from repro.serving.engine import Engine, ServeConfig
    cfg, model, params, reg, mt = engine
    import jax
    ad = init_adapters(jax.random.PRNGKey(int(cid[1:]) + 1), cfg)
    bump = jax.random.PRNGKey(int(cid[1:]) + 99)
    ad = jax.tree.map(lambda l: l + 0.02 * jax.random.normal(bump, l.shape),
                      ad)
    sc = ServeConfig(batch_size=1, max_new_tokens=budget, cache_len=64)
    return np.asarray(Engine(model, cfg, params, ad).generate(
        jnp.asarray(np.asarray(prompt, np.int32))[None], sc))[0]


def test_engine_stream_warm_bitmatches_cold(engine):
    from repro.serving.engine import ServeConfig
    cfg, model, params, reg, mt = engine
    reqs = _shared_prefix_requests(cfg)
    sc_cold = ServeConfig(batch_size=2, max_new_tokens=4, block_size=4,
                          num_blocks=24, prefill_chunk=4)
    sc_warm = dataclasses.replace(sc_cold, prefix_cache=True)
    mt.release_prefix_cache()                      # isolate from other tests

    def collect(sc):
        got = {i: [] for i in range(len(reqs))}
        for rid, toks, _ in mt.generate_stream(reqs, sc):
            got[rid].extend(toks)
        return got

    cold = collect(sc_cold)
    _ = collect(sc_warm)
    warm = collect(sc_warm)
    assert mt.last_stats["prefix_hit_rate"] > 0.5
    for rid in cold:
        np.testing.assert_array_equal(np.asarray(cold[rid], np.int32),
                                      np.asarray(warm[rid], np.int32))


def test_engine_preempted_request_resumes_with_near_zero_reprefill(engine):
    """Flagship: under forced pool starvation WITH prefix caching, a
    preempted request re-admitted with prompt+emitted re-matches its own
    sealed blocks — outputs stay bitwise-equal to the uncached starved run
    while replayed prompt tokens are served from cache."""
    from repro.serving.engine import Request, ServeConfig
    cfg, model, params, reg, mt = engine
    pre = (np.arange(12, dtype=np.int32) * 3 + 1) % cfg.vocab_size
    reqs = [Request("c0", pre, max_new_tokens=6),
            Request("c1", pre[:10], max_new_tokens=6),
            Request("c0", pre[:7], max_new_tokens=5),
            Request("c1", pre[:11], max_new_tokens=4),
            Request("c0", pre[:9], max_new_tokens=6)]
    # span anchor 18 -> 5 blocks of 4; 3 slots want 15, pool holds 7
    sc_cold = ServeConfig(batch_size=3, max_new_tokens=6, block_size=4,
                          num_blocks=8, prefill_chunk=4)
    sc_warm = dataclasses.replace(sc_cold, prefix_cache=True)
    mt.release_prefix_cache()
    cold = mt.generate(reqs, sc_cold)
    st_cold = dict(mt.last_stats)
    assert st_cold["preemptions"] > 0, "workload must force preemption"
    warm = mt.generate(reqs, sc_warm)
    st_warm = dict(mt.last_stats)
    for a, b in zip(cold, warm):
        np.testing.assert_array_equal(a, b)
    assert st_warm["preemptions"] > 0
    assert st_warm["prefix_hit_tokens"] > 0        # replays re-matched
    # preemption replays inflate prompt_tokens; cached hits must absorb a
    # real share of that re-prefill work
    assert st_warm["prefill_dispatches"] <= st_cold["prefill_dispatches"]


def test_engine_rejects_prefix_cache_on_recurrent_models():
    import jax
    from conftest import tiny_ssm
    from repro.core.lora import init_adapters
    from repro.models.api import get_model
    from repro.serving.engine import (MultiTenantEngine, Request,
                                      ServeConfig)
    from repro.serving.registry import AdapterRegistry

    cfg = tiny_ssm()
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    reg = AdapterRegistry(cfg, capacity=2)
    reg.register("c0", init_adapters(jax.random.PRNGKey(1), cfg))
    mt = MultiTenantEngine(model, cfg, params, reg)
    sc = ServeConfig(batch_size=1, max_new_tokens=2, block_size=4,
                     prefix_cache=True)
    with pytest.raises(ValueError, match="attention-only"):
        mt.generate([Request("c0", np.arange(5, dtype=np.int32))], sc)


def test_registry_version_bumps_invalidate_scope(engine):
    """Re-registering a client's adapter bumps its version; the engine's
    hash scope folds the version in, so stale K/V can never be matched."""
    import jax
    from repro.core.lora import init_adapters
    from repro.serving.registry import AdapterRegistry
    cfg = engine[0]
    reg = AdapterRegistry(cfg, capacity=2)
    with pytest.raises(KeyError, match="never registered"):
        reg.version("c0")                          # never registered
    reg.register("c0", init_adapters(jax.random.PRNGKey(50), cfg))
    assert reg.version("c0") == 1
    reg.register("c0", init_adapters(jax.random.PRNGKey(51), cfg))
    assert reg.version("c0") == 2                  # refresh invalidates
    reg.evict("c0")
    assert reg.version("c0") == 2                  # eviction keeps history
