"""Per-assigned-architecture smoke tests (task deliverable f).

Each instantiates the REDUCED variant of the same family (<=2 layers per
period, d_model<=512, <=4 experts) and runs one forward + one LoRA train
step on CPU, asserting output shapes and the absence of NaNs. The FULL
configs are exercised only via the dry-run (ShapeDtypeStruct, no allocation).
"""
import jax
import jax.numpy as jnp
import pytest

from repro.configs.registry import ALL_ARCHS, get_config
from repro.core.lora import init_adapters
from repro.models.api import get_model
from repro.training.optimizers import adamw
from repro.training.train_step import make_lora_train_step


def _smoke_batch(cfg, B=2, S=32):
    k = jax.random.PRNGKey(1)
    b = {"tokens": jax.random.randint(k, (B, S), 0, cfg.vocab_size),
         "loss_mask": jnp.ones((B, S), jnp.int32)}
    if cfg.family == "vlm":
        b["patch_embeds"] = jax.random.normal(
            jax.random.PRNGKey(2), (B, cfg.n_patch_tokens, cfg.d_model),
            dtype=jnp.float32)
    if cfg.is_encdec:
        b["enc_embeds"] = jax.random.normal(
            jax.random.PRNGKey(3), (B, cfg.encoder_seq_len, cfg.d_model),
            dtype=jnp.float32)
    return b


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_forward_and_train_step(arch):
    cfg = get_config(arch, smoke=True).with_overrides(remat=False)
    assert cfg.d_model <= 512 and cfg.n_experts <= 4
    assert cfg.n_layers <= 2 * len(cfg.layer_pattern)
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _smoke_batch(cfg)

    logits, aux = model.forward(params, batch)
    S_out = batch["tokens"].shape[1] + (cfg.n_patch_tokens
                                        if cfg.family == "vlm" else 0)
    assert logits.shape == (2, S_out, cfg.vocab_size)
    assert not bool(jnp.isnan(logits).any()), f"{arch}: NaN logits"

    adapters = init_adapters(jax.random.PRNGKey(1), cfg)
    opt = adamw(lr=1e-3)
    step = jax.jit(make_lora_train_step(model, cfg, opt))
    state = opt.init(adapters)
    ad2, state, metrics = step(params, adapters, state, batch)
    assert not bool(jnp.isnan(metrics["loss"])), f"{arch}: NaN loss"
    assert float(metrics["loss"]) > 0
    # adapters actually moved (B factors leave zero)
    moved = any(float(jnp.abs(l).max()) > 0
                for l in jax.tree.leaves(ad2)) and not all(
        bool(jnp.allclose(a, b)) for a, b in
        zip(jax.tree.leaves(adapters), jax.tree.leaves(ad2)))
    assert moved, f"{arch}: train step did not update adapters"


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_decode_step(arch):
    cfg = get_config(arch, smoke=True).with_overrides(remat=False)
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B = 2
    cache = model.init_decode_cache(B, 32)
    if cfg.is_encdec:
        from repro.models.encdec import prefill_cross
        ee = jax.random.normal(jax.random.PRNGKey(3),
                               (B, cfg.encoder_seq_len, cfg.d_model))
        cache["cross_k"], cache["cross_v"] = prefill_cross(params, ee, cfg)
    tok = jnp.zeros((B, 1), jnp.int32)
    logits, cache2 = model.decode_step(params, cache, tok, jnp.int32(0))
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert not bool(jnp.isnan(logits).any())
