"""Scheduling-policy edges + the Pallas paged-backend flag.

Covers the SLA subsystem's corner cases (tie-breaking inside a class,
aging promoting a starved background request, deadline ordering,
zero-cached victim fallback to newest-first, the protected progress
bound) directly against the real ``Scheduler`` + ``PagedKVCache``, and
the ``ServeConfig.paged_backend="pallas"`` route through the real jitted
engine — greedy token streams must be BITWISE equal to the jnp oracle
path on ragged mixed-client batches, preemption included.
"""
import dataclasses

import numpy as np
import pytest

from repro.serving.kv_cache import PagedKVCache, blocks_needed
from repro.serving.scheduler import (PRIORITY_CLASSES, Scheduler,
                                     VictimInfo, newest_victim, sla_victim)

VOCAB = 50


def _prompt(n, seed=0):
    return (np.arange(n, dtype=np.int32) * 3 + seed) % VOCAB


def _drain_prefill(sched, width=32):
    """Feed every active slot its whole remaining prompt (one chunk)."""
    plan = sched.prepare_chunk(width, 4)
    assert plan[0] == "prefill"
    arrs = sched.prefill_arrays(width)
    sampled = np.arange(sched.kv.num_slots, dtype=np.int32) + 30
    return sched.observe_prefill(arrs["n_new"], sampled)


# ---------------------------------------------------------------------------
# Admission ordering: classes, aging, deadlines, in-class ties
# ---------------------------------------------------------------------------

def _admission_order(sched, kv, prefill_chunk=8, decode_cap=4):
    """Drive the real scheduler loop with a trivial host model; return the
    rid admission order."""
    order = []
    while sched.has_work:
        for slot, _ in sched.admit():
            order.append(sched._slots[slot].rid)
        plan = sched.prepare_chunk(prefill_chunk, decode_cap)
        assert plan is not None
        K = kv.num_slots
        if plan[0] == "prefill":
            arrs = sched.prefill_arrays(prefill_chunk)
            sched.observe_prefill(arrs["n_new"],
                                  np.full((K,), 7, np.int32))
        else:
            sched.observe_chunk(np.full((plan[1], K), 7, np.int32))
    return order


def _make(num_slots=1, block_size=4, num_blocks=32, mbps=8, **kw):
    kv = PagedKVCache(num_slots, block_size, num_blocks, mbps,
                      prefix_cache=kw.pop("prefix_cache", False))
    return kv, Scheduler(kv, **kw)


def test_classes_order_admission():
    """interactive < batch < background, regardless of submit order."""
    kv, sched = _make()
    sched.submit(0, "c", _prompt(4), 2, priority="background")
    sched.submit(1, "c", _prompt(4), 2, priority="batch")
    sched.submit(2, "c", _prompt(4), 2, priority="interactive")
    assert _admission_order(sched, kv) == [2, 1, 0]


def test_tie_break_inside_class_is_arrival_order():
    kv, sched = _make()
    for rid in range(4):
        sched.submit(rid, "c", _prompt(3, rid), 2, priority="batch")
    assert _admission_order(sched, kv) == [0, 1, 2, 3]


def test_deadlines_order_inside_class_deadline_less_last():
    """EDF inside a class; deadline-less requests sort after any deadlined
    peer but still run (and classes still dominate deadlines)."""
    kv, sched = _make()
    sched.submit(0, "c", _prompt(3), 2, priority="batch")             # no ddl
    sched.submit(1, "c", _prompt(3), 2, priority="batch", deadline=90)
    sched.submit(2, "c", _prompt(3), 2, priority="batch", deadline=10)
    sched.submit(3, "c", _prompt(3), 2, priority="background",
                 deadline=1)                       # class beats deadline
    assert _admission_order(sched, kv) == [2, 1, 0, 3]


def test_aging_promotes_starved_background():
    """One slot, a background request behind a stream of interactives:
    with aging it overtakes the interactive tail once promoted; with
    aging disabled it is admitted dead last."""
    def order(aging):
        kv, sched = _make(aging_ticks=aging)
        sched.submit(0, "c", _prompt(4), 2, priority="background")
        for rid in range(1, 9):
            sched.submit(rid, "c", _prompt(4), 2, priority="interactive")
        return _admission_order(sched, kv)

    assert order(0)[-1] == 0                       # no aging: starved to last
    aged = order(2)                                # promoted after 4 ticks
    assert aged[-1] != 0 and aged.index(0) < 6
    # the starvation bound itself: effective level hits 0 within
    # level * aging_ticks rounds
    kv, sched = _make(aging_ticks=2)
    sched.submit(0, "c", _prompt(4), 2, priority="background")
    assert sched.effective_level(0) == PRIORITY_CLASSES["background"]
    sched.ticks += 2 * PRIORITY_CLASSES["background"]
    assert sched.effective_level(0) == 0


def test_fcfs_policy_ignores_priorities():
    kv, sched = _make(policy="fcfs")
    sched.submit(0, "c", _prompt(4), 2, priority="background")
    sched.submit(1, "c", _prompt(4), 2, priority="interactive")
    assert _admission_order(sched, kv) == [0, 1]


def test_unknown_priority_rejected():
    kv, sched = _make()
    with pytest.raises(ValueError, match="unknown priority"):
        sched.submit(0, "c", _prompt(4), 2, priority="urgent")
    with pytest.raises(ValueError, match="unknown sched policy"):
        Scheduler(kv, policy="lifo")


# ---------------------------------------------------------------------------
# Victim selection: fallback, protection, pluggability
# ---------------------------------------------------------------------------

def test_zero_cached_victims_fall_back_to_newest_first():
    """Without prefix caching nothing is sealed/co-owned, so no candidate
    passes the guaranteed-cost guard and the SLA pick IS newest-first."""
    kv, sched = _make(num_slots=3, num_blocks=16, mbps=8)
    for rid in range(3):
        sched.submit(rid, "c", _prompt(8, rid), 4, priority="batch")
    sched.admit()
    _drain_prefill(sched)
    assert sched._pick_victim(0) == 2              # newest seq, slot 2
    # and equal-progress candidates under scoring tie-break to newest too
    infos = [VictimInfo(slot=s, rid=s, seq=s, level=1, emitted=0,
                        context_len=8, block_size=4, sealed_tokens=0,
                        sealed_fraction=0.0, shared_prefix_tokens=0,
                        releasable_blocks=2, prompt_len=8, fed=8)
             for s in (1, 2)]
    assert sla_victim(infos) == 2
    assert newest_victim(infos) == 2


def test_deadline_breaks_victim_ties_toward_most_slack():
    """Among same-class candidates the latest deadline (None = infinite
    slack) marks the safest victim: it anchors the newest-first pick and
    breaks guaranteed-cost ties among cheap candidates — and with no
    deadlines set the pick is exactly the legacy newest-first."""
    def info(slot, seq, deadline=None, shared=0):
        return VictimInfo(slot=slot, rid=slot, seq=seq, level=1, emitted=0,
                          context_len=8, block_size=4, sealed_tokens=shared,
                          sealed_fraction=0.0, shared_prefix_tokens=shared,
                          releasable_blocks=2, prompt_len=8, fed=8,
                          deadline=deadline)

    # anchor path (nothing co-owned): latest deadline loses its slot even
    # though it arrived FIRST — legacy would have taken seq 2
    assert sla_victim([info(0, 0, deadline=50.0), info(1, 1, deadline=10.0),
                       info(2, 2, deadline=30.0)]) == 0
    # a deadline-less peer has infinite slack: preferred over any deadline
    assert sla_victim([info(1, 1, deadline=10.0), info(2, 2)]) == 2
    # cheap path: equal guaranteed costs tie-break toward the most slack...
    pool = [info(0, 0, deadline=100.0, shared=8),
            info(1, 1, deadline=10.0, shared=8), info(2, 2)]
    assert sla_victim(pool) == 0
    # ...and with deadlines stripped, toward the newest (legacy behaviour)
    pool = [info(0, 0, shared=8), info(1, 1, shared=8), info(2, 2)]
    assert sla_victim(pool) == 1


def test_deadline_guides_scheduler_victim_pick():
    """Through the real scheduler: the active request with the LATEST
    deadline is preempted ahead of newer-but-tighter peers (the oldest
    top-class request stays protected)."""
    kv, sched = _make(num_slots=3, num_blocks=16, mbps=8)
    sched.submit(0, "c", _prompt(8), 4, deadline=5.0)
    sched.submit(1, "c", _prompt(8, 1), 4, deadline=99.0)
    sched.submit(2, "c", _prompt(8, 2), 4, deadline=50.0)
    sched.admit()
    _drain_prefill(sched)
    slot_of = {st.rid: s for s, st in enumerate(sched._slots)}
    # rid 0 (oldest, top class) is protected; rid 1 has the most slack
    assert sched._pick_victim(slot_of[0]) == slot_of[1]


def test_oldest_top_class_request_is_never_preempted():
    """The progress bound: the oldest active request of the top class
    present is protected from every pick."""
    kv, sched = _make(num_slots=3, num_blocks=16, mbps=8)
    sched.submit(0, "c", _prompt(8), 4, priority="batch")
    sched.submit(1, "c", _prompt(8, 1), 4, priority="interactive")
    sched.submit(2, "c", _prompt(8, 2), 4, priority="interactive")
    sched.admit()           # priority admission: slots = [rid1, rid2, rid0]
    slot_of = {st.rid: s for s, st in enumerate(sched._slots)}
    assert [sched._slots[s].rid for s in range(3)] == [1, 2, 0]
    _drain_prefill(sched)
    # top class among actives is interactive; its oldest is rid 1
    for grower in range(3):
        assert sched._pick_victim(grower) != slot_of[1]
    # lower classes are preferred victims over a newer interactive
    assert sched._pick_victim(slot_of[1]) == slot_of[0]


def test_custom_victim_policy_is_used():
    picked = []

    def leftmost(cands):
        picked.append(tuple(c.slot for c in cands))
        return min(cands, key=lambda c: c.slot).slot

    kv, sched = _make(num_slots=3, num_blocks=16, mbps=8,
                      victim_policy=leftmost)
    for rid in range(3):
        sched.submit(rid, "c", _prompt(8, rid), 4)
    sched.admit()
    _drain_prefill(sched)
    assert sched._pick_victim(2) == 1              # slot 0 protected
    assert picked == [(1, 2)]


def test_preempted_request_keeps_seq_and_restarts_aging():
    kv, sched = _make(num_slots=2, num_blocks=16, mbps=8)
    sched.submit(0, "c", _prompt(8), 4)
    sched.submit(1, "c", _prompt(8, 1), 4)
    sched.admit()
    _drain_prefill(sched)
    sched.ticks += 5
    sched.preempt(1)
    m = sched._meta[1]
    assert m.seq == 1 and m.enqueue_tick == sched.ticks
    assert sched.preemptions_by_class == {"batch": 1}
    assert len(sched.victim_sealed_fractions) == 1


def test_wait_stats_recorded_per_class():
    kv, sched = _make()
    sched.submit(0, "c", _prompt(4), 2, priority="interactive")
    sched.submit(1, "c", _prompt(4), 2, priority="background")
    _admission_order(sched, kv)
    assert len(sched.wait_ticks["interactive"]) == 1
    assert len(sched.wait_ticks["background"]) == 1
    assert (sched.wait_ticks["interactive"][0]
            <= sched.wait_ticks["background"][0])


# ---------------------------------------------------------------------------
# paged_backend="pallas": the kernels behind the flag, bitwise greedy parity
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def f32_engine():
    import jax
    from conftest import tiny_dense
    from repro.core.lora import init_adapters
    from repro.models.api import get_model
    from repro.serving.engine import MultiTenantEngine
    from repro.serving.registry import AdapterRegistry

    cfg = tiny_dense(dtype="float32", param_dtype="float32")
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    reg = AdapterRegistry(cfg, capacity=2)
    for i in range(2):
        reg.register(f"c{i}", init_adapters(jax.random.PRNGKey(i + 1), cfg))
    return cfg, MultiTenantEngine(model, cfg, params, reg)


def _ragged_requests(cfg, n=4):
    from repro.serving.engine import Request
    rng = np.random.default_rng(5)
    reqs = [Request("c0", _prompt(12) % cfg.vocab_size, max_new_tokens=6)]
    for i in range(n - 1):
        plen = int(rng.integers(2, 13))
        reqs.append(Request(f"c{i % 2}",
                            rng.integers(0, cfg.vocab_size, plen)
                            .astype(np.int32),
                            max_new_tokens=int(rng.integers(2, 7))))
    return reqs


def test_pallas_backend_bitwise_greedy_parity_ragged(f32_engine):
    """paged_backend="pallas" (interpret mode on CPU) must emit the exact
    greedy token streams of the jnp oracle path on a ragged mixed-client
    batch — the TPU switch cannot change outputs."""
    from repro.serving.engine import ServeConfig
    cfg, mt = f32_engine
    sc = ServeConfig(batch_size=2, max_new_tokens=6, block_size=4,
                     num_blocks=24, prefill_chunk=4)
    reqs = _ragged_requests(cfg)
    out_jnp = mt.generate(reqs, sc)
    out_pal = mt.generate(reqs,
                          dataclasses.replace(sc, paged_backend="pallas"))
    for a, b in zip(out_jnp, out_pal):
        np.testing.assert_array_equal(a, b)


def test_pallas_backend_parity_under_preemption(f32_engine):
    """The flag holds through the starved-pool path too: growth,
    preemption, replay — all through the Pallas kernels."""
    from repro.serving.engine import ServeConfig
    cfg, mt = f32_engine
    reqs = _ragged_requests(cfg, n=5)
    sc = ServeConfig(batch_size=3, max_new_tokens=6, block_size=4,
                     num_blocks=8, prefill_chunk=4)
    out_jnp = mt.generate(reqs, sc)
    assert mt.last_stats["preemptions"] > 0
    out_pal = mt.generate(reqs,
                          dataclasses.replace(sc, paged_backend="pallas"))
    assert mt.last_stats["preemptions"] > 0
    for a, b in zip(out_jnp, out_pal):
        np.testing.assert_array_equal(a, b)


def test_pallas_backend_rejects_unsupported_attention():
    """Sliding-window / softcap archs must fail loudly, not silently
    diverge, when routed through the kernels."""
    import jax
    from conftest import tiny_dense
    from repro.models.api import get_model

    cfg = tiny_dense(dtype="float32", param_dtype="float32",
                     sliding_window=8, paged_backend="pallas")
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    cache = model.init_paged_decode_cache(2, 8, 4)
    bt = np.zeros((2, 4), np.int32)
    bt[0, 0] = 1
    bt[1, 0] = 2
    with pytest.raises(NotImplementedError, match="full attention only"):
        model.decode_step(params, cache,
                          np.zeros((2, 1), np.int32),
                          np.zeros((2,), np.int32),
                          block_tables=np.asarray(bt))


def test_invalid_paged_backend_rejected(f32_engine):
    cfg, mt = f32_engine
    with pytest.raises(ValueError, match="unknown paged_backend"):
        mt.model.decode_step(None, None, None, None, paged_backend="cuda")


def test_engine_priority_classes_reorder_and_report(f32_engine):
    """End-to-end: the interactive request submitted LAST runs first on a
    contended 1-slot engine (everything queues at t0, and priority
    admission outranks arrival), and last_stats reports per-class waits;
    fcfs keeps submission order."""
    from repro.serving.engine import Request, ServeConfig
    cfg, mt = f32_engine
    prompt = _prompt(8) % cfg.vocab_size
    reqs = [Request("c0", prompt, max_new_tokens=4, priority="batch"),
            Request("c1", prompt[:6], max_new_tokens=4, priority="batch"),
            Request("c0", prompt[:5], max_new_tokens=4,
                    priority="interactive")]
    sc = ServeConfig(batch_size=1, max_new_tokens=4, block_size=4,
                     num_blocks=24, prefill_chunk=4)

    def finish_order(sc):
        order = []
        for rid, _toks, fin in mt.generate_stream(reqs, sc):
            if fin:
                order.append(rid)
        return order

    assert finish_order(sc) == [2, 0, 1]           # interactive jumps queue
    st = mt.last_stats
    assert st["sched_policy"] == "sla"
    assert st["classes"]["interactive"]["admitted"] == 1
    assert st["classes"]["batch"]["admitted"] == 2
    assert (st["classes"]["interactive"]["wait_p50"]
            <= st["classes"]["batch"]["wait_p99"])
    assert finish_order(
        dataclasses.replace(sc, sched_policy="fcfs")) == [0, 1, 2]


# ---------------------------------------------------------------------------
# Registry-level default priorities
# ---------------------------------------------------------------------------

def test_registry_default_priority_roundtrip():
    """register(default_priority=) sticks, None keeps the previous value
    (a weight refresh must not demote a tenant's SLA), unknown classes are
    rejected, and eviction clears the default."""
    import jax
    from conftest import tiny_dense
    from repro.core.lora import init_adapters
    from repro.serving.registry import AdapterRegistry

    cfg = tiny_dense()
    ad = init_adapters(jax.random.PRNGKey(0), cfg)
    reg = AdapterRegistry(cfg, capacity=2)
    assert reg.default_priority("c0") is None
    reg.register("c0", ad, default_priority="interactive")
    assert reg.default_priority("c0") == "interactive"
    reg.register("c0", ad)                         # refresh: default kept
    assert reg.default_priority("c0") == "interactive"
    reg.register("c0", ad, default_priority="background")
    assert reg.default_priority("c0") == "background"
    with pytest.raises(ValueError, match="default_priority"):
        reg.register("c1", ad, default_priority="turbo")
    reg.evict("c0")
    assert reg.default_priority("c0") is None


def test_engine_client_default_priority_explicit_wins(f32_engine):
    """End-to-end on a contended 1-slot engine: a request WITHOUT a
    priority inherits its client's registered default (c1 -> interactive,
    so rid 1 jumps the queue), while an explicit Request.priority
    overrides the default (rid 2 is c1 but explicitly background, so it
    finishes last despite its client's interactive default)."""
    import jax
    from conftest import tiny_dense
    from repro.core.lora import init_adapters
    from repro.models.api import get_model
    from repro.serving.engine import MultiTenantEngine, Request, ServeConfig
    from repro.serving.registry import AdapterRegistry

    cfg = tiny_dense(dtype="float32", param_dtype="float32")
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    reg = AdapterRegistry(cfg, capacity=2)
    reg.register("c0", init_adapters(jax.random.PRNGKey(1), cfg))
    reg.register("c1", init_adapters(jax.random.PRNGKey(2), cfg),
                 default_priority="interactive")
    mt = MultiTenantEngine(model, cfg, params, reg)
    prompt = _prompt(8) % cfg.vocab_size
    reqs = [Request("c0", prompt, max_new_tokens=4),          # -> batch
            Request("c1", prompt[:6], max_new_tokens=4),      # -> interactive
            Request("c1", prompt[:5], max_new_tokens=4,
                    priority="background")]                   # explicit wins
    sc = ServeConfig(batch_size=1, max_new_tokens=4, block_size=4,
                     num_blocks=24, prefill_chunk=4)
    order = [rid for rid, _t, fin in mt.generate_stream(reqs, sc) if fin]
    assert order == [1, 0, 2]
    st = mt.last_stats
    assert st["classes"]["interactive"]["admitted"] == 1
    assert st["classes"]["batch"]["admitted"] == 1
    assert st["classes"]["background"]["admitted"] == 1
