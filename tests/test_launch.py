"""Launcher-layer units that don't need 512 devices: input specs, batch-axis
assignment, sharding fixups, registry shape rules."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs.base import INPUT_SHAPES
from repro.configs.registry import (ALL_ARCHS, ASSIGNED_ARCHS,
                                    config_for_shape, get_config,
                                    shape_supported)
from repro.launch import specs as sp
from repro.launch.mesh import make_host_mesh, make_production_mesh


def test_registry_covers_assignment():
    assert len(ASSIGNED_ARCHS) == 10
    assert "llama2-7b" in ALL_ARCHS  # the paper's own backbone
    for a in ALL_ARCHS:
        cfg = get_config(a)
        assert cfg.citation, a
        smoke = get_config(a, smoke=True)
        assert smoke.d_model <= 512 and smoke.n_experts <= 4


def test_shape_support_matrix():
    combos = [(a, s) for a in ASSIGNED_ARCHS for s in INPUT_SHAPES]
    assert len(combos) == 40
    skipped = [(a, s) for a, s in combos if not shape_supported(a, s)]
    assert skipped == [("whisper-small", "long_500k")]  # DESIGN.md §5


def test_long_500k_forces_subquadratic():
    for a in ASSIGNED_ARCHS:
        if not shape_supported(a, "long_500k"):
            continue
        cfg = config_for_shape(a, "long_500k")
        ok = cfg.sliding_window > 0 or cfg.has_mixer("mamba")
        assert ok, f"{a} would run quadratic attention at 500k"


def test_train_inputs_shapes():
    cfg = get_config("internvl2-26b")
    ins = sp.train_inputs(cfg, "train_4k")
    assert ins["tokens"].shape == (256, 4096)
    assert ins["patch_embeds"].shape == (256, cfg.n_patch_tokens, cfg.d_model)
    cfg = get_config("whisper-small")
    ins = sp.train_inputs(cfg, "train_4k")
    assert ins["enc_embeds"].shape == (256, 1500, 768)


def test_batch_axes_divisibility():
    mesh = make_host_mesh()  # (1, 1) on CPU
    assert sp.batch_axes(mesh, 256) == ("data",)
    # batch=1 -> no batch sharding at all
    assert sp.batch_axes(mesh, 1) in (("data",), None)  # data=1 divides 1


def test_decode_inputs():
    cfg = get_config("yi-6b")
    ins = sp.decode_inputs(cfg, "decode_32k")
    assert ins["tokens"].shape == (128, 1)
    assert ins["pos"].shape == ()


# ---------------------------------------------------------------------------
# Mesh factories
# ---------------------------------------------------------------------------

def test_host_mesh_default_shape():
    n = len(jax.devices())
    mesh = make_host_mesh()
    assert mesh.axis_names == ("data", "model")
    assert mesh.shape == {"data": n, "model": 1}


def test_host_mesh_model_axis_must_divide_devices():
    n = len(jax.devices())
    bad = n + 1  # never divides n (and n+1 > n when n is 1)
    with pytest.raises(ValueError, match="not divisible by the model axis"):
        make_host_mesh(model=bad)


def test_host_mesh_rejects_nonpositive_model_axis():
    with pytest.raises(ValueError, match="must be >= 1"):
        make_host_mesh(model=0)


def test_host_mesh_splits_model_axis():
    n = len(jax.devices())
    if n % 2 != 0:
        pytest.skip("needs an even device count "
                    "(XLA_FLAGS=--xla_force_host_platform_device_count)")
    mesh = make_host_mesh(model=2)
    assert mesh.shape == {"data": n // 2, "model": 2}


def test_production_mesh_needs_real_pod():
    if len(jax.devices()) >= 256:
        pytest.skip("real pod attached")
    with pytest.raises(ValueError, match="use make_host_mesh"):
        make_production_mesh()
    with pytest.raises(ValueError, match="needs 512 devices"):
        make_production_mesh(multi_pod=True)
