"""Sharded serving stack: partitioned pools, banks, placement, the engine.

Unit-level coverage for ``repro/serving/sharded.py`` (global<->local id
translation, per-shard allocators behind one device view, adapter homing
and bank concatenation, round negotiation) plus the end-to-end contract
through the REAL jitted engine: ``ServeConfig.num_shards=2`` must emit
greedy token streams BITWISE equal to the single-pool path — across the
jnp and Pallas paged backends, speculative decoding, and warm prefix-cache
reuse — because sharding only re-partitions host bookkeeping around the
same fused dispatch.  The mesh integration test runs wherever >=2 devices
exist (CI forces them with
``XLA_FLAGS=--xla_force_host_platform_device_count=2``).

Randomized multi-chunk schedules (preemption, growth, oracle parity per
seed) live in ``test_serving_sim.py::run_sharded_sim``.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.serving.kv_cache import PagedKVCache
from repro.serving.sharded import (ShardedAdapterRegistry,
                                   ShardedPagedKVCache, ShardedScheduler)

VOCAB = 300


def _prompt(n, seed=0):
    return (np.arange(n, dtype=np.int32) * 3 + seed) % VOCAB


# ---------------------------------------------------------------------------
# ShardedPagedKVCache: geometry, translation, disjointness
# ---------------------------------------------------------------------------

def test_sharded_kv_geometry_validation():
    with pytest.raises(ValueError, match="num_shards"):
        ShardedPagedKVCache(0, 4, 4, 17, 4)
    with pytest.raises(ValueError, match="num_slots"):
        ShardedPagedKVCache(2, 3, 4, 17, 4)
    with pytest.raises(ValueError, match="allocatable blocks"):
        ShardedPagedKVCache(2, 4, 4, 18, 4)   # 17 allocatable, odd


def test_sharded_kv_slot_translation_roundtrip():
    kv = ShardedPagedKVCache(3, 6, 4, 1 + 3 * 4, 4)
    for g in range(6):
        s, local = kv.shard_of_slot(g)
        assert kv.global_slot(s, local) == g
        assert 0 <= s < 3 and 0 <= local < 2


def test_sharded_kv_device_tables_translate_into_disjoint_slices():
    """Each shard's table entries map into its own global block slice;
    block 0 stays the shared scratch id everywhere."""
    kv = ShardedPagedKVCache(2, 4, 4, 1 + 2 * 6, 4)
    for g in range(4):
        s, local = kv.shard_of_slot(g)
        kv.shards[s].admit(local, None, _prompt(4, g))
        kv.shards[s].ensure(local, 8)
    tables, lengths = kv.device_tables()
    tables = np.asarray(tables)
    assert tables.shape[0] == 4 and np.asarray(lengths).shape == (4,)
    kv.check_invariants()
    used = tables[tables > 0]
    assert used.size == 8                        # 2 blocks per slot
    assert len(set(used.tolist())) == used.size  # globally disjoint
    lo, hi = used[:4], used[4:]                  # shard 0 rows, shard 1 rows
    assert lo.max() <= 6 and hi.min() >= 7       # per-shard slices


def test_sharded_kv_aggregates_sum_over_shards():
    kv = ShardedPagedKVCache(2, 4, 4, 1 + 2 * 6, 4)
    assert kv.free_blocks == 12 and kv.allocatable_blocks == 12
    assert kv.idle
    kv.shards[0].admit(0, None, _prompt(4))
    kv.shards[0].ensure(0, 4)
    assert kv.free_blocks == 11 and not kv.idle
    assert kv.fits(4)


def test_best_prefix_shard_finds_the_sealing_shard():
    kv = ShardedPagedKVCache(2, 4, 4, 1 + 2 * 6, 6, prefix_cache=True)
    toks = _prompt(9)
    pool = kv.shards[1]
    pool.admit(0, "c0", toks)
    pool.ensure(0, 9)
    pool.advance(0, 9, tokens=toks)              # seals two full blocks
    pool.release(0)
    assert kv.best_prefix_shard("c0", toks) == (1, 8)
    assert kv.best_prefix_shard("other", toks) == (None, 0)


# ---------------------------------------------------------------------------
# ShardedAdapterRegistry: homing, global slots, bank concatenation
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def tiny_cfg_and_adapters():
    from conftest import tiny_dense
    from repro.core.lora import init_adapters

    cfg = tiny_dense()
    ads = {f"c{i}": init_adapters(jax.random.PRNGKey(i + 1), cfg)
           for i in range(5)}
    return cfg, ads


def test_sharded_registry_capacity_validation(tiny_cfg_and_adapters):
    cfg, _ = tiny_cfg_and_adapters
    with pytest.raises(ValueError, match="capacity"):
        ShardedAdapterRegistry(cfg, capacity=3, num_shards=2)
    with pytest.raises(ValueError, match="num_shards"):
        ShardedAdapterRegistry(cfg, capacity=4, num_shards=0)


def test_sharded_registry_homes_balance_and_global_slots(
        tiny_cfg_and_adapters):
    cfg, ads = tiny_cfg_and_adapters
    reg = ShardedAdapterRegistry(cfg, capacity=4, num_shards=2)
    slots = {c: reg.register(c, ads[c]) for c in ("c0", "c1", "c2", "c3")}
    # fewest-resident homing alternates shards; global slot = shard*2+local
    assert [reg.shard_of(f"c{i}") for i in range(4)] == [0, 1, 0, 1]
    assert sorted(slots.values()) == [0, 1, 2, 3]
    for c, slot in slots.items():
        assert reg.acquire(c) == slot
    assert len(reg) == 4 and "c0" in reg
    with pytest.raises(KeyError, match="not resident"):
        reg.acquire("stranger")


def test_sharded_registry_bank_matches_flat_registry(tiny_cfg_and_adapters):
    """The concatenated bank indexed at a client's GLOBAL slot holds the
    same adapter values a flat registry serves — layout is the only
    difference."""
    from repro.serving.registry import AdapterRegistry

    cfg, ads = tiny_cfg_and_adapters
    flat = AdapterRegistry(cfg, capacity=4)
    sharded = ShardedAdapterRegistry(cfg, capacity=4, num_shards=2)
    for c in ("c0", "c1", "c2", "c3"):
        flat.register(c, ads[c])
        sharded.register(c, ads[c])
    fb, sb = flat.bank(), sharded.bank()
    assert (jax.tree.leaves(sb)[0].shape[1]
            == jax.tree.leaves(fb)[0].shape[1] == 4)
    for c in ("c0", "c1", "c2", "c3"):
        fs, ss = flat.acquire(c), sharded.acquire(c)
        jax.tree.map(lambda a, b: np.testing.assert_array_equal(
            a[:, fs], b[:, ss]), fb, sb)


def test_sharded_registry_evicts_within_home_shard(tiny_cfg_and_adapters):
    cfg, ads = tiny_cfg_and_adapters
    reg = ShardedAdapterRegistry(cfg, capacity=4, num_shards=2)
    for c in ("c0", "c1", "c2", "c3"):
        reg.register(c, ads[c])
    # both shards full; c4 homes to shard 0 (tie, lowest index) and its
    # LRU client c0 is evicted THERE — shard 1 residents untouched
    slot = reg.register("c4", ads["c4"])
    assert reg.shard_of("c4") == 0 and slot in (0, 1)
    assert "c0" not in reg and reg.shard_of("c0") is None
    assert all(c in reg for c in ("c1", "c2", "c3", "c4"))
    assert reg.evictions == 1
    reg.evict("c4")
    assert "c4" not in reg and len(reg) == 3


# ---------------------------------------------------------------------------
# ShardedScheduler: round negotiation
# ---------------------------------------------------------------------------

def test_negotiated_decode_steps_is_min_over_shards():
    """A decode round's step count is the min over per-shard plans, so no
    slot on any shard can overshoot its budget inside a fused chunk."""
    kv = ShardedPagedKVCache(2, 2, 4, 17, 8)
    sched = ShardedScheduler(kv)
    sched.shards[0].submit(0, "a", _prompt(4), 10)   # plans a deep chunk
    sched.shards[1].submit(1, "b", _prompt(4), 2)    # nearly done
    sched.admit()
    plan = sched.prepare_chunk(8, 8)
    assert plan == ("prefill", None)                 # both still prefilling
    arrs = sched.prefill_arrays(8)
    sched.observe_prefill(arrs["n_new"], np.ones((2,), np.int32))
    plan = sched.prepare_chunk(8, 8)
    assert plan[0] == "decode"
    assert plan[1] == sched.shards[1].plan_steps(8) == 1


def test_mixed_readiness_forces_global_prefill_round():
    """One shard mid-prompt holds the OTHER (already decoding) shard in
    prefill-shaped rounds — its rows ride as 1-token feedback — until the
    prompt is fed; decoding still advances every round."""
    kv = ShardedPagedKVCache(2, 2, 4, 17, 8)
    sched = ShardedScheduler(kv)
    sched.shards[0].submit(0, "a", _prompt(12), 4)   # 3 prefill chunks of 4
    sched.shards[1].submit(1, "b", _prompt(2), 6)    # prefills in one
    sched.admit()
    rounds = []
    while sched.has_work:
        plan = sched.prepare_chunk(4, 4)
        rounds.append(plan[0])
        K = kv.num_slots
        if plan[0] == "prefill":
            arrs = sched.prefill_arrays(4)
            sched.observe_prefill(arrs["n_new"], np.ones((K,), np.int32))
        else:
            sched.chunk_arrays()
            sched.observe_chunk(np.ones((plan[1], K), np.int32))
    assert rounds[:3] == ["prefill"] * 3             # shard 0's prompt wins
    assert sched.results[0].size == 4 and sched.results[1].size == 6


# ---------------------------------------------------------------------------
# The real jitted engine: num_shards=2 is bitwise the single-pool stream
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def sharded_engine():
    from conftest import tiny_dense
    from repro.core.lora import init_adapters
    from repro.models.api import get_model
    from repro.serving.engine import MultiTenantEngine

    cfg = tiny_dense(dtype="float32", param_dtype="float32")
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    reg = ShardedAdapterRegistry(cfg, capacity=4, num_shards=2)
    for i in range(4):
        reg.register(f"c{i}", init_adapters(jax.random.PRNGKey(i + 1), cfg))
    return cfg, MultiTenantEngine(model, cfg, params, reg)


def _mixed_requests(cfg, n=8):
    from repro.serving.engine import Request
    rng = np.random.default_rng(11)
    reqs = [Request("c0", _prompt(12), max_new_tokens=6)]
    for i in range(n - 1):
        plen = int(rng.integers(2, 13))
        reqs.append(Request(f"c{i % 4}",
                            rng.integers(0, cfg.vocab_size, plen)
                            .astype(np.int32),
                            max_new_tokens=int(rng.integers(2, 7))))
    return reqs


def _sc(**kw):
    from repro.serving.engine import ServeConfig
    base = dict(batch_size=4, max_new_tokens=6, block_size=4,
                num_blocks=25, prefill_chunk=4)
    base.update(kw)
    return ServeConfig(**base)


def test_engine_two_shards_bitwise_equals_single_pool(sharded_engine):
    """The tentpole contract: sharding re-partitions host bookkeeping only,
    so greedy streams are bitwise identical at num_shards=1 and 2 — on the
    jnp backend, the Pallas kernels, and under speculative decoding."""
    cfg, mt = sharded_engine
    reqs = _mixed_requests(cfg)
    for extra in ({}, {"paged_backend": "pallas"}, {"spec_decode": True}):
        one = mt.generate(reqs, _sc(num_shards=1, **extra))
        two = mt.generate(reqs, _sc(num_shards=2, **extra))
        assert mt.last_stats["num_shards"] == 2
        for a, b in zip(one, two):
            np.testing.assert_array_equal(a, b)


def test_engine_sharded_reports_placements_and_uses_both_shards(
        sharded_engine):
    cfg, mt = sharded_engine
    mt.generate(_mixed_requests(cfg), _sc(num_shards=2))
    st = mt.last_stats
    assert st["num_shards"] == 2
    placed = st["shard_placements"]
    assert set(placed) == {"prefix", "adapter", "load"}
    # every client has a resident adapter -> affinity routing drove intake
    assert placed["adapter"] == 8 and placed["prefix"] == 0


def test_engine_sharded_warm_prefix_reuse_is_bitwise(sharded_engine):
    """Warm cross-call reuse through the sharded pool: the second call
    re-matches blocks sealed by the first (prefix placements appear) and
    stays bitwise equal to the cold stream."""
    cfg, mt = sharded_engine
    reqs = _mixed_requests(cfg, n=6)
    sc = _sc(num_shards=2, prefix_cache=True)
    mt.release_prefix_cache()
    cold = mt.generate(reqs, sc)
    warm = mt.generate(reqs, sc)
    assert mt.last_stats["prefix_pool_reused"]
    assert mt.last_stats["prefix_hit_tokens"] > 0
    assert mt.last_stats["shard_placements"]["prefix"] > 0
    for a, b in zip(cold, warm):
        np.testing.assert_array_equal(a, b)
    mt.release_prefix_cache()


def test_engine_sharded_geometry_validation(sharded_engine):
    cfg, mt = sharded_engine
    reqs = _mixed_requests(cfg, n=2)
    with pytest.raises(ValueError, match="num_shards"):
        mt.generate(reqs, _sc(num_shards=0))
    with pytest.raises(ValueError, match="batch_size"):
        mt.generate(reqs, _sc(batch_size=3, num_shards=2))
    with pytest.raises(ValueError, match="not divisible"):
        mt.generate(reqs, _sc(num_shards=2, num_blocks=24))


@pytest.mark.skipif(len(jax.devices()) < 2,
                    reason="needs >=2 devices (force with XLA_FLAGS="
                           "--xla_force_host_platform_device_count)")
def test_engine_sharded_under_host_mesh_is_bitwise(sharded_engine):
    """With a real 2-device host mesh entered around the dispatches, the
    batch axis lays slots over "data" shard-contiguously — and the stream
    stays bitwise equal to the meshless single-pool run."""
    from repro.launch.mesh import make_host_mesh

    cfg, mt = sharded_engine
    reqs = _mixed_requests(cfg)
    base = mt.generate(reqs, _sc(num_shards=1))
    mesh = make_host_mesh()
    meshed = mt.generate(reqs, _sc(num_shards=2, mesh=mesh))
    assert mt.last_stats["num_shards"] == 2
    for a, b in zip(base, meshed):
        np.testing.assert_array_equal(a, b)
