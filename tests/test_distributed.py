"""Distributed FDLoRA round step (single-device mesh execution) + roofline
extraction units."""
import jax
import jax.numpy as jnp
import numpy as np

from conftest import tiny_dense
from repro.analysis import roofline as rl
from repro.core.lora import init_adapters
from repro.core.outer_opt import make_outer_optimizer
from repro.federated.distributed import make_fdlora_round_step
from repro.models.api import get_model
from repro.training.optimizers import adamw


def test_fdlora_round_step_runs_and_aggregates():
    cfg = tiny_dense()
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    inner = adamw(lr=1e-3)
    outer = make_outer_optimizer("nesterov", lr=0.5, momentum=0.5)
    K, N, B, S = 2, 2, 2, 16
    round_step = make_fdlora_round_step(model, cfg, inner, outer, K)

    theta_s = init_adapters(jax.random.PRNGKey(1), cfg)
    state = {
        "inner_opt": jax.tree.map(
            lambda x: jnp.stack([x] * N), inner.init(theta_s)),
        "outer_opt": outer.init(theta_s),
    }
    batches = {
        "tokens": jax.random.randint(jax.random.PRNGKey(2), (N, K, B, S),
                                     0, cfg.vocab_size),
        "loss_mask": jnp.ones((N, K, B, S), jnp.int32),
    }
    theta_new, state2, loss = jax.jit(round_step)(params, theta_s, state, batches)
    assert bool(jnp.isfinite(loss))
    changed = any(not bool(jnp.allclose(a, b)) for a, b in
                  zip(jax.tree.leaves(theta_new), jax.tree.leaves(theta_s)))
    assert changed


def test_round_step_fedavg_equivalence():
    """With OuterOpt=SGD(lr=1) the round ends at the client mean."""
    cfg = tiny_dense()
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    inner = adamw(lr=1e-2)
    outer = make_outer_optimizer("fedavg")
    K, N, B, S = 1, 2, 2, 8
    round_step = make_fdlora_round_step(model, cfg, inner, outer, K)
    theta_s = init_adapters(jax.random.PRNGKey(1), cfg)
    state = {"inner_opt": jax.tree.map(lambda x: jnp.stack([x] * N),
                                       inner.init(theta_s)),
             "outer_opt": outer.init(theta_s)}
    batches = {"tokens": jax.random.randint(jax.random.PRNGKey(2),
                                            (N, K, B, S), 0, cfg.vocab_size),
               "loss_mask": jnp.ones((N, K, B, S), jnp.int32)}
    theta_new, _, _ = jax.jit(round_step)(params, theta_s, state, batches)
    # run the two clients by hand
    from repro.training.train_step import make_lora_train_step
    step = jax.jit(make_lora_train_step(model, cfg, inner))
    outs = []
    for i in range(N):
        st = inner.init(theta_s)
        ad = theta_s
        b = {"tokens": batches["tokens"][i, 0],
             "loss_mask": batches["loss_mask"][i, 0]}
        ad, st, _ = step(params, ad, st, b)
        outs.append(ad)
    from repro.core.lora import tree_mean
    expect = tree_mean(outs)
    for a, b in zip(jax.tree.leaves(theta_new), jax.tree.leaves(expect)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=2e-5, rtol=1e-4)


# ---------------------------------------------------------------------------
# Roofline units
# ---------------------------------------------------------------------------

def test_cost_analysis_is_per_device_and_scan_counts_once():
    """The two facts the dry-run methodology rests on (DESIGN/EXPERIMENTS)."""
    def f(x, w):
        def body(c, _):
            return c @ w, None
        y, _ = jax.lax.scan(body, x, None, length=4)
        return y

    from repro.launch.dryrun import cost_dict

    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    w = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    rolled = cost_dict(jax.jit(f).lower(x, w).compile())["flops"]
    unrolled = cost_dict(jax.jit(
        lambda x, w: x @ w @ w @ w @ w).lower(x, w).compile())["flops"]
    assert abs(unrolled - 4 * rolled) / unrolled < 0.05


def test_roofline_terms_and_dominance():
    cost = {"flops": 197e12, "bytes accessed": 819e9 * 2}
    roof = rl.analyze(cost, "", chips=4, model_flops=197e12 * 4)
    assert abs(roof.compute_s - 1.0) < 1e-6
    assert abs(roof.memory_s - 2.0) < 1e-6
    assert roof.dominant == "memory"
    assert abs(roof.useful_ratio - 1.0) < 1e-6


def test_collective_factors():
    hlo = """
  %ar = bf16[1024]{0} all-reduce(%a), replica_groups={{0,1,2,3}}
  %ag = bf16[1024]{0} all-gather(%b), replica_groups=[2,4]
  %rs = bf16[256]{0} reduce-scatter(%c), replica_groups={{0,1,2,3}}
"""
    colls = rl.parse_collectives(hlo)
    by = {c.op: c for c in colls}
    assert by["all-reduce"].per_chip_bytes == 2 * 2048 * 3 / 4
    assert by["all-gather"].per_chip_bytes == 2048 * 3 / 4
    assert by["reduce-scatter"].per_chip_bytes == 512 * 3
