"""Per-kernel validation: shape/dtype sweeps vs the pure-jnp oracles
(interpret=True executes the kernel body on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.dual_lora import dual_lora_matmul
from repro.kernels.flash_attention import flash_attention
from repro.kernels.lora_matmul import lora_matmul
from repro.kernels.ops import fused_dual_lora_dense, gqa_flash_attention, lora_dense
from repro.kernels.ref import (dual_lora_matmul_ref, flash_attention_ref,
                               lora_matmul_ref)

RNG = np.random.default_rng(42)


def _tol(dtype):
    return 0.08 if dtype == jnp.bfloat16 else 2e-4


def _rand(shape, dtype, scale=1.0):
    return jnp.asarray(RNG.standard_normal(shape) * scale, dtype)


@pytest.mark.parametrize("M,K,N,r", [(256, 256, 256, 8), (512, 256, 256, 16),
                                     (256, 512, 384, 64), (384, 768, 256, 128)])
@pytest.mark.parametrize("dtype", [jnp.bfloat16, jnp.float32])
def test_lora_matmul_sweep(M, K, N, r, dtype):
    x = _rand((M, K), dtype)
    w = _rand((K, N), dtype, 0.05)
    a = _rand((K, r), jnp.float32, 0.05)
    b = _rand((r, N), jnp.float32, 0.05)
    y = lora_matmul(x, w, a, b, scale=2.0, bm=128, bn=128, bk=128)
    yr = lora_matmul_ref(x, w, a, b, 2.0)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32),
                               atol=_tol(dtype) * max(1, K // 256), rtol=0.05)


def test_lora_matmul_zero_adapter_equals_base():
    x = _rand((256, 256), jnp.bfloat16)
    w = _rand((256, 256), jnp.bfloat16, 0.05)
    a = jnp.zeros((256, 8), jnp.float32)
    b = jnp.zeros((8, 256), jnp.float32)
    y = lora_matmul(x, w, a, b, scale=7.0, bm=128, bn=128, bk=128)
    base = jnp.matmul(x, w, preferred_element_type=jnp.float32).astype(x.dtype)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(base, np.float32), atol=1e-2)


@pytest.mark.parametrize("r", [4, 8, 32])
def test_dual_lora_matches_ref_and_eq7(r):
    M = K = N = 256
    x = _rand((M, K), jnp.bfloat16)
    w = _rand((K, N), jnp.bfloat16, 0.05)
    a1, a2 = _rand((K, r), jnp.float32, 0.05), _rand((K, r), jnp.float32, 0.05)
    b1, b2 = _rand((r, N), jnp.float32, 0.05), _rand((r, N), jnp.float32, 0.05)
    fw = jnp.array([0.8, 0.3], jnp.float32)
    y = dual_lora_matmul(x, w, a1, b1, a2, b2, fw, scale=2.0,
                         bm=128, bn=128, bk=128)
    yr = dual_lora_matmul_ref(x, w, a1, b1, a2, b2, fw[0], fw[1], 2.0)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32), atol=0.08, rtol=0.05)


def test_dual_lora_reduces_to_single_when_w2_zero():
    M = K = N = 256
    r = 8
    x = _rand((M, K), jnp.bfloat16)
    w = _rand((K, N), jnp.bfloat16, 0.05)
    a1, b1 = _rand((K, r), jnp.float32, 0.05), _rand((r, N), jnp.float32, 0.05)
    a2, b2 = _rand((K, r), jnp.float32, 0.05), _rand((r, N), jnp.float32, 0.05)
    fw = jnp.array([1.0, 0.0], jnp.float32)
    y = dual_lora_matmul(x, w, a1, b1, a2, b2, fw, scale=2.0,
                         bm=128, bn=128, bk=128)
    ys = lora_matmul(x, w, a1, b1, scale=2.0, bm=128, bn=128, bk=128)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(ys, np.float32), atol=0.05)


@pytest.mark.parametrize("B,H,Sq,Sk,d", [(2, 2, 256, 256, 64),
                                         (1, 4, 128, 512, 64),
                                         (2, 1, 256, 256, 128)])
@pytest.mark.parametrize("causal,window", [(True, 0), (True, 64), (False, 0)])
def test_flash_attention_sweep(B, H, Sq, Sk, d, causal, window):
    if not causal and Sq != Sk:
        pytest.skip("non-causal decode alignment not used")
    q = _rand((B, H, Sq, d), jnp.bfloat16)
    k = _rand((B, H, Sk, d), jnp.bfloat16)
    v = _rand((B, H, Sk, d), jnp.bfloat16)
    o = flash_attention(q, k, v, causal=causal, sliding_window=window)
    orf = flash_attention_ref(q, k, v, causal=causal, sliding_window=window)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(orf, np.float32), atol=0.03)


def test_flash_attention_fp32():
    q = _rand((1, 2, 128, 64), jnp.float32)
    k = _rand((1, 2, 128, 64), jnp.float32)
    v = _rand((1, 2, 128, 64), jnp.float32)
    o = flash_attention(q, k, v, causal=True)
    orf = flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(o), np.asarray(orf), atol=2e-4)


def test_ops_lora_dense_padding():
    """Wrapper pads non-tile shapes (odd M/K/N, rank 4)."""
    x = _rand((2, 10, 200), jnp.bfloat16)  # M=20 -> pad
    w = _rand((200, 300), jnp.bfloat16, 0.05)
    ad = {"a": _rand((200, 4), jnp.float32, 0.05),
          "b": _rand((4, 300), jnp.float32, 0.05)}
    y = lora_dense(x, w, ad, scale=2.0, block=128)
    yr = lora_matmul_ref(x.reshape(20, 200), w, ad["a"], ad["b"], 2.0
                         ).reshape(2, 10, 300)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32), atol=0.08, rtol=0.05)


def test_ops_gqa_flash_matches_model_layer():
    """The kernel path reproduces layers.multihead_attention core math."""
    B, S, H, Kv, d = 1, 128, 4, 2, 64
    q = _rand((B, S, H, d), jnp.bfloat16)
    k = _rand((B, S, Kv, d), jnp.bfloat16)
    v = _rand((B, S, Kv, d), jnp.bfloat16)
    o = gqa_flash_attention(q, k, v, causal=True)
    # oracle via repeat + ref
    rep = H // Kv
    kr = jnp.repeat(k.transpose(0, 2, 1, 3), rep, axis=1)
    vr = jnp.repeat(v.transpose(0, 2, 1, 3), rep, axis=1)
    orf = flash_attention_ref(q.transpose(0, 2, 1, 3), kr, vr, causal=True)
    np.testing.assert_allclose(np.asarray(o.transpose(0, 2, 1, 3), np.float32),
                               np.asarray(orf, np.float32), atol=0.03)
