"""The six paper baselines: each runs end-to-end on tiny data and respects
its communication contract."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import tiny_dense
from repro.data.pipeline import SFTBatcher
from repro.data.synthetic import gen_log_dataset
from repro.data.tokenizer import ByteTokenizer
from repro.federated.baselines import BASELINES, FedConfig, concat_rank
from repro.core.lora import init_adapters
from repro.models.api import get_model


@pytest.fixture(scope="module")
def setup():
    cfg = tiny_dense(vocab_size=300)
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(1)
    tok = ByteTokenizer()
    batchers = [SFTBatcher(gen_log_dataset(rng, 16, i), tok, 64, 4, seed=i)
                for i in range(2)]
    return cfg, model, params, batchers


@pytest.mark.parametrize("name", sorted(BASELINES))
def test_baseline_runs(name, setup):
    cfg, model, params, batchers = setup
    fed = FedConfig(n_clients=2, rounds=2, local_steps=1)
    b = BASELINES[name](model, cfg, fed, params)
    ads = b.fit(batchers)
    assert len(ads) == 2
    for ad in ads:
        assert all(bool(jnp.isfinite(l).all()) for l in jax.tree.leaves(ad))
    if name == "local":
        assert b.comm_bytes == 0.0
    else:
        assert b.comm_bytes > 0


def test_fedavg_clients_share_model(setup):
    cfg, model, params, batchers = setup
    fed = FedConfig(n_clients=2, rounds=1, local_steps=1)
    ads = BASELINES["fedavg"](model, cfg, fed, params).fit(batchers)
    for a, b in zip(jax.tree.leaves(ads[0]), jax.tree.leaves(ads[1])):
        assert jnp.allclose(a, b)


def test_local_clients_differ(setup):
    cfg, model, params, batchers = setup
    fed = FedConfig(n_clients=2, rounds=1, local_steps=2)
    ads = BASELINES["local"](model, cfg, fed, params).fit(batchers)
    same = all(bool(jnp.allclose(a, b)) for a, b in
               zip(jax.tree.leaves(ads[0]), jax.tree.leaves(ads[1])))
    assert not same


def test_fedkd_communicates_less_than_fedavg(setup):
    """FedKD ships only the rank-r/2 student: bytes must be < FedAvg's."""
    cfg, model, params, batchers = setup
    fed = FedConfig(n_clients=2, rounds=2, local_steps=1)
    avg = BASELINES["fedavg"](model, cfg, fed, params)
    avg.fit(batchers)
    kd = BASELINES["fedkd"](model, cfg, fed, params)
    kd.fit(batchers)
    assert kd.comm_bytes < avg.comm_bytes


def test_concat_rank_is_exact_sum():
    """(A1|A2)(B1;B2) == A1B1 + A2B2 — the FedRoD/FedKD composition."""
    cfg = tiny_dense()
    g = init_adapters(jax.random.PRNGKey(3), cfg)
    p = init_adapters(jax.random.PRNGKey(4), cfg)
    # give B factors nonzero values
    g = jax.tree.map(lambda x: x + 0.1, g)
    p = jax.tree.map(lambda x: x + 0.2, p)
    cat = concat_rank(g, p)

    def leafpaths(t, pref=()):
        if isinstance(t, dict) and set(t.keys()) == {"a", "b"}:
            yield pref, t
        elif isinstance(t, dict):
            for k, v in t.items():
                yield from leafpaths(v, pref + (k,))

    for (path, gl), (_, pl), (_, cl) in zip(leafpaths(g), leafpaths(p),
                                            leafpaths(cat)):
        direct = (jnp.einsum("lkr,lrn->lkn", gl["a"], gl["b"])
                  + jnp.einsum("lkr,lrn->lkn", pl["a"], pl["b"]))
        via_cat = jnp.einsum("lkr,lrn->lkn", cl["a"], cl["b"])
        np.testing.assert_allclose(np.asarray(via_cat), np.asarray(direct),
                                   atol=1e-5)
