"""Shared fixtures: tiny configs + models for CPU-speed tests.

NOTE: no XLA_FLAGS here — tests must see the real (single) CPU device; only
repro.launch.dryrun sets the 512-device placeholder count.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ModelConfig
from repro.models.api import get_model


def tiny_dense(**kw):
    base = dict(name="tiny", family="dense", n_layers=2, d_model=64,
                n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=300,
                max_seq_len=64, lora_rank=4, remat=False)
    base.update(kw)
    return ModelConfig(**base)


def tiny_moe(**kw):
    return tiny_dense(family="moe", layer_pattern=("attn+moe",),
                      n_experts=4, n_experts_per_tok=2, d_ff_moe=96, **kw)


def tiny_ssm(**kw):
    return tiny_dense(family="ssm", layer_pattern=("mamba+none",), d_ff=0,
                      n_heads=1, n_kv_heads=1, ssm_d_state=16,
                      ssm_head_dim=16, ssm_chunk=8, use_rope=False, **kw)


@pytest.fixture(scope="session")
def dense_cfg():
    return tiny_dense()


@pytest.fixture(scope="session")
def dense_model(dense_cfg):
    m = get_model(dense_cfg)
    p = m.init(jax.random.PRNGKey(0))
    return m, p


@pytest.fixture()
def rng():
    return np.random.default_rng(0)


def rand_batch(cfg, B=2, S=16, seed=3):
    k = jax.random.PRNGKey(seed)
    toks = jax.random.randint(k, (B, S), 0, cfg.vocab_size)
    return {"tokens": toks, "loss_mask": jnp.ones((B, S), jnp.int32)}
