"""Multi-tenant serving: batched-LoRA kernel parity, adapter registry,
mixed-client engine regression vs single-tenant generation."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import tiny_dense
from repro.core.dual_lora import merge
from repro.core.lora import init_adapters, lora_scale
from repro.kernels.batched_lora import (batched_dual_lora_matmul,
                                        batched_lora_matmul)
from repro.kernels.lora_matmul import lora_matmul
from repro.kernels.ops import batched_lora_dense
from repro.kernels.ref import (batched_dual_lora_matmul_ref,
                               batched_lora_matmul_ref)
from repro.models.api import get_model
from repro.models.layers import lora_delta
from repro.serving.engine import (Engine, MultiTenantEngine, Request,
                                  ServeConfig)
from repro.serving.registry import AdapterRegistry

RNG = np.random.default_rng(7)


def _rand(shape, dtype, scale=1.0):
    return jnp.asarray(RNG.standard_normal(shape) * scale, dtype)


def _tol(dtype):
    return 0.08 if dtype == jnp.bfloat16 else 2e-4


# ---------------------------------------------------------------------------
# Kernel parity vs the jnp oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("r", [8, 16])
@pytest.mark.parametrize("dtype", [jnp.bfloat16, jnp.float32])
def test_batched_lora_matches_ref(r, dtype):
    M, K, N, C = 256, 256, 384, 5
    x = _rand((M, K), dtype)
    w = _rand((K, N), dtype, 0.05)
    a = _rand((C, K, r), jnp.float32, 0.05)
    b = _rand((C, r, N), jnp.float32, 0.05)
    g = jnp.asarray(RNG.integers(0, C, M), jnp.int32)  # non-uniform ids
    y = batched_lora_matmul(x, w, a, b, g, 2.0, bm=128, bn=128, bk=128)
    yr = batched_lora_matmul_ref(x, w, a, b, g, 2.0)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32),
                               atol=_tol(dtype), rtol=0.05)


def test_batched_uniform_ids_equals_single_lora():
    """Every row routed to slot c == the single-adapter kernel on bank[c]."""
    M = K = N = 256
    r, C = 8, 3
    x = _rand((M, K), jnp.bfloat16)
    w = _rand((K, N), jnp.bfloat16, 0.05)
    a = _rand((C, K, r), jnp.float32, 0.05)
    b = _rand((C, r, N), jnp.float32, 0.05)
    for c in range(C):
        g = jnp.full((M,), c, jnp.int32)
        y = batched_lora_matmul(x, w, a, b, g, 2.0, bm=128, bn=128, bk=128)
        ys = lora_matmul(x, w, a[c], b[c], scale=2.0, bm=128, bn=128, bk=128)
        np.testing.assert_allclose(np.asarray(y, np.float32),
                                   np.asarray(ys, np.float32), atol=0.05)


@pytest.mark.parametrize("r", [8, 16])
def test_batched_dual_lora_per_row_fusion_weights(r):
    """Eq. 7 merged on-chip per request: banked personalized + shared
    global, every row with its own (w1, w2)."""
    M, K, N, C = 256, 256, 256, 4
    x = _rand((M, K), jnp.bfloat16)
    w = _rand((K, N), jnp.bfloat16, 0.05)
    a1 = _rand((C, K, r), jnp.float32, 0.05)
    b1 = _rand((C, r, N), jnp.float32, 0.05)
    a2 = _rand((K, r), jnp.float32, 0.05)
    b2 = _rand((r, N), jnp.float32, 0.05)
    g = jnp.asarray(RNG.integers(0, C, M), jnp.int32)
    fw = jnp.asarray(RNG.uniform(-0.2, 1.2, (M, 2)), jnp.float32)
    y = batched_dual_lora_matmul(x, w, a1, b1, a2, b2, g, fw, 2.0,
                                 bm=128, bn=128, bk=128)
    yr = batched_dual_lora_matmul_ref(x, w, a1, b1, a2, b2, g, fw, 2.0)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32), atol=0.08,
                               rtol=0.05)


def test_batched_dual_row_reduces_to_merged_single():
    """A row with fusion weights (w1, w2) equals the pre-merged (Eq. 7)
    adapter served through the plain batched kernel."""
    M = K = N = 128
    r, C = 8, 2
    x = _rand((M, K), jnp.bfloat16)
    w = _rand((K, N), jnp.bfloat16, 0.05)
    a1 = _rand((C, K, r), jnp.float32, 0.05)
    b1 = _rand((C, r, N), jnp.float32, 0.05)
    a2 = _rand((K, r), jnp.float32, 0.05)
    b2 = _rand((r, N), jnp.float32, 0.05)
    g = jnp.zeros((M,), jnp.int32)
    w1, w2 = 0.7, 0.4
    fw = jnp.tile(jnp.array([[w1, w2]], jnp.float32), (M, 1))
    y = batched_dual_lora_matmul(x, w, a1, b1, a2, b2, g, fw, 2.0,
                                 bm=128, bn=128, bk=128)
    am = (w1 * a1[0] + w2 * a2)[None]
    bm_ = (w1 * b1[0] + w2 * b2)[None]
    ym = batched_lora_matmul(x, w, am, bm_, g, 2.0, bm=128, bn=128, bk=128)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(ym, np.float32), atol=0.06)


def test_ops_batched_lora_dense_padding():
    """Wrapper pads non-tile shapes and broadcasts (B,) ids over S."""
    B, S, K, N, r, C = 3, 10, 200, 300, 4, 5
    x = _rand((B, S, K), jnp.bfloat16)
    w = _rand((K, N), jnp.bfloat16, 0.05)
    bank = {"a": _rand((C, K, r), jnp.float32, 0.05),
            "b": _rand((C, r, N), jnp.float32, 0.05)}
    ids = jnp.asarray([1, 4, 2], jnp.int32)
    y = batched_lora_dense(x, w, bank, ids, 2.0, block=128)
    g = jnp.repeat(ids, S)
    yr = batched_lora_matmul_ref(x.reshape(B * S, K), w, bank["a"], bank["b"],
                                 g, 2.0).reshape(B, S, N)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32), atol=0.08,
                               rtol=0.05)


# ---------------------------------------------------------------------------
# layers.lora_delta banked path (the model-side oracle)
# ---------------------------------------------------------------------------

def test_lora_delta_banked_matches_per_row():
    B, S, K, N, r, C = 4, 6, 32, 24, 4, 3
    x = _rand((B, S, K), jnp.float32)
    a = _rand((C, K, r), jnp.float32)
    b = _rand((C, r, N), jnp.float32)
    ids = jnp.asarray([2, 0, 1, 2], jnp.int32)
    z = lora_delta(x, a, b, ids)
    for i in range(B):
        zi = lora_delta(x[i:i + 1], a[int(ids[i])], b[int(ids[i])])
        np.testing.assert_allclose(np.asarray(z[i]), np.asarray(zi[0]),
                                   rtol=1e-5, atol=1e-5)


def test_lora_delta_banked_requires_ids():
    with pytest.raises(ValueError):
        lora_delta(_rand((2, 3, 8), jnp.float32),
                   _rand((2, 8, 4), jnp.float32),
                   _rand((2, 4, 8), jnp.float32))


# ---------------------------------------------------------------------------
# AdapterRegistry
# ---------------------------------------------------------------------------

def _cfg():
    return tiny_dense()


def test_registry_register_acquire_roundtrip():
    cfg = _cfg()
    reg = AdapterRegistry(cfg, capacity=3)
    ad = init_adapters(jax.random.PRNGKey(1), cfg)
    slot = reg.register("alice", ad)
    assert reg.acquire("alice") == slot
    assert "alice" in reg and len(reg) == 1
    # bank slot holds exactly the registered tree
    bank = reg.bank()
    leaf = jax.tree.leaves(ad)[0]
    bank_leaf = jax.tree.leaves(bank)[0]
    np.testing.assert_allclose(np.asarray(bank_leaf[:, slot]),
                               np.asarray(leaf))
    with pytest.raises(KeyError):
        reg.acquire("nobody")


def test_registry_lru_eviction_order():
    cfg = _cfg()
    reg = AdapterRegistry(cfg, capacity=2)
    ad = init_adapters(jax.random.PRNGKey(1), cfg)
    reg.register("a", ad)
    reg.register("b", ad)
    reg.acquire("a")              # 'a' now most-recent; LRU is 'b'
    reg.register("c", ad)         # evicts 'b'
    assert "b" not in reg and "a" in reg and "c" in reg
    assert reg.evictions == 1
    # re-register refreshes in place, no eviction
    reg.register("a", ad)
    assert reg.evictions == 1 and len(reg) == 2


def test_registry_capacity_one_eviction():
    """Capacity 1: every registration of a new client evicts the resident
    one and reuses the single bank slot."""
    cfg = _cfg()
    reg = AdapterRegistry(cfg, capacity=1)
    ad = init_adapters(jax.random.PRNGKey(1), cfg)
    s_a = reg.register("a", ad)
    s_b = reg.register("b", ad)
    assert s_a == s_b == 0                  # the one slot is recycled
    assert "a" not in reg and "b" in reg and len(reg) == 1
    assert reg.evictions == 1
    with pytest.raises(KeyError):
        reg.acquire("a")


def test_registry_reregister_refreshes_recency_no_duplicate():
    """Re-registering a resident client updates its slot in place (no second
    bank slot) and bumps it to most-recent, changing who gets evicted."""
    cfg = _cfg()
    reg = AdapterRegistry(cfg, capacity=2)
    ad1 = init_adapters(jax.random.PRNGKey(1), cfg)
    ad2 = init_adapters(jax.random.PRNGKey(2), cfg)
    s_a = reg.register("a", ad1)
    reg.register("b", ad1)
    assert reg.register("a", ad2) == s_a and len(reg) == 2   # refreshed, not dup
    np.testing.assert_allclose(                               # new weights live
        np.asarray(jax.tree.leaves(reg.bank())[0][:, s_a]),
        np.asarray(jax.tree.leaves(ad2)[0]))
    assert reg.resident == ["b", "a"]        # 'a' now most-recent
    reg.register("c", ad1)                   # evicts 'b', NOT the refreshed 'a'
    assert "a" in reg and "b" not in reg and reg.evictions == 1


def test_registry_register_dual_is_eq7_merge():
    cfg = _cfg()
    reg = AdapterRegistry(cfg, capacity=1)
    p = init_adapters(jax.random.PRNGKey(2), cfg)
    s = init_adapters(jax.random.PRNGKey(3), cfg)
    fw = jnp.array([0.7, 0.4], jnp.float32)
    slot = reg.register_dual("c", p, s, fw)
    fused = merge(p, s, fw)
    np.testing.assert_allclose(
        np.asarray(jax.tree.leaves(reg.bank())[0][:, slot]),
        np.asarray(jax.tree.leaves(fused)[0]), rtol=1e-6)


# ---------------------------------------------------------------------------
# Engine regression: mixed-client batch == per-client single-tenant output
# ---------------------------------------------------------------------------

def _client_adapters(cfg, seed):
    ad = init_adapters(jax.random.PRNGKey(seed), cfg)
    bump = jax.random.PRNGKey(seed + 99)
    return jax.tree.map(
        lambda l: l + 0.02 * jax.random.normal(bump, l.shape), ad)


def test_mixed_batch_matches_single_tenant_greedy():
    cfg = tiny_dense()
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    ads = {"c0": _client_adapters(cfg, 1), "c1": _client_adapters(cfg, 2)}
    prompt = np.arange(8, dtype=np.int32) % cfg.vocab_size
    sc = ServeConfig(batch_size=1, max_new_tokens=8, cache_len=32)

    reg = AdapterRegistry(cfg, capacity=4)
    for cid, ad in ads.items():
        reg.register(cid, ad)
    mt = MultiTenantEngine(model, cfg, params, reg)
    order = ["c1", "c0", "c1", "c0"]          # interleaved two-client batch
    out_mt = np.asarray(mt.generate_fixed([Request(c, prompt) for c in order],
                                          sc))

    singles = {cid: np.asarray(
        Engine(model, cfg, params, ad).generate(jnp.asarray(prompt)[None],
                                                sc))[0]
        for cid, ad in ads.items()}
    assert (singles["c0"] != singles["c1"]).any(), "clients must differ"
    for i, cid in enumerate(order):
        np.testing.assert_array_equal(out_mt[i], singles[cid])


def test_unregistered_slot_serves_base_model():
    """A zeroed bank slot is a no-op adapter: identical to no adapters."""
    cfg = tiny_dense()
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompt = np.arange(8, dtype=np.int32) % cfg.vocab_size
    sc = ServeConfig(batch_size=1, max_new_tokens=6, cache_len=32)
    reg = AdapterRegistry(cfg, capacity=2)
    reg.register("zero", jax.tree.map(jnp.zeros_like,
                                      init_adapters(jax.random.PRNGKey(5),
                                                    cfg)))
    mt = MultiTenantEngine(model, cfg, params, reg)
    out = np.asarray(mt.generate_fixed([Request("zero", prompt)], sc))[0]
    base = np.asarray(Engine(model, cfg, params, None).generate(
        jnp.asarray(prompt)[None], sc))[0]
    np.testing.assert_array_equal(out, base)
