"""The CI shard partition must be exhaustive, disjoint and stable —
a bug here silently drops test files from the PR critical path."""
import os
import subprocess
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(REPO, "scripts"))

from ci_shard import DEFAULT_WEIGHT, WEIGHTS, discover, partition  # noqa: E402


def test_partition_is_exhaustive_and_disjoint():
    files = discover(REPO)
    assert os.path.join("tests", "test_ci_shard.py") in files
    for n in (2, 3):
        shards = partition(files, n)
        flat = [f for s in shards for f in s]
        assert sorted(flat) == sorted(files), "file dropped or duplicated"
        assert len(set(flat)) == len(flat)


def test_partition_is_stable_and_balanced():
    files = discover(REPO)
    a = partition(files, 2)
    b = partition(list(reversed(files)), 2)        # input order irrelevant
    assert a == b
    loads = [sum(WEIGHTS.get(f, DEFAULT_WEIGHT) for f in s) for s in a]
    total = sum(loads)
    # LPT with one dominant file can't do better than that file's weight;
    # both shards must still carry real work
    assert min(loads) > 0.2 * total, f"degenerate split: {loads}"


def test_cli_outputs_each_file_exactly_once():
    out = []
    for shard in (0, 1):
        r = subprocess.run(
            [sys.executable, os.path.join(REPO, "scripts", "ci_shard.py"),
             "--num-shards", "2", "--shard", str(shard), "--root", REPO],
            capture_output=True, text=True, check=True)
        out.extend(r.stdout.split())
    assert sorted(out) == sorted(discover(REPO))
