"""Ragged-rank adapter banks: per-slot effective-rank masking in the
batched kernel, bucketed registry layout, and mixed-rank engine parity
against per-client native-rank dense-LoRA oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import tiny_dense
from repro.core.lora import init_adapters
from repro.kernels.batched_lora import batched_lora_matmul
from repro.kernels.ops import batched_lora_dense
from repro.kernels.quant import quantize_int8
from repro.kernels.ref import batched_lora_matmul_ref
from repro.models.api import get_model
from repro.models.layers import lora_delta
from repro.serving.engine import (Engine, MultiTenantEngine, Request,
                                  ServeConfig)
from repro.serving.registry import AdapterRegistry, _zip_banks
from repro.serving.sharded import ShardedAdapterRegistry

RNG = np.random.default_rng(13)


def _rand(shape, dtype, scale=1.0):
    return jnp.asarray(RNG.standard_normal(shape) * scale, dtype)


# ---------------------------------------------------------------------------
# Kernel: the per-slot rank mask makes padded rank columns exact zeros
# ---------------------------------------------------------------------------

def _ragged_bank(C, K, N, r_max, ranks, garbage=False):
    """A padded-to-r_max bank whose slot c only uses ranks[c] columns.
    With ``garbage`` the padded columns hold large non-zero junk — the
    kernel's rank mask (not zero padding) must neutralise them."""
    a = np.asarray(_rand((C, K, r_max), jnp.float32, 0.05))
    b = np.asarray(_rand((C, r_max, N), jnp.float32, 0.05))
    col = np.arange(r_max)
    pad_a = col[None, None, :] >= np.asarray(ranks)[:, None, None]
    pad_b = col[None, :, None] >= np.asarray(ranks)[:, None, None]
    fill = (99.0, -77.0) if garbage else (0.0, 0.0)
    a = np.where(pad_a, fill[0], a)
    b = np.where(pad_b, fill[1], b)
    return jnp.asarray(a), jnp.asarray(b)


def test_kernel_rank_mask_zeroes_padded_columns():
    """The kernel with ``ranks`` must ignore padded rank columns even when
    they hold garbage: bitwise equal to the kernel on the zero-padded bank,
    and exactly equal to the truncated per-slot dense reference."""
    M = K = N = 128
    C, r_max = 4, 8
    ranks = [2, 4, 8, 3]
    x = _rand((M, K), jnp.float32)
    w = _rand((K, N), jnp.float32, 0.05)
    # identical live columns, different padding content
    RNG2 = np.random.default_rng(21)
    a_live = RNG2.standard_normal((C, K, r_max)) * 0.05
    b_live = RNG2.standard_normal((C, r_max, N)) * 0.05
    col = np.arange(r_max)
    pad_a = col[None, None, :] >= np.asarray(ranks)[:, None, None]
    pad_b = col[None, :, None] >= np.asarray(ranks)[:, None, None]
    a_clean = jnp.asarray(np.where(pad_a, 0.0, a_live), jnp.float32)
    b_clean = jnp.asarray(np.where(pad_b, 0.0, b_live), jnp.float32)
    a_junk = jnp.asarray(np.where(pad_a, 99.0, a_live), jnp.float32)
    b_junk = jnp.asarray(np.where(pad_b, -77.0, b_live), jnp.float32)
    g = jnp.asarray(RNG.integers(0, C, M), jnp.int32)
    rk = jnp.asarray(ranks, jnp.int32)
    kw = dict(bm=128, bn=128, bk=128)
    y_junk = batched_lora_matmul(x, w, a_junk, b_junk, g, 2.0, ranks=rk, **kw)
    y_clean = batched_lora_matmul(x, w, a_clean, b_clean, g, 2.0, ranks=rk,
                                  **kw)
    np.testing.assert_array_equal(np.asarray(y_junk), np.asarray(y_clean))
    # ranked ref on the junk bank == truncated-factor dense oracle (per-row
    # matmuls contract in a different order than the batched einsum, so the
    # comparison is tight-tolerance, not bitwise)
    yr = batched_lora_matmul_ref(x, w, a_junk, b_junk, g, 2.0, ranks=rk)
    y_trunc = jnp.stack([
        x[i] @ w + 2.0 * (x[i] @ a_clean[c, :, :ranks[c]])
        @ b_clean[c, :ranks[c], :]
        for i, c in enumerate(np.asarray(g))])
    np.testing.assert_allclose(np.asarray(yr), np.asarray(y_trunc),
                               atol=1e-5, rtol=1e-5)
    # ...but the ranked ref must be BITWISE immune to padding content
    yr_clean = batched_lora_matmul_ref(x, w, a_clean, b_clean, g, 2.0,
                                       ranks=rk)
    np.testing.assert_array_equal(np.asarray(yr), np.asarray(yr_clean))
    np.testing.assert_allclose(np.asarray(y_junk), np.asarray(yr),
                               atol=2e-4, rtol=0.05)


def test_kernel_without_ranks_unchanged():
    """ranks=None keeps the legacy kernel path bitwise intact."""
    M = K = N = 128
    C, r = 3, 8
    x = _rand((M, K), jnp.bfloat16)
    w = _rand((K, N), jnp.bfloat16, 0.05)
    a = _rand((C, K, r), jnp.float32, 0.05)
    b = _rand((C, r, N), jnp.float32, 0.05)
    g = jnp.asarray(RNG.integers(0, C, M), jnp.int32)
    y = batched_lora_matmul(x, w, a, b, g, 2.0, bm=128, bn=128, bk=128)
    y_full = batched_lora_matmul(x, w, a, b, g, 2.0,
                                 ranks=jnp.full((C,), r, jnp.int32),
                                 bm=128, bn=128, bk=128)
    np.testing.assert_array_equal(np.asarray(y, np.float32),
                                  np.asarray(y_full, np.float32))


# ---------------------------------------------------------------------------
# ops.batched_lora_dense: list-leaf (per-bucket) banks
# ---------------------------------------------------------------------------

def test_ops_list_bank_matches_concat_ref():
    B, S, K, N = 4, 6, 200, 300
    bucket_ranks = [2, 4, 8]
    sizes = [2, 1, 2]                       # 5 global slots
    bank = {"a": [_rand((c, K, r), jnp.float32, 0.05)
                  for c, r in zip(sizes, bucket_ranks)],
            "b": [_rand((c, r, N), jnp.float32, 0.05)
                  for c, r in zip(sizes, bucket_ranks)]}
    x = _rand((B, S, K), jnp.bfloat16)
    w = _rand((K, N), jnp.bfloat16, 0.05)
    ids = jnp.asarray([0, 2, 4, 3], jnp.int32)   # one slot per bucket + more
    y = batched_lora_dense(x, w, bank, ids, 2.0, block=128)
    # reference: zero-pad buckets to r_max, concat, mask by effective rank
    r_max = max(bucket_ranks)
    a_all = jnp.concatenate(
        [jnp.pad(ab, ((0, 0), (0, 0), (0, r_max - ab.shape[-1])))
         for ab in bank["a"]])
    b_all = jnp.concatenate(
        [jnp.pad(bb, ((0, 0), (0, r_max - bb.shape[1]), (0, 0)))
         for bb in bank["b"]])
    rk = jnp.asarray(sum(([r] * c for c, r in zip(sizes, bucket_ranks)), []),
                     jnp.int32)
    g = jnp.repeat(ids, S)
    yr = batched_lora_matmul_ref(x.reshape(B * S, K), w, a_all, b_all, g,
                                 2.0, ranks=rk).reshape(B, S, N)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32), atol=0.08,
                               rtol=0.05)


def test_ops_list_bank_int8_scales():
    B, S, K, N = 2, 4, 128, 128
    bucket_ranks = [4, 8]
    sizes = [2, 2]
    fa = [_rand((c, K, r), jnp.float32, 0.05)
          for c, r in zip(sizes, bucket_ranks)]
    fb = [_rand((c, r, N), jnp.float32, 0.05)
          for c, r in zip(sizes, bucket_ranks)]
    qa = [quantize_int8(a, axis=(1, 2)) for a in fa]
    qb = [quantize_int8(b, axis=(1, 2)) for b in fb]
    bank = {"a": [q[0] for q in qa], "b": [q[0] for q in qb],
            "a_scale": [q[1] for q in qa], "b_scale": [q[1] for q in qb]}
    x = _rand((B, S, K), jnp.bfloat16)
    w = _rand((K, N), jnp.bfloat16, 0.05)
    ids = jnp.asarray([1, 3], jnp.int32)
    y = batched_lora_dense(x, w, bank, ids, 2.0, block=128)
    # fp32 list bank as oracle (int8 quantization error bounded)
    yf = batched_lora_dense(x, w, {"a": fa, "b": fb}, ids, 2.0, block=128)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yf, np.float32), atol=0.15,
                               rtol=0.1)


# ---------------------------------------------------------------------------
# layers.lora_delta: bucket routing is bitwise per-client
# ---------------------------------------------------------------------------

def test_lora_delta_ragged_routes_by_bucket():
    B, S, K, N = 5, 3, 32, 24
    bucket_ranks = [2, 8]
    sizes = [2, 3]
    a = [_rand((c, K, r), jnp.float32)
         for c, r in zip(sizes, bucket_ranks)]
    b = [_rand((c, r, N), jnp.float32)
         for c, r in zip(sizes, bucket_ranks)]
    x = _rand((B, S, K), jnp.float32)
    ids = jnp.asarray([0, 1, 2, 4, 3], jnp.int32)
    z = lora_delta(x, a, b, ids)
    offs = np.cumsum([0] + sizes)
    for i, gid in enumerate(np.asarray(ids)):
        bkt = int(np.searchsorted(offs, gid, side="right") - 1)
        loc = int(gid) - int(offs[bkt])
        # routing: bitwise equal to the banked path on that bucket alone
        local_ids = jnp.clip(ids - int(offs[bkt]), 0, sizes[bkt] - 1)
        zb = lora_delta(x, a[bkt], b[bkt], local_ids)
        np.testing.assert_array_equal(np.asarray(z[i]), np.asarray(zb[i]))
        # numerics: the per-client single-adapter oracle at native rank
        zi = lora_delta(x[i:i + 1], a[bkt][loc], b[bkt][loc])
        np.testing.assert_allclose(np.asarray(z[i]), np.asarray(zi[0]),
                                   rtol=1e-5, atol=1e-5)


def test_lora_delta_ragged_requires_ids():
    with pytest.raises(ValueError):
        lora_delta(_rand((2, 3, 8), jnp.float32),
                   [_rand((2, 8, 4), jnp.float32)],
                   [_rand((2, 4, 8), jnp.float32)])


# ---------------------------------------------------------------------------
# AdapterRegistry: bucketed layout + validation
# ---------------------------------------------------------------------------

def _cfg():
    return tiny_dense()


def test_registry_bucket_layout():
    reg = AdapterRegistry(_cfg(), capacity=7, ranks=[8, 2, 4])
    assert reg.ragged
    assert reg.bucket_ranks == [2, 4, 8]          # sorted, deduped
    assert reg.bucket_sizes == [3, 2, 2]          # remainder to small ranks
    assert reg.bucket_offsets == [0, 3, 5]
    assert reg.bucket_of_slot(0) == (0, 0)
    assert reg.bucket_of_slot(4) == (1, 1)
    assert reg.bucket_of_slot(6) == (2, 1)
    np.testing.assert_array_equal(reg.slot_ranks(),
                                  [2, 2, 2, 4, 4, 8, 8])


def test_registry_bucket_constructor_validation():
    cfg = _cfg()
    with pytest.raises(ValueError, match="not both"):
        AdapterRegistry(cfg, capacity=4, rank=4, ranks=[2, 4])
    with pytest.raises(ValueError, match="positive"):
        AdapterRegistry(cfg, capacity=4, ranks=[0, 4])
    with pytest.raises(ValueError, match="cannot host"):
        AdapterRegistry(cfg, capacity=2, ranks=[2, 4, 8])


def test_registry_smallest_covering_bucket_and_padding():
    cfg = _cfg()
    reg = AdapterRegistry(cfg, capacity=4, ranks=[4, 8])
    ad3 = init_adapters(jax.random.PRNGKey(1), cfg, rank=3)
    slot = reg.register("c3", ad3)               # rank 3 -> bucket rank 4
    b, local = reg.bucket_of_slot(slot)
    assert reg.bucket_ranks[b] == 4
    assert reg.slot_ranks()[slot] == 3           # native rank survives
    # the bank slot holds the zero-padded tree exactly
    bank = reg.bank()
    a_leaf = jax.tree.leaves(ad3)[0]             # ("a" first per sort order)
    first_list = jax.tree.leaves(
        bank, is_leaf=lambda l: isinstance(l, list))[0]
    got = np.asarray(first_list[b][:, local])
    want = np.zeros(got.shape, got.dtype)
    want[..., :a_leaf.shape[-1]] = np.asarray(a_leaf)
    np.testing.assert_array_equal(got, want)


def test_registry_rank_too_large_names_buckets():
    cfg = _cfg()
    reg = AdapterRegistry(cfg, capacity=2, ranks=[2, 4])
    ad = init_adapters(jax.random.PRNGKey(1), cfg, rank=16)
    with pytest.raises(ValueError, match=r"buckets: \[2, 4\]"):
        reg.register("big", ad)


def test_registry_mixed_rank_tree_rejected():
    cfg = _cfg()
    reg = AdapterRegistry(cfg, capacity=2, ranks=[4, 8])
    ad4 = init_adapters(jax.random.PRNGKey(1), cfg, rank=4)
    ad8 = init_adapters(jax.random.PRNGKey(1), cfg, rank=8)

    def graft(n4, n8):
        if isinstance(n4, dict) and set(n4) == {"a", "b"}:
            graft.first, out = False, (n4 if graft.first else n8)
            return dict(out)
        keys = list(n4)
        out = {}
        for k in keys:
            out[k] = graft(n4[k], n8[k])
        return out
    graft.first = True
    franken = graft(ad4, ad8)
    with pytest.raises(ValueError, match="mixes LoRA ranks"):
        reg.register("bad", franken)


def test_registry_per_bucket_lru_eviction():
    cfg = _cfg()
    reg = AdapterRegistry(cfg, capacity=3, ranks=[2, 8])  # sizes [2, 1]
    a2 = lambda s: init_adapters(jax.random.PRNGKey(s), cfg, rank=2)
    a8 = lambda s: init_adapters(jax.random.PRNGKey(s), cfg, rank=8)
    reg.register("s0", a2(1))
    reg.register("s1", a2(2))
    reg.register("big", a8(3))
    reg.acquire("s0")                            # LRU in bucket 0 is now s1
    reg.register("s2", a2(4))                    # bucket 0 full: evicts s1
    assert "s1" not in reg and "s0" in reg and "big" in reg
    assert reg.evictions == 1
    # the big-bucket resident was never a candidate
    assert reg.acquire("big") == reg.bucket_offsets[1]


def test_registry_rank_change_moves_bucket_without_eviction():
    cfg = _cfg()
    reg = AdapterRegistry(cfg, capacity=4, ranks=[2, 8])
    reg.register("c", init_adapters(jax.random.PRNGKey(1), cfg, rank=2))
    s_old = reg.acquire("c")
    assert reg.bucket_of_slot(s_old)[0] == 0
    s_new = reg.register("c", init_adapters(jax.random.PRNGKey(2), cfg,
                                            rank=8))
    assert reg.bucket_of_slot(s_new)[0] == 1
    assert reg.evictions == 0                    # a move is not an eviction
    assert len(reg) == 1 and reg.version("c") == 2
    # the vacated small-bucket slot is allocatable again (FIFO free list:
    # filling the bucket reuses it without any eviction)
    reg.register("d", init_adapters(jax.random.PRNGKey(3), cfg, rank=2))
    reg.register("e", init_adapters(jax.random.PRNGKey(4), cfg, rank=2))
    assert reg.evictions == 0
    assert s_old in {reg.acquire("d"), reg.acquire("e")}


def test_registry_bank_list_structure_and_epoch():
    cfg = _cfg()
    reg = AdapterRegistry(cfg, capacity=4, ranks=[2, 4])
    assert reg.bank_epoch == 0
    bank = reg.bank()
    leaves = jax.tree.leaves(bank, is_leaf=lambda l: isinstance(l, list))
    assert all(isinstance(l, list) and len(l) == 2 for l in leaves)
    e0 = reg.bank_epoch
    reg.register("c", init_adapters(jax.random.PRNGKey(1), cfg, rank=2))
    assert reg.bank_epoch == e0 + 1
    reg.evict("c")                               # content unchanged: no bump
    assert reg.bank_epoch == e0 + 1
    # single-bucket registries still return plain stacked arrays
    legacy = AdapterRegistry(cfg, capacity=2).bank()
    assert all(hasattr(l, "shape") for l in jax.tree.leaves(legacy))


def test_registry_int8_ragged_roundtrip():
    cfg = _cfg()
    reg = AdapterRegistry(cfg, capacity=4, ranks=[2, 8], bank_dtype="int8")
    ad = init_adapters(jax.random.PRNGKey(1), cfg, rank=2)
    ad = jax.tree.map(lambda l: l + 0.1, ad)     # non-zero so scales move
    slot = reg.register("c", ad)
    b, local = reg.bucket_of_slot(slot)
    assert b == 0
    bank = reg.bank()

    def find_pair(node):
        if isinstance(node, dict) and "a_scale" in node:
            return node
        for v in node.values():
            got = find_pair(v)
            if got is not None:
                return got
        return None
    pair = find_pair(bank)
    assert isinstance(pair["a"], list) and pair["a"][0].dtype == jnp.int8
    assert float(jnp.max(jnp.abs(pair["a_scale"][0][:, local]))) > 0


def test_zip_banks_structure():
    b0 = {"blocks": {"q": {"a": jnp.zeros((1, 2, 3, 2)),
                           "b": jnp.zeros((1, 2, 2, 3))}}}
    b1 = {"blocks": {"q": {"a": jnp.ones((1, 3, 3, 4)),
                           "b": jnp.ones((1, 3, 4, 3))}}}
    z = _zip_banks([b0, b1])
    assert isinstance(z["blocks"]["q"]["a"], list)
    assert z["blocks"]["q"]["a"][0].shape == (1, 2, 3, 2)
    assert z["blocks"]["q"]["b"][1].shape == (1, 3, 4, 3)


# ---------------------------------------------------------------------------
# Satellite regressions: register_dual rank agreement, version() KeyError
# ---------------------------------------------------------------------------

def _mismatched_dual(cfg):
    """(personalized, global) whose FIRST {"a","b"} target disagrees in
    rank — the Eq. 7 merge would silently broadcast without validation."""
    p = init_adapters(jax.random.PRNGKey(1), cfg, rank=4)
    g = init_adapters(jax.random.PRNGKey(2), cfg, rank=4)

    def widen_first(node):
        if isinstance(node, dict) and set(node) == {"a", "b"}:
            if widen_first.done:
                return node
            widen_first.done = True
            a, b = node["a"], node["b"]
            return {"a": jnp.pad(a, [(0, 0)] * (a.ndim - 1) + [(0, 4)]),
                    "b": jnp.pad(b, [(0, 0)] * (b.ndim - 2)
                                 + [(0, 4), (0, 0)])}
        return {k: widen_first(v) for k, v in node.items()}
    widen_first.done = False
    return p, widen_first(g)


@pytest.mark.parametrize("sharded", [False, True])
def test_register_dual_rank_mismatch_names_leaf(sharded):
    cfg = _cfg()
    if sharded:
        reg = ShardedAdapterRegistry(cfg, capacity=4, num_shards=2,
                                     ranks=[4, 8])
    else:
        reg = AdapterRegistry(cfg, capacity=4, ranks=[4, 8])
    p, g = _mismatched_dual(cfg)
    with pytest.raises(ValueError,
                       match=r"equal LoRA rank per target.*rank 4.*rank 8"):
        reg.register_dual("c", p, g, jnp.array([0.5, 0.5]))


@pytest.mark.parametrize("sharded", [False, True])
def test_version_unregistered_raises_naming_residents(sharded):
    cfg = _cfg()
    if sharded:
        reg = ShardedAdapterRegistry(cfg, capacity=4, num_shards=2)
    else:
        reg = AdapterRegistry(cfg, capacity=4)
    reg.register("alice", init_adapters(jax.random.PRNGKey(1), cfg))
    with pytest.raises(KeyError, match=r"never registered.*alice"):
        reg.version("ghost")
    assert reg.version("alice") == 1
    reg.evict("alice")
    assert reg.version("alice") == 1             # history survives eviction


def test_sharded_version_monotone_across_shard_moves():
    """A client churned off one shard and later re-placed (possibly on a
    different shard) must keep a MONOTONE version — per-shard counters
    would restart at 1 and resurrect stale prefix-cache entries."""
    cfg = _cfg()
    reg = ShardedAdapterRegistry(cfg, capacity=2, num_shards=2)
    ad = init_adapters(jax.random.PRNGKey(1), cfg)
    reg.register("c0", ad)
    assert reg.version("c0") == 1
    reg.evict("c0")
    reg.register("other", ad)                    # takes a slot somewhere
    reg.register("c0", ad)                       # re-placed
    assert reg.version("c0") == 2


def test_sharded_ragged_global_slots():
    cfg = _cfg()
    reg = ShardedAdapterRegistry(cfg, capacity=8, num_shards=2,
                                 ranks=[4, 8])
    assert reg.ragged and reg.bucket_ranks == [4, 8]
    np.testing.assert_array_equal(reg.slot_ranks(),
                                  [4, 4, 4, 4, 8, 8, 8, 8])
    slots = []
    for i in range(4):
        rk = [4, 8][i % 2]
        slots.append(reg.register(
            f"c{i}", init_adapters(jax.random.PRNGKey(i), cfg, rank=rk)))
    assert len(set(slots)) == 4
    for i, s in enumerate(slots):
        assert reg.slot_ranks()[s] == [4, 8][i % 2]
        assert reg.acquire(f"c{i}") == s
    # bank concat order matches _global_slot: leaf list per bucket, each
    # bucket spanning num_shards * bucket_size clients
    bank = reg.bank()
    leaves = jax.tree.leaves(bank, is_leaf=lambda l: isinstance(l, list))
    assert all(len(l) == 2 for l in leaves)
    a0 = leaves[0]
    assert a0[0].shape[1] == 4 and a0[1].shape[1] == 4  # 2 shards x size 2


# ---------------------------------------------------------------------------
# Engine: >= 3 distinct ranks in ONE dispatch, bitwise vs native-rank oracle
# ---------------------------------------------------------------------------

CLIENT_RANKS = {"c0": 2, "c1": 4, "c2": 8}


def _client_adapters(cfg, seed, rank):
    ad = init_adapters(jax.random.PRNGKey(seed), cfg, rank=rank)
    bump = jax.random.PRNGKey(seed + 99)
    return jax.tree.map(
        lambda l: l + 0.02 * jax.random.normal(bump, l.shape), ad)


@pytest.fixture(scope="module")
def setup():
    # f32 end to end: the Pallas attention kernels' online-softmax
    # accumulation only guarantees bitwise greedy parity with the jnp
    # oracle in float32 (same precedent as test_sched_policy's f32_engine)
    cfg = tiny_dense(dtype="float32", param_dtype="float32")
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    ads = {cid: _client_adapters(cfg, i + 1, r)
           for i, (cid, r) in enumerate(CLIENT_RANKS.items())}
    return cfg, model, params, ads


@pytest.fixture(scope="module")
def singles(setup):
    cfg, model, params, ads = setup
    prompt = np.arange(8, dtype=np.int32) % cfg.vocab_size
    sc = ServeConfig(batch_size=1, max_new_tokens=6, cache_len=64)
    out = {cid: np.asarray(Engine(model, cfg, params, ad).generate(
        jnp.asarray(prompt)[None], sc))[0] for cid, ad in ads.items()}
    vals = list(out.values())
    assert any((vals[0] != v).any() for v in vals[1:]), "clients must differ"
    return prompt, out


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
@pytest.mark.parametrize("shards", [1, 2])
def test_mixed_rank_batch_bitwise_vs_native_oracle(setup, singles, backend,
                                                   shards):
    """Acceptance: one continuous-batching dispatch mixing >= 3 distinct
    native ranks serves every request bitwise equal to that client's
    dense per-client LoRA at its NATIVE rank."""
    cfg, model, params, ads = setup
    prompt, oracle = singles
    if shards == 1:
        reg = AdapterRegistry(cfg, capacity=3, ranks=[2, 4, 8])
    else:
        reg = ShardedAdapterRegistry(cfg, capacity=6, num_shards=2,
                                     ranks=[2, 4, 8])
    for cid, ad in ads.items():
        reg.register(cid, ad)
    assert len({CLIENT_RANKS[c] for c in CLIENT_RANKS}) >= 3
    mt = MultiTenantEngine(model, cfg, params, reg)
    order = ["c2", "c0", "c1", "c0", "c2", "c1"]
    sc = ServeConfig(batch_size=2 * shards, max_new_tokens=6, block_size=4,
                     num_blocks=1 + 8 * shards, prefill_chunk=4,
                     cache_len=64, paged_backend=backend, num_shards=shards)
    outs = mt.generate([Request(c, prompt) for c in order], sc)
    for got, cid in zip(outs, order):
        np.testing.assert_array_equal(got, oracle[cid])


def test_mixed_rank_fixed_batch_bitwise(setup, singles):
    """The fixed-shape (PR-1) dispatch path routes ragged banks too."""
    cfg, model, params, ads = setup
    prompt, oracle = singles
    reg = AdapterRegistry(cfg, capacity=4, ranks=[2, 4, 8])
    for cid, ad in ads.items():
        reg.register(cid, ad)
    mt = MultiTenantEngine(model, cfg, params, reg)
    order = ["c1", "c2", "c0", "c2"]
    sc = ServeConfig(batch_size=1, max_new_tokens=6, cache_len=32)
    out = np.asarray(mt.generate_fixed(
        [Request(c, prompt) for c in order], sc))
    for i, cid in enumerate(order):
        np.testing.assert_array_equal(out[i], oracle[cid])
