"""int8 quantized serving (KV pools + adapter banks) and the registry /
KV-pool edge-case hardening.

Parity discipline: the int8 serving path is NOT bitwise against f32 — it is
held to (a) an exact contract between each Pallas kernel and the jnp
dequantizing oracle fed the same int8 data, (b) a documented error bound
between quantized and unquantized attention outputs, and (c) greedy
token-stream equality on the smoke model across every serving feature
(ragged batches, preemption, warm prefix reuse, spec decode, sharding) —
argmax survives the quantization noise at these scales.
"""
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import tiny_dense
from repro.core.lora import init_adapters
from repro.kernels.ops import (batched_lora_dense, paged_gqa_attention,
                               paged_prefill_gqa_attention)
from repro.kernels.paged_attention import paged_attention
from repro.kernels.paged_prefill import (paged_prefill_attention,
                                         paged_scatter, paged_scatter_quant)
from repro.kernels.quant import dequantize_int8, quantize_int8
from repro.kernels.ref import (batched_lora_matmul_ref, paged_attention_ref,
                               paged_prefill_attention_ref)
from repro.kernels.batched_lora import batched_lora_matmul
from repro.models.api import get_model
from repro.serving.engine import MultiTenantEngine, Request, ServeConfig
from repro.serving.kv_cache import PagedKVCache, kv_bytes_per_block
from repro.serving.registry import AdapterRegistry
from repro.serving.sharded import ShardedAdapterRegistry

RNG = np.random.default_rng(23)

# |dequant(x) - x| <= scale/2 per element; scales here are amax/127 of unit
# normals, so attention outputs (convex combos of V rows) stay within a few
# quantization steps.  This is the documented error bound the int8 path is
# held to against the f32 oracle.
KV_ATOL = 0.05


def _rand(shape, dtype=jnp.float32, scale=1.0):
    return jnp.asarray(RNG.standard_normal(shape) * scale, dtype)


# ---------------------------------------------------------------------------
# quantize/dequantize primitives
# ---------------------------------------------------------------------------

def test_quantize_int8_roundtrip_error_bound():
    x = _rand((16, 8, 4, 32))
    q, s = quantize_int8(x, axis=-1)
    assert q.dtype == jnp.int8 and s.dtype == jnp.float32
    assert s.shape == (16, 8, 4)
    err = np.abs(np.asarray(dequantize_int8(q, s, -1) - x))
    # rounding error is at most half a step (= scale/2) per element
    assert (err <= np.asarray(s)[..., None] / 2 + 1e-7).all()


def test_quantize_int8_zero_group_roundtrips_to_zero():
    x = jnp.zeros((4, 32))
    q, s = quantize_int8(x, axis=-1)
    dq = dequantize_int8(q, s, -1)
    assert not np.isnan(np.asarray(dq)).any()
    np.testing.assert_array_equal(np.asarray(dq), 0.0)


# ---------------------------------------------------------------------------
# Kernel parity: int8 pools, decode + prefill
# ---------------------------------------------------------------------------

def _quant_pools(NB, bs, Kv, hd):
    kf = _rand((NB, bs, Kv, hd))
    vf = _rand((NB, bs, Kv, hd))
    kq, ks = quantize_int8(kf, axis=-1)
    vq, vs = quantize_int8(vf, axis=-1)
    return kf, vf, kq, ks, vq, vs


@pytest.mark.parametrize("H,Kv", [(4, 4), (8, 2)])
def test_paged_attention_int8_matches_dequant_oracle(H, Kv):
    """Kernel vs the jnp oracle fed the SAME int8 blocks: tight tolerance
    (both dequantize identically; only accumulation order differs)."""
    B, hd, NB, bs, MB = 5, 32, 11, 8, 4
    kf, vf, kq, ks, vq, vs = _quant_pools(NB, bs, Kv, hd)
    q = _rand((B, H, hd))
    bt = jnp.asarray(np.stack([RNG.permutation(NB)[:MB] for _ in range(B)]),
                     jnp.int32)
    lens = jnp.asarray([0, 1, 7, 19, 32], jnp.int32)
    pad = [(0, 0)] * 3 + [(0, 128 - hd)]
    y = paged_attention(jnp.pad(q, [(0, 0), (0, 0), (0, 128 - hd)]),
                        jnp.pad(kq, pad), jnp.pad(vq, pad), bt, lens,
                        k_scale=ks, v_scale=vs,
                        scale=hd ** -0.5)[..., :hd]
    yr = paged_attention_ref(q, kq, vq, bt, lens, k_scale=ks, v_scale=vs)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=2e-5)
    # and within the quantization error bound of the UNQUANTIZED pools
    yf = paged_attention_ref(q, kf, vf, bt, lens)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yf), atol=KV_ATOL)
    np.testing.assert_array_equal(np.asarray(y)[0], 0.0)  # empty slot


def test_paged_prefill_int8_matches_dequant_oracle():
    B, T, H, Kv, hd, NB, bs, MB = 3, 8, 4, 2, 32, 16, 8, 5
    kf, vf, kq, ks, vq, vs = _quant_pools(NB, bs, Kv, hd)
    q = _rand((B, T, H, hd))
    kn = _rand((B, T, Kv, hd))
    vn = _rand((B, T, Kv, hd))
    bt = jnp.asarray(np.stack([RNG.permutation(np.arange(1, NB))[:MB]
                               for _ in range(B)]), jnp.int32)
    lens = jnp.asarray([0, 5, 13], jnp.int32)
    n_new = jnp.asarray([8, 8, 3], jnp.int32)         # ragged chunk tails
    kq2, vq2, ks2, vs2 = paged_scatter_quant(kq, vq, ks, vs, kn, vn,
                                             bt, lens, n_new)
    pad = [(0, 0)] * 3 + [(0, 128 - hd)]
    y = paged_prefill_attention(
        jnp.pad(q, [(0, 0)] * 3 + [(0, 128 - hd)]),
        jnp.pad(kq2, pad), jnp.pad(vq2, pad), bt, lens,
        k_scale=ks2, v_scale=vs2, scale=hd ** -0.5)[..., :hd]
    yr = paged_prefill_attention_ref(q, kq2, vq2, bt, lens,
                                     k_scale=ks2, v_scale=vs2)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=2e-5)


def test_paged_scatter_quant_matches_unquantized_scatter_coords():
    """Quantized and plain scatter write through identical coordinates:
    dequantizing the int8 pool recovers the f32 pool's written positions
    within the error bound, including the scratch-block-0 redirect."""
    B, S, Kv, hd, NB, bs = 2, 6, 2, 16, 5, 4
    k = _rand((B, S, Kv, hd))
    v = _rand((B, S, Kv, hd))
    bt = jnp.asarray([[1, 2, 0], [3, 4, 0]], jnp.int32)
    lens = jnp.asarray([2, 0], jnp.int32)
    n_new = jnp.asarray([6, 4], jnp.int32)
    kf = jnp.zeros((NB, bs, Kv, hd))
    kq0 = jnp.zeros((NB, bs, Kv, hd), jnp.int8)
    s0 = jnp.zeros((NB, bs, Kv))
    kp, vp = paged_scatter(kf, kf, k, v, bt, lens, n_new)
    kq, vq, ks, vs = paged_scatter_quant(kq0, kq0, s0, s0, k, v,
                                         bt, lens, n_new)
    dq = np.asarray(kq, np.float32) * np.asarray(ks)[..., None]
    # block 0 is scratch — exclude it (redirected garbage differs is fine,
    # but actually both paths redirect the same tokens there too)
    np.testing.assert_allclose(dq[1:], np.asarray(kp)[1:], atol=KV_ATOL)


def test_ops_wrappers_thread_scales_with_lane_padding():
    """Model-layout wrappers: non-aligned head dim, scales untouched by
    padding; prefill wrapper returns the four updated pools."""
    B, H, Kv, hd, NB, bs, MB = 3, 4, 2, 24, 7, 4, 3
    kf, vf, kq, ks, vq, vs = _quant_pools(NB, bs, Kv, hd)
    q = _rand((B, 1, H, hd))
    bt = jnp.asarray(RNG.integers(1, NB, (B, MB)), jnp.int32)
    lens = jnp.asarray([2, 5, 11], jnp.int32)
    y = paged_gqa_attention(q, kq, vq, bt, lens, k_scale=ks, v_scale=vs)
    yr = paged_attention_ref(q[:, 0], kq, vq, bt, lens,
                             k_scale=ks, v_scale=vs)
    np.testing.assert_allclose(np.asarray(y[:, 0]), np.asarray(yr),
                               atol=2e-5)
    T = 4
    out = paged_prefill_gqa_attention(
        _rand((B, T, H, hd)), _rand((B, T, Kv, hd)), _rand((B, T, Kv, hd)),
        kq, vq, bt, lens, jnp.full((B,), T, jnp.int32),
        k_scale=ks, v_scale=vs)
    assert len(out) == 5
    _, kp2, vp2, ks2, vs2 = out
    assert kp2.dtype == jnp.int8 and ks2.shape == (NB, bs, Kv)


# ---------------------------------------------------------------------------
# int8 adapter banks
# ---------------------------------------------------------------------------

def test_batched_lora_int8_kernel_matches_refs():
    M, K, N, C, r = 256, 256, 256, 4, 8
    x = _rand((M, K), jnp.bfloat16)
    w = _rand((K, N), jnp.bfloat16, 0.05)
    a = _rand((C, K, r), jnp.float32, 0.05)
    b = _rand((C, r, N), jnp.float32, 0.05)
    g = jnp.asarray(RNG.integers(0, C, M), jnp.int32)
    aq, asc = quantize_int8(a, axis=(1, 2))
    bq, bsc = quantize_int8(b, axis=(1, 2))
    y = batched_lora_matmul(x, w, aq, bq, g, 2.0, a_scale=asc, b_scale=bsc,
                            bm=128, bn=128, bk=128)
    yr = batched_lora_matmul_ref(x, w, aq, bq, g, 2.0,
                                 a_scale=asc, b_scale=bsc)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32),
                               atol=0.3, rtol=0.05)  # one bf16 ulp of |y|
    # quantized vs unquantized LoRA delta stays within the scale bound
    yf = batched_lora_matmul_ref(x, w, a, b, g, 2.0)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yf, np.float32),
                               atol=0.5, rtol=0.05)


def test_batched_lora_dense_reads_bank_scales():
    K, N, C, r = 64, 64, 3, 4
    x = _rand((2, 5, K), jnp.bfloat16)
    w = _rand((K, N), jnp.bfloat16, 0.1)
    a = _rand((C, K, r), jnp.float32, 0.05)
    b = _rand((C, r, N), jnp.float32, 0.05)
    aq, asc = quantize_int8(a, axis=(1, 2))
    bq, bsc = quantize_int8(b, axis=(1, 2))
    ids = jnp.asarray([0, 2], jnp.int32)
    y = batched_lora_dense(x, w, {"a": aq, "b": bq,
                                  "a_scale": asc, "b_scale": bsc},
                           ids, 2.0, block=64)
    yr = batched_lora_dense(x, w, {"a": a, "b": b}, ids, 2.0, block=64)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32), atol=0.25)


def test_registry_int8_bank_layout_and_dequant():
    cfg = tiny_dense()
    reg = AdapterRegistry(cfg, capacity=3, bank_dtype="int8")
    ad = init_adapters(jax.random.PRNGKey(1), cfg)
    reg.register("c0", ad)
    bank = reg.bank()
    tgt = bank["blocks"]["b0"]["mixer"]["wq"]
    P = ad["blocks"]["b0"]["mixer"]["wq"]["a"].shape[0]
    assert tgt["a"].dtype == jnp.int8
    assert tgt["a_scale"].shape == (P, 3)
    slot = reg.acquire("c0")
    got = dequantize_int8(tgt["a"][:, slot], tgt["a_scale"][:, slot],
                          (1, 2))
    want = np.asarray(ad["blocks"]["b0"]["mixer"]["wq"]["a"], np.float32)
    step = np.asarray(tgt["a_scale"][:, slot])[:, None, None]
    assert (np.abs(np.asarray(got) - want) <= step / 2 + 1e-7).all()
    # unregistered slots must stay an exact no-op (zero ints, zero scales)
    other = (slot + 1) % 3
    np.testing.assert_array_equal(np.asarray(tgt["a"][:, other]), 0)


def test_sharded_registry_int8_bank_concat():
    cfg = tiny_dense()
    reg = ShardedAdapterRegistry(cfg, capacity=4, num_shards=2,
                                 bank_dtype="int8")
    for i in range(3):
        reg.register(f"c{i}", init_adapters(jax.random.PRNGKey(i), cfg))
    bank = reg.bank()
    tgt = bank["blocks"]["b0"]["mixer"]["wq"]
    assert tgt["a"].shape[1] == 4 and tgt["a_scale"].shape[1] == 4
    assert tgt["a"].dtype == jnp.int8


# ---------------------------------------------------------------------------
# Registry edge-case hardening (the three bugfix regressions)
# ---------------------------------------------------------------------------

def test_lru_eviction_clears_default_priority():
    """Regression: an LRU-evicted client's SLA class must not resurrect
    when it re-registers without one (and the dict must not grow without
    bound under churn)."""
    cfg = tiny_dense()
    reg = AdapterRegistry(cfg, capacity=1)
    ad = init_adapters(jax.random.PRNGKey(0), cfg)
    reg.register("c0", ad, default_priority="interactive")
    reg.register("c1", ad)                    # evicts c0
    assert reg.evictions == 1
    assert reg.default_priority("c0") is None
    reg.register("c0", ad)                    # back, no priority given
    assert reg.default_priority("c0") is None
    # version monotonicity survives eviction (prefix-cache scoping)
    assert reg.version("c0") == 2
    # explicit evict() already cleared it (unchanged behaviour)
    reg.register("c2", ad, default_priority="batch")
    reg.evict("c2")
    assert reg.default_priority("c2") is None


def test_register_rejects_misshaped_tree_naming_leaf():
    cfg = tiny_dense()
    reg = AdapterRegistry(cfg, capacity=2)
    ad = init_adapters(jax.random.PRNGKey(0), cfg)
    bad = jax.tree.map(lambda l: l, ad)
    leaf = bad["blocks"]["b0"]["mixer"]["wq"]["a"]
    bad["blocks"]["b0"]["mixer"]["wq"]["a"] = leaf[:, :-1]
    with pytest.raises(ValueError, match=r"wq.*\['a'\]|\['a'\].*wq"):
        reg.register("c0", bad)
    assert "c0" not in reg                    # nothing half-registered
    with pytest.raises(KeyError):
        reg.version("c0")                     # no version entry leaked
    assert reg.default_priority("c0") is None  # no priority leaked either
    with pytest.raises(ValueError, match=r"wq"):
        reg.register("c0", bad, default_priority="interactive")
    assert reg.default_priority("c0") is None


def test_register_rejects_wrong_structure():
    cfg = tiny_dense()
    reg = AdapterRegistry(cfg, capacity=2)
    ad = init_adapters(jax.random.PRNGKey(0), cfg)
    extra = jax.tree.map(lambda l: l, ad)
    extra["blocks"]["b0"]["mixer"]["bogus"] = {"a": jnp.zeros((1, 2, 3))}
    with pytest.raises(ValueError, match="unexpected"):
        reg.register("c0", extra)
    missing = jax.tree.map(lambda l: l, ad)
    del missing["blocks"]["b0"]["mixer"]["wq"]
    with pytest.raises(ValueError, match="missing"):
        reg.register("c0", missing)


def test_register_dual_validates_both_trees():
    cfg = tiny_dense()
    reg = AdapterRegistry(cfg, capacity=2)
    ad = init_adapters(jax.random.PRNGKey(0), cfg)
    bad = jax.tree.map(lambda l: l, ad)
    bad["blocks"]["b0"]["mlp"]["w_up"]["b"] = \
        bad["blocks"]["b0"]["mlp"]["w_up"]["b"][:, :-1]
    with pytest.raises(ValueError, match="personalized"):
        reg.register_dual("c0", bad, ad, [0.5, 0.5])
    with pytest.raises(ValueError, match="global"):
        reg.register_dual("c0", ad, bad, [0.5, 0.5])


def test_evict_nonresident_raises_keyerror():
    cfg = tiny_dense()
    reg = AdapterRegistry(cfg, capacity=2)
    with pytest.raises(KeyError, match="not resident"):
        reg.evict("ghost")
    sharded = ShardedAdapterRegistry(cfg, capacity=2, num_shards=2)
    with pytest.raises(KeyError, match="not resident"):
        sharded.evict("ghost")


# ---------------------------------------------------------------------------
# KV-pool guards survive ``python -O`` (assert -> exception promotion)
# ---------------------------------------------------------------------------

def test_ensure_over_table_capacity_raises_valueerror():
    kv = PagedKVCache(num_slots=1, block_size=4, num_blocks=8,
                      max_blocks_per_slot=2)
    kv.admit(0)
    with pytest.raises(ValueError, match="max_blocks_per_slot"):
        kv.ensure(0, 9)                       # needs 3 > 2 blocks


def test_pool_guards_live_under_python_O():
    """The promoted guards must fire with asserts compiled out; the
    diagnostic ``check_invariants`` suite may legitimately stay assert-
    based (it is opt-in, not hot-path)."""
    code = (
        "from repro.serving.kv_cache import PagedKVCache\n"
        "kv = PagedKVCache(1, 4, 8, 2)\n"
        "kv.admit(0)\n"
        "try:\n"
        "    kv.ensure(0, 9)\n"
        "except ValueError:\n"
        "    print('GUARDED')\n")
    out = subprocess.run([sys.executable, "-O", "-c", code],
                         capture_output=True, text=True,
                         env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"},
                         cwd="/root/repo")
    assert out.returncode == 0, out.stderr
    assert "GUARDED" in out.stdout


def test_rollback_shared_tail_guard_is_runtimeerror():
    """Corrupting a tail block's refcount must trip the promoted
    RuntimeError (not a stripped assert) before the block is freed."""
    kv = PagedKVCache(num_slots=2, block_size=4, num_blocks=8,
                      max_blocks_per_slot=4)
    kv.admit(0)
    kv.ensure(0, 8)
    kv.advance(0, 8, tokens=None)
    tail = kv.block_tables[0, 1]
    kv._refcount[tail] = 2                    # simulate corruption
    with pytest.raises(RuntimeError, match="refcount"):
        kv.rollback(0, 2)
    kv._refcount[tail] = 1                    # restore


# ---------------------------------------------------------------------------
# Engine-level int8 parity (greedy streams vs the f32 path)
# ---------------------------------------------------------------------------

def _mt(cfg, bank_dtype="f32", n_clients=2):
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    reg = AdapterRegistry(cfg, capacity=4, bank_dtype=bank_dtype)
    for i in range(n_clients):
        ad = init_adapters(jax.random.PRNGKey(42), cfg)
        bump = jax.random.PRNGKey(101 + i)
        reg.register(f"c{i}", jax.tree.map(
            lambda l: l + 0.02 * jax.random.normal(bump, l.shape), ad))
    return MultiTenantEngine(model, cfg, params, reg)


def _reqs(cfg):
    mk = lambda n, o=0: ((np.arange(n, dtype=np.int32) * 3 + 1 + o)
                         % cfg.vocab_size)
    return [Request("c0", mk(5), max_new_tokens=4),
            Request("c1", mk(11), max_new_tokens=7),
            Request("c1", mk(2, 3), max_new_tokens=5),
            Request("c0", mk(8, 1), max_new_tokens=3)]


def _assert_stream_parity(cfg, sc_kw, bank_dtype="f32"):
    reqs = _reqs(cfg)
    ref = _mt(cfg).generate(reqs, ServeConfig(**sc_kw))
    got = _mt(cfg, bank_dtype=bank_dtype).generate(
        reqs, ServeConfig(kv_dtype="int8", **sc_kw))
    for r, o in zip(ref, got):
        np.testing.assert_array_equal(o, r)


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_int8_greedy_streams_match_f32_ragged(backend):
    cfg = tiny_dense()
    _assert_stream_parity(cfg, dict(batch_size=2, max_new_tokens=8,
                                    block_size=4, paged_backend=backend))


def test_int8_greedy_streams_match_under_preemption():
    cfg = tiny_dense()
    # pool of 5 allocatable blocks with spans up to 18 tokens -> forced
    # preemption churn; int8 must replay identically
    _assert_stream_parity(cfg, dict(batch_size=3, max_new_tokens=8,
                                    block_size=4, num_blocks=6))


def test_int8_greedy_streams_match_with_warm_prefix_reuse():
    cfg = tiny_dense()
    kw = dict(batch_size=2, max_new_tokens=8, block_size=4,
              prefix_cache=True)
    reqs = _reqs(cfg)
    mt_f, mt_q = _mt(cfg), _mt(cfg)
    for rnd in range(2):                      # second round hits warm pool
        ref = mt_f.generate(reqs, ServeConfig(**kw))
        got = mt_q.generate(reqs, ServeConfig(kv_dtype="int8", **kw))
        for r, o in zip(ref, got):
            np.testing.assert_array_equal(o, r)
    assert mt_q.last_stats["prefix_pool_reused"]
    assert mt_q.last_stats["prefix_hit_tokens"] > 0
    assert mt_q.last_stats["kv_dtype"] == "int8"


def test_int8_greedy_streams_match_spec_decode():
    cfg = tiny_dense()
    _assert_stream_parity(cfg, dict(batch_size=2, max_new_tokens=8,
                                    block_size=4, spec_decode=True,
                                    spec_k=3))


def test_int8_greedy_streams_match_sharded():
    cfg = tiny_dense()
    _assert_stream_parity(cfg, dict(batch_size=4, max_new_tokens=8,
                                    block_size=4, num_shards=2))


def test_int8_bank_and_int8_kv_together():
    cfg = tiny_dense()
    _assert_stream_parity(cfg, dict(batch_size=2, max_new_tokens=8,
                                    block_size=4), bank_dtype="int8")


def test_kv_dtype_validated():
    cfg = tiny_dense()
    mt = _mt(cfg)
    with pytest.raises(ValueError, match="kv_dtype"):
        mt.generate(_reqs(cfg), ServeConfig(batch_size=2, kv_dtype="fp8"))
    with pytest.raises(ValueError, match="bank_dtype"):
        AdapterRegistry(cfg, capacity=2, bank_dtype="fp4")


def test_warm_pool_not_reused_across_kv_dtype_change():
    """The warm prefix pool is keyed by kv_dtype: an f32 stream must not
    inherit int8 blocks (or vice versa)."""
    cfg = tiny_dense()
    mt = _mt(cfg)
    kw = dict(batch_size=2, max_new_tokens=4, block_size=4,
              prefix_cache=True)
    reqs = _reqs(cfg)
    mt.generate(reqs, ServeConfig(kv_dtype="int8", **kw))
    mt.generate(reqs, ServeConfig(kv_dtype="f32", **kw))
    assert not mt.last_stats["prefix_pool_reused"]


# ---------------------------------------------------------------------------
# Capacity: the point of int8 pools
# ---------------------------------------------------------------------------

def test_int8_block_bytes_give_capacity_headroom():
    """At a fixed HBM budget the int8 pool holds >= 1.5x the blocks of the
    bf16 pool (the bench gate's static counterpart)."""
    bs, Kv, hd = 16, 2, 32
    f32 = kv_bytes_per_block(bs, Kv, hd, "f32")
    i8 = kv_bytes_per_block(bs, Kv, hd, "int8")
    assert f32 / i8 >= 1.5
    # and the formula matches the actual pytree the model allocates
    cfg = tiny_dense()
    model = get_model(cfg)
    for kv_dtype in ("f32", "int8"):
        cache = model.init_paged_decode_cache(1, 4, bs, kv_dtype=kv_dtype)
        entry = cache["blocks"]["b0"]
        per_block = sum(                     # leaves are (P, NB, bs, ...)
            l.dtype.itemsize * int(np.prod(l.shape[2:]))
            for l in jax.tree.leaves(entry))
        want = kv_bytes_per_block(bs, cfg.n_kv_heads,
                                  cfg.resolved_head_dim, kv_dtype)
        assert per_block == want
