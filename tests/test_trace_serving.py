"""Open-loop trace workloads + the async serving front end.

Three layers, cheapest first:

  * ``synth_trace`` statistics — seeded determinism, empirical arrival
    rates (Poisson AND bursty trend to the same long-run rate; the bursty
    process is measurably burstier), length clipping, priority/client
    mixes.
  * ``run_trace`` logical mode — the parity harness: the async overlapped
    engine (``ServeConfig.overlap=True``) must emit BITWISE-identical
    greedy streams to the synchronous reference loop on the same trace,
    across ragged / preemption / prefix-cache / spec-decode / sharded /
    sampled configs.  Logical mode maps arrivals to engine rounds, so
    both runs execute identical dispatch sequences by construction.
  * the asyncio front end (``launch/serve.py``) — submissions mid-flight,
    per-request streamed tokens, graceful drain, and wall-clock queue
    waits in ``last_stats`` exactly when the session is driven open-loop.
"""
import asyncio
import dataclasses

import numpy as np
import pytest

from repro.serving.engine import Request, ServeConfig
from repro.serving.trace import (DEFAULT_PRIORITY_MIX, TraceEntry,
                                 synth_trace, run_trace)
from test_serving_sim import real_engine  # noqa: F401 (module fixture)


# ---------------------------------------------------------------------------
# workload generator
# ---------------------------------------------------------------------------

def test_synth_trace_deterministic_per_seed():
    a = synth_trace(7, 40, arrival="bursty")
    b = synth_trace(7, 40, arrival="bursty")
    assert len(a) == len(b) == 40
    for x, y in zip(a, b):
        assert x.arrival_s == y.arrival_s
        assert x.client_id == y.client_id
        assert x.priority == y.priority
        assert x.max_new_tokens == y.max_new_tokens
        np.testing.assert_array_equal(x.prompt, y.prompt)
    c = synth_trace(8, 40, arrival="bursty")
    assert any(x.arrival_s != y.arrival_s for x, y in zip(a, c))


def test_synth_trace_sorted_and_clipped():
    tr = synth_trace(3, 200, prompt_max=48, out_max=24)
    arr = [e.arrival_s for e in tr]
    assert arr == sorted(arr) and arr[0] > 0
    for e in tr:
        assert 1 <= e.prompt.size <= 48
        assert 1 <= e.max_new_tokens <= 24
        assert e.prompt.dtype == np.int32
        # pad id (0) excluded by default; tokens inside the vocab
        assert e.prompt.min() >= 1 and e.prompt.max() < 300


def test_poisson_empirical_rate():
    rate = 8.0
    tr = synth_trace(0, 2000, arrival="poisson", rate=rate)
    emp = len(tr) / tr[-1].arrival_s
    assert 0.85 * rate <= emp <= 1.15 * rate


def test_bursty_rate_matches_but_is_burstier():
    rate = 8.0
    po = synth_trace(1, 2000, arrival="poisson", rate=rate)
    bu = synth_trace(1, 2000, arrival="bursty", rate=rate,
                     burst_on_s=0.5, burst_off_s=1.5)
    emp = len(bu) / bu[-1].arrival_s
    # ON-OFF scaling keeps the LONG-RUN rate comparable to Poisson
    assert 0.7 * rate <= emp <= 1.3 * rate
    # burstiness: coefficient of variation of inter-arrival gaps is ~1
    # for Poisson and strictly larger for the ON-OFF process
    def cv(tr):
        gaps = np.diff([0.0] + [e.arrival_s for e in tr])
        return float(np.std(gaps) / np.mean(gaps))
    assert cv(bu) > 1.3 * cv(po)


def test_priority_and_client_mix():
    tr = synth_trace(5, 600, clients=("a", "b"), client_weights=(3, 1))
    prio = {p: 0 for p in DEFAULT_PRIORITY_MIX}
    cl = {"a": 0, "b": 0}
    for e in tr:
        prio[e.priority] += 1
        cl[e.client_id] += 1
    for p, w in DEFAULT_PRIORITY_MIX.items():
        assert abs(prio[p] / len(tr) - w) < 0.1
    assert abs(cl["a"] / len(tr) - 0.75) < 0.1


def test_synth_trace_validates_inputs():
    with pytest.raises(ValueError):
        synth_trace(0, 0)
    with pytest.raises(ValueError):
        synth_trace(0, 4, rate=0.0)
    with pytest.raises(ValueError):
        synth_trace(0, 4, arrival="uniform")
    with pytest.raises(ValueError):
        synth_trace(0, 4, vocab_size=2, forbid_tokens=(0, 1))


def test_run_trace_rejects_unsorted_trace(real_engine):
    cfg, model, params, ads, mt = real_engine
    e = synth_trace(0, 2)[0]
    bad = [dataclasses.replace(e, arrival_s=2.0),
           dataclasses.replace(e, arrival_s=1.0)]
    with pytest.raises(ValueError):
        run_trace(mt, _sc(), bad)


# ---------------------------------------------------------------------------
# async-vs-sync bitwise parity (logical mode)
# ---------------------------------------------------------------------------

def _sc(**kw):
    """Open-loop pool geometry for the tiny engine: 4 slots sized for the
    trace's worst-case span."""
    base = dict(batch_size=4, max_new_tokens=12, block_size=8,
                num_blocks=21, max_blocks_per_slot=5, prefill_chunk=4,
                scan_chunk=4)
    base.update(kw)
    return ServeConfig(**base)


def _trace(seed=0, n=10, **kw):
    args = dict(arrival="bursty", rate=40.0, prompt_mean=8.0,
                prompt_max=24, out_mean=6.0, out_max=10)
    args.update(kw)
    return synth_trace(seed, n, **args)


def _parity(mt, sc, trace, rounds_per_s=6.0):
    """Same logical trace, overlap on vs off: streams must be bitwise
    equal (and both runs must actually finish every request)."""
    on = run_trace(mt, dataclasses.replace(sc, overlap=True), trace,
                   rounds_per_s=rounds_per_s)
    off = run_trace(mt, dataclasses.replace(sc, overlap=False), trace,
                    rounds_per_s=rounds_per_s)
    assert on["completed"] == off["completed"] == len(trace)
    assert set(on["streams"]) == set(off["streams"])
    for rid in on["streams"]:
        assert on["streams"][rid] == off["streams"][rid], f"rid {rid}"
    assert on["last_stats"]["overlap"] is True
    assert off["last_stats"]["overlap"] is False
    return on


def test_parity_ragged(real_engine):
    cfg, model, params, ads, mt = real_engine
    rep = _parity(mt, _sc(), _trace())
    assert rep["emitted_tokens"] > 0
    assert rep["mode"] == "logical" and rep["unit"] == "rounds"


def test_parity_under_preemption(real_engine):
    """Starved pool: admission must preempt mid-trace and the overlap
    fast path must survive the table churn (its cached device tables are
    keyed on the pool's table_version)."""
    cfg, model, params, ads, mt = real_engine
    sc = _sc(batch_size=3, num_blocks=8, max_blocks_per_slot=5)
    tr = _trace(n=12, rate=80.0, prompt_mean=16.0, out_mean=8.0)
    rep = _parity(mt, sc, tr)
    assert rep["last_stats"]["preemptions"] > 0


def test_parity_warm_prefix_cache(real_engine):
    """Shared prompts over a warm content-addressed pool: admissions skip
    cached prefixes (table mutations at admit) and streams stay bitwise
    equal across overlap settings."""
    cfg, model, params, ads, mt = real_engine
    sc = _sc(prefix_cache=True)
    # one shared >=2-block prompt, one client: later admissions must
    # re-match the blocks the first request sealed (scope is per client,
    # and only FULL blocks seal — hence 16 tokens at block_size 8)
    shared = ((np.arange(16, dtype=np.int32) * 5) % 290 + 1).astype(np.int32)
    tr = [dataclasses.replace(e, prompt=shared.copy(), client_id="c0")
          for e in _trace(n=8)]
    mt.release_prefix_cache()
    rep = _parity(mt, sc, tr)
    assert rep["last_stats"]["prefix_hit_tokens"] > 0
    mt.release_prefix_cache()


def test_parity_spec_decode(real_engine):
    """Draft/verify rounds interleave with the overlap fast path: verify
    advances are host logic, so chained device lengths must refresh."""
    cfg, model, params, ads, mt = real_engine
    sc = _sc(spec_decode=True, spec_k=4)
    # repetitive prompts so the prompt-lookup drafter actually fires
    tr = []
    for e in _trace(n=8):
        pat = np.tile(e.prompt[:4], 6)[: e.prompt.size + 8].astype(np.int32)
        tr.append(dataclasses.replace(e, prompt=pat))
    rep = _parity(mt, sc, tr)
    assert rep["last_stats"]["verify_dispatches"] > 0


def test_parity_two_shards(real_engine):
    cfg, model, params, ads, mt = real_engine
    sc = _sc(num_shards=2, num_blocks=21)   # 20 allocatable = 2 * 10
    rep = _parity(mt, sc, _trace())
    assert rep["last_stats"]["num_shards"] == 2


def test_parity_sampled_stream(real_engine):
    """temperature > 0 exercises the rng chain: the per-round split now
    happens inside the jit, and must consume the SAME key sequence in
    both loops (and on verify-less vs verify-bearing mixes)."""
    cfg, model, params, ads, mt = real_engine
    _parity(mt, _sc(temperature=0.7, seed=3), _trace(n=8))


def test_realtime_matches_logical_streams(real_engine):
    """Greedy schedule-invariance: per-request token streams do not
    depend on WHEN requests are submitted, so the wall-clock replay of a
    trace emits the same per-request tokens as the logical replay."""
    cfg, model, params, ads, mt = real_engine
    tr = _trace(n=8)
    lo = run_trace(mt, _sc(), tr, rounds_per_s=6.0)
    rt = run_trace(mt, _sc(), tr, realtime=True, time_scale=0.02)
    assert rt["mode"] == "realtime" and rt["unit"] == "ms"
    assert set(lo["streams"]) == set(rt["streams"])
    for rid in lo["streams"]:
        assert lo["streams"][rid] == rt["streams"][rid]
    # wall-clock queue waits only exist on the realtime (open-loop) run
    assert any("wait_wall_ms_p50" in cs
               for cs in rt["last_stats"]["classes"].values())
    assert not any("wait_wall_ms_p50" in cs
                   for cs in lo["last_stats"]["classes"].values())


def test_report_shape(real_engine):
    cfg, model, params, ads, mt = real_engine
    rep = run_trace(mt, _sc(), _trace(n=6), rounds_per_s=6.0)
    assert rep["n_requests"] == 6 and rep["completed"] == 6
    assert rep["goodput_tok_per_unit"] > 0
    assert {"p50", "p99"} <= set(rep["ttft"])
    for cls, d in rep["per_class"].items():
        assert d["n"] > 0
        assert d["ttft"]["p99"] >= d["ttft"]["p50"] >= 0.0
    # every emitted token is attributed to exactly one request
    assert rep["emitted_tokens"] == sum(len(v)
                                        for v in rep["streams"].values())


# ---------------------------------------------------------------------------
# open-loop session semantics
# ---------------------------------------------------------------------------

def test_open_loop_mid_stream_submit(real_engine):
    """Submitting while earlier requests are mid-flight must interleave
    into the same slots — and the session must go idle (step() == []) and
    wake again on later submissions."""
    cfg, model, params, ads, mt = real_engine
    ses = mt.session(_sc())
    prompt = (np.arange(10, dtype=np.int32) % 290) + 1
    r0 = ses.submit(Request("c0", prompt, max_new_tokens=6))
    got = {r0: []}
    for _ in range(3):
        for rid, toks, fin in ses.step():
            got[rid].extend(toks)
    r1 = ses.submit(Request("c1", prompt[:5], max_new_tokens=4))
    got[r1] = []
    while ses.has_work:
        for rid, toks, fin in ses.step():
            got[rid].extend(toks)
    assert ses.step() == []                  # idle, not an error
    assert len(got[r0]) == 6 and len(got[r1]) == 4
    r2 = ses.submit(Request("c0", prompt[:3], max_new_tokens=3))
    got[r2] = []
    while ses.has_work:
        for rid, toks, fin in ses.step():
            got[rid].extend(toks)
    assert len(got[r2]) == 3
    stats = ses.finalize()
    assert stats["open_loop"] is True


def test_open_loop_requires_pinned_pool(real_engine):
    cfg, model, params, ads, mt = real_engine
    with pytest.raises(ValueError):
        mt.session(ServeConfig(batch_size=4, num_blocks=None))


def test_closed_loop_stats_have_no_wall_waits(real_engine):
    """generate() (closed loop, no arrival times) keeps round-based
    queue waits only — the wall-clock keys would be meaningless."""
    cfg, model, params, ads, mt = real_engine
    prompt = (np.arange(8, dtype=np.int32) % 290) + 1
    reqs = [Request(f"c{i % 2}", prompt, max_new_tokens=4)
            for i in range(4)]
    mt.generate(reqs, _sc())
    stats = mt.last_stats
    assert stats["open_loop"] is False
    assert stats["classes"]
    for cs in stats["classes"].values():
        assert "wait_wall_ms_p50" not in cs
        assert "wait_p50" in cs


# ---------------------------------------------------------------------------
# asyncio front end
# ---------------------------------------------------------------------------

def test_async_server_serves_and_drains(real_engine):
    from repro.launch.serve import AsyncServer

    cfg, model, params, ads, mt = real_engine
    prompt = (np.arange(9, dtype=np.int32) % 290) + 1

    async def run():
        out = {}
        async with AsyncServer(mt, _sc()) as srv:
            async def client(i):
                await asyncio.sleep(0.002 * i)
                rid = await srv.submit(
                    Request(f"c{i % 2}", prompt[: 3 + i],
                            max_new_tokens=3 + i))
                toks = []
                async for t in srv.stream(rid):
                    toks.extend(t)
                out[rid] = toks
            await asyncio.gather(*(client(i) for i in range(3)))
        return out, srv.stats

    out, stats = asyncio.run(run())
    assert sorted(out) == [0, 1, 2]
    for rid, toks in out.items():
        assert len(toks) == 3 + rid
    # driven with arrival times -> wall-clock waits in the stats
    assert any("wait_wall_ms_p50" in cs
               for cs in stats["classes"].values())


def test_async_server_rejects_after_drain(real_engine):
    from repro.launch.serve import AsyncServer

    cfg, model, params, ads, mt = real_engine
    prompt = (np.arange(6, dtype=np.int32) % 290) + 1

    async def run():
        srv = AsyncServer(mt, _sc()).start()
        rid = await srv.submit(Request("c0", prompt, max_new_tokens=2))
        toks = []
        async for t in srv.stream(rid):
            toks.extend(t)
        await srv.drain()
        with pytest.raises(RuntimeError):
            await srv.submit(Request("c0", prompt))
        return toks

    assert len(asyncio.run(run())) == 2


# ---------------------------------------------------------------------------
# device views must be snapshots (async-dispatch safety)
# ---------------------------------------------------------------------------

def test_device_tables_snapshot_not_view():
    """On CPU, ``jnp.asarray`` may alias a suitably aligned numpy buffer
    zero-copy.  The overlapped session dispatches chunks that read the
    block tables/lengths/ids and only synchronizes later, while the host
    keeps mutating those buffers in place — so every device view handed
    to a dispatch must be a SNAPSHOT.  Aliasing depends on allocator
    alignment luck, so probe many fresh pools."""
    from repro.serving.kv_cache import PagedKVCache

    for _ in range(20):
        kv = PagedKVCache(num_slots=4, block_size=4, num_blocks=8,
                          max_blocks_per_slot=2)
        kv.admit(0, scope="c0")
        kv.ensure(0, 4)
        bt, lens = kv.device_tables()
        before_bt = np.asarray(bt).copy()
        before_lens = np.asarray(lens).copy()
        kv.block_tables[:] = 77          # host keeps planning the next chunk
        kv.lengths[:] = 55
        np.testing.assert_array_equal(np.asarray(bt), before_bt)
        np.testing.assert_array_equal(np.asarray(lens), before_lens)
