"""Serve-during-update: online FDLoRA re-registration hot-swaps the
serving bank mid-stream.  Untouched clients' greedy streams stay bitwise
stable across the swap; the updated client's prefix-cache scope is
invalidated exactly once per version bump; the real
``FDLoRATrainer.stage2_round`` -> ``publish`` loop interleaves with live
serving."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import tiny_dense
from repro.core.fdlora import FDLoRAConfig, FDLoRATrainer
from repro.core.lora import init_adapters
from repro.data.pipeline import SFTBatcher
from repro.data.synthetic import gen_log_dataset
from repro.data.tokenizer import ByteTokenizer
from repro.models.api import get_model
from repro.serving.engine import (MultiTenantEngine, Request, ServeConfig)
from repro.serving.registry import AdapterRegistry
from repro.serving.sharded import ShardedAdapterRegistry

CLIENT_RANKS = {"c0": 2, "c1": 4, "c2": 8}


def _client_adapters(cfg, seed, rank):
    ad = init_adapters(jax.random.PRNGKey(seed), cfg, rank=rank)
    bump = jax.random.PRNGKey(seed + 99)
    return jax.tree.map(
        lambda l: l + 0.02 * jax.random.normal(bump, l.shape), ad)


@pytest.fixture(scope="module")
def setup():
    cfg = tiny_dense()
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _registry(cfg, shards):
    if shards == 1:
        reg = AdapterRegistry(cfg, capacity=3, ranks=[2, 4, 8])
    else:
        reg = ShardedAdapterRegistry(cfg, capacity=6, num_shards=2,
                                     ranks=[2, 4, 8])
    for i, (cid, rk) in enumerate(CLIENT_RANKS.items()):
        reg.register(cid, _client_adapters(cfg, i + 1, rk))
    return reg


def _requests(cfg):
    prompt = np.arange(8, dtype=np.int32) % cfg.vocab_size
    order = ["c0", "c1", "c2", "c0", "c2", "c1"]
    return [Request(c, prompt, max_new_tokens=6) for c in order], order


def _drive(mt, reqs, sc, update_at=None, update_fn=None):
    """Step a closed-loop session to completion, firing ``update_fn``
    between rounds ``update_at`` steps in.  Returns (streams, stats)."""
    ses = mt.session(sc, reqs)
    got = {i: [] for i in range(len(reqs))}
    steps = 0
    while ses.has_work:
        for rid, toks, _fin in ses.step():
            got[rid].extend(toks)
        steps += 1
        if update_at is not None and steps == update_at:
            update_fn()
    return got, ses.finalize()


# ---------------------------------------------------------------------------
# Tentpole acceptance: hot-swap mid-serve, untouched clients bitwise stable
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("backend", ["jnp", "pallas"])
@pytest.mark.parametrize("shards", [1, 2])
def test_hot_swap_untouched_clients_bitwise_stable(setup, backend, shards):
    cfg, model, params = setup
    reqs, order = _requests(cfg)
    sc = ServeConfig(batch_size=2 * shards, max_new_tokens=6, block_size=4,
                     num_blocks=1 + 8 * shards, prefill_chunk=4,
                     paged_backend=backend, num_shards=shards)

    mt_base = MultiTenantEngine(model, cfg, params, _registry(cfg, shards))
    base, st_base = _drive(mt_base, reqs, sc)
    assert st_base["adapter_bank_refreshes"] == 0

    reg = _registry(cfg, shards)
    mt = MultiTenantEngine(model, cfg, params, reg)
    v0 = reg.version("c1")

    def update():           # online update lands for c1 mid-stream
        reg.register("c1", _client_adapters(cfg, 41, CLIENT_RANKS["c1"]))
    upd, st = _drive(mt, reqs, sc, update_at=2, update_fn=update)
    assert st["adapter_bank_refreshes"] >= 1
    assert reg.version("c1") == v0 + 1
    changed = False
    for rid, cid in enumerate(order):
        if cid == "c1":     # the updated client may (and should) diverge
            changed |= upd[rid] != base[rid]
            continue
        np.testing.assert_array_equal(
            np.asarray(upd[rid], np.int32), np.asarray(base[rid], np.int32),
            err_msg=f"untouched client {cid} (rid {rid}) drifted "
                    f"across the hot-swap")
    assert changed, "the updated client's mid-flight stream never moved " \
                    "(swap had no observable effect)"


def test_hot_swap_int8_kv_untouched_stable(setup):
    """The swap composes with quantized KV pools: untouched clients'
    int8-served streams are bitwise identical to an int8 run without the
    update."""
    cfg, model, params = setup
    reqs, order = _requests(cfg)
    sc = ServeConfig(batch_size=2, max_new_tokens=6, block_size=4,
                     num_blocks=9, prefill_chunk=4, kv_dtype="int8")
    mt_base = MultiTenantEngine(model, cfg, params, _registry(cfg, 1))
    base, _ = _drive(mt_base, reqs, sc)
    reg = _registry(cfg, 1)
    mt = MultiTenantEngine(model, cfg, params, reg)
    upd, st = _drive(mt, reqs, sc, update_at=2, update_fn=lambda:
                     reg.register("c1", _client_adapters(cfg, 41, 4)))
    assert st["adapter_bank_refreshes"] >= 1 and st["kv_dtype"] == "int8"
    for rid, cid in enumerate(order):
        if cid != "c1":
            np.testing.assert_array_equal(np.asarray(upd[rid], np.int32),
                                          np.asarray(base[rid], np.int32))


def test_hot_swap_applies_new_weights_next_session(setup):
    """After the swap drains, a fresh stream for the updated client serves
    the NEW adapter: bitwise equal to a registry built with those weights
    from scratch."""
    cfg, model, params = setup
    prompt = np.arange(8, dtype=np.int32) % cfg.vocab_size
    sc = ServeConfig(batch_size=2, max_new_tokens=6, block_size=4,
                     num_blocks=9, prefill_chunk=4)
    new_c1 = _client_adapters(cfg, 41, 4)

    reg = _registry(cfg, 1)
    mt = MultiTenantEngine(model, cfg, params, reg)
    reqs = [Request("c1", prompt, max_new_tokens=6)]
    _drive(mt, reqs, sc, update_at=1, update_fn=lambda:
           reg.register("c1", new_c1))
    after, _ = _drive(mt, reqs, sc)

    fresh_reg = AdapterRegistry(cfg, capacity=3, ranks=[2, 4, 8])
    for i, (cid, rk) in enumerate(CLIENT_RANKS.items()):
        fresh_reg.register(cid, new_c1 if cid == "c1"
                           else _client_adapters(cfg, i + 1, rk))
    fresh, _ = _drive(MultiTenantEngine(model, cfg, params, fresh_reg),
                      reqs, sc)
    np.testing.assert_array_equal(np.asarray(after[0], np.int32),
                                  np.asarray(fresh[0], np.int32))


# ---------------------------------------------------------------------------
# Prefix-cache scope: one version bump invalidates exactly once
# ---------------------------------------------------------------------------

def test_version_bump_invalidates_prefix_scope_exactly_once(setup):
    cfg, model, params = setup
    reg = _registry(cfg, 1)
    mt = MultiTenantEngine(model, cfg, params, reg)
    pre = (np.arange(12, dtype=np.int32) * 3 + 1) % cfg.vocab_size
    mk = lambda tail: np.concatenate([pre, np.asarray(tail, np.int32)])
    # single-request probes: a second same-client request would re-match
    # blocks sealed INTRA-call and muddy the post-bump hit accounting
    reqs_c0 = [Request("c0", mk([5, 9]), max_new_tokens=4)]
    reqs_c2 = [Request("c2", mk([7, 7]), max_new_tokens=4)]
    sc = ServeConfig(batch_size=2, max_new_tokens=4, block_size=4,
                     num_blocks=24, prefill_chunk=4, prefix_cache=True)
    mt.release_prefix_cache()
    mt.generate(reqs_c0, sc)                       # cold: seeds the cache
    mt.generate(reqs_c2, sc)
    out_warm = mt.generate(reqs_c0, sc)            # warm under version 1
    assert mt.last_stats["prefix_hit_tokens"] > 0

    reg.register("c0", _client_adapters(cfg, 77, CLIENT_RANKS["c0"]))
    out_v2a = mt.generate(reqs_c0, sc)             # scope moved: no hits
    st = mt.last_stats
    assert st["prefix_hit_tokens"] == 0, \
        "stale K/V served after the adapter update"
    # the new weights actually changed the served tokens
    assert (np.asarray(out_warm[0]) != np.asarray(out_v2a[0])).any()
    out_v2b = mt.generate(reqs_c0, sc)             # re-cached under v2
    assert mt.last_stats["prefix_hit_tokens"] > 0, \
        "invalidation must happen exactly once per bump, not forever"
    for a, b in zip(out_v2a, out_v2b):
        np.testing.assert_array_equal(a, b)
    # the untouched client's scope (and cached blocks) survived the bump
    mt.generate(reqs_c2, sc)
    assert mt.last_stats["prefix_hit_tokens"] > 0
    mt.release_prefix_cache()


# ---------------------------------------------------------------------------
# The real loop: stage2_round training interleaved with live serving
# ---------------------------------------------------------------------------

def test_stage2_publish_interleaves_with_live_serving(setup):
    """FDLoRA continual learning end to end: a live session streams while
    ``stage2_round`` + ``publish`` push client1's refreshed Eq. 7 fusion
    into the registry — client0's stream is bitwise unaffected."""
    cfg, model, params = setup
    rng = np.random.default_rng(0)
    tok = ByteTokenizer()
    batchers = [SFTBatcher(gen_log_dataset(rng, 12, i), tok, 64, 2, seed=i)
                for i in range(2)]
    fed = FDLoRAConfig(n_clients=2, rounds=1, inner_steps=1, sync_every=1,
                       stage1_steps=1, fusion_steps=1, few_shot_k=2)
    tr = FDLoRATrainer(model, cfg, fed, params)
    clients = tr.stage1(batchers)
    tr.stage3(clients, batchers)                   # fusion weights for Eq. 7

    reg = AdapterRegistry(cfg, capacity=3)
    slots = tr.publish(reg, clients)
    assert set(slots) == {"client0", "client1"}
    assert reg.version("client0") == 1

    mt = MultiTenantEngine(model, cfg, params, reg)
    prompt = np.arange(8, dtype=np.int32) % cfg.vocab_size
    reqs = [Request("client0", prompt, max_new_tokens=6),
            Request("client1", prompt, max_new_tokens=6),
            Request("client0", prompt, max_new_tokens=6)]
    sc = ServeConfig(batch_size=2, max_new_tokens=6, block_size=4,
                     num_blocks=13, prefill_chunk=4)
    base, _ = _drive(mt, reqs, sc)

    def train_and_publish():                       # one federated round
        tr.stage2_round(1, clients, batchers)
        tr.publish(reg, [clients[1]], client_ids=["client1"])
    upd, st = _drive(mt, reqs, sc, update_at=2, update_fn=train_and_publish)
    assert st["adapter_bank_refreshes"] >= 1
    assert reg.version("client1") == 2 and reg.version("client0") == 1
    np.testing.assert_array_equal(np.asarray(upd[0], np.int32),
                                  np.asarray(base[0], np.int32))
    np.testing.assert_array_equal(np.asarray(upd[2], np.int32),
                                  np.asarray(base[2], np.int32))


def test_stage2_on_round_hook_fires_every_round(setup):
    cfg, model, params = setup
    rng = np.random.default_rng(1)
    tok = ByteTokenizer()
    batchers = [SFTBatcher(gen_log_dataset(rng, 12, i), tok, 64, 2, seed=i)
                for i in range(2)]
    fed = FDLoRAConfig(n_clients=2, rounds=3, inner_steps=1, sync_every=1,
                       stage1_steps=1)
    tr = FDLoRATrainer(model, cfg, fed, params)
    clients = tr.stage1(batchers)
    seen = []
    tr.stage2(clients, batchers,
              on_round=lambda t, cl: seen.append((t, len(cl))))
    assert seen == [(1, 2), (2, 2), (3, 2)]
