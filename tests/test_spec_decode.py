"""Speculative decoding: drafter, KV rollback, scheduler and engine.

Bottom-up coverage of the draft-then-verify path:

  * ``propose_draft`` — prompt-lookup drafting is a pure function of the
    slot's history (longest trailing n-gram, most recent match wins);
  * ``PagedKVCache.rollback`` — token-granular undo: lengths, block
    tables, sealing chain and pending tail rewind exactly, tail blocks
    return to the pool, and co-owned sealed content is REFUSED (the
    refcount >= 2 guard) before anything mutates;
  * ``check_invariants`` — the rollback-era checks actually fire on
    corrupted states (negative tests);
  * ``Scheduler`` — a preemption landing while drafts are in flight
    requeues prompt+emitted ONLY (drafts never leak into a replay);
  * the REAL jitted engine — speculative greedy streams are bitwise the
    non-speculative streams on both paged backends, through preemption
    and a warm prefix cache, with acceptance/rollback stats exposed.
"""
import dataclasses

import numpy as np
import pytest

from repro.serving.kv_cache import PagedKVCache, blocks_needed
from repro.serving.scheduler import Scheduler
from repro.serving.spec_decode import propose_draft

from test_serving_sim import real_engine, _single_tenant_ref  # noqa: F401


# ---------------------------------------------------------------------------
# propose_draft: prompt-lookup drafting
# ---------------------------------------------------------------------------

def test_draft_continues_most_recent_ngram_match():
    # trailing [8, 9] last occurred at positions 4-5; continuation is [7, 3]
    h = [8, 9, 1, 2, 8, 9, 7, 3, 8, 9]
    assert propose_draft(h, k=2) == [7, 3]


def test_draft_prefers_longest_ngram():
    # trailing [1, 2, 3] matches at the start (-> 4); the shorter [2, 3]
    # also matches later with a DIFFERENT continuation — the 3-gram wins
    h = [1, 2, 3, 4, 9, 2, 3, 8, 1, 2, 3]
    assert propose_draft(h, k=1, max_ngram=3) == [4]


def test_draft_prefers_most_recent_occurrence():
    # [5] occurs twice; the drafter continues from the LATEST earlier one
    h = [5, 1, 5, 2, 5]
    assert propose_draft(h, k=1, max_ngram=1) == [2]


def test_draft_empty_without_match_or_budget():
    assert propose_draft([1, 2, 3, 4], k=4) == []       # nothing repeats
    assert propose_draft([1, 1, 1], k=0) == []          # no budget
    assert propose_draft([7], k=4) == []                # history too short
    assert propose_draft([], k=4) == []


def test_draft_truncated_by_k_and_history_end():
    h = [1, 2, 3, 4, 5, 1, 2]
    # match at 0-1, continuation [3, 4, 5, 1, ...] capped at k
    assert propose_draft(h, k=3) == [3, 4, 5]
    assert propose_draft(h, k=10) == [3, 4, 5, 1, 2]    # runs off the end


def test_draft_is_pure_and_does_not_mutate():
    h = [1, 2, 1, 2, 1, 2]
    before = list(h)
    # trailing [2,1,2] recurs at position 1 -> continuation h[4:6]
    out1, out2 = propose_draft(h, k=2), propose_draft(h, k=2)
    assert out1 == out2 == [1, 2]
    assert h == before


# ---------------------------------------------------------------------------
# PagedKVCache.rollback
# ---------------------------------------------------------------------------

def _fresh_kv(prefix_cache=False, num_slots=2, bs=4, blocks=12, mbps=5):
    return PagedKVCache(num_slots, bs, blocks, mbps,
                        prefix_cache=prefix_cache)


def test_rollback_trims_length_and_frees_tail_blocks():
    kv = _fresh_kv()
    kv.admit(0)
    assert kv.ensure(0, 10)                 # 3 blocks of 4
    kv.advance(0, 10, tokens=list(range(10)))
    free_before = kv.free_blocks
    freed = kv.rollback(0, 5)               # keep 2 blocks
    assert freed == 1
    assert int(kv.lengths[0]) == 5
    assert kv.owned_blocks(0) == 2
    assert kv.free_blocks == free_before + 1
    kv.check_invariants()
    # the slot keeps working: grow and advance again
    assert kv.ensure(0, 9)
    kv.advance(0, 4, tokens=[9, 9, 9, 9])
    kv.check_invariants()


def test_rollback_to_current_length_is_a_noop():
    kv = _fresh_kv()
    kv.admit(0)
    kv.ensure(0, 6)
    kv.advance(0, 6, tokens=list(range(6)))
    assert kv.rollback(0, 6) == 0
    assert int(kv.lengths[0]) == 6
    kv.check_invariants()


def test_rollback_bounds_and_occupancy_validated():
    kv = _fresh_kv()
    with pytest.raises(ValueError, match="not occupied"):
        kv.rollback(0, 0)
    kv.admit(0)
    kv.ensure(0, 4)
    kv.advance(0, 4, tokens=[1, 2, 3, 4])
    with pytest.raises(ValueError, match="outside"):
        kv.rollback(0, 5)
    with pytest.raises(ValueError, match="outside"):
        kv.rollback(0, -1)


def test_rollback_rewinds_sealing_chain_exactly():
    """Unsealing must rewind the digest chain and refill the pending tail
    so RE-advancing the same tokens reproduces the identical digests —
    the property that keeps prefix-cache hits correct after speculation."""
    kv = _fresh_kv(prefix_cache=True)
    toks = list(range(100, 110))            # 2 sealed blocks + 2 pending
    kv.admit(0, scope="c0", tokens=toks)
    kv.ensure(0, 10)
    kv.advance(0, 10, tokens=toks)
    chain_full = kv._chain[0]
    index_full = dict(kv._index)
    # roll back into the FIRST block (unseals both, partial refill)
    freed = kv.rollback(0, 3)
    assert int(kv.lengths[0]) == 3
    assert kv._nseal[0] == 0
    assert kv._pending[0] == toks[:3]
    assert freed == 2                       # ceil(3/4)=1 block kept of 3
    kv.check_invariants()
    # re-advance the same suffix: chain and index converge to the originals
    kv.ensure(0, 10)
    kv.advance(0, 7, tokens=toks[3:])
    assert kv._chain[0] == chain_full
    assert set(index_full) <= set(kv._index)
    kv.check_invariants()


def test_rollback_partial_block_keeps_seal_boundary():
    kv = _fresh_kv(prefix_cache=True)
    toks = list(range(9))                   # 2 sealed + 1 pending
    kv.admit(0, scope="s", tokens=toks)
    kv.ensure(0, 9)
    kv.advance(0, 9, tokens=toks)
    # 8 is a seal boundary: drop only the pending token, unseal nothing
    assert kv.rollback(0, 8) == 1           # 3rd block freed
    assert kv._nseal[0] == 2
    assert kv._pending[0] == []
    kv.check_invariants()


def test_rollback_refuses_coowned_sealed_blocks():
    """A sealed block mapped into ANOTHER slot's table (refcount >= 2) is
    live shared context — rolling it back must raise before mutating."""
    kv = _fresh_kv(prefix_cache=True)
    toks = list(range(50, 62))
    kv.admit(0, scope="c", tokens=toks)
    kv.ensure(0, 12)
    kv.advance(0, 12, tokens=toks)          # 3 sealed blocks
    hit = kv.admit(1, scope="c", tokens=np.asarray(toks, np.int32))
    assert hit == 8                         # slot 1 co-owns 2 blocks
    before = (int(kv.lengths[0]), kv._nseal[0], list(kv._owned[0]))
    with pytest.raises(ValueError, match="co-owned"):
        kv.rollback(0, 4)                   # would unseal co-owned block 2
    # the guard fired BEFORE any mutation
    assert (int(kv.lengths[0]), kv._nseal[0], list(kv._owned[0])) == before
    kv.check_invariants()
    # rolling back only PRIVATE content (block 3 + pending) is still fine
    assert kv.rollback(0, 8) >= 0
    kv.check_invariants()


def test_invariants_catch_length_past_table_capacity():
    kv = _fresh_kv()
    kv.admit(0)
    kv.ensure(0, 4)
    kv.advance(0, 4, tokens=[0, 1, 2, 3])
    kv.lengths[0] = kv.max_blocks_per_slot * kv.block_size + 1
    with pytest.raises(AssertionError):
        kv.check_invariants()


def test_invariants_catch_freed_block_still_referenced():
    kv = _fresh_kv()
    kv.admit(0)
    kv.ensure(0, 4)
    kv.advance(0, 4, tokens=[0, 1, 2, 3])
    kv._free.append(int(kv.block_tables[0, 0]))   # corrupt: freed AND mapped
    with pytest.raises(AssertionError):
        kv.check_invariants()


def test_invariants_catch_chain_history_desync():
    kv = _fresh_kv(prefix_cache=True)
    toks = list(range(8))
    kv.admit(0, scope="x", tokens=toks)
    kv.ensure(0, 8)
    kv.advance(0, 8, tokens=toks)
    kv._chain_stack[0].pop()                      # corrupt seal history
    with pytest.raises(AssertionError):
        kv.check_invariants()


# ---------------------------------------------------------------------------
# Scheduler: drafts never leak through preemption
# ---------------------------------------------------------------------------

def _drive_to_verify(sched, prefill_chunk=4, decode_cap=8):
    """Admit + chunk until prepare_chunk plans a verify round; the sim
    in test_serving_sim covers full execution — here we only need the
    scheduler to reach the drafted state."""
    from test_serving_sim import _next_token
    for _ in range(100):
        sched.admit()
        plan = sched.prepare_chunk(prefill_chunk, decode_cap)
        assert plan is not None
        if plan[0] == "verify":
            return
        K = sched.kv.num_slots
        if plan[0] == "prefill":
            arrs = sched.prefill_arrays(prefill_chunk)
            sampled = np.zeros((K,), np.int32)
            for s in range(K):
                if arrs["n_new"][s]:
                    st = sched._slots[s]
                    hist = ([int(t) for t in st.prompt[:st.fed]]
                            + [int(t) for t in
                               arrs["tokens"][s, :arrs["n_new"][s]]])
                    sampled[s] = _next_token(hist)
            sched.observe_prefill(arrs["n_new"], sampled)
        else:
            n = plan[1]
            arr = sched.chunk_arrays()
            block = np.tile(arr["last"], (n, 1))
            sched.observe_chunk(block)
    raise AssertionError("never reached a verify plan")


def test_preemption_mid_verify_requeues_without_drafts():
    """Preempt a slot AFTER drafting but BEFORE observe_verify: the
    requeued prompt must be prompt+emitted exactly — the draft (planning-
    local state) must not leak into the replay."""
    kv = PagedKVCache(2, 4, 16, 8)
    sched = Scheduler(kv, spec_k=4)
    prompt = np.asarray([3, 4, 3, 4, 3, 4, 3], np.int32)
    sched.submit(0, "c0", prompt, budget=8)
    sched.submit(1, "c0", prompt[:5], budget=6)
    _drive_to_verify(sched)
    drafted = [s for s in sched.active_slots if sched._slots[s].draft]
    assert drafted, "verify plan with no drafted slot"
    slot = drafted[0]
    st = sched._slots[slot]
    want = np.concatenate([st.prompt,
                           np.asarray(st.emitted, np.int32)]
                          ) if st.emitted else st.prompt
    draft = list(st.draft)
    rid = sched.preempt(slot)
    q_rid, _cid, q_prompt, q_budget, _prior = sched._queue[0]
    assert q_rid == rid
    np.testing.assert_array_equal(q_prompt, want)
    # the drafted continuation is a repeat — make the leak check explicit:
    # the requeued prompt is strictly shorter than prompt+emitted+draft
    assert q_prompt.size == want.size < want.size + len(draft)
    kv.check_invariants()


def test_scheduler_rejects_negative_spec_k():
    kv = PagedKVCache(1, 4, 8, 4)
    with pytest.raises(ValueError, match="spec_k"):
        Scheduler(kv, spec_k=-1)


def test_draft_capped_by_budget_and_table_capacity():
    """k <= remaining-1 (the bonus token covers the last emission) and
    k <= capacity - length - 1 (the verify write must fit the table)."""
    kv = PagedKVCache(1, 4, 16, 3)          # capacity 12 tokens
    sched = Scheduler(kv, spec_k=8)
    prompt = np.asarray([5, 5, 5, 5, 5, 5], np.int32)
    sched.submit(0, "c0", prompt, budget=4)
    sched.admit()
    kv.ensure(0, 6)
    kv.advance(0, 6, tokens=[int(t) for t in prompt])
    sched._slots[0].fed = 6
    sched._slots[0].next_token = 5
    draft = sched._draft(0)
    # remaining=4 -> k<=3; capacity 12 - length 6 - 1 -> k<=5; budget wins
    assert 0 < len(draft) <= 3


# ---------------------------------------------------------------------------
# Real engine: bitwise parity on both backends + stats
# ---------------------------------------------------------------------------

def _spec_reqs(cfg):
    from repro.serving.engine import Request
    pre = (np.arange(12, dtype=np.int32) * 3 + 1) % cfg.vocab_size
    return [Request("c0", pre, max_new_tokens=24),
            Request("c1", pre[:9], max_new_tokens=20),
            Request("c0", pre[:6], max_new_tokens=16)]


def _spec_cfg(**kw):
    from repro.serving.engine import ServeConfig
    base = dict(batch_size=2, max_new_tokens=24, block_size=4,
                num_blocks=40, prefill_chunk=4)
    base.update(kw)
    return ServeConfig(**base)


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_real_engine_spec_parity_both_backends(real_engine, backend):
    """Speculative greedy decoding through the jitted engine emits the
    BITWISE stream of plain decoding on both paged backends, and the
    speculative path demonstrably engaged (draft/verify/rollback stats)."""
    cfg, model, params, ads, mt = real_engine
    reqs = _spec_reqs(cfg)
    sc = _spec_cfg(paged_backend=backend)
    base = mt.generate(reqs, sc)
    spec = mt.generate(reqs, dataclasses.replace(sc, spec_decode=True,
                                                 spec_k=4))
    for b, s in zip(base, spec):
        np.testing.assert_array_equal(b, s)
    stats = mt.last_stats
    assert stats["spec_decode"] is True
    assert stats["verify_dispatches"] > 0
    assert stats["drafted_tokens"] > 0
    assert stats["accepted_tokens"] > 0
    assert stats["rollback_tokens"] >= 0
    assert 0.0 <= stats["acceptance_rate"] <= 1.0


def test_real_engine_spec_parity_under_preemption(real_engine):
    """Starved pool with speculation in flight: preemptions fire and the
    stream stays bitwise non-speculative (accepted tokens survive the
    requeue; drafts never do)."""
    cfg, model, params, ads, mt = real_engine
    reqs = _spec_reqs(cfg)
    base = mt.generate(reqs, _spec_cfg())
    sc = _spec_cfg(batch_size=3, num_blocks=10, spec_decode=True, spec_k=4)
    spec = mt.generate(reqs, sc)
    assert mt.last_stats["preemptions"] > 0
    assert mt.last_stats["verify_dispatches"] > 0
    for b, s in zip(base, spec):
        np.testing.assert_array_equal(b, s)


def test_real_engine_spec_parity_warm_prefix_cache(real_engine):
    """Speculation over a warm content-addressed pool: admissions skip
    cached prefixes, verify rounds seal/rollback on the same chains, and
    the stream is still bitwise non-speculative."""
    cfg, model, params, ads, mt = real_engine
    reqs = _spec_reqs(cfg)
    base = mt.generate(reqs, _spec_cfg())
    sc = _spec_cfg(spec_decode=True, spec_k=4, prefix_cache=True)
    mt.release_prefix_cache()
    mt.generate(reqs, sc)                   # seed the cache
    spec = mt.generate(reqs, sc)            # warm pass
    assert mt.last_stats["prefix_hit_tokens"] > 0
    assert mt.last_stats["verify_dispatches"] > 0
    for b, s in zip(base, spec):
        np.testing.assert_array_equal(b, s)
    mt.release_prefix_cache()


def test_real_engine_spec_stream_yields_accepted_runs(real_engine):
    """generate_stream under speculation: events reassemble exactly into
    generate()'s results and at least one event carries a multi-token
    accepted run (the point of speculating)."""
    cfg, model, params, ads, mt = real_engine
    reqs = _spec_reqs(cfg)
    sc = _spec_cfg(spec_decode=True, spec_k=4)
    got = {i: [] for i in range(len(reqs))}
    multi = 0
    finishes = []
    for rid, toks, finished in mt.generate_stream(reqs, sc):
        got[rid].extend(toks)
        multi += len(toks) > 1
        if finished:
            finishes.append(rid)
    assert sorted(finishes) == [0, 1, 2]
    assert multi > 0, "no multi-token accepted runs streamed"
    outs = mt.generate(reqs, _spec_cfg())
    for i, o in enumerate(outs):
        np.testing.assert_array_equal(np.asarray(got[i], np.int32), o)


def test_spec_decode_is_greedy_only(real_engine):
    cfg, model, params, ads, mt = real_engine
    reqs = _spec_reqs(cfg)
    with pytest.raises(ValueError, match="greedy"):
        list(mt.generate_stream(
            reqs, _spec_cfg(spec_decode=True, temperature=0.7)))
    with pytest.raises(ValueError, match="spec_k"):
        list(mt.generate_stream(reqs, _spec_cfg(spec_decode=True, spec_k=0)))
