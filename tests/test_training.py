"""Optimizers, loss, checkpointing, serving engine."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import rand_batch, tiny_dense
from repro.core.lora import init_adapters, lora_scale
from repro.models.api import get_model
from repro.serving.engine import Engine, ServeConfig
from repro.training.checkpoint import load_checkpoint, save_checkpoint
from repro.training.optimizers import (adamw, apply_updates,
                                       clip_by_global_norm, cosine_schedule,
                                       sgd)
from repro.training.train_step import (cross_entropy, make_lora_train_step)


def test_adamw_first_step_is_lr_sized():
    p = {"w": jnp.zeros((4,))}
    opt = adamw(lr=0.1, weight_decay=0.0)
    st = opt.init(p)
    g = {"w": jnp.full((4,), 3.0)}
    upd, st = opt.update(g, st, p)
    # bias-corrected first Adam step = -lr * sign(g)
    np.testing.assert_allclose(np.asarray(upd["w"]), -0.1, atol=1e-4)


def test_adamw_decoupled_weight_decay():
    p = {"w": jnp.full((2,), 10.0)}
    opt = adamw(lr=0.1, weight_decay=0.5)
    st = opt.init(p)
    g = {"w": jnp.zeros((2,))}
    upd, _ = opt.update(g, st, p)
    np.testing.assert_allclose(np.asarray(upd["w"]), -0.1 * 0.5 * 10.0, atol=1e-5)


def test_sgd_nesterov_vs_plain():
    p = {"w": jnp.zeros((1,))}
    g = {"w": jnp.ones((1,))}
    plain = sgd(lr=1.0, momentum=0.9)
    nest = sgd(lr=1.0, momentum=0.9, nesterov=True)
    sp, sn = plain.init(p), nest.init(p)
    up, sp = plain.update(g, sp, p)
    un, sn = nest.update(g, sn, p)
    assert abs(float(un["w"][0])) > abs(float(up["w"][0]))  # lookahead larger


def test_clip_by_global_norm():
    g = {"a": jnp.full((3,), 10.0)}
    c = clip_by_global_norm(g, 1.0)
    norm = float(jnp.linalg.norm(c["a"]))
    assert abs(norm - 1.0) < 1e-5


def test_cosine_schedule_bounds():
    sched = cosine_schedule(warmup=10, total=100, floor=0.1)
    vals = [float(sched(jnp.int32(i))) for i in (1, 10, 50, 100, 200)]
    assert vals[0] < 1.0 and abs(vals[1] - 1.0) < 1e-5
    assert all(0.1 - 1e-6 <= v <= 1.0 for v in vals[1:])


def test_cross_entropy_masking():
    cfg = tiny_dense()
    B, S, V = 2, 8, cfg.vocab_size
    logits = jnp.zeros((B, S, V))
    batch = {"tokens": jnp.ones((B, S), jnp.int32),
             "loss_mask": jnp.zeros((B, S), jnp.int32)}
    batch["loss_mask"] = batch["loss_mask"].at[:, -2:].set(1)
    loss, m = cross_entropy(cfg, logits, batch)
    np.testing.assert_allclose(float(loss), np.log(V), rtol=1e-5)
    assert float(m["tokens"]) == 2 * 2  # only masked-in positions count


def test_lora_training_reduces_loss():
    cfg = tiny_dense()
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = rand_batch(cfg, B=4, S=16)
    opt = adamw(lr=1e-2)
    step = jax.jit(make_lora_train_step(model, cfg, opt))
    ad = init_adapters(jax.random.PRNGKey(1), cfg)
    st = opt.init(ad)
    losses = []
    for _ in range(20):
        ad, st, m = step(params, ad, st, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] * 0.9, losses


def test_checkpoint_roundtrip(tmp_path):
    cfg = tiny_dense()
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    path = os.path.join(tmp_path, "ckpt.npz")
    save_checkpoint(path, params, metadata={"step": 7})
    back = load_checkpoint(path)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(back)):
        assert a.dtype == b.dtype
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32))


def test_engine_generates_deterministically():
    cfg = tiny_dense()
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = Engine(model, cfg, params)
    prompts = jnp.ones((2, 5), jnp.int32)
    sc = ServeConfig(batch_size=2, max_new_tokens=6, cache_len=32)
    out1 = eng.generate(prompts, sc)
    out2 = eng.generate(prompts, sc)
    assert out1.shape == (2, 6)
    assert jnp.array_equal(out1, out2)  # greedy
    assert bool((out1 >= 0).all()) and bool((out1 < cfg.vocab_size).all())
