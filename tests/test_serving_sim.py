"""Randomized serving-simulation harness for the continuous-batching stack.

Hand-written unit tests stop covering the scheduler's state space once
on-demand block growth and preemption enter: admission order, chunk
boundaries, pool pressure, EOS placement and preemption victims interact
combinatorially.  This harness samples thousands of workloads (ragged
prompts, mixed budgets, pool sizes down to near-starvation) and drives the
REAL ``Scheduler`` + ``PagedKVCache`` through the exact engine loop
(admit -> prepare_chunk -> dispatch -> observe), replacing only the device
model with a deterministic host token function — so every schedule the
real engine could produce is checked against a single-tenant greedy oracle
token-for-token, with block-accounting invariants asserted after every
chunk:

  * free list + owned blocks always partition {1..num_blocks-1}
  * no block owned twice; tables name owned blocks in position order
  * lengths[slot] <= len(owned) * block_size
  * per-slot context mirror matches lengths exactly

Preemption conservation rides the same driver: a starved pool must emit
exactly the same tokens as a full-residency pool (prompt+emitted requeue
loses nothing), and a progress bound over the simulator rules out
livelock.  A small randomized subset runs the REAL jitted engine
(chunked paged prefill + decode on device) against the single-tenant
``Engine`` oracle, including a forced-starvation pool.

When ``hypothesis`` is installed the same driver runs under ``@given``
with a bounded ``ci`` profile (fast on PRs) and an opt-in ``deep``
profile (``HYPOTHESIS_PROFILE=deep``, scheduled CI) — a failing workload
shrinks to a minimal prompt/budget/pool counterexample instead of a
500-seed haystack.
"""
import dataclasses
import os
from typing import Dict, List, Optional, Tuple

import numpy as np
import pytest

from repro.serving.kv_cache import PagedKVCache, blocks_needed
from repro.serving.scheduler import Scheduler, newest_victim
from repro.serving.sharded import ShardedPagedKVCache, ShardedScheduler

VOCAB = 50


# ---------------------------------------------------------------------------
# Deterministic host "model" + single-tenant greedy oracle
# ---------------------------------------------------------------------------

def _next_token(ctx: List[int]) -> int:
    """Pure function of the fed context — stands in for greedy decoding."""
    h = 0
    for t in ctx:
        h = (h * 31 + int(t) + 7) % 100003
    return h % VOCAB


def _cyclic_token(ctx: List[int]) -> int:
    """Eventually-periodic host model: emissions cycle 0..6, so a
    prompt-lookup drafter converges to near-perfect acceptance — the
    high-acceptance regime for speculative decoding."""
    return (int(ctx[-1]) + 1) % 7


def _oracle(prompt, budget: int, eos_id: Optional[int],
            token_fn=_next_token) -> List[int]:
    ctx = [int(t) for t in prompt]
    out: List[int] = []
    for _ in range(budget):
        tok = token_fn(ctx)
        out.append(tok)
        ctx.append(tok)
        if eos_id is not None and tok == eos_id:
            break
    return out


# ---------------------------------------------------------------------------
# Workloads
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Workload:
    requests: List[Tuple[str, np.ndarray, int]]   # (client_id, prompt, budget)
    num_slots: int
    block_size: int
    num_blocks: int                               # incl. scratch block 0
    prefill_chunk: int
    decode_cap: int
    eos_id: Optional[int]
    prefix_cache: bool = False                    # content-addressed blocks
    priorities: Optional[List[str]] = None        # per-request class (None
    #                                               = all "batch")
    deadlines: Optional[List[Optional[float]]] = None
    policy: str = "sla"                           # sla | fcfs
    aging: int = 16                               # rounds per promotion
    victim: Optional[str] = None                  # None = policy default;
    #                                               "newest" isolates victim
    #                                               choice from admission
    spec_k: int = 0                               # >0: draft-then-verify
    spec_ngram: int = 3
    client_ranks: Optional[Dict[str, int]] = None  # per-client LoRA rank:
    #                                               drives a ragged-bucket
    #                                               AdapterRegistry alongside
    #                                               the sim (churn + invariant
    #                                               checks; token parity is
    #                                               adapter-independent here)

    @property
    def max_span(self) -> int:
        return max(p.size + b for _, p, b in self.requests)

    def priority(self, rid: int) -> str:
        return self.priorities[rid] if self.priorities else "batch"


def gen_workload(rng: np.random.Generator) -> Workload:
    n_req = int(rng.integers(1, 9))
    requests = []
    for i in range(n_req):
        plen = int(rng.integers(1, 21))
        budget = int(rng.integers(1, 17))
        prompt = rng.integers(0, VOCAB, plen).astype(np.int32)
        requests.append((f"c{int(rng.integers(0, 3))}", prompt, budget))
    block_size = int(rng.choice([2, 3, 4, 8]))
    num_slots = int(rng.integers(1, 5))
    mbps = blocks_needed(max(p.size + b for _, p, b in requests), block_size)
    # pool from near-starvation (one request's span, preemption-heavy) up
    # to full residency for every slot
    extra = int(rng.integers(0, mbps * num_slots + 1))
    num_blocks = 1 + mbps + extra
    eos_id = int(rng.integers(0, VOCAB)) if rng.random() < 0.5 else None
    return Workload(requests, num_slots, block_size, num_blocks,
                    prefill_chunk=int(rng.integers(1, 9)),
                    decode_cap=int(rng.integers(1, 9)), eos_id=eos_id)


# ---------------------------------------------------------------------------
# Ragged-rank registry riding along with the sim
# ---------------------------------------------------------------------------

_SIM_RANK_BUCKETS = [2, 4, 8]      # fixed buckets; drawn ranks 1..8 exercise
#                                    both exact-fit and zero-padded placement
_SIM_CFG = None
_SIM_TREES: Dict[int, object] = {}


def _sim_adapter_tree(rank: int):
    """A cached tiny adapter tree at ``rank`` (content is irrelevant — the
    sim's token function never reads the bank; only layout is checked)."""
    if rank not in _SIM_TREES:
        import jax
        from conftest import tiny_dense
        from repro.core.lora import init_adapters
        global _SIM_CFG
        if _SIM_CFG is None:
            _SIM_CFG = tiny_dense()
        _SIM_TREES[rank] = init_adapters(jax.random.PRNGKey(rank), _SIM_CFG,
                                         rank=rank)
    return _SIM_TREES[rank]


def _sim_adapter_registry(client_ranks: Dict[str, int]):
    """A deliberately tiny ragged registry (ONE slot per rank bucket) so
    clients sharing a bucket churn each other under realistic admission
    orders."""
    from repro.serving.registry import AdapterRegistry
    _sim_adapter_tree(2)                           # builds _SIM_CFG
    reg = AdapterRegistry(_SIM_CFG, capacity=len(_SIM_RANK_BUCKETS),
                          ranks=_SIM_RANK_BUCKETS)
    for cid in sorted(client_ranks):
        reg.register(cid, _sim_adapter_tree(client_ranks[cid]))
    return reg


def _registry_invariants(reg) -> None:
    """Allocator invariants for the bucketed bank, checked after every
    admission: slot uniqueness, smallest-covering bucket membership, and
    per-bucket free/resident partition."""
    slots = list(reg._lru.values())
    assert len(set(slots)) == len(slots), f"slot owned twice: {slots}"
    sr = reg.slot_ranks()
    for cid, slot in reg._lru.items():
        b, local = reg.bucket_of_slot(slot)
        rank = reg._client_rank[cid]
        assert b == reg._bucket_for(rank), \
            f"{cid} (rank {rank}) in bucket {b}, not its smallest cover"
        assert 0 <= local < reg.bucket_sizes[b]
        assert sr[slot] == rank, f"slot_ranks()[{slot}] != {rank}"
    for b, size in enumerate(reg.bucket_sizes):
        resident = {reg.bucket_of_slot(s)[1] for s in slots
                    if reg.bucket_of_slot(s)[0] == b}
        free = set(reg._free[b])
        assert free | resident == set(range(size)), \
            f"bucket {b}: free {free} + resident {resident} != 0..{size}"
        assert not (free & resident), \
            f"bucket {b}: slots both free and resident: {free & resident}"


# ---------------------------------------------------------------------------
# The simulator: the engine loop with a host model
# ---------------------------------------------------------------------------

def run_sim(w: Workload, token_fn=_next_token) -> Scheduler:
    """Drive Scheduler+PagedKVCache exactly as ``generate_stream`` does and
    verify oracle parity, streaming consistency and block invariants.
    With ``w.prefix_cache`` the pool is content-addressed: admissions may
    skip past a matched prefix, whose cached token ids are verified against
    the prompt before being trusted as fed context.  With ``w.spec_k``
    decode rounds become draft-then-verify: a verify plan scores the
    feedback token + draft per slot in one causal pass (greedy at every
    position, exactly what the chunked-prefill dispatch returns) and the
    accepted run extends the context mirror — rejected positions must
    vanish from the cache via rollback, which the post-chunk invariants
    and length mirror catch."""
    mbps = blocks_needed(w.max_span, w.block_size)
    kv = PagedKVCache(w.num_slots, w.block_size, w.num_blocks, mbps,
                      prefix_cache=w.prefix_cache)
    sched = Scheduler(kv, policy=w.policy, aging_ticks=w.aging,
                      victim_policy={"newest": newest_victim,
                                     None: None}[w.victim],
                      spec_k=w.spec_k, spec_ngram=w.spec_ngram)
    for rid, (cid, prompt, budget) in enumerate(w.requests):
        sched.submit(rid, cid, prompt, budget, scope=cid,
                     priority=w.priority(rid),
                     deadline=w.deadlines[rid] if w.deadlines else None)
    reg = (_sim_adapter_registry(w.client_ranks)
           if w.client_ranks is not None else None)
    sched.sim_registry = reg                      # exposed for sweep stats

    ctx = {s: [] for s in range(w.num_slots)}     # per-slot fed-token mirror
    streamed = {rid: [] for rid in range(len(w.requests))}
    finish_events = {rid: 0 for rid in range(len(w.requests))}
    total_work = sum(p.size + b for _, p, b in w.requests)
    budget_iters = 50 * total_work + 200          # livelock / progress bound
    iters = 0
    while sched.has_work:
        iters += 1
        assert iters <= budget_iters, \
            f"progress bound exceeded ({iters} chunks): scheduler livelock"
        for slot, _cid in sched.admit():
            if reg is not None:
                # the serving engine acquires the client's bank slot on
                # every admission; churned-out clients re-register first
                if _cid not in reg:
                    reg.register(_cid, _sim_adapter_tree(
                        w.client_ranks[_cid]))
                reg.acquire(_cid)
                _registry_invariants(reg)
            st = sched._slots[slot]
            # a prefix hit seeds the context with the matched prompt span;
            # the cached blocks must name EXACTLY those tokens
            ctx[slot] = [int(t) for t in st.prompt[:st.fed]]
            if st.fed:
                cached = [t for b in kv._owned[slot][:kv._nseal[slot]]
                          for t in kv._block_tokens[b]]
                assert cached == ctx[slot], \
                    f"slot {slot} matched wrong tokens: {cached} != {ctx[slot]}"
        plan = sched.prepare_chunk(w.prefill_chunk, w.decode_cap)
        kv.check_invariants()                      # after growth/preemption
        assert plan is not None, "stalled with queued work"
        K = w.num_slots
        if plan[0] == "prefill":
            arrs = sched.prefill_arrays(w.prefill_chunk)
            sampled = np.zeros((K,), np.int32)
            for s in range(K):
                n = int(arrs["n_new"][s])
                if n == 0:
                    continue
                ctx[s].extend(int(t) for t in arrs["tokens"][s, :n])
                sampled[s] = token_fn(ctx[s])
            events = sched.observe_prefill(arrs["n_new"], sampled,
                                           eos_id=w.eos_id)
        elif plan[0] == "verify":
            width = 1 + w.spec_k
            arrs = sched.verify_arrays(width)
            # greedy[s, t]: the model's choice after feeding positions
            # 0..t of the chunk — one causal pass, like the device dispatch
            greedy = np.zeros((K, width), np.int32)
            for s in range(K):
                n = int(arrs["n_new"][s])
                if n == 0:
                    continue
                probe = list(ctx[s])
                for t in range(n):
                    probe.append(int(arrs["tokens"][s, t]))
                    greedy[s, t] = token_fn(probe)
            pre_len = {s: int(kv.lengths[s]) for s in range(K)}
            events = sched.observe_verify(arrs["n_new"], greedy,
                                          eos_id=w.eos_id)
            for s in range(K):
                # surviving slots keep feedback + accepted drafts only;
                # finished slots were released (mirror resets on re-admit)
                if int(arrs["n_new"][s]) and sched._slots[s] is not None:
                    acc = int(kv.lengths[s]) - pre_len[s]
                    ctx[s].extend(int(arrs["tokens"][s, t])
                                  for t in range(acc))
        else:
            n = plan[1]
            arr = sched.chunk_arrays()
            block = np.zeros((n, K), np.int32)
            last = arr["last"].copy()
            for t in range(n):
                for s in range(K):
                    if arr["active"][s]:
                        ctx[s].append(int(last[s]))
                        block[t, s] = token_fn(ctx[s])
                        last[s] = block[t, s]
            events = sched.observe_chunk(block, eos_id=w.eos_id)
        kv.check_invariants()
        for s in sched.active_slots:               # mirror == device lengths
            assert kv.lengths[s] == len(ctx[s]), (s, kv.lengths[s], len(ctx[s]))
        for rid, toks, finished in events:
            streamed[rid].extend(toks)
            finish_events[rid] += finished

    for rid, (cid, prompt, budget) in enumerate(w.requests):
        want = _oracle(prompt, budget, w.eos_id, token_fn)
        got = list(sched.results[rid])
        assert got == want, (
            f"rid {rid}: oracle parity broken\n got {got}\nwant {want}")
        # streaming increments reassemble the result; exactly one finish
        assert streamed[rid] == want
        assert finish_events[rid] == 1
    assert all(s is None for s in sched._slots)
    # everything released: cached-free blocks stay retained (allocatable)
    assert kv.free_blocks + kv.cached_blocks == kv.num_blocks - 1
    if not w.prefix_cache:
        assert kv.cached_blocks == 0
    if reg is not None:
        _registry_invariants(reg)
    return sched


# ---------------------------------------------------------------------------
# 500+ seeded workloads (runs everywhere, no hypothesis needed)
# ---------------------------------------------------------------------------

def test_simulation_500_randomized_workloads():
    preemptions = 0
    starved = 0
    for seed in range(520):
        rng = np.random.default_rng(seed)
        w = gen_workload(rng)
        if w.num_blocks - 1 < blocks_needed(w.max_span, w.block_size) * min(
                w.num_slots, len(w.requests)):
            starved += 1                           # pool below full residency
        sched = run_sim(w)
        preemptions += sched.preemptions
    # the sample must actually exercise the interesting regimes
    assert starved > 50, f"only {starved} starvation workloads sampled"
    assert preemptions > 20, f"only {preemptions} preemptions exercised"


def test_ragged_registry_churn_150_seeded_workloads():
    """150 seeded workloads with per-client LoRA ranks drawn 1..8: the
    one-slot-per-bucket registry churns under realistic admission orders
    while oracle parity and allocator invariants hold unchanged — and the
    per-client weight version stays monotone through the churn."""
    churn = 0
    padded = 0
    for seed in range(150):
        rng = np.random.default_rng(3000 + seed)
        w = dataclasses.replace(
            gen_workload(rng),
            client_ranks={f"c{j}": int(rng.integers(1, 9))
                          for j in range(3)})
        sched = run_sim(w)
        reg = sched.sim_registry
        churn += reg.evictions
        padded += sum(1 for r in w.client_ranks.values()
                      if r not in _SIM_RANK_BUCKETS)
        for cid in w.client_ranks:
            assert reg.version(cid) >= 1           # monotone, never reset
    assert churn > 50, f"only {churn} registry evictions exercised"
    assert padded > 50, f"only {padded} zero-padded (off-bucket) ranks drawn"


def test_preemption_conserves_output_tokens():
    """Starved pool (preemption-heavy) must emit exactly what a
    full-residency pool (never preempts) emits, request for request."""
    checked = 0
    for seed in range(40):
        rng = np.random.default_rng(1000 + seed)
        w = gen_workload(rng)
        if len(w.requests) < 2:
            continue
        mbps = blocks_needed(w.max_span, w.block_size)
        roomy = dataclasses.replace(
            w, num_blocks=1 + mbps * w.num_slots)
        starved = dataclasses.replace(w, num_blocks=1 + mbps)
        s_roomy = run_sim(roomy)
        s_starved = run_sim(starved)
        for rid in range(len(w.requests)):
            np.testing.assert_array_equal(s_roomy.results[rid],
                                          s_starved.results[rid])
        checked += s_starved.preemptions
    assert checked > 0, "starved pools never triggered preemption"


def gen_shared_prefix_workload(rng: np.random.Generator) -> Workload:
    """The prefix-cache profile: per-client system prompts — every request
    is ``client_prefix[:k] + fresh suffix`` — over a content-addressed pool
    so admissions re-match blocks sealed by earlier requests (and by their
    own preempted incarnations)."""
    prefixes = {f"c{i}": rng.integers(0, VOCAB, 16).astype(np.int32)
                for i in range(2)}
    n_req = int(rng.integers(2, 9))
    requests = []
    for _ in range(n_req):
        cid = f"c{int(rng.integers(0, 2))}"
        k = int(rng.integers(4, 17))
        suffix = rng.integers(0, VOCAB, int(rng.integers(1, 6)))
        prompt = np.concatenate([prefixes[cid][:k],
                                 suffix]).astype(np.int32)
        requests.append((cid, prompt, int(rng.integers(1, 13))))
    block_size = int(rng.choice([2, 3, 4]))
    num_slots = int(rng.integers(1, 5))
    mbps = blocks_needed(max(p.size + b for _, p, b in requests), block_size)
    extra = int(rng.integers(0, mbps * num_slots + 1))
    eos_id = int(rng.integers(0, VOCAB)) if rng.random() < 0.3 else None
    return Workload(requests, num_slots, block_size, 1 + mbps + extra,
                    prefill_chunk=int(rng.integers(1, 9)),
                    decode_cap=int(rng.integers(1, 9)), eos_id=eos_id,
                    prefix_cache=True)


def test_shared_prefix_simulation_sweep():
    """200 seeded shared-prefix workloads over the content-addressed pool:
    oracle parity and refcount invariants hold chunk by chunk, and the
    profile actually exercises hits, sharing and preemption re-matching."""
    hit_tokens = 0
    preemptions = 0
    for seed in range(200):
        rng = np.random.default_rng(5000 + seed)
        w = gen_shared_prefix_workload(rng)
        sched = run_sim(w)
        hit_tokens += sched.prefix_hit_tokens
        preemptions += sched.preemptions
    assert hit_tokens > 500, f"only {hit_tokens} cached tokens served"
    assert preemptions > 10, f"only {preemptions} preemptions exercised"


def test_preempted_requests_rematch_under_starvation():
    """Starved shared-prefix pools: preempted requests replay prompt+emitted
    and must re-match their own sealed blocks (hits strictly above the
    no-preemption admission hits), with results equal to a roomy pool."""
    rematch_hits = 0
    for seed in range(30):
        rng = np.random.default_rng(9000 + seed)
        w = gen_shared_prefix_workload(rng)
        if len(w.requests) < 2:
            continue
        mbps = blocks_needed(w.max_span, w.block_size)
        roomy = dataclasses.replace(w, num_blocks=1 + mbps * w.num_slots)
        starved = dataclasses.replace(w, num_blocks=1 + mbps)
        s_roomy = run_sim(roomy)
        s_starved = run_sim(starved)
        for rid in range(len(w.requests)):
            np.testing.assert_array_equal(s_roomy.results[rid],
                                          s_starved.results[rid])
        if s_starved.preemptions:
            rematch_hits += max(0, s_starved.prefix_hit_tokens
                                - s_roomy.prefix_hit_tokens)
    assert rematch_hits > 0, \
        "preemption replays never re-matched their sealed blocks"


def test_progress_bound_under_forced_thrash():
    """Worst-case pool (exactly one request's span) with many long
    requests: completes within the simulator's progress bound (run_sim
    asserts it) and every preempted request still matches the oracle."""
    prompts = [np.arange(i, i + 12, dtype=np.int32) % VOCAB
               for i in range(6)]
    w = Workload([("c0", p, 10) for p in prompts],
                 num_slots=3, block_size=4,
                 num_blocks=1 + blocks_needed(22, 4),
                 prefill_chunk=4, decode_cap=4, eos_id=None)
    sched = run_sim(w)
    assert sched.preemptions > 0


# ---------------------------------------------------------------------------
# Priority classes: SLA admission + aging + scored victims through the sim
# ---------------------------------------------------------------------------

CLASSES = ("interactive", "batch", "background")


def gen_priority_workload(rng: np.random.Generator) -> Workload:
    """The SLA profile: contended pools (few slots, deep queues) with a
    random mix of priority classes and occasional deadlines — the regime
    where admission order and victim choice actually matter."""
    n_req = int(rng.integers(4, 11))
    requests, priorities, deadlines = [], [], []
    for i in range(n_req):
        plen = int(rng.integers(1, 16))
        budget = int(rng.integers(1, 13))
        requests.append((f"c{int(rng.integers(0, 3))}",
                         rng.integers(0, VOCAB, plen).astype(np.int32),
                         budget))
        priorities.append(str(rng.choice(CLASSES)))
        deadlines.append(float(rng.integers(0, 50))
                         if rng.random() < 0.3 else None)
    block_size = int(rng.choice([2, 3, 4]))
    num_slots = int(rng.integers(1, 3))           # deep queues: 1-2 slots
    mbps = blocks_needed(max(p.size + b for _, p, b in requests), block_size)
    extra = int(rng.integers(0, mbps + 1))        # mostly starved pools
    eos_id = int(rng.integers(0, VOCAB)) if rng.random() < 0.3 else None
    return Workload(requests, num_slots, block_size, 1 + mbps + extra,
                    prefill_chunk=int(rng.integers(1, 7)),
                    decode_cap=int(rng.integers(1, 7)), eos_id=eos_id,
                    priorities=priorities, deadlines=deadlines,
                    aging=int(rng.choice([2, 4, 16])))


def test_priority_mix_sweep_no_starvation():
    """150 seeded priority-mix workloads under the SLA policy: every
    request completes with oracle token parity inside run_sim's progress
    bound (starvation-freedom — aging guarantees queued work is admitted),
    refcount invariants hold chunk by chunk, and across the sweep the
    interactive class waits less than background for admission."""
    waits = {c: [] for c in CLASSES}
    preemptions = 0
    for seed in range(150):
        rng = np.random.default_rng(20_000 + seed)
        w = gen_priority_workload(rng)
        sched = run_sim(w)                        # parity + progress bound
        preemptions += sched.preemptions
        for cname, ticks in sched.wait_ticks.items():
            waits[cname].extend(ticks)
    assert preemptions > 20, f"only {preemptions} preemptions exercised"
    assert all(len(waits[c]) > 50 for c in CLASSES), \
        f"class coverage too thin: { {c: len(v) for c, v in waits.items()} }"
    # admission preference must show up in aggregate queue waits
    assert np.mean(waits["interactive"]) < np.mean(waits["background"]), (
        f"interactive waited {np.mean(waits['interactive']):.2f} ticks vs "
        f"background {np.mean(waits['background']):.2f}")


def test_priority_conservation_starved_vs_roomy():
    """Preemption conservation is policy-independent: a starved pool under
    the SLA victim policy emits exactly what a roomy pool emits, request
    for request, on priority-mix workloads."""
    checked = 0
    for seed in range(30):
        rng = np.random.default_rng(30_000 + seed)
        w = gen_priority_workload(rng)
        if len(w.requests) < 2:
            continue
        mbps = blocks_needed(w.max_span, w.block_size)
        roomy = dataclasses.replace(w, num_blocks=1 + mbps * w.num_slots)
        starved = dataclasses.replace(w, num_blocks=1 + mbps)
        s_roomy = run_sim(roomy)
        s_starved = run_sim(starved)
        for rid in range(len(w.requests)):
            np.testing.assert_array_equal(s_roomy.results[rid],
                                          s_starved.results[rid])
        checked += s_starved.preemptions
    assert checked > 0, "starved pools never triggered preemption"


def _reprefilled(sched) -> int:
    """Prompt tokens actually pushed through prefill (admissions + replays
    minus cache hits) — the cost prefix-aware victim selection minimises."""
    return sched.prompt_tokens - sched.prefix_hit_tokens


def gen_anchored_shared_workload(rng: np.random.Generator) -> Workload:
    """The regime where victim CHOICE is structural, not noise (measured:
    under sustained thrash any victim's re-prefill is ~proportional to the
    blocks its release recovers, so policies tie — see docs/serving.md):

    * an ``interactive`` ANCHOR holds a sealed system prefix and decodes
      slowly (protected: oldest top-class, never preempted);
    * a ``batch`` RIDER whose prompt is that prefix + a small suffix —
      priority admission delays it past the anchor's sealing, so it admits
      matching blocks CO-OWNED with the live anchor (eviction-proof);
    * a stream of unique ``interactive`` requests keeps the pool churning.

    When growth runs dry with the rider and a unique request both active,
    newest-first preempts the unique one (nothing co-owned survives its
    release — the churn flushes its parked blocks) while the prefix-aware
    default preempts the rider, whose replay re-matches through the
    anchor.  Content is randomised; the block arithmetic is pinned so the
    choice point occurs every seed."""
    bs = 4
    P = rng.integers(0, VOCAB, 16).astype(np.int32)
    mk = lambda n: rng.integers(0, VOCAB, n).astype(np.int32)
    requests = [("c0", np.concatenate([P, mk(2)]).astype(np.int32), 12),
                ("c0", np.concatenate([P, mk(2)]).astype(np.int32), 2)]
    priorities = ["interactive", "batch"]
    for _ in range(5):
        requests.append(("c0", mk(16), 2))
        priorities.append("interactive")
    return Workload(requests, num_slots=3, block_size=bs, num_blocks=12,
                    prefill_chunk=8, decode_cap=2, eos_id=None,
                    prefix_cache=True, priorities=priorities)


def test_prefix_aware_victims_reduce_reprefill():
    """Seeded sweep: under identical (sla) admission, the prefix-aware
    victim policy must STRICTLY reduce re-prefilled tokens vs newest-first
    on every anchored shared-prefix workload, with oracle parity (asserted
    inside run_sim) on both."""
    total = {"sla": 0, "newest": 0}
    preemptions = 0
    for seed in range(40):
        rng = np.random.default_rng(seed)
        w = gen_anchored_shared_workload(rng)
        per = {}
        for victim in (None, "newest"):
            sched = run_sim(dataclasses.replace(w, victim=victim))
            per[victim or "sla"] = _reprefilled(sched)
            preemptions += sched.preemptions
        assert per["sla"] < per["newest"], (
            f"seed {seed}: prefix-aware victim must beat newest-first "
            f"({per['sla']} vs {per['newest']} re-prefilled tokens)")
        total["sla"] += per["sla"]
        total["newest"] += per["newest"]
    assert preemptions > 40, f"only {preemptions} preemptions exercised"
    assert total["sla"] < total["newest"]


# ---------------------------------------------------------------------------
# Speculative decoding: draft-verify-rollback through the sim
# ---------------------------------------------------------------------------

def gen_spec_workload(rng: np.random.Generator) -> Workload:
    """The speculative-decoding profile: repetitive prompts (tiled motifs
    plus a fresh tail) — the regime prompt-lookup drafting targets — over
    the same pool spectrum as :func:`gen_workload`, starvation included."""
    n_req = int(rng.integers(1, 7))
    requests = []
    for _ in range(n_req):
        motif = rng.integers(0, VOCAB, int(rng.integers(2, 6)))
        tail = rng.integers(0, VOCAB, int(rng.integers(0, 3)))
        prompt = np.concatenate(
            [np.tile(motif, int(rng.integers(2, 5))), tail]).astype(np.int32)
        requests.append((f"c{int(rng.integers(0, 3))}", prompt,
                         int(rng.integers(1, 17))))
    block_size = int(rng.choice([2, 3, 4, 8]))
    num_slots = int(rng.integers(1, 5))
    mbps = blocks_needed(max(p.size + b for _, p, b in requests), block_size)
    extra = int(rng.integers(0, mbps * num_slots + 1))
    eos_id = int(rng.integers(0, VOCAB)) if rng.random() < 0.3 else None
    return Workload(requests, num_slots, block_size, 1 + mbps + extra,
                    prefill_chunk=int(rng.integers(1, 9)),
                    decode_cap=int(rng.integers(1, 9)), eos_id=eos_id,
                    spec_k=int(rng.integers(1, 7)))


def test_spec_decode_bitwise_parity_sweep():
    """120 seeded spec workloads: the speculative stream must be BITWISE
    the non-speculative stream (both also oracle-checked inside run_sim),
    with the sweep actually exercising drafting, acceptance, rollback and
    preemption-under-spec."""
    drafted = accepted = rolled = verifies = preemptions = 0
    for seed in range(120):
        rng = np.random.default_rng(40_000 + seed)
        w = gen_spec_workload(rng)
        # hash model: drafts mostly REJECT (pseudorandom emissions) — the
        # rollback-heavy regime; periodic model: drafts mostly ACCEPT —
        # both must stay bitwise non-speculative
        fn = _cyclic_token if seed % 3 == 0 else _next_token
        s_spec = run_sim(w, token_fn=fn)
        s_base = run_sim(dataclasses.replace(w, spec_k=0), token_fn=fn)
        for rid in range(len(w.requests)):
            np.testing.assert_array_equal(s_spec.results[rid],
                                          s_base.results[rid])
        drafted += s_spec.drafted_tokens
        accepted += s_spec.accepted_tokens
        rolled += s_spec.rollback_tokens
        verifies += s_spec.verify_dispatches
        preemptions += s_spec.preemptions
    assert verifies > 100, f"only {verifies} verify dispatches"
    assert drafted > 200, f"only {drafted} tokens drafted"
    assert accepted > 50, f"only {accepted} tokens accepted"
    assert rolled > 50, f"rollback barely exercised ({rolled} tokens)"
    assert preemptions > 5, f"only {preemptions} preemptions under spec"


def test_spec_decode_starved_pool_conserves_tokens():
    """Preemption mid-speculation: a starved pool (drafts in flight when
    victims release) must emit exactly what a roomy pool emits — the
    requeued prompt is prompt+emitted ONLY, drafts never leak."""
    checked = 0
    for seed in range(40):
        rng = np.random.default_rng(50_000 + seed)
        w = gen_spec_workload(rng)
        if len(w.requests) < 2:
            continue
        mbps = blocks_needed(w.max_span, w.block_size)
        roomy = dataclasses.replace(w, num_blocks=1 + mbps * w.num_slots)
        starved = dataclasses.replace(w, num_blocks=1 + mbps)
        s_roomy = run_sim(roomy)
        s_starved = run_sim(starved)
        for rid in range(len(w.requests)):
            np.testing.assert_array_equal(s_roomy.results[rid],
                                          s_starved.results[rid])
        checked += s_starved.preemptions
    assert checked > 0, "starved spec pools never triggered preemption"


def test_spec_decode_with_prefix_cache_parity():
    """Spec decoding over a warm content-addressed pool: admissions skip
    matched prefixes AND verify rounds seal/rollback blocks on the same
    hash chains — streams stay bitwise non-speculative."""
    hit_tokens = verifies = 0
    for seed in range(40):
        rng = np.random.default_rng(60_000 + seed)
        w = gen_shared_prefix_workload(rng)
        w_spec = dataclasses.replace(w, spec_k=4)
        s_spec = run_sim(w_spec)
        s_base = run_sim(w)
        for rid in range(len(w.requests)):
            np.testing.assert_array_equal(s_spec.results[rid],
                                          s_base.results[rid])
        hit_tokens += s_spec.prefix_hit_tokens
        verifies += s_spec.verify_dispatches
    assert hit_tokens > 100, f"only {hit_tokens} cached tokens under spec"
    assert verifies > 50, f"only {verifies} verify dispatches"


def test_spec_decode_high_acceptance_on_periodic_model():
    """An eventually-periodic model is the drafter's best case: after
    warmup every draft matches, acceptance dominates, and most emitted
    tokens ride verify dispatches instead of decode steps."""
    rng = np.random.default_rng(3)
    requests = [("c0", (np.arange(8, dtype=np.int32) % 7), 24),
                ("c1", (np.arange(6, dtype=np.int32) % 7), 20),
                ("c0", rng.integers(0, 7, 5).astype(np.int32), 16)]
    mbps = blocks_needed(max(p.size + b for _, p, b in requests), 4)
    w = Workload(requests, num_slots=2, block_size=4,
                 num_blocks=1 + 2 * mbps, prefill_chunk=4, decode_cap=8,
                 eos_id=None, spec_k=4)
    sched = run_sim(w, token_fn=_cyclic_token)
    base = run_sim(dataclasses.replace(w, spec_k=0), token_fn=_cyclic_token)
    for rid in range(len(requests)):
        np.testing.assert_array_equal(sched.results[rid], base.results[rid])
    rate = sched.accepted_tokens / max(1, sched.drafted_tokens)
    assert rate > 0.8, f"acceptance only {rate:.2f} on a periodic model"
    assert sched.accepted_tokens > sched.steps, \
        "speculation should carry most tokens on a periodic model"


# ---------------------------------------------------------------------------
# Sharded serving: the same engine loop over partitioned pools
# ---------------------------------------------------------------------------

def _gslot_state(sched: ShardedScheduler, gslot: int):
    """Slot state behind a GLOBAL slot id (per-shard schedulers only know
    local slots)."""
    s, local = sched.kv.shard_of_slot(gslot)
    return sched.shards[s]._slots[local]


def run_sharded_sim(w: Workload, num_shards: int,
                    token_fn=_next_token) -> ShardedScheduler:
    """``run_sim`` against the sharded stack: each shard gets the
    workload's single-pool geometry (so starvation pressure per shard
    matches the unsharded run), the coordinator places requests and
    negotiates one fused round per chunk, and the SAME checks hold —
    oracle token parity per request, per-shard allocator invariants plus
    global block disjointness after every chunk (``check_invariants``),
    the per-slot context mirror, and per-shard end-state conservation
    (a starved shard settles independently of a roomy one)."""
    mbps = blocks_needed(w.max_span, w.block_size)
    kv = ShardedPagedKVCache(num_shards, w.num_slots * num_shards,
                             w.block_size,
                             1 + (w.num_blocks - 1) * num_shards, mbps,
                             prefix_cache=w.prefix_cache)
    sched = ShardedScheduler(kv, policy=w.policy, aging_ticks=w.aging,
                             victim_policy={"newest": newest_victim,
                                            None: None}[w.victim],
                             spec_k=w.spec_k, spec_ngram=w.spec_ngram)
    for rid, (cid, prompt, budget) in enumerate(w.requests):
        sched.submit(rid, cid, prompt, budget, scope=cid,
                     priority=w.priority(rid),
                     deadline=w.deadlines[rid] if w.deadlines else None)

    K = kv.num_slots                              # global fused slot axis
    ctx = {s: [] for s in range(K)}
    streamed = {rid: [] for rid in range(len(w.requests))}
    finish_events = {rid: 0 for rid in range(len(w.requests))}
    total_work = sum(p.size + b for _, p, b in w.requests)
    budget_iters = 50 * total_work + 200
    iters = 0
    while sched.has_work:
        iters += 1
        assert iters <= budget_iters, \
            f"progress bound exceeded ({iters} chunks): scheduler livelock"
        for slot, _cid in sched.admit():
            s_sh, local = kv.shard_of_slot(slot)
            st = sched.shards[s_sh]._slots[local]
            ctx[slot] = [int(t) for t in st.prompt[:st.fed]]
            if st.fed:                             # hit must be THIS shard's
                pool = kv.shards[s_sh]
                cached = [t for b in pool._owned[local][:pool._nseal[local]]
                          for t in pool._block_tokens[b]]
                assert cached == ctx[slot], \
                    f"slot {slot} matched wrong tokens: {cached} != {ctx[slot]}"
        plan = sched.prepare_chunk(w.prefill_chunk, w.decode_cap)
        kv.check_invariants()                      # per shard + disjointness
        assert plan is not None, "stalled with queued work"
        if plan[0] == "prefill":
            arrs = sched.prefill_arrays(w.prefill_chunk)
            sampled = np.zeros((K,), np.int32)
            for s in range(K):
                n = int(arrs["n_new"][s])
                if n == 0:
                    continue
                ctx[s].extend(int(t) for t in arrs["tokens"][s, :n])
                sampled[s] = token_fn(ctx[s])
            events = sched.observe_prefill(arrs["n_new"], sampled,
                                           eos_id=w.eos_id)
        elif plan[0] == "verify":
            width = 1 + w.spec_k
            arrs = sched.verify_arrays(width)
            greedy = np.zeros((K, width), np.int32)
            for s in range(K):
                n = int(arrs["n_new"][s])
                if n == 0:
                    continue
                probe = list(ctx[s])
                for t in range(n):
                    probe.append(int(arrs["tokens"][s, t]))
                    greedy[s, t] = token_fn(probe)
            pre = kv.lengths
            events = sched.observe_verify(arrs["n_new"], greedy,
                                          eos_id=w.eos_id)
            post = kv.lengths
            for s in range(K):
                if int(arrs["n_new"][s]) and _gslot_state(sched, s) is not None:
                    acc = int(post[s]) - int(pre[s])
                    ctx[s].extend(int(arrs["tokens"][s, t])
                                  for t in range(acc))
        else:
            n = plan[1]
            arr = sched.chunk_arrays()
            block = np.zeros((n, K), np.int32)
            last = arr["last"].copy()
            for t in range(n):
                for s in range(K):
                    if arr["active"][s]:
                        ctx[s].append(int(last[s]))
                        block[t, s] = token_fn(ctx[s])
                        last[s] = block[t, s]
            events = sched.observe_chunk(block, eos_id=w.eos_id)
        kv.check_invariants()
        lens = kv.lengths
        for s in sched.active_slots:
            assert lens[s] == len(ctx[s]), (s, lens[s], len(ctx[s]))
        for rid, toks, finished in events:
            streamed[rid].extend(toks)
            finish_events[rid] += finished

    results = sched.results
    for rid, (cid, prompt, budget) in enumerate(w.requests):
        want = _oracle(prompt, budget, w.eos_id, token_fn)
        got = list(results[rid])
        assert got == want, (
            f"rid {rid}: oracle parity broken\n got {got}\nwant {want}")
        assert streamed[rid] == want
        assert finish_events[rid] == 1
    assert all(st is None for sub in sched.shards for st in sub._slots)
    # conservation holds SHARD BY SHARD, not just in aggregate
    for sh in kv.shards:
        assert sh.free_blocks + sh.cached_blocks == sh.num_blocks - 1
        if not w.prefix_cache:
            assert sh.cached_blocks == 0
    return sched


def test_sharded_simulation_sweep():
    """120+ seeded workloads through the sharded stack, cycling all four
    profiles (plain, shared-prefix, speculative, priority/deadline) and
    2-3 shards: oracle parity, per-shard invariants and conservation hold
    on every seed (inside run_sharded_sim), and the sweep exercises the
    multi-shard regimes — both shards used, within-shard preemption,
    prefix hits and draft-verify rounds."""
    gens = (gen_workload, gen_shared_prefix_workload, gen_spec_workload,
            gen_priority_workload)
    preemptions = hit_tokens = drafted = 0
    multi_shard_used = 0
    for seed in range(120):
        rng = np.random.default_rng(70_000 + seed)
        w = gens[seed % 4](rng)
        num_shards = 3 if seed % 7 == 0 else 2
        sched = run_sharded_sim(w, num_shards)
        preemptions += sched.preemptions
        hit_tokens += sched.prefix_hit_tokens
        drafted += sched.drafted_tokens
        if len(set(sched.placements.values())) > 1:
            multi_shard_used += 1
    assert preemptions > 10, f"only {preemptions} preemptions exercised"
    assert hit_tokens > 100, f"only {hit_tokens} cached tokens served"
    assert drafted > 100, f"only {drafted} tokens drafted"
    assert multi_shard_used > 40, \
        f"placement spread shards on only {multi_shard_used} workloads"


def test_sharded_stream_matches_single_pool():
    """Greedy decoding is schedule-invariant, so routing requests across
    shards must not change a single emitted token: per-request results
    from the sharded stack equal the single-pool run bit for bit."""
    for seed in range(30):
        rng = np.random.default_rng(80_000 + seed)
        w = (gen_spec_workload if seed % 3 == 0 else gen_workload)(rng)
        single = run_sim(w)
        sharded = run_sharded_sim(w, num_shards=2)
        for rid in range(len(w.requests)):
            np.testing.assert_array_equal(single.results[rid],
                                          sharded.results[rid])


def test_sharded_conservation_starved_vs_roomy():
    """Per-shard preemption conservation: starving every shard's pool
    (each shard down to one request's span) emits exactly what roomy
    shards emit, request for request — preemption never leaks tokens
    across the shard boundary."""
    checked = 0
    for seed in range(30):
        rng = np.random.default_rng(90_000 + seed)
        w = gen_workload(rng)
        if len(w.requests) < 2:
            continue
        mbps = blocks_needed(w.max_span, w.block_size)
        roomy = dataclasses.replace(w, num_blocks=1 + mbps * w.num_slots)
        starved = dataclasses.replace(w, num_blocks=1 + mbps)
        s_roomy = run_sharded_sim(roomy, num_shards=2)
        s_starved = run_sharded_sim(starved, num_shards=2)
        for rid in range(len(w.requests)):
            np.testing.assert_array_equal(s_roomy.results[rid],
                                          s_starved.results[rid])
        checked += s_starved.preemptions
    assert checked > 0, "starved shards never triggered preemption"


def _drain_sharded(sched: ShardedScheduler, prefill_chunk=4, decode_cap=4):
    """Drive a sharded scheduler to completion with a constant-token host
    model (placement tests care about routing, not emissions)."""
    K = sched.kv.num_slots
    while sched.has_work:
        sched.admit()
        plan = sched.prepare_chunk(prefill_chunk, decode_cap)
        assert plan is not None
        if plan[0] == "prefill":
            arrs = sched.prefill_arrays(prefill_chunk)
            sched.observe_prefill(arrs["n_new"], np.ones((K,), np.int32))
        elif plan[0] == "verify":
            width = 1 + sched.spec_k
            arrs = sched.verify_arrays(width)
            sched.observe_verify(arrs["n_new"],
                                 np.ones((K, width), np.int32))
        else:
            sched.chunk_arrays()
            sched.observe_chunk(np.ones((plan[1], K), np.int32))


def test_shard_placement_prefix_affinity():
    """A follow-up sharing a served request's prompt routes to the shard
    that sealed those blocks — even when that shard is the more loaded
    one — and records a ``"prefix"`` placement."""
    kv = ShardedPagedKVCache(2, 4, 4, 1 + 8 * 2, 8, prefix_cache=True)
    sched = ShardedScheduler(kv)
    prompt = np.arange(12, dtype=np.int32)
    sched.submit(0, "c0", prompt, budget=2, scope="c0")
    home = sched.placements[0]
    _drain_sharded(sched)                         # seals c0's prefix blocks
    # load the prefix shard so least-loaded would pick the OTHER one
    sched.shards[home].submit(1, "cx", np.arange(4, dtype=np.int32), 1,
                              scope="cx")
    shard, why = sched.place("c9", "c0", prompt)
    assert (shard, why) == (home, "prefix")
    # a different scope can't see those blocks -> falls through to load
    other, why2 = sched.place("c9", "other-scope", prompt)
    assert why2 == "load" and other != home


def test_shard_placement_adapter_home_and_load_fallback():
    """Without a cached prefix the router follows the client's adapter
    home shard; clients with no resident adapter spread by load
    (active+queued, lowest index on ties)."""
    class _Reg:
        def shard_of(self, cid):
            return {"homed": 1}.get(cid)

    kv = ShardedPagedKVCache(2, 4, 4, 17, 4)
    sched = ShardedScheduler(kv, registry=_Reg())
    assert sched.place("homed", "homed", np.arange(4)) == (1, "adapter")
    assert sched.place("anon", "anon", np.arange(4)) == (0, "load")
    # queue depth drives the fallback: balanced round-robin under ties
    for rid, cid in enumerate(["a", "b", "c", "d"]):
        sched.submit(rid, cid, np.arange(6, dtype=np.int32), 2, scope=cid)
    assert [sched.placements[r] for r in range(4)] == [0, 1, 0, 1]
    assert sched.placed["load"] == 4


# ---------------------------------------------------------------------------
# hypothesis: same driver, shrinking counterexamples, ci/deep profiles
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
    settings.register_profile("ci", max_examples=60, deadline=None)
    settings.register_profile("deep", max_examples=1500, deadline=None)
    settings.load_profile(os.environ.get("HYPOTHESIS_PROFILE", "ci"))
except ImportError:                                # pragma: no cover
    HAVE_HYPOTHESIS = False

if HAVE_HYPOTHESIS:
    @st.composite
    def workloads(draw):
        n_req = draw(st.integers(1, 6))
        requests = []
        for i in range(n_req):
            prompt = np.asarray(
                draw(st.lists(st.integers(0, VOCAB - 1), min_size=1,
                              max_size=14)), np.int32)
            requests.append((f"c{i % 3}", prompt, draw(st.integers(1, 10))))
        block_size = draw(st.sampled_from([2, 3, 4]))
        num_slots = draw(st.integers(1, 4))
        mbps = blocks_needed(max(p.size + b for _, p, b in requests),
                             block_size)
        extra = draw(st.integers(0, mbps * num_slots))
        num_blocks = 1 + mbps + extra
        eos = draw(st.one_of(st.none(), st.integers(0, VOCAB - 1)))
        prios = draw(st.one_of(st.none(), st.lists(
            st.sampled_from(CLASSES), min_size=n_req, max_size=n_req)))
        ranks = draw(st.one_of(st.none(), st.fixed_dictionaries(
            {f"c{j}": st.integers(1, 8) for j in range(3)})))
        return Workload(requests, num_slots, block_size, num_blocks,
                        prefill_chunk=draw(st.integers(1, 6)),
                        decode_cap=draw(st.integers(1, 6)), eos_id=eos,
                        prefix_cache=draw(st.booleans()),
                        priorities=prios,
                        policy=draw(st.sampled_from(["sla", "fcfs"])),
                        aging=draw(st.sampled_from([0, 2, 16])),
                        spec_k=draw(st.sampled_from([0, 0, 2, 4])),
                        client_ranks=ranks)

    @given(workloads())
    def test_simulation_hypothesis(w):
        run_sim(w)


# ---------------------------------------------------------------------------
# Real-engine randomized spot checks (device chunked prefill + decode)
# ---------------------------------------------------------------------------

def _real_engine_setup():
    import jax
    from conftest import tiny_dense
    from repro.core.lora import init_adapters
    from repro.models.api import get_model
    from repro.serving.engine import MultiTenantEngine
    from repro.serving.registry import AdapterRegistry

    cfg = tiny_dense()
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    ads = {}
    for i in range(2):
        ad = init_adapters(jax.random.PRNGKey(i + 1), cfg)
        bump = jax.random.PRNGKey(i + 99)
        ads[f"c{i}"] = jax.tree.map(
            lambda l: l + 0.02 * jax.random.normal(bump, l.shape), ad)
    reg = AdapterRegistry(cfg, capacity=4)
    for cid, ad in ads.items():
        reg.register(cid, ad)
    return cfg, model, params, ads, MultiTenantEngine(model, cfg, params, reg)


@pytest.fixture(scope="module")
def real_engine():
    return _real_engine_setup()


def _real_workload(cfg, rng, n_req):
    """Random ragged requests pinned to one (span, shape) envelope so every
    seed reuses the same compiled prefill/decode programs."""
    from repro.serving.engine import Request
    reqs = [Request(f"c{rng.integers(0, 2)}",
                    (np.arange(12, dtype=np.int32) * 3 + 1) % cfg.vocab_size,
                    max_new_tokens=6)]             # span anchor: 12 + 6
    for _ in range(n_req - 1):
        plen = int(rng.integers(1, 13))
        budget = int(rng.integers(1, 7))
        prompt = rng.integers(0, cfg.vocab_size, plen).astype(np.int32)
        reqs.append(Request(f"c{rng.integers(0, 2)}", prompt,
                            max_new_tokens=budget))
    return reqs


def _single_tenant_ref(model, cfg, params, ad, prompt, budget):
    import jax.numpy as jnp
    from repro.serving.engine import Engine, ServeConfig
    sc = ServeConfig(batch_size=1, max_new_tokens=budget, cache_len=64)
    return np.asarray(Engine(model, cfg, params, ad).generate(
        jnp.asarray(np.asarray(prompt, np.int32))[None], sc))[0]


def test_real_engine_randomized_oracle_parity(real_engine):
    """Chunked paged prefill + decode through the jitted engine must match
    single-tenant greedy decoding token-for-token on random ragged
    mixed-client workloads."""
    from repro.serving.engine import ServeConfig
    cfg, model, params, ads, mt = real_engine
    sc = ServeConfig(batch_size=2, max_new_tokens=6, block_size=4,
                     num_blocks=24, prefill_chunk=4)
    for seed in (0, 1, 2, 3):
        rng = np.random.default_rng(seed)
        reqs = _real_workload(cfg, rng, n_req=4)
        outs = mt.generate(reqs, sc)
        assert mt.last_stats["prefill_dispatches"] > 0
        for r, o in zip(reqs, outs):
            ref = _single_tenant_ref(model, cfg, params, ads[r.client_id],
                                     r.prompt, r.max_new_tokens)
            np.testing.assert_array_equal(o, ref)


def test_real_engine_starved_pool_preempts_and_matches(real_engine):
    """Forced pool starvation on the real engine: preemption fires, and
    preempted-then-resumed requests emit exactly the tokens of an
    unpreempted single-tenant run."""
    from repro.serving.engine import ServeConfig
    cfg, model, params, ads, mt = real_engine
    rng = np.random.default_rng(7)
    reqs = _real_workload(cfg, rng, n_req=5)
    # span anchor 18 -> 5 blocks of 4; 3 slots want 15, pool holds 7
    sc = ServeConfig(batch_size=3, max_new_tokens=6, block_size=4,
                     num_blocks=8, prefill_chunk=4)
    outs = mt.generate(reqs, sc)
    assert mt.last_stats["preemptions"] > 0
    for r, o in zip(reqs, outs):
        ref = _single_tenant_ref(model, cfg, params, ads[r.client_id],
                                 r.prompt, r.max_new_tokens)
        np.testing.assert_array_equal(o, ref)


def test_real_engine_shared_prefix_profile_reports_hit_rate(real_engine):
    """The shared-prefix profile through the REAL jitted engine: warm runs
    report a >0 prefix hit rate in last_stats and stay token-identical to
    the single-tenant oracle."""
    import dataclasses as dc
    from repro.serving.engine import Request, ServeConfig
    cfg, model, params, ads, mt = real_engine
    pre = (np.arange(12, dtype=np.int32) * 3 + 1) % cfg.vocab_size
    reqs = [Request("c0", pre, max_new_tokens=6),
            Request("c0", np.concatenate([pre[:10],
                                          np.asarray([3, 4], np.int32)]),
                    max_new_tokens=5),
            Request("c1", pre[:11], max_new_tokens=4)]
    sc = ServeConfig(batch_size=2, max_new_tokens=6, block_size=4,
                     num_blocks=24, prefill_chunk=4, prefix_cache=True)
    mt.release_prefix_cache()
    mt.generate(reqs, sc)                          # seeds the cache
    outs = mt.generate(reqs, sc)                   # warm pass
    assert mt.last_stats["prefix_hit_rate"] > 0
    assert mt.last_stats["prefix_hit_tokens"] > 0
    for r, o in zip(reqs, outs):
        ref = _single_tenant_ref(model, cfg, params, ads[r.client_id],
                                 r.prompt, r.max_new_tokens)
        np.testing.assert_array_equal(o, ref)
    mt.release_prefix_cache()                      # don't leak warm state


def test_real_engine_stream_yields_incrementally(real_engine):
    """generate_stream yields (rid, tokens, finished) increments that
    reassemble exactly into generate()'s results, with tokens visible
    across multiple chunks (not one burst at drain)."""
    from repro.serving.engine import Request, ServeConfig
    cfg, model, params, ads, mt = real_engine
    prompt = (np.arange(12, dtype=np.int32) * 3 + 1) % cfg.vocab_size
    reqs = [Request("c0", prompt, max_new_tokens=6),
            Request("c1", prompt[:5], max_new_tokens=6),
            Request("c0", prompt[:8], max_new_tokens=4)]
    sc = ServeConfig(batch_size=2, max_new_tokens=6, block_size=4,
                     num_blocks=24, prefill_chunk=4, scan_chunk=2)
    got = {i: [] for i in range(len(reqs))}
    finishes = []
    n_events = 0
    for rid, toks, finished in mt.generate_stream(reqs, sc):
        got[rid].extend(toks)
        n_events += 1
        if finished:
            finishes.append(rid)
    assert n_events > len(reqs)                    # incremental, not one burst
    assert sorted(finishes) == [0, 1, 2]
    outs = mt.generate(reqs, sc)
    for i, o in enumerate(outs):
        np.testing.assert_array_equal(np.asarray(got[i], np.int32), o)
