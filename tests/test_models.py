"""Model-zoo behaviour: forwards, LoRA zero-init, decode consistency."""
import jax
import jax.numpy as jnp
import pytest

from conftest import rand_batch, tiny_dense, tiny_moe, tiny_ssm
from repro.configs.base import ModelConfig
from repro.core.lora import init_adapters, lora_scale
from repro.models.api import get_model

FAMILIES = {
    "dense": tiny_dense(),
    "dense_sw": tiny_dense(name="sw", sliding_window=6),
    "moe": tiny_moe(),
    "ssm": tiny_ssm(),
    "hybrid": tiny_dense(
        name="hy", family="hybrid",
        layer_pattern=("mamba+mlp", "mamba+moe", "attn+mlp", "mamba+moe"),
        n_layers=4, n_experts=4, n_experts_per_tok=2, ssm_d_state=16,
        ssm_head_dim=16, ssm_chunk=8),
    "vlm": tiny_dense(name="vlm", family="vlm", n_patch_tokens=8),
    "encdec": tiny_dense(
        name="ed", family="encdec", n_kv_heads=4, norm_type="layernorm",
        mlp_type="gelu", use_rope=False, tie_embeddings=True,
        n_encoder_layers=2, encoder_seq_len=24,
        lora_targets=("wq", "wv", "w_up", "w_out")),
}


def _batch_for(cfg, B=2, S=16):
    b = rand_batch(cfg, B, S)
    if cfg.family == "vlm":
        b["patch_embeds"] = jax.random.normal(
            jax.random.PRNGKey(5), (B, cfg.n_patch_tokens, cfg.d_model))
    if cfg.is_encdec:
        b["enc_embeds"] = jax.random.normal(
            jax.random.PRNGKey(6), (B, cfg.encoder_seq_len, cfg.d_model))
    return b


@pytest.mark.parametrize("fam", list(FAMILIES))
def test_forward_shapes_and_finite(fam):
    cfg = FAMILIES[fam]
    m = get_model(cfg)
    p = m.init(jax.random.PRNGKey(0))
    b = _batch_for(cfg)
    logits, aux = m.forward(p, b)
    S = b["tokens"].shape[1] + (cfg.n_patch_tokens if cfg.family == "vlm" else 0)
    assert logits.shape == (2, S, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("fam", list(FAMILIES))
def test_lora_zero_init_is_identity(fam):
    cfg = FAMILIES[fam]
    m = get_model(cfg)
    p = m.init(jax.random.PRNGKey(0))
    b = _batch_for(cfg)
    base, _ = m.forward(p, b)
    ad = init_adapters(jax.random.PRNGKey(1), cfg)
    with_ad, _ = m.forward(p, b, adapters=ad, lora_scale=lora_scale(cfg))
    assert jnp.allclose(base, with_ad, atol=1e-4)


@pytest.mark.parametrize("fam", ["dense", "dense_sw", "ssm", "hybrid"])
def test_decode_matches_forward(fam):
    cfg = FAMILIES[fam]
    m = get_model(cfg)
    p = m.init(jax.random.PRNGKey(0))
    B, S = 2, 16
    b = _batch_for(cfg, B, S)
    full, _ = m.forward(p, b)
    cache = m.init_decode_cache(B, S)
    outs = []
    for t in range(S):
        lg, cache = m.decode_step(p, cache, b["tokens"][:, t:t + 1], jnp.int32(t))
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, axis=1)
    assert jnp.max(jnp.abs(dec - full)) < 0.02  # bf16 attention tolerance


def test_nonparametric_norm_has_no_params():
    cfg = tiny_dense(norm_type="nonparametric", tie_embeddings=True)
    m = get_model(cfg)
    p = m.init(jax.random.PRNGKey(0))
    assert p["final_norm"] == {}
    logits, _ = m.forward(p, rand_batch(cfg))
    assert bool(jnp.isfinite(logits).all())


def test_gqa_repeat_consistency():
    """MQA (kv=1) and MHA (kv=H) both run and differ from each other."""
    out = {}
    for kv in (1, 4):
        cfg = tiny_dense(name=f"kv{kv}", n_kv_heads=kv)
        m = get_model(cfg)
        p = m.init(jax.random.PRNGKey(0))
        out[kv], _ = m.forward(p, rand_batch(cfg))
    assert out[1].shape == out[4].shape


def test_moe_aux_loss_positive_and_capacity_drop():
    cfg = tiny_moe(moe_capacity_factor=0.25)  # force drops
    m = get_model(cfg)
    p = m.init(jax.random.PRNGKey(0))
    logits, aux = m.forward(p, rand_batch(cfg, B=2, S=32))
    assert float(aux) > 0
    assert bool(jnp.isfinite(logits).all())


def test_sliding_window_changes_output():
    b = rand_batch(tiny_dense(), B=1, S=32)
    full, _ = get_model(tiny_dense()).forward(
        get_model(tiny_dense()).init(jax.random.PRNGKey(0)), b)
    cfgw = tiny_dense(name="w", sliding_window=4)
    win, _ = get_model(cfgw).forward(
        get_model(cfgw).init(jax.random.PRNGKey(0)), b)
    assert not jnp.allclose(full, win, atol=1e-3)


def test_grouped_attention_matches_repeat():
    """§Perf knob: attn_impl=grouped is numerically identical (fp32)."""
    cfg1 = tiny_dense(dtype="float32", param_dtype="float32")
    cfg2 = cfg1.with_overrides(attn_impl="grouped")
    b = rand_batch(cfg1, 2, 16)
    p = get_model(cfg1).init(jax.random.PRNGKey(0))
    l1, _ = get_model(cfg1).forward(p, b)
    l2, _ = get_model(cfg2).forward(p, b)
    assert jnp.max(jnp.abs(l1 - l2)) < 1e-5


def test_bf16_softmax_close_to_fp32():
    cfg1 = tiny_dense(dtype="float32", param_dtype="float32")
    cfg2 = cfg1.with_overrides(attn_softmax_dtype="bfloat16")
    b = rand_batch(cfg1, 2, 16)
    p = get_model(cfg1).init(jax.random.PRNGKey(0))
    l1, _ = get_model(cfg1).forward(p, b)
    l2, _ = get_model(cfg2).forward(p, b)
    assert jnp.max(jnp.abs(l1 - l2)) < 0.05


def test_remat_policies_same_value_and_grad():
    import repro.training.train_step as ts
    from repro.core.lora import init_adapters, lora_scale
    cfgs = [tiny_dense(remat=True),
            tiny_dense(remat=True, remat_policy="dots"),
            tiny_dense(remat=False)]
    b = rand_batch(cfgs[0], 2, 16)
    outs = []
    for cfg in cfgs:
        m = get_model(cfg)
        p = m.init(jax.random.PRNGKey(0))
        ad = init_adapters(jax.random.PRNGKey(1), cfg)
        loss_fn = ts.make_lora_loss_fn(m, cfg)
        (l, _), g = jax.value_and_grad(loss_fn, has_aux=True)(ad, p, b)
        outs.append((float(l), g))
    assert abs(outs[0][0] - outs[1][0]) < 1e-4
    assert abs(outs[0][0] - outs[2][0]) < 1e-4
    for a, b2 in zip(jax.tree.leaves(outs[0][1]), jax.tree.leaves(outs[2][1])):
        assert jnp.allclose(a, b2, atol=1e-3)


def test_whisper_prefill_cross_matches_forward():
    cfg = FAMILIES["encdec"]
    m = get_model(cfg)
    p = m.init(jax.random.PRNGKey(0))
    b = _batch_for(cfg, 2, 8)
    full, _ = m.forward(p, b)
    from repro.models.encdec import prefill_cross
    cache = m.init_decode_cache(2, 8)
    ck, cv = prefill_cross(p, b["enc_embeds"], cfg)
    cache["cross_k"], cache["cross_v"] = ck, cv
    outs = []
    for t in range(8):
        lg, cache = m.decode_step(p, cache, b["tokens"][:, t:t + 1], jnp.int32(t))
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, axis=1)
    assert jnp.max(jnp.abs(dec - full)) < 0.05
