"""Deterministic tier-1 test sharding for CI.

Partitions ``tests/test_*.py`` into N shards balanced by measured
wall-clock weight (longest-processing-time greedy over the table below;
unknown new files get a default weight), so two parallel CI jobs finish in
roughly half the single-job time:

    python -m pytest -x -q $(python scripts/ci_shard.py --num-shards 2 --shard 0)

The partition is a pure function of the file list — stable across runs and
machines, every file lands in exactly one shard (``tests/test_ci_shard.py``
asserts it) — so a PR's two shards always cover the full suite.  Refresh
the weights occasionally from a quiet ``--durations``-style per-file run;
they only need to be *relatively* right for balance.
"""
from __future__ import annotations

import argparse
import glob
import os
import sys

# seconds per file on the reference CPU box (quiet, interpret-mode Pallas);
# balance only needs relative magnitudes
WEIGHTS = {
    "tests/test_models.py": 132,
    "tests/test_quant.py": 100,
    "tests/test_arch_smoke.py": 93,
    "tests/test_baselines.py": 64,
    "tests/test_continuous.py": 62,
    "tests/test_serving_sim.py": 60,
    "tests/test_online_update.py": 80,
    "tests/test_ragged_rank.py": 43,
    "tests/test_multitenant.py": 22,
    "tests/test_distributed.py": 21,
    "tests/test_spec_decode.py": 20,
    "tests/test_fdlora.py": 19,
    "tests/test_sched_policy.py": 18,
    "tests/test_sharded_serving.py": 16,
    "tests/test_prefix_cache.py": 16,
    "tests/test_kernels.py": 15,
    "tests/test_trace_serving.py": 9,
    "tests/test_training.py": 7,
    "tests/test_launch.py": 3,
    "tests/test_property.py": 3,
    "tests/test_ci_shard.py": 2,
    "tests/test_docs.py": 2,
}
DEFAULT_WEIGHT = 30


def discover(root: str = ".") -> list:
    files = sorted(glob.glob(os.path.join(root, "tests", "test_*.py")))
    return [os.path.relpath(f, root) for f in files]


def partition(files, num_shards: int) -> list:
    """LPT greedy: heaviest file first onto the lightest shard; ties break
    by shard index, file order by (-weight, name) — fully deterministic."""
    shards = [[] for _ in range(num_shards)]
    loads = [0.0] * num_shards
    for f in sorted(files, key=lambda f: (-WEIGHTS.get(f, DEFAULT_WEIGHT), f)):
        i = loads.index(min(loads))
        shards[i].append(f)
        loads[i] += WEIGHTS.get(f, DEFAULT_WEIGHT)
    return [sorted(s) for s in shards]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--num-shards", type=int, default=2)
    ap.add_argument("--shard", type=int, required=True)
    ap.add_argument("--root", default=".")
    args = ap.parse_args(argv)
    if not 0 <= args.shard < args.num_shards:
        ap.error(f"--shard must be in [0, {args.num_shards})")
    files = discover(args.root)
    if not files:
        print("no test files found", file=sys.stderr)
        return 1
    print(" ".join(partition(files, args.num_shards)[args.shard]))
    return 0


if __name__ == "__main__":
    sys.exit(main())
