"""Inject generated tables into EXPERIMENTS.md placeholders.

Run after the dry-run sweeps + hillclimb variants + benchmarks:
    PYTHONPATH=src python scripts/finalize_experiments.py
"""
import glob
import json
import os
import re
import sys

sys.path.insert(0, "src")
sys.path.insert(0, ".")
from benchmarks.roofline_report import load_results, markdown_table  # noqa: E402


def variant_rows(arch, shape, step="train", mesh="16x16"):
    rows = {}
    base = f"experiments/dryrun/{arch}__{shape}__{mesh}__{step}"
    for path in glob.glob(base + "*.json"):
        r = json.load(open(path))
        rows[r.get("variant", "baseline")] = r
    return rows


def perf_table(rows, order):
    out = ["| variant | compute_s | memory_s | collective_s | dominant | useful | peak_GiB |",
           "|---|---|---|---|---|---|---|"]
    for v in order:
        if v not in rows:
            continue
        r = rows[v]
        roof = r["roofline"]
        peak = (r["memory"].get("peak_bytes") or 0) / 2**30
        out.append(f"| {v} | {roof['compute_s']:.3f} | {roof['memory_s']:.3f} "
                   f"| {roof['collective_s']:.3f} | {roof['dominant']} "
                   f"| {roof['useful_ratio']:.2f} | {peak:.2f} |")
    return "\n".join(out)


def main():
    text = open("EXPERIMENTS.md").read()

    # §Roofline table (single-pod baselines)
    results = [r for r in load_results() if r.get("variant", "baseline") == "baseline"]
    text = text.replace("<!-- ROOFLINE_TABLE -->", markdown_table(results))

    # §Perf tables
    kimi = variant_rows("kimi-k2-1t-a32b", "train_4k")
    text = text.replace("<!-- PERF_KIMI -->", perf_table(
        kimi, ["baseline", "moe_cap1", "opt_moe"]))
    sc = variant_rows("starcoder2-15b", "train_4k")
    text = text.replace("<!-- PERF_STARCODER -->", perf_table(
        sc, ["baseline", "gqa_grouped", "sm_bf16", "opt_attn", "remat_dots",
             "no_remat"]))
    fd = variant_rows("llama2-7b", "train_4k", step="fdlora_round",
                      mesh="2x16x16")
    text = text.replace("<!-- PERF_FDLORA -->", perf_table(
        fd, ["baseline", "bf16_outer"]))

    # §Reproduction table from bench_output.txt if present
    if os.path.exists("bench_output.txt"):
        lines = [l for l in open("bench_output.txt")
                 if re.match(r"^(table|fig)", l)]
        repro = "```\n" + "".join(lines) + "```"
        text = text.replace("<!-- REPRO_TABLE -->", repro)

    open("EXPERIMENTS.md", "w").write(text)
    print("EXPERIMENTS.md updated:",
          len(results), "roofline rows;",
          {k: list(v) for k, v in
           [("kimi", kimi), ("starcoder", sc), ("fdlora", fd)]})


if __name__ == "__main__":
    main()
