"""Fail CI when a benchmarked serving metric regresses past tolerance.

The bench-gate CI job runs ``benchmarks/multitenant_bench.py`` (which
merges its sections into ``BENCH_serving.json``) and then this script,
which compares the fresh numbers against committed baselines.  One
manifest-driven invocation checks every gate:

    python scripts/check_bench_regression.py \
        --current BENCH_serving.json \
        --manifest benchmarks/baselines/manifest.json

The manifest lists gates as ``{"baseline": <path>, "key": <dotted>,
"max_regression": <fraction>, "direction": "higher"|"lower"}``.
``direction`` defaults to ``"higher"`` (throughput-like: fail when
``current < baseline * (1 - max_regression)``); ``"lower"`` gates
latency-like metrics (fail when ``current > baseline *
(1 + max_regression)``).  Improvements never fail in either direction —
ratchet baselines with ``--update`` when a PR legitimately moves a
workload (and justify in the PR).  ``BENCH_MAX_REGRESSION`` overrides
the tolerance of gates that do not pin their own (shared CI runners are
noisier than a quiet dev box).

The single-gate form (``--baseline`` + ``--key``) still works for local
spot checks.  ``--update`` MERGES the measured value into the baseline
file, preserving its other keys — several gates may share one file
(e.g. ``serving_trace.json`` carries both a throughput and a latency
key).
"""
from __future__ import annotations

import argparse
import json
import os
import sys


def dig(record: dict, dotted: str):
    cur = record
    for part in dotted.split("."):
        if not isinstance(cur, dict) or part not in cur:
            raise KeyError(f"key {dotted!r} not found (missing {part!r})")
        cur = cur[part]
    return cur


def merge_key(path: str, dotted: str, value, note: str | None = None) -> None:
    """Set ``dotted`` = ``value`` inside the JSON file at ``path``,
    creating it if absent and leaving every other key untouched."""
    record: dict = {}
    if os.path.exists(path):
        with open(path) as f:
            record = json.load(f)
    if note and "note" not in record:
        record["note"] = note
    cur = record
    parts = dotted.split(".")
    for part in parts[:-1]:
        cur = cur.setdefault(part, {})
    cur[parts[-1]] = value
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "w") as f:
        json.dump(record, f, indent=2)
        f.write("\n")


def check_gate(current_record: dict, baseline_path: str, key: str,
               max_regression: float, direction: str = "higher",
               update: bool = False) -> bool:
    """Run one gate; returns True when it passes (or was updated)."""
    current = dig(current_record, key)
    if update:
        merge_key(baseline_path, key, current,
                  note="bench-gate baseline; refresh with "
                       "scripts/check_bench_regression.py --update")
        print(f"baseline updated: {key} = {current:.1f} -> {baseline_path}")
        return True
    with open(baseline_path) as f:
        baseline = dig(json.load(f), key)
    ratio = current / baseline if baseline else float("inf")
    if direction == "lower":
        ceil = baseline * (1.0 + max_regression)
        ok = current <= ceil
        bound = f"ceil={ceil:.1f} at +{max_regression:.0%}"
    else:
        floor = baseline * (1.0 - max_regression)
        ok = current >= floor
        bound = f"floor={floor:.1f} at -{max_regression:.0%}"
    verdict = "OK" if ok else "REGRESSION"
    print(f"{key}: current={current:.1f} baseline={baseline:.1f} "
          f"({ratio:.2f}x, {bound}) -> {verdict}")
    return ok


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--current", default="BENCH_serving.json",
                    help="bench record produced by the current run")
    ap.add_argument("--manifest", default=None,
                    help="JSON manifest listing every gate "
                         "(benchmarks/baselines/manifest.json); replaces "
                         "--baseline/--key")
    ap.add_argument("--baseline",
                    default="benchmarks/baselines/serving_smoke.json",
                    help="committed baseline record (single-gate mode)")
    ap.add_argument("--key", default="smoke.tok_per_s",
                    help="dotted path to the gated metric "
                         "(single-gate mode)")
    ap.add_argument("--direction", choices=("higher", "lower"),
                    default="higher",
                    help="'higher' = throughput-like (drop fails); "
                         "'lower' = latency-like (rise fails)")
    env_tol = os.environ.get("BENCH_MAX_REGRESSION")
    ap.add_argument("--max-regression", type=float,
                    default=float(env_tol) if env_tol is not None else None,
                    help="allowed fractional regression; in manifest mode "
                         "this (or BENCH_MAX_REGRESSION) only overrides "
                         "gates without their own value "
                         "(single-gate default 0.25)")
    ap.add_argument("--update", action="store_true",
                    help="merge the measured value(s) into the baseline "
                         "file(s) instead of checking")
    args = ap.parse_args(argv)

    with open(args.current) as f:
        current_record = json.load(f)

    if args.manifest:
        with open(args.manifest) as f:
            manifest = json.load(f)
        gates = manifest.get("gates")
        if not gates:
            print(f"manifest {args.manifest} has no gates", file=sys.stderr)
            return 2
        failed = []
        for g in gates:
            tol = g.get("max_regression")
            if args.max_regression is not None:
                tol = args.max_regression if tol is None else tol
            if tol is None:
                tol = 0.25
            ok = check_gate(current_record, g["baseline"], g["key"],
                            float(tol), g.get("direction", "higher"),
                            update=args.update)
            if not ok:
                failed.append(g["key"])
        if failed:
            print(f"bench gate failed for {', '.join(failed)}: regressed "
                  "past tolerance; if intentional, refresh baselines with "
                  "--update and justify in the PR", file=sys.stderr)
            return 1
        return 0

    tol = args.max_regression if args.max_regression is not None else 0.25
    ok = check_gate(current_record, args.baseline, args.key, tol,
                    args.direction, update=args.update)
    if not ok:
        print("bench gate failed: metric regressed past tolerance; if "
              "intentional, refresh the baseline with --update and "
              "justify in the PR", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
