"""Fail CI when a benchmarked serving metric regresses past tolerance.

The bench-gate CI job runs ``benchmarks/multitenant_bench.py --smoke``
(which merges a ``smoke`` throughput section into ``BENCH_serving.json``)
and then this script, which compares the fresh number against the
committed baseline:

    python scripts/check_bench_regression.py \
        --current BENCH_serving.json \
        --baseline benchmarks/baselines/serving_smoke.json

Exit 1 when ``current < baseline * (1 - max_regression)``.  Improvements
never fail (ratchet the baseline with ``--update`` when a PR makes the
smoke workload legitimately faster — or slower, with justification in the
PR).  ``BENCH_MAX_REGRESSION`` overrides the tolerance without a code
change (shared CI runners are noisier than a quiet dev box).
"""
from __future__ import annotations

import argparse
import json
import os
import sys


def dig(record: dict, dotted: str):
    cur = record
    for part in dotted.split("."):
        if not isinstance(cur, dict) or part not in cur:
            raise KeyError(f"key {dotted!r} not found (missing {part!r})")
        cur = cur[part]
    return cur


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--current", default="BENCH_serving.json",
                    help="bench record produced by the current run")
    ap.add_argument("--baseline",
                    default="benchmarks/baselines/serving_smoke.json",
                    help="committed baseline record")
    ap.add_argument("--key", default="smoke.tok_per_s",
                    help="dotted path to the gated metric (higher = better)")
    ap.add_argument("--max-regression", type=float,
                    default=float(os.environ.get("BENCH_MAX_REGRESSION",
                                                 "0.25")),
                    help="allowed fractional drop (default 0.25 = 25%%)")
    ap.add_argument("--update", action="store_true",
                    help="rewrite the baseline with the current value")
    args = ap.parse_args(argv)

    with open(args.current) as f:
        current = dig(json.load(f), args.key)

    if args.update:
        nested: dict = {"note": "smoke-gate baseline; refresh with "
                                "scripts/check_bench_regression.py --update"}
        cur = nested
        parts = args.key.split(".")
        for part in parts[:-1]:
            cur = cur.setdefault(part, {})
        cur[parts[-1]] = current
        os.makedirs(os.path.dirname(args.baseline) or ".", exist_ok=True)
        with open(args.baseline, "w") as f:
            json.dump(nested, f, indent=2)
            f.write("\n")
        print(f"baseline updated: {args.key} = {current:.1f}")
        return 0

    with open(args.baseline) as f:
        baseline = dig(json.load(f), args.key)

    floor = baseline * (1.0 - args.max_regression)
    ratio = current / baseline if baseline else float("inf")
    verdict = "OK" if current >= floor else "REGRESSION"
    print(f"{args.key}: current={current:.1f} baseline={baseline:.1f} "
          f"({ratio:.2f}x, floor={floor:.1f} at "
          f"-{args.max_regression:.0%}) -> {verdict}")
    if current < floor:
        print("bench gate failed: smoke throughput regressed past "
              "tolerance; if intentional, refresh the baseline with "
              "--update and justify in the PR", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
